//! Document search: the paper's motivating use-case — nearest-neighbor
//! retrieval under the l_4 distance over term-frequency vectors, where
//! fourth-moment (kurtosis) information separates documents that l_2
//! treats as equidistant.
//!
//! Builds a sketch index over the bundled corpus, runs queries with and
//! without exact re-ranking, and reports recall + the storage saving.
//!
//! Run: `cargo run --release --example document_search`

use lpsketch::data::corpus;
use lpsketch::knn::{exact_knn, recall, KnnIndex};
use lpsketch::projection::{ProjectionDist, ProjectionSpec, Strategy};

fn main() -> anyhow::Result<()> {
    let (n, d, k, m) = (1500, 1024, 192, 10);
    println!("corpus: {n} documents, hash-TF to {d} dims; index k={k}");
    let corpus = corpus::generate(n, d, 80, 42);
    let data = &corpus.tf;

    let index = KnnIndex::build(
        data,
        ProjectionSpec::new(42, k, ProjectionDist::Normal, Strategy::Basic),
        4,
    )?;
    println!(
        "index: {:.1} KiB sketches vs {:.1} KiB raw ({:.1}x smaller)\n",
        index.bytes() as f64 / 1024.0,
        data.bytes() as f64 / 1024.0,
        data.bytes() as f64 / index.bytes() as f64
    );

    let queries = 50;
    let (mut r_plain, mut r_rerank, mut topic_hits) = (0.0, 0.0, 0);
    for qi in 0..queries {
        let qrow = (qi * 31) % n;
        let q = data.row(qrow).to_vec();
        let truth = exact_knn(data, &q, m, 4);
        let plain = index.query(&q, m);
        let reranked = index.query_rerank(data, &q, m, 10 * m);
        r_plain += recall(&plain, &truth);
        r_rerank += recall(&reranked, &truth);
        // Label agreement: do retrieved docs share the query's topic?
        topic_hits += reranked
            .iter()
            .filter(|nb| corpus.labels[nb.index] == corpus.labels[qrow])
            .count();
    }
    println!("recall@{m} (sketch only):   {:.3}", r_plain / queries as f64);
    println!("recall@{m} (+exact rerank): {:.3}", r_rerank / queries as f64);
    println!(
        "topic purity of retrieved docs: {:.3}",
        topic_hits as f64 / (queries * m) as f64
    );

    // One concrete query, printed.
    let q = data.row(17).to_vec();
    println!("\nquery = doc 17 (topic {}):", corpus.labels[17]);
    for nb in index.query_rerank(data, &q, 5, 100) {
        println!(
            "  doc {:>5}  topic {}  d={:.4e}",
            nb.index, corpus.labels[nb.index], nb.distance
        );
    }
    Ok(())
}
