//! Quickstart: sketch a small matrix, estimate a few l_4 distances, and
//! compare against the exact values.
//!
//! Run: `cargo run --release --example quickstart`

use lpsketch::baselines::exact;
use lpsketch::config::Config;
use lpsketch::coordinator::Pipeline;
use lpsketch::data::{gen, DataDist};

fn main() -> anyhow::Result<()> {
    // 1. Configure: p = 4 distance, k = 128 sketch width, basic strategy.
    let mut cfg = Config::default();
    cfg.n = 200;
    cfg.d = 2048; // high-dimensional rows — the regime sketches are for
    cfg.k = 128;
    println!("config: {}", cfg.describe());

    // 2. Some synthetic heavy-tailed non-negative data (TF-like).
    let data = gen::generate(
        DataDist::ZipfTf { exponent: 1.1, density: 0.1 },
        cfg.n,
        cfg.d,
        cfg.seed,
    );

    // 3. One linear scan: stream the matrix into O(nk) sketches.
    let pipeline = Pipeline::new(cfg)?;
    let report = pipeline.ingest(&data)?;
    println!(
        "ingested {} rows in {:.1}ms — sketches use {:.1}x less memory than the data",
        report.rows,
        report.elapsed.as_secs_f64() * 1e3,
        report.data_bytes as f64 / report.sketch_bytes as f64,
    );

    // 4. Query pairwise distances from the sketches alone.
    println!("\n pair      estimate      exact         rel.err");
    for (a, b) in [(0u64, 1u64), (2, 3), (10, 99), (42, 137)] {
        let est = pipeline.estimate_pair(a, b).expect("rows are ingested");
        let exact = exact::distance_f32(data.row(a as usize), data.row(b as usize), 4);
        println!(
            " ({a:>3},{b:>3})  {est:>12.5e}  {exact:>12.5e}  {:>7.4}",
            (est - exact).abs() / exact
        );
    }

    println!("\nmetrics: {}", pipeline.metrics().render());
    Ok(())
}
