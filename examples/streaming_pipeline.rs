//! End-to-end driver (the EXPERIMENTS.md validation run): stream a real
//! small workload through the full three-layer stack and report the
//! paper's headline metric — all-pairs l_4 cost and storage vs the exact
//! baseline — plus estimate quality and pipeline metrics.
//!
//! Exercises every layer: L1/L2 AOT artifacts via PJRT when available
//! (`--pjrt`, needs `make artifacts`), the L3 streaming coordinator with
//! backpressure, the batched query service, and the margin MLE.
//!
//! Run: `cargo run --release --example streaming_pipeline -- [--pjrt]`

use std::sync::Arc;
use std::time::Instant;

use lpsketch::baselines::exact;
use lpsketch::config::Config;
use lpsketch::coordinator::Pipeline;
use lpsketch::data::corpus;
use lpsketch::util::stats::summarize;

fn main() -> anyhow::Result<()> {
    let use_pjrt = std::env::args().any(|a| a == "--pjrt");
    let mut cfg = Config::default();
    cfg.n = 512;
    cfg.d = 1024; // matches the default artifact grid
    cfg.k = 128;
    cfg.workers = 4;
    cfg.block_rows = 64;
    cfg.use_pjrt = use_pjrt;
    println!("config: {}", cfg.describe());

    // Real small workload: the bundled document corpus.
    let corpus = corpus::generate(cfg.n, cfg.d, 80, 7);
    let data = corpus.tf;
    let p = cfg.p;

    // --- exact baseline: O(n²D) ---
    let t0 = Instant::now();
    let exact_all = exact::pairwise_condensed(&data, p, cfg.workers);
    let exact_s = t0.elapsed().as_secs_f64();
    println!("\nexact all-pairs ({} pairs): {exact_s:.3}s", exact_all.len());

    // --- sketch path: O(nD) scan + O(n²k) estimates ---
    let pipeline = Arc::new(Pipeline::new(cfg)?);
    let t1 = Instant::now();
    let report = pipeline.ingest(&data)?;
    let ingest_s = t1.elapsed().as_secs_f64();
    let t2 = Instant::now();
    let est_all = pipeline.all_pairs_condensed();
    let pairs_s = t2.elapsed().as_secs_f64();
    println!(
        "sketch path: ingest {ingest_s:.3}s ({} rows via PJRT) + all-pairs {pairs_s:.3}s \
         = {:.3}s total ({:.1}x vs exact)",
        report.pjrt_rows,
        ingest_s + pairs_s,
        exact_s / (ingest_s + pairs_s)
    );
    println!(
        "storage: {} B data → {} B sketches ({:.1}x compression)",
        report.data_bytes,
        report.sketch_bytes,
        report.data_bytes as f64 / report.sketch_bytes as f64
    );

    // --- estimate quality ---
    let rel_errs: Vec<f64> = exact_all
        .iter()
        .zip(&est_all)
        .filter(|(&e, _)| e > 0.0)
        .map(|(&e, &g)| (g - e).abs() / e)
        .collect();
    let s = summarize(&rel_errs);
    println!(
        "\nestimate rel.err over {} pairs: mean {:.3}  p50 {:.3}  p95 {:.3}",
        rel_errs.len(),
        s.mean,
        s.p50,
        s.p95
    );

    // --- batched query service (latency path) ---
    let service = pipeline.spawn_query_service();
    let t3 = Instant::now();
    let queries = 2000u64;
    for i in 0..queries {
        let a = i % data.n() as u64;
        let b = (i * 7 + 1) % data.n() as u64;
        if a != b {
            service.query(a, b)?;
        }
    }
    let q_s = t3.elapsed().as_secs_f64();
    println!(
        "\nbatched query service: {queries} queries in {q_s:.3}s ({:.0} q/s)",
        queries as f64 / q_s
    );
    println!("metrics: {}", pipeline.metrics().render());
    Ok(())
}
