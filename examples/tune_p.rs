//! Treating p as a tuning parameter (paper §1): "if there is an
//! efficient mechanism to compute the l_p distances, then it becomes
//! affordable to tune learning algorithms for many values of p".
//!
//! Demonstrates exactly that: a 1-NN classifier over the bundled corpus
//! evaluated at p = 2 (exact, cheap) and p = 4, 6 (sketched), showing
//! the higher-moment distances separating heavy-tailed documents, at
//! sketch cost rather than O(nD) per distance.
//!
//! Run: `cargo run --release --example tune_p`

use lpsketch::data::corpus;
use lpsketch::knn::{exact_knn, KnnIndex};
use lpsketch::projection::{ProjectionDist, ProjectionSpec, Strategy};

fn main() -> anyhow::Result<()> {
    let (n, d, k) = (1200usize, 1024usize, 128usize);
    let corpus = corpus::generate(n, d, 80, 99);
    let data = &corpus.tf;
    let queries: Vec<usize> = (0..120).map(|i| (i * 9 + 3) % n).collect();

    println!("1-NN topic accuracy on {n} docs (leave-self-out), {} queries:\n", queries.len());
    println!("  p   method            accuracy");
    println!("  -----------------------------------");

    // p = 2: plain Euclidean, exact (the cheap default everyone uses).
    let acc2 = accuracy_exact(&corpus, &queries, 2);
    println!("  2   exact scan        {acc2:.3}");

    // p = 4 and 6: sketched (affordable at scale), with exact re-rank.
    for p in [4usize, 6] {
        let index = KnnIndex::build(
            data,
            ProjectionSpec::new(5, k, ProjectionDist::Normal, Strategy::Basic),
            p,
        )?;
        let mut hits = 0;
        for &q in &queries {
            let got = index.query_rerank(data, data.row(q), 2, 16);
            // got[0] is the query row itself (d = 0); vote with got[1].
            let nb = got.iter().find(|nb| nb.index != q).expect("n > 1");
            hits += (corpus.labels[nb.index] == corpus.labels[q]) as usize;
        }
        println!(
            "  {p}   sketch k={k} +rr    {:.3}",
            hits as f64 / queries.len() as f64
        );
    }

    // Exact p=4/6 accuracy as the reference for the sketched versions.
    for p in [4usize, 6] {
        let acc = accuracy_exact(&corpus, &queries, p);
        println!("  {p}   exact scan        {acc:.3}");
    }

    println!(
        "\nsketch index answers each query from {k} floats/row instead of {d}; \
         tuning p costs one extra index, not another O(nD) scan per query."
    );
    Ok(())
}

fn accuracy_exact(corpus: &corpus::Corpus, queries: &[usize], p: usize) -> f64 {
    let data = &corpus.tf;
    let mut hits = 0;
    for &q in queries {
        let got = exact_knn(data, data.row(q), 2, p);
        let nb = got.iter().find(|nb| nb.index != q).expect("n > 1");
        hits += (corpus.labels[nb.index] == corpus.labels[q]) as usize;
    }
    hits as f64 / queries.len() as f64
}
