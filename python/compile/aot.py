"""AOT lowering: JAX graphs -> HLO *text* artifacts + manifest for rust.

HLO text (NOT `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's bundled
XLA (xla_extension 0.5.1) rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly. See /opt/xla-example.

The manifest is line-oriented `key=value` tokens (one artifact per line)
so the rust side needs no JSON parser:

    name=sketch_p4_b64_d1024_k128 op=sketch p=4 b=64 d=1024 k=128 \
        orders=3 moments=6 file=sketch_p4_b64_d1024_k128.hlo.txt

Run once via `make artifacts`; python never executes on the request path.
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.coeffs import moment_orders, orders

F32 = jnp.float32

# Default artifact shape grid. The rust pipeline pads row blocks to B and
# chunks/pads the feature axis to D (sketches and moments are additive over
# D-chunks), so a small fixed grid serves arbitrary data sizes.
DEFAULT_B = 64
DEFAULT_D = 1024
DEFAULT_KS = (64, 128, 256)
DEFAULT_PS = (4, 6)


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def build_artifacts(b, d, ks, ps, b2=None):
    """Yield (name, manifest_fields, lowered) for the full artifact grid."""
    b2 = b2 or b
    for p in ps:
        ns, nm = orders(p), moment_orders(p)
        for k in ks:
            name = f"sketch_p{p}_b{b}_d{d}_k{k}"
            fn = functools.partial(model.sketch_block, p=p)
            yield (
                name,
                dict(op="sketch", p=p, b=b, d=d, k=k, orders=ns, moments=nm),
                jax.jit(fn).lower(_spec(b, d), _spec(d, k)),
            )
            name = f"sketch_alt_p{p}_b{b}_d{d}_k{k}"
            fn = functools.partial(model.sketch_block_alt, p=p)
            yield (
                name,
                dict(op="sketch_alt", p=p, b=b, d=d, k=k, orders=ns, moments=nm),
                jax.jit(fn).lower(_spec(b, d), _spec(ns, d, k)),
            )
            name = f"estimate_p{p}_b{b}_k{k}"
            fn = functools.partial(model.estimate_block, p=p)
            yield (
                name,
                dict(op="estimate", p=p, b=b, b2=b2, k=k, orders=ns),
                jax.jit(fn).lower(
                    _spec(ns, b, k), _spec(ns, b2, k), _spec(b), _spec(b2)
                ),
            )
        name = f"exact_p{p}_b{b}_d{d}"
        fn = functools.partial(model.exact_block, p=p)
        yield (
            name,
            dict(op="exact", p=p, b=b, b2=b2, d=d),
            jax.jit(fn).lower(_spec(b, d), _spec(b2, d)),
        )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--b", type=int, default=DEFAULT_B)
    ap.add_argument("--d", type=int, default=DEFAULT_D)
    ap.add_argument("--ks", type=int, nargs="+", default=list(DEFAULT_KS))
    ap.add_argument("--ps", type=int, nargs="+", default=list(DEFAULT_PS))
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    lines = []
    for name, fields, lowered in build_artifacts(args.b, args.d, args.ks, args.ps):
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        fields["name"] = name
        fields["file"] = fname
        keys = ["name", "op", "p", "b", "b2", "d", "k", "orders", "moments", "file"]
        lines.append(" ".join(f"{k}={fields[k]}" for k in keys if k in fields))
        print(f"  {name}: {len(text)} chars")
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"wrote {len(lines)} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
