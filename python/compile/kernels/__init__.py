# L1: Pallas kernels for the paper's compute hot-spot + pure-jnp oracles.
from . import coeffs, estimate, ref, sketch  # noqa: F401
