"""Binomial decomposition of the even-p l_p distance (paper §1.1).

For even p,

    |x - y|^p = (x - y)^p = sum_{m=0}^{p} (-1)^(p-m) C(p, m) x^m y^(p-m)

so the distance splits into 2 marginal norms (m = 0 and m = p, coefficient
+1) and p-1 mixed "inner products" Sum_i x_i^m y_i^(p-m) with coefficient

    c_m = (-1)^m C(p, m)          (p even => (-1)^(p-m) == (-1)^m)

p = 4: c = [-4, +6, -4]           (m = 1, 2, 3)
p = 6: c = [-6, +15, -20, +15, -6] (m = 1..5)
"""

import math


def inner_coeffs(p: int) -> list[int]:
    """Coefficients c_m of Sum x^m y^(p-m) for m = 1..p-1."""
    if p < 4 or p % 2 != 0:
        raise ValueError(f"p must be even and >= 4, got {p}")
    return [(-1) ** m * math.comb(p, m) for m in range(1, p)]


def orders(p: int) -> int:
    """Number of mixed inner products (= power-sketch orders) for p."""
    return p - 1


def moment_orders(p: int) -> int:
    """Highest marginal moment the estimators/variance formulas consume.

    Lemma 1 (p=4) needs Sum x^6; Lemma 5 (p=6) needs Sum x^10 — i.e.
    moments up to 2(p-1). The sketch artifact emits all of 1..2(p-1) so a
    single linear scan powers the plain estimator, the margin MLE and the
    theoretical-variance evaluation.
    """
    return 2 * (p - 1)
