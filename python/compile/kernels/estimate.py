"""L1 Pallas kernel: pairwise estimate combine.

Turns two blocks of power sketches + exact marginal p-norms into the
B×B2 matrix of unbiased l_p^p distance estimates,

    d̂[i,j] = Σx_i^p + Σy_j^p + (1/k) Σ_{m=1}^{p-1} c_m ⟨u_m[i], v_{p-m}[j]⟩

i.e. p-1 MXU matmuls U_m V_{p-m}ᵀ fused with the rank-1 marginal add.
This is the request-path hot loop (O(n²k) work of the headline claim),
so it is a single VMEM-resident grid step for the default block sizes.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .coeffs import inner_coeffs


def _estimate_kernel(u_ref, v_ref, mx_ref, my_ref, o_ref, *, p: int, k: int):
    coeffs = inner_coeffs(p)
    acc = mx_ref[...][:, None] + my_ref[...][None, :]
    for m in range(1, p):
        c = coeffs[m - 1] / k
        acc += c * jnp.dot(u_ref[m - 1], v_ref[p - m - 1].T)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("p",))
def estimate(u, v, mx_p, my_p, *, p: int):
    """u: (p-1, B, K), v: (p-1, B2, K), mx_p: (B,), my_p: (B2,) → (B, B2)."""
    _, b, k = u.shape
    b2 = v.shape[1]
    return pl.pallas_call(
        functools.partial(_estimate_kernel, p=p, k=k),
        out_shape=jax.ShapeDtypeStruct((b, b2), u.dtype),
        interpret=True,
    )(u, v, mx_p, my_p)
