"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth.

Everything here is written in the most direct style possible (no tiling,
no fusion) so that a mismatch unambiguously implicates the kernel.
"""

import jax.numpy as jnp

from .coeffs import inner_coeffs, moment_orders, orders


def ref_powers(x, n: int):
    """Stack [x^1, x^2, ..., x^n] along a new leading axis."""
    return jnp.stack([x ** m for m in range(1, n + 1)], axis=0)


def ref_sketch(x, r, p: int):
    """Power sketches for the *basic* strategy (one shared R).

    x: (B, D) row block, r: (D, K).
    Returns u: (p-1, B, K) with u[m-1] = (x ** m) @ r.
    """
    return jnp.stack([(x ** m) @ r for m in range(1, orders(p) + 1)], axis=0)


def ref_sketch_alt(x, r_stack, p: int):
    """Power sketches for the *alternative* strategy (independent R per order).

    r_stack: (p-1, D, K); u[m-1] = (x ** m) @ r_stack[m-1].
    """
    return jnp.stack(
        [(x ** m) @ r_stack[m - 1] for m in range(1, orders(p) + 1)], axis=0
    )


def ref_moments(x, p: int):
    """Marginal moments M[m-1] = Sum_i x_i^m for m = 1..2(p-1). Shape (2(p-1), B)."""
    return jnp.stack(
        [jnp.sum(x ** m, axis=-1) for m in range(1, moment_orders(p) + 1)], axis=0
    )


def ref_estimate(u, v, mx_p, my_p, p: int):
    """Plain (no-margin-MLE) pairwise estimate matrix, both strategies.

    u: (p-1, B, K) sketches of the x rows, v: (p-1, B2, K) of the y rows,
    mx_p: (B,) exact Sum x^p per row, my_p: (B2,).
    Returns (B, B2): d_hat[i,j] per the paper's unbiased estimator.
    """
    k = u.shape[-1]
    acc = mx_p[:, None] + my_p[None, :]
    for m, c in zip(range(1, p), inner_coeffs(p)):
        acc = acc + (c / k) * (u[m - 1] @ v[p - m - 1].T)
    return acc


def ref_exact(x, y, p: int):
    """Exact pairwise l_p^p distance matrix: (B, B2)."""
    return jnp.sum(jnp.abs(x[:, None, :] - y[None, :, :]) ** p, axis=-1)
