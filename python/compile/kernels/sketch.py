"""L1 Pallas kernel: fused power-sketch + marginal-moment pass.

The paper's compute hot-spot is the linear scan that turns a row block
X (B, D) into

  * power sketches  u_m = (X^∘m) @ R   for m = 1..p-1   (the "inner
    product" estimators' raw material), and
  * marginal moments M_m = Σ_i x_i^m   for m = 1..2(p-1) (consumed by the
    plain estimator, the margin MLE of Lemma 4, and the variance
    formulas of Lemmas 1/2/5/6).

A GPU-style implementation makes p-1 (or 2p-2) passes over X. Here the
HBM→VMEM schedule (BlockSpec grid over D tiles) loads each X tile ONCE,
walks the Hadamard power ladder x, x², x³… in VMEM (VPU), and issues one
MXU matmul per sketch order against the resident R tile, accumulating
both outputs across the grid. Bandwidth win ≈ (p-1)× on the dominant
X stream — see DESIGN.md §6.

interpret=True always: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU efficiency is estimated analytically (DESIGN.md §8).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .coeffs import moment_orders, orders


def _sketch_kernel(x_ref, r_ref, u_ref, m_ref, *, n_sketch: int, n_moment: int):
    """Grid axis 0 walks D tiles; u_ref / m_ref are revisited accumulators."""

    @pl.when(pl.program_id(0) == 0)
    def _init():
        u_ref[...] = jnp.zeros_like(u_ref)
        m_ref[...] = jnp.zeros_like(m_ref)

    x = x_ref[...]          # (B, DT) — loaded into VMEM once per grid step
    r = r_ref[...]          # (DT, K) — resident for the whole ladder
    xp = x
    for m in range(1, n_moment + 1):
        if m > 1:
            xp = xp * x     # Hadamard power ladder, no extra HBM traffic
        if m <= n_sketch:
            u_ref[m - 1] += jnp.dot(xp, r)
        m_ref[m - 1] += jnp.sum(xp, axis=1)


def _sketch_alt_kernel(x_ref, r_ref, u_ref, m_ref, *, n_sketch: int, n_moment: int):
    """Alternative strategy: r_ref is (p-1, DT, K), one independent R per order."""

    @pl.when(pl.program_id(0) == 0)
    def _init():
        u_ref[...] = jnp.zeros_like(u_ref)
        m_ref[...] = jnp.zeros_like(m_ref)

    x = x_ref[...]
    xp = x
    for m in range(1, n_moment + 1):
        if m > 1:
            xp = xp * x
        if m <= n_sketch:
            u_ref[m - 1] += jnp.dot(xp, r_ref[m - 1])
        m_ref[m - 1] += jnp.sum(xp, axis=1)


def _pick_tile(d: int, target: int = 256) -> int:
    """Largest divisor of d not exceeding target (D tiles must divide D)."""
    t = min(d, target)
    while d % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("p", "d_tile"))
def sketch(x, r, *, p: int, d_tile: int | None = None):
    """Basic-strategy fused sketch. x: (B, D), r: (D, K) shared across orders.

    Returns (u, m): u (p-1, B, K), m (2(p-1), B).
    """
    b, d = x.shape
    k = r.shape[1]
    ns, nm = orders(p), moment_orders(p)
    dt = d_tile or _pick_tile(d)
    grid = (d // dt,)
    return pl.pallas_call(
        functools.partial(_sketch_kernel, n_sketch=ns, n_moment=nm),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, dt), lambda i: (0, i)),
            pl.BlockSpec((dt, k), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((ns, b, k), lambda i: (0, 0, 0)),
            pl.BlockSpec((nm, b), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((ns, b, k), x.dtype),
            jax.ShapeDtypeStruct((nm, b), x.dtype),
        ],
        interpret=True,
    )(x, r)


@functools.partial(jax.jit, static_argnames=("p", "d_tile"))
def sketch_alt(x, r_stack, *, p: int, d_tile: int | None = None):
    """Alternative-strategy fused sketch. r_stack: (p-1, D, K) independent R's."""
    b, d = x.shape
    ns, nm = orders(p), moment_orders(p)
    assert r_stack.shape[0] == ns, "need one projection matrix per order"
    k = r_stack.shape[2]
    dt = d_tile or _pick_tile(d)
    grid = (d // dt,)
    return pl.pallas_call(
        functools.partial(_sketch_alt_kernel, n_sketch=ns, n_moment=nm),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, dt), lambda i: (0, i)),
            pl.BlockSpec((ns, dt, k), lambda i: (0, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((ns, b, k), lambda i: (0, 0, 0)),
            pl.BlockSpec((nm, b), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((ns, b, k), x.dtype),
            jax.ShapeDtypeStruct((nm, b), x.dtype),
        ],
        interpret=True,
    )(x, r_stack)
