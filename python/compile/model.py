"""L2: JAX compute graphs composing the Pallas kernels.

These are the functions `aot.py` lowers to HLO text for the rust runtime.
Python (and everything in this package) runs only at build time; the rust
coordinator executes the lowered artifacts via PJRT on the request path.

Graphs
------
* sketch_block / sketch_block_alt — fused power sketch + marginal moments
  of a row block (the linear-scan pass).
* estimate_block — pairwise d-hat matrix from two sketch blocks (the
  O(n^2 k) request-path op).
* exact_block — XLA-fused exact pairwise l_p^p distances (the O(n^2 D)
  baseline of the paper's headline cost comparison, E7).
"""

import functools

import jax
import jax.numpy as jnp

from .kernels.estimate import estimate as _estimate_kernel
from .kernels.sketch import sketch as _sketch_kernel
from .kernels.sketch import sketch_alt as _sketch_alt_kernel


def sketch_block(x, r, *, p: int):
    """(u, moments) for the basic strategy: one shared R across orders."""
    return _sketch_kernel(x, r, p=p)


def sketch_block_alt(x, r_stack, *, p: int):
    """(u, moments) for the alternative strategy: independent R per order."""
    return _sketch_alt_kernel(x, r_stack, p=p)


def estimate_block(u, v, mx_p, my_p, *, p: int):
    """Pairwise unbiased estimate matrix (B, B2)."""
    return _estimate_kernel(u, v, mx_p, my_p, p=p)


@functools.partial(jax.jit, static_argnames=("p",))
def exact_block(x, y, *, p: int):
    """Exact pairwise l_p^p distances; vmapped over rows to bound memory."""
    def row(xi):
        return jnp.sum(jnp.abs(xi[None, :] - y) ** p, axis=-1)

    return jax.vmap(row)(x)
