"""Analytical TPU performance model for the L1 Pallas kernels.

interpret=True gives CPU-numpy timings only — not a TPU proxy — so the
kernels' TPU efficiency is *estimated* from their BlockSpec schedule:
VMEM residency, HBM traffic, and MXU/VPU work. This is the §Perf L1
instrument (DESIGN.md §8): it reports whether a (B, D_tile, K) schedule
fits VMEM, its arithmetic intensity, and the roofline-implied MXU
utilization, and it verifies the fused ladder's claimed (p−1)× bandwidth
win over the naive per-order passes.

Reference machine: TPU v4-ish — 16 MiB VMEM/core, 1.2 TB/s HBM,
137.5 bf16-TFLOP/s per core (f32 ≈ half). Constants are parameters, not
oracles; the *ratios* are what the perf targets check.
"""

from dataclasses import dataclass


@dataclass(frozen=True)
class Chip:
    """Per-core hardware envelope."""

    vmem_bytes: int = 16 * 2**20
    hbm_bw: float = 1.2e12  # B/s
    peak_flops: float = 137.5e12 / 2  # f32 MXU FLOP/s

    @property
    def ridge_intensity(self) -> float:
        """FLOP/byte at which compute and bandwidth balance."""
        return self.peak_flops / self.hbm_bw


@dataclass(frozen=True)
class SketchSchedule:
    """One grid step of the fused sketch kernel (sketch.py).

    Per step the kernel holds: the X tile (B, DT), the R tile (DT, K),
    one power buffer (B, DT), the U accumulators (p-1, B, K) and the
    moment accumulators (2(p-1), B) — all f32.
    """

    b: int
    d: int
    d_tile: int
    k: int
    p: int
    dtype_bytes: int = 4

    @property
    def orders(self) -> int:
        return self.p - 1

    @property
    def moment_orders(self) -> int:
        return 2 * (self.p - 1)

    def vmem_bytes(self) -> int:
        x = self.b * self.d_tile
        r = self.d_tile * self.k
        power = self.b * self.d_tile
        u = self.orders * self.b * self.k
        m = self.moment_orders * self.b
        return (x + r + power + u + m) * self.dtype_bytes

    def fits(self, chip: Chip, head_room: float = 0.5) -> bool:
        """Double-buffered tiles must fit in a VMEM fraction."""
        return 2 * self.vmem_bytes() <= head_room * chip.vmem_bytes

    def hbm_bytes(self) -> int:
        """Fused schedule: X and R stream once; outputs written once."""
        x = self.b * self.d
        r = self.d * self.k
        out = self.orders * self.b * self.k + self.moment_orders * self.b
        return (x + r + out) * self.dtype_bytes

    def hbm_bytes_naive(self) -> int:
        """Per-order passes (GPU-style): X re-streamed for every sketch
        order and once more for the moment scan; R re-streamed per order."""
        x = (self.orders + 1) * self.b * self.d
        r = self.orders * self.d * self.k
        out = self.orders * self.b * self.k + self.moment_orders * self.b
        return (x + r + out) * self.dtype_bytes

    def flops(self) -> int:
        """MXU matmuls (2·B·D·K per order) + VPU ladder (D·B per power)."""
        mxu = 2 * self.orders * self.b * self.d * self.k
        vpu = self.moment_orders * self.b * self.d * 2  # mul + moment add
        return mxu + vpu

    def intensity(self) -> float:
        return self.flops() / self.hbm_bytes()

    def mxu_utilization(self, chip: Chip) -> float:
        """Roofline: min(1, intensity/ridge) — the fraction of peak the
        schedule can sustain if the MXU pipeline is otherwise perfect."""
        return min(1.0, self.intensity() / chip.ridge_intensity)

    def bandwidth_win(self) -> float:
        """The fused ladder's HBM-traffic advantage over naive passes."""
        return self.hbm_bytes_naive() / self.hbm_bytes()


@dataclass(frozen=True)
class EstimateSchedule:
    """The pairwise-combine kernel: p−1 GEMMs (B,K)x(K,B2) + rank-1 add."""

    b: int
    b2: int
    k: int
    p: int
    dtype_bytes: int = 4

    def vmem_bytes(self) -> int:
        u = (self.p - 1) * self.b * self.k
        v = (self.p - 1) * self.b2 * self.k
        out = self.b * self.b2
        margins = self.b + self.b2
        return (u + v + out + margins) * self.dtype_bytes

    def fits(self, chip: Chip, head_room: float = 0.5) -> bool:
        return 2 * self.vmem_bytes() <= head_room * chip.vmem_bytes

    def hbm_bytes(self) -> int:
        return self.vmem_bytes()  # single grid step: everything streams once

    def flops(self) -> int:
        return 2 * (self.p - 1) * self.b * self.b2 * self.k + 2 * self.b * self.b2

    def intensity(self) -> float:
        return self.flops() / self.hbm_bytes()

    def mxu_utilization(self, chip: Chip) -> float:
        return min(1.0, self.intensity() / chip.ridge_intensity)


def report(b=64, d=1024, d_tile=256, ks=(64, 128, 256), ps=(4, 6)) -> str:
    """The §8 table: one row per artifact shape."""
    chip = Chip()
    lines = [
        f"chip: vmem={chip.vmem_bytes >> 20}MiB hbm={chip.hbm_bw / 1e12:.1f}TB/s "
        f"peak={chip.peak_flops / 1e12:.1f}TF/s ridge={chip.ridge_intensity:.0f} FLOP/B",
        f"{'kernel':<22}{'vmem':>8}{'fits':>6}{'int.':>7}{'mxu%':>6}{'bw win':>8}",
    ]
    for p in ps:
        for k in ks:
            s = SketchSchedule(b=b, d=d, d_tile=d_tile, k=k, p=p)
            lines.append(
                f"sketch p={p} k={k:<10}{s.vmem_bytes() >> 10:>6}Ki"
                f"{str(s.fits(chip)):>6}{s.intensity():>7.1f}"
                f"{100 * s.mxu_utilization(chip):>6.1f}{s.bandwidth_win():>7.2f}x"
            )
            e = EstimateSchedule(b=b, b2=b, k=k, p=p)
            lines.append(
                f"estimate p={p} k={k:<8}{e.vmem_bytes() >> 10:>6}Ki"
                f"{str(e.fits(chip)):>6}{e.intensity():>7.1f}"
                f"{100 * e.mxu_utilization(chip):>6.1f}{'':>8}"
            )
    return "\n".join(lines)


if __name__ == "__main__":
    print(report())
