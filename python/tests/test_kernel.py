"""Kernel vs pure-jnp oracle — the CORE L1 correctness signal.

Hypothesis sweeps shapes/dtypes/strategies of the Pallas kernels and
asserts allclose against ref.py; plus deterministic edge cases and the
decomposition identities the whole method rests on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import coeffs, ref
from compile.kernels.estimate import estimate
from compile.kernels.sketch import _pick_tile, sketch, sketch_alt

F32 = jnp.float32


def rand(key, *shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, dtype=F32)


# ---------------------------------------------------------------- coeffs


def test_coeffs_p4():
    assert coeffs.inner_coeffs(4) == [-4, 6, -4]
    assert coeffs.orders(4) == 3
    assert coeffs.moment_orders(4) == 6


def test_coeffs_p6():
    assert coeffs.inner_coeffs(6) == [-6, 15, -20, 15, -6]


@pytest.mark.parametrize("p", [3, 5, 2, 0, 7])
def test_coeffs_rejects_bad_p(p):
    with pytest.raises(ValueError):
        coeffs.inner_coeffs(p)


@pytest.mark.parametrize("p", [4, 6, 8, 10])
def test_binomial_identity(p):
    # Sum over the full binomial row at x=y=1: (1-1)^p = 0.
    total = 2 + sum(coeffs.inner_coeffs(p))  # marginals carry +1 each
    assert total == 0


@pytest.mark.parametrize("p", [4, 6, 8])
def test_decomposition_reconstructs_distance(p):
    x = np.random.RandomState(0).rand(37)
    y = np.random.RandomState(1).rand(37)
    direct = np.sum(np.abs(x - y) ** p)
    via = np.sum(x**p) + np.sum(y**p) + sum(
        c * np.sum(x**m * y ** (p - m))
        for m, c in zip(range(1, p), coeffs.inner_coeffs(p))
    )
    np.testing.assert_allclose(via, direct, rtol=1e-10)


# ---------------------------------------------------------------- sketch


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 8),
    d=st.sampled_from([4, 12, 32, 96]),
    k=st.integers(1, 16),
    p=st.sampled_from([4, 6]),
    seed=st.integers(0, 2**31),
)
def test_sketch_matches_ref(b, d, k, p, seed):
    x = rand(seed, b, d)
    r = rand(seed + 1, d, k)
    u, m = sketch(x, r, p=p)
    np.testing.assert_allclose(u, ref.ref_sketch(x, r, p), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(m, ref.ref_moments(x, p), rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 6),
    d=st.sampled_from([8, 24, 64]),
    k=st.integers(1, 12),
    p=st.sampled_from([4, 6]),
    seed=st.integers(0, 2**31),
)
def test_sketch_alt_matches_ref(b, d, k, p, seed):
    x = rand(seed, b, d)
    r_stack = rand(seed + 2, coeffs.orders(p), d, k)
    u, m = sketch_alt(x, r_stack, p=p)
    np.testing.assert_allclose(
        u, ref.ref_sketch_alt(x, r_stack, p), rtol=2e-4, atol=2e-4
    )
    np.testing.assert_allclose(m, ref.ref_moments(x, p), rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(
    d=st.sampled_from([32, 60, 96]),
    tile=st.sampled_from([None, 4, 16]),
    seed=st.integers(0, 2**31),
)
def test_sketch_tile_invariance(d, tile, seed):
    # The D-grid schedule must not change the numbers.
    if tile is not None and d % tile != 0:
        tile = _pick_tile(d, tile)
    x = rand(seed, 4, d)
    r = rand(seed + 1, d, 8)
    u_t, m_t = sketch(x, r, p=4, d_tile=tile)
    u_full, m_full = sketch(x, r, p=4, d_tile=d)
    np.testing.assert_allclose(u_t, u_full, rtol=2e-4, atol=1e-4)
    np.testing.assert_allclose(m_t, m_full, rtol=2e-4, atol=1e-4)


def test_sketch_zero_input():
    u, m = sketch(jnp.zeros((3, 16)), jnp.ones((16, 4)), p=4)
    assert not np.asarray(u).any()
    assert not np.asarray(m).any()


def test_pick_tile_divides():
    for d in [7, 64, 100, 1024, 777]:
        t = _pick_tile(d)
        assert d % t == 0 and 1 <= t <= min(d, 256)


# -------------------------------------------------------------- estimate


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 6),
    b2=st.integers(1, 6),
    k=st.integers(1, 16),
    p=st.sampled_from([4, 6]),
    seed=st.integers(0, 2**31),
)
def test_estimate_matches_ref(b, b2, k, p, seed):
    u = rand(seed, p - 1, b, k)
    v = rand(seed + 1, p - 1, b2, k)
    mx = jnp.abs(rand(seed + 2, b))
    my = jnp.abs(rand(seed + 3, b2))
    got = estimate(u, v, mx, my, p=p)
    want = ref.ref_estimate(u, v, mx, my, p)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("p", [4, 6])
def test_sketch_then_estimate_is_unbiased_mc(p):
    # End-to-end: mean over many projections approaches the exact
    # distance (the paper's core claim at kernel level).
    d, k, reps = 24, 16, 400
    x = jnp.abs(rand(3, 2, d))
    mx = jnp.sum(x**p, axis=-1)
    exact = ref.ref_exact(x, x, p)  # 2x2, off-diagonal is d(x0, x1)
    est = np.zeros((2, 2))
    for rep in range(reps):
        r = rand(1000 + rep, d, k)
        u, _ = sketch(x, r, p=p)
        est += np.asarray(estimate(u, u, mx, mx, p=p))
    est /= reps
    # Diagonal must be ~0; off-diagonal within MC error (~1/sqrt(reps)).
    target = float(exact[0, 1])
    assert abs(est[0, 1] - target) / target < 0.2
    assert abs(est[0, 0]) < 0.05 * target


def test_exact_block_identity():
    x = jnp.abs(rand(5, 3, 10))
    d = ref.ref_exact(x, x, 4)
    assert np.allclose(np.diag(d), 0.0, atol=1e-5)
    # Symmetry of the exact distance matrix on identical sets.
    np.testing.assert_allclose(d, d.T, rtol=1e-5, atol=1e-6)


# ----------------------------------------------------------------- model


def test_model_shapes():
    from compile import model

    b, d, k, p = 4, 32, 8, 4
    x = rand(0, b, d)
    r = rand(1, d, k)
    u, m = model.sketch_block(x, r, p=p)
    assert u.shape == (p - 1, b, k)
    assert m.shape == (2 * (p - 1), b)
    e = model.estimate_block(u, u, m[p - 1], m[p - 1], p=p)
    assert e.shape == (b, b)
    ex = model.exact_block(x, x, p=p)
    assert ex.shape == (b, b)


def test_model_estimate_consistent_with_ref():
    from compile import model

    b, d, k, p = 3, 20, 6, 4
    x = jnp.abs(rand(5, b, d))
    r = rand(6, d, k)
    u, m = model.sketch_block(x, r, p=p)
    got = model.estimate_block(u, u, m[p - 1], m[p - 1], p=p)
    want = ref.ref_estimate(u, u, m[p - 1], m[p - 1], p)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------------ aot


def test_aot_hlo_text_roundtrip():
    # Lower a small artifact grid and sanity-check the HLO text output.
    from compile import aot

    arts = list(aot.build_artifacts(b=4, d=16, ks=[4], ps=[4]))
    names = [a[0] for a in arts]
    assert "sketch_p4_b4_d16_k4" in names
    assert "estimate_p4_b4_k4" in names
    assert "exact_p4_b4_d16" in names
    for name, fields, lowered in arts:
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), name
        assert "f32" in text
        assert fields["op"] in ("sketch", "sketch_alt", "estimate", "exact")
