"""Tests for the analytical TPU schedule model (DESIGN.md §8)."""

from compile.tpu_model import Chip, EstimateSchedule, SketchSchedule, report


def default_sketch(**kw):
    args = dict(b=64, d=1024, d_tile=256, k=128, p=4)
    args.update(kw)
    return SketchSchedule(**args)


def test_default_artifact_grid_fits_vmem():
    chip = Chip()
    for p in (4, 6):
        for k in (64, 128, 256):
            assert default_sketch(k=k, p=p).fits(chip), (p, k)
            assert EstimateSchedule(b=64, b2=64, k=k, p=p).fits(chip), (p, k)


def test_vmem_grows_with_tile_and_k():
    s = default_sketch()
    assert default_sketch(d_tile=512).vmem_bytes() > s.vmem_bytes()
    assert default_sketch(k=256).vmem_bytes() > s.vmem_bytes()


def test_oversized_tile_rejected():
    chip = Chip()
    huge = default_sketch(b=512, d_tile=4096, k=512)
    assert not huge.fits(chip)


def test_bandwidth_win_approaches_p_minus_1():
    # The fused ladder streams X once instead of (p-1)+1 times; with K
    # << D the X stream dominates, so the win approaches p (orders + the
    # moment pass) as K/D -> 0 and is > 2 for the default shapes.
    s4 = default_sketch(k=64)
    assert 2.0 < s4.bandwidth_win() <= s4.p
    s6 = default_sketch(k=64, p=6)
    assert s6.bandwidth_win() > s4.bandwidth_win()


def test_hbm_accounting_consistent():
    s = default_sketch()
    assert s.hbm_bytes_naive() > s.hbm_bytes()
    # Fused traffic = inputs + outputs, exactly once.
    expected = 4 * (s.b * s.d + s.d * s.k + s.orders * s.b * s.k + s.moment_orders * s.b)
    assert s.hbm_bytes() == expected


def test_intensity_increases_with_k():
    # More MXU work per X byte as K grows.
    assert default_sketch(k=256).intensity() > default_sketch(k=64).intensity()


def test_mxu_utilization_bounded():
    chip = Chip()
    for k in (16, 64, 256, 1024):
        u = default_sketch(k=k).mxu_utilization(chip)
        assert 0.0 < u <= 1.0


def test_estimate_is_compute_bound_at_large_k():
    chip = Chip()
    e = EstimateSchedule(b=256, b2=256, k=512, p=4)
    # Large square blocks at wide k push the GEMMs past the ridge.
    assert e.intensity() > 0.5 * chip.ridge_intensity


def test_report_renders():
    text = report()
    assert "sketch p=4" in text
    assert "estimate p=6" in text
    assert "ridge" in text
