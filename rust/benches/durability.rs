//! Durability-layer benchmarks — the ISSUE 7 acceptance numbers,
//! recorded machine-readably in `BENCH_durability.json`:
//!
//!   * ingest throughput with the WAL off vs on (the per-batch
//!     append+fsync is the entire price of the ack guarantee)
//!   * recovery (`Durability::open`) time as a function of WAL length,
//!     for an unsealed WAL tail (full replay) and for the same rows
//!     after a seal (segment-file adoption, near-empty WAL)
//!
//! Works against scratch directories under the system temp dir;
//! `LPSKETCH_BENCH_FAST=1` shrinks sizes for CI.

use std::collections::HashSet;
use std::path::PathBuf;
use std::sync::Arc;

use lpsketch::bench_support::{bench, fmt_duration, Table};
use lpsketch::config::Config;
use lpsketch::coordinator::{Durability, MetaShape, Pipeline, RealFs};
use lpsketch::data::{gen, DataDist};
use lpsketch::projection::sketcher::Sketcher;

fn fresh_root(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join("lpsketch_durability_bench")
        .join(format!("{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn wal_files(root: &std::path::Path) -> HashSet<PathBuf> {
    std::fs::read_dir(root.join("wal"))
        .map(|rd| rd.filter_map(|e| e.ok().map(|e| e.path())).collect())
        .unwrap_or_default()
}

/// Remove WAL files a benchmarked reopen created beyond `baseline`, so
/// repeated recoveries measure a stable directory instead of an
/// ever-growing pile of header-only logs.
fn prune_wal(root: &std::path::Path, baseline: &HashSet<PathBuf>) {
    for path in wal_files(root) {
        if !baseline.contains(&path) {
            let _ = std::fs::remove_file(&path);
        }
    }
}

fn main() {
    let fast = std::env::var("LPSKETCH_BENCH_FAST").as_deref() == Ok("1");
    let mut table = Table::new(&["path", "config", "mean", "p95", "throughput"]);

    let mut cfg = Config::default();
    let (n, d, k) = if fast { (128usize, 64usize, 16usize) } else { (256, 64, 32) };
    cfg.n = n;
    cfg.d = d;
    cfg.k = k;
    cfg.p = 4;
    cfg.block_rows = 32;
    cfg.workers = 2;
    cfg.compact_min_rows = 0; // isolate the append path from compaction
    let shape = MetaShape::from_config(&cfg);
    let data = gen::generate(DataDist::Gaussian, n, d, 7);

    // -- Ingest throughput, WAL off vs on ---------------------------------
    // Both arms repeatedly ingest the same batch into a growing store, so
    // the only difference between them is the durability append+fsync per
    // acknowledged batch.
    let plain = Pipeline::new(cfg.clone()).unwrap();
    let m_off = bench("ingest/wal_off", Some(n as u64), || {
        plain.ingest(&data).unwrap();
    });
    table.row(&[
        "ingest".into(),
        format!("wal off n={n} d={d} k={k}"),
        fmt_duration(m_off.mean),
        fmt_duration(m_off.p95),
        format!("{:.1} Krows/s", m_off.throughput().unwrap() / 1e3),
    ]);

    let ingest_root = fresh_root("ingest_on");
    let opened = Durability::open(Arc::new(RealFs), &ingest_root, shape, cfg.workers).unwrap();
    let mut durable_pipeline =
        Pipeline::with_store_restored(cfg.clone(), opened.store, true).unwrap();
    durable_pipeline.attach_durability(Arc::new(opened.durability));
    let m_on = bench("ingest/wal_on", Some(n as u64), || {
        durable_pipeline.ingest(&data).unwrap();
    });
    table.row(&[
        "ingest".into(),
        format!("wal on n={n} d={d} k={k}"),
        fmt_duration(m_on.mean),
        fmt_duration(m_on.p95),
        format!("{:.1} Krows/s", m_on.throughput().unwrap() / 1e3),
    ]);
    let overhead = m_on.mean.as_secs_f64() / m_off.mean.as_secs_f64();
    println!(
        "durable ingest overhead: {overhead:.2}x ({} -> {})",
        fmt_duration(m_off.mean),
        fmt_duration(m_on.mean),
    );
    drop(durable_pipeline);
    let _ = std::fs::remove_dir_all(&ingest_root);

    // -- Recovery time vs WAL length --------------------------------------
    // One pre-sketched block logged at disjoint bases; `nblocks` scales
    // the log. The sealed arm recovers the same rows from segment files
    // (the post-compaction steady state), pricing what the seal buys.
    let block_rows = 64usize;
    let sk = Sketcher::new(cfg.projection_spec(), cfg.p);
    let bdata = gen::generate(DataDist::Gaussian, block_rows, d, 9);
    let brefs: Vec<&[f32]> = (0..block_rows).map(|i| bdata.row(i)).collect();
    let block = sk.sketch_block(&brefs, 1);
    let block_counts: &[usize] = if fast { &[2, 8] } else { &[2, 8, 32] };
    let mut recovery_json: Vec<String> = Vec::new();
    for &nblocks in block_counts {
        let rows = nblocks * block_rows;
        let root = fresh_root(&format!("rc_{nblocks}"));
        {
            let o = Durability::open(Arc::new(RealFs), &root, shape, cfg.workers).unwrap();
            for b in 0..nblocks {
                let base = (b * block_rows) as u64;
                o.store.insert_block_columnar(base, block.clone());
                o.durability.log_block(base, &block).unwrap();
            }
        }
        for sealed in [false, true] {
            if sealed {
                let o = Durability::open(Arc::new(RealFs), &root, shape, cfg.workers).unwrap();
                o.durability.seal(&o.store).unwrap();
            }
            let state = if sealed { "sealed" } else { "wal_tail" };
            let baseline = wal_files(&root);
            let m = bench(&format!("recover/{state}_{nblocks}"), Some(rows as u64), || {
                let o = Durability::open(Arc::new(RealFs), &root, shape, cfg.workers).unwrap();
                assert_eq!(o.store.len(), rows);
                drop(o);
                prune_wal(&root, &baseline);
            });
            table.row(&[
                "recover".into(),
                format!("{state} blocks={nblocks} rows={rows}"),
                fmt_duration(m.mean),
                fmt_duration(m.p95),
                format!("{:.1} Krows/s", m.throughput().unwrap() / 1e3),
            ]);
            recovery_json.push(format!(
                "    {{\"state\": \"{state}\", \"blocks\": {nblocks}, \"rows\": {rows}, \
                 \"mean_s\": {:.6e}, \"rows_per_s\": {:.1}}}",
                m.mean.as_secs_f64(),
                m.throughput().unwrap(),
            ));
        }
        let _ = std::fs::remove_dir_all(&root);
    }

    let json = format!(
        "{{\n  \"bench\": \"durability\",\n  \"n\": {n},\n  \"d\": {d},\n  \"k\": {k},\n  \
         \"p\": 4,\n  \"block_rows_recovery\": {block_rows},\n  \"ingest\": [\n    \
         {{\"path\": \"wal_off\", \"mean_s\": {:.6e}, \"rows_per_s\": {:.1}}},\n    \
         {{\"path\": \"wal_on\", \"mean_s\": {:.6e}, \"rows_per_s\": {:.1}}}\n  ],\n  \
         \"wal_overhead_x\": {overhead:.2},\n  \"recovery\": [\n{}\n  ]\n}}\n",
        m_off.mean.as_secs_f64(),
        m_off.throughput().unwrap(),
        m_on.mean.as_secs_f64(),
        m_on.throughput().unwrap(),
        recovery_json.join(",\n"),
    );
    if let Err(e) = std::fs::write("BENCH_durability.json", &json) {
        eprintln!("(could not write BENCH_durability.json: {e})");
    } else {
        println!("wrote BENCH_durability.json");
    }

    table.print();
}
