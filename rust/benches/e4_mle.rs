//! Bench target regenerating paper experiment e4 (see DESIGN.md §4).
//! Full sweep by default; set LPSKETCH_BENCH_FAST=1 for the short grid.

fn main() {
    let fast = std::env::var("LPSKETCH_BENCH_FAST").as_deref() == Ok("1");
    let acc = lpsketch::experiments::run("e4", fast).expect("experiment runs");
    let ok = lpsketch::experiments::common::report(&acc);
    if !ok {
        std::process::exit(1);
    }
}
