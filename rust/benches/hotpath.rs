//! Hot-path micro-benchmarks — the instrument for the §Perf pass
//! (EXPERIMENTS.md). Measures each layer in isolation:
//!   * L3 sketch-ingest path: per-row reference vs the register-tiled
//!     GEMM block kernel, by distribution (dense/sparse) — the ISSUE 2
//!     acceptance (GEMM ≥ 2× per-row at n=256, d=1024, k=128, p=4,
//!     Normal), recorded machine-readably in `BENCH_ingest.json`
//!   * L3 estimate path: plain vs MLE combine, pairs/s
//!   * SIMD dispatch + quantized panels: scalar vs vector kernels on
//!     the dense ingest block and the fused top-k scan (bitwise
//!     equality guard — the reduction-order contract), plus the
//!     f16/bf16/i8 panel-encoding ablation (bytes/row, scan
//!     throughput, empirical ε under the analytic dot bound) — the
//!     ISSUE 9 acceptance, recorded in `BENCH_simd.json`
//!   * arena vs per-row: blocked batch estimation + fused top-k on the
//!     columnar arena against the per-row reference (the ISSUE 1
//!     acceptance: ≥3× at n=10⁴, k=64, p=4)
//!   * zone-pruned top-k: the zone-map scan vs the full fused scan vs
//!     per-row scoring, across population skew levels (the ISSUE 8
//!     acceptance — pruned must equal full bitwise, and skewed
//!     populations must record >0 skipped segments; `BENCH_topk.json`)
//!   * typed API: one pair batch through the direct path, the typed
//!     in-process dispatch, the batched query service, and a TCP
//!     loopback client (equality-guarded; `BENCH_api.json`)
//!   * PJRT dispatch: artifact sketch/estimate per block (needs
//!     `make artifacts`; skipped if absent)
//!   * store: insert + pair-visit

use std::path::Path;

use lpsketch::bench_support::{bench, fmt_duration, Table};
use lpsketch::config::Config;
use lpsketch::coordinator::{Pipeline, SketchStore};
use lpsketch::core::arena::SketchArena;
use lpsketch::core::decompose::Decomposition;
use lpsketch::core::estimator;
use lpsketch::core::mle::{self, Solve};
use lpsketch::data::{gen, DataDist};
use lpsketch::projection::sketcher::Sketcher;
use lpsketch::projection::{ProjectionDist, ProjectionSpec, Strategy};
use lpsketch::runtime::{Engine, OpKind, OwnedInput};

fn main() {
    let mut table = Table::new(&["path", "config", "mean", "p95", "throughput"]);
    let (n, d, k) = (256usize, 1024usize, 128usize);
    let data = gen::generate(DataDist::ZipfTf { exponent: 1.1, density: 0.1 }, n, d, 7);
    let rows: Vec<&[f32]> = (0..n).map(|i| data.row(i)).collect();

    // L3 sketch-ingest throughput: the per-row reference path vs the
    // GEMM block kernel (w=1 isolates the kernel; w=N is the standalone
    // batch API as deployed). Dense (Gaussian) data exercises the
    // register-tiled route — the ISSUE 2 acceptance (≥2× at n=256,
    // d=1024, k=128, p=4, Normal) reads those rows; the ZipfTf arm
    // exercises the sparse-data axpy route, where the block path must
    // hold parity with the zero-skipping baseline. All arms land in
    // BENCH_ingest.json for the perf trajectory.
    let ingest_workers = std::thread::available_parallelism().map_or(1, |w| w.get());
    let dense_data = gen::generate(DataDist::Gaussian, n, d, 8);
    let dense_rows: Vec<&[f32]> = (0..n).map(|i| dense_data.row(i)).collect();
    let mut ingest_json: Vec<String> = Vec::new();
    let mut ingest_speedups: Vec<String> = Vec::new();
    for (name, dist, batch) in [
        ("normal", ProjectionDist::Normal, &dense_rows),
        ("uniform", ProjectionDist::Uniform, &dense_rows),
        ("3pt_s3", ProjectionDist::ThreePoint(3.0), &dense_rows),
        ("3pt_s100", ProjectionDist::ThreePoint(100.0), &dense_rows),
        ("normal_zipf", ProjectionDist::Normal, &rows),
    ] {
        let sk = Sketcher::new(ProjectionSpec::new(1, k, dist, Strategy::Basic), 4);
        // TOLERANCE guard before timing: the tiled kernel legitimately
        // reorders the f32 accumulation relative to the per-row
        // reference, so agreement is a relative band, not bitwise. The
        // BITWISE guards (scalar vs SIMD under the shared
        // reduction-order contract) live in the simd section below.
        {
            let probe = 8.min(n);
            let want = sk.sketch_rows(&batch[..probe]);
            let got = sk.sketch_block(&batch[..probe], 2);
            for (r, rs) in want.iter().enumerate() {
                for m in 1..4 {
                    for (a, b) in got.u_row(m, r).iter().zip(rs.uside.u(m)) {
                        assert!(
                            (a - b).abs() <= 1e-3 * (1.0 + b.abs()),
                            "gemm mismatch {name} r={r} m={m}: {a} vs {b}"
                        );
                    }
                }
            }
        }
        let mut arms: Vec<(String, lpsketch::bench_support::Measurement)> = vec![
            (
                "per_row".to_string(),
                bench(&format!("ingest/{name}/per_row"), Some((n * d) as u64), || {
                    std::hint::black_box(sk.sketch_rows(batch));
                }),
            ),
            (
                "gemm_w1".to_string(),
                bench(&format!("ingest/{name}/gemm_w1"), Some((n * d) as u64), || {
                    std::hint::black_box(sk.sketch_block(batch, 1));
                }),
            ),
        ];
        // Only a distinct multi-worker arm — on a 1-CPU box it would
        // duplicate the gemm_w1 label (and JSON keys) for no information.
        if ingest_workers > 1 {
            arms.push((
                format!("gemm_w{ingest_workers}"),
                bench(&format!("ingest/{name}/gemm_wN"), Some((n * d) as u64), || {
                    std::hint::black_box(sk.sketch_block(batch, ingest_workers));
                }),
            ));
        }
        for (path, m) in &arms {
            table.row(&[
                "ingest".into(),
                format!("{name} {path} n={n} d={d} k={k}"),
                fmt_duration(m.mean),
                fmt_duration(m.p95),
                format!("{:.1} Melem/s", m.throughput().unwrap() / 1e6),
            ]);
            ingest_json.push(format!(
                "    {{\"dist\": \"{name}\", \"path\": \"{path}\", \"mean_s\": {:.6e}, \
                 \"rows_per_s\": {:.1}, \"melem_per_s\": {:.2}}}",
                m.mean.as_secs_f64(),
                n as f64 / m.mean.as_secs_f64(),
                m.throughput().unwrap() / 1e6,
            ));
        }
        let per_row_s = arms[0].1.mean.as_secs_f64();
        let w1 = per_row_s / arms[1].1.mean.as_secs_f64();
        if let Some(wn_arm) = arms.get(2) {
            let wn = per_row_s / wn_arm.1.mean.as_secs_f64();
            ingest_speedups.push(format!(
                "    {{\"dist\": \"{name}\", \"gemm_w1\": {w1:.2}, \
                 \"gemm_w{ingest_workers}\": {wn:.2}}}"
            ));
            println!("ingest {name}: gemm speedup {w1:.1}x (w=1), {wn:.1}x (w={ingest_workers})");
        } else {
            ingest_speedups.push(format!("    {{\"dist\": \"{name}\", \"gemm_w1\": {w1:.2}}}"));
            println!("ingest {name}: gemm speedup {w1:.1}x (w=1)");
        }
    }
    let json = format!(
        "{{\n  \"bench\": \"ingest\",\n  \"n\": {n},\n  \"d\": {d},\n  \"k\": {k},\n  \
         \"p\": 4,\n  \"workers\": {ingest_workers},\n  \"data\": \
         {{\"default\": \"gaussian (dense)\", \"normal_zipf\": \"zipf-tf density 0.1 (sparse)\"}},\n  \
         \"results\": [\n{}\n  ],\n  \"speedup\": [\n{}\n  ]\n}}\n",
        ingest_json.join(",\n"),
        ingest_speedups.join(",\n"),
    );
    if let Err(e) = std::fs::write("BENCH_ingest.json", &json) {
        eprintln!("(could not write BENCH_ingest.json: {e})");
    } else {
        println!("wrote BENCH_ingest.json");
    }

    // L3 estimate throughput: plain vs one-step MLE.
    let sk = Sketcher::new(ProjectionSpec::new(1, k, ProjectionDist::Normal, Strategy::Basic), 4);
    let sketches = sk.sketch_rows(&rows);
    let dec = Decomposition::new(4).unwrap();
    let pairs: Vec<(usize, usize)> =
        (0..n).flat_map(|i| ((i + 1)..n).map(move |j| (i, j))).collect();
    let m = bench("estimate/plain", Some(pairs.len() as u64), || {
        let mut acc = 0.0;
        for &(i, j) in &pairs {
            acc += estimator::estimate(&dec, &sketches[i], &sketches[j]);
        }
        std::hint::black_box(acc);
    });
    table.row(&[
        "estimate".into(),
        format!("plain {} pairs k={k}", pairs.len()),
        fmt_duration(m.mean),
        fmt_duration(m.p95),
        format!("{:.2} Mpairs/s", m.throughput().unwrap() / 1e6),
    ]);
    let m = bench("estimate/mle", Some(pairs.len() as u64), || {
        let mut acc = 0.0;
        for &(i, j) in &pairs {
            acc += mle::estimate_mle(&dec, &sketches[i], &sketches[j], Solve::OneStepNewton);
        }
        std::hint::black_box(acc);
    });
    table.row(&[
        "estimate".into(),
        format!("mle-newton {} pairs k={k}", pairs.len()),
        fmt_duration(m.mean),
        fmt_duration(m.p95),
        format!("{:.2} Mpairs/s", m.throughput().unwrap() / 1e6),
    ]);

    // SIMD dispatch + quantized sketch panels — the ISSUE 9 arms,
    // recorded machine-readably in BENCH_simd.json.
    //
    // Equality-guard taxonomy (the split this file commits to):
    //   * BITWISE (assert_eq): scalar vs SIMD on f32 panels. The dot /
    //     power-ladder reduction-order contract — four independent f64
    //     accumulators over chunks of 4 lanes, a scalar tail, and the
    //     fixed final combine (acc0+acc2)+(acc1+acc3)+tail, with AVX
    //     widening each product via cvtps_pd + mul_pd/add_pd and never
    //     FMA — makes every vector kernel produce the *identical* bits
    //     to the scalar reference, so any divergence is a kernel bug,
    //     not noise. Same applies to quantized serving vs serving the
    //     decoded panels: decode is value-exact, so those scans are
    //     bitwise-equal too.
    //   * TOLERANCE (analytic band): quantized panels vs the original
    //     f32 values. Quantization moves the stored values themselves;
    //     the observed dot error must sit under `dot_error_bound`, and
    //     the end-to-end estimate drift is recorded as empirical ε.
    {
        use lpsketch::core::quant::{dot_error_bound, dot_views, PanelQuant};
        use lpsketch::projection::simd;

        let fast = std::env::var("LPSKETCH_BENCH_FAST").as_deref() == Ok("1");
        let (sn, sq) = if fast { (1_000usize, 32usize) } else { (4_000, 64) };
        let (sd, sk2, stop) = (256usize, 128usize, 10usize);
        let kernel = simd::active_kernel();
        let sdata = gen::generate(DataDist::Gaussian, sn, sd, 41);
        let srows: Vec<&[f32]> = (0..sn).map(|i| sdata.row(i)).collect();
        let ssk =
            Sketcher::new(ProjectionSpec::new(17, sk2, ProjectionDist::Normal, Strategy::Basic), 4);
        let sblock = ssk.sketch_block(&srows, 1);
        let ssketches = ssk.sketch_rows(&srows[..sq]);
        let sqarena = SketchArena::from_rows(4, sk2, &ssketches);
        let starena = {
            let all = ssk.sketch_rows(&srows);
            SketchArena::from_rows(4, sk2, &all)
        };

        // BITWISE guard: the SIMD sketch-ingest, block-estimate, and
        // top-k kernels must reproduce the scalar bits exactly.
        simd::force_scalar(true);
        let ingest_ref = ssk.sketch_block(&srows[..256.min(sn)], 1);
        let est_ref = estimator::estimate_block_arena(&dec, &sqarena, &starena, 1);
        let topk_ref = estimator::top_k_scan_arena(&dec, &sqarena, &starena, stop, 1);
        simd::force_scalar(false);
        assert_eq!(
            ingest_ref,
            ssk.sketch_block(&srows[..256.min(sn)], 1),
            "SIMD sketch ingest diverged bitwise from scalar ({kernel})"
        );
        assert_eq!(
            est_ref,
            estimator::estimate_block_arena(&dec, &sqarena, &starena, 1),
            "SIMD block estimate diverged bitwise from scalar ({kernel})"
        );
        assert_eq!(
            topk_ref,
            estimator::top_k_scan_arena(&dec, &sqarena, &starena, stop, 1),
            "SIMD top-k scan diverged bitwise from scalar ({kernel})"
        );

        // Scalar-vs-SIMD throughput, w=1 to isolate the kernel.
        let selems = (sn * sd) as u64;
        let spairs = (sq * sn) as u64;
        simd::force_scalar(true);
        let m_ing_s = bench("simd/ingest_scalar", Some(selems), || {
            std::hint::black_box(ssk.sketch_block(&srows, 1));
        });
        let m_scan_s = bench("simd/topk_scalar", Some(spairs), || {
            std::hint::black_box(estimator::top_k_scan_arena(&dec, &sqarena, &starena, stop, 1));
        });
        simd::force_scalar(false);
        let m_ing_v = bench("simd/ingest_simd", Some(selems), || {
            std::hint::black_box(ssk.sketch_block(&srows, 1));
        });
        let m_scan_v = bench("simd/topk_simd", Some(spairs), || {
            std::hint::black_box(estimator::top_k_scan_arena(&dec, &sqarena, &starena, stop, 1));
        });
        let mut simd_json: Vec<String> = Vec::new();
        for (path, m_s, m_v, unit) in [
            ("ingest_dense", &m_ing_s, &m_ing_v, "Melem/s"),
            ("topk_scan", &m_scan_s, &m_scan_v, "Mpairs/s"),
        ] {
            let speedup = m_s.mean.as_secs_f64() / m_v.mean.as_secs_f64();
            for (arm, m) in [("scalar", m_s), (kernel, m_v)] {
                table.row(&[
                    "simd".into(),
                    format!("{path} {arm} n={sn} d={sd} k={sk2}"),
                    fmt_duration(m.mean),
                    fmt_duration(m.p95),
                    format!("{:.1} {unit}", m.throughput().unwrap() / 1e6),
                ]);
            }
            simd_json.push(format!(
                "    {{\"path\": \"{path}\", \"scalar_s\": {:.6e}, \"simd_s\": {:.6e}, \
                 \"speedup\": {speedup:.2}}}",
                m_s.mean.as_secs_f64(),
                m_v.mean.as_secs_f64(),
            ));
            println!("simd {path}: {speedup:.2}x {kernel} over scalar");
        }

        // Quantized-panel ablation: per encoding, the serving scan over
        // quantized panels (decode in registers) vs the f32 reference.
        // Guards: (a) TOLERANCE — observed dot error ≤ dot_error_bound
        // on sampled row pairs; (b) BITWISE — the quantized-served scan
        // equals the scan over the eagerly-decoded panels (decode is
        // value-exact, so quantization error enters only through the
        // stored values, never through the kernel route).
        let est_f32: Vec<f64> = {
            let store = SketchStore::new(2);
            store.insert_block_columnar(0, sblock.clone());
            let snap = store.snapshot();
            let panels = snap.columnar_panels(4).expect("fully columnar store");
            estimator::estimate_block_arena(&dec, &sqarena, &panels, 1)
        };
        let f32_row_bytes = sblock.u_store().bytes() as f64 / sblock.rows() as f64;
        let mut quant_json: Vec<String> = Vec::new();
        for q in [PanelQuant::None, PanelQuant::F16, PanelQuant::Bf16, PanelQuant::I8] {
            let store = SketchStore::new(2);
            store.set_panel_quant(q);
            store.insert_block_columnar(0, sblock.clone());
            let snap = store.snapshot();
            let panels = snap.columnar_panels(4).expect("fully columnar store");
            let stored = store.segments_snapshot().remove(0).1;
            assert_eq!(stored.encoding(), q, "store boundary did not apply panel-quant");
            let row_bytes = stored.u_store().bytes() as f64 / stored.rows() as f64;

            // (a) TOLERANCE: sampled per-order dots against the f32
            // originals, pinned under the analytic bound.
            let mut max_err_over_bound = 0.0f64;
            if q != PanelQuant::None {
                for t in 0..16usize {
                    let (r, s) = ((t * 131) % sn, (t * 197 + 7) % sn);
                    for m in 1..4 {
                        let su = stored.u_store().i8_scales().map_or(0.0, |sc| sc[m - 1]);
                        let want = dot_views(sblock.u_view(m, r), sblock.u_view(m, s));
                        let got = dot_views(stored.u_view(m, r), stored.u_view(m, s));
                        let bound =
                            dot_error_bound(sblock.u_row(m, r), sblock.u_row(m, s), q, su, q, su);
                        let err = (got - want).abs();
                        assert!(
                            err <= bound,
                            "{} dot error {err:.3e} exceeds analytic bound {bound:.3e} \
                             (r={r} s={s} m={m})",
                            q.name()
                        );
                        max_err_over_bound = max_err_over_bound.max(err / bound);
                    }
                }

                // (b) BITWISE: quantized-served scan == scan over the
                // eagerly-decoded panels.
                let dstore = SketchStore::new(2);
                dstore.insert_block_columnar(0, stored.decode());
                let dsnap = dstore.snapshot();
                let dpanels = dsnap.columnar_panels(4).expect("fully columnar store");
                assert_eq!(
                    estimator::top_k_scan_arena(&dec, &sqarena, &panels, stop, 1),
                    estimator::top_k_scan_arena(&dec, &sqarena, &dpanels, stop, 1),
                    "{}-served scan diverged from serving the decoded panels",
                    q.name()
                );
            }

            // Empirical end-to-end ε: worst relative estimate drift vs
            // the f32 panels (recorded, not asserted — the assertable
            // contract lives at the dot level above).
            let est_q = estimator::estimate_block_arena(&dec, &sqarena, &panels, 1);
            let max_rel_err = est_q
                .iter()
                .zip(&est_f32)
                .map(|(a, b)| (a - b).abs() / b.abs().max(1e-30))
                .fold(0.0f64, f64::max);

            let m_q = bench(&format!("quant/{}/topk", q.name()), Some(spairs), || {
                std::hint::black_box(estimator::top_k_scan_arena(
                    &dec, &sqarena, &panels, stop, 1,
                ));
            });
            table.row(&[
                "quant".into(),
                format!("{} topk B={sq} n={sn} k={sk2} ({row_bytes:.0} B/row)", q.name()),
                fmt_duration(m_q.mean),
                fmt_duration(m_q.p95),
                format!("{:.2} Mpairs/s", m_q.throughput().unwrap() / 1e6),
            ]);
            quant_json.push(format!(
                "    {{\"encoding\": \"{}\", \"bytes_per_row\": {row_bytes:.1}, \
                 \"bytes_ratio\": {:.2}, \"mpairs_per_s\": {:.2}, \
                 \"max_pair_rel_err\": {max_rel_err:.3e}, \
                 \"max_dot_err_over_bound\": {max_err_over_bound:.3}}}",
                q.name(),
                f32_row_bytes / row_bytes,
                m_q.throughput().unwrap() / 1e6,
            ));
            println!(
                "quant {}: {row_bytes:.0} B/row ({:.2}x smaller), {:.2} Mpairs/s, \
                 pair ε ≤ {max_rel_err:.2e}",
                q.name(),
                f32_row_bytes / row_bytes,
                m_q.throughput().unwrap() / 1e6,
            );
        }
        let json = format!(
            "{{\n  \"bench\": \"simd\",\n  \"kernel\": \"{kernel}\",\n  \"n\": {sn},\n  \
             \"d\": {sd},\n  \"k\": {sk2},\n  \"p\": 4,\n  \"queries\": {sq},\n  \
             \"top\": {stop},\n  \"simd\": [\n{}\n  ],\n  \"quant\": [\n{}\n  ]\n}}\n",
            simd_json.join(",\n"),
            quant_json.join(",\n"),
        );
        if let Err(e) = std::fs::write("BENCH_simd.json", &json) {
            eprintln!("(could not write BENCH_simd.json: {e})");
        } else {
            println!("wrote BENCH_simd.json");
        }
    }

    // Arena vs per-row blocked kernels — the ISSUE 1 acceptance arm:
    // batched all-pairs / top-k estimation at n=10⁴, k=64, p=4 must run
    // ≥3× faster through the columnar arena than through per-row
    // RowSketch scoring, with identical results within fp tolerance.
    {
        let fast = std::env::var("LPSKETCH_BENCH_FAST").as_deref() == Ok("1");
        let (an, bq) = if fast { (2_000usize, 64usize) } else { (10_000, 256) };
        let (ad, ak, top) = (128usize, 64usize, 10usize);
        let workers = std::thread::available_parallelism().map_or(1, |w| w.get());
        let adata = gen::generate(DataDist::LogNormal { sigma: 1.0 }, an, ad, 21);
        let ask = Sketcher::new(
            ProjectionSpec::new(2, ak, ProjectionDist::Normal, Strategy::Basic),
            4,
        );
        let arefs: Vec<&[f32]> = (0..an).map(|i| adata.row(i)).collect();
        let asketches = ask.sketch_rows(&arefs);
        let tarena = SketchArena::from_rows(4, ak, &asketches);
        let qarena = SketchArena::from_rows(4, ak, &asketches[..bq]);
        let batch_pairs = (bq * an) as u64;

        // Correctness guard: arena block == per-row block (fp-identical).
        let want = estimator::estimate_block(&dec, &asketches[..bq.min(8)], &asketches[..64]);
        let small_q = SketchArena::from_rows(4, ak, &asketches[..bq.min(8)]);
        let small_t = SketchArena::from_rows(4, ak, &asketches[..64]);
        let got = estimator::estimate_block_arena(&dec, &small_q, &small_t, workers);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() <= 1e-12 * w.abs().max(1.0), "arena mismatch: {g} vs {w}");
        }

        let m_pr = bench("arena/block_per_row", Some(batch_pairs), || {
            std::hint::black_box(estimator::estimate_block(&dec, &asketches[..bq], &asketches));
        });
        table.row(&[
            "arena".into(),
            format!("block per-row B={bq} n={an} k={ak}"),
            fmt_duration(m_pr.mean),
            fmt_duration(m_pr.p95),
            format!("{:.2} Mpairs/s", m_pr.throughput().unwrap() / 1e6),
        ]);
        // w=1 arm isolates the columnar layout's contribution; the
        // w=workers arm is the arena path as deployed (layout + shards).
        let m_a1 = bench("arena/block_arena_w1", Some(batch_pairs), || {
            std::hint::black_box(estimator::estimate_block_arena(&dec, &qarena, &tarena, 1));
        });
        table.row(&[
            "arena".into(),
            format!("block arena B={bq} n={an} k={ak} w=1"),
            fmt_duration(m_a1.mean),
            fmt_duration(m_a1.p95),
            format!("{:.2} Mpairs/s", m_a1.throughput().unwrap() / 1e6),
        ]);
        let m_ar = bench("arena/block_arena", Some(batch_pairs), || {
            std::hint::black_box(estimator::estimate_block_arena(&dec, &qarena, &tarena, workers));
        });
        table.row(&[
            "arena".into(),
            format!("block arena B={bq} n={an} k={ak} w={workers}"),
            fmt_duration(m_ar.mean),
            fmt_duration(m_ar.p95),
            format!("{:.2} Mpairs/s", m_ar.throughput().unwrap() / 1e6),
        ]);
        println!(
            "arena block speedup: {:.1}x layout-only (w=1), {:.1}x with {workers} workers \
             (per-row {})",
            m_pr.mean.as_secs_f64() / m_a1.mean.as_secs_f64(),
            m_pr.mean.as_secs_f64() / m_ar.mean.as_secs_f64(),
            fmt_duration(m_pr.mean),
        );

        let m_tpr = bench("arena/topk_per_row", Some(batch_pairs), || {
            for qi in 0..bq {
                let mut scored: Vec<(usize, f64)> = asketches
                    .iter()
                    .enumerate()
                    .map(|(j, r)| (j, estimator::estimate(&dec, &asketches[qi], r)))
                    .collect();
                scored.select_nth_unstable_by(top - 1, |a, b| a.1.total_cmp(&b.1));
                scored.truncate(top);
                scored.sort_by(|a, b| a.1.total_cmp(&b.1));
                std::hint::black_box(scored);
            }
        });
        table.row(&[
            "arena".into(),
            format!("top-{top} per-row B={bq} n={an}"),
            fmt_duration(m_tpr.mean),
            fmt_duration(m_tpr.p95),
            format!("{:.2} Mpairs/s", m_tpr.throughput().unwrap() / 1e6),
        ]);
        let m_t1 = bench("arena/topk_arena_w1", Some(batch_pairs), || {
            std::hint::black_box(estimator::top_k_scan_arena(&dec, &qarena, &tarena, top, 1));
        });
        table.row(&[
            "arena".into(),
            format!("top-{top} arena B={bq} n={an} w=1"),
            fmt_duration(m_t1.mean),
            fmt_duration(m_t1.p95),
            format!("{:.2} Mpairs/s", m_t1.throughput().unwrap() / 1e6),
        ]);
        let m_tar = bench("arena/topk_arena", Some(batch_pairs), || {
            std::hint::black_box(estimator::top_k_scan_arena(&dec, &qarena, &tarena, top, workers));
        });
        table.row(&[
            "arena".into(),
            format!("top-{top} arena B={bq} n={an} w={workers}"),
            fmt_duration(m_tar.mean),
            fmt_duration(m_tar.p95),
            format!("{:.2} Mpairs/s", m_tar.throughput().unwrap() / 1e6),
        ]);
        println!(
            "arena top-k speedup: {:.1}x layout-only (w=1), {:.1}x with {workers} workers \
             (per-row {})",
            m_tpr.mean.as_secs_f64() / m_t1.mean.as_secs_f64(),
            m_tpr.mean.as_secs_f64() / m_tar.mean.as_secs_f64(),
            fmt_duration(m_tpr.mean),
        );
    }

    // Zone-pruned fused top-k vs the full scan vs per-row scoring,
    // across population skew levels — the ISSUE 8 arm. Each level
    // builds a fully-columnar store of `zsegs` segments whose entry
    // magnitudes grow geometrically (growth 1 = uniform, no pruning
    // expected; growth 4 = steep bands, pruning must engage). Queries
    // sit at the smallest band's scale, so their neighbors live there
    // and large-band segments fail the zone lower bound. Equality is
    // guarded per level before timing: the pruned scan must be
    // bitwise-identical to the full scan, and the steep level must
    // record >0 skipped segments. Recorded machine-readably in
    // BENCH_topk.json.
    {
        let fast = std::env::var("LPSKETCH_BENCH_FAST").as_deref() == Ok("1");
        let (zsegs, zseg_rows, zq) = if fast { (8usize, 64usize, 16usize) } else { (16, 512, 64) };
        let (zd, zk, ztop) = (64usize, 64usize, 10usize);
        let zn = zsegs * zseg_rows;
        let workers = std::thread::available_parallelism().map_or(1, |w| w.get());
        let zsk =
            Sketcher::new(ProjectionSpec::new(11, zk, ProjectionDist::Normal, Strategy::Basic), 4);
        let zdata = gen::generate(DataDist::Gaussian, zn, zd, 31);
        let zqdata = gen::generate(DataDist::Gaussian, zq, zd, 32);
        let zqrows: Vec<&[f32]> = (0..zq).map(|i| zqdata.row(i)).collect();
        let zqsketches = zsk.sketch_rows(&zqrows);
        let zqarena = SketchArena::from_rows(4, zk, &zqsketches);
        let zpairs = (zq * zn) as u64;
        let mut topk_json: Vec<String> = Vec::new();
        let mut prune_json: Vec<String> = Vec::new();
        for (lvl, growth) in [("uniform", 1.0f32), ("mild", 2.0), ("steep", 4.0)] {
            let store = SketchStore::new(2);
            let mut rowsk = Vec::with_capacity(zn);
            for s in 0..zsegs {
                let scale = growth.powi(s as i32);
                let rows: Vec<Vec<f32>> = (0..zseg_rows)
                    .map(|r| zdata.row(s * zseg_rows + r).iter().map(|x| x * scale).collect())
                    .collect();
                let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
                let block = zsk.sketch_block(&refs, 1);
                for r in 0..block.rows() {
                    rowsk.push(block.to_row_sketch(r));
                }
                // Gapped bases keep the segments distinct under any
                // later compaction heuristics.
                store.insert_block_columnar(1000 + (s * (zseg_rows + 3)) as u64, block);
            }
            let snap = store.snapshot();
            let panels = snap.columnar_panels(4).expect("fully columnar store");
            let extents = panels.extents();
            // Equality guard before timing: pruned == full, bitwise,
            // with coherent visit accounting — and the steep level must
            // actually skip segments, else the zone maps are inert.
            let full = estimator::top_k_scan_arena(&dec, &zqarena, &panels, ztop, workers);
            let (pruned, stats) =
                estimator::top_k_scan_zoned(&dec, &zqarena, &panels, &extents, ztop, workers);
            assert_eq!(pruned, full, "pruned top-k diverged from the full scan ({lvl})");
            assert_eq!(
                stats.segments_visited + stats.segments_skipped,
                zpairs / zseg_rows as u64,
                "visit accounting broken ({lvl})"
            );
            if growth >= 4.0 {
                assert!(
                    stats.segments_skipped > 0,
                    "steep skew must prune segments (visited={}, skipped=0)",
                    stats.segments_visited
                );
            }
            let m_zpr = bench(&format!("topk/{lvl}/per_row"), Some(zpairs), || {
                for qs in &zqsketches {
                    let mut scored: Vec<(usize, f64)> = rowsk
                        .iter()
                        .enumerate()
                        .map(|(j, r)| (j, estimator::estimate(&dec, qs, r)))
                        .collect();
                    scored.select_nth_unstable_by(ztop - 1, |a, b| a.1.total_cmp(&b.1));
                    scored.truncate(ztop);
                    scored.sort_by(|a, b| a.1.total_cmp(&b.1));
                    std::hint::black_box(scored);
                }
            });
            let m_zfull = bench(&format!("topk/{lvl}/full"), Some(zpairs), || {
                std::hint::black_box(estimator::top_k_scan_arena(
                    &dec, &zqarena, &panels, ztop, workers,
                ));
            });
            let m_zpruned = bench(&format!("topk/{lvl}/pruned"), Some(zpairs), || {
                std::hint::black_box(estimator::top_k_scan_zoned(
                    &dec, &zqarena, &panels, &extents, ztop, workers,
                ));
            });
            for (path, m) in
                [("per_row", &m_zpr), ("full", &m_zfull), ("pruned", &m_zpruned)]
            {
                table.row(&[
                    "topk".into(),
                    format!("{lvl} {path} B={zq} n={zn} segs={zsegs} k={zk}"),
                    fmt_duration(m.mean),
                    fmt_duration(m.p95),
                    format!("{:.2} Mpairs/s", m.throughput().unwrap() / 1e6),
                ]);
                topk_json.push(format!(
                    "    {{\"skew\": \"{lvl}\", \"path\": \"{path}\", \"mean_s\": {:.6e}, \
                     \"mpairs_per_s\": {:.2}}}",
                    m.mean.as_secs_f64(),
                    m.throughput().unwrap() / 1e6,
                ));
            }
            let visits = (zq * zsegs) as u64;
            prune_json.push(format!(
                "    {{\"skew\": \"{lvl}\", \"growth\": {growth}, \"segments\": {zsegs}, \
                 \"segments_visited\": {}, \"segments_skipped\": {}, \"rows_skipped\": {}, \
                 \"skip_fraction\": {:.3}}}",
                stats.segments_visited,
                stats.segments_skipped,
                stats.rows_skipped,
                stats.segments_skipped as f64 / visits as f64,
            ));
            println!(
                "topk {lvl}: pruned {:.2}x of full, {:.2}x of per-row \
                 ({}/{} segment visits skipped)",
                m_zfull.mean.as_secs_f64() / m_zpruned.mean.as_secs_f64(),
                m_zpr.mean.as_secs_f64() / m_zpruned.mean.as_secs_f64(),
                stats.segments_skipped,
                visits,
            );
        }
        let json = format!(
            "{{\n  \"bench\": \"topk\",\n  \"n\": {zn},\n  \"segments\": {zsegs},\n  \
             \"d\": {zd},\n  \"k\": {zk},\n  \"p\": 4,\n  \"queries\": {zq},\n  \
             \"top\": {ztop},\n  \"workers\": {workers},\n  \"results\": [\n{}\n  ],\n  \
             \"pruning\": [\n{}\n  ]\n}}\n",
            topk_json.join(",\n"),
            prune_json.join(",\n"),
        );
        if let Err(e) = std::fs::write("BENCH_topk.json", &json) {
            eprintln!("(could not write BENCH_topk.json: {e})");
        } else {
            println!("wrote BENCH_topk.json");
        }
    }

    // End-to-end all-pairs through the pipeline (arena path vs the
    // per-row reference path). Arc-wrapped so the API arm below can
    // spawn the query service over the same pipeline.
    let mut cfg = Config::default();
    cfg.n = n;
    cfg.d = d;
    cfg.k = k;
    let pipeline = std::sync::Arc::new(Pipeline::new(cfg).unwrap());
    pipeline.ingest(&data).unwrap();
    let m = bench("pipeline/all_pairs", Some(pairs.len() as u64), || {
        std::hint::black_box(pipeline.all_pairs_condensed());
    });
    table.row(&[
        "pipeline".into(),
        format!("all-pairs (arena) n={n} k={k}"),
        fmt_duration(m.mean),
        fmt_duration(m.p95),
        format!("{:.2} Mpairs/s", m.throughput().unwrap() / 1e6),
    ]);
    let m = bench("pipeline/all_pairs_per_row", Some(pairs.len() as u64), || {
        std::hint::black_box(pipeline.all_pairs_condensed_per_row());
    });
    table.row(&[
        "pipeline".into(),
        format!("all-pairs (per-row) n={n} k={k}"),
        fmt_duration(m.mean),
        fmt_duration(m.p95),
        format!("{:.2} Mpairs/s", m.throughput().unwrap() / 1e6),
    ]);

    // Batch-query paths over the GEMM-ingested (fully columnar) store:
    // segment-native scoring vs a per-batch arena_snapshot vs per-pair
    // per-row scoring — the ISSUE 3 acceptance arm, recorded
    // machine-readably in BENCH_query.json.
    {
        let qpairs: Vec<(u64, u64)> =
            pairs.iter().map(|&(i, j)| (i as u64, j as u64)).collect();
        let qstore = pipeline.store();
        let workers = pipeline.config().workers;
        // Correctness guard before timing: all three routes agree
        // bitwise (the lifecycle property tests pin this broadly; the
        // bench re-checks its own operating point).
        {
            let native = pipeline.estimate_pairs(&qpairs[..64]);
            let snap = qstore.arena_snapshot(4, k);
            for (&(a, b), got) in qpairs[..64].iter().zip(&native) {
                let want = estimator::estimate_arena(
                    &dec, &snap.arena, snap.pos[&a], &snap.arena, snap.pos[&b],
                );
                assert_eq!(*got, Some(want), "native vs snapshot mismatch ({a},{b})");
                assert_eq!(
                    *got,
                    qstore.estimate_pair_plain(&dec, a, b),
                    "native vs per-row mismatch ({a},{b})"
                );
            }
        }
        let m_native = bench("query/batch_native", Some(qpairs.len() as u64), || {
            std::hint::black_box(pipeline.estimate_pairs(&qpairs));
        });
        let m_snap = bench("query/batch_snapshot", Some(qpairs.len() as u64), || {
            let snap = qstore.arena_snapshot(4, k);
            let out: Vec<Option<f64>> = qpairs
                .iter()
                .map(|&(a, b)| match (snap.pos.get(&a), snap.pos.get(&b)) {
                    (Some(&i), Some(&j)) => Some(estimator::estimate_arena(
                        &dec, &snap.arena, i, &snap.arena, j,
                    )),
                    _ => None,
                })
                .collect();
            std::hint::black_box(out);
        });
        let m_pr = bench("query/batch_per_row", Some(qpairs.len() as u64), || {
            let out: Vec<Option<f64>> = qpairs
                .iter()
                .map(|&(a, b)| qstore.estimate_pair_plain(&dec, a, b))
                .collect();
            std::hint::black_box(out);
        });
        for (label, m) in [("native", &m_native), ("snapshot", &m_snap), ("per_row", &m_pr)] {
            table.row(&[
                "query".into(),
                format!("batch {label} {} pairs n={n} k={k}", qpairs.len()),
                fmt_duration(m.mean),
                fmt_duration(m.p95),
                format!("{:.2} Mpairs/s", m.throughput().unwrap() / 1e6),
            ]);
        }
        // Store-served batch top-k: segment-native vs snapshot-backed.
        let topq: Vec<&[f32]> = (0..32).map(|i| data.row(i * 7)).collect();
        let top = 10usize;
        let qsk = Sketcher::new(pipeline.config().projection_spec(), 4);
        {
            let native = pipeline.top_k(&topq[..4], top).unwrap();
            let snap = qstore.arena_snapshot(4, k);
            let qarena = SketchArena::from_rows(4, k, &qsk.sketch_rows(&topq[..4]));
            let want: Vec<Vec<(u64, f64)>> =
                estimator::top_k_scan_arena(&dec, &qarena, &snap.arena, top, workers)
                    .into_iter()
                    .map(|lst| lst.into_iter().map(|(i, d)| (snap.ids[i], d)).collect())
                    .collect();
            assert_eq!(native, want, "top-k native vs snapshot mismatch");
        }
        let topk_elems = (topq.len() * n) as u64;
        let m_topk_native = bench("query/topk_native", Some(topk_elems), || {
            std::hint::black_box(pipeline.top_k(&topq, top).unwrap());
        });
        let m_topk_snap = bench("query/topk_snapshot", Some(topk_elems), || {
            let snap = qstore.arena_snapshot(4, k);
            let qarena = SketchArena::from_rows(4, k, &qsk.sketch_rows(&topq));
            let out: Vec<Vec<(u64, f64)>> =
                estimator::top_k_scan_arena(&dec, &qarena, &snap.arena, top, workers)
                    .into_iter()
                    .map(|lst| lst.into_iter().map(|(i, d)| (snap.ids[i], d)).collect())
                    .collect();
            std::hint::black_box(out);
        });
        for (label, m) in [("native", &m_topk_native), ("snapshot", &m_topk_snap)] {
            table.row(&[
                "query".into(),
                format!("top-{top} {label} B={} n={n} k={k}", topq.len()),
                fmt_duration(m.mean),
                fmt_duration(m.p95),
                format!("{:.2} Mpairs/s", m.throughput().unwrap() / 1e6),
            ]);
        }
        let pairs_vs_snap = m_snap.mean.as_secs_f64() / m_native.mean.as_secs_f64();
        let pairs_vs_pr = m_pr.mean.as_secs_f64() / m_native.mean.as_secs_f64();
        let topk_vs_snap = m_topk_snap.mean.as_secs_f64() / m_topk_native.mean.as_secs_f64();
        println!(
            "query batch speedup: {pairs_vs_snap:.2}x vs snapshot, {pairs_vs_pr:.2}x vs \
             per-row; top-k {topk_vs_snap:.2}x vs snapshot"
        );
        let mut results: Vec<String> = Vec::new();
        for (path, m) in [
            ("batch_native", &m_native),
            ("batch_snapshot", &m_snap),
            ("batch_per_row", &m_pr),
            ("topk_native", &m_topk_native),
            ("topk_snapshot", &m_topk_snap),
        ] {
            results.push(format!(
                "    {{\"path\": \"{path}\", \"mean_s\": {:.6e}, \"mpairs_per_s\": {:.2}}}",
                m.mean.as_secs_f64(),
                m.throughput().unwrap() / 1e6,
            ));
        }
        let json = format!(
            "{{\n  \"bench\": \"query\",\n  \"n\": {n},\n  \"d\": {d},\n  \"k\": {k},\n  \
             \"p\": 4,\n  \"pairs\": {},\n  \"topk_queries\": {},\n  \"top\": {top},\n  \
             \"workers\": {workers},\n  \"results\": [\n{}\n  ],\n  \"speedup\": \
             {{\"pairs_native_vs_snapshot\": {pairs_vs_snap:.2}, \
             \"pairs_native_vs_per_row\": {pairs_vs_pr:.2}, \
             \"topk_native_vs_snapshot\": {topk_vs_snap:.2}}}\n}}\n",
            qpairs.len(),
            topq.len(),
            results.join(",\n"),
        );
        if let Err(e) = std::fs::write("BENCH_query.json", &json) {
            eprintln!("(could not write BENCH_query.json: {e})");
        } else {
            println!("wrote BENCH_query.json");
        }
    }

    // Concurrent serving: snapshot-served pair batches vs the legacy
    // lock-pinned columnar view, with 0 vs 1 concurrent ingest writer.
    // The snapshot path must hold its queries/s under ingest AND let
    // the writer keep landing blocks (the legacy path queues the writer
    // behind every scan). Recorded machine-readably in BENCH_serve.json.
    {
        use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
        let qstore = pipeline.store();
        let serve_pairs: Vec<(u64, u64)> =
            (0..512u64).map(|i| ((i * 7) % n as u64, (i * 13 + 1) % n as u64)).collect();
        // Writer payload: one pre-sketched block (same shape as the
        // store), re-landed by Arc handle at fresh gapped bases — the
        // writer arm measures store contention, not sketch kernels.
        let wsk =
            Sketcher::new(ProjectionSpec::new(5, k, ProjectionDist::Normal, Strategy::Basic), 4);
        let wrows: Vec<Vec<f32>> = (0..64)
            .map(|i| (0..32).map(|t| ((i * 3 + t) as f32 * 0.17).sin()).collect())
            .collect();
        let wrefs: Vec<&[f32]> = wrows.iter().map(|r| r.as_slice()).collect();
        let wblock = std::sync::Arc::new(wsk.sketch_block(&wrefs, 1));
        let next_base = AtomicU64::new(1 << 32);
        // Equality guard before timing: snapshot path == legacy locked
        // path, bitwise, on the same pair batch.
        {
            let snap = qstore.snapshot();
            let via_snap: Vec<Option<f64>> = serve_pairs
                .iter()
                .map(|&(a, b)| snap.estimate_pair_plain(&dec, a, b))
                .collect();
            let via_locked: Vec<Option<f64>> = qstore.with_columnar_view_locked(4, |v| {
                let v = v.expect("fully columnar store");
                serve_pairs
                    .iter()
                    .map(|&(a, b)| match (v.pos_of(a), v.pos_of(b)) {
                        (Some(i), Some(j)) => Some(estimator::estimate_arena(&dec, v, i, v, j)),
                        _ => None,
                    })
                    .collect()
            });
            assert_eq!(via_snap, via_locked, "snapshot vs legacy locked path mismatch");
        }
        let arm = |locked: bool, writers: usize| -> (f64, f64) {
            // Fresh store copy per arm (panels shared by Arc, so the
            // copy is cheap): every arm starts from the identical
            // baseline state — writer arms grow only their own copy,
            // never a later arm's.
            let (astore, _) =
                lpsketch::coordinator::rebalance::rebalance(qstore, pipeline.config().workers);
            let astore = &astore;
            let stop = AtomicBool::new(false);
            let queries = AtomicU64::new(0);
            let blocks = AtomicU64::new(0);
            let window = std::time::Duration::from_millis(250);
            std::thread::scope(|s| {
                for _ in 0..writers {
                    s.spawn(|| {
                        while !stop.load(Ordering::Relaxed) {
                            let base = next_base
                                .fetch_add(wblock.rows() as u64 + 1, Ordering::Relaxed);
                            astore.insert_block_shared(base, std::sync::Arc::clone(&wblock));
                            blocks.fetch_add(1, Ordering::Relaxed);
                        }
                    });
                }
                for _ in 0..2 {
                    s.spawn(|| {
                        while !stop.load(Ordering::Relaxed) {
                            let mut acc = 0.0f64;
                            if locked {
                                astore.with_columnar_view_locked(4, |v| {
                                    if let Some(v) = v {
                                        for &(a, b) in &serve_pairs {
                                            if let (Some(i), Some(j)) = (v.pos_of(a), v.pos_of(b))
                                            {
                                                acc += estimator::estimate_arena(&dec, v, i, v, j);
                                            }
                                        }
                                    }
                                });
                            } else {
                                let snap = astore.snapshot();
                                if let Some(v) = snap.columnar_panels(4) {
                                    for &(a, b) in &serve_pairs {
                                        if let (Some(i), Some(j)) = (v.pos_of(a), v.pos_of(b)) {
                                            acc += estimator::estimate_arena(&dec, &v, i, &v, j);
                                        }
                                    }
                                }
                            }
                            std::hint::black_box(acc);
                            queries.fetch_add(serve_pairs.len() as u64, Ordering::Relaxed);
                        }
                    });
                }
                std::thread::sleep(window);
                stop.store(true, Ordering::Relaxed);
            });
            let secs = window.as_secs_f64();
            (
                queries.load(Ordering::Relaxed) as f64 / secs,
                blocks.load(Ordering::Relaxed) as f64 / secs,
            )
        };
        let mut results: Vec<String> = Vec::new();
        for (name, locked, writers) in [
            ("snapshot", false, 0usize),
            ("snapshot_ingest", false, 1),
            ("locked", true, 0),
            ("locked_ingest", true, 1),
        ] {
            let (qps, bps) = arm(locked, writers);
            table.row(&[
                "serve".into(),
                format!("{name} batch={} writers={writers} n={n} k={k}", serve_pairs.len()),
                "-".into(),
                "-".into(),
                format!("{:.2} Mpairs/s", qps / 1e6),
            ]);
            results.push(format!(
                "    {{\"path\": \"{name}\", \"writers\": {writers}, \
                 \"pairs_per_s\": {qps:.1}, \"ingest_blocks_per_s\": {bps:.1}}}"
            ));
            println!("serve {name}: {:.2} Mpairs/s, {bps:.0} ingest blocks/s", qps / 1e6);
        }
        let json = format!(
            "{{\n  \"bench\": \"serve\",\n  \"n\": {n},\n  \"d\": {d},\n  \"k\": {k},\n  \
             \"p\": 4,\n  \"pairs_per_batch\": {},\n  \"reader_threads\": 2,\n  \
             \"window_s\": 0.25,\n  \"results\": [\n{}\n  ]\n}}\n",
            serve_pairs.len(),
            results.join(",\n"),
        );
        if let Err(e) = std::fs::write("BENCH_serve.json", &json) {
            eprintln!("(could not write BENCH_serve.json: {e})");
        } else {
            println!("wrote BENCH_serve.json");
        }
    }

    // Typed-API arm: the same pair batch through (a) the legacy direct
    // estimate path, (b) the typed in-process dispatch
    // (Pipeline::answer), (c) the batched query service, and (d) a TCP
    // loopback client — equality-guarded, recorded machine-readably in
    // BENCH_api.json. The service/wire arms price the unified surface
    // against PR-4's raw snapshot serving.
    {
        use lpsketch::api::{Client, Request, Response, Server};
        let api_pairs: Vec<(u64, u64)> =
            (0..1024u64).map(|i| ((i * 7) % n as u64, (i * 13 + 1) % n as u64)).collect();
        let service = pipeline.spawn_query_service();
        let guard = Server::bind("127.0.0.1:0", service.clone())
            .expect("bind loopback")
            .spawn()
            .expect("spawn server");
        let mut client = Client::connect(guard.addr()).expect("connect loopback");
        // Equality guard before timing: all four routes agree bitwise.
        {
            let direct = pipeline.estimate_pairs(&api_pairs);
            let typed = match pipeline.answer(Request::PairBatch(api_pairs.clone())) {
                Response::PairBatch(v) => v,
                other => panic!("unexpected response {other:?}"),
            };
            assert_eq!(typed, direct, "typed dispatch diverged from direct path");
            let served = match service.call(Request::PairBatch(api_pairs.clone())).unwrap() {
                Response::PairBatch(v) => v,
                other => panic!("unexpected response {other:?}"),
            };
            assert_eq!(served, direct, "batched service diverged from direct path");
            let remote = client.pairs(&api_pairs).unwrap();
            assert_eq!(remote, direct, "TCP loopback diverged from direct path");
        }
        let batch_len = api_pairs.len() as u64;
        let m_direct = bench("api/pairs_direct", Some(batch_len), || {
            std::hint::black_box(pipeline.estimate_pairs(&api_pairs));
        });
        let m_typed = bench("api/pairs_typed", Some(batch_len), || {
            std::hint::black_box(pipeline.answer(Request::PairBatch(api_pairs.clone())));
        });
        let m_service = bench("api/pairs_service", Some(batch_len), || {
            std::hint::black_box(service.call(Request::PairBatch(api_pairs.clone())).unwrap());
        });
        let m_tcp = bench("api/pairs_tcp", Some(batch_len), || {
            std::hint::black_box(client.pairs(&api_pairs).unwrap());
        });
        let mut results: Vec<String> = Vec::new();
        for (path, m) in [
            ("direct", &m_direct),
            ("typed_inprocess", &m_typed),
            ("service_batched", &m_service),
            ("tcp_loopback", &m_tcp),
        ] {
            table.row(&[
                "api".into(),
                format!("pairs {path} batch={} n={n} k={k}", api_pairs.len()),
                fmt_duration(m.mean),
                fmt_duration(m.p95),
                format!("{:.2} Mpairs/s", m.throughput().unwrap() / 1e6),
            ]);
            results.push(format!(
                "    {{\"path\": \"{path}\", \"mean_s\": {:.6e}, \"pairs_per_s\": {:.1}}}",
                m.mean.as_secs_f64(),
                m.throughput().unwrap(),
            ));
        }
        let typed_vs_direct = m_direct.mean.as_secs_f64() / m_typed.mean.as_secs_f64();
        let tcp_vs_typed = m_typed.mean.as_secs_f64() / m_tcp.mean.as_secs_f64();
        println!(
            "api pairs: typed {typed_vs_direct:.2}x of direct, tcp loopback {:.2} Mpairs/s \
             ({tcp_vs_typed:.2}x of typed)",
            m_tcp.throughput().unwrap() / 1e6,
        );
        let json = format!(
            "{{\n  \"bench\": \"api\",\n  \"n\": {n},\n  \"d\": {d},\n  \"k\": {k},\n  \
             \"p\": 4,\n  \"pairs_per_batch\": {},\n  \"results\": [\n{}\n  ]\n}}\n",
            api_pairs.len(),
            results.join(",\n"),
        );
        if let Err(e) = std::fs::write("BENCH_api.json", &json) {
            eprintln!("(could not write BENCH_api.json: {e})");
        } else {
            println!("wrote BENCH_api.json");
        }
        guard.stop();
    }

    // Store ops.
    let store = SketchStore::new(4);
    for (i, s) in sketches.iter().enumerate() {
        store.insert(i as u64, s.clone());
    }
    let m = bench("store/pair_visit", Some(pairs.len() as u64), || {
        let mut acc = 0.0;
        for &(i, j) in &pairs {
            acc += store
                .with_pair(i as u64, j as u64, |a, b| estimator::estimate(&dec, a, b))
                .unwrap();
        }
        std::hint::black_box(acc);
    });
    table.row(&[
        "store".into(),
        format!("locked pair visit × {}", pairs.len()),
        fmt_duration(m.mean),
        fmt_duration(m.p95),
        format!("{:.2} Mpairs/s", m.throughput().unwrap() / 1e6),
    ]);

    // PJRT block dispatch (if artifacts exist).
    if Path::new("artifacts/manifest.txt").exists() {
        let engine = Engine::start(Path::new("artifacts")).unwrap();
        let h = engine.handle();
        if let Some(meta) = h.manifest().find_sketch(OpKind::Sketch, 4, 64).cloned() {
            h.warm(&meta.name).unwrap();
            let spec = ProjectionSpec::new(1, meta.k, ProjectionDist::Normal, Strategy::Basic);
            let r = spec.materialize(1, 0, meta.d).data;
            let x = gen::generate(DataDist::Uniform01, meta.b, meta.d, 3).data().to_vec();
            let m = bench("pjrt/sketch_block", Some((meta.b * meta.d) as u64), || {
                std::hint::black_box(
                    h.run(
                        &meta.name,
                        vec![
                            OwnedInput::new(x.clone(), &[meta.b, meta.d]),
                            OwnedInput::new(r.clone(), &[meta.d, meta.k]),
                        ],
                    )
                    .unwrap(),
                );
            });
            table.row(&[
                "pjrt".into(),
                format!("sketch b={} d={} k={}", meta.b, meta.d, meta.k),
                fmt_duration(m.mean),
                fmt_duration(m.p95),
                format!("{:.1} Melem/s", m.throughput().unwrap() / 1e6),
            ]);
        }
        if let Some(meta) = h.manifest().find_estimate(4, 64).cloned() {
            h.warm(&meta.name).unwrap();
            let orders = meta.p - 1;
            let u: Vec<f32> = (0..orders * meta.b * meta.k).map(|i| (i % 97) as f32 * 0.01).collect();
            let v = u.clone();
            let mx = vec![1.0f32; meta.b];
            let my = vec![1.0f32; meta.b2];
            let m = bench("pjrt/estimate_block", Some((meta.b * meta.b2) as u64), || {
                std::hint::black_box(
                    h.run(
                        &meta.name,
                        vec![
                            OwnedInput::new(u.clone(), &[orders, meta.b, meta.k]),
                            OwnedInput::new(v.clone(), &[orders, meta.b2, meta.k]),
                            OwnedInput::new(mx.clone(), &[meta.b]),
                            OwnedInput::new(my.clone(), &[meta.b2]),
                        ],
                    )
                    .unwrap(),
                );
            });
            table.row(&[
                "pjrt".into(),
                format!("estimate b={}x{} k={}", meta.b, meta.b2, meta.k),
                fmt_duration(m.mean),
                fmt_duration(m.p95),
                format!("{:.2} Mpairs/s", m.throughput().unwrap() / 1e6),
            ]);
        }
    } else {
        eprintln!("(artifacts/ missing — PJRT rows skipped; run `make artifacts`)");
    }

    table.print();
}
