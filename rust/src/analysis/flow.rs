//! Per-function forward dataflow for pallas-lint v2.
//!
//! Two linear passes over a function body's token stream:
//!
//! * **Taint** — tracks where integer values come from. Origins are
//!   strings: `param:<name>` for formal parameters, `dec@<line>` for
//!   values produced by a byte decoder (`from_le_bytes`, a crate
//!   function whose return is tainted, or an unresolved method named
//!   like a primitive width). Field projection composes
//!   (`param:h.map_rows`). `ensure!`/`bail!` arguments, `if`/`while`
//!   conditions, and `match` scrutinees *validate* the origins they
//!   mention. An allocation sized by an unvalidated `dec@` origin is a
//!   finding; sized by an unvalidated parameter it marks that
//!   parameter *sensitive*, and callers passing unvalidated decoded
//!   values into sensitive positions get the finding instead — that is
//!   the cross-helper reach the v1 lexical rule lacked.
//!
//! * **Locks** — tracks which lock classes are held at each point.
//!   Classes are the `SketchStore` lock fields in their declared
//!   global order ([`LOCK_ORDER`]); guards from `let` bindings live to
//!   end of scope, temporaries die at the end of their statement.
//!   Blocking acquisitions while a lower-ordered class is held,
//!   re-acquisition of a non-sharded class, and channel/thread
//!   blocking operations under any guard are findings. Acquisition
//!   pairs involving classes outside the declared order become crate
//!   edges; rules.rs reports them only when two call paths disagree
//!   on direction.
//!
//! Both passes are linear-scan approximations of dominance: facts
//! established earlier in the token stream are assumed to dominate
//! later uses, which holds for the rustfmt-shaped, early-return style
//! this crate enforces.
//!
//! Crate-level context lives in [`Summaries`]; rules.rs recomputes the
//! per-function facts to a fixpoint as summaries evolve.

use std::collections::{BTreeMap, BTreeSet};

use super::syntax::{TokKind, Tree};

/// Declared global lock-acquisition order for `SketchStore` fields.
/// Earlier classes must be acquired before later ones; `shards` may
/// nest with itself because shard guards are taken index-ascending.
pub const LOCK_ORDER: [&str; 4] = ["cached", "compaction", "shards", "segments"];

const ACQUIRE_METHODS: [&str; 9] = [
    "read",
    "write",
    "lock",
    "read_recover",
    "write_recover",
    "lock_recover",
    "try_read",
    "try_write",
    "try_lock",
];

/// Guard adapters that keep the acquire expression a guard value.
const GUARD_ADAPTERS: [&str; 4] = ["unwrap", "ok", "expect", "unwrap_or_else"];

/// Methods whose result does not carry the receiver's taint (sizes of
/// in-memory values, counts already bounded by materialized data).
const BENIGN_METHODS: [&str; 7] =
    ["len", "capacity", "is_empty", "remaining", "bytes", "count", "min"];

/// Method names assumed to decode untrusted bytes when they do not
/// resolve to a crate function (reader helpers named after widths).
const DECODER_FALLBACK: [&str; 4] = ["u8", "u16", "u32", "u64"];

/// Receiver methods that block on another thread.
const BLOCKING_METHODS: [&str; 4] = ["send", "recv", "recv_timeout", "spawn"];

const KEYWORDS_NOT_CALLS: [&str; 8] =
    ["if", "while", "for", "match", "return", "let", "loop", "in"];

/// Crate-level facts carried between fixpoint iterations.
#[derive(Default, Clone, PartialEq, Eq)]
pub struct Summaries {
    /// All function names defined in the crate (resolution universe).
    pub fns: BTreeSet<String>,
    /// Functions whose return value derives from decoded bytes
    /// without an intervening validation.
    pub taint_ret: BTreeSet<String>,
    /// Function → parameter indices that size an allocation without
    /// local validation.
    pub sensitive: BTreeMap<String, BTreeSet<usize>>,
    /// Function → known lock classes it (transitively) acquires.
    pub locks: BTreeMap<String, BTreeSet<String>>,
}

/// Facts extracted from one function under the current summaries.
#[derive(Default, Clone)]
pub struct FnFacts {
    pub name: String,
    /// Lines allocating with an unvalidated decoded size.
    pub alloc_findings: Vec<usize>,
    /// (line, callee): unvalidated decoded value passed into a
    /// sensitive parameter position.
    pub call_findings: Vec<(usize, String)>,
    /// Parameter indices that size allocations (here or in callees).
    pub sensitive: BTreeSet<usize>,
    /// Return value carries unvalidated decoded taint.
    pub taint_ret: bool,
    /// (line, message): definite lock-order violations.
    pub order_findings: Vec<(usize, String)>,
    /// (held-class, acquired-class, line) edges involving a class
    /// outside [`LOCK_ORDER`]; adjudicated crate-wide.
    pub edges: Vec<(String, String, usize)>,
    /// (line, message): blocking operation while a guard is held.
    pub blocking_findings: Vec<(usize, String)>,
    /// Known lock classes acquired directly or via callees.
    pub acquired: BTreeSet<String>,
}

/// Run both passes over `item`'s body.
pub fn fn_facts(
    code: &str,
    tree: &Tree,
    item: &super::syntax::FnItem,
    sums: &Summaries,
) -> FnFacts {
    let mut facts = FnFacts { name: item.name.clone(), ..FnFacts::default() };
    let Some((b0, b1)) = item.body else { return facts };
    taint_walk(code, tree, item, b0, b1, sums, &mut facts);
    lock_walk(code, tree, b0, b1, sums, &mut facts);
    facts
}

fn byte_at(code: &str, tree: &Tree, i: usize) -> u8 {
    code.as_bytes()[tree.toks[i].start]
}

fn is_punct(code: &str, tree: &Tree, i: usize, c: u8) -> bool {
    tree.toks[i].kind == TokKind::Punct && byte_at(code, tree, i) == c
}

fn is_open(code: &str, tree: &Tree, i: usize, c: u8) -> bool {
    tree.toks[i].kind == TokKind::Open && byte_at(code, tree, i) == c
}

/// `i` and `i+1` form a `::` path separator.
fn is_path_sep(code: &str, tree: &Tree, i: usize) -> bool {
    i + 1 < tree.toks.len()
        && is_punct(code, tree, i, b':')
        && is_punct(code, tree, i + 1, b':')
        && tree.toks[i].end == tree.toks[i + 1].start
}

// ---------------------------------------------------------------- taint

struct TaintCx<'a> {
    taint: BTreeMap<String, BTreeSet<String>>,
    validated: BTreeSet<String>,
    sums: &'a Summaries,
}

impl TaintCx<'_> {
    fn valid(&self, origin: &str) -> bool {
        self.validated.contains(origin)
            || self.validated.iter().any(|v| {
                // A validated value vouches for its field projections
                // (`ensure!(h <= cap)` covers `h.rows`), and a
                // field-level gate vouches for the struct it projects
                // from when that struct is passed onward whole:
                // `ensure!(header.rows * row_bytes <= file_len)`
                // followed by `read_row(&mut r, &header)` is the
                // dominant decode-then-gate idiom, and a name-keyed
                // analysis cannot see which fields the callee sizes by.
                // Scalar allocation sizes still need their own origin
                // (or a field of it) validated — `dec@L` never gains a
                // `.field` suffix from a gate on an unrelated value.
                (origin.len() > v.len()
                    && origin.starts_with(v.as_str())
                    && origin.as_bytes()[v.len()] == b'.')
                    || (v.len() > origin.len()
                        && v.starts_with(origin)
                        && v.as_bytes()[origin.len()] == b'.')
            })
    }
}

/// Union of chain origins for every chain rooted in `[from, to)`.
fn origins_of(cx: &TaintCx, code: &str, tree: &Tree, from: usize, to: usize) -> BTreeSet<String> {
    let t = &tree.toks;
    let mut out = BTreeSet::new();
    let to = to.min(t.len());
    for i in from..to {
        if t[i].kind != TokKind::Ident {
            continue;
        }
        // Chain roots only: not a `.field`/`.m()` segment, not the
        // tail of a `::` path.
        if i > 0 && is_punct(code, tree, i - 1, b'.') {
            continue;
        }
        if i >= 2 && is_path_sep(code, tree, i - 2) {
            continue;
        }
        out.extend(chain_origins(cx, code, tree, i, to));
    }
    out
}

/// Walk one ident chain (`a.b.c()`, `T::f(x)?`, `buf[i]`) and return
/// the origin set of its value.
fn chain_origins(
    cx: &TaintCx,
    code: &str,
    tree: &Tree,
    start: usize,
    limit: usize,
) -> BTreeSet<String> {
    let t = &tree.toks;
    let root = tree.text(code, start);
    let mut acc: BTreeSet<String> =
        cx.taint.get(root).cloned().unwrap_or_default();
    let mut emitted: BTreeSet<String> = BTreeSet::new();
    let mut i = start; // current segment ident (or tuple-index num)
    let mut is_root = true;
    loop {
        // Segment: call or field?
        let callish = i + 1 < limit && is_open(code, tree, i + 1, b'(');
        if callish && t[i].kind == TokKind::Ident {
            let callee = tree.text(code, i);
            let line = tree.line(code, i);
            if BENIGN_METHODS.contains(&callee) {
                acc.clear();
            } else if cx.sums.taint_ret.contains(callee)
                || callee == "from_le_bytes"
                || callee == "from_be_bytes"
                || (DECODER_FALLBACK.contains(&callee) && !cx.sums.fns.contains(callee))
            {
                acc = BTreeSet::from([format!("dec@{line}")]);
            } else {
                // Unknown transform: the receiver's taint escapes into
                // the result only as "was derived from" — record it.
                emitted.append(&mut acc);
            }
        } else if !is_root {
            // Field / tuple-index projection composes origins.
            let field = tree.text(code, i);
            acc = acc.iter().map(|o| format!("{o}.{field}")).collect();
        }
        is_root = false;
        // Continuation: skip the call group, then `?`/index hops, then
        // follow `.`/`::` to the next segment.
        let mut p = if callish { tree.close_of(i + 1) } else { i };
        loop {
            let n = p + 1;
            if n >= limit {
                emitted.extend(acc);
                return emitted;
            }
            if is_punct(code, tree, n, b'?') {
                p = n;
            } else if is_open(code, tree, n, b'[') {
                p = tree.close_of(n);
            } else {
                break;
            }
        }
        let n = p + 1;
        if n < limit && is_punct(code, tree, n, b'.') && n + 1 < limit
            && matches!(t[n + 1].kind, TokKind::Ident | TokKind::Num)
        {
            i = n + 1;
        } else if n + 2 < limit && is_path_sep(code, tree, n)
            && t[n + 2].kind == TokKind::Ident
        {
            i = n + 2;
        } else {
            emitted.extend(acc);
            return emitted;
        }
    }
}

/// Scan a `let`/`for` pattern region and return bound names (skips
/// `mut`/`ref`, constructors, paths, and the type annotation after a
/// top-level `:`).
fn pattern_names(code: &str, tree: &Tree, from: usize, to: usize) -> Vec<String> {
    let t = &tree.toks;
    let mut names = Vec::new();
    let mut i = from;
    while i < to.min(t.len()) {
        if is_punct(code, tree, i, b':') && !is_path_sep(code, tree, i)
            && !(i > 0 && is_path_sep(code, tree, i - 1))
        {
            break; // type annotation — stop collecting
        }
        if t[i].kind == TokKind::Ident {
            let s = tree.text(code, i);
            let ctor = i + 1 < t.len()
                && (is_open(code, tree, i + 1, b'(')
                    || is_punct(code, tree, i + 1, b'!')
                    || is_path_sep(code, tree, i + 1));
            if s != "mut" && s != "ref" && s != "_" && !ctor
                && !(i >= 2 && is_path_sep(code, tree, i - 2))
            {
                names.push(s.to_string());
            }
        }
        i += 1;
    }
    names
}

/// Find the `=` terminating a `let` pattern, scanning from `from`.
/// Returns None for `let`-else-less declarations (`let x;`).
fn find_pattern_eq(code: &str, tree: &Tree, from: usize, to: usize) -> Option<usize> {
    let t = &tree.toks;
    let mut i = from;
    while i < to.min(t.len()) {
        match t[i].kind {
            TokKind::Open => i = tree.close_of(i) + 1,
            TokKind::Punct => {
                let c = byte_at(code, tree, i);
                if c == b'=' {
                    // not ==, >=, <=, =>
                    let next_eq = is_punct(code, tree, i + 1, b'=')
                        && t[i].end == t[i + 1].start;
                    let next_gt = is_punct(code, tree, i + 1, b'>')
                        && t[i].end == t[i + 1].start;
                    let prev_cmp = i > 0
                        && t[i - 1].end == t[i].start
                        && matches!(byte_at(code, tree, i - 1), b'=' | b'>' | b'<' | b'!');
                    if !next_eq && !next_gt && !prev_cmp {
                        return Some(i);
                    }
                } else if c == b';' {
                    return None;
                }
                i += 1;
            }
            _ => i += 1,
        }
    }
    None
}

/// End of the statement starting after `=` at token `from`: the first
/// `;` at relative brace depth 0, or a `{` opening a block body
/// (`if let`/`while let`). Groups are jumped.
fn stmt_end(code: &str, tree: &Tree, from: usize, to: usize) -> usize {
    let t = &tree.toks;
    let mut i = from;
    while i < to.min(t.len()) {
        match t[i].kind {
            TokKind::Open => {
                if byte_at(code, tree, i) == b'{' {
                    return i;
                }
                i = tree.close_of(i) + 1;
            }
            TokKind::Punct if byte_at(code, tree, i) == b';' => return i,
            _ => i += 1,
        }
    }
    to.min(t.len())
}

/// Condition region: from `from` to the next `{` at group depth 0.
fn cond_end(code: &str, tree: &Tree, from: usize, to: usize) -> usize {
    let t = &tree.toks;
    let mut i = from;
    while i < to.min(t.len()) {
        match t[i].kind {
            TokKind::Open => {
                if byte_at(code, tree, i) == b'{' {
                    return i;
                }
                i = tree.close_of(i) + 1;
            }
            _ => i += 1,
        }
    }
    to.min(t.len())
}

fn taint_walk(
    code: &str,
    tree: &Tree,
    item: &super::syntax::FnItem,
    b0: usize,
    b1: usize,
    sums: &Summaries,
    facts: &mut FnFacts,
) {
    let t = &tree.toks;
    let mut cx = TaintCx { taint: BTreeMap::new(), validated: BTreeSet::new(), sums };
    for p in &item.params {
        cx.taint.insert(p.clone(), BTreeSet::from([format!("param:{p}")]));
    }
    let mut ret_origins: BTreeSet<String> = BTreeSet::new();
    let mut depth = 1usize;
    let mut last_semi = b0; // last `;` at body depth 1
    let mut i = b0 + 1;
    while i < b1 {
        match t[i].kind {
            TokKind::Open if byte_at(code, tree, i) == b'{' => {
                depth += 1;
                i += 1;
            }
            TokKind::Close if byte_at(code, tree, i) == b'}' => {
                depth = depth.saturating_sub(1);
                i += 1;
            }
            TokKind::Punct if byte_at(code, tree, i) == b';' => {
                if depth == 1 {
                    last_semi = i;
                }
                i += 1;
            }
            TokKind::Ident => {
                let w = tree.text(code, i);
                match w {
                    "let" => {
                        if let Some(eq) = find_pattern_eq(code, tree, i + 1, b1) {
                            let names = pattern_names(code, tree, i + 1, eq);
                            let end = stmt_end(code, tree, eq + 1, b1);
                            let orig = origins_of(&cx, code, tree, eq + 1, end);
                            for n in names {
                                cx.taint.insert(n, orig.clone());
                            }
                            i = eq + 1; // rescan RHS for allocs/validators
                        } else {
                            i += 1;
                        }
                    }
                    "for" => {
                        // `for <pat> in <iter> {`
                        let mut j = i + 1;
                        while j < b1 && !(t[j].kind == TokKind::Ident && tree.is(code, j, "in"))
                        {
                            if t[j].kind == TokKind::Open {
                                j = tree.close_of(j);
                            }
                            j += 1;
                        }
                        if j < b1 {
                            let names = pattern_names(code, tree, i + 1, j);
                            let end = cond_end(code, tree, j + 1, b1);
                            let orig = origins_of(&cx, code, tree, j + 1, end);
                            for n in names {
                                cx.taint.insert(n, orig.clone());
                            }
                            i = j + 1;
                        } else {
                            i += 1;
                        }
                    }
                    "if" | "while" | "match" => {
                        let from = if w != "match"
                            && i + 1 < b1
                            && tree.is(code, i + 1, "let")
                        {
                            // if-let: bind the pattern, validate the RHS
                            let pat_from = i + 2;
                            if let Some(eq) = find_pattern_eq(code, tree, pat_from, b1) {
                                let end = cond_end(code, tree, eq + 1, b1);
                                let orig = origins_of(&cx, code, tree, eq + 1, end);
                                for n in pattern_names(code, tree, pat_from, eq) {
                                    cx.taint.insert(n, orig.clone());
                                }
                                eq + 1
                            } else {
                                i + 1
                            }
                        } else {
                            i + 1
                        };
                        let end = cond_end(code, tree, from, b1);
                        let orig = origins_of(&cx, code, tree, from, end);
                        cx.validated.extend(orig);
                        i += 1;
                    }
                    "return" => {
                        let end = stmt_end(code, tree, i + 1, b1);
                        ret_origins.extend(origins_of(&cx, code, tree, i + 1, end));
                        i += 1;
                    }
                    "ensure" | "bail" => {
                        if i + 1 < b1
                            && is_punct(code, tree, i + 1, b'!')
                            && i + 2 < b1
                            && t[i + 2].kind == TokKind::Open
                        {
                            let close = tree.close_of(i + 2);
                            let orig = origins_of(&cx, code, tree, i + 3, close);
                            cx.validated.extend(orig);
                            i = i + 3;
                        } else {
                            i += 1;
                        }
                    }
                    "vec" => {
                        // `vec![elem; size]`
                        if i + 1 < b1
                            && is_punct(code, tree, i + 1, b'!')
                            && i + 2 < b1
                            && is_open(code, tree, i + 2, b'[')
                        {
                            let close = tree.close_of(i + 2);
                            let mut semi = None;
                            let mut j = i + 3;
                            while j < close {
                                if t[j].kind == TokKind::Open {
                                    j = tree.close_of(j);
                                } else if is_punct(code, tree, j, b';') {
                                    semi = Some(j);
                                    break;
                                }
                                j += 1;
                            }
                            if let Some(s) = semi {
                                let orig = origins_of(&cx, code, tree, s + 1, close);
                                note_alloc(&cx, item, facts, tree.line(code, i), &orig);
                            }
                        }
                        i += 1;
                    }
                    "with_capacity" | "reserve" => {
                        let dotted = i > 0 && is_punct(code, tree, i - 1, b'.');
                        let ok = if w == "reserve" { dotted } else { true };
                        if ok && i + 1 < b1 && is_open(code, tree, i + 1, b'(') {
                            let close = tree.close_of(i + 1);
                            let orig = origins_of(&cx, code, tree, i + 2, close);
                            note_alloc(&cx, item, facts, tree.line(code, i), &orig);
                        }
                        i += 1;
                    }
                    _ => {
                        // Call into a function with sensitive params?
                        let callish = i + 1 < b1
                            && is_open(code, tree, i + 1, b'(')
                            && !KEYWORDS_NOT_CALLS.contains(&w);
                        if callish && crate_local_callee(code, tree, i) {
                            if let Some(sens) = sums.sensitive.get(w) {
                                check_sensitive_call(
                                    &cx, code, tree, item, facts, i, sens,
                                );
                            }
                        }
                        i += 1;
                    }
                }
            }
            _ => i += 1,
        }
    }
    // Trailing expression (implicit return).
    if last_semi + 1 < b1 {
        ret_origins.extend(origins_of(&cx, code, tree, last_semi + 1, b1));
    }
    facts.taint_ret = ret_origins
        .iter()
        .any(|o| o.starts_with("dec@") && !cx.valid(o));
}

/// Record an allocation sized by `origins`: unvalidated decode →
/// finding; unvalidated parameter → sensitive parameter.
fn note_alloc(
    cx: &TaintCx,
    item: &super::syntax::FnItem,
    facts: &mut FnFacts,
    line: usize,
    origins: &BTreeSet<String>,
) {
    for o in origins {
        if cx.valid(o) {
            continue;
        }
        if o.starts_with("dec@") {
            facts.alloc_findings.push(line);
        } else if let Some(rest) = o.strip_prefix("param:") {
            let root = rest.split('.').next().unwrap_or(rest);
            if let Some(idx) = item.params.iter().position(|p| p == root) {
                facts.sensitive.insert(idx);
            }
        }
    }
}

/// Whether the call ident at `i` plausibly targets a crate-local fn, so
/// that a name-keyed summary may be applied: `self.f(..)`, free `f(..)`,
/// or `Self::f(..)`. Foreign-path calls (`Arc::new(..)`, `Vec::insert`
/// receivers) must NOT match — otherwise an unrelated local `fn new`
/// or `fn clone` poisons every `Arc::new` / `Arc::clone` call site in
/// the crate with its lock and taint summaries.
fn crate_local_callee(code: &str, tree: &Tree, i: usize) -> bool {
    let dotted = i > 0 && is_punct(code, tree, i - 1, b'.');
    if dotted {
        // Method call: only `self.f(..)` is summary-eligible; the
        // receiver of `segs.insert(..)` is a std container, not us.
        return i >= 2
            && tree.toks[i - 2].kind == TokKind::Ident
            && tree.is(code, i - 2, "self");
    }
    if i >= 2 && is_path_sep(code, tree, i - 2) {
        // Path call `X::f(..)`: eligible only when X is `Self`.
        return i >= 3
            && tree.toks[i - 3].kind == TokKind::Ident
            && tree.is(code, i - 3, "Self");
    }
    true
}

/// Arguments flowing into sensitive parameter positions of `callee`.
fn check_sensitive_call(
    cx: &TaintCx,
    code: &str,
    tree: &Tree,
    item: &super::syntax::FnItem,
    facts: &mut FnFacts,
    name_tok: usize,
    sens: &BTreeSet<usize>,
) {
    let open = name_tok + 1;
    let close = tree.close_of(open);
    // Split top-level commas.
    let t = &tree.toks;
    let mut args: Vec<(usize, usize)> = Vec::new();
    let mut seg = open + 1;
    let mut j = open + 1;
    while j <= close && j < t.len() {
        let comma = is_punct(code, tree, j, b',');
        if j == close || comma {
            if j > seg {
                args.push((seg, j));
            }
            seg = j + 1;
        } else if t[j].kind == TokKind::Open {
            j = tree.close_of(j);
        }
        j += 1;
    }
    // Method receivers shift positions by zero here: sensitive indices
    // are computed over declared params excluding self, and call-site
    // args exclude the receiver, so positions line up.
    for &si in sens {
        let Some(&(a0, a1)) = args.get(si) else { continue };
        for o in origins_of(cx, code, tree, a0, a1) {
            if cx.valid(&o) {
                continue;
            }
            if o.starts_with("dec@") {
                facts
                    .call_findings
                    .push((tree.line(code, name_tok), tree.text(code, name_tok).to_string()));
            } else if let Some(rest) = o.strip_prefix("param:") {
                let root = rest.split('.').next().unwrap_or(rest);
                if let Some(idx) = item.params.iter().position(|p| p == root) {
                    facts.sensitive.insert(idx);
                }
            }
        }
    }
}

// ---------------------------------------------------------------- locks

struct Guard {
    class: String,
    known: bool,
    name: Option<String>,
    /// Brace depth of the binding (named) or acquisition (temporary).
    depth: usize,
    temp: bool,
}

fn lock_fields() -> &'static [&'static str] {
    &LOCK_ORDER
}

/// Prepass: `let`/`for` bindings whose right-hand side mentions
/// `self.<lock-field>` alias their bound names to that field (iterator
/// pipelines over `self.shards`, etc.).
fn alias_map(code: &str, tree: &Tree, b0: usize, b1: usize) -> BTreeMap<String, String> {
    let t = &tree.toks;
    let mut out = BTreeMap::new();
    let mut i = b0 + 1;
    while i < b1 {
        if t[i].kind == TokKind::Ident {
            let w = tree.text(code, i);
            if w == "let" {
                if let Some(eq) = find_pattern_eq(code, tree, i + 1, b1) {
                    let end = stmt_end(code, tree, eq + 1, b1);
                    if let Some(f) = mentioned_lock_field(code, tree, eq + 1, end) {
                        for n in pattern_names(code, tree, i + 1, eq) {
                            out.insert(n, f.to_string());
                        }
                    }
                    i = eq;
                }
            } else if w == "for" {
                let mut j = i + 1;
                while j < b1 && !(t[j].kind == TokKind::Ident && tree.is(code, j, "in")) {
                    if t[j].kind == TokKind::Open {
                        j = tree.close_of(j);
                    }
                    j += 1;
                }
                if j < b1 {
                    let end = cond_end(code, tree, j + 1, b1);
                    if let Some(f) = mentioned_lock_field(code, tree, j + 1, end) {
                        for n in pattern_names(code, tree, i + 1, j) {
                            out.insert(n, f.to_string());
                        }
                    }
                    i = j;
                }
            }
        }
        i += 1;
    }
    out
}

/// First `self.<lock-field>` mentioned in the region, if any.
fn mentioned_lock_field<'c>(
    code: &'c str,
    tree: &Tree,
    from: usize,
    to: usize,
) -> Option<&'c str> {
    let t = &tree.toks;
    for i in from..to.min(t.len()).saturating_sub(2) {
        if t[i].kind == TokKind::Ident
            && tree.is(code, i, "self")
            && is_punct(code, tree, i + 1, b'.')
            && t[i + 2].kind == TokKind::Ident
        {
            let f = tree.text(code, i + 2);
            if lock_fields().contains(&f) {
                return Some(f);
            }
        }
    }
    None
}

fn order_index(class: &str) -> Option<usize> {
    LOCK_ORDER.iter().position(|c| *c == class)
}

fn lock_walk(
    code: &str,
    tree: &Tree,
    b0: usize,
    b1: usize,
    sums: &Summaries,
    facts: &mut FnFacts,
) {
    let t = &tree.toks;
    let aliases = alias_map(code, tree, b0, b1);
    let mut held: Vec<Guard> = Vec::new();
    let mut depth = 1usize;
    let mut i = b0 + 1;
    while i < b1 {
        match t[i].kind {
            TokKind::Open if byte_at(code, tree, i) == b'{' => {
                depth += 1;
                i += 1;
            }
            TokKind::Close if byte_at(code, tree, i) == b'}' => {
                depth = depth.saturating_sub(1);
                let d = depth;
                // Temporaries die when the closing brace lands back at
                // (or below) their acquisition depth — an if-let
                // scrutinee guard lives exactly through the if body.
                held.retain(|g| if g.temp { d > g.depth } else { g.depth <= d });
                i += 1;
            }
            TokKind::Punct if byte_at(code, tree, i) == b';' => {
                let d = depth;
                held.retain(|g| !(g.temp && g.depth == d));
                i += 1;
            }
            TokKind::Ident => {
                let w = tree.text(code, i);
                let dotted = i > 0 && is_punct(code, tree, i - 1, b'.');
                let next_open_paren = i + 1 < b1 && is_open(code, tree, i + 1, b'(');
                if w == "drop" && !dotted && next_open_paren {
                    let close = tree.close_of(i + 1);
                    if close == i + 3 && t[i + 2].kind == TokKind::Ident {
                        let victim = tree.text(code, i + 2);
                        held.retain(|g| g.name.as_deref() != Some(victim));
                    }
                    i = close + 1;
                    continue;
                }
                if dotted
                    && next_open_paren
                    && tree.close_of(i + 1) == i + 2
                    && ACQUIRE_METHODS.contains(&w)
                {
                    // Lock acquisition.
                    let non_blocking = w.starts_with("try_");
                    let (class, known) = resolve_class(code, tree, i, b0, &aliases);
                    if !non_blocking {
                        for g in &held {
                            note_edge(facts, g, &class, known, tree.line(code, i));
                        }
                    }
                    if known {
                        facts.acquired.insert(class.clone());
                    }
                    let close = i + 2;
                    let temp = guard_is_temporary(code, tree, close, b1);
                    let name = if temp { None } else { let_binding_name(code, tree, i, b0) };
                    let temp = temp || name.is_none();
                    held.push(Guard { class, known, name, depth, temp });
                    i = close + 1;
                    continue;
                }
                // Blocking operations under a guard.
                let blocking = (dotted
                    && next_open_paren
                    && (BLOCKING_METHODS.contains(&w)
                        || (w == "join" && tree.close_of(i + 1) == i + 2)))
                    || (!dotted
                        && w == "thread"
                        && i + 3 < b1
                        && is_path_sep(code, tree, i + 1)
                        && (tree.is(code, i + 3, "spawn") || tree.is(code, i + 3, "scope")));
                if blocking && !held.is_empty() {
                    let classes: Vec<&str> =
                        held.iter().map(|g| g.class.as_str()).collect();
                    facts.blocking_findings.push((
                        tree.line(code, i),
                        format!(
                            "blocking `{w}` while holding lock(s) {}",
                            classes.join(", ")
                        ),
                    ));
                    i += 1;
                    continue;
                }
                // Calls into crate functions that acquire locks:
                // `self.f(..)`, free `f(..)`, and `Self::f(..)` only —
                // see `crate_local_callee`.
                if next_open_paren
                    && !KEYWORDS_NOT_CALLS.contains(&w)
                    && crate_local_callee(code, tree, i)
                {
                    if let Some(classes) = sums.locks.get(w) {
                        for c in classes {
                            for g in &held {
                                note_edge(facts, g, c, true, tree.line(code, i));
                            }
                            facts.acquired.insert(c.clone());
                        }
                    }
                }
                i += 1;
            }
            _ => i += 1,
        }
    }
}

/// Record the (held → acquired) relation: definite finding when both
/// classes are in the declared order, a crate edge otherwise.
fn note_edge(facts: &mut FnFacts, held: &Guard, new_class: &str, new_known: bool, line: usize) {
    if held.known && new_known {
        let hi = order_index(&held.class);
        let ni = order_index(new_class);
        if let (Some(hi), Some(ni)) = (hi, ni) {
            if held.class == new_class {
                if new_class != "shards" {
                    facts.order_findings.push((
                        line,
                        format!("re-acquires lock class `{new_class}` while already held"),
                    ));
                }
            } else if hi > ni {
                facts.order_findings.push((
                    line,
                    format!(
                        "acquires `{new_class}` while holding `{}` — declared order is {}",
                        held.class,
                        LOCK_ORDER.join(" -> ")
                    ),
                ));
            }
            return;
        }
    }
    facts
        .edges
        .push((held.class.clone(), new_class.to_string(), line));
}

/// Classify the expression after an acquire's `()` — adapters and `?`
/// keep it a guard; any other `.method` consumes it immediately.
fn guard_is_temporary(code: &str, tree: &Tree, close: usize, b1: usize) -> bool {
    let t = &tree.toks;
    let mut p = close;
    loop {
        let n = p + 1;
        if n >= b1 {
            return false;
        }
        if is_punct(code, tree, n, b'?') {
            p = n;
            continue;
        }
        if is_punct(code, tree, n, b'.') && n + 1 < b1 && t[n + 1].kind == TokKind::Ident {
            let m = tree.text(code, n + 1);
            if GUARD_ADAPTERS.contains(&m)
                && n + 2 < b1
                && is_open(code, tree, n + 2, b'(')
            {
                p = tree.close_of(n + 2);
                continue;
            }
            return true; // projected through — the guard is a temporary
        }
        return false;
    }
}

/// If the statement containing token `at` is a `let`, return the first
/// bound name (the guard binding).
fn let_binding_name(code: &str, tree: &Tree, at: usize, b0: usize) -> Option<String> {
    let t = &tree.toks;
    let mut j = at;
    while j > b0 {
        j -= 1;
        match t[j].kind {
            TokKind::Punct if byte_at(code, tree, j) == b';' => break,
            TokKind::Open if byte_at(code, tree, j) == b'{' => break,
            TokKind::Close if byte_at(code, tree, j) == b'}' => break,
            _ => {}
        }
    }
    // First significant token after the boundary.
    let mut k = if j == b0 { b0 + 1 } else { j + 1 };
    while k < at && t[k].kind != TokKind::Ident {
        k += 1;
    }
    if k < at && tree.is(code, k, "let") {
        let eq = find_pattern_eq(code, tree, k + 1, at)?;
        pattern_names(code, tree, k + 1, eq).into_iter().next()
    } else {
        None
    }
}

/// Resolve the lock class of the receiver of the acquire method at
/// token `at` (the method ident; `at - 1` is the dot).
fn resolve_class(
    code: &str,
    tree: &Tree,
    at: usize,
    b0: usize,
    aliases: &BTreeMap<String, String>,
) -> (String, bool) {
    let t = &tree.toks;
    let mut r = at.saturating_sub(2); // token before the dot
    if t[r].kind == TokKind::Close && byte_at(code, tree, r) == b']' {
        // `self.shards[i].write()` — hop over the index.
        let open = tree.pair[r];
        if open != super::syntax::NO_PAIR && open > 0 {
            r = open - 1;
        }
    }
    if t[r].kind == TokKind::Ident {
        let name = tree.text(code, r);
        let field_dot = r > 0 && is_punct(code, tree, r - 1, b'.');
        if field_dot && lock_fields().contains(&name) {
            return (name.to_string(), true);
        }
        if !field_dot {
            // Same-statement backward search for `self.<field>` first
            // (closure parameters over a lock-field iterator). This
            // outranks the alias map: the alias prepass is
            // flow-insensitive, so a closure param `|s|` shadowing an
            // earlier `if let Some(s) = self.cached...` binding would
            // otherwise resolve to the wrong class.
            let mut j = r;
            while j > b0 {
                j -= 1;
                match t[j].kind {
                    TokKind::Punct if byte_at(code, tree, j) == b';' => break,
                    TokKind::Open if byte_at(code, tree, j) == b'{' => break,
                    TokKind::Close if byte_at(code, tree, j) == b'}' => break,
                    _ => {}
                }
            }
            if let Some(f) = mentioned_lock_field(code, tree, j, at) {
                return (f.to_string(), true);
            }
            if let Some(f) = aliases.get(name) {
                return (f.clone(), true);
            }
            return (name.to_string(), false);
        }
        // Dotted field that is not a declared lock class.
        return (name.to_string(), false);
    }
    ("<expr>".to_string(), false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::syntax::{fn_items, Tree};

    fn facts_of(code: &str, sums: &Summaries) -> Vec<FnFacts> {
        let tree = Tree::parse(code);
        fn_items(code, &tree)
            .iter()
            .map(|f| fn_facts(code, &tree, f, sums))
            .collect()
    }

    #[test]
    fn decoded_alloc_without_validation_is_flagged() {
        let code = r#"
fn read(buf: &[u8]) -> Vec<u8> {
    let n = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]) as usize;
    let mut out = Vec::with_capacity(n);
    out
}
"#;
        let fs = facts_of(code, &Summaries::default());
        assert_eq!(fs[0].alloc_findings.len(), 1, "{:?}", fs[0].alloc_findings);
    }

    #[test]
    fn ensure_validation_dominates_the_alloc() {
        let code = r#"
fn read(buf: &[u8]) -> Vec<u8> {
    let n = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]) as usize;
    ensure!(n <= MAX_ROWS);
    let mut out = Vec::with_capacity(n);
    out
}
"#;
        let fs = facts_of(code, &Summaries::default());
        assert!(fs[0].alloc_findings.is_empty(), "{:?}", fs[0].alloc_findings);
    }

    #[test]
    fn param_sized_alloc_marks_sensitive_not_finding() {
        let code = "fn fill(n: usize) -> Vec<u8> { let v = vec![0u8; n]; v }";
        let fs = facts_of(code, &Summaries::default());
        assert!(fs[0].alloc_findings.is_empty());
        assert_eq!(fs[0].sensitive, BTreeSet::from([0]));
    }

    #[test]
    fn decoded_arg_into_sensitive_param_is_flagged_at_call_site() {
        let mut sums = Summaries::default();
        sums.fns.insert("fill".into());
        sums.sensitive.insert("fill".into(), BTreeSet::from([0]));
        let code = r#"
fn load(buf: &[u8]) {
    let n = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]) as usize;
    fill(n);
}
"#;
        let fs = facts_of(code, &sums);
        assert_eq!(fs[0].call_findings.len(), 1, "{:?}", fs[0].call_findings);
    }

    #[test]
    fn taint_ret_propagates_through_helper() {
        let code = "fn rd(b: &[u8]) -> u32 { u32::from_le_bytes([b[0], b[1], b[2], b[3]]) }";
        let fs = facts_of(code, &Summaries::default());
        assert!(fs[0].taint_ret);
    }

    #[test]
    fn benign_len_clears_taint() {
        let code = "fn f(rows: &[u8]) -> Vec<u8> { Vec::with_capacity(rows.len()) }";
        let fs = facts_of(code, &Summaries::default());
        assert!(fs[0].alloc_findings.is_empty());
        assert!(fs[0].sensitive.is_empty());
    }

    #[test]
    fn inverted_lock_order_is_flagged() {
        let code = r#"
fn bad(&self) {
    let segs = self.segments.write_recover();
    let c = self.compaction.lock_recover();
}
"#;
        let fs = facts_of(code, &Summaries::default());
        assert_eq!(fs[0].order_findings.len(), 1, "{:?}", fs[0].order_findings);
    }

    #[test]
    fn declared_order_is_clean_and_temporaries_die_at_semicolon() {
        let code = r#"
fn good(&self) {
    let plan = self.segments.read_recover().clone();
    let c = self.compaction.lock_recover();
    let mut segs = self.segments.write_recover();
}
"#;
        // plan's guard is a temporary (projected through .clone()) and
        // dies at the `;`, so compaction-after-segments never happens.
        let fs = facts_of(code, &Summaries::default());
        assert!(fs[0].order_findings.is_empty(), "{:?}", fs[0].order_findings);
    }

    #[test]
    fn blocking_recv_under_guard_is_flagged() {
        let code = r#"
fn worker(&self) {
    let guard = rx.lock_recover();
    let block = guard.recv();
}
"#;
        let fs = facts_of(code, &Summaries::default());
        assert_eq!(fs[0].blocking_findings.len(), 1, "{:?}", fs[0].blocking_findings);
    }

    #[test]
    fn closure_guard_resolves_via_same_statement_receiver() {
        let code = r#"
fn snap(&self) {
    let cache = self.cached.write_recover();
    let shards: Vec<_> = self.shards.iter().map(|s| s.read_recover()).collect();
    let segs = self.segments.read_recover();
}
"#;
        let fs = facts_of(code, &Summaries::default());
        assert!(fs[0].order_findings.is_empty(), "{:?}", fs[0].order_findings);
        assert!(fs[0].edges.is_empty(), "{:?}", fs[0].edges);
        assert_eq!(
            fs[0].acquired,
            BTreeSet::from(["cached".to_string(), "shards".to_string(), "segments".to_string()])
        );
    }

    #[test]
    fn callee_lock_summary_creates_edges_at_call_site() {
        let mut sums = Summaries::default();
        sums.fns.insert("refresh".into());
        sums.locks.insert("refresh".into(), BTreeSet::from(["compaction".to_string()]));
        let code = r#"
fn bad(&self) {
    let segs = self.segments.write_recover();
    self.refresh();
}
"#;
        let fs = facts_of(code, &sums);
        assert_eq!(fs[0].order_findings.len(), 1, "{:?}", fs[0].order_findings);
    }

    #[test]
    fn try_acquire_is_held_but_creates_no_edge() {
        let code = r#"
fn ins(&self) {
    let g = self.shards.write_recover();
    if let Some(mut cache) = self.cached.try_write() {
        cache.clear();
    }
}
"#;
        // shards -> cached would be inverted, but try_write is
        // non-blocking and must not create the edge.
        let fs = facts_of(code, &Summaries::default());
        assert!(fs[0].order_findings.is_empty(), "{:?}", fs[0].order_findings);
    }

    #[test]
    fn unknown_classes_become_crate_edges() {
        let code = r#"
fn a(&self) {
    let g = left.lock_recover();
    let h = right.lock_recover();
}
"#;
        let fs = facts_of(code, &Summaries::default());
        assert_eq!(fs[0].edges.len(), 1);
        assert_eq!(fs[0].edges[0].0, "left");
        assert_eq!(fs[0].edges[0].1, "right");
    }

    #[test]
    fn foreign_path_call_does_not_match_local_summaries() {
        // A crate-local `fn new` that locks and allocates must not
        // poison `Arc::new(..)` call sites via the shared bare name.
        let mut sums = Summaries::default();
        sums.fns.insert("new".into());
        sums.locks.insert("new".into(), BTreeSet::from(["cached".to_string()]));
        sums.sensitive.insert("new".into(), BTreeSet::from([0]));
        let code = r#"
fn publish(&self, b: &[u8]) {
    let n = u32::from_le_bytes([b[0], b[1], b[2], b[3]]) as usize;
    let g = self.shards[0].write_recover();
    let v = Arc::new(n);
}
"#;
        let fs = facts_of(code, &sums);
        assert!(fs[0].order_findings.is_empty(), "{:?}", fs[0].order_findings);
        assert!(fs[0].call_findings.is_empty(), "{:?}", fs[0].call_findings);
        // `Self::new(..)` IS the local constructor — summaries apply.
        let local = code.replace("Arc::new(n)", "Self::new(n)");
        let fs = facts_of(&local, &sums);
        assert_eq!(fs[0].order_findings.len(), 1, "{:?}", fs[0].order_findings);
        assert_eq!(fs[0].call_findings.len(), 1, "{:?}", fs[0].call_findings);
    }

    #[test]
    fn stale_alias_is_outranked_by_same_statement_receiver() {
        // The alias prepass is flow-insensitive: `s` below is first an
        // if-let binding over `cached`, then a closure parameter over
        // the `shards` iterator. Same-statement evidence must win or
        // the capture loop reads as a bogus `cached` re-acquisition.
        let code = r#"
fn snapshot(&self) -> Arc<StoreSnapshot> {
    if let Some(s) = self.cached.read_recover().as_ref() {
        return Arc::clone(s);
    }
    let mut cache = self.cached.write_recover();
    let guards: Vec<_> = self.shards.iter().map(|s| s.read_recover()).collect();
    *cache = Some(build(&guards));
    drop(guards);
}
"#;
        let fs = facts_of(code, &Summaries::default());
        assert!(fs[0].order_findings.is_empty(), "{:?}", fs[0].order_findings);
    }

    #[test]
    fn field_gate_validates_the_struct_passed_whole() {
        // The decode-then-gate idiom: `ensure!` over header fields
        // vouches for passing the header itself into a size-sensitive
        // helper — a name-keyed analysis cannot see which fields the
        // callee sizes by.
        let mut sums = Summaries::default();
        sums.fns.insert("read_row".into());
        sums.fns.insert("decode_header".into());
        sums.taint_ret.insert("decode_header".into());
        sums.sensitive.insert("read_row".into(), BTreeSet::from([1]));
        let code = r#"
fn load(r: &mut Reader, file_len: u64) -> anyhow::Result<()> {
    let h = decode_header(r)?;
    ensure!(h.rows * h.row_bytes <= file_len);
    for _ in 0..h.rows {
        read_row(r, &h)?;
    }
    Ok(())
}
"#;
        let fs = facts_of(code, &sums);
        assert!(fs[0].call_findings.is_empty(), "{:?}", fs[0].call_findings);
        let unguarded = code.replace("    ensure!(h.rows * h.row_bytes <= file_len);\n", "");
        let fs = facts_of(&unguarded, &sums);
        assert_eq!(fs[0].call_findings.len(), 1, "{:?}", fs[0].call_findings);
    }
}
