//! Lexical preprocessing for pallas-lint.
//!
//! [`strip`] produces a copy of a Rust source file with comments,
//! string literals, and char literals blanked to spaces — **same byte
//! length, newlines preserved** — so every byte offset and line number
//! in the stripped text maps 1:1 onto the original file. Rule matching
//! then runs over the stripped text and can never fire on `unwrap()`
//! inside a doc comment or an error message.
//!
//! Handled Rust lexical forms: line comments, nested block comments,
//! plain / escaped strings, byte strings, C strings (`c".."` /
//! `cr#".."#`, Rust 1.77+), raw (byte) strings with any `#` count,
//! char and byte-char literals, and the char-literal vs lifetime
//! (`'a`) ambiguity. Raw identifiers (`r#fn`) pass through as code.
//! Known simplification: a multi-byte char literal (`'→'`) is left as
//! code — it cannot contain a rule token, so this is harmless.
//!
//! Allow pragmas are extracted from line comments during the same scan:
//!
//! ```text
//! // pallas-lint: allow(<rule>) -- <reason>
//! ```
//!
//! The reason clause is mandatory; a pragma without one is itself
//! reported by the engine. A pragma suppresses matching findings on its
//! own line or on the next non-blank code line.

/// One `// pallas-lint: allow(..)` comment found during stripping.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Pragma {
    /// 1-based line the pragma comment sits on.
    pub line: usize,
    /// Rule name inside `allow(..)`; `None` when the pragma is
    /// syntactically malformed.
    pub rule: Option<String>,
    /// Text after `--`; `None` when the mandatory reason is missing.
    pub reason: Option<String>,
}

/// Result of [`strip`]: blank-stripped source plus extracted pragmas.
#[derive(Debug)]
pub struct Stripped {
    /// Same byte length as the input; comments/strings/chars are
    /// spaces, newlines are preserved.
    pub code: String,
    pub pragmas: Vec<Pragma>,
    /// 1-based lines of comments carrying a `SAFETY:` contract. The
    /// comments themselves are blanked like any other, so the
    /// `unsafe-contract` rule reads this list instead of the code.
    pub safety_lines: Vec<usize>,
}

const PRAGMA_MARKER: &str = "pallas-lint:";
const SAFETY_MARKER: &str = "SAFETY:";

fn is_ident_byte(c: u8) -> bool {
    c == b'_' || c.is_ascii_alphanumeric()
}

fn blank(out: &mut [u8], from: usize, to: usize) {
    for c in out.iter_mut().take(to).skip(from) {
        if *c != b'\n' {
            *c = b' ';
        }
    }
}

/// Strip comments, strings, and char literals from `src`, extracting
/// pragmas along the way. Output is byte-length-identical to the input.
pub fn strip(src: &str) -> Stripped {
    let b = src.as_bytes();
    let n = b.len();
    let mut out = b.to_vec();
    let mut pragmas = Vec::new();
    let mut safety_lines = Vec::new();
    let mut line = 1usize;
    let mut i = 0usize;
    while i < n {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            let start = i;
            let mut j = i + 2;
            while j < n && b[j] != b'\n' {
                j += 1;
            }
            // Doc comments (`///`, `//!`) never carry pragmas — they
            // *describe* the syntax (as this module's docs do).
            let doc = matches!(b.get(i + 2), Some(b'/') | Some(b'!'));
            if !doc {
                if let Some(p) = parse_pragma(&src[start..j], line) {
                    pragmas.push(p);
                }
            }
            if src[start..j].contains(SAFETY_MARKER) {
                safety_lines.push(line);
            }
            blank(&mut out, start, j);
            i = j;
            continue;
        }
        if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let start = i;
            let comment_line = line;
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if b[j] == b'/' && j + 1 < n && b[j + 1] == b'*' {
                    depth += 1;
                    j += 2;
                } else if b[j] == b'*' && j + 1 < n && b[j + 1] == b'/' {
                    depth -= 1;
                    j += 2;
                } else {
                    if b[j] == b'\n' {
                        line += 1;
                    }
                    j += 1;
                }
            }
            if src[start..j].contains(SAFETY_MARKER) {
                safety_lines.push(comment_line);
            }
            blank(&mut out, start, j);
            i = j;
            continue;
        }
        if c == b'"' {
            let j = skip_string(b, i, &mut line);
            blank(&mut out, i, j);
            i = j;
            continue;
        }
        let fresh = i == 0 || !is_ident_byte(b[i - 1]);
        if c == b'r' && fresh {
            if let Some(j) = skip_raw_string(b, i + 1, &mut line) {
                blank(&mut out, i, j);
                i = j;
                continue;
            }
        }
        if c == b'b' && fresh && i + 1 < n {
            if b[i + 1] == b'"' {
                let j = skip_string(b, i + 1, &mut line);
                blank(&mut out, i, j);
                i = j;
                continue;
            }
            if b[i + 1] == b'r' {
                if let Some(j) = skip_raw_string(b, i + 2, &mut line) {
                    blank(&mut out, i, j);
                    i = j;
                    continue;
                }
            }
            if b[i + 1] == b'\'' {
                let j = skip_char(b, i + 1);
                blank(&mut out, i, j);
                i = j;
                continue;
            }
        }
        // C-string literals (Rust 1.77+): `c".."` and raw `cr#".."#`.
        // Without this arm the `c` lexes as an identifier and the
        // string body is scanned as code — desyncing every later
        // offset if the literal contains a quote or comment marker.
        if c == b'c' && fresh && i + 1 < n {
            if b[i + 1] == b'"' {
                let j = skip_string(b, i + 1, &mut line);
                blank(&mut out, i, j);
                i = j;
                continue;
            }
            if b[i + 1] == b'r' {
                if let Some(j) = skip_raw_string(b, i + 2, &mut line) {
                    blank(&mut out, i, j);
                    i = j;
                    continue;
                }
            }
        }
        if c == b'\'' && is_char_literal(b, i) {
            let j = skip_char(b, i);
            blank(&mut out, i, j);
            i = j;
            continue;
        }
        i += 1;
    }
    // Blanking only ever touches non-newline bytes inside literal /
    // comment spans, so the output stays valid UTF-8: multi-byte
    // sequences are replaced whole, never split.
    let code = String::from_utf8(out).unwrap_or_else(|e| {
        String::from_utf8_lossy(e.as_bytes()).into_owned()
    });
    Stripped { code, pragmas, safety_lines }
}

/// `i` points at the opening quote; returns the index one past the
/// closing quote (or end of input for an unterminated string).
fn skip_string(b: &[u8], i: usize, line: &mut usize) -> usize {
    let n = b.len();
    let mut j = i + 1;
    while j < n {
        match b[j] {
            // An escape pair may hide a line-continuation newline —
            // count it, or every later line number drifts.
            b'\\' => {
                if b.get(j + 1) == Some(&b'\n') {
                    *line += 1;
                }
                j += 2;
            }
            b'"' => return j + 1,
            b'\n' => {
                *line += 1;
                j += 1;
            }
            _ => j += 1,
        }
    }
    n
}

/// `j` points just past the `r` (or `br`) prefix. Returns the index one
/// past the closing delimiter, or `None` if this is not a raw string
/// (e.g. a raw identifier like `r#fn`).
fn skip_raw_string(b: &[u8], j: usize, line: &mut usize) -> Option<usize> {
    let n = b.len();
    let mut hashes = 0usize;
    let mut k = j;
    while k < n && b[k] == b'#' {
        hashes += 1;
        k += 1;
    }
    if k >= n || b[k] != b'"' {
        return None;
    }
    k += 1;
    while k < n {
        if b[k] == b'\n' {
            *line += 1;
        } else if b[k] == b'"' {
            let close = &b[k + 1..];
            if close.len() >= hashes && close[..hashes].iter().all(|&c| c == b'#') {
                return Some(k + 1 + hashes);
            }
        }
        k += 1;
    }
    Some(n)
}

/// `i` points at a `'` in code position: char literal or lifetime?
fn is_char_literal(b: &[u8], i: usize) -> bool {
    let n = b.len();
    if i + 1 >= n {
        return false;
    }
    if b[i + 1] == b'\\' {
        return true; // '\n', '\'', '\u{..}' — always a literal
    }
    // 'x' is a literal; 'x anything-else (lifetime, loop label) is not.
    b[i + 1] != b'\'' && i + 2 < n && b[i + 2] == b'\''
}

/// `i` points at the opening quote of a (validated) char literal.
fn skip_char(b: &[u8], i: usize) -> usize {
    let n = b.len();
    let mut j = i + 1;
    if j < n && b[j] == b'\\' {
        j += 2;
    } else {
        j += 1;
    }
    while j < n && b[j] != b'\'' && j - i < 12 {
        j += 1; // escapes like '\u{1F600}' span several bytes
    }
    (j + 1).min(n)
}

/// Parse one line comment as a pragma. `None` when the comment does not
/// mention the pragma marker at all.
fn parse_pragma(comment: &str, line: usize) -> Option<Pragma> {
    let at = comment.find(PRAGMA_MARKER)?;
    let rest = comment[at + PRAGMA_MARKER.len()..].trim();
    let Some(body) = rest.strip_prefix("allow(") else {
        return Some(Pragma { line, rule: None, reason: None });
    };
    let Some(close) = body.find(')') else {
        return Some(Pragma { line, rule: None, reason: None });
    };
    let rule = body[..close].trim().to_string();
    let rule = (!rule.is_empty()).then_some(rule);
    let tail = body[close + 1..].trim();
    let reason = tail
        .strip_prefix("--")
        .map(str::trim)
        .filter(|r| !r.is_empty())
        .map(str::to_string);
    Some(Pragma { line, rule, reason })
}

/// 1-based inclusive line ranges of `#[cfg(test)]` items (their whole
/// brace-delimited bodies) in **stripped** code. Rules skip these
/// lines: tests unwrap freely by design.
pub fn test_spans(code: &str) -> Vec<(usize, usize)> {
    let b = code.as_bytes();
    let n = b.len();
    let mut spans = Vec::new();
    let mut search = 0usize;
    while let Some(rel) = code[search..].find("#[cfg(test)]") {
        let attr = search + rel;
        let mut j = attr + "#[cfg(test)]".len();
        // Skip whitespace and any further attributes, then find the
        // item's opening brace (a `;` first means a bodyless item).
        loop {
            while j < n && (b[j] as char).is_whitespace() {
                j += 1;
            }
            if j < n && b[j] == b'#' {
                while j < n && b[j] != b']' {
                    j += 1;
                }
                j += 1;
                continue;
            }
            break;
        }
        let mut open = None;
        let mut k = j;
        while k < n {
            match b[k] {
                b'{' => {
                    open = Some(k);
                    break;
                }
                b';' => break,
                _ => k += 1,
            }
        }
        if let Some(open) = open {
            let mut depth = 0isize;
            let mut end = open;
            while end < n {
                match b[end] {
                    b'{' => depth += 1,
                    b'}' => {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    _ => {}
                }
                end += 1;
            }
            spans.push((line_of(code, attr), line_of(code, end.min(n - 1))));
            search = end.min(n - 1) + 1;
        } else {
            search = j.max(attr + 1);
        }
        if search >= n {
            break;
        }
    }
    spans
}

/// 1-based line number of byte offset `at`.
pub fn line_of(code: &str, at: usize) -> usize {
    code.as_bytes()[..at.min(code.len())]
        .iter()
        .filter(|&&c| c == b'\n')
        .count()
        + 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_and_strings_are_blanked_to_same_length() {
        let src = r#"let x = "unwrap() in a string"; // unwrap() in a comment
let y = 1; /* block unwrap() */ let z = 2;
"#;
        let s = strip(src);
        assert_eq!(s.code.len(), src.len());
        assert!(!s.code.contains("unwrap"), "{}", s.code);
        assert!(s.code.contains("let x ="));
        assert!(s.code.contains("let z = 2;"));
        // Newlines survive so line numbers still map.
        assert_eq!(s.code.matches('\n').count(), src.matches('\n').count());
    }

    #[test]
    fn nested_block_comments() {
        let src = "a /* outer /* inner unwrap() */ still comment */ b";
        let s = strip(src);
        assert!(!s.code.contains("unwrap"));
        assert!(s.code.starts_with('a'));
        assert!(s.code.ends_with('b'));
    }

    #[test]
    fn raw_strings_and_escapes() {
        let src = r####"let a = r#"raw "quoted" unwrap()"#; let b = "esc \" unwrap()"; let c = br##"bytes unwrap()"##;"####;
        let s = strip(src);
        assert!(!s.code.contains("unwrap"), "{}", s.code);
        assert!(s.code.contains("let a ="));
        assert!(s.code.contains("let b ="));
        assert!(s.code.contains("let c ="));
    }

    #[test]
    fn c_string_literals_are_blanked_with_exact_offsets() {
        // `c"..."` (Rust 1.77+) must be treated like `b"..."`: the old
        // lexer read `c` as an identifier and entered the string body
        // as code, so an embedded `//` would eat the rest of the line.
        let src = "let p = c\"unwrap() // not a comment\"; let q = 1;\nlet r = cr#\"raw c unwrap()\"#; let s = 2;\n";
        let s = strip(src);
        assert_eq!(s.code.len(), src.len(), "byte length preserved");
        assert!(!s.code.contains("unwrap"), "{}", s.code);
        assert!(s.code.contains("let q = 1;"), "code after the literal survives: {}", s.code);
        assert!(s.code.contains("let s = 2;"), "{}", s.code);
        // Offsets still map 1:1: `let q` sits at the same byte index.
        assert_eq!(s.code.find("let q"), src.find("let q"));
        assert_eq!(s.code.matches('\n').count(), src.matches('\n').count());
    }

    #[test]
    fn c_prefixed_identifiers_are_not_strings() {
        let src = "let count = cfg.count; c_helper();\n";
        let s = strip(src);
        assert_eq!(s.code, src);
    }

    #[test]
    fn safety_comment_lines_are_recorded() {
        let src = "// SAFETY: len checked above\nunsafe { ptr.read() }\n// ordinary comment\n/* SAFETY: block form */\n";
        let s = strip(src);
        assert_eq!(s.safety_lines, vec![1, 4]);
        assert!(!s.code.contains("SAFETY"), "comment still blanked: {}", s.code);
    }

    #[test]
    fn raw_identifier_is_not_a_string() {
        let src = "fn r#type() { r#match.unwrap() }";
        let s = strip(src);
        assert!(s.code.contains("unwrap"));
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        let src = "fn f<'a>(x: &'a str) { let c = '\\''; let d = 'x'; 'outer: loop { break 'outer; } }";
        let s = strip(src);
        assert_eq!(s.code.len(), src.len());
        assert!(s.code.contains("'a>"), "lifetime kept: {}", s.code);
        assert!(s.code.contains("'outer: loop"), "label kept: {}", s.code);
        assert!(!s.code.contains("'x'"), "char blanked: {}", s.code);
    }

    #[test]
    fn pragma_with_reason_parses() {
        let src = "x(); // pallas-lint: allow(serving-no-panic) -- checked two lines up\n";
        let s = strip(src);
        assert_eq!(
            s.pragmas,
            vec![Pragma {
                line: 1,
                rule: Some("serving-no-panic".into()),
                reason: Some("checked two lines up".into()),
            }]
        );
    }

    #[test]
    fn pragma_without_reason_has_none() {
        let src = "// pallas-lint: allow(len-before-alloc)\n// pallas-lint: allow(x) --   \n";
        let s = strip(src);
        assert_eq!(s.pragmas.len(), 2);
        assert!(s.pragmas.iter().all(|p| p.reason.is_none()));
        assert_eq!(s.pragmas[0].rule.as_deref(), Some("len-before-alloc"));
        assert_eq!(s.pragmas[1].line, 2);
    }

    #[test]
    fn malformed_pragma_is_surfaced_not_dropped() {
        let src = "// pallas-lint: allo(typo)\n";
        let s = strip(src);
        assert_eq!(s.pragmas.len(), 1);
        assert!(s.pragmas[0].rule.is_none());
    }

    #[test]
    fn doc_comments_never_carry_pragmas() {
        // Rule docs quote pragma syntax in `///` blocks; only plain
        // `//` comments may carry live pragmas.
        let src = "/// pallas-lint: allow(serving-no-panic) -- quoted in docs\n//! pallas-lint: allo(typo)\n// pallas-lint: allow(pragma) -- the real one\n";
        let s = strip(src);
        assert_eq!(s.pragmas.len(), 1);
        assert_eq!(s.pragmas[0].line, 3);
        assert_eq!(s.pragmas[0].rule.as_deref(), Some("pragma"));
    }

    #[test]
    fn line_continuation_escape_keeps_line_numbers_exact() {
        // A `\` at end of a string line escapes the newline; the lexer
        // must still count that line or every later number drifts.
        let src = "let s = \"one \\\n    two\";\nx(); // pallas-lint: allow(serving-no-panic) -- after the continuation\n";
        let s = strip(src);
        assert_eq!(s.pragmas.len(), 1);
        assert_eq!(s.pragmas[0].line, 3);
    }

    #[test]
    fn ordinary_comments_are_not_pragmas() {
        let src = "// just a note about allow(foo)\n";
        assert!(strip(src).pragmas.is_empty());
    }

    #[test]
    fn test_mod_span_covers_body() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\nfn after() {}\n";
        let s = strip(src);
        let spans = test_spans(&s.code);
        assert_eq!(spans.len(), 1);
        let (a, b) = spans[0];
        assert!(a <= 2 && b >= 5, "span {a}..{b}");
        assert!(b < 6, "span must not swallow code after the mod");
    }

    #[test]
    fn cfg_test_on_bodyless_item_yields_no_span() {
        let src = "#[cfg(test)]\nuse foo::bar;\nfn live() {}\n";
        let s = strip(src);
        assert!(test_spans(&s.code).is_empty());
    }
}
