//! pallas-lint: in-repo static analysis enforcing the crate's serving
//! conventions.
//!
//! PRs 1–5 built a concurrent serving system whose correctness rests
//! on hand-maintained disciplines — panic-free serving paths,
//! "validate declared counts before any allocation" in the wire and
//! persist codecs, and the epoch/COW lock order of the snapshot store.
//! This module machine-checks them: a [`lexer`] that strips comments,
//! strings, and char literals (byte-length-preserving, so offsets map
//! to lines), and a [`rules`] engine with module-scoped rule sets and
//! an inline allow-pragma syntax:
//!
//! ```text
//! // pallas-lint: allow(serving-no-panic) -- length checked two lines up
//! ```
//!
//! The reason clause after `--` is mandatory; stale or malformed
//! pragmas are themselves findings. Run it as `lpsketch lint` or via
//! the `lint_gate` integration test, both of which walk `rust/src/`
//! and fail on any un-pragma'd violation. Rule inventory and scoping
//! live in [`rules`]; the README has the operator-facing summary.
//!
//! The analyzer is deliberately lexical (no syn, no rustc internals —
//! the crate stays dependency-free): precise enough for this
//! codebase's rustfmt-shaped sources, and every heuristic limit is
//! documented where it lives.

pub mod lexer;
pub mod rules;

pub use rules::{analyze_source, analyze_tree, count_rs_files, rules_for, Finding};
pub use rules::{
    GUARD_ACROSS_BLOCKING, LEN_BEFORE_ALLOC, NO_INDEX_UNTRUSTED, PRAGMA_RULE, SERVING_NO_PANIC,
    WRITER_BUMPS_EPOCH,
};

#[cfg(test)]
mod tests {
    use super::*;

    fn fires(findings: &[Finding], rule: &str) -> bool {
        findings.iter().any(|f| f.rule == rule)
    }

    // -- serving-no-panic ---------------------------------------------------

    #[test]
    fn no_panic_fires_on_unwrap_expect_and_macros() {
        let src = "pub fn f(x: Option<u32>) -> u32 { x.unwrap() }\n\
                   pub fn g(x: Option<u32>) -> u32 { x.expect(\"present\") }\n\
                   pub fn h() { panic!(\"boom\") }\n\
                   pub fn i() { unreachable!() }\n";
        let f = analyze_source("core/estimator.rs", src);
        assert_eq!(f.iter().filter(|x| x.rule == SERVING_NO_PANIC).count(), 4, "{f:?}");
        assert_eq!(f[0].line, 1);
    }

    #[test]
    fn no_panic_passes_on_fallible_style() {
        let src = "pub fn f(x: Option<u32>) -> anyhow::Result<u32> {\n\
                       x.ok_or_else(|| anyhow::anyhow!(\"missing\"))\n\
                   }\n\
                   pub fn g(x: Option<u32>) -> u32 { x.unwrap_or(0) }\n";
        let f = analyze_source("core/estimator.rs", src);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn no_panic_is_scoped_to_serving_modules() {
        let src = "pub fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
        assert!(fires(&analyze_source("api/service.rs", src), SERVING_NO_PANIC));
        assert!(fires(&analyze_source("coordinator/pipeline.rs", src), SERVING_NO_PANIC));
        assert!(!fires(&analyze_source("experiments/mod.rs", src), SERVING_NO_PANIC));
        assert!(!fires(&analyze_source("main.rs", src), SERVING_NO_PANIC));
    }

    #[test]
    fn no_panic_ignores_test_mods_strings_and_comments() {
        let src = "pub fn f() -> u32 { 1 } // the old code called unwrap() here\n\
                   pub fn g() -> &'static str { \"never unwrap() in serving\" }\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                       #[test]\n\
                       fn t() { Some(1).unwrap(); panic!(\"fine in tests\"); }\n\
                   }\n";
        let f = analyze_source("api/wire.rs", src);
        assert!(f.is_empty(), "{f:?}");
    }

    // -- no-index-untrusted -------------------------------------------------

    #[test]
    fn index_fires_on_slice_indexing() {
        let src = "pub fn kind(b: &[u8]) -> u8 { b[4] }\n\
                   pub fn window(b: &[u8]) -> &[u8] { &b[2..6] }\n";
        let f = analyze_source("api/protocol.rs", src);
        assert_eq!(f.iter().filter(|x| x.rule == NO_INDEX_UNTRUSTED).count(), 2, "{f:?}");
    }

    #[test]
    fn index_passes_on_get_and_type_position() {
        let src = "pub fn kind(b: &[u8]) -> Option<u8> { b.get(4).copied() }\n\
                   pub fn fill(buf: &mut [u8], arr: [u8; 4]) -> Vec<[f32; 2]> { Vec::new() }\n";
        let f = analyze_source("api/protocol.rs", src);
        assert!(!fires(&f, NO_INDEX_UNTRUSTED), "{f:?}");
    }

    #[test]
    fn index_is_scoped_to_the_api_boundary() {
        let src = "pub fn dot(a: &[f32], b: &[f32]) -> f32 { a[0] * b[0] }\n";
        assert!(!fires(&analyze_source("core/estimator.rs", src), NO_INDEX_UNTRUSTED));
        assert!(fires(&analyze_source("api/wire.rs", src), NO_INDEX_UNTRUSTED));
    }

    // -- len-before-alloc ---------------------------------------------------

    #[test]
    fn alloc_fires_without_validation() {
        let src = "fn decode(cur: &mut Cur) -> anyhow::Result<Vec<u64>> {\n\
                       let n = cur.u32()? as usize;\n\
                       let mut v = Vec::with_capacity(n);\n\
                       Ok(v)\n\
                   }\n";
        let f = analyze_source("api/wire.rs", src);
        assert!(fires(&f, LEN_BEFORE_ALLOC), "{f:?}");
    }

    #[test]
    fn alloc_passes_with_count_check_or_benign_size() {
        let src = "fn decode(cur: &mut Cur) -> anyhow::Result<Vec<u64>> {\n\
                       let n = cur.count(8, \"pairs\")?;\n\
                       let mut v = Vec::with_capacity(n);\n\
                       Ok(v)\n\
                   }\n\
                   fn encode(xs: &[u64]) -> Vec<u8> {\n\
                       let mut out = Vec::with_capacity(xs.len() * 8);\n\
                       let head = vec![0u8; HEADER_LEN];\n\
                       out\n\
                   }\n";
        let f = analyze_source("api/wire.rs", src);
        assert!(!fires(&f, LEN_BEFORE_ALLOC), "{f:?}");
    }

    #[test]
    fn alloc_fires_on_vec_macro_and_reserve() {
        let src = "fn a(n: usize) -> Vec<u8> { vec![0u8; n * 4] }\n\
                   fn b(v: &mut Vec<u8>, n: usize) { v.reserve(n); }\n";
        let f = analyze_source("coordinator/persist.rs", src);
        assert_eq!(f.iter().filter(|x| x.rule == LEN_BEFORE_ALLOC).count(), 2, "{f:?}");
    }

    #[test]
    fn alloc_validator_must_precede_the_allocation() {
        let src = "fn decode(cur: &mut Cur) -> anyhow::Result<Vec<u64>> {\n\
                       let n = cur.u32()? as usize;\n\
                       let mut v = Vec::with_capacity(n);\n\
                       ensure!(n <= 10, \"late\");\n\
                       Ok(v)\n\
                   }\n";
        let f = analyze_source("api/wire.rs", src);
        assert!(fires(&f, LEN_BEFORE_ALLOC), "checks after the alloc don't count: {f:?}");
    }

    // -- guard-across-blocking ----------------------------------------------

    #[test]
    fn guard_fires_on_send_while_live() {
        let src = "fn f(&self) {\n\
                       let g = self.state.lock_recover();\n\
                       self.tx.send(1);\n\
                   }\n";
        let f = analyze_source("coordinator/scheduler.rs", src);
        assert!(fires(&f, GUARD_ACROSS_BLOCKING), "{f:?}");
        assert!(f[0].message.contains('g'), "names the guard: {f:?}");
    }

    #[test]
    fn guard_fires_on_second_blocking_lock() {
        let src = "fn f(&self) {\n\
                       let a = self.x.read_recover();\n\
                       let b = self.y.write_recover();\n\
                   }\n";
        let f = analyze_source("coordinator/scheduler.rs", src);
        assert!(fires(&f, GUARD_ACROSS_BLOCKING), "{f:?}");
    }

    #[test]
    fn guard_passes_when_scoped_before_blocking() {
        let src = "fn f(&self) {\n\
                       {\n\
                           let g = self.state.lock_recover();\n\
                           g.bump();\n\
                       }\n\
                       self.tx.send(1);\n\
                   }\n\
                   fn h(&self) {\n\
                       let g = self.state.lock_recover();\n\
                       drop(g);\n\
                       self.tx.send(2);\n\
                   }\n";
        let f = analyze_source("coordinator/scheduler.rs", src);
        assert!(!fires(&f, GUARD_ACROSS_BLOCKING), "{f:?}");
    }

    #[test]
    fn guard_ignores_temporaries_and_try_locks() {
        // A chained temporary dies at the `;`; try_* never blocks.
        let src = "fn f(&self) {\n\
                       self.errors.lock_recover().push(1);\n\
                       self.tx.send(1);\n\
                   }\n\
                   fn g(&self) {\n\
                       let shard = self.shard.write_recover();\n\
                       if let Ok(mut c) = self.cached.try_write() {\n\
                           c.clear();\n\
                       }\n\
                   }\n";
        let f = analyze_source("coordinator/state_helpers.rs", src);
        assert!(!fires(&f, GUARD_ACROSS_BLOCKING), "{f:?}");
    }

    // -- writer-bumps-epoch -------------------------------------------------

    const STORE_OK: &str = "impl SketchStore {\n\
        pub fn insert(&self) {\n\
            let mut shard = self.shards.write_recover();\n\
            shard.push(1);\n\
            self.epoch.fetch_add(1, Ordering::Release);\n\
        }\n\
        pub fn insert_block_shared(&self) {\n\
            let mut shard = self.shards.write_recover();\n\
            shard.push(2);\n\
            self.epoch.fetch_add(1, Ordering::Release);\n\
        }\n\
        pub fn compact_range(&self) {\n\
            let mut segs = self.segments.write_recover();\n\
            segs.clear();\n\
            self.epoch.fetch_add(1, Ordering::Release);\n\
        }\n\
    }\n";

    #[test]
    fn epoch_passes_when_every_mutator_bumps_in_section() {
        let f = analyze_source("coordinator/state.rs", STORE_OK);
        assert!(!fires(&f, WRITER_BUMPS_EPOCH), "{f:?}");
    }

    #[test]
    fn epoch_fires_on_missing_bump() {
        let src = STORE_OK.replacen("self.epoch.fetch_add(1, Ordering::Release);\n", "", 1);
        let f = analyze_source("coordinator/state.rs", &src);
        assert!(fires(&f, WRITER_BUMPS_EPOCH), "{f:?}");
        assert!(f.iter().any(|x| x.message.contains("insert")), "{f:?}");
    }

    #[test]
    fn epoch_fires_on_manifest_drift() {
        let src = STORE_OK.replace("compact_range", "compact_ranges_v2");
        let f = analyze_source("coordinator/state.rs", &src);
        assert!(
            f.iter().any(|x| x.rule == WRITER_BUMPS_EPOCH && x.message.contains("not found")),
            "{f:?}"
        );
    }

    #[test]
    fn epoch_fires_on_bump_outside_critical_section() {
        let src = "impl SketchStore {\n\
            pub fn insert(&self) {\n\
                self.epoch.fetch_add(1, Ordering::Release);\n\
                let mut shard = self.shards.write_recover();\n\
                shard.push(1);\n\
            }\n\
            pub fn insert_block_shared(&self) {\n\
                let mut shard = self.shards.write_recover();\n\
                self.epoch.fetch_add(1, Ordering::Release);\n\
            }\n\
            pub fn compact_range(&self) {\n\
                let mut segs = self.segments.write_recover();\n\
                self.epoch.fetch_add(1, Ordering::Release);\n\
            }\n\
        }\n";
        let f = analyze_source("coordinator/state.rs", src);
        assert!(
            f.iter().any(|x| x.rule == WRITER_BUMPS_EPOCH && x.message.contains("outside")),
            "{f:?}"
        );
    }

    #[test]
    fn epoch_foreign_mode_bans_store_internals() {
        // Outside state.rs the rule has no mutator definitions to check;
        // it bans direct store-internals access instead.
        let src = "pub fn pass(store: &SketchStore) {\n\
                store.epoch.fetch_add(1, Ordering::Release);\n\
            }\n";
        let f = analyze_source("coordinator/compactor.rs", src);
        assert!(fires(&f, WRITER_BUMPS_EPOCH), "{f:?}");
        assert!(f.iter().any(|x| x.message.contains("manifest mutator")), "{f:?}");
        // Going through the sanctioned mutators is clean.
        let ok = "pub fn pass(store: &SketchStore) {\n\
                store.compact_segments(1, 2);\n\
                let segs = store.segments_snapshot();\n\
            }\n";
        let f = analyze_source("coordinator/compactor.rs", ok);
        assert!(!fires(&f, WRITER_BUMPS_EPOCH), "{f:?}");
    }

    #[test]
    fn durability_modules_are_in_scope() {
        use super::rules::rules_for;
        for file in ["coordinator/durable.rs", "coordinator/wal.rs", "coordinator/segfile.rs"] {
            let rules = rules_for(file);
            assert!(rules.contains(&SERVING_NO_PANIC), "{file}: {rules:?}");
            assert!(rules.contains(&LEN_BEFORE_ALLOC), "{file}: {rules:?}");
            assert!(rules.contains(&GUARD_ACROSS_BLOCKING), "{file}: {rules:?}");
        }
        let compactor = rules_for("coordinator/compactor.rs");
        assert!(compactor.contains(&SERVING_NO_PANIC), "{compactor:?}");
        assert!(compactor.contains(&WRITER_BUMPS_EPOCH), "{compactor:?}");
        assert!(compactor.contains(&GUARD_ACROSS_BLOCKING), "{compactor:?}");
    }

    #[test]
    fn simd_and_quant_modules_are_in_scope() {
        // The SIMD dispatch and the panel codec feed every serving
        // kernel and decode persisted bytes, so both sit under the
        // panic ban and the allocation-size discipline.
        use super::rules::rules_for;
        for file in ["projection/simd.rs", "core/quant.rs"] {
            let rules = rules_for(file);
            assert!(rules.contains(&SERVING_NO_PANIC), "{file}: {rules:?}");
            assert!(rules.contains(&LEN_BEFORE_ALLOC), "{file}: {rules:?}");
        }
    }

    #[test]
    fn unvalidated_alloc_fires_in_wal() {
        let src = "pub fn decode(n: usize) -> Vec<f32> {\n\
                let out = Vec::with_capacity(n);\n\
                out\n\
            }\n";
        let f = analyze_source("coordinator/wal.rs", src);
        assert!(fires(&f, LEN_BEFORE_ALLOC), "{f:?}");
    }

    // -- pragmas ------------------------------------------------------------

    #[test]
    fn pragma_with_reason_suppresses_on_same_line() {
        let src = "pub fn f(x: Option<u32>) -> u32 { x.unwrap() } \
                   // pallas-lint: allow(serving-no-panic) -- x is Some by construction\n";
        let f = analyze_source("core/estimator.rs", src);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn pragma_with_reason_suppresses_on_next_line() {
        let src = "// pallas-lint: allow(serving-no-panic) -- guarded by the match above\n\
                   pub fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
        let f = analyze_source("core/estimator.rs", src);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn pragma_without_reason_does_not_suppress() {
        let src = "// pallas-lint: allow(serving-no-panic)\n\
                   pub fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
        let f = analyze_source("core/estimator.rs", src);
        assert!(fires(&f, SERVING_NO_PANIC), "violation still reported: {f:?}");
        assert!(
            f.iter().any(|x| x.rule == PRAGMA_RULE && x.message.contains("missing")),
            "missing reason reported: {f:?}"
        );
    }

    #[test]
    fn stale_pragma_is_reported() {
        let src = "// pallas-lint: allow(serving-no-panic) -- left behind after a refactor\n\
                   pub fn f(x: Option<u32>) -> u32 { x.unwrap_or(0) }\n";
        let f = analyze_source("core/estimator.rs", src);
        assert!(
            f.iter().any(|x| x.rule == PRAGMA_RULE && x.message.contains("stale")),
            "{f:?}"
        );
    }

    #[test]
    fn pragma_for_wrong_rule_does_not_suppress() {
        let src = "// pallas-lint: allow(len-before-alloc) -- wrong rule\n\
                   pub fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
        let f = analyze_source("core/estimator.rs", src);
        assert!(fires(&f, SERVING_NO_PANIC), "{f:?}");
    }

    #[test]
    fn render_is_click_through_formatted() {
        let f = Finding {
            file: "api/wire.rs".into(),
            line: 7,
            rule: SERVING_NO_PANIC,
            message: "msg".into(),
        };
        assert_eq!(f.render(), "api/wire.rs:7: [serving-no-panic] msg");
    }
}
