//! pallas-lint: in-repo static analysis enforcing the crate's serving
//! conventions.
//!
//! PRs 1–9 built a concurrent serving system whose correctness rests
//! on hand-maintained disciplines — panic-free serving paths,
//! "validate declared counts before any allocation" in the wire and
//! persist codecs, the epoch/COW lock order of the snapshot store,
//! and ~760 lines of `unsafe` SIMD kernels behind bitwise-equality
//! contracts. v2 machine-checks them *structurally*:
//!
//! * [`lexer`] strips comments/strings/chars byte-length-preserving
//!   (offsets map to lines) and records `// SAFETY:` comment lines
//!   and `pallas-lint:` pragmas;
//! * [`syntax`] turns the stripped text into a token tree — matched
//!   delimiters, function outlines with parameter names, `unsafe`
//!   sites, call expressions;
//! * [`flow`] runs a per-function forward dataflow: decoded-integer
//!   taint with validation tracking, and lock classes held at each
//!   point under the store's declared acquisition order;
//! * [`rules`] iterates the dataflow to a crate-wide fixpoint
//!   (tainted returns, size-sensitive parameters, and transitive lock
//!   summaries cross function and file boundaries) and emits
//!   findings; [`report`] serializes them as JSON or SARIF.
//!
//! The pragma syntax is unchanged from v1:
//!
//! ```text
//! // pallas-lint: allow(serving-no-panic) -- length checked two lines up
//! ```
//!
//! The reason clause after `--` is mandatory; stale, malformed, or
//! unknown-rule pragmas (including ones naming a rule that has since
//! been renamed) are themselves findings. Run it as `lpsketch lint`
//! (`--format json|sarif` for machines) or via the `lint_gate`
//! integration test, both of which walk `rust/src/` and fail on any
//! un-pragma'd violation. Rule inventory and scoping live in
//! [`rules`]; the README has the operator-facing summary.
//!
//! The analyzer remains dependency-free (no syn, no rustc internals):
//! the token tree pairs `()[]{}` only, angle brackets stay ordinary
//! punctuation, and both dataflow passes are linear scans that
//! approximate dominance — precise for this codebase's
//! rustfmt-shaped, early-return sources, with every heuristic limit
//! documented where it lives.

pub mod flow;
pub mod lexer;
pub mod report;
pub mod rules;
pub mod syntax;

pub use report::{to_json, to_sarif};
pub use rules::{
    analyze_source, analyze_sources, analyze_tree, count_rs_files, rules_for, Finding,
};
pub use rules::{
    CODEC_VERSION_EXHAUSTIVE, KNOWN_RULES, LEN_BEFORE_ALLOC, LOCK_ORDER, NO_INDEX_UNTRUSTED,
    PRAGMA_RULE, RENAMED_RULES, SERVING_NO_PANIC, SNAPSHOT_DISCIPLINE, UNSAFE_CONTRACT,
    WRITER_BUMPS_EPOCH,
};

#[cfg(test)]
mod tests {
    use super::*;

    fn fires(findings: &[Finding], rule: &str) -> bool {
        findings.iter().any(|f| f.rule == rule)
    }

    // -- serving-no-panic ---------------------------------------------------

    #[test]
    fn no_panic_fires_on_unwrap_expect_and_macros() {
        let src = "pub fn f(x: Option<u32>) -> u32 { x.unwrap() }\n\
                   pub fn g(x: Option<u32>) -> u32 { x.expect(\"present\") }\n\
                   pub fn h() { panic!(\"boom\") }\n\
                   pub fn i() { unreachable!() }\n";
        let f = analyze_source("core/estimator.rs", src);
        assert_eq!(f.iter().filter(|x| x.rule == SERVING_NO_PANIC).count(), 4, "{f:?}");
        assert_eq!(f[0].line, 1);
    }

    #[test]
    fn no_panic_passes_on_fallible_style() {
        let src = "pub fn f(x: Option<u32>) -> anyhow::Result<u32> {\n\
                       x.ok_or_else(|| anyhow::anyhow!(\"missing\"))\n\
                   }\n\
                   pub fn g(x: Option<u32>) -> u32 { x.unwrap_or(0) }\n";
        let f = analyze_source("core/estimator.rs", src);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn no_panic_is_scoped_to_serving_modules() {
        let src = "pub fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
        assert!(fires(&analyze_source("api/service.rs", src), SERVING_NO_PANIC));
        assert!(fires(&analyze_source("coordinator/pipeline.rs", src), SERVING_NO_PANIC));
        assert!(!fires(&analyze_source("experiments/mod.rs", src), SERVING_NO_PANIC));
        assert!(!fires(&analyze_source("main.rs", src), SERVING_NO_PANIC));
    }

    #[test]
    fn no_panic_ignores_test_mods_strings_and_comments() {
        let src = "pub fn f() -> u32 { 1 } // the old code called unwrap() here\n\
                   pub fn g() -> &'static str { \"never unwrap() in serving\" }\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                       #[test]\n\
                       fn t() { Some(1).unwrap(); panic!(\"fine in tests\"); }\n\
                   }\n";
        let f = analyze_source("api/wire.rs", src);
        assert!(f.is_empty(), "{f:?}");
    }

    // -- no-index-untrusted -------------------------------------------------

    #[test]
    fn index_fires_on_slice_indexing() {
        let src = "pub fn kind(b: &[u8]) -> u8 { b[4] }\n\
                   pub fn window(b: &[u8]) -> &[u8] { &b[2..6] }\n";
        let f = analyze_source("api/protocol.rs", src);
        assert_eq!(f.iter().filter(|x| x.rule == NO_INDEX_UNTRUSTED).count(), 2, "{f:?}");
    }

    #[test]
    fn index_passes_on_get_and_type_position() {
        let src = "pub fn kind(b: &[u8]) -> Option<u8> { b.get(4).copied() }\n\
                   pub fn fill(buf: &mut [u8], arr: [u8; 4]) -> Vec<[f32; 2]> { Vec::new() }\n";
        let f = analyze_source("api/protocol.rs", src);
        assert!(!fires(&f, NO_INDEX_UNTRUSTED), "{f:?}");
    }

    #[test]
    fn index_is_scoped_to_the_api_boundary() {
        let src = "pub fn dot(a: &[f32], b: &[f32]) -> f32 { a[0] * b[0] }\n";
        assert!(!fires(&analyze_source("core/estimator.rs", src), NO_INDEX_UNTRUSTED));
        assert!(fires(&analyze_source("api/wire.rs", src), NO_INDEX_UNTRUSTED));
    }

    // -- len-before-alloc (v2: taint-tracked) -------------------------------

    #[test]
    fn alloc_fires_without_validation() {
        let src = "fn decode(cur: &mut Cur) -> anyhow::Result<Vec<u64>> {\n\
                       let n = cur.u32()? as usize;\n\
                       let mut v = Vec::with_capacity(n);\n\
                       Ok(v)\n\
                   }\n";
        let f = analyze_source("api/wire.rs", src);
        assert!(fires(&f, LEN_BEFORE_ALLOC), "{f:?}");
    }

    #[test]
    fn alloc_passes_with_count_check_or_benign_size() {
        let src = "fn decode(cur: &mut Cur) -> anyhow::Result<Vec<u64>> {\n\
                       let n = cur.count(8, \"pairs\")?;\n\
                       let mut v = Vec::with_capacity(n);\n\
                       Ok(v)\n\
                   }\n\
                   fn encode(xs: &[u64]) -> Vec<u8> {\n\
                       let mut out = Vec::with_capacity(xs.len() * 8);\n\
                       let head = vec![0u8; HEADER_LEN];\n\
                       out\n\
                   }\n";
        let f = analyze_source("api/wire.rs", src);
        assert!(!fires(&f, LEN_BEFORE_ALLOC), "{f:?}");
    }

    #[test]
    fn alloc_fires_on_vec_macro_and_reserve() {
        let src = "fn a(b: &[u8]) -> Vec<u8> {\n\
                       let n = u32::from_le_bytes([b[0], b[1], b[2], b[3]]) as usize;\n\
                       vec![0u8; n * 4]\n\
                   }\n\
                   fn c(v: &mut Vec<u8>, b: &[u8]) {\n\
                       let n = u32::from_le_bytes([b[0], b[1], b[2], b[3]]) as usize;\n\
                       v.reserve(n);\n\
                   }\n";
        let f = analyze_source("coordinator/persist.rs", src);
        assert_eq!(f.iter().filter(|x| x.rule == LEN_BEFORE_ALLOC).count(), 2, "{f:?}");
    }

    #[test]
    fn alloc_validator_must_precede_the_allocation() {
        let src = "fn decode(cur: &mut Cur) -> anyhow::Result<Vec<u64>> {\n\
                       let n = cur.u32()? as usize;\n\
                       let mut v = Vec::with_capacity(n);\n\
                       ensure!(n <= 10, \"late\");\n\
                       Ok(v)\n\
                   }\n";
        let f = analyze_source("api/wire.rs", src);
        assert!(fires(&f, LEN_BEFORE_ALLOC), "checks after the alloc don't count: {f:?}");
    }

    #[test]
    fn alloc_tracks_across_helper_calls() {
        // The v1 lexical rule could not see this: the helper allocates
        // from its parameter, and the caller passes a raw decoded
        // count. v2 marks the parameter size-sensitive and moves the
        // finding to the call site.
        let src = "fn fill(n: usize) -> Vec<u8> {\n\
                       vec![0u8; n]\n\
                   }\n\
                   fn load(b: &[u8]) -> Vec<u8> {\n\
                       let n = u32::from_le_bytes([b[0], b[1], b[2], b[3]]) as usize;\n\
                       fill(n)\n\
                   }\n";
        let f = analyze_source("coordinator/persist.rs", src);
        let hits: Vec<_> = f.iter().filter(|x| x.rule == LEN_BEFORE_ALLOC).collect();
        assert_eq!(hits.len(), 1, "{f:?}");
        assert_eq!(hits[0].line, 6, "finding lands on the call site: {f:?}");
        assert!(hits[0].message.contains("fill"), "{f:?}");

        // Validating before the call clears it.
        let ok = src.replace("fill(n)\n", "ensure!(n <= MAX_ROWS);\nfill(n)\n");
        let f = analyze_source("coordinator/persist.rs", &ok);
        assert!(!fires(&f, LEN_BEFORE_ALLOC), "{f:?}");
    }

    #[test]
    fn unvalidated_alloc_fires_in_wal() {
        let src = "pub fn replay(b: &[u8]) -> Vec<f32> {\n\
                       let n = u32::from_le_bytes([b[0], b[1], b[2], b[3]]) as usize;\n\
                       let out = Vec::with_capacity(n);\n\
                       out\n\
                   }\n";
        let f = analyze_source("coordinator/wal.rs", src);
        assert!(fires(&f, LEN_BEFORE_ALLOC), "{f:?}");
    }

    // -- lock-order ----------------------------------------------------------

    #[test]
    fn lock_order_fires_on_inverted_known_order() {
        let src = "fn f(&self) {\n\
                       let segs = self.segments.write_recover();\n\
                       let serial = self.compaction.lock_recover();\n\
                   }\n";
        let f = analyze_source("coordinator/scheduler.rs", src);
        assert!(fires(&f, LOCK_ORDER), "{f:?}");
        assert!(f[0].message.contains("declared order"), "{f:?}");
    }

    #[test]
    fn lock_order_fires_on_blocking_op_while_guard_held() {
        let src = "fn f(&self) {\n\
                       let g = self.state.lock_recover();\n\
                       self.tx.send(1);\n\
                   }\n";
        let f = analyze_source("coordinator/scheduler.rs", src);
        assert!(fires(&f, LOCK_ORDER), "{f:?}");
    }

    #[test]
    fn lock_order_passes_when_scoped_before_blocking() {
        let src = "fn f(&self) {\n\
                       {\n\
                           let g = self.state.lock_recover();\n\
                           g.bump();\n\
                       }\n\
                       self.tx.send(1);\n\
                   }\n\
                   fn h(&self) {\n\
                       let g = self.state.lock_recover();\n\
                       drop(g);\n\
                       self.tx.send(2);\n\
                   }\n";
        let f = analyze_source("coordinator/scheduler.rs", src);
        assert!(!fires(&f, LOCK_ORDER), "{f:?}");
    }

    #[test]
    fn lock_order_ignores_temporaries_and_try_locks() {
        // A chained temporary dies at the `;`; try_* never blocks.
        let src = "fn f(&self) {\n\
                       self.errors.lock_recover().push(1);\n\
                       self.tx.send(1);\n\
                   }\n\
                   fn g(&self) {\n\
                       let shard = self.shards.write_recover();\n\
                       if let Ok(mut c) = self.cached.try_write() {\n\
                           c.clear();\n\
                       }\n\
                   }\n";
        let f = analyze_source("coordinator/state_helpers.rs", src);
        assert!(!fires(&f, LOCK_ORDER), "{f:?}");
    }

    #[test]
    fn lock_order_allows_ascending_shards_flags_same_class_reacquire() {
        let shards = "fn two(&self) {\n\
                          let a = self.shards[0].write_recover();\n\
                          let b = self.shards[1].write_recover();\n\
                      }\n";
        let f = analyze_source("coordinator/state.rs", shards);
        assert!(!fires(&f, LOCK_ORDER), "index-ascending shard nesting is legal: {f:?}");

        let segs = "fn twice(&self) {\n\
                        let a = self.segments.read_recover();\n\
                        let b = self.segments.read_recover();\n\
                    }\n";
        let f = analyze_source("coordinator/scheduler.rs", segs);
        assert!(fires(&f, LOCK_ORDER), "{f:?}");
        assert!(f.iter().any(|x| x.message.contains("re-acquires")), "{f:?}");
    }

    #[test]
    fn lock_order_fires_on_inconsistent_order_across_paths() {
        // `journal` and `index` are not declared store classes; a
        // single nesting is fine, but two call paths that disagree on
        // direction are a deadlock and both get flagged.
        let one = "fn a(&self) {\n\
                       let g = self.journal.lock_recover();\n\
                       let h = self.index.lock_recover();\n\
                   }\n";
        let f = analyze_source("coordinator/scheduler.rs", one);
        assert!(!fires(&f, LOCK_ORDER), "one direction alone is not a finding: {f:?}");

        let both = "fn a(&self) {\n\
                        let g = self.journal.lock_recover();\n\
                        let h = self.index.lock_recover();\n\
                    }\n\
                    fn b(&self) {\n\
                        let g = self.index.lock_recover();\n\
                        let h = self.journal.lock_recover();\n\
                    }\n";
        let f = analyze_source("coordinator/scheduler.rs", both);
        assert_eq!(f.iter().filter(|x| x.rule == LOCK_ORDER).count(), 2, "{f:?}");
        assert!(f[0].message.contains("inconsistent order"), "{f:?}");
    }

    #[test]
    fn lock_order_sees_through_the_call_graph() {
        let src = "fn refresh(&self) {\n\
                       let serial = self.compaction.lock_recover();\n\
                   }\n\
                   fn outer(&self) {\n\
                       let segs = self.segments.write_recover();\n\
                       self.refresh();\n\
                   }\n";
        let f = analyze_source("coordinator/scheduler.rs", src);
        assert!(fires(&f, LOCK_ORDER), "callee acquisitions count: {f:?}");
    }

    // -- unsafe-contract -----------------------------------------------------

    #[test]
    fn unsafe_without_safety_comment_fires() {
        let src = "pub unsafe fn k(p: *const f32) -> f32 { *p }\n";
        let f = analyze_source("baselines/exact.rs", src);
        assert!(fires(&f, UNSAFE_CONTRACT), "{f:?}");
        assert!(f[0].message.contains("SAFETY"), "{f:?}");
    }

    #[test]
    fn safety_comment_covers_through_attributes() {
        let src = "// SAFETY: dispatch only calls this after runtime AVX2 detection\n\
                   #[target_feature(enable = \"avx2\")]\n\
                   pub unsafe fn d(p: *const f32) -> f32 { *p }\n\
                   \n\
                   pub fn wrap(p: *const f32) -> f32 {\n\
                       // SAFETY: p points into the caller-owned panel\n\
                       unsafe { *p }\n\
                   }\n";
        let f = analyze_source("baselines/exact.rs", src);
        assert!(!fires(&f, UNSAFE_CONTRACT), "{f:?}");
    }

    #[test]
    fn unsafe_is_banned_in_serving_and_analysis_modules() {
        let src = "// SAFETY: even a documented contract does not excuse it here\n\
                   pub fn f(p: *const u8) -> u8 { unsafe { *p } }\n";
        for file in ["api/handlers.rs", "coordinator/state.rs", "analysis/lexer.rs"] {
            let f = analyze_source(file, src);
            assert!(fires(&f, UNSAFE_CONTRACT), "{file}: {f:?}");
            assert!(
                f.iter().any(|x| x.message.contains("not permitted")),
                "{file}: {f:?}"
            );
        }
    }

    #[test]
    fn pointer_arithmetic_is_confined_to_kernel_allowlist() {
        let src = "pub fn scatter(p: *mut f64, i: usize) {\n\
                       // SAFETY: i < len by the loop bound\n\
                       unsafe { *p.add(i) = 0.0 };\n\
                   }\n";
        let f = analyze_source("baselines/exact.rs", src);
        assert!(fires(&f, UNSAFE_CONTRACT), "{f:?}");
        assert!(f.iter().any(|x| x.message.contains("allowlist")), "{f:?}");
        // The same code inside a kernel module is fine.
        let f = analyze_source("projection/simd.rs", src);
        assert!(!fires(&f, UNSAFE_CONTRACT), "{f:?}");
    }

    #[test]
    fn core_arch_outside_kernels_fires() {
        let src = "use core::arch::x86_64::_mm256_loadu_ps;\n";
        let f = analyze_source("baselines/exact.rs", src);
        assert!(fires(&f, UNSAFE_CONTRACT), "{f:?}");
    }

    #[test]
    fn unsafe_contract_is_pragma_suppressible() {
        let src = "pub fn scatter(p: *mut f64, i: usize) {\n\
                       // pallas-lint: allow(unsafe-contract) -- fixed offset into an owned buffer\n\
                       unsafe { *p.add(i) = 0.0 };\n\
                   }\n";
        let f = analyze_source("baselines/exact.rs", src);
        assert!(f.is_empty(), "pragma suppresses and is not stale: {f:?}");
    }

    // -- snapshot-discipline -------------------------------------------------

    #[test]
    fn snapshot_discipline_fires_on_store_lock_acquisition() {
        let src = "pub fn serve(&self) {\n\
                       let g = self.store.shards[0].read_recover();\n\
                   }\n";
        let f = analyze_source("knn/mod.rs", src);
        assert!(fires(&f, SNAPSHOT_DISCIPLINE), "{f:?}");
        assert!(f[0].message.contains("shards"), "{f:?}");
    }

    #[test]
    fn snapshot_discipline_allows_plain_fields_named_like_locks() {
        // knn keeps its own `shards: Vec<ShardView>` — touching it is
        // fine; only acquire-routed access to the store's locks fires.
        let src = "pub fn locate(&self, id: u64) -> usize {\n\
                       self.shards.partition_point(|s| s.min_id <= id)\n\
                   }\n";
        let f = analyze_source("knn/mod.rs", src);
        assert!(!fires(&f, SNAPSHOT_DISCIPLINE), "{f:?}");
    }

    #[test]
    fn snapshot_discipline_polices_raw_epoch_reads() {
        let raw = "pub fn e(&self) -> u64 { self.store.epoch.load(Ordering::Acquire) }\n";
        let f = analyze_source("api/service.rs", raw);
        assert!(fires(&f, SNAPSHOT_DISCIPLINE), "{f:?}");
        let accessor = "pub fn e(&self) -> u64 { self.store.epoch() }\n";
        let f = analyze_source("api/service.rs", accessor);
        assert!(!fires(&f, SNAPSHOT_DISCIPLINE), "{f:?}");
        // A plain `epoch` field on a wire struct (or a snapshot's
        // frozen epoch) has no atomic-method tail and is not a
        // store-internals read.
        let field_copy = "pub fn stats_epoch(s: &ApiStats) -> u64 { s.epoch }\n";
        let f = analyze_source("api/service.rs", field_copy);
        assert!(!fires(&f, SNAPSHOT_DISCIPLINE), "{f:?}");
    }

    // -- codec-version-exhaustive ---------------------------------------------

    const SEGFILE_OK: &str = "pub const SEG_VERSION: u32 = 3;\n\
        fn read_seg(f: &mut File) -> anyhow::Result<Seg> {\n\
            let version = r_u32(f)?;\n\
            ensure!(version >= 1 && version <= SEG_VERSION, \"segfile version\");\n\
            if version >= 2 { read_zones(f)?; }\n\
            if version >= 3 { read_checksums(f)?; }\n\
            Ok(Seg::default())\n\
        }\n";

    #[test]
    fn codec_versions_pass_when_exhaustive_and_bounded_by_name() {
        let f = analyze_source("coordinator/segfile.rs", SEGFILE_OK);
        assert!(!fires(&f, CODEC_VERSION_EXHAUSTIVE), "{f:?}");
    }

    #[test]
    fn codec_fires_on_missing_historical_arm() {
        let src = SEGFILE_OK.replace("if version >= 3 { read_checksums(f)?; }\n", "");
        let f = analyze_source("coordinator/segfile.rs", &src);
        assert!(fires(&f, CODEC_VERSION_EXHAUSTIVE), "{f:?}");
        assert!(f.iter().any(|x| x.message.contains("no explicit arm")), "{f:?}");
    }

    #[test]
    fn codec_fires_when_upper_bound_is_a_magic_number() {
        let src = SEGFILE_OK.replace("version <= SEG_VERSION", "version <= 3");
        let f = analyze_source("coordinator/segfile.rs", &src);
        assert!(fires(&f, CODEC_VERSION_EXHAUSTIVE), "{f:?}");
        assert!(f.iter().any(|x| x.message.contains("by name")), "{f:?}");
    }

    #[test]
    fn codec_fires_on_manifest_drift() {
        let src = SEGFILE_OK.replace("SEG_VERSION", "SEGMENT_VERSION");
        let f = analyze_source("coordinator/segfile.rs", &src);
        assert!(
            f.iter().any(|x| x.rule == CODEC_VERSION_EXHAUSTIVE && x.message.contains("not found")),
            "{f:?}"
        );
    }

    #[test]
    fn codec_equality_bound_covers_a_v1_format() {
        let src = "pub const WAL_VERSION: u32 = 1;\n\
            fn read_rec(f: &mut File) -> anyhow::Result<Rec> {\n\
                let version = r_u32(f)?;\n\
                ensure!(version == WAL_VERSION, \"wal version\");\n\
                Ok(Rec::default())\n\
            }\n";
        let f = analyze_source("coordinator/wal.rs", src);
        assert!(!fires(&f, CODEC_VERSION_EXHAUSTIVE), "{f:?}");
    }

    // -- writer-bumps-epoch -------------------------------------------------

    const STORE_OK: &str = "impl SketchStore {\n\
        pub fn insert(&self) {\n\
            let mut shard = self.shards.write_recover();\n\
            shard.push(1);\n\
            self.epoch.fetch_add(1, Ordering::Release);\n\
        }\n\
        pub fn insert_block_prezoned(&self) {\n\
            let mut shard = self.shards.write_recover();\n\
            shard.push(2);\n\
            self.epoch.fetch_add(1, Ordering::Release);\n\
        }\n\
        pub fn compact_range(&self) {\n\
            let mut segs = self.segments.write_recover();\n\
            segs.clear();\n\
            self.epoch.fetch_add(1, Ordering::Release);\n\
        }\n\
    }\n";

    #[test]
    fn epoch_passes_when_every_mutator_bumps_in_section() {
        let f = analyze_source("coordinator/state.rs", STORE_OK);
        assert!(!fires(&f, WRITER_BUMPS_EPOCH), "{f:?}");
    }

    #[test]
    fn epoch_fires_on_missing_bump() {
        let src = STORE_OK.replacen("self.epoch.fetch_add(1, Ordering::Release);\n", "", 1);
        let f = analyze_source("coordinator/state.rs", &src);
        assert!(fires(&f, WRITER_BUMPS_EPOCH), "{f:?}");
        assert!(f.iter().any(|x| x.message.contains("insert")), "{f:?}");
    }

    #[test]
    fn epoch_fires_on_manifest_drift() {
        let src = STORE_OK.replace("compact_range", "compact_ranges_v2");
        let f = analyze_source("coordinator/state.rs", &src);
        assert!(
            f.iter().any(|x| x.rule == WRITER_BUMPS_EPOCH && x.message.contains("not found")),
            "{f:?}"
        );
    }

    #[test]
    fn epoch_fires_on_bump_outside_critical_section() {
        let src = "impl SketchStore {\n\
            pub fn insert(&self) {\n\
                self.epoch.fetch_add(1, Ordering::Release);\n\
                let mut shard = self.shards.write_recover();\n\
                shard.push(1);\n\
            }\n\
            pub fn insert_block_prezoned(&self) {\n\
                let mut shard = self.shards.write_recover();\n\
                self.epoch.fetch_add(1, Ordering::Release);\n\
            }\n\
            pub fn compact_range(&self) {\n\
                let mut segs = self.segments.write_recover();\n\
                self.epoch.fetch_add(1, Ordering::Release);\n\
            }\n\
        }\n";
        let f = analyze_source("coordinator/state.rs", src);
        assert!(
            f.iter().any(|x| x.rule == WRITER_BUMPS_EPOCH && x.message.contains("outside")),
            "{f:?}"
        );
    }

    #[test]
    fn epoch_foreign_mode_bans_store_internals() {
        // Outside state.rs the rule has no mutator definitions to check;
        // it bans direct store-internals access instead.
        let src = "pub fn pass(store: &SketchStore) {\n\
                store.epoch.fetch_add(1, Ordering::Release);\n\
            }\n";
        let f = analyze_source("coordinator/compactor.rs", src);
        assert!(fires(&f, WRITER_BUMPS_EPOCH), "{f:?}");
        assert!(f.iter().any(|x| x.message.contains("manifest mutator")), "{f:?}");
        // Going through the sanctioned mutators is clean.
        let ok = "pub fn pass(store: &SketchStore) {\n\
                store.compact_segments(1, 2);\n\
                let segs = store.segments_snapshot();\n\
            }\n";
        let f = analyze_source("coordinator/compactor.rs", ok);
        assert!(!fires(&f, WRITER_BUMPS_EPOCH), "{f:?}");
    }

    // -- scoping -------------------------------------------------------------

    #[test]
    fn durability_modules_are_in_scope() {
        use super::rules::rules_for;
        for file in ["coordinator/durable.rs", "coordinator/wal.rs", "coordinator/segfile.rs"] {
            let rules = rules_for(file);
            assert!(rules.contains(&SERVING_NO_PANIC), "{file}: {rules:?}");
            assert!(rules.contains(&LEN_BEFORE_ALLOC), "{file}: {rules:?}");
            assert!(rules.contains(&LOCK_ORDER), "{file}: {rules:?}");
        }
        let compactor = rules_for("coordinator/compactor.rs");
        assert!(compactor.contains(&SERVING_NO_PANIC), "{compactor:?}");
        assert!(compactor.contains(&WRITER_BUMPS_EPOCH), "{compactor:?}");
        assert!(compactor.contains(&LOCK_ORDER), "{compactor:?}");
    }

    #[test]
    fn simd_and_quant_modules_are_in_scope() {
        // The SIMD dispatch and the panel codec feed every serving
        // kernel and decode persisted bytes, so both sit under the
        // panic ban and the allocation-size discipline.
        use super::rules::rules_for;
        for file in ["projection/simd.rs", "core/quant.rs"] {
            let rules = rules_for(file);
            assert!(rules.contains(&SERVING_NO_PANIC), "{file}: {rules:?}");
            assert!(rules.contains(&LEN_BEFORE_ALLOC), "{file}: {rules:?}");
        }
    }

    #[test]
    fn v2_rules_are_scoped() {
        use super::rules::rules_for;
        // unsafe-contract runs everywhere, even outside serving scope.
        assert!(rules_for("baselines/exact.rs").contains(&UNSAFE_CONTRACT));
        assert!(rules_for("experiments/mod.rs").contains(&UNSAFE_CONTRACT));
        // snapshot-discipline covers the serving read paths only.
        assert!(rules_for("api/wire.rs").contains(&SNAPSHOT_DISCIPLINE));
        assert!(rules_for("knn/mod.rs").contains(&SNAPSHOT_DISCIPLINE));
        assert!(!rules_for("core/estimator.rs").contains(&SNAPSHOT_DISCIPLINE));
        assert!(!rules_for("coordinator/state.rs").contains(&SNAPSHOT_DISCIPLINE));
        // codec-version-exhaustive pins the three versioned readers.
        assert!(rules_for("coordinator/persist.rs").contains(&CODEC_VERSION_EXHAUSTIVE));
        assert!(rules_for("coordinator/wal.rs").contains(&CODEC_VERSION_EXHAUSTIVE));
        assert!(!rules_for("api/wire.rs").contains(&CODEC_VERSION_EXHAUSTIVE));
    }

    // -- pragmas ------------------------------------------------------------

    #[test]
    fn pragma_with_reason_suppresses_on_same_line() {
        let src = "pub fn f(x: Option<u32>) -> u32 { x.unwrap() } \
                   // pallas-lint: allow(serving-no-panic) -- x is Some by construction\n";
        let f = analyze_source("core/estimator.rs", src);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn pragma_with_reason_suppresses_on_next_line() {
        let src = "// pallas-lint: allow(serving-no-panic) -- guarded by the match above\n\
                   pub fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
        let f = analyze_source("core/estimator.rs", src);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn pragma_without_reason_does_not_suppress() {
        let src = "// pallas-lint: allow(serving-no-panic)\n\
                   pub fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
        let f = analyze_source("core/estimator.rs", src);
        assert!(fires(&f, SERVING_NO_PANIC), "violation still reported: {f:?}");
        assert!(
            f.iter().any(|x| x.rule == PRAGMA_RULE && x.message.contains("missing")),
            "missing reason reported: {f:?}"
        );
    }

    #[test]
    fn stale_pragma_is_reported() {
        let src = "// pallas-lint: allow(serving-no-panic) -- left behind after a refactor\n\
                   pub fn f(x: Option<u32>) -> u32 { x.unwrap_or(0) }\n";
        let f = analyze_source("core/estimator.rs", src);
        assert!(
            f.iter().any(|x| x.rule == PRAGMA_RULE && x.message.contains("stale")),
            "{f:?}"
        );
    }

    #[test]
    fn pragma_for_wrong_rule_does_not_suppress() {
        let src = "// pallas-lint: allow(len-before-alloc) -- wrong rule\n\
                   pub fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
        let f = analyze_source("core/estimator.rs", src);
        assert!(fires(&f, SERVING_NO_PANIC), "{f:?}");
    }

    #[test]
    fn each_new_rule_is_pragma_suppressible() {
        // unsafe-contract's suppression is pinned above; the other four
        // structural rules must honor the same escape hatch.
        let fixtures: &[(&str, &str)] = &[
            (
                "api/wire.rs",
                "fn decode(cur: &mut Cur) -> anyhow::Result<Vec<u64>> {\n\
                 let n = cur.u32()? as usize;\n\
                 // pallas-lint: allow(len-before-alloc) -- n is capped by the frame length checked upstream\n\
                 let mut v = Vec::with_capacity(n);\n\
                 Ok(v)\n\
                 }\n",
            ),
            (
                "coordinator/scheduler.rs",
                "fn f(&self) {\n\
                 let segs = self.segments.write_recover();\n\
                 // pallas-lint: allow(lock-order) -- startup path, single-threaded by construction\n\
                 let serial = self.compaction.lock_recover();\n\
                 }\n",
            ),
            (
                "knn/mod.rs",
                "pub fn serve(&self) {\n\
                 // pallas-lint: allow(snapshot-discipline) -- warm path before the first snapshot exists\n\
                 let g = self.store.shards[0].read_recover();\n\
                 }\n",
            ),
            (
                "coordinator/segfile.rs",
                "// pallas-lint: allow(codec-version-exhaustive) -- v3 checksum arm lands with the reader next PR\n\
                 pub const SEG_VERSION: u32 = 3;\n\
                 fn read_seg(f: &mut File) -> anyhow::Result<Seg> {\n\
                 let version = r_u32(f)?;\n\
                 ensure!(version >= 1 && version <= SEG_VERSION, \"segfile version\");\n\
                 if version >= 2 { read_zones(f)?; }\n\
                 Ok(Seg::default())\n\
                 }\n",
            ),
        ];
        for (rel, src) in fixtures {
            let f = analyze_source(rel, src);
            assert!(f.is_empty(), "{rel}: suppressed and not stale: {f:?}");
        }
    }

    #[test]
    fn stale_pragmas_are_reported_for_each_new_rule() {
        // Each fixture is clean under its rule, so the pragma has
        // nothing to cover and must surface as a stale finding.
        let fixtures: &[(&str, &str)] = &[
            (
                "api/wire.rs",
                "// pallas-lint: allow(len-before-alloc) -- left after refactor\n\
                 fn decode(cur: &mut Cur) -> anyhow::Result<Vec<u64>> {\n\
                 let n = cur.count(8, \"pairs\")?;\n\
                 let mut v = Vec::with_capacity(n);\n\
                 Ok(v)\n\
                 }\n",
            ),
            (
                "coordinator/scheduler.rs",
                "// pallas-lint: allow(lock-order) -- left after refactor\n\
                 fn f(&self) {\n\
                 let serial = self.compaction.lock_recover();\n\
                 }\n",
            ),
            (
                "baselines/exact.rs",
                "// pallas-lint: allow(unsafe-contract) -- left after refactor\n\
                 pub fn f(x: u32) -> u32 { x + 1 }\n",
            ),
            (
                "knn/mod.rs",
                "// pallas-lint: allow(snapshot-discipline) -- left after refactor\n\
                 pub fn serve(&self) { self.snapshot().len(); }\n",
            ),
            (
                "coordinator/segfile.rs",
                "// pallas-lint: allow(codec-version-exhaustive) -- left after refactor\n\
                 pub const SEG_VERSION: u32 = 3;\n\
                 fn read_seg(f: &mut File) -> anyhow::Result<Seg> {\n\
                 let version = r_u32(f)?;\n\
                 ensure!(version >= 1 && version <= SEG_VERSION, \"segfile version\");\n\
                 if version >= 2 { read_zones(f)?; }\n\
                 if version >= 3 { read_checksums(f)?; }\n\
                 Ok(Seg::default())\n\
                 }\n",
            ),
        ];
        for (rel, src) in fixtures {
            let f = analyze_source(rel, src);
            assert!(
                f.iter().any(|x| x.rule == PRAGMA_RULE && x.message.contains("stale")),
                "{rel}: {f:?}"
            );
            assert_eq!(f.len(), 1, "{rel}: only the stale-pragma finding: {f:?}");
        }
    }

    #[test]
    fn pragma_for_renamed_rule_names_the_successor() {
        let src = "// pallas-lint: allow(guard-across-blocking) -- shared Receiver idiom\n\
                   pub fn f() {}\n";
        let f = analyze_source("coordinator/scheduler.rs", src);
        assert!(
            f.iter().any(|x| {
                x.rule == PRAGMA_RULE
                    && x.message.contains("retired")
                    && x.message.contains("lock-order")
            }),
            "{f:?}"
        );
        // And it never reports as merely "stale" — the rename hint wins.
        assert!(!f.iter().any(|x| x.message.contains("stale")), "{f:?}");
    }

    #[test]
    fn pragma_for_unknown_rule_is_reported() {
        let src = "// pallas-lint: allow(no-such-rule) -- misremembered\n\
                   pub fn f() {}\n";
        let f = analyze_source("api/service.rs", src);
        assert!(
            f.iter().any(|x| x.rule == PRAGMA_RULE && x.message.contains("unknown rule")),
            "{f:?}"
        );
    }

    #[test]
    fn render_is_click_through_formatted() {
        let f = Finding {
            file: "api/wire.rs".into(),
            line: 7,
            rule: SERVING_NO_PANIC,
            message: "msg".into(),
        };
        assert_eq!(f.render(), "api/wire.rs:7: [serving-no-panic] msg");
    }
}
