//! Machine-readable lint output: plain JSON and SARIF 2.1.0.
//!
//! Hand-rolled serialization — the crate is dependency-free by policy,
//! and the two shapes emitted here are small enough that a serializer
//! would be more code than the escaping helper. Both formats carry the
//! same findings: the JSON path is the round-trip source of truth
//! (`findings` array, `count`), SARIF adds the tool/rule envelope that
//! code-scanning UIs ingest.

use super::rules::Finding;

/// Escape `s` for a JSON string literal (without the quotes).
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Findings as a JSON document:
/// `{"tool": "pallas-lint", "count": N, "findings": [...]}`.
pub fn to_json(findings: &[Finding]) -> String {
    let mut out = String::from("{\n  \"tool\": \"pallas-lint\",\n");
    out.push_str(&format!("  \"count\": {},\n  \"findings\": [", findings.len()));
    for (i, f) in findings.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n    {{\"file\": \"{}\", \"line\": {}, \"rule\": \"{}\", \"message\": \"{}\"}}",
            esc(&f.file),
            f.line,
            esc(f.rule),
            esc(&f.message)
        ));
    }
    if findings.is_empty() {
        out.push_str("]\n}\n");
    } else {
        out.push_str("\n  ]\n}\n");
    }
    out
}

/// Findings as a SARIF 2.1.0 document (one run, one driver; level is
/// always `error` — pallas-lint has no warning tier, a finding gates).
pub fn to_sarif(findings: &[Finding]) -> String {
    let mut rules: Vec<&str> = findings.iter().map(|f| f.rule).collect();
    rules.sort_unstable();
    rules.dedup();
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"$schema\": \"https://json.schemastore.org/sarif-2.1.0.json\",\n");
    out.push_str("  \"version\": \"2.1.0\",\n");
    out.push_str("  \"runs\": [\n    {\n");
    out.push_str("      \"tool\": {\n        \"driver\": {\n");
    out.push_str("          \"name\": \"pallas-lint\",\n");
    out.push_str("          \"rules\": [");
    for (i, r) in rules.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!("\n            {{\"id\": \"{}\"}}", esc(r)));
    }
    if rules.is_empty() {
        out.push_str("]\n");
    } else {
        out.push_str("\n          ]\n");
    }
    out.push_str("        }\n      },\n");
    out.push_str("      \"results\": [");
    for (i, f) in findings.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n        {{\n          \"ruleId\": \"{}\",\n          \"level\": \"error\",\n          \
             \"message\": {{\"text\": \"{}\"}},\n          \"locations\": [\n            \
             {{\"physicalLocation\": {{\"artifactLocation\": {{\"uri\": \"{}\"}}, \
             \"region\": {{\"startLine\": {}}}}}}}\n          ]\n        }}",
            esc(f.rule),
            esc(&f.message),
            esc(&f.file),
            f.line
        ));
    }
    if findings.is_empty() {
        out.push_str("]\n");
    } else {
        out.push_str("\n      ]\n");
    }
    out.push_str("    }\n  ]\n}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<Finding> {
        vec![Finding {
            file: "api/wire.rs".into(),
            line: 42,
            rule: crate::analysis::rules::LEN_BEFORE_ALLOC,
            message: "allocation \"sized\" by\na decoded value".into(),
        }]
    }

    #[test]
    fn json_escapes_and_counts() {
        let j = to_json(&sample());
        assert!(j.contains("\"count\": 1"), "{j}");
        assert!(j.contains("\\\"sized\\\""), "escaped quotes: {j}");
        assert!(j.contains("\\n"), "escaped newline: {j}");
        assert!(!j.contains("sized\" by\na"), "raw newline leaked: {j}");
    }

    #[test]
    fn empty_inputs_produce_empty_arrays() {
        let j = to_json(&[]);
        assert!(j.contains("\"count\": 0"), "{j}");
        assert!(j.contains("\"findings\": []"), "{j}");
        let s = to_sarif(&[]);
        assert!(s.contains("\"results\": []"), "{s}");
        assert!(s.contains("\"rules\": []"), "{s}");
    }

    #[test]
    fn sarif_carries_rule_ids_and_locations() {
        let s = to_sarif(&sample());
        assert!(s.contains("\"version\": \"2.1.0\""), "{s}");
        assert!(s.contains("{\"id\": \"len-before-alloc\"}"), "{s}");
        assert!(s.contains("\"ruleId\": \"len-before-alloc\""), "{s}");
        assert!(s.contains("\"uri\": \"api/wire.rs\""), "{s}");
        assert!(s.contains("\"startLine\": 42"), "{s}");
    }
}
