//! The pallas-lint v2 rule engine: structural rules over token trees
//! ([`super::syntax`]) and per-function dataflow ([`super::flow`]),
//! plus the original lexical rules, with pragma suppression.
//!
//! Rules and scopes (paths relative to `rust/src/`):
//!
//! | rule | kind | scope | enforces |
//! |------|------|-------|----------|
//! | `serving-no-panic` | lexical | `api/`, `coordinator/state.rs`, `coordinator/pipeline.rs`, `coordinator/durable.rs`, `coordinator/wal.rs`, `coordinator/segfile.rs`, `coordinator/compactor.rs`, `core/estimator.rs`, `core/zone.rs`, `core/quant.rs`, `projection/simd.rs`, `knn/mod.rs` | no `unwrap()` / `expect(` / `panic!` / `unreachable!` / `todo!` / `unimplemented!` on serving paths |
//! | `no-index-untrusted` | lexical | `api/` | no `x[..]` indexing at the untrusted-input boundary — use `get(..)` |
//! | `len-before-alloc` | dataflow | `api/wire.rs`, `coordinator/persist.rs`, `coordinator/durable.rs`, `coordinator/wal.rs`, `coordinator/segfile.rs`, `core/quant.rs`, `projection/simd.rs` | an allocation sized by a decoded integer needs a dominating cap / bytes-present validation — tracked across helper calls via parameter sensitivity |
//! | `lock-order` | dataflow | `api/`, `coordinator/` | nested lock acquisitions respect the declared global order (`cached -> compaction -> shards -> segments`, shards index-ascending); no blocking channel/thread op while a guard is held; unknown lock classes must agree on direction across all call paths |
//! | `unsafe-contract` | structural | every file | each `unsafe` fn/block/impl carries a `// SAFETY:` comment; raw-pointer arithmetic and `core::arch` only in `projection/simd.rs` / `core/quant.rs`; no `unsafe` at all in `api/`, `coordinator/`, `analysis/` |
//! | `snapshot-discipline` | structural | `api/`, `knn/mod.rs`, `coordinator/pipeline.rs`, `coordinator/durable.rs`, `coordinator/compactor.rs` | serving paths read store state only through `StoreSnapshot` / sanctioned accessors — no acquiring the store's `shards`/`segments`/`cached` locks, no raw `.epoch` field reads |
//! | `codec-version-exhaustive` | structural | `coordinator/persist.rs`, `coordinator/segfile.rs`, `coordinator/wal.rs` | readers compare the decoded version against the format-current const by name (reject-newer) and gate every historical version `2..=current` explicitly |
//! | `writer-bumps-epoch` | lexical | `coordinator/state.rs`, `coordinator/compactor.rs` | in `state.rs`, every manifest mutator bumps the store epoch inside its write critical section; elsewhere in scope, store internals must not be touched directly |
//!
//! `no-index-untrusted` is deliberately **not** applied to the numeric
//! kernels (`core/estimator.rs`): they index with loop-bounded offsets
//! pervasively and rewriting them around `get()` would obscure the
//! tiling structure; the panic tokens themselves are still banned
//! there by `serving-no-panic`.
//!
//! `#[cfg(test)]` items are exempt from every rule — tests unwrap
//! freely by design. Analysis is crate-aware: [`analyze_sources`]
//! parses every file, then iterates per-function dataflow to a
//! fixpoint so that taint return values, size-sensitive parameters,
//! and transitive lock acquisition summaries cross function (and
//! file) boundaries.

use std::collections::{BTreeMap, BTreeSet, HashSet};
use std::path::Path;

use super::{flow, lexer, syntax};

pub const SERVING_NO_PANIC: &str = "serving-no-panic";
pub const NO_INDEX_UNTRUSTED: &str = "no-index-untrusted";
pub const LEN_BEFORE_ALLOC: &str = "len-before-alloc";
pub const LOCK_ORDER: &str = "lock-order";
pub const UNSAFE_CONTRACT: &str = "unsafe-contract";
pub const SNAPSHOT_DISCIPLINE: &str = "snapshot-discipline";
pub const CODEC_VERSION_EXHAUSTIVE: &str = "codec-version-exhaustive";
pub const WRITER_BUMPS_EPOCH: &str = "writer-bumps-epoch";
/// Diagnostics about the pragmas themselves (malformed / missing
/// reason / unknown rule / stale). Not suppressible.
pub const PRAGMA_RULE: &str = "pragma";

/// Every rule a pragma may name.
pub const KNOWN_RULES: &[&str] = &[
    SERVING_NO_PANIC,
    NO_INDEX_UNTRUSTED,
    LEN_BEFORE_ALLOC,
    LOCK_ORDER,
    UNSAFE_CONTRACT,
    SNAPSHOT_DISCIPLINE,
    CODEC_VERSION_EXHAUSTIVE,
    WRITER_BUMPS_EPOCH,
];

/// Retired rule names and their successors, so a pragma left behind by
/// a rename gets a pointed diagnostic instead of a silent dead-letter.
pub const RENAMED_RULES: &[(&str, &str)] = &[("guard-across-blocking", LOCK_ORDER)];

/// Kernel modules allowed to contain raw-pointer arithmetic and
/// `core::arch` intrinsics.
const UNSAFE_ALLOWLIST: &[&str] = &["projection/simd.rs", "core/quant.rs"];

/// `SketchStore` mutators that must bump the epoch inside their write
/// critical section. Extend this list when adding a mutator; a listed
/// name that no longer exists is itself reported (manifest drift).
/// (`insert_block_shared` / `insert_block_columnar` delegate to
/// `insert_block_prezoned` after computing the zone summary, so the
/// bump lives there.)
const MUTATOR_MANIFEST: &[&str] = &["insert", "insert_block_prezoned", "compact_range"];

/// Versioned readers: (file, name of the format-current const).
const CODEC_MANIFEST: &[(&str, &str)] = &[
    ("coordinator/persist.rs", "VERSION"),
    ("coordinator/segfile.rs", "SEG_VERSION"),
    ("coordinator/wal.rs", "WAL_VERSION"),
];

/// One rule violation (or pragma diagnostic).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    /// Path relative to the scanned root, `/`-separated.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl Finding {
    pub fn render(&self) -> String {
        format!("{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// Which rules apply to a file, by its root-relative path.
pub fn rules_for(rel: &str) -> Vec<&'static str> {
    let rel = rel.replace('\\', "/");
    let mut rules = Vec::new();
    let serving = rel.starts_with("api/")
        || rel == "coordinator/state.rs"
        || rel == "coordinator/pipeline.rs"
        || rel == "coordinator/durable.rs"
        || rel == "coordinator/wal.rs"
        || rel == "coordinator/segfile.rs"
        || rel == "coordinator/compactor.rs"
        || rel == "core/estimator.rs"
        || rel == "core/zone.rs"
        || rel == "core/quant.rs"
        || rel == "projection/simd.rs"
        || rel == "knn/mod.rs";
    if serving {
        rules.push(SERVING_NO_PANIC);
    }
    if rel.starts_with("api/") {
        rules.push(NO_INDEX_UNTRUSTED);
    }
    if rel == "api/wire.rs"
        || rel == "coordinator/persist.rs"
        || rel == "coordinator/durable.rs"
        || rel == "coordinator/wal.rs"
        || rel == "coordinator/segfile.rs"
        || rel == "core/quant.rs"
        || rel == "projection/simd.rs"
    {
        rules.push(LEN_BEFORE_ALLOC);
    }
    if rel.starts_with("api/") || rel.starts_with("coordinator/") {
        rules.push(LOCK_ORDER);
    }
    rules.push(UNSAFE_CONTRACT);
    if rel.starts_with("api/")
        || rel == "knn/mod.rs"
        || rel == "coordinator/pipeline.rs"
        || rel == "coordinator/durable.rs"
        || rel == "coordinator/compactor.rs"
    {
        rules.push(SNAPSHOT_DISCIPLINE);
    }
    if CODEC_MANIFEST.iter().any(|(f, _)| *f == rel) {
        rules.push(CODEC_VERSION_EXHAUSTIVE);
    }
    if rel == "coordinator/state.rs" || rel == "coordinator/compactor.rs" {
        rules.push(WRITER_BUMPS_EPOCH);
    }
    rules
}

struct FileCx {
    rel: String,
    stripped: lexer::Stripped,
    tree: syntax::Tree,
    /// Non-test function items, parallel to the per-file facts.
    fns: Vec<syntax::FnItem>,
    test_spans: Vec<(usize, usize)>,
}

/// Analyze a set of files as one crate. This is the real entry point:
/// dataflow summaries (tainted returns, size-sensitive parameters,
/// lock acquisition sets) are iterated to a fixpoint across every
/// file before findings are emitted.
pub fn analyze_sources(files: &[(String, String)]) -> Vec<Finding> {
    let cxs: Vec<FileCx> = files
        .iter()
        .map(|(rel, src)| {
            let stripped = lexer::strip(src);
            let tree = syntax::Tree::parse(&stripped.code);
            let test_spans = lexer::test_spans(&stripped.code);
            let fns = syntax::fn_items(&stripped.code, &tree)
                .into_iter()
                .filter(|f| !test_spans.iter().any(|&(a, b)| a <= f.line && f.line <= b))
                .collect();
            FileCx { rel: rel.replace('\\', "/"), stripped, tree, fns, test_spans }
        })
        .collect();

    // Crate summaries to a fixpoint (bounded: each iteration only adds
    // facts, and the fact space is finite).
    let mut sums = flow::Summaries::default();
    for cx in &cxs {
        for f in &cx.fns {
            sums.fns.insert(f.name.clone());
        }
    }
    let mut facts: Vec<Vec<flow::FnFacts>> = Vec::new();
    for _ in 0..10 {
        facts = cxs
            .iter()
            .map(|cx| {
                cx.fns
                    .iter()
                    .map(|f| flow::fn_facts(&cx.stripped.code, &cx.tree, f, &sums))
                    .collect()
            })
            .collect();
        let mut next = sums.clone();
        for file_facts in &facts {
            for fa in file_facts {
                if fa.taint_ret {
                    next.taint_ret.insert(fa.name.clone());
                }
                if !fa.sensitive.is_empty() {
                    next.sensitive
                        .entry(fa.name.clone())
                        .or_default()
                        .extend(fa.sensitive.iter().copied());
                }
                if !fa.acquired.is_empty() {
                    next.locks
                        .entry(fa.name.clone())
                        .or_default()
                        .extend(fa.acquired.iter().cloned());
                }
            }
        }
        if next == sums {
            break;
        }
        sums = next;
    }

    // Crate-wide lock edges involving undeclared classes: a finding
    // only when two call paths disagree on direction.
    let mut edge_map: BTreeMap<(String, String), Vec<(usize, usize)>> = BTreeMap::new();
    for (fi, file_facts) in facts.iter().enumerate() {
        for fa in file_facts {
            for (a, b, line) in &fa.edges {
                edge_map.entry((a.clone(), b.clone())).or_default().push((fi, *line));
            }
        }
    }

    let mut findings = Vec::new();
    for (fi, cx) in cxs.iter().enumerate() {
        findings.extend(file_findings(cx, fi, &facts[fi], &edge_map));
    }
    findings
}

/// Analyze one file's source under its root-relative path (a
/// single-file crate; cross-file summaries are empty).
pub fn analyze_source(rel: &str, src: &str) -> Vec<Finding> {
    analyze_sources(&[(rel.to_string(), src.to_string())])
}

fn file_findings(
    cx: &FileCx,
    fi: usize,
    facts: &[flow::FnFacts],
    edge_map: &BTreeMap<(String, String), Vec<(usize, usize)>>,
) -> Vec<Finding> {
    let rel = cx.rel.as_str();
    let code = cx.stripped.code.as_str();
    let in_test =
        |line: usize| cx.test_spans.iter().any(|&(a, b)| a <= line && line <= b);

    let mut raw = Vec::new();
    for rule in rules_for(rel) {
        match rule {
            SERVING_NO_PANIC => serving_no_panic(rel, code, &cx.tree, &mut raw),
            NO_INDEX_UNTRUSTED => no_index_untrusted(rel, code, &cx.tree, &mut raw),
            LEN_BEFORE_ALLOC => {
                for fa in facts {
                    for &line in &fa.alloc_findings {
                        raw.push(Finding {
                            file: rel.to_string(),
                            line,
                            rule: LEN_BEFORE_ALLOC,
                            message: format!(
                                "allocation in `{}` sized by a decoded value with no dominating \
                                 cap/bytes-present validation — `ensure!` a bound first",
                                fa.name
                            ),
                        });
                    }
                    for (line, callee) in &fa.call_findings {
                        raw.push(Finding {
                            file: rel.to_string(),
                            line: *line,
                            rule: LEN_BEFORE_ALLOC,
                            message: format!(
                                "`{}` passes an unvalidated decoded value into a size-sensitive \
                                 parameter of `{callee}` — validate before the call",
                                fa.name
                            ),
                        });
                    }
                }
            }
            LOCK_ORDER => {
                for fa in facts {
                    for (line, msg) in &fa.order_findings {
                        raw.push(Finding {
                            file: rel.to_string(),
                            line: *line,
                            rule: LOCK_ORDER,
                            message: format!("in `{}`: {msg}", fa.name),
                        });
                    }
                    for (line, msg) in &fa.blocking_findings {
                        raw.push(Finding {
                            file: rel.to_string(),
                            line: *line,
                            rule: LOCK_ORDER,
                            message: format!(
                                "in `{}`: {msg} — scope the guard to end first, or pragma the \
                                 documented protocol",
                                fa.name
                            ),
                        });
                    }
                }
                for ((a, b), locs) in edge_map {
                    let reversed = edge_map.contains_key(&(b.clone(), a.clone()));
                    let both = a == b || reversed;
                    if !both {
                        continue;
                    }
                    for &(efi, line) in locs {
                        if efi == fi {
                            raw.push(Finding {
                                file: rel.to_string(),
                                line,
                                rule: LOCK_ORDER,
                                message: format!(
                                    "locks `{a}` and `{b}` are acquired in inconsistent order \
                                     across call paths — pick one order or merge the locks"
                                ),
                            });
                        }
                    }
                }
            }
            UNSAFE_CONTRACT => {
                unsafe_contract(rel, code, &cx.stripped, &cx.tree, &cx.fns, &mut raw)
            }
            SNAPSHOT_DISCIPLINE => snapshot_discipline(rel, code, &cx.tree, &mut raw),
            CODEC_VERSION_EXHAUSTIVE => {
                codec_version_exhaustive(rel, code, &cx.tree, &mut raw)
            }
            WRITER_BUMPS_EPOCH => writer_bumps_epoch(rel, code, &mut raw),
            _ => {}
        }
    }
    raw.retain(|f| !in_test(f.line));
    // One finding per (rule, line): `a[0][1]` is one problem, not two.
    let mut seen = HashSet::new();
    raw.retain(|f| seen.insert((f.rule, f.line)));

    let lines: Vec<&str> = code.lines().collect();
    let mut used = vec![false; cx.stripped.pragmas.len()];
    let mut findings = Vec::new();
    for f in raw {
        let suppressed = cx.stripped.pragmas.iter().enumerate().any(|(pi, p)| {
            let hit = p.rule.as_deref() == Some(f.rule)
                && p.reason.is_some()
                && pragma_covers(p.line, f.line, &lines);
            if hit {
                used[pi] = true;
            }
            hit
        });
        if !suppressed {
            findings.push(f);
        }
    }
    for (pi, p) in cx.stripped.pragmas.iter().enumerate() {
        if in_test(p.line) {
            continue;
        }
        let message = match (&p.rule, &p.reason) {
            (None, _) => {
                "malformed pragma — expected `pallas-lint: allow(<rule>) -- <reason>`".to_string()
            }
            (Some(rule), _) if !KNOWN_RULES.contains(&rule.as_str()) => {
                match RENAMED_RULES.iter().find(|(old, _)| old == rule) {
                    Some((_, new)) => format!(
                        "allow({rule}) names a retired rule — it was renamed to `{new}`; \
                         update the pragma"
                    ),
                    None => format!("allow({rule}) names an unknown rule"),
                }
            }
            (Some(rule), None) => {
                format!("allow({rule}) is missing its mandatory `-- <reason>` clause")
            }
            (Some(rule), Some(_)) if !used[pi] => {
                format!("stale allow({rule}) — no matching finding on this or the next line")
            }
            _ => continue,
        };
        findings.push(Finding { file: rel.to_string(), line: p.line, rule: PRAGMA_RULE, message });
    }
    findings.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    findings
}

/// A pragma on line `p` covers findings on `p` itself or on the next
/// non-blank line (the standalone-comment-above-the-statement form).
fn pragma_covers(p: usize, finding: usize, lines: &[&str]) -> bool {
    if finding == p {
        return true;
    }
    let mut q = p + 1;
    while q <= lines.len() && lines[q - 1].trim().is_empty() {
        q += 1;
    }
    finding == q
}

/// Recursively analyze every `.rs` file under `root` (usually
/// `rust/src`) as one crate. Findings are ordered by path, then line.
pub fn analyze_tree(root: &Path) -> anyhow::Result<Vec<Finding>> {
    let mut files = Vec::new();
    collect_rs(root, root, &mut files)?;
    files.sort();
    let mut sources = Vec::new();
    for rel in files {
        let src = std::fs::read_to_string(root.join(&rel))
            .map_err(|e| anyhow::anyhow!("reading {rel}: {e}"))?;
        sources.push((rel, src));
    }
    let mut findings = analyze_sources(&sources);
    findings.sort_by(|a, b| (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule)));
    Ok(findings)
}

/// Number of `.rs` files [`analyze_tree`] would scan — for reporting.
pub fn count_rs_files(root: &Path) -> anyhow::Result<usize> {
    let mut files = Vec::new();
    collect_rs(root, root, &mut files)?;
    Ok(files.len())
}

fn collect_rs(root: &Path, dir: &Path, out: &mut Vec<String>) -> anyhow::Result<()> {
    let entries =
        std::fs::read_dir(dir).map_err(|e| anyhow::anyhow!("reading {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| anyhow::anyhow!("walking {}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(root, &path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .to_string_lossy()
                .replace('\\', "/");
            out.push(rel);
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Token-stream helpers.

fn tok_byte(code: &str, tree: &syntax::Tree, i: usize) -> u8 {
    code.as_bytes()[tree.toks[i].start]
}

fn is_punct_tok(code: &str, tree: &syntax::Tree, i: usize, c: u8) -> bool {
    tree.toks[i].kind == syntax::TokKind::Punct && tok_byte(code, tree, i) == c
}

// ---------------------------------------------------------------------------
// serving-no-panic (lexical, over the token stream)

fn serving_no_panic(rel: &str, code: &str, tree: &syntax::Tree, out: &mut Vec<Finding>) {
    const METHODS: &[&str] = &["unwrap", "expect"];
    const MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];
    let t = &tree.toks;
    for i in 0..t.len() {
        if t[i].kind != syntax::TokKind::Ident {
            continue;
        }
        let w = tree.text(code, i);
        if METHODS.contains(&w)
            && i > 0
            && is_punct_tok(code, tree, i - 1, b'.')
            && i + 1 < t.len()
            && t[i + 1].kind == syntax::TokKind::Open
            && tok_byte(code, tree, i + 1) == b'('
        {
            out.push(Finding {
                file: rel.to_string(),
                line: tree.line(code, i),
                rule: SERVING_NO_PANIC,
                message: format!(
                    "`.{w}(..)` on a serving path — return an error instead, or add \
                     `// pallas-lint: allow(serving-no-panic) -- <why infallible>`"
                ),
            });
        }
        if MACROS.contains(&w) && i + 1 < t.len() && is_punct_tok(code, tree, i + 1, b'!') {
            out.push(Finding {
                file: rel.to_string(),
                line: tree.line(code, i),
                rule: SERVING_NO_PANIC,
                message: format!("`{w}!` on a serving path — serving code must not abort"),
            });
        }
    }
}

// ---------------------------------------------------------------------------
// no-index-untrusted (lexical, over the token stream)

fn no_index_untrusted(rel: &str, code: &str, tree: &syntax::Tree, out: &mut Vec<Finding>) {
    let t = &tree.toks;
    for i in 0..t.len() {
        if t[i].kind != syntax::TokKind::Open || tok_byte(code, tree, i) != b'[' {
            continue;
        }
        let Some(p) = i.checked_sub(1) else { continue };
        let indexes = match t[p].kind {
            syntax::TokKind::Ident => {
                let w = tree.text(code, p);
                let lifetime = p > 0 && is_punct_tok(code, tree, p - 1, b'\'');
                // A keyword or lifetime before `[` means type/expression
                // position (`&mut [u8]`, `&'a [u8]`, `return [..]`).
                !lifetime
                    && !matches!(
                        w,
                        "mut" | "dyn" | "impl" | "else" | "return" | "in" | "as" | "move"
                            | "where" | "const" | "static" | "ref" | "box" | "match" | "if"
                            | "break" | "let"
                    )
            }
            syntax::TokKind::Num => true,
            syntax::TokKind::Close => {
                matches!(tok_byte(code, tree, p), b')' | b']')
            }
            syntax::TokKind::Punct => tok_byte(code, tree, p) == b'?',
            _ => false,
        };
        if indexes {
            out.push(Finding {
                file: rel.to_string(),
                line: tree.line(code, i),
                rule: NO_INDEX_UNTRUSTED,
                message: "`[..]` indexing at the wire boundary can panic on malformed input — \
                          use `get(..)` / `split_at_checked`-style accessors"
                    .to_string(),
            });
        }
    }
}

// ---------------------------------------------------------------------------
// unsafe-contract

fn unsafe_contract(
    rel: &str,
    code: &str,
    stripped: &lexer::Stripped,
    tree: &syntax::Tree,
    fns: &[syntax::FnItem],
    out: &mut Vec<Finding>,
) {
    let sites = syntax::unsafe_sites(code, tree);
    let banned_module = rel.starts_with("api/")
        || rel.starts_with("coordinator/")
        || rel.starts_with("analysis/");
    let lines: Vec<&str> = code.lines().collect();
    for site in &sites {
        if banned_module {
            out.push(Finding {
                file: rel.to_string(),
                line: site.line,
                rule: UNSAFE_CONTRACT,
                message: "`unsafe` is not permitted in api/, coordinator/, or analysis/ — \
                          keep unsafety inside the kernel modules behind safe wrappers"
                    .to_string(),
            });
            continue;
        }
        if !safety_covered(site.line, &lines, &stripped.safety_lines) {
            let what = match site.kind {
                syntax::UnsafeKind::Fn => "unsafe fn",
                syntax::UnsafeKind::Block => "unsafe block",
                syntax::UnsafeKind::Impl => "unsafe impl/trait",
            };
            out.push(Finding {
                file: rel.to_string(),
                line: site.line,
                rule: UNSAFE_CONTRACT,
                message: format!(
                    "{what} without a `// SAFETY:` comment — state the invariant that makes \
                     this sound"
                ),
            });
        }
    }
    if UNSAFE_ALLOWLIST.contains(&rel) {
        return;
    }
    // Raw-pointer arithmetic inside unsafe regions, and core::arch
    // anywhere, are confined to the kernel allowlist.
    let mut regions: Vec<(usize, usize)> = sites.iter().filter_map(|s| s.body).collect();
    for f in fns {
        if f.is_unsafe {
            if let Some(b) = f.body {
                regions.push(b);
            }
        }
    }
    let t = &tree.toks;
    for i in 0..t.len() {
        if t[i].kind != syntax::TokKind::Ident {
            continue;
        }
        let w = tree.text(code, i);
        if (w == "add" || w == "offset")
            && i > 0
            && is_punct_tok(code, tree, i - 1, b'.')
            && i + 1 < t.len()
            && t[i + 1].kind == syntax::TokKind::Open
            && tok_byte(code, tree, i + 1) == b'('
            && regions.iter().any(|&(a, b)| a < i && i < b)
        {
            out.push(Finding {
                file: rel.to_string(),
                line: tree.line(code, i),
                rule: UNSAFE_CONTRACT,
                message: format!(
                    "raw-pointer `.{w}(..)` outside the kernel allowlist \
                     ({}) — move the arithmetic into a kernel module or index safely",
                    UNSAFE_ALLOWLIST.join(", ")
                ),
            });
        }
        if w == "arch"
            && i >= 3
            && t[i - 3].kind == syntax::TokKind::Ident
            && matches!(tree.text(code, i - 3), "core" | "std")
            && is_punct_tok(code, tree, i - 2, b':')
            && is_punct_tok(code, tree, i - 1, b':')
        {
            out.push(Finding {
                file: rel.to_string(),
                line: tree.line(code, i),
                rule: UNSAFE_CONTRACT,
                message: format!(
                    "`{}::arch` intrinsics outside the kernel allowlist ({})",
                    tree.text(code, i - 3),
                    UNSAFE_ALLOWLIST.join(", ")
                ),
            });
        }
    }
}

/// A SAFETY comment covers an `unsafe` site if it sits on the same
/// line, or above it separated only by blank (comment-stripped) lines
/// and `#[..]` attribute lines, within a small window.
fn safety_covered(line: usize, lines: &[&str], safety_lines: &[usize]) -> bool {
    if safety_lines.contains(&line) {
        return true;
    }
    let mut l = line;
    for _ in 0..8 {
        if l <= 1 {
            return false;
        }
        l -= 1;
        if safety_lines.contains(&l) {
            return true;
        }
        let text = lines.get(l - 1).map_or("", |s| s.trim());
        if text.is_empty() || text.starts_with('#') {
            continue;
        }
        return false;
    }
    false
}

// ---------------------------------------------------------------------------
// snapshot-discipline

/// Store lock fields a serving-path module must not route through.
const STORE_LOCK_FIELDS: &[&str] = &["shards", "segments", "cached"];

/// Atomic operations whose presence after `.epoch` marks a live
/// store-internals read (vs a plain `epoch` field on a wire struct).
const ATOMIC_METHODS: &[&str] = &[
    "load",
    "store",
    "swap",
    "fetch_add",
    "fetch_sub",
    "fetch_update",
    "compare_exchange",
    "compare_exchange_weak",
];

const ACQUIRE_METHODS: &[&str] = &[
    "read",
    "write",
    "lock",
    "read_recover",
    "write_recover",
    "lock_recover",
    "try_read",
    "try_write",
    "try_lock",
];

fn snapshot_discipline(rel: &str, code: &str, tree: &syntax::Tree, out: &mut Vec<Finding>) {
    let t = &tree.toks;
    for i in 0..t.len() {
        if t[i].kind != syntax::TokKind::Ident {
            continue;
        }
        let w = tree.text(code, i);
        // Raw `.epoch` atomic access (the `.epoch()` accessor is the
        // sanctioned read). The store's epoch is an `AtomicU64`, so any
        // direct touch reads `.epoch.load(..)` / `.epoch.fetch_add(..)`
        // — that atomic-method tail is the discriminator. A bare
        // `.epoch` copy out of a plain struct (wire stats, a
        // `StoreSnapshot`'s frozen epoch) carries no atomic call and is
        // not a store-internals read.
        let atomic_tail = w == "epoch"
            && i + 3 < t.len()
            && is_punct_tok(code, tree, i + 1, b'.')
            && t[i + 2].kind == syntax::TokKind::Ident
            && ATOMIC_METHODS.contains(&tree.text(code, i + 2))
            && t[i + 3].kind == syntax::TokKind::Open
            && tok_byte(code, tree, i + 3) == b'(';
        if atomic_tail && i > 0 && is_punct_tok(code, tree, i - 1, b'.') {
            out.push(Finding {
                file: rel.to_string(),
                line: tree.line(code, i),
                rule: SNAPSHOT_DISCIPLINE,
                message: "raw `.epoch` field access on a serving path — use the `epoch()` \
                          accessor or a `StoreSnapshot`"
                    .to_string(),
            });
            continue;
        }
        // Lock acquisition routed through a store lock field.
        if !ACQUIRE_METHODS.contains(&w)
            || i == 0
            || !is_punct_tok(code, tree, i - 1, b'.')
            || i + 1 >= t.len()
            || t[i + 1].kind != syntax::TokKind::Open
            || tok_byte(code, tree, i + 1) != b'('
            || tree.close_of(i + 1) != i + 2
        {
            continue;
        }
        let mut r = i - 2;
        if t[r].kind == syntax::TokKind::Close && tok_byte(code, tree, r) == b']' {
            let open = tree.pair[r];
            if open != syntax::NO_PAIR && open > 0 {
                r = open - 1;
            }
        }
        if t[r].kind == syntax::TokKind::Ident
            && r > 0
            && is_punct_tok(code, tree, r - 1, b'.')
        {
            let field = tree.text(code, r);
            if STORE_LOCK_FIELDS.contains(&field) {
                out.push(Finding {
                    file: rel.to_string(),
                    line: tree.line(code, i),
                    rule: SNAPSHOT_DISCIPLINE,
                    message: format!(
                        "serving path acquires the store's `{field}` lock directly — read \
                         through a `StoreSnapshot` / sanctioned accessor instead"
                    ),
                });
            }
        }
    }
}

// ---------------------------------------------------------------------------
// codec-version-exhaustive

fn codec_version_exhaustive(rel: &str, code: &str, tree: &syntax::Tree, out: &mut Vec<Finding>) {
    let Some((_, cname)) = CODEC_MANIFEST.iter().find(|(f, _)| *f == rel) else {
        return;
    };
    let t = &tree.toks;
    // `const <cname>: u32 = N;`
    let mut current: Option<u64> = None;
    for i in 0..t.len() {
        if t[i].kind == syntax::TokKind::Ident
            && tree.is(code, i, "const")
            && i + 1 < t.len()
            && tree.is(code, i + 1, cname)
        {
            for j in i + 2..(i + 8).min(t.len()) {
                if t[j].kind == syntax::TokKind::Num {
                    current = num_value(tree.text(code, j));
                    break;
                }
            }
        }
    }
    let Some(current) = current else {
        out.push(Finding {
            file: rel.to_string(),
            line: 1,
            rule: CODEC_VERSION_EXHAUSTIVE,
            message: format!(
                "format-current const `{cname}` not found — update CODEC_MANIFEST in \
                 analysis/rules.rs if it was renamed"
            ),
        });
        return;
    };
    // Comparisons of the ident `version` against integer literals and
    // against the const by name.
    let mut literals: BTreeSet<u64> = BTreeSet::new();
    let mut bound_by_name = false;
    for i in 0..t.len() {
        if t[i].kind != syntax::TokKind::Ident || !tree.is(code, i, "version") {
            continue;
        }
        // Right side: `version <op> X`
        if let Some((other, _)) = comparison_operand(code, tree, i, true) {
            record_operand(code, tree, other, cname, &mut literals, &mut bound_by_name);
        }
        // Left side: `X <op> version`
        if let Some((other, _)) = comparison_operand(code, tree, i, false) {
            record_operand(code, tree, other, cname, &mut literals, &mut bound_by_name);
        }
    }
    if !bound_by_name {
        out.push(Finding {
            file: rel.to_string(),
            line: 1,
            rule: CODEC_VERSION_EXHAUSTIVE,
            message: format!(
                "reader never compares `version` against `{cname}` — future versions must \
                 be rejected by name, not by magic number"
            ),
        });
    }
    let missing: Vec<String> =
        (2..=current).filter(|v| !literals.contains(v)).map(|v| v.to_string()).collect();
    if !missing.is_empty() {
        out.push(Finding {
            file: rel.to_string(),
            line: 1,
            rule: CODEC_VERSION_EXHAUSTIVE,
            message: format!(
                "reader has no explicit arm for historical version(s) {} (current is \
                 {current}; v1 is the base path) — every tag <= `{cname}` needs a gate",
                missing.join(", ")
            ),
        });
    }
}

fn num_value(text: &str) -> Option<u64> {
    let digits: String = text.chars().take_while(|c| c.is_ascii_digit()).collect();
    digits.parse().ok()
}

/// If the token after (`right == true`) or before the `version` ident
/// is a comparison operator, return the operand token on the far side.
fn comparison_operand(
    code: &str,
    tree: &syntax::Tree,
    i: usize,
    right: bool,
) -> Option<(usize, ())> {
    let t = &tree.toks;
    let is_cmp = |j: usize| {
        j < t.len()
            && t[j].kind == syntax::TokKind::Punct
            && matches!(tok_byte(code, tree, j), b'<' | b'>' | b'=' | b'!')
    };
    if right {
        let mut j = i + 1;
        if !is_cmp(j) {
            return None;
        }
        while is_cmp(j) {
            j += 1;
        }
        (j < t.len()
            && matches!(t[j].kind, syntax::TokKind::Num | syntax::TokKind::Ident))
        .then_some((j, ()))
    } else {
        let j = i.checked_sub(1)?;
        if !is_cmp(j) {
            return None;
        }
        let mut k = j;
        while k > 0 && is_cmp(k - 1) {
            k -= 1;
        }
        let o = k.checked_sub(1)?;
        matches!(t[o].kind, syntax::TokKind::Num | syntax::TokKind::Ident)
            .then_some((o, ()))
    }
}

fn record_operand(
    code: &str,
    tree: &syntax::Tree,
    at: usize,
    cname: &str,
    literals: &mut BTreeSet<u64>,
    bound_by_name: &mut bool,
) {
    match tree.toks[at].kind {
        syntax::TokKind::Num => {
            if let Some(v) = num_value(tree.text(code, at)) {
                literals.insert(v);
            }
        }
        syntax::TokKind::Ident => {
            if tree.is(code, at, cname) {
                *bound_by_name = true;
            }
        }
        _ => {}
    }
}

// ---------------------------------------------------------------------------
// writer-bumps-epoch (lexical, kept byte-oriented from v1: the
// mutator-manifest check reads whole function bodies, which the byte
// scan does precisely enough, and its messages are pinned by tests)

fn is_ident_byte(c: u8) -> bool {
    c == b'_' || c.is_ascii_alphanumeric()
}

/// Byte offsets of `tok` occurrences with identifier boundaries on any
/// end of `tok` that is itself an identifier byte.
fn token_positions(code: &str, tok: &str) -> Vec<usize> {
    let b = code.as_bytes();
    let t = tok.as_bytes();
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(rel) = code[from..].find(tok) {
        let at = from + rel;
        let left_ok = !is_ident_byte(t[0]) || at == 0 || !is_ident_byte(b[at - 1]);
        let end = at + t.len();
        let right_ok =
            !is_ident_byte(t[t.len() - 1]) || end >= b.len() || !is_ident_byte(b[end]);
        if left_ok && right_ok {
            out.push(at);
        }
        from = at + 1;
    }
    out
}

/// Offset of the delimiter closing the one at `open` (same line or
/// beyond); `code.len()` when unbalanced.
fn match_delim(code: &str, open: usize, oc: u8, cc: u8) -> usize {
    let b = code.as_bytes();
    let mut depth = 0usize;
    let mut i = open;
    while i < b.len() {
        if b[i] == oc {
            depth += 1;
        } else if b[i] == cc {
            depth -= 1;
            if depth == 0 {
                return i;
            }
        }
        i += 1;
    }
    code.len()
}

struct FnSpan {
    body_start: usize,
    body_end: usize,
    name_at: usize,
    name: String,
}

/// Brace-delimited function bodies, including nested fns.
fn fn_spans(code: &str) -> Vec<FnSpan> {
    let b = code.as_bytes();
    let mut spans = Vec::new();
    for at in token_positions(code, "fn") {
        let mut i = at + 2;
        while i < b.len() && b[i].is_ascii_whitespace() {
            i += 1;
        }
        let name_at = i;
        while i < b.len() && is_ident_byte(b[i]) {
            i += 1;
        }
        if i == name_at {
            continue; // `fn` in e.g. a closure type — not an item
        }
        let name = code[name_at..i].to_string();
        // Body `{` at bracket/paren depth 0; a `;` first means no body.
        let mut depth = 0isize;
        let mut body_start = None;
        let mut j = i;
        while j < b.len() {
            match b[j] {
                b'(' | b'[' => depth += 1,
                b')' | b']' => depth -= 1,
                b'{' if depth == 0 => {
                    body_start = Some(j);
                    break;
                }
                b';' if depth == 0 => break,
                _ => {}
            }
            j += 1;
        }
        if let Some(start) = body_start {
            spans.push(FnSpan {
                body_start: start,
                body_end: match_delim(code, start, b'{', b'}'),
                name_at,
                name,
            });
        }
    }
    spans
}

/// Store-internals tokens banned outside `state.rs`: touching these
/// directly bypasses the epoch bump the manifest mutators guarantee,
/// so snapshot readers could miss the write.
const STORE_INTERNALS: &[&str] = &[".epoch.fetch_add(", ".shards[", ".segments."];

fn writer_bumps_epoch(rel: &str, code: &str, out: &mut Vec<Finding>) {
    if rel != "coordinator/state.rs" {
        // Non-defining files (e.g. the compactor): the manifest
        // mutators live in state.rs, so the rule here bans direct
        // store-internals access instead.
        for tok in STORE_INTERNALS {
            for at in token_positions(code, tok) {
                out.push(Finding {
                    file: rel.to_string(),
                    line: lexer::line_of(code, at),
                    rule: WRITER_BUMPS_EPOCH,
                    message: format!(
                        "`{tok}..` touches store internals outside state.rs — go through a \
                         manifest mutator ({}) so the epoch bump is guaranteed",
                        MUTATOR_MANIFEST.join(" / ")
                    ),
                });
            }
        }
        return;
    }
    let spans = fn_spans(code);
    let test_spans = lexer::test_spans(code);
    let in_test =
        |at: usize| test_spans.iter().any(|&(a, b)| a <= lexer::line_of(code, at) && lexer::line_of(code, at) <= b);
    for name in MUTATOR_MANIFEST {
        let Some(span) = spans.iter().find(|s| s.name == *name && !in_test(s.name_at)) else {
            out.push(Finding {
                file: rel.to_string(),
                line: 1,
                rule: WRITER_BUMPS_EPOCH,
                message: format!(
                    "manifest mutator `{name}` not found — update MUTATOR_MANIFEST in \
                     analysis/rules.rs if it was renamed or removed"
                ),
            });
            continue;
        };
        let body = &code[span.body_start..span.body_end];
        let Some(bump) = body.find("epoch.fetch_add(") else {
            out.push(Finding {
                file: rel.to_string(),
                line: lexer::line_of(code, span.name_at),
                rule: WRITER_BUMPS_EPOCH,
                message: format!(
                    "mutator `{name}` never bumps the store epoch — snapshot readers would \
                     not observe its write"
                ),
            });
            continue;
        };
        let bump_depth = brace_depth(body, bump);
        let ok = ["write(", "write_recover(", "lock(", "lock_recover("].iter().any(|acq| {
            let mut search = 0;
            while let Some(rel_at) = body[search..bump].find(acq) {
                let at = search + rel_at;
                let dotted = at > 0 && body.as_bytes()[at - 1] == b'.';
                if dotted && brace_depth(body, at) <= bump_depth {
                    return true;
                }
                search = at + 1;
                if search >= bump {
                    break;
                }
            }
            false
        });
        if !ok {
            out.push(Finding {
                file: rel.to_string(),
                line: lexer::line_of(code, span.body_start + bump),
                rule: WRITER_BUMPS_EPOCH,
                message: format!(
                    "`{name}` bumps the epoch outside its write critical section — readers \
                     could snapshot the new epoch without the write"
                ),
            });
        }
    }
}

fn brace_depth(s: &str, at: usize) -> isize {
    s.as_bytes()[..at]
        .iter()
        .map(|&c| match c {
            b'{' => 1,
            b'}' => -1,
            _ => 0,
        })
        .sum()
}
