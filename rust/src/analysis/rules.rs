//! The pallas-lint rule engine: module-scoped rules over stripped
//! source (see [`super::lexer`]), with pragma suppression.
//!
//! Rules and scopes (paths relative to `rust/src/`):
//!
//! | rule | scope | enforces |
//! |------|-------|----------|
//! | `serving-no-panic` | `api/`, `coordinator/state.rs`, `coordinator/pipeline.rs`, `coordinator/durable.rs`, `coordinator/wal.rs`, `coordinator/segfile.rs`, `coordinator/compactor.rs`, `core/estimator.rs`, `core/zone.rs`, `core/quant.rs`, `projection/simd.rs`, `knn/mod.rs` | no `unwrap()` / `expect(` / `panic!` / `unreachable!` / `todo!` / `unimplemented!` on serving paths |
//! | `no-index-untrusted` | `api/` | no `x[..]` indexing at the untrusted-input boundary — use `get(..)` |
//! | `len-before-alloc` | `api/wire.rs`, `coordinator/persist.rs`, `coordinator/durable.rs`, `coordinator/wal.rs`, `coordinator/segfile.rs`, `core/quant.rs`, `projection/simd.rs` | decoded-count allocations need a cap/bytes-present check earlier in the same function |
//! | `guard-across-blocking` | `api/`, `coordinator/` | lock guards must not be live across channel ops, thread scopes, or a second blocking lock |
//! | `writer-bumps-epoch` | `coordinator/state.rs`, `coordinator/compactor.rs` | in `state.rs`, every manifest mutator bumps the store epoch inside its write critical section; elsewhere in scope, store internals must not be touched directly (the mutators are the only sanctioned write path) |
//!
//! `no-index-untrusted` is deliberately **not** applied to the numeric
//! kernels (`core/estimator.rs`): they index with loop-bounded offsets
//! pervasively and rewriting them around `get()` would obscure the
//! tiling structure; the panic tokens themselves are still banned
//! there by `serving-no-panic`.
//!
//! `#[cfg(test)]` items are exempt from every rule — tests unwrap
//! freely by design. The engine is lexical, line-oriented for the
//! guard rule (a guard binding and its acquire are assumed to share a
//! line, which matches rustfmt output for every real site in-tree).

use std::collections::HashSet;
use std::path::Path;

use super::lexer;

pub const SERVING_NO_PANIC: &str = "serving-no-panic";
pub const NO_INDEX_UNTRUSTED: &str = "no-index-untrusted";
pub const LEN_BEFORE_ALLOC: &str = "len-before-alloc";
pub const GUARD_ACROSS_BLOCKING: &str = "guard-across-blocking";
pub const WRITER_BUMPS_EPOCH: &str = "writer-bumps-epoch";
/// Diagnostics about the pragmas themselves (malformed / missing
/// reason / stale). Not suppressible.
pub const PRAGMA_RULE: &str = "pragma";

/// `SketchStore` mutators that must bump the epoch inside their write
/// critical section. Extend this list when adding a mutator; a listed
/// name that no longer exists is itself reported (manifest drift).
/// (`insert_block_shared` / `insert_block_columnar` delegate to
/// `insert_block_prezoned` after computing the zone summary, so the
/// bump lives there.)
const MUTATOR_MANIFEST: &[&str] = &["insert", "insert_block_prezoned", "compact_range"];

/// One rule violation (or pragma diagnostic).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    /// Path relative to the scanned root, `/`-separated.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl Finding {
    pub fn render(&self) -> String {
        format!("{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// Which rules apply to a file, by its root-relative path.
pub fn rules_for(rel: &str) -> Vec<&'static str> {
    let rel = rel.replace('\\', "/");
    let mut rules = Vec::new();
    let serving = rel.starts_with("api/")
        || rel == "coordinator/state.rs"
        || rel == "coordinator/pipeline.rs"
        || rel == "coordinator/durable.rs"
        || rel == "coordinator/wal.rs"
        || rel == "coordinator/segfile.rs"
        || rel == "coordinator/compactor.rs"
        || rel == "core/estimator.rs"
        || rel == "core/zone.rs"
        || rel == "core/quant.rs"
        || rel == "projection/simd.rs"
        || rel == "knn/mod.rs";
    if serving {
        rules.push(SERVING_NO_PANIC);
    }
    if rel.starts_with("api/") {
        rules.push(NO_INDEX_UNTRUSTED);
    }
    if rel == "api/wire.rs"
        || rel == "coordinator/persist.rs"
        || rel == "coordinator/durable.rs"
        || rel == "coordinator/wal.rs"
        || rel == "coordinator/segfile.rs"
        || rel == "core/quant.rs"
        || rel == "projection/simd.rs"
    {
        rules.push(LEN_BEFORE_ALLOC);
    }
    if rel.starts_with("api/") || rel.starts_with("coordinator/") {
        rules.push(GUARD_ACROSS_BLOCKING);
    }
    if rel == "coordinator/state.rs" || rel == "coordinator/compactor.rs" {
        rules.push(WRITER_BUMPS_EPOCH);
    }
    rules
}

/// Analyze one file's source under its root-relative path.
pub fn analyze_source(rel: &str, src: &str) -> Vec<Finding> {
    let stripped = lexer::strip(src);
    let code = stripped.code.as_str();
    let spans = lexer::test_spans(code);
    let in_test = |line: usize| spans.iter().any(|&(a, b)| a <= line && line <= b);

    let mut raw = Vec::new();
    for rule in rules_for(rel) {
        match rule {
            SERVING_NO_PANIC => serving_no_panic(rel, code, &mut raw),
            NO_INDEX_UNTRUSTED => no_index_untrusted(rel, code, &mut raw),
            LEN_BEFORE_ALLOC => len_before_alloc(rel, code, &mut raw),
            GUARD_ACROSS_BLOCKING => guard_across_blocking(rel, code, &mut raw),
            WRITER_BUMPS_EPOCH => writer_bumps_epoch(rel, code, &mut raw),
            _ => {}
        }
    }
    raw.retain(|f| !in_test(f.line));
    // One finding per (rule, line): `a[0][1]` is one problem, not two.
    let mut seen = HashSet::new();
    raw.retain(|f| seen.insert((f.rule, f.line)));

    let lines: Vec<&str> = code.lines().collect();
    let mut used = vec![false; stripped.pragmas.len()];
    let mut findings = Vec::new();
    for f in raw {
        let suppressed = stripped.pragmas.iter().enumerate().any(|(pi, p)| {
            let hit = p.rule.as_deref() == Some(f.rule)
                && p.reason.is_some()
                && pragma_covers(p.line, f.line, &lines);
            if hit {
                used[pi] = true;
            }
            hit
        });
        if !suppressed {
            findings.push(f);
        }
    }
    for (pi, p) in stripped.pragmas.iter().enumerate() {
        if in_test(p.line) {
            continue;
        }
        let message = match (&p.rule, &p.reason) {
            (None, _) => {
                "malformed pragma — expected `pallas-lint: allow(<rule>) -- <reason>`".to_string()
            }
            (Some(rule), None) => {
                format!("allow({rule}) is missing its mandatory `-- <reason>` clause")
            }
            (Some(rule), Some(_)) if !used[pi] => {
                format!("stale allow({rule}) — no matching finding on this or the next line")
            }
            _ => continue,
        };
        findings.push(Finding { file: rel.to_string(), line: p.line, rule: PRAGMA_RULE, message });
    }
    findings.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    findings
}

/// A pragma on line `p` covers findings on `p` itself or on the next
/// non-blank line (the standalone-comment-above-the-statement form).
fn pragma_covers(p: usize, finding: usize, lines: &[&str]) -> bool {
    if finding == p {
        return true;
    }
    let mut q = p + 1;
    while q <= lines.len() && lines[q - 1].trim().is_empty() {
        q += 1;
    }
    finding == q
}

/// Recursively analyze every `.rs` file under `root` (usually
/// `rust/src`). Findings are ordered by path, then line.
pub fn analyze_tree(root: &Path) -> anyhow::Result<Vec<Finding>> {
    let mut files = Vec::new();
    collect_rs(root, root, &mut files)?;
    files.sort();
    let mut findings = Vec::new();
    for rel in &files {
        let src = std::fs::read_to_string(root.join(rel))
            .map_err(|e| anyhow::anyhow!("reading {rel}: {e}"))?;
        findings.extend(analyze_source(rel, &src));
    }
    Ok(findings)
}

/// Number of `.rs` files [`analyze_tree`] would scan — for reporting.
pub fn count_rs_files(root: &Path) -> anyhow::Result<usize> {
    let mut files = Vec::new();
    collect_rs(root, root, &mut files)?;
    Ok(files.len())
}

fn collect_rs(root: &Path, dir: &Path, out: &mut Vec<String>) -> anyhow::Result<()> {
    let entries =
        std::fs::read_dir(dir).map_err(|e| anyhow::anyhow!("reading {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| anyhow::anyhow!("walking {}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(root, &path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .to_string_lossy()
                .replace('\\', "/");
            out.push(rel);
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Token scanning helpers (over stripped code).

fn is_ident_byte(c: u8) -> bool {
    c == b'_' || c.is_ascii_alphanumeric()
}

/// Byte offsets of `tok` occurrences with identifier boundaries on any
/// end of `tok` that is itself an identifier byte.
fn token_positions(code: &str, tok: &str) -> Vec<usize> {
    let b = code.as_bytes();
    let t = tok.as_bytes();
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(rel) = code[from..].find(tok) {
        let at = from + rel;
        let left_ok = !is_ident_byte(t[0]) || at == 0 || !is_ident_byte(b[at - 1]);
        let end = at + t.len();
        let right_ok =
            !is_ident_byte(t[t.len() - 1]) || end >= b.len() || !is_ident_byte(b[end]);
        if left_ok && right_ok {
            out.push(at);
        }
        from = at + 1;
    }
    out
}

fn next_non_space(b: &[u8], mut i: usize) -> Option<u8> {
    while i < b.len() {
        if !b[i].is_ascii_whitespace() {
            return Some(b[i]);
        }
        i += 1;
    }
    None
}

fn prev_non_space(b: &[u8], i: usize) -> Option<u8> {
    let mut j = i;
    while j > 0 {
        j -= 1;
        if !b[j].is_ascii_whitespace() {
            return Some(b[j]);
        }
    }
    None
}

/// Offset of the delimiter closing the one at `open` (same line or
/// beyond); `code.len()` when unbalanced.
fn match_delim(code: &str, open: usize, oc: u8, cc: u8) -> usize {
    let b = code.as_bytes();
    let mut depth = 0usize;
    let mut i = open;
    while i < b.len() {
        if b[i] == oc {
            depth += 1;
        } else if b[i] == cc {
            depth -= 1;
            if depth == 0 {
                return i;
            }
        }
        i += 1;
    }
    code.len()
}

/// Maximal identifier tokens in `s`.
fn idents(s: &str) -> Vec<&str> {
    let b = s.as_bytes();
    let mut out = Vec::new();
    let mut i = 0;
    while i < b.len() {
        if (b[i] == b'_' || b[i].is_ascii_alphabetic()) && (i == 0 || !is_ident_byte(b[i - 1])) {
            let start = i;
            while i < b.len() && is_ident_byte(b[i]) {
                i += 1;
            }
            out.push(&s[start..i]);
        } else {
            i += 1;
        }
    }
    out
}

// ---------------------------------------------------------------------------
// serving-no-panic

fn serving_no_panic(rel: &str, code: &str, out: &mut Vec<Finding>) {
    let b = code.as_bytes();
    const METHODS: &[&str] = &["unwrap", "expect"];
    const MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];
    for tok in METHODS {
        for at in token_positions(code, tok) {
            if prev_non_space(b, at) == Some(b'.') && next_non_space(b, at + tok.len()) == Some(b'(')
            {
                out.push(Finding {
                    file: rel.to_string(),
                    line: lexer::line_of(code, at),
                    rule: SERVING_NO_PANIC,
                    message: format!(
                        "`.{tok}(..)` on a serving path — return an error instead, or add \
                         `// pallas-lint: allow(serving-no-panic) -- <why infallible>`"
                    ),
                });
            }
        }
    }
    for tok in MACROS {
        for at in token_positions(code, tok) {
            if next_non_space(b, at + tok.len()) == Some(b'!') {
                out.push(Finding {
                    file: rel.to_string(),
                    line: lexer::line_of(code, at),
                    rule: SERVING_NO_PANIC,
                    message: format!("`{tok}!` on a serving path — serving code must not abort"),
                });
            }
        }
    }
}

// ---------------------------------------------------------------------------
// no-index-untrusted

fn no_index_untrusted(rel: &str, code: &str, out: &mut Vec<Finding>) {
    let b = code.as_bytes();
    for (i, &c) in b.iter().enumerate() {
        if c != b'[' {
            continue;
        }
        let Some(prev) = prev_non_space(b, i) else { continue };
        // A keyword or lifetime before `[` means type/expression
        // position (`&mut [u8]`, `&'a [u8]`, `return [..]`), not
        // indexing.
        if is_ident_byte(prev) && preceding_word_is_keyword_or_lifetime(b, i) {
            continue;
        }
        if is_ident_byte(prev) || prev == b')' || prev == b']' || prev == b'?' {
            out.push(Finding {
                file: rel.to_string(),
                line: lexer::line_of(code, i),
                rule: NO_INDEX_UNTRUSTED,
                message: "`[..]` indexing at the wire boundary can panic on malformed input — \
                          use `get(..)` / `split_at_checked`-style accessors"
                    .to_string(),
            });
        }
    }
}

/// Is the identifier ending just before offset `i` (after skipping
/// whitespace) a keyword or a lifetime (`&'a [u8]`) rather than an
/// indexable expression?
fn preceding_word_is_keyword_or_lifetime(b: &[u8], i: usize) -> bool {
    let mut end = i;
    while end > 0 && b[end - 1].is_ascii_whitespace() {
        end -= 1;
    }
    let mut start = end;
    while start > 0 && is_ident_byte(b[start - 1]) {
        start -= 1;
    }
    if start > 0 && b[start - 1] == b'\'' {
        return true;
    }
    matches!(
        std::str::from_utf8(&b[start..end]).unwrap_or(""),
        "mut" | "dyn" | "impl" | "else" | "return" | "in" | "as" | "move" | "where" | "const"
            | "static" | "ref" | "box" | "match" | "if" | "break" | "let"
    )
}

// ---------------------------------------------------------------------------
// len-before-alloc

struct FnSpan {
    body_start: usize,
    body_end: usize,
    name_at: usize,
    name: String,
}

/// Brace-delimited function bodies, including nested fns.
fn fn_spans(code: &str) -> Vec<FnSpan> {
    let b = code.as_bytes();
    let mut spans = Vec::new();
    for at in token_positions(code, "fn") {
        let mut i = at + 2;
        while i < b.len() && b[i].is_ascii_whitespace() {
            i += 1;
        }
        let name_at = i;
        while i < b.len() && is_ident_byte(b[i]) {
            i += 1;
        }
        if i == name_at {
            continue; // `fn` in e.g. a closure type — not an item
        }
        let name = code[name_at..i].to_string();
        // Body `{` at bracket/paren depth 0; a `;` first means no body.
        let mut depth = 0isize;
        let mut body_start = None;
        let mut j = i;
        while j < b.len() {
            match b[j] {
                b'(' | b'[' => depth += 1,
                b')' | b']' => depth -= 1,
                b'{' if depth == 0 => {
                    body_start = Some(j);
                    break;
                }
                b';' if depth == 0 => break,
                _ => {}
            }
            j += 1;
        }
        if let Some(start) = body_start {
            spans.push(FnSpan {
                body_start: start,
                body_end: match_delim(code, start, b'{', b'}'),
                name_at,
                name,
            });
        }
    }
    spans
}

/// Innermost function body containing `at`.
fn enclosing_fn(spans: &[FnSpan], at: usize) -> Option<&FnSpan> {
    spans
        .iter()
        .filter(|s| s.body_start < at && at < s.body_end)
        .min_by_key(|s| s.body_end - s.body_start)
}

/// Size expressions that cannot come from a decoded count: literal /
/// const-only arithmetic, or sizes measured off in-memory data via
/// `.len()`.
fn alloc_size_is_benign(arg: &str) -> bool {
    if arg.contains(".len(") {
        return true;
    }
    const PRIMS: &[&str] = &[
        "as", "usize", "isize", "u8", "u16", "u32", "u64", "i8", "i16", "i32", "i64", "f32", "f64",
    ];
    idents(arg).iter().all(|id| {
        PRIMS.contains(id)
            || id
                .chars()
                .all(|c| c.is_ascii_uppercase() || c.is_ascii_digit() || c == '_')
    })
}

/// Tokens accepted as "a cap / bytes-present check happened".
const VALIDATORS: &[&str] = &[
    "ensure!",
    "bail!",
    ".count(",
    "checked_mul",
    "checked_add",
    "parse_header",
    "ensure_frame_fits",
    "MAX_",
];

fn has_validator_before(code: &str, from: usize, to: usize) -> bool {
    let window = &code[from..to];
    VALIDATORS.iter().any(|v| {
        let mut search = 0;
        while let Some(rel) = window[search..].find(v) {
            let at = search + rel;
            let first = v.as_bytes()[0];
            let left_ok = !is_ident_byte(first)
                || at == 0
                || !is_ident_byte(window.as_bytes()[at - 1]);
            if left_ok {
                return true;
            }
            search = at + 1;
        }
        false
    })
}

fn len_before_alloc(rel: &str, code: &str, out: &mut Vec<Finding>) {
    let spans = fn_spans(code);
    let b = code.as_bytes();
    let mut sites: Vec<(usize, String)> = Vec::new();
    for at in token_positions(code, "with_capacity") {
        let Some(open_rel) = code[at..].find('(') else { continue };
        let open = at + open_rel;
        let close = match_delim(code, open, b'(', b')');
        sites.push((at, code[open + 1..close.min(code.len())].to_string()));
    }
    for at in token_positions(code, "reserve") {
        if prev_non_space(b, at) != Some(b'.') {
            continue;
        }
        let Some(open_rel) = code[at..].find('(') else { continue };
        let open = at + open_rel;
        let close = match_delim(code, open, b'(', b')');
        sites.push((at, code[open + 1..close.min(code.len())].to_string()));
    }
    for at in token_positions(code, "vec") {
        if next_non_space(b, at + 3) != Some(b'!') {
            continue;
        }
        let Some(open_rel) = code[at..].find('[') else { continue };
        let open = at + open_rel;
        let close = match_delim(code, open, b'[', b']');
        let body = &code[open + 1..close.min(code.len())];
        // `vec![elem; size]` — only the repeat form declares a size.
        let Some(semi) = top_level_semi(body) else { continue };
        sites.push((at, body[semi + 1..].to_string()));
    }
    for (at, arg) in sites {
        if alloc_size_is_benign(&arg) {
            continue;
        }
        let Some(span) = enclosing_fn(&spans, at) else { continue };
        if has_validator_before(code, span.body_start, at) {
            continue;
        }
        out.push(Finding {
            file: rel.to_string(),
            line: lexer::line_of(code, at),
            rule: LEN_BEFORE_ALLOC,
            message: format!(
                "allocation sized by `{}` with no cap/bytes-present check earlier in `{}` — \
                 validate the decoded count first",
                arg.trim(),
                span.name
            ),
        });
    }
}

/// Offset of the last `;` at bracket depth 0 in `s`, if any.
fn top_level_semi(s: &str) -> Option<usize> {
    let b = s.as_bytes();
    let mut depth = 0isize;
    let mut found = None;
    for (i, &c) in b.iter().enumerate() {
        match c {
            b'(' | b'[' | b'{' => depth += 1,
            b')' | b']' | b'}' => depth -= 1,
            b';' if depth == 0 => found = Some(i),
            _ => {}
        }
    }
    found
}

// ---------------------------------------------------------------------------
// guard-across-blocking

/// Lock acquisitions that produce a guard.
const ACQUIRES: &[&str] = &[
    ".lock()",
    ".read()",
    ".write()",
    ".lock_recover()",
    ".read_recover()",
    ".write_recover()",
    ".try_read()",
    ".try_write()",
];
/// The blocking subset: acquiring one of these while another guard is
/// live risks deadlock; `try_*` never blocks and is exempt (it is the
/// sanctioned non-blocking pattern, e.g. the insert-path cache purge).
const BLOCKING_ACQUIRES: &[&str] = &[
    ".lock()",
    ".read()",
    ".write()",
    ".lock_recover()",
    ".read_recover()",
    ".write_recover()",
];
/// Blocking operations a guard must not be live across. `.join()` is
/// the no-arg thread-join form (`path.join("..")` takes an argument
/// and never matches); `thread::spawn` covers the non-method form.
const BLOCKING_OPS: &[&str] = &[
    "thread::scope",
    "thread::spawn",
    ".spawn(",
    ".send(",
    ".recv(",
    ".recv_timeout(",
    ".join()",
];

fn guard_across_blocking(rel: &str, code: &str, out: &mut Vec<Finding>) {
    struct Guard {
        name: String,
        depth: isize,
        line: usize,
    }
    let mut guards: Vec<Guard> = Vec::new();
    let mut depth = 0isize;
    for (ln0, line) in code.lines().enumerate() {
        let ln = ln0 + 1;
        if let Some(g) = guards.last() {
            let tok = BLOCKING_OPS
                .iter()
                .chain(BLOCKING_ACQUIRES)
                .find(|t| line.contains(*t));
            if let Some(tok) = tok {
                out.push(Finding {
                    file: rel.to_string(),
                    line: ln,
                    rule: GUARD_ACROSS_BLOCKING,
                    message: format!(
                        "lock guard `{}` (bound on line {}) is live across `{}` — scope the \
                         guard to end first, or pragma the documented lock order",
                        g.name, g.line, tok
                    ),
                });
            }
        } else {
            // Two blocking acquisitions inside one statement.
            let hits: usize =
                BLOCKING_ACQUIRES.iter().map(|t| line.matches(t).count()).sum();
            if hits >= 2 {
                out.push(Finding {
                    file: rel.to_string(),
                    line: ln,
                    rule: GUARD_ACROSS_BLOCKING,
                    message: "two blocking lock acquisitions in one statement — acquire in a \
                              documented order, one at a time"
                        .to_string(),
                });
            }
        }
        let opens = line.bytes().filter(|&c| c == b'{').count() as isize;
        let closes = line.bytes().filter(|&c| c == b'}').count() as isize;
        depth += opens - closes;
        guards.retain(|g| g.depth <= depth);
        if !line.is_empty() {
            guards.retain(|g| !line.contains(&format!("drop({})", g.name)));
        }
        if token_positions(line, "let").is_empty() {
            continue;
        }
        let acquire = ACQUIRES
            .iter()
            .filter_map(|t| line.find(t).map(|p| (p, *t)))
            .min();
        if let Some((pos, tok)) = acquire {
            if binds_guard(line, pos + tok.len()) {
                guards.push(Guard {
                    name: binding_name(line).unwrap_or_else(|| "_".to_string()),
                    depth,
                    line: ln,
                });
            }
        }
    }
}

/// After an acquire token: does this statement keep the guard (true)
/// or immediately extract a value through it (false → temporary whose
/// guard dies at the `;`)?
fn binds_guard(line: &str, mut i: usize) -> bool {
    let b = line.as_bytes();
    loop {
        while i < b.len() && b[i].is_ascii_whitespace() {
            i += 1;
        }
        if i >= b.len() {
            return true; // statement continues on the next line — assume guard
        }
        match b[i] {
            b'?' => i += 1,
            b'.' => {
                let rest = &line[i..];
                // Poison/Option adapters still yield the guard itself.
                if let Some(skip) = chained_adapter_len(rest) {
                    i += skip;
                } else {
                    return false;
                }
            }
            _ => return true,
        }
    }
}

/// If `rest` starts with an adapter that returns the guard
/// (`.unwrap()`, `.expect(..)`, `.unwrap_or_else(..)`, `.ok()`),
/// return its length on this line.
fn chained_adapter_len(rest: &str) -> Option<usize> {
    for prefix in [".unwrap()", ".ok()"] {
        if rest.starts_with(prefix) {
            return Some(prefix.len());
        }
    }
    for prefix in [".expect(", ".unwrap_or_else("] {
        if rest.starts_with(prefix) {
            let open = prefix.len() - 1;
            let close = match_delim(rest, open, b'(', b')');
            return Some(if close >= rest.len() { rest.len() } else { close + 1 });
        }
    }
    None
}

/// Identifier bound by a `let` on this line (last ident of the pattern,
/// skipping `mut`/`ref` and enum constructors).
fn binding_name(line: &str) -> Option<String> {
    let let_at = token_positions(line, "let").first().copied()?;
    let eq = assignment_eq(line, let_at + 3)?;
    let pat = &line[let_at + 3..eq];
    idents(pat)
        .into_iter()
        .filter(|id| !matches!(*id, "mut" | "ref" | "Some" | "Ok" | "Err"))
        .next_back()
        .map(str::to_string)
}

/// First plain `=` (not `==`, `=>`, `<=`, `>=`, `!=`, `+=`, …).
fn assignment_eq(line: &str, from: usize) -> Option<usize> {
    let b = line.as_bytes();
    let mut i = from;
    while i < b.len() {
        if b[i] == b'='
            && b.get(i + 1) != Some(&b'=')
            && b.get(i + 1) != Some(&b'>')
            && (i == 0 || !matches!(b[i - 1], b'=' | b'!' | b'<' | b'>' | b'+' | b'-' | b'*' | b'/' | b'&' | b'|' | b'^' | b'%'))
        {
            return Some(i);
        }
        i += 1;
    }
    None
}

// ---------------------------------------------------------------------------
// writer-bumps-epoch

/// Store-internals tokens banned outside `state.rs`: touching these
/// directly bypasses the epoch bump the manifest mutators guarantee,
/// so snapshot readers could miss the write.
const STORE_INTERNALS: &[&str] = &[".epoch.fetch_add(", ".shards[", ".segments."];

fn writer_bumps_epoch(rel: &str, code: &str, out: &mut Vec<Finding>) {
    if rel != "coordinator/state.rs" {
        // Non-defining files (e.g. the compactor): the manifest
        // mutators live in state.rs, so the rule here bans direct
        // store-internals access instead.
        for tok in STORE_INTERNALS {
            for at in token_positions(code, tok) {
                out.push(Finding {
                    file: rel.to_string(),
                    line: lexer::line_of(code, at),
                    rule: WRITER_BUMPS_EPOCH,
                    message: format!(
                        "`{tok}..` touches store internals outside state.rs — go through a \
                         manifest mutator ({}) so the epoch bump is guaranteed",
                        MUTATOR_MANIFEST.join(" / ")
                    ),
                });
            }
        }
        return;
    }
    let spans = fn_spans(code);
    let test_spans = lexer::test_spans(code);
    let in_test =
        |at: usize| test_spans.iter().any(|&(a, b)| a <= lexer::line_of(code, at) && lexer::line_of(code, at) <= b);
    for name in MUTATOR_MANIFEST {
        let Some(span) = spans.iter().find(|s| s.name == *name && !in_test(s.name_at)) else {
            out.push(Finding {
                file: rel.to_string(),
                line: 1,
                rule: WRITER_BUMPS_EPOCH,
                message: format!(
                    "manifest mutator `{name}` not found — update MUTATOR_MANIFEST in \
                     analysis/rules.rs if it was renamed or removed"
                ),
            });
            continue;
        };
        let body = &code[span.body_start..span.body_end];
        let Some(bump) = body.find("epoch.fetch_add(") else {
            out.push(Finding {
                file: rel.to_string(),
                line: lexer::line_of(code, span.name_at),
                rule: WRITER_BUMPS_EPOCH,
                message: format!(
                    "mutator `{name}` never bumps the store epoch — snapshot readers would \
                     not observe its write"
                ),
            });
            continue;
        };
        let bump_depth = brace_depth(body, bump);
        let ok = ["write(", "write_recover(", "lock(", "lock_recover("].iter().any(|acq| {
            let mut search = 0;
            while let Some(rel_at) = body[search..bump].find(acq) {
                let at = search + rel_at;
                let dotted = at > 0 && body.as_bytes()[at - 1] == b'.';
                if dotted && brace_depth(body, at) <= bump_depth {
                    return true;
                }
                search = at + 1;
                if search >= bump {
                    break;
                }
            }
            false
        });
        if !ok {
            out.push(Finding {
                file: rel.to_string(),
                line: lexer::line_of(code, span.body_start + bump),
                rule: WRITER_BUMPS_EPOCH,
                message: format!(
                    "`{name}` bumps the epoch outside its write critical section — readers \
                     could snapshot the new epoch without the write"
                ),
            });
        }
    }
}

fn brace_depth(s: &str, at: usize) -> isize {
    s.as_bytes()[..at]
        .iter()
        .map(|&c| match c {
            b'{' => 1,
            b'}' => -1,
            _ => 0,
        })
        .sum()
}
