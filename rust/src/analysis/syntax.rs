//! Token trees and item outlines for pallas-lint v2.
//!
//! The [`lexer`](super::lexer) strips literals and comments while
//! preserving byte offsets; this module turns the stripped text into a
//! token stream with matched `()` `[]` `{}` delimiter pairs (a token
//! *tree*, flattened: [`Tree::pair`] maps each opener to its closer),
//! and reads item outlines off it: function items with parameter
//! names and body extents ([`fn_items`]), `unsafe` sites
//! ([`unsafe_sites`]), and call expressions ([`calls_in`]).
//!
//! Generics are deliberately **not** delimiters here — `<`/`>` are
//! ordinary punctuation (the `Vec<Vec<[u8; N]>>` ambiguity is why
//! real Rust lexers do the same); outline scanning tracks angle depth
//! locally where it matters (skipping a generic parameter list to
//! find a function's parameter parentheses). This stays precise for
//! rustfmt-shaped sources without importing a real parser, which is
//! the crate's no-dependency constraint.

use super::lexer;

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum TokKind {
    Ident,
    Num,
    Punct,
    Open,
    Close,
}

/// One token over the stripped code: byte range plus kind.
#[derive(Clone, Copy, Debug)]
pub struct Tok {
    pub start: usize,
    pub end: usize,
    pub kind: TokKind,
}

/// Sentinel for an unmatched delimiter in [`Tree::pair`].
pub const NO_PAIR: usize = usize::MAX;

/// Flattened token tree: tokens plus delimiter pairing.
pub struct Tree {
    pub toks: Vec<Tok>,
    /// For `Open`/`Close` tokens, the index of the matching delimiter;
    /// [`NO_PAIR`] when unbalanced. Unused entries for other kinds.
    pub pair: Vec<usize>,
}

fn is_ident_start(c: u8) -> bool {
    c == b'_' || c.is_ascii_alphabetic()
}

fn is_ident_byte(c: u8) -> bool {
    c == b'_' || c.is_ascii_alphanumeric()
}

impl Tree {
    pub fn parse(code: &str) -> Tree {
        let toks = lex(code);
        let mut pair = vec![NO_PAIR; toks.len()];
        // One stack per delimiter kind: a stray `)` must not steal a
        // pending `{` (mismatches happen mid-edit; the gate still runs).
        let mut stacks: [Vec<usize>; 3] = [Vec::new(), Vec::new(), Vec::new()];
        let which = |c: u8| match c {
            b'(' | b')' => 0usize,
            b'[' | b']' => 1,
            _ => 2,
        };
        for (i, t) in toks.iter().enumerate() {
            match t.kind {
                TokKind::Open => stacks[which(code.as_bytes()[t.start])].push(i),
                TokKind::Close => {
                    if let Some(open) = stacks[which(code.as_bytes()[t.start])].pop() {
                        pair[open] = i;
                        pair[i] = open;
                    }
                }
                _ => {}
            }
        }
        Tree { toks, pair }
    }

    /// Token text slice.
    pub fn text<'c>(&self, code: &'c str, i: usize) -> &'c str {
        &code[self.toks[i].start..self.toks[i].end]
    }

    pub fn is(&self, code: &str, i: usize, s: &str) -> bool {
        self.text(code, i) == s
    }

    /// 1-based line of token `i`.
    pub fn line(&self, code: &str, i: usize) -> usize {
        lexer::line_of(code, self.toks[i].start)
    }

    /// Matching close index for the `Open` at `i` (or the end of the
    /// stream when unbalanced, so range loops stay safe).
    pub fn close_of(&self, i: usize) -> usize {
        let p = self.pair[i];
        if p == NO_PAIR {
            self.toks.len().saturating_sub(1)
        } else {
            p
        }
    }
}

/// Tokenize stripped code: identifiers (keywords included), numeric
/// literals, delimiters, and single-byte punctuation. Multi-byte
/// operators arrive as adjacent punct tokens; adjacency is detectable
/// via byte offsets (`==` is two `=` toks with `end == start`).
pub fn lex(code: &str) -> Vec<Tok> {
    let b = code.as_bytes();
    let n = b.len();
    let mut toks = Vec::new();
    let mut i = 0usize;
    while i < n {
        let c = b[i];
        if c.is_ascii_whitespace() {
            i += 1;
            continue;
        }
        if is_ident_start(c) {
            let start = i;
            while i < n && is_ident_byte(b[i]) {
                i += 1;
            }
            toks.push(Tok { start, end: i, kind: TokKind::Ident });
            continue;
        }
        if c.is_ascii_digit() {
            // Numeric literal: digits plus suffix/hex/underscore bytes
            // and the `.` of a float when followed by a digit.
            let start = i;
            while i < n
                && (is_ident_byte(b[i])
                    || (b[i] == b'.' && i + 1 < n && b[i + 1].is_ascii_digit()))
            {
                i += 1;
            }
            toks.push(Tok { start, end: i, kind: TokKind::Num });
            continue;
        }
        let kind = match c {
            b'(' | b'[' | b'{' => TokKind::Open,
            b')' | b']' | b'}' => TokKind::Close,
            _ => TokKind::Punct,
        };
        toks.push(Tok { start: i, end: i + 1, kind });
        i += 1;
    }
    toks
}

/// A `fn` item outline.
#[derive(Clone, Debug)]
pub struct FnItem {
    pub name: String,
    pub name_tok: usize,
    /// Parameter names in declaration order, `self` receivers
    /// excluded (call-site arguments line up positionally).
    pub params: Vec<String>,
    /// Token indices of the body `{` and its matching `}`; `None` for
    /// bodyless declarations.
    pub body: Option<(usize, usize)>,
    pub is_unsafe: bool,
    /// 1-based line of the `fn` keyword.
    pub line: usize,
}

/// All function items (including nested fns and methods) in the tree.
pub fn fn_items(code: &str, tree: &Tree) -> Vec<FnItem> {
    let t = &tree.toks;
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < t.len() {
        if t[i].kind != TokKind::Ident || !tree.is(code, i, "fn") {
            i += 1;
            continue;
        }
        let kw = i;
        let Some(name_tok) = next_at(t, i + 1) else { break };
        if t[name_tok].kind != TokKind::Ident {
            i += 1;
            continue; // `fn` inside a closure type — not an item
        }
        let name = tree.text(code, name_tok).to_string();
        // Find the parameter parens, skipping a generic list. Angle
        // depth counts `<`/`>` puncts; `->` cannot appear before the
        // parameter list, so no arrow correction is needed here.
        let mut j = name_tok + 1;
        let mut angle = 0i32;
        let mut params_group = None;
        while j < t.len() {
            match t[j].kind {
                TokKind::Punct => {
                    let c = code.as_bytes()[t[j].start];
                    if c == b'<' {
                        angle += 1;
                    } else if c == b'>' {
                        angle -= 1;
                    } else if c == b';' {
                        break;
                    }
                    j += 1;
                }
                TokKind::Open => {
                    if angle == 0 && code.as_bytes()[t[j].start] == b'(' {
                        params_group = Some(j);
                        break;
                    }
                    j = tree.close_of(j) + 1;
                }
                _ => j += 1,
            }
        }
        let Some(pg) = params_group else {
            i = kw + 1;
            continue;
        };
        let pg_close = tree.close_of(pg);
        let params = param_names(code, tree, pg, pg_close);
        // Body `{` after the signature: skip bracketed groups (array
        // types in the return position), stop at `;` outside angles.
        let mut k = pg_close + 1;
        let mut angle = 0i32;
        let mut body = None;
        while k < t.len() {
            match t[k].kind {
                TokKind::Punct => {
                    let c = code.as_bytes()[t[k].start];
                    let prev_minus = k > 0
                        && t[k - 1].end == t[k].start
                        && code.as_bytes()[t[k - 1].start] == b'-';
                    if c == b'<' {
                        angle += 1;
                    } else if c == b'>' && !prev_minus {
                        angle -= 1;
                    } else if c == b';' && angle <= 0 {
                        break;
                    }
                    k += 1;
                }
                TokKind::Open => {
                    if code.as_bytes()[t[k].start] == b'{' {
                        body = Some((k, tree.close_of(k)));
                        break;
                    }
                    k = tree.close_of(k) + 1;
                }
                _ => k += 1,
            }
        }
        let is_unsafe = prev_at(t, kw).is_some_and(|p| tree.is(code, p, "unsafe"));
        out.push(FnItem {
            name,
            name_tok,
            params,
            body,
            is_unsafe,
            line: tree.line(code, kw),
        });
        i = name_tok + 1;
    }
    out
}

fn next_at(t: &[Tok], i: usize) -> Option<usize> {
    (i < t.len()).then_some(i)
}

fn prev_at(_t: &[Tok], i: usize) -> Option<usize> {
    i.checked_sub(1)
}

/// Parameter names: split the paren group at top-level commas; each
/// parameter contributes its first pattern identifier (skipping
/// `mut`/`ref` and reference sigils), except `self` receivers.
fn param_names(code: &str, tree: &Tree, open: usize, close: usize) -> Vec<String> {
    let t = &tree.toks;
    let mut names = Vec::new();
    let mut seg_start = open + 1;
    let mut i = open + 1;
    while i <= close && i < t.len() {
        let at_comma = t[i].kind == TokKind::Punct && code.as_bytes()[t[i].start] == b',';
        if i == close || at_comma {
            let mut j = seg_start;
            let mut first = None;
            while j < i {
                if t[j].kind == TokKind::Ident {
                    let s = tree.text(code, j);
                    if s != "mut" && s != "ref" {
                        first = Some(s.to_string());
                        break;
                    }
                }
                if t[j].kind == TokKind::Open {
                    j = tree.close_of(j) + 1;
                    continue;
                }
                j += 1;
            }
            if let Some(p) = first {
                if p != "self" {
                    names.push(p);
                }
            }
            seg_start = i + 1;
        } else if t[i].kind == TokKind::Open {
            i = tree.close_of(i);
        }
        i += 1;
    }
    names
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum UnsafeKind {
    /// `unsafe fn` — the contract covers the whole function.
    Fn,
    /// `unsafe { .. }` block.
    Block,
    /// `unsafe impl`/`unsafe trait` (e.g. a manual `Send`).
    Impl,
}

/// One `unsafe` occurrence.
#[derive(Clone, Debug)]
pub struct UnsafeSite {
    pub kind: UnsafeKind,
    /// Token index of the `unsafe` keyword.
    pub tok: usize,
    pub line: usize,
    /// Body token range (`{`, `}`) for blocks and fns, when present.
    pub body: Option<(usize, usize)>,
}

/// All `unsafe` keywords, classified.
pub fn unsafe_sites(code: &str, tree: &Tree) -> Vec<UnsafeSite> {
    let t = &tree.toks;
    let mut out = Vec::new();
    for i in 0..t.len() {
        if t[i].kind != TokKind::Ident || !tree.is(code, i, "unsafe") {
            continue;
        }
        let line = tree.line(code, i);
        let Some(next) = t.get(i + 1) else {
            continue;
        };
        let site = match next.kind {
            TokKind::Open if code.as_bytes()[next.start] == b'{' => UnsafeSite {
                kind: UnsafeKind::Block,
                tok: i,
                line,
                body: Some((i + 1, tree.close_of(i + 1))),
            },
            TokKind::Ident => {
                let word = tree.text(code, i + 1);
                match word {
                    "fn" | "extern" => {
                        // Body extent comes from the matching FnItem.
                        UnsafeSite { kind: UnsafeKind::Fn, tok: i, line, body: None }
                    }
                    "impl" | "trait" => {
                        UnsafeSite { kind: UnsafeKind::Impl, tok: i, line, body: None }
                    }
                    _ => continue,
                }
            }
            _ => continue,
        };
        out.push(site);
    }
    out
}

/// Receiver shape of a call expression.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Recv {
    /// `f(..)` or `path::f(..)` — a free/path call.
    Free,
    /// `self.f(..)` — a method on the defining type.
    SelfDot,
    /// `x.f(..)` — a method on some other receiver.
    Other,
}

/// One call expression inside a body.
#[derive(Clone, Debug)]
pub struct Call {
    pub name: String,
    /// Token index of the callee identifier.
    pub tok: usize,
    pub line: usize,
    pub recv: Recv,
    /// Token ranges (inclusive start, exclusive end) of each
    /// top-level-comma argument inside the paren group.
    pub args: Vec<(usize, usize)>,
}

const CALL_KEYWORDS: &[&str] = &[
    "if", "while", "for", "match", "return", "fn", "loop", "let", "in", "as", "move", "where",
    "impl", "dyn", "pub", "use", "mod", "unsafe", "else", "break", "continue",
];

/// Call expressions within the token range `[from, to]`: an identifier
/// followed by `(` (optionally through a `::<..>` turbofish), macro
/// invocations excluded.
pub fn calls_in(code: &str, tree: &Tree, from: usize, to: usize) -> Vec<Call> {
    let t = &tree.toks;
    let mut out = Vec::new();
    for i in from..=to.min(t.len().saturating_sub(1)) {
        if t[i].kind != TokKind::Ident {
            continue;
        }
        let name = tree.text(code, i);
        if CALL_KEYWORDS.contains(&name) {
            continue;
        }
        // Locate the argument parens: directly, or through a turbofish.
        let mut open = None;
        if let Some(n1) = t.get(i + 1) {
            if n1.kind == TokKind::Open && code.as_bytes()[n1.start] == b'(' {
                open = Some(i + 1);
            } else if n1.kind == TokKind::Punct && code.as_bytes()[n1.start] == b'!' {
                continue; // macro, not a call
            } else if n1.kind == TokKind::Punct
                && code.as_bytes()[n1.start] == b':'
                && t.get(i + 2).is_some_and(|p| {
                    p.kind == TokKind::Punct && code.as_bytes()[p.start] == b':'
                })
                && t.get(i + 3).is_some_and(|p| {
                    p.kind == TokKind::Punct && code.as_bytes()[p.start] == b'<'
                })
            {
                // `name::<..>(` — scan past the turbofish.
                let mut j = i + 4;
                let mut angle = 1i32;
                while j < t.len() && angle > 0 {
                    if t[j].kind == TokKind::Punct {
                        match code.as_bytes()[t[j].start] {
                            b'<' => angle += 1,
                            b'>' => angle -= 1,
                            _ => {}
                        }
                    } else if t[j].kind == TokKind::Open {
                        j = tree.close_of(j);
                    }
                    j += 1;
                }
                if t.get(j).is_some_and(|p| {
                    p.kind == TokKind::Open && code.as_bytes()[p.start] == b'('
                }) {
                    open = Some(j);
                }
            }
        }
        let Some(open) = open else { continue };
        let close = tree.close_of(open);
        let recv = match i.checked_sub(1) {
            Some(p)
                if t[p].kind == TokKind::Punct && code.as_bytes()[t[p].start] == b'.' =>
            {
                if p > 0 && t[p - 1].kind == TokKind::Ident && tree.is(code, p - 1, "self") {
                    Recv::SelfDot
                } else {
                    Recv::Other
                }
            }
            _ => Recv::Free,
        };
        // Split args at top-level commas.
        let mut args = Vec::new();
        let mut seg = open + 1;
        let mut j = open + 1;
        while j <= close && j < t.len() {
            let comma = t[j].kind == TokKind::Punct && code.as_bytes()[t[j].start] == b',';
            if j == close || comma {
                if j > seg {
                    args.push((seg, j));
                }
                seg = j + 1;
            } else if t[j].kind == TokKind::Open {
                j = tree.close_of(j);
            }
            j += 1;
        }
        out.push(Call { name: name.to_string(), tok: i, line: tree.line(code, i), recv, args });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tree(code: &str) -> Tree {
        Tree::parse(code)
    }

    #[test]
    fn delimiters_pair_through_nested_generics() {
        // `Vec<Vec<[u8; N]>>`: the brackets pair; `<`/`>` stay puncts.
        let code = "fn f(x: Vec<Vec<[u8; N]>>) -> Vec<[f32; 4]> { x.len() }";
        let t = tree(code);
        let opens: Vec<usize> = (0..t.toks.len())
            .filter(|&i| t.toks[i].kind == TokKind::Open)
            .collect();
        for o in opens {
            assert_ne!(t.pair[o], NO_PAIR, "unpaired delimiter in {code}");
        }
        let fns = fn_items(code, &t);
        assert_eq!(fns.len(), 1);
        assert_eq!(fns[0].name, "f");
        assert_eq!(fns[0].params, vec!["x"]);
        assert!(fns[0].body.is_some(), "array-typed return must not hide the body");
    }

    #[test]
    fn fn_outline_skips_generic_parameter_lists() {
        let code = "fn g<F: Fn(u32) -> u64, const N: usize>(cb: F, buf: [u8; N]) -> u64 { cb(0) }";
        let t = tree(code);
        let fns = fn_items(code, &t);
        assert_eq!(fns.len(), 1, "the Fn(u32) in the generic list is not the param group");
        assert_eq!(fns[0].params, vec!["cb", "buf"]);
    }

    #[test]
    fn self_receivers_are_excluded_from_params() {
        let code = "impl S { fn m(&mut self, n: usize, mut k: u32) {} }";
        let t = tree(code);
        let fns = fn_items(code, &t);
        assert_eq!(fns[0].params, vec!["n", "k"]);
    }

    #[test]
    fn unsafe_sites_classify_fn_block_impl() {
        let code = "unsafe fn k() {}\nfn f() { unsafe { g() } }\nunsafe impl Send for P {}\n";
        let t = tree(code);
        let sites = unsafe_sites(code, &t);
        let kinds: Vec<UnsafeKind> = sites.iter().map(|s| s.kind).collect();
        assert_eq!(kinds, vec![UnsafeKind::Fn, UnsafeKind::Block, UnsafeKind::Impl]);
        assert_eq!(sites[1].line, 2);
        assert!(sites[1].body.is_some());
    }

    #[test]
    fn calls_distinguish_receivers_and_skip_macros() {
        let code = "fn f(&self) { self.step(1); other.go(2, 3); helper(x); ensure!(a <= b); }";
        let t = tree(code);
        let fns = fn_items(code, &t);
        let (b0, b1) = fns[0].body.unwrap();
        let calls = calls_in(code, &t, b0, b1);
        let names: Vec<(&str, Recv)> =
            calls.iter().map(|c| (c.name.as_str(), c.recv)).collect();
        assert!(names.contains(&("step", Recv::SelfDot)), "{names:?}");
        assert!(names.contains(&("go", Recv::Other)), "{names:?}");
        assert!(names.contains(&("helper", Recv::Free)), "{names:?}");
        assert!(!names.iter().any(|(n, _)| *n == "ensure"), "macros excluded: {names:?}");
        let go = calls.iter().find(|c| c.name == "go").unwrap();
        assert_eq!(go.args.len(), 2);
    }

    #[test]
    fn turbofish_calls_are_still_calls() {
        let code = "fn f() { parse::<Vec<u32>>(input); }";
        let t = tree(code);
        let fns = fn_items(code, &t);
        let (b0, b1) = fns[0].body.unwrap();
        let calls = calls_in(code, &t, b0, b1);
        assert!(calls.iter().any(|c| c.name == "parse" && c.args.len() == 1), "{calls:?}");
    }

    #[test]
    fn closure_fn_keyword_is_not_an_item() {
        let code = "fn f(cb: impl Fn(u32)) { let g: fn(u32) -> u32 = id; cb(g(1)) }";
        let t = tree(code);
        let fns = fn_items(code, &t);
        assert_eq!(fns.len(), 1, "only the real item: {fns:?}");
        assert_eq!(fns[0].name, "f");
    }
}
