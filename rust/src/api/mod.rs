//! Unified typed query API — the single serving surface.
//!
//! The paper's operating model is a server that holds only the O(nk)
//! sketch state and answers distance queries, *including queries for
//! points that were never ingested* (the stable-projection workload of
//! Li 2006 / Li & Mahoney 2008). This module is that server's contract:
//!
//! * [`protocol`] — the typed [`Request`]/[`Response`] enums: pair
//!   batches, top-k by stored id or by fresh vector, fresh-vector
//!   distances, stats, ping.
//! * [`wire`] — the versioned, length-prefixed binary codec (no crates;
//!   persist-v2-style corruption discipline: caps and length checks
//!   before any allocation).
//! * [`service`] — the batched in-process service: [`ApiHandle`] →
//!   [`crate::coordinator::batcher::Batcher`] → `query-workers` threads
//!   serving each batch from one epoch snapshot.
//! * [`server`] — [`Server`] (std `TcpListener` accept loop feeding the
//!   same service) and the blocking [`Client`].
//!
//! Every entry point — `lpsketch query`, `lpsketch knn`, the `serve`
//! stress demo, `serve --listen` + `client`, tests, benches — goes
//! through these types, and every route returns bitwise-identical
//! estimates to a direct [`crate::coordinator::Pipeline`] call.

// The serving surface must degrade, never die: clippy backs the
// pallas-lint serving-no-panic rule here. Test modules opt back in.
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod protocol;
pub mod server;
pub mod service;
pub mod wire;

pub use protocol::{ApiStats, Request, Response, TopKTarget};
pub use server::{Client, ConnPolicy, Server, ServerGuard};
pub use service::{ApiHandle, ApiJob};
