//! The typed request/response protocol — the one vocabulary every query
//! surface speaks.
//!
//! A [`Request`] names *what* is being asked (a pair batch, a top-k
//! scan, a fresh-vector distance, stats, a ping); a [`Response`] is the
//! typed answer or a [`Response::Error`]. The same enums travel three
//! ways:
//!
//! * **in-process, direct** — [`crate::coordinator::Pipeline::answer`]
//!   dispatches a request against one store snapshot;
//! * **in-process, batched** — [`super::ApiHandle::call`] enqueues the
//!   request into the query service's batcher, where `query-workers`
//!   threads serve whole batches from per-batch epoch snapshots;
//! * **remote** — [`super::Client`] frames the request with the
//!   [`super::wire`] codec and sends it to an [`super::Server`] over
//!   TCP, which feeds the very same service.
//!
//! All three produce bitwise-identical estimates: the wire codec moves
//! f32/f64 values by their IEEE bit patterns, and every serving path
//! runs the same estimator kernels on the same snapshot machinery.

/// One typed query. Estimate semantics per kind:
///
/// * [`Request::PairBatch`] — plain (or MLE, per config) pairwise
///   estimates between stored rows; unknown ids answer `None`.
/// * [`Request::TopK`] — the `top` nearest stored rows by estimated
///   distance, for a stored row id *or* a fresh query vector that was
///   never ingested (the paper's stable-projection query model). Served
///   from the epoch-cached serving index
///   ([`crate::knn::KnnIndex::from_snapshot`]).
/// * [`Request::VectorDistance`] — sketch an out-of-store vector with
///   the pipeline's projection and score it against the given stored
///   ids.
/// * [`Request::Stats`] — metrics counters + store shape, one snapshot.
/// * [`Request::Ping`] — liveness + protocol version echo.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Ping,
    Stats,
    PairBatch(Vec<(u64, u64)>),
    TopK { target: TopKTarget, top: u32 },
    VectorDistance { vector: Vec<f32>, ids: Vec<u64> },
}

/// What a [`Request::TopK`] ranks against the store.
#[derive(Clone, Debug, PartialEq)]
pub enum TopKTarget {
    /// A row already in the store (served from its stored sketch — no
    /// raw data, no re-sketching, works even when the projection
    /// parameters are unknown).
    StoredId(u64),
    /// A fresh vector, sketched on the fly with the pipeline's
    /// projection spec. Requires known projection parameters (rejected
    /// with a clear error on stores restored from files that don't
    /// record them).
    Vector(Vec<f32>),
}

/// Typed answer to a [`Request`]. Variants pair 1:1 with request kinds;
/// [`Response::Error`] carries any serving-side failure (unknown id on
/// top-k, unknown projection on fresh-vector queries, …) instead of a
/// transport-level disconnect.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    Pong { version: u32 },
    Stats(ApiStats),
    PairBatch(Vec<Option<f64>>),
    /// `(store id, estimated distance)` ascending; at most `top` rows.
    TopK(Vec<(u64, f64)>),
    VectorDistance(Vec<Option<f64>>),
    Error(String),
}

/// Metrics counters + store shape, captured from one epoch snapshot
/// (the `Stats` reply body).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ApiStats {
    /// Rows in the store (map + segment-resident).
    pub rows: u64,
    /// Rows held in the per-row map shards.
    pub map_rows: u64,
    /// Columnar segments.
    pub segments: u64,
    /// Store write epoch at capture.
    pub epoch: u64,
    pub rows_ingested: u64,
    pub queries_served: u64,
    pub batches_flushed: u64,
    pub compactions: u64,
    pub queries_in_flight: u64,
    pub snapshot_age: u64,
    /// Distance order p.
    pub p: u32,
    /// Sketch width k.
    pub k: u32,
    /// Alternative (two-sided) strategy?
    pub two_sided: bool,
    /// Whether the serving pipeline knows its projection parameters
    /// (false only for stores restored from sketch files that predate
    /// the recorded-projection header, where fresh-vector queries are
    /// rejected).
    pub projection_known: bool,
}
