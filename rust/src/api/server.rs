//! TCP wire server and blocking client for the typed query API.
//!
//! The [`Server`] is deliberately thin: an accept loop plus one thread
//! per connection that decodes [`wire`] frames and forwards the typed
//! requests into the shared [`ApiHandle`] — i.e. into the very same
//! batcher and `query-workers` pool that serves in-process callers.
//! Remote clients therefore get the identical snapshot discipline (and
//! bitwise-identical estimates) as a local `pipeline.answer(..)` call;
//! the wire adds framing, never semantics.
//!
//! A malformed frame gets a best-effort `Error` response and the
//! connection is dropped (a corrupt length prefix leaves no resync
//! point). Clean client shutdown is just closing the socket.
//!
//! Connections are paced by a [`ConnPolicy`]: a client sitting idle
//! between requests past `max_idle` is closed cleanly, while a client
//! that stalls *inside* a frame (slowloris-style dribbling) is
//! disconnected once its in-frame wait budget `max_stall` is spent —
//! so a stalled or malicious peer can never pin a connection thread
//! forever. Stall disconnects and malformed frames both increment the
//! shared `wire_errors` counter (exported via pipeline metrics).

use std::io::{self, BufReader, BufWriter, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::protocol::{ApiStats, Request, Response, TopKTarget};
use super::service::ApiHandle;
use super::wire;

/// Per-connection pacing policy.
#[derive(Clone)]
pub struct ConnPolicy {
    /// How long a client may sit between requests before the server
    /// closes the connection (a clean close, not an error — idle
    /// keep-alive clients are well-behaved).
    pub max_idle: Duration,
    /// Total in-frame wait budget: once a request's first byte arrived,
    /// the cumulative time spent waiting for the rest may not exceed
    /// this. Dribbling one byte per poll slice does not reset it.
    pub max_stall: Duration,
    /// Poll slice for the socket read timeout — the granularity at
    /// which the idle/stall budgets are charged.
    pub poll: Duration,
    /// Shared malformed-frame / stall-disconnect counter (see
    /// `Pipeline::wire_errors_handle`).
    pub wire_errors: Arc<AtomicU64>,
}

impl Default for ConnPolicy {
    fn default() -> Self {
        ConnPolicy {
            max_idle: Duration::from_secs(300),
            max_stall: Duration::from_secs(30),
            poll: Duration::from_millis(250),
            wire_errors: Arc::new(AtomicU64::new(0)),
        }
    }
}

/// Why a [`PacedReader`] stopped delivering bytes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Expiry {
    /// Idle budget spent between requests — treat as a clean close.
    Idle,
    /// Stall budget spent inside a frame — a wire error.
    Stall,
}

/// A [`Read`] over the connection that charges wait time against the
/// policy budgets. On expiry it reports EOF (`Ok(0)`) and records which
/// budget ran out; the serving loop reads that out of band, because the
/// flag survives however many layers (`BufReader`, anyhow contexts) the
/// I/O error would have been wrapped in.
struct PacedReader {
    stream: TcpStream,
    policy: ConnPolicy,
    in_frame: bool,
    waited: Duration,
    expired: Option<Expiry>,
}

impl PacedReader {
    fn new(stream: TcpStream, policy: ConnPolicy) -> io::Result<Self> {
        stream.set_read_timeout(Some(policy.poll))?;
        // Bound the best-effort error write too: flushing to a stalled
        // peer must not pin the thread either.
        stream.set_write_timeout(Some(policy.max_stall))?;
        Ok(PacedReader { stream, policy, in_frame: false, waited: Duration::ZERO, expired: None })
    }

    /// Reset to the between-requests state: the idle budget applies
    /// until the next byte arrives.
    fn begin_frame(&mut self) {
        self.in_frame = false;
        self.waited = Duration::ZERO;
    }

    fn expiry(&self) -> Option<Expiry> {
        self.expired
    }
}

impl Read for PacedReader {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        loop {
            if self.expired.is_some() {
                return Ok(0);
            }
            match self.stream.read(buf) {
                Ok(n) => {
                    if n > 0 {
                        self.in_frame = true;
                    }
                    return Ok(n);
                }
                Err(e) if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) =>
                {
                    self.waited += self.policy.poll;
                    let budget =
                        if self.in_frame { self.policy.max_stall } else { self.policy.max_idle };
                    if self.waited >= budget {
                        self.expired =
                            Some(if self.in_frame { Expiry::Stall } else { Expiry::Idle });
                        return Ok(0);
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
    }
}

/// A bound-but-not-yet-serving TCP server for the typed API.
pub struct Server {
    listener: TcpListener,
    handle: ApiHandle,
    policy: ConnPolicy,
}

impl Server {
    /// Bind `addr` (e.g. `127.0.0.1:4100`, or port `0` for an
    /// OS-assigned port) and attach the query-service handle every
    /// connection will be served from.
    pub fn bind(addr: &str, handle: ApiHandle) -> anyhow::Result<Self> {
        Self::bind_with(addr, handle, ConnPolicy::default())
    }

    /// [`Server::bind`] with an explicit pacing policy / error counter.
    pub fn bind_with(addr: &str, handle: ApiHandle, policy: ConnPolicy) -> anyhow::Result<Self> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("binding {addr}: {e}"))?;
        Ok(Server { listener, handle, policy })
    }

    pub fn local_addr(&self) -> anyhow::Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Serve forever on the calling thread (the `serve --listen` mode):
    /// one spawned thread per accepted connection.
    pub fn run(self) -> anyhow::Result<()> {
        for conn in self.listener.incoming() {
            match conn {
                Ok(stream) => {
                    let handle = self.handle.clone();
                    let policy = self.policy.clone();
                    std::thread::spawn(move || {
                        let _ = serve_conn(stream, handle, policy);
                    });
                }
                Err(e) => eprintln!("accept error: {e}"),
            }
        }
        Ok(())
    }

    /// Serve on a background thread and return a guard that can stop
    /// the accept loop — the embedded/test mode.
    pub fn spawn(self) -> anyhow::Result<ServerGuard> {
        let addr = self.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let accept_stop = Arc::clone(&stop);
        let handle = self.handle;
        let listener = self.listener;
        let policy = self.policy;
        let join = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if accept_stop.load(Ordering::Relaxed) {
                    break;
                }
                if let Ok(stream) = conn {
                    let handle = handle.clone();
                    let policy = policy.clone();
                    std::thread::spawn(move || {
                        let _ = serve_conn(stream, handle, policy);
                    });
                }
            }
        });
        Ok(ServerGuard { addr, stop, join: Some(join) })
    }
}

/// Handle for a background [`Server::spawn`] accept loop.
pub struct ServerGuard {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ServerGuard {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join the accept thread. Connections already
    /// being served drain on their own threads.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Poke the blocking accept so the loop observes the flag.
        let _ = TcpStream::connect(self.addr);
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

fn serve_conn(stream: TcpStream, handle: ApiHandle, policy: ConnPolicy) -> anyhow::Result<()> {
    stream.set_nodelay(true).ok();
    let wire_errors = Arc::clone(&policy.wire_errors);
    let writer_stream = stream.try_clone()?;
    let mut reader = BufReader::new(PacedReader::new(stream, policy)?);
    let mut writer = BufWriter::new(writer_stream);
    loop {
        reader.get_mut().begin_frame();
        let req = match wire::read_request(&mut reader) {
            Ok(Some(req)) => req,
            // Clean client close — or the idle budget ran out, which is
            // the same thing from the server's point of view.
            Ok(None) => return Ok(()),
            Err(e) => {
                wire_errors.fetch_add(1, Ordering::Relaxed);
                if reader.get_ref().expiry() == Some(Expiry::Stall) {
                    // The peer stopped sending mid-frame; don't write a
                    // farewell it isn't reading.
                    anyhow::bail!("connection stalled mid-frame (read budget spent)");
                }
                let _ = wire::write_response(
                    &mut writer,
                    &Response::Error(format!("bad request frame: {e}")),
                );
                let _ = writer.flush();
                return Err(e);
            }
        };
        let resp = match handle.call(req) {
            Ok(resp) => resp,
            Err(e) => Response::Error(e.to_string()),
        };
        wire::write_response(&mut writer, &resp)?;
        writer.flush()?;
    }
}

/// Blocking client for the typed API over TCP — the remote counterpart
/// of [`ApiHandle`]. One request in flight at a time per connection.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Client {
    pub fn connect<A: ToSocketAddrs + std::fmt::Debug>(addr: A) -> anyhow::Result<Self> {
        let stream = TcpStream::connect(&addr)
            .map_err(|e| anyhow::anyhow!("connecting {addr:?}: {e}"))?;
        stream.set_nodelay(true).ok();
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
        })
    }

    /// Send one request and block for its response.
    pub fn call(&mut self, req: &Request) -> anyhow::Result<Response> {
        wire::write_request(&mut self.writer, req)?;
        self.writer.flush()?;
        wire::read_response(&mut self.reader)?
            .ok_or_else(|| anyhow::anyhow!("server closed the connection"))
    }

    /// Liveness probe; returns the server's protocol version.
    pub fn ping(&mut self) -> anyhow::Result<u32> {
        match self.call(&Request::Ping)? {
            Response::Pong { version } => Ok(version),
            other => Self::unexpected("ping", other),
        }
    }

    pub fn stats(&mut self) -> anyhow::Result<ApiStats> {
        match self.call(&Request::Stats)? {
            Response::Stats(s) => Ok(s),
            other => Self::unexpected("stats", other),
        }
    }

    /// Batch of pair estimates (`None` per unknown id).
    pub fn pairs(&mut self, pairs: &[(u64, u64)]) -> anyhow::Result<Vec<Option<f64>>> {
        match self.call(&Request::PairBatch(pairs.to_vec()))? {
            Response::PairBatch(ests) => Ok(ests),
            other => Self::unexpected("pair batch", other),
        }
    }

    /// Top-k nearest stored rows for a stored id.
    pub fn top_k_id(&mut self, id: u64, top: u32) -> anyhow::Result<Vec<(u64, f64)>> {
        match self.call(&Request::TopK { target: TopKTarget::StoredId(id), top })? {
            Response::TopK(list) => Ok(list),
            other => Self::unexpected("top-k", other),
        }
    }

    /// Top-k nearest stored rows for a fresh (never-ingested) vector.
    pub fn top_k_vector(&mut self, vector: &[f32], top: u32) -> anyhow::Result<Vec<(u64, f64)>> {
        let target = TopKTarget::Vector(vector.to_vec());
        match self.call(&Request::TopK { target, top })? {
            Response::TopK(list) => Ok(list),
            other => Self::unexpected("top-k", other),
        }
    }

    /// Distances from a fresh vector to the given stored ids.
    pub fn vector_distances(
        &mut self,
        vector: &[f32],
        ids: &[u64],
    ) -> anyhow::Result<Vec<Option<f64>>> {
        let req = Request::VectorDistance { vector: vector.to_vec(), ids: ids.to_vec() };
        match self.call(&req)? {
            Response::VectorDistance(ests) => Ok(ests),
            other => Self::unexpected("vector distance", other),
        }
    }

    fn unexpected<T>(what: &str, resp: Response) -> anyhow::Result<T> {
        match resp {
            Response::Error(e) => anyhow::bail!("server error on {what}: {e}"),
            other => anyhow::bail!("unexpected response to {what}: {other:?}"),
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::coordinator::Pipeline;
    use crate::data::{gen, DataDist};

    fn served_pipeline() -> (Arc<Pipeline>, crate::data::RowMatrix) {
        let mut cfg = Config::default();
        cfg.n = 24;
        cfg.d = 48;
        cfg.k = 16;
        cfg.block_rows = 8;
        cfg.workers = 2;
        let data = gen::generate(DataDist::Gaussian, cfg.n, cfg.d, 404);
        let pipeline = Arc::new(Pipeline::new(cfg).unwrap());
        pipeline.ingest(&data).unwrap();
        (pipeline, data)
    }

    #[test]
    fn loopback_round_trips_every_request_kind() {
        let (pipeline, data) = served_pipeline();
        let handle = pipeline.spawn_query_service();
        let guard = Server::bind("127.0.0.1:0", handle).unwrap().spawn().unwrap();
        let mut client = Client::connect(guard.addr()).unwrap();

        assert_eq!(client.ping().unwrap(), wire::WIRE_VERSION as u32);
        let stats = client.stats().unwrap();
        assert_eq!(stats.rows, 24);
        assert!(stats.projection_known);

        let pairs: Vec<(u64, u64)> = (0..24).map(|i| (i, (i + 5) % 24)).collect();
        assert_eq!(client.pairs(&pairs).unwrap(), pipeline.estimate_pairs(&pairs));

        let direct = pipeline.top_k_ids(&[3], 5);
        assert_eq!(client.top_k_id(3, 5).unwrap(), direct[0].clone().unwrap());
        assert!(client
            .top_k_id(9999, 5)
            .unwrap_err()
            .to_string()
            .contains("unknown id"));

        let q = data.row(7);
        assert_eq!(
            client.top_k_vector(q, 4).unwrap(),
            pipeline.top_k(&[q], 4).unwrap()[0]
        );
        let ids: Vec<u64> = (0..24).collect();
        assert_eq!(
            client.vector_distances(q, &ids).unwrap(),
            pipeline.vector_distances(q, &ids).unwrap()
        );
        guard.stop();
    }

    #[test]
    fn malformed_frame_gets_an_error_and_a_hangup() {
        let (pipeline, _) = served_pipeline();
        let handle = pipeline.spawn_query_service();
        let guard = Server::bind("127.0.0.1:0", handle).unwrap().spawn().unwrap();
        let mut stream = TcpStream::connect(guard.addr()).unwrap();
        stream.write_all(b"garbage that is not a frame at all").unwrap();
        stream.flush().unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        match wire::read_response(&mut reader).unwrap() {
            Some(Response::Error(e)) => assert!(e.contains("bad request frame"), "{e}"),
            other => panic!("expected an error response, got {other:?}"),
        }
        // Server hangs up after an unrecoverable frame.
        assert_eq!(wire::read_response(&mut reader).unwrap(), None);
        guard.stop();
    }

    fn test_policy(idle_ms: u64, stall_ms: u64) -> ConnPolicy {
        ConnPolicy {
            max_idle: Duration::from_millis(idle_ms),
            max_stall: Duration::from_millis(stall_ms),
            poll: Duration::from_millis(20),
            wire_errors: Arc::new(AtomicU64::new(0)),
        }
    }

    #[test]
    fn idle_connection_is_closed_cleanly_without_counting() {
        let (pipeline, _) = served_pipeline();
        let handle = pipeline.spawn_query_service();
        let policy = test_policy(120, 5000);
        let errors = Arc::clone(&policy.wire_errors);
        let guard = Server::bind_with("127.0.0.1:0", handle, policy).unwrap().spawn().unwrap();
        let stream = TcpStream::connect(guard.addr()).unwrap();
        // Send nothing: the server must hang up on its own.
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        assert_eq!(wire::read_response(&mut reader).unwrap(), None, "idle close");
        assert_eq!(errors.load(Ordering::Relaxed), 0, "idle is not a wire error");
        guard.stop();
    }

    #[test]
    fn stalled_mid_frame_connection_is_dropped_and_counted() {
        let (pipeline, _) = served_pipeline();
        let handle = pipeline.spawn_query_service();
        let policy = test_policy(5000, 120);
        let errors = Arc::clone(&policy.wire_errors);
        let guard = Server::bind_with("127.0.0.1:0", handle, policy).unwrap().spawn().unwrap();
        let mut stream = TcpStream::connect(guard.addr()).unwrap();
        // Two bytes of a frame, then silence: slowloris. The stall
        // budget (not the much longer idle budget) must apply.
        stream.write_all(&[0x01, 0x02]).unwrap();
        stream.flush().unwrap();
        let t0 = std::time::Instant::now();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        assert_eq!(wire::read_response(&mut reader).unwrap(), None, "server hung up");
        assert!(t0.elapsed() < Duration::from_secs(4), "stall budget applied, not idle");
        assert_eq!(errors.load(Ordering::Relaxed), 1, "stall counts as a wire error");
        guard.stop();
    }

    #[test]
    fn malformed_frame_increments_wire_errors() {
        let (pipeline, _) = served_pipeline();
        let handle = pipeline.spawn_query_service();
        let policy = ConnPolicy::default();
        let errors = Arc::clone(&policy.wire_errors);
        let guard = Server::bind_with("127.0.0.1:0", handle, policy).unwrap().spawn().unwrap();
        let mut stream = TcpStream::connect(guard.addr()).unwrap();
        stream.write_all(b"garbage that is not a frame at all").unwrap();
        stream.flush().unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        match wire::read_response(&mut reader).unwrap() {
            Some(Response::Error(e)) => assert!(e.contains("bad request frame"), "{e}"),
            other => panic!("expected an error response, got {other:?}"),
        }
        assert_eq!(wire::read_response(&mut reader).unwrap(), None);
        assert_eq!(errors.load(Ordering::Relaxed), 1);
        guard.stop();
    }

    #[test]
    fn two_clients_share_one_service() {
        let (pipeline, _) = served_pipeline();
        let handle = pipeline.spawn_query_service();
        let guard = Server::bind("127.0.0.1:0", handle).unwrap().spawn().unwrap();
        let addr = guard.addr();
        let want = pipeline.estimate_pairs(&[(0, 1)])[0];
        let threads: Vec<_> = (0..3)
            .map(|_| {
                std::thread::spawn(move || {
                    let mut client = Client::connect(addr).unwrap();
                    for _ in 0..20 {
                        assert_eq!(client.pairs(&[(0, 1)]).unwrap(), vec![want]);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        guard.stop();
    }
}
