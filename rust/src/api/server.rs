//! TCP wire server and blocking client for the typed query API.
//!
//! The [`Server`] is deliberately thin: an accept loop plus one thread
//! per connection that decodes [`wire`] frames and forwards the typed
//! requests into the shared [`ApiHandle`] — i.e. into the very same
//! batcher and `query-workers` pool that serves in-process callers.
//! Remote clients therefore get the identical snapshot discipline (and
//! bitwise-identical estimates) as a local `pipeline.answer(..)` call;
//! the wire adds framing, never semantics.
//!
//! A malformed frame gets a best-effort `Error` response and the
//! connection is dropped (a corrupt length prefix leaves no resync
//! point). Clean client shutdown is just closing the socket.

use std::io::{BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use super::protocol::{ApiStats, Request, Response, TopKTarget};
use super::service::ApiHandle;
use super::wire;

/// A bound-but-not-yet-serving TCP server for the typed API.
pub struct Server {
    listener: TcpListener,
    handle: ApiHandle,
}

impl Server {
    /// Bind `addr` (e.g. `127.0.0.1:4100`, or port `0` for an
    /// OS-assigned port) and attach the query-service handle every
    /// connection will be served from.
    pub fn bind(addr: &str, handle: ApiHandle) -> anyhow::Result<Self> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("binding {addr}: {e}"))?;
        Ok(Server { listener, handle })
    }

    pub fn local_addr(&self) -> anyhow::Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Serve forever on the calling thread (the `serve --listen` mode):
    /// one spawned thread per accepted connection.
    pub fn run(self) -> anyhow::Result<()> {
        for conn in self.listener.incoming() {
            match conn {
                Ok(stream) => {
                    let handle = self.handle.clone();
                    std::thread::spawn(move || {
                        let _ = serve_conn(stream, handle);
                    });
                }
                Err(e) => eprintln!("accept error: {e}"),
            }
        }
        Ok(())
    }

    /// Serve on a background thread and return a guard that can stop
    /// the accept loop — the embedded/test mode.
    pub fn spawn(self) -> anyhow::Result<ServerGuard> {
        let addr = self.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let accept_stop = Arc::clone(&stop);
        let handle = self.handle;
        let listener = self.listener;
        let join = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if accept_stop.load(Ordering::Relaxed) {
                    break;
                }
                if let Ok(stream) = conn {
                    let handle = handle.clone();
                    std::thread::spawn(move || {
                        let _ = serve_conn(stream, handle);
                    });
                }
            }
        });
        Ok(ServerGuard { addr, stop, join: Some(join) })
    }
}

/// Handle for a background [`Server::spawn`] accept loop.
pub struct ServerGuard {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ServerGuard {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join the accept thread. Connections already
    /// being served drain on their own threads.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Poke the blocking accept so the loop observes the flag.
        let _ = TcpStream::connect(self.addr);
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

fn serve_conn(stream: TcpStream, handle: ApiHandle) -> anyhow::Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    loop {
        let req = match wire::read_request(&mut reader) {
            Ok(Some(req)) => req,
            Ok(None) => return Ok(()), // client closed cleanly
            Err(e) => {
                let _ = wire::write_response(
                    &mut writer,
                    &Response::Error(format!("bad request frame: {e}")),
                );
                let _ = writer.flush();
                return Err(e);
            }
        };
        let resp = match handle.call(req) {
            Ok(resp) => resp,
            Err(e) => Response::Error(e.to_string()),
        };
        wire::write_response(&mut writer, &resp)?;
        writer.flush()?;
    }
}

/// Blocking client for the typed API over TCP — the remote counterpart
/// of [`ApiHandle`]. One request in flight at a time per connection.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Client {
    pub fn connect<A: ToSocketAddrs + std::fmt::Debug>(addr: A) -> anyhow::Result<Self> {
        let stream = TcpStream::connect(&addr)
            .map_err(|e| anyhow::anyhow!("connecting {addr:?}: {e}"))?;
        stream.set_nodelay(true).ok();
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
        })
    }

    /// Send one request and block for its response.
    pub fn call(&mut self, req: &Request) -> anyhow::Result<Response> {
        wire::write_request(&mut self.writer, req)?;
        self.writer.flush()?;
        wire::read_response(&mut self.reader)?
            .ok_or_else(|| anyhow::anyhow!("server closed the connection"))
    }

    /// Liveness probe; returns the server's protocol version.
    pub fn ping(&mut self) -> anyhow::Result<u32> {
        match self.call(&Request::Ping)? {
            Response::Pong { version } => Ok(version),
            other => Self::unexpected("ping", other),
        }
    }

    pub fn stats(&mut self) -> anyhow::Result<ApiStats> {
        match self.call(&Request::Stats)? {
            Response::Stats(s) => Ok(s),
            other => Self::unexpected("stats", other),
        }
    }

    /// Batch of pair estimates (`None` per unknown id).
    pub fn pairs(&mut self, pairs: &[(u64, u64)]) -> anyhow::Result<Vec<Option<f64>>> {
        match self.call(&Request::PairBatch(pairs.to_vec()))? {
            Response::PairBatch(ests) => Ok(ests),
            other => Self::unexpected("pair batch", other),
        }
    }

    /// Top-k nearest stored rows for a stored id.
    pub fn top_k_id(&mut self, id: u64, top: u32) -> anyhow::Result<Vec<(u64, f64)>> {
        match self.call(&Request::TopK { target: TopKTarget::StoredId(id), top })? {
            Response::TopK(list) => Ok(list),
            other => Self::unexpected("top-k", other),
        }
    }

    /// Top-k nearest stored rows for a fresh (never-ingested) vector.
    pub fn top_k_vector(&mut self, vector: &[f32], top: u32) -> anyhow::Result<Vec<(u64, f64)>> {
        let target = TopKTarget::Vector(vector.to_vec());
        match self.call(&Request::TopK { target, top })? {
            Response::TopK(list) => Ok(list),
            other => Self::unexpected("top-k", other),
        }
    }

    /// Distances from a fresh vector to the given stored ids.
    pub fn vector_distances(
        &mut self,
        vector: &[f32],
        ids: &[u64],
    ) -> anyhow::Result<Vec<Option<f64>>> {
        let req = Request::VectorDistance { vector: vector.to_vec(), ids: ids.to_vec() };
        match self.call(&req)? {
            Response::VectorDistance(ests) => Ok(ests),
            other => Self::unexpected("vector distance", other),
        }
    }

    fn unexpected<T>(what: &str, resp: Response) -> anyhow::Result<T> {
        match resp {
            Response::Error(e) => anyhow::bail!("server error on {what}: {e}"),
            other => anyhow::bail!("unexpected response to {what}: {other:?}"),
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::coordinator::Pipeline;
    use crate::data::{gen, DataDist};

    fn served_pipeline() -> (Arc<Pipeline>, crate::data::RowMatrix) {
        let mut cfg = Config::default();
        cfg.n = 24;
        cfg.d = 48;
        cfg.k = 16;
        cfg.block_rows = 8;
        cfg.workers = 2;
        let data = gen::generate(DataDist::Gaussian, cfg.n, cfg.d, 404);
        let pipeline = Arc::new(Pipeline::new(cfg).unwrap());
        pipeline.ingest(&data).unwrap();
        (pipeline, data)
    }

    #[test]
    fn loopback_round_trips_every_request_kind() {
        let (pipeline, data) = served_pipeline();
        let handle = pipeline.spawn_query_service();
        let guard = Server::bind("127.0.0.1:0", handle).unwrap().spawn().unwrap();
        let mut client = Client::connect(guard.addr()).unwrap();

        assert_eq!(client.ping().unwrap(), wire::WIRE_VERSION as u32);
        let stats = client.stats().unwrap();
        assert_eq!(stats.rows, 24);
        assert!(stats.projection_known);

        let pairs: Vec<(u64, u64)> = (0..24).map(|i| (i, (i + 5) % 24)).collect();
        assert_eq!(client.pairs(&pairs).unwrap(), pipeline.estimate_pairs(&pairs));

        let direct = pipeline.top_k_ids(&[3], 5);
        assert_eq!(client.top_k_id(3, 5).unwrap(), direct[0].clone().unwrap());
        assert!(client
            .top_k_id(9999, 5)
            .unwrap_err()
            .to_string()
            .contains("unknown id"));

        let q = data.row(7);
        assert_eq!(
            client.top_k_vector(q, 4).unwrap(),
            pipeline.top_k(&[q], 4).unwrap()[0]
        );
        let ids: Vec<u64> = (0..24).collect();
        assert_eq!(
            client.vector_distances(q, &ids).unwrap(),
            pipeline.vector_distances(q, &ids).unwrap()
        );
        guard.stop();
    }

    #[test]
    fn malformed_frame_gets_an_error_and_a_hangup() {
        let (pipeline, _) = served_pipeline();
        let handle = pipeline.spawn_query_service();
        let guard = Server::bind("127.0.0.1:0", handle).unwrap().spawn().unwrap();
        let mut stream = TcpStream::connect(guard.addr()).unwrap();
        stream.write_all(b"garbage that is not a frame at all").unwrap();
        stream.flush().unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        match wire::read_response(&mut reader).unwrap() {
            Some(Response::Error(e)) => assert!(e.contains("bad request frame"), "{e}"),
            other => panic!("expected an error response, got {other:?}"),
        }
        // Server hangs up after an unrecoverable frame.
        assert_eq!(wire::read_response(&mut reader).unwrap(), None);
        guard.stop();
    }

    #[test]
    fn two_clients_share_one_service() {
        let (pipeline, _) = served_pipeline();
        let handle = pipeline.spawn_query_service();
        let guard = Server::bind("127.0.0.1:0", handle).unwrap().spawn().unwrap();
        let addr = guard.addr();
        let want = pipeline.estimate_pairs(&[(0, 1)])[0];
        let threads: Vec<_> = (0..3)
            .map(|_| {
                std::thread::spawn(move || {
                    let mut client = Client::connect(addr).unwrap();
                    for _ in 0..20 {
                        assert_eq!(client.pairs(&[(0, 1)]).unwrap(), vec![want]);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        guard.stop();
    }
}
