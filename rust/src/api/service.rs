//! The batched query service: the in-process serving layer of the
//! unified API.
//!
//! [`spawn`] wires one [`Batcher`] (now generic over request kinds, not
//! just id pairs) to `query-workers` serving threads. Workers take
//! turns draining the batcher — one drainer at a time behind a mutex,
//! the lock released before a batch is *served*, so batches execute
//! concurrently — and each drained batch is answered by
//! [`Pipeline::serve_api_batch`] from a single per-batch epoch
//! snapshot. A pair query, a top-k scan, and a stats probe that land in
//! the same batch therefore all observe the same consistent cut. Top-k
//! requests are answered through the zone-pruned fused scan: segments
//! whose marginal-norm zone bound cannot beat the current heap root are
//! skipped outright (bitwise-identical results to the full scan), and
//! the visit/skip counters land in the metrics registry.
//!
//! The [`ApiHandle`] is the client side: cloneable, blocking, used
//! directly by the CLI (`query`, `knn`, the `serve` demo) and by every
//! TCP connection the [`super::Server`] accepts — remote and local
//! callers share one queue, one worker pool, one snapshot discipline.

use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use crate::coordinator::batcher::{Batcher, Drained};
use crate::coordinator::Pipeline;
use crate::util::sync::MutexExt;

use super::protocol::{Request, Response};

/// One queued request with its reply slot.
pub struct ApiJob {
    pub request: Request,
    pub reply: mpsc::SyncSender<Response>,
}

/// Cloneable client handle to the batched query service. The service
/// stops when every handle is dropped.
#[derive(Clone)]
pub struct ApiHandle {
    tx: mpsc::Sender<ApiJob>,
}

impl ApiHandle {
    /// Blocking typed call through the batcher.
    pub fn call(&self, request: Request) -> anyhow::Result<Response> {
        let (reply, rx) = mpsc::sync_channel(1);
        self.tx
            .send(ApiJob { request, reply })
            .map_err(|_| anyhow::anyhow!("query service stopped"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("query service dropped reply"))
    }

    /// Single pair query (the historical `QueryHandle::query` shape):
    /// `None` for unknown ids, `Err` only on transport/service failure.
    pub fn query(&self, a: u64, b: u64) -> anyhow::Result<Option<f64>> {
        match self.call(Request::PairBatch(vec![(a, b)]))? {
            Response::PairBatch(mut ests) => Ok(ests.pop().flatten()),
            Response::Error(e) => anyhow::bail!("service error: {e}"),
            other => anyhow::bail!("unexpected response to pair query: {other:?}"),
        }
    }
}

/// Start `query-workers` serving threads over one shared batcher.
/// Called by [`Pipeline::spawn_query_service`]; see the module doc.
pub fn spawn(pipeline: Arc<Pipeline>) -> ApiHandle {
    let (tx, rx) = mpsc::channel::<ApiJob>();
    let cfg = pipeline.config();
    let workers = cfg.query_workers.max(1);
    let batcher = Arc::new(Mutex::new(Batcher::new(
        rx,
        cfg.batch_max,
        Duration::from_micros(cfg.batch_deadline_us),
    )));
    for _ in 0..workers {
        let pipeline = Arc::clone(&pipeline);
        let batcher = Arc::clone(&batcher);
        std::thread::spawn(move || loop {
            let drained = batcher.lock_recover().drain();
            match drained {
                Drained::Batch(batch, reason) => pipeline.serve_api_batch(batch, reason),
                Drained::Closed => break,
            }
        });
    }
    ApiHandle { tx }
}
