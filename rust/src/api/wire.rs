//! Hand-rolled, versioned, length-prefixed binary codec for the typed
//! query protocol — no serialization crates, symmetric encode/decode,
//! and the same corruption discipline as persistence v2: every declared
//! length is validated against hard caps and the bytes actually present
//! *before* any buffer is allocated, so a hostile or truncated frame
//! returns an error — never a panic, never an abort-scale allocation.
//!
//! ## Frame layout (little-endian)
//!
//! | field       | type     | notes                                  |
//! |-------------|----------|----------------------------------------|
//! | magic       | `b"LPA1"`|                                        |
//! | version     | `u8` = 1 | bumped on any layout change            |
//! | kind        | `u8`     | request 0x01–0x05, response 0x81–0xFF  |
//! | payload_len | `u32`    | bytes that follow, ≤ 64 MiB            |
//! | payload     | bytes    | kind-specific body (tables below)      |
//!
//! Request payloads:
//! * `Ping` (0x01), `Stats` (0x02) — empty.
//! * `PairBatch` (0x03) — `u32 count`, then `count × (u64 a, u64 b)`.
//! * `TopK` (0x04) — `u8 tag` (0 = stored id → `u64 id`; 1 = vector →
//!   `u32 dim`, `dim × f32`), then `u32 top`.
//! * `VectorDistance` (0x05) — `u32 dim`, `dim × f32`, `u32 ids`,
//!   `ids × u64`.
//!
//! Response payloads:
//! * `Pong` (0x81) — `u32 version`.
//! * `Stats` (0x82) — the fixed [`ApiStats`] field block (ten `u64`s,
//!   two `u32`s, two `u8` bools, in struct order).
//! * `PairBatch` (0x83) / `VectorDistance` (0x85) — `u32 count`, then
//!   `count × (u8 tag, f64 if tag = 1)` (`Option<f64>`; estimates move
//!   by IEEE bit pattern, so answers are bitwise-identical across the
//!   wire).
//! * `TopK` (0x84) — `u32 len`, then `len × (u64 id, f64 distance)`.
//! * `Error` (0xFF) — the message as raw UTF-8 (the whole payload).
//!
//! Frames are self-delimiting, so concatenated frames stream cleanly
//! through [`read_request`]/[`read_response`]; the one-shot
//! [`request_from_bytes`]/[`response_from_bytes`] parsers are strict
//! and reject trailing bytes (a concatenated buffer is a stream, not a
//! frame).

use std::io::{Read, Write};

use super::protocol::{ApiStats, Request, Response, TopKTarget};

pub const MAGIC: [u8; 4] = *b"LPA1";
pub const WIRE_VERSION: u8 = 1;
/// Hard cap on one frame's payload: large enough for any realistic
/// batch (a 64 MiB pair batch is 4M pairs), small enough that a corrupt
/// length can never drive an abort-scale allocation.
pub const MAX_FRAME_PAYLOAD: usize = 64 << 20;
const HEADER_LEN: usize = 10;

const K_PING: u8 = 0x01;
const K_STATS: u8 = 0x02;
const K_PAIR_BATCH: u8 = 0x03;
const K_TOP_K: u8 = 0x04;
const K_VECTOR_DISTANCE: u8 = 0x05;
const K_PONG: u8 = 0x81;
const K_STATS_REPLY: u8 = 0x82;
const K_PAIR_REPLY: u8 = 0x83;
const K_TOP_K_REPLY: u8 = 0x84;
const K_VECTOR_REPLY: u8 = 0x85;
const K_ERROR: u8 = 0xFF;

// ---- encode ---------------------------------------------------------

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_opt_f64s(out: &mut Vec<u8>, xs: &[Option<f64>]) {
    put_u32(out, xs.len() as u32);
    for x in xs {
        match x {
            Some(v) => {
                out.push(1);
                out.extend_from_slice(&v.to_le_bytes());
            }
            None => out.push(0),
        }
    }
}

fn frame(kind: u8, payload: Vec<u8>) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(&MAGIC);
    out.push(WIRE_VERSION);
    out.push(kind);
    put_u32(&mut out, payload.len() as u32);
    out.extend_from_slice(&payload);
    out
}

/// Encode one request as a complete frame.
pub fn encode_request(req: &Request) -> Vec<u8> {
    let (kind, mut payload) = (
        match req {
            Request::Ping => K_PING,
            Request::Stats => K_STATS,
            Request::PairBatch(_) => K_PAIR_BATCH,
            Request::TopK { .. } => K_TOP_K,
            Request::VectorDistance { .. } => K_VECTOR_DISTANCE,
        },
        Vec::new(),
    );
    match req {
        Request::Ping | Request::Stats => {}
        Request::PairBatch(pairs) => {
            put_u32(&mut payload, pairs.len() as u32);
            for &(a, b) in pairs {
                put_u64(&mut payload, a);
                put_u64(&mut payload, b);
            }
        }
        Request::TopK { target, top } => {
            match target {
                TopKTarget::StoredId(id) => {
                    payload.push(0);
                    put_u64(&mut payload, *id);
                }
                TopKTarget::Vector(v) => {
                    payload.push(1);
                    put_u32(&mut payload, v.len() as u32);
                    put_f32s(&mut payload, v);
                }
            }
            put_u32(&mut payload, *top);
        }
        Request::VectorDistance { vector, ids } => {
            put_u32(&mut payload, vector.len() as u32);
            put_f32s(&mut payload, vector);
            put_u32(&mut payload, ids.len() as u32);
            for &id in ids {
                put_u64(&mut payload, id);
            }
        }
    }
    frame(kind, payload)
}

/// Encode one response as a complete frame.
pub fn encode_response(resp: &Response) -> Vec<u8> {
    let mut payload = Vec::new();
    let kind = match resp {
        Response::Pong { version } => {
            put_u32(&mut payload, *version);
            K_PONG
        }
        Response::Stats(s) => {
            for v in [
                s.rows,
                s.map_rows,
                s.segments,
                s.epoch,
                s.rows_ingested,
                s.queries_served,
                s.batches_flushed,
                s.compactions,
                s.queries_in_flight,
                s.snapshot_age,
            ] {
                put_u64(&mut payload, v);
            }
            put_u32(&mut payload, s.p);
            put_u32(&mut payload, s.k);
            payload.push(s.two_sided as u8);
            payload.push(s.projection_known as u8);
            K_STATS_REPLY
        }
        Response::PairBatch(ests) => {
            put_opt_f64s(&mut payload, ests);
            K_PAIR_REPLY
        }
        Response::TopK(list) => {
            put_u32(&mut payload, list.len() as u32);
            for &(id, d) in list {
                put_u64(&mut payload, id);
                payload.extend_from_slice(&d.to_le_bytes());
            }
            K_TOP_K_REPLY
        }
        Response::VectorDistance(ests) => {
            put_opt_f64s(&mut payload, ests);
            K_VECTOR_REPLY
        }
        Response::Error(msg) => {
            // The whole payload is the message; a pathologically long
            // one is truncated at the frame cap rather than rejected.
            let bytes = msg.as_bytes();
            let take = bytes.len().min(MAX_FRAME_PAYLOAD);
            payload.extend(bytes.iter().take(take));
            K_ERROR
        }
    };
    frame(kind, payload)
}

// ---- decode ---------------------------------------------------------

/// Bounds-checked little-endian reader over a payload slice. Every
/// accessor errors on overrun instead of panicking.
struct Cur<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> Cur<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Cur { buf, off: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.off
    }

    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(n <= self.remaining(), "truncated frame payload");
        let end = self.off + n;
        let s = self
            .buf
            .get(self.off..end)
            .ok_or_else(|| anyhow::anyhow!("truncated frame payload"))?;
        self.off = end;
        Ok(s)
    }

    /// Fixed-width read: exactly `N` bytes as an array. The conversion
    /// is checked, not asserted — a `Cur` must never panic, whatever
    /// the input bytes.
    fn array<const N: usize>(&mut self) -> anyhow::Result<[u8; N]> {
        let arr: [u8; N] = self
            .take(N)?
            .try_into()
            .map_err(|_| anyhow::anyhow!("internal: take({N}) width mismatch"))?;
        Ok(arr)
    }

    fn u8(&mut self) -> anyhow::Result<u8> {
        let [b] = self.array()?;
        Ok(b)
    }

    fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.array()?))
    }

    fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.array()?))
    }

    fn f64(&mut self) -> anyhow::Result<f64> {
        Ok(f64::from_le_bytes(self.array()?))
    }

    /// A `u32` element count, validated against the bytes actually left
    /// in the payload (`elem_bytes` each) before any allocation.
    fn count(&mut self, elem_bytes: usize, what: &str) -> anyhow::Result<usize> {
        let n = self.u32()? as usize;
        anyhow::ensure!(
            n.checked_mul(elem_bytes.max(1))
                .is_some_and(|bytes| bytes <= self.remaining()),
            "declared {what} count {n} exceeds the frame payload"
        );
        Ok(n)
    }

    fn f32s(&mut self, n: usize) -> anyhow::Result<Vec<f32>> {
        let bytes = n
            .checked_mul(4)
            .ok_or_else(|| anyhow::anyhow!("vector length overflow"))?;
        let raw = self.take(bytes)?;
        let mut out = Vec::with_capacity(n);
        for c in raw.chunks_exact(4) {
            let arr: [u8; 4] = c
                .try_into()
                .map_err(|_| anyhow::anyhow!("internal: misaligned f32 chunk"))?;
            out.push(f32::from_le_bytes(arr));
        }
        Ok(out)
    }

    fn opt_f64s(&mut self) -> anyhow::Result<Vec<Option<f64>>> {
        // Each entry is ≥ 1 byte, so `count` bounds the allocation.
        let n = self.count(1, "estimate")?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(match self.u8()? {
                0 => None,
                1 => Some(self.f64()?),
                t => anyhow::bail!("bad option tag {t}"),
            });
        }
        Ok(out)
    }

    fn finish(&self, what: &str) -> anyhow::Result<()> {
        anyhow::ensure!(self.remaining() == 0, "trailing bytes in {what} payload");
        Ok(())
    }
}

/// Validate one 10-byte frame header (magic, version, length cap) and
/// return `(kind, payload_len)`. The single source of truth for both
/// the byte-slice and the stream decode paths.
fn parse_header(header: &[u8; HEADER_LEN]) -> anyhow::Result<(u8, usize)> {
    // Destructuring makes the 10-byte layout explicit and leaves no
    // indexing to get wrong (the pattern length is checked at compile
    // time against HEADER_LEN).
    let [m0, m1, m2, m3, version, kind, l0, l1, l2, l3] = *header;
    anyhow::ensure!([m0, m1, m2, m3] == MAGIC, "not a wire-protocol frame (bad magic)");
    anyhow::ensure!(
        version == WIRE_VERSION,
        "unsupported wire version {version} (this build speaks {WIRE_VERSION})"
    );
    let len = u32::from_le_bytes([l0, l1, l2, l3]) as usize;
    anyhow::ensure!(
        len <= MAX_FRAME_PAYLOAD,
        "implausible frame length {len} (cap {MAX_FRAME_PAYLOAD})"
    );
    Ok((kind, len))
}

/// Parse and validate one frame header + payload out of `buf`; returns
/// `(kind, payload, bytes consumed)`. Errors on short input, bad
/// magic/version, or a declared length that exceeds the cap or the
/// buffer.
fn frame_from_bytes(buf: &[u8]) -> anyhow::Result<(u8, &[u8], usize)> {
    let header: &[u8; HEADER_LEN] = buf
        .get(..HEADER_LEN)
        .and_then(|s| s.try_into().ok())
        .ok_or_else(|| anyhow::anyhow!("truncated frame header"))?;
    let (kind, len) = parse_header(header)?;
    let payload = buf
        .get(HEADER_LEN..HEADER_LEN + len)
        .ok_or_else(|| anyhow::anyhow!("truncated frame payload"))?;
    Ok((kind, payload, HEADER_LEN + len))
}

fn decode_request_payload(kind: u8, payload: &[u8]) -> anyhow::Result<Request> {
    let mut cur = Cur::new(payload);
    let req = match kind {
        K_PING => Request::Ping,
        K_STATS => Request::Stats,
        K_PAIR_BATCH => {
            let n = cur.count(16, "pair")?;
            let mut pairs = Vec::with_capacity(n);
            for _ in 0..n {
                pairs.push((cur.u64()?, cur.u64()?));
            }
            Request::PairBatch(pairs)
        }
        K_TOP_K => {
            let target = match cur.u8()? {
                0 => TopKTarget::StoredId(cur.u64()?),
                1 => {
                    let dim = cur.count(4, "vector entry")?;
                    TopKTarget::Vector(cur.f32s(dim)?)
                }
                t => anyhow::bail!("bad top-k target tag {t}"),
            };
            let top = cur.u32()?;
            Request::TopK { target, top }
        }
        K_VECTOR_DISTANCE => {
            let dim = cur.count(4, "vector entry")?;
            let vector = cur.f32s(dim)?;
            let n = cur.count(8, "id")?;
            let mut ids = Vec::with_capacity(n);
            for _ in 0..n {
                ids.push(cur.u64()?);
            }
            Request::VectorDistance { vector, ids }
        }
        other => anyhow::bail!("unknown request kind 0x{other:02x}"),
    };
    cur.finish("request")?;
    Ok(req)
}

fn decode_response_payload(kind: u8, payload: &[u8]) -> anyhow::Result<Response> {
    let mut cur = Cur::new(payload);
    let resp = match kind {
        K_PONG => Response::Pong { version: cur.u32()? },
        K_STATS_REPLY => {
            let mut s = ApiStats::default();
            for slot in [
                &mut s.rows,
                &mut s.map_rows,
                &mut s.segments,
                &mut s.epoch,
                &mut s.rows_ingested,
                &mut s.queries_served,
                &mut s.batches_flushed,
                &mut s.compactions,
                &mut s.queries_in_flight,
                &mut s.snapshot_age,
            ] {
                *slot = cur.u64()?;
            }
            s.p = cur.u32()?;
            s.k = cur.u32()?;
            s.two_sided = cur.u8()? != 0;
            s.projection_known = cur.u8()? != 0;
            Response::Stats(s)
        }
        K_PAIR_REPLY => Response::PairBatch(cur.opt_f64s()?),
        K_TOP_K_REPLY => {
            let n = cur.count(16, "neighbor")?;
            let mut list = Vec::with_capacity(n);
            for _ in 0..n {
                list.push((cur.u64()?, cur.f64()?));
            }
            Response::TopK(list)
        }
        K_VECTOR_REPLY => Response::VectorDistance(cur.opt_f64s()?),
        K_ERROR => {
            let msg = String::from_utf8(payload.to_vec())
                .map_err(|_| anyhow::anyhow!("error message is not UTF-8"))?;
            cur.take(payload.len())?; // the whole payload is consumed
            Response::Error(msg)
        }
        other => anyhow::bail!("unknown response kind 0x{other:02x}"),
    };
    cur.finish("response")?;
    Ok(resp)
}

/// Strict one-shot request parser: exactly one frame, no trailing
/// bytes (concatenated frames must go through [`read_request`]).
pub fn request_from_bytes(buf: &[u8]) -> anyhow::Result<Request> {
    let (kind, payload, used) = frame_from_bytes(buf)?;
    anyhow::ensure!(
        used == buf.len(),
        "trailing bytes after frame (concatenated frames must be read as a stream)"
    );
    decode_request_payload(kind, payload)
}

/// Strict one-shot response parser (see [`request_from_bytes`]).
pub fn response_from_bytes(buf: &[u8]) -> anyhow::Result<Response> {
    let (kind, payload, used) = frame_from_bytes(buf)?;
    anyhow::ensure!(
        used == buf.len(),
        "trailing bytes after frame (concatenated frames must be read as a stream)"
    );
    decode_response_payload(kind, payload)
}

/// Read one frame from a stream. `Ok(None)` on clean EOF at a frame
/// boundary; an EOF mid-frame is a truncation error. The payload
/// buffer is allocated only after the declared length passes the cap
/// check.
fn read_frame(r: &mut impl Read) -> anyhow::Result<Option<(u8, Vec<u8>)>> {
    let mut header = [0u8; HEADER_LEN];
    let mut got = 0usize;
    while got < HEADER_LEN {
        // pallas-lint: allow(no-index-untrusted) -- `got` is bounded below HEADER_LEN by the loop condition
        let n = r.read(&mut header[got..])?;
        if n == 0 {
            if got == 0 {
                return Ok(None); // clean end of stream
            }
            anyhow::bail!("truncated frame header (EOF mid-frame)");
        }
        got += n;
    }
    let (kind, len) = parse_header(&header)?;
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)
        .map_err(|e| anyhow::anyhow!("truncated frame payload: {e}"))?;
    Ok(Some((kind, payload)))
}

/// Read the next request from a stream (`Ok(None)` on clean EOF).
pub fn read_request(r: &mut impl Read) -> anyhow::Result<Option<Request>> {
    match read_frame(r)? {
        Some((kind, payload)) => decode_request_payload(kind, &payload).map(Some),
        None => Ok(None),
    }
}

/// Read the next response from a stream (`Ok(None)` on clean EOF).
pub fn read_response(r: &mut impl Read) -> anyhow::Result<Option<Response>> {
    match read_frame(r)? {
        Some((kind, payload)) => decode_response_payload(kind, &payload).map(Some),
        None => Ok(None),
    }
}

/// A frame that cannot legally cross the wire (its receiver would
/// reject the declared length) must fail on the *sender* with a clear
/// error, not as an opaque peer hangup.
fn ensure_frame_fits(bytes: &[u8]) -> anyhow::Result<()> {
    let payload = bytes.len().saturating_sub(HEADER_LEN);
    anyhow::ensure!(
        payload <= MAX_FRAME_PAYLOAD,
        "frame payload {payload} B exceeds the {MAX_FRAME_PAYLOAD} B cap — split the batch"
    );
    Ok(())
}

/// Write one request frame (errors on payloads past the frame cap).
pub fn write_request(w: &mut impl Write, req: &Request) -> anyhow::Result<()> {
    let bytes = encode_request(req);
    ensure_frame_fits(&bytes)?;
    w.write_all(&bytes)?;
    Ok(())
}

/// Write one response frame (errors on payloads past the frame cap).
pub fn write_response(w: &mut impl Write, resp: &Response) -> anyhow::Result<()> {
    let bytes = encode_response(resp);
    ensure_frame_fits(&bytes)?;
    w.write_all(&bytes)?;
    Ok(())
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn sample_requests() -> Vec<Request> {
        vec![
            Request::Ping,
            Request::Stats,
            Request::PairBatch(vec![]),
            Request::PairBatch(vec![(0, 1), (u64::MAX, 42), (7, 7)]),
            Request::TopK { target: TopKTarget::StoredId(99), top: 10 },
            Request::TopK {
                target: TopKTarget::Vector(vec![1.5, -0.25, f32::MIN_POSITIVE, 0.0]),
                top: 3,
            },
            Request::VectorDistance {
                vector: vec![0.5; 7],
                ids: vec![1, 2, 3, u64::MAX],
            },
            Request::VectorDistance { vector: vec![], ids: vec![] },
        ]
    }

    fn sample_responses() -> Vec<Response> {
        vec![
            Response::Pong { version: 1 },
            Response::Stats(ApiStats {
                rows: 10,
                map_rows: 3,
                segments: 2,
                epoch: 99,
                rows_ingested: 10,
                queries_served: 55,
                batches_flushed: 4,
                compactions: 1,
                queries_in_flight: 0,
                snapshot_age: 2,
                p: 4,
                k: 64,
                two_sided: true,
                projection_known: false,
            }),
            Response::PairBatch(vec![Some(1.25), None, Some(-0.0), Some(f64::MAX)]),
            Response::PairBatch(vec![]),
            Response::TopK(vec![(3, 0.5), (9, 1.75)]),
            Response::TopK(vec![]),
            Response::VectorDistance(vec![None, Some(2.5)]),
            Response::Error("unknown id 42".into()),
        ]
    }

    #[test]
    fn requests_roundtrip() {
        for req in sample_requests() {
            let bytes = encode_request(&req);
            assert_eq!(request_from_bytes(&bytes).unwrap(), req, "{req:?}");
            // Stream read agrees and consumes the full frame.
            let mut cursor = std::io::Cursor::new(bytes);
            assert_eq!(read_request(&mut cursor).unwrap(), Some(req));
            assert_eq!(read_request(&mut cursor).unwrap(), None);
        }
    }

    #[test]
    fn responses_roundtrip() {
        for resp in sample_responses() {
            let bytes = encode_response(&resp);
            assert_eq!(response_from_bytes(&bytes).unwrap(), resp, "{resp:?}");
            let mut cursor = std::io::Cursor::new(bytes);
            assert_eq!(read_response(&mut cursor).unwrap(), Some(resp));
            assert_eq!(read_response(&mut cursor).unwrap(), None);
        }
    }

    #[test]
    fn estimates_cross_the_wire_by_bit_pattern() {
        // NaN payloads can't use assert_eq; compare the re-encoded
        // bytes — bit-identical f64s must produce bit-identical frames.
        let nan = f64::from_bits(0x7ff8_dead_beef_0001);
        let resp = Response::PairBatch(vec![Some(nan), Some(-0.0), None]);
        let bytes = encode_response(&resp);
        let back = response_from_bytes(&bytes).unwrap();
        assert_eq!(encode_response(&back), bytes);
        let Response::PairBatch(ests) = back else { panic!("wrong kind") };
        assert_eq!(ests[0].unwrap().to_bits(), nan.to_bits());
        assert_eq!(ests[1].unwrap().to_bits(), (-0.0f64).to_bits());
    }

    #[test]
    fn every_truncation_errors_never_panics() {
        for req in sample_requests() {
            let bytes = encode_request(&req);
            for cut in 0..bytes.len() {
                assert!(
                    request_from_bytes(&bytes[..cut]).is_err(),
                    "{req:?} truncated at {cut} must error"
                );
                // Stream reads see either a clean EOF (cut 0) or an error.
                let mut cursor = std::io::Cursor::new(&bytes[..cut]);
                let got = read_request(&mut cursor);
                if cut == 0 {
                    assert!(matches!(got, Ok(None)));
                } else {
                    assert!(got.is_err(), "{req:?} stream-truncated at {cut}");
                }
            }
        }
        for resp in sample_responses() {
            let bytes = encode_response(&resp);
            for cut in 0..bytes.len() {
                assert!(response_from_bytes(&bytes[..cut]).is_err());
            }
        }
    }

    #[test]
    fn bad_magic_version_and_kind_are_errors() {
        let good = encode_request(&Request::Ping);
        let mut bad = good.clone();
        bad[0] = b'X';
        assert!(request_from_bytes(&bad).unwrap_err().to_string().contains("magic"));
        let mut bad = good.clone();
        bad[4] = 9;
        assert!(request_from_bytes(&bad)
            .unwrap_err()
            .to_string()
            .contains("unsupported wire version"));
        let mut bad = good.clone();
        bad[5] = 0x77;
        assert!(request_from_bytes(&bad).unwrap_err().to_string().contains("kind"));
        // A request kind is not a valid response kind and vice versa.
        assert!(response_from_bytes(&good).is_err());
        assert!(request_from_bytes(&encode_response(&Response::Pong { version: 1 })).is_err());
    }

    #[test]
    fn oversized_declared_lengths_error_before_allocation() {
        // Frame length far past the cap: must be rejected from the
        // 10-byte header alone (the 4 GiB payload is never allocated).
        let mut hdr = Vec::new();
        hdr.extend_from_slice(&MAGIC);
        hdr.push(WIRE_VERSION);
        hdr.push(0x03);
        hdr.extend_from_slice(&u32::MAX.to_le_bytes());
        let err = request_from_bytes(&hdr).unwrap_err().to_string();
        assert!(err.contains("implausible frame length"), "{err}");
        let mut cursor = std::io::Cursor::new(hdr);
        assert!(read_request(&mut cursor).is_err());

        // Inner count far past the payload: a PairBatch declaring 2³⁰
        // pairs inside a 12-byte payload must error without allocating
        // the 16 GiB vector.
        let mut payload = Vec::new();
        put_u32(&mut payload, 1 << 30);
        payload.extend_from_slice(&[0u8; 8]);
        let framed = frame(0x03, payload);
        let err = request_from_bytes(&framed).unwrap_err().to_string();
        assert!(err.contains("exceeds the frame payload"), "{err}");

        // Same discipline on the vector dim and the option-list count.
        let mut payload = Vec::new();
        put_u32(&mut payload, u32::MAX);
        let framed = frame(0x05, payload);
        assert!(request_from_bytes(&framed).is_err());
        let mut payload = Vec::new();
        put_u32(&mut payload, u32::MAX);
        let framed = frame(0x83, payload);
        assert!(response_from_bytes(&framed).is_err());
    }

    #[test]
    fn concatenated_frames_stream_but_do_not_parse_as_one() {
        let a = Request::PairBatch(vec![(1, 2)]);
        let b = Request::TopK { target: TopKTarget::StoredId(5), top: 2 };
        let mut joined = encode_request(&a);
        joined.extend_from_slice(&encode_request(&b));
        // One-shot parse of a concatenated buffer is an error...
        let err = request_from_bytes(&joined).unwrap_err().to_string();
        assert!(err.contains("trailing bytes"), "{err}");
        // ...but the stream reader hands the frames out in order.
        let mut cursor = std::io::Cursor::new(joined);
        assert_eq!(read_request(&mut cursor).unwrap(), Some(a));
        assert_eq!(read_request(&mut cursor).unwrap(), Some(b));
        assert_eq!(read_request(&mut cursor).unwrap(), None);
    }

    #[test]
    fn trailing_garbage_inside_payload_is_rejected() {
        // A well-formed body followed by junk *inside* the declared
        // payload must error (symmetry: every encoder output decodes,
        // nothing else does).
        let mut payload = Vec::new();
        put_u32(&mut payload, 1);
        put_u64(&mut payload, 3);
        put_u64(&mut payload, 4);
        payload.push(0xAB);
        let framed = frame(0x03, payload);
        let err = request_from_bytes(&framed).unwrap_err().to_string();
        assert!(err.contains("trailing bytes in request payload"), "{err}");
    }

    #[test]
    fn oversized_outgoing_frames_fail_on_the_sender() {
        // 4M+ pairs push the payload past the 64 MiB cap: the writer
        // must error clearly instead of shipping a frame every receiver
        // would reject (an opaque hangup from the client's viewpoint).
        let too_big = Request::PairBatch(vec![(0, 0); 4_194_304]);
        let mut sink = Vec::new();
        let err = write_request(&mut sink, &too_big).unwrap_err().to_string();
        assert!(err.contains("exceeds the"), "{err}");
        assert!(sink.is_empty(), "nothing may be written on failure");
        // The largest batch under the cap still goes through.
        let fits = Request::PairBatch(vec![(0, 0); 4_194_291]);
        write_request(&mut sink, &fits).unwrap();
        let mut cursor = std::io::Cursor::new(sink);
        assert_eq!(read_request(&mut cursor).unwrap(), Some(fits));
    }

    #[test]
    fn error_response_requires_utf8() {
        let framed = frame(K_ERROR, vec![0xFF, 0xFE, 0x80]);
        assert!(response_from_bytes(&framed).unwrap_err().to_string().contains("UTF-8"));
    }

    #[test]
    fn bad_option_and_target_tags_error() {
        let mut payload = Vec::new();
        put_u32(&mut payload, 1);
        payload.push(7); // neither 0 nor 1
        assert!(response_from_bytes(&frame(K_PAIR_REPLY, payload)).is_err());
        let mut payload = Vec::new();
        payload.push(9); // bad top-k target tag
        put_u32(&mut payload, 1);
        assert!(request_from_bytes(&frame(K_TOP_K, payload)).is_err());
    }
}
