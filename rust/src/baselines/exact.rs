//! Exact pairwise l_p^p computation — the O(n²D) baseline of the paper's
//! headline cost comparison (E7), multi-threaded over row blocks.

use crate::data::RowMatrix;

/// Exact l_p^p distance between two f32 rows, accumulated in f64.
#[inline]
pub fn distance_f32(x: &[f32], y: &[f32], p: usize) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    debug_assert!(p % 2 == 0);
    let half = (p / 2) as i32;
    let mut acc = 0.0f64;
    for (&a, &b) in x.iter().zip(y) {
        let diff = (a - b) as f64;
        acc += (diff * diff).powi(half);
    }
    acc
}

/// All pairwise distances of `m` (upper triangle, row-major condensed:
/// entry for (i, j), i < j, at index `i*n - i*(i+1)/2 + (j - i - 1)`).
pub fn pairwise_condensed(m: &RowMatrix, p: usize, threads: usize) -> Vec<f64> {
    let n = m.n();
    let len = n * (n - 1) / 2;
    let mut out = vec![0.0f64; len];
    if n < 2 {
        return out;
    }
    let threads = threads.max(1).min(n);
    // Partition rows round-robin so thread loads balance despite the
    // triangular row lengths.
    std::thread::scope(|scope| {
        for (t, chunk) in partition_condensed(n, threads).into_iter().enumerate() {
            let out_ptr = SendPtr(out.as_mut_ptr());
            scope.spawn(move || {
                let out_ptr = out_ptr; // move the Send wrapper in
                for i in chunk {
                    let base = condensed_base(n, i);
                    for j in (i + 1)..n {
                        let d = distance_f32(m.row(i), m.row(j), p);
                        // SAFETY: rows are disjoint across threads, so the
                        // condensed ranges [base, base+n-i-1) never overlap.
                        // pallas-lint: allow(unsafe-contract) -- offline baseline writer, not a serving kernel; per-thread ranges are disjoint by construction
                        unsafe { *out_ptr.0.add(base + j - i - 1) = d };
                    }
                }
                let _ = t;
            });
        }
    });
    out
}

/// Condensed index of the first pair of row `i`.
#[inline]
pub fn condensed_base(n: usize, i: usize) -> usize {
    // Σ_{r<i} (n-1-r) = i·n − i(i+1)/2 (scipy's squareform convention).
    i * n - i * (i + 1) / 2
}

/// Condensed index of pair (i, j), i < j.
#[inline]
pub fn condensed_index(n: usize, i: usize, j: usize) -> usize {
    debug_assert!(i < j && j < n);
    condensed_base(n, i) + j - i - 1
}

/// Round-robin row partition balancing triangular work.
fn partition_condensed(n: usize, threads: usize) -> Vec<Vec<usize>> {
    let mut parts = vec![Vec::new(); threads];
    for i in 0..n {
        // Pair row i (long) with row n-1-i (short) by folding.
        parts[i % threads].push(i);
    }
    parts
}

struct SendPtr(*mut f64);
// SAFETY: SendPtr only ferries the base pointer of a caller-owned `out`
// buffer into scoped threads that write disjoint condensed ranges; the
// buffer outlives the scope and no element is aliased by two threads.
unsafe impl Send for SendPtr {}

/// Dense n×n2 exact distance matrix between two row sets (E7's block op).
pub fn block(x: &RowMatrix, y: &RowMatrix, p: usize) -> Vec<f64> {
    assert_eq!(x.d(), y.d());
    let mut out = Vec::with_capacity(x.n() * y.n());
    for i in 0..x.n() {
        for j in 0..y.n() {
            out.push(distance_f32(x.row(i), y.row(j), p));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::decompose::exact_distance;
    use crate::data::{gen, DataDist};

    #[test]
    fn condensed_index_is_bijective() {
        let n = 9;
        let mut seen = vec![false; n * (n - 1) / 2];
        for i in 0..n {
            for j in (i + 1)..n {
                let idx = condensed_index(n, i, j);
                assert!(!seen[idx], "collision at ({i},{j})");
                seen[idx] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn matches_f64_reference() {
        let m = gen::generate(DataDist::Gaussian, 6, 33, 5);
        let d = pairwise_condensed(&m, 4, 3);
        for i in 0..m.n() {
            for j in (i + 1)..m.n() {
                let want = exact_distance(&m.row_f64(i), &m.row_f64(j), 4);
                let got = d[condensed_index(m.n(), i, j)];
                assert!((got - want).abs() < 1e-3 * (1.0 + want), "{got} vs {want}");
            }
        }
    }

    #[test]
    fn thread_count_invariant() {
        let m = gen::generate(DataDist::Uniform01, 17, 24, 9);
        let a = pairwise_condensed(&m, 6, 1);
        let b = pairwise_condensed(&m, 6, 7);
        assert_eq!(a, b);
    }

    #[test]
    fn block_matches_condensed() {
        let m = gen::generate(DataDist::Uniform01, 5, 16, 2);
        let full = block(&m, &m, 4);
        let cond = pairwise_condensed(&m, 4, 2);
        for i in 0..5 {
            assert_eq!(full[i * 5 + i], 0.0);
            for j in (i + 1)..5 {
                let got = full[i * 5 + j];
                let want = cond[condensed_index(5, i, j)];
                assert!((got - want).abs() < 1e-9);
            }
        }
    }
}
