//! Comparison baselines the paper's evaluation (and motivation) needs:
//!
//! * [`exact`] — the O(n²D) exact computation the sketches beat (E7).
//! * [`stable`] — symmetric α-stable random projections (prior art;
//!   structurally limited to p ≤ 2, the paper's whole motivation — E11).
//! * [`sampling`] — coordinate sampling, the naive data-reduction
//!   alternative that collapses on heavy-tailed data.

pub mod exact;
pub mod sampling;
pub mod stable;
