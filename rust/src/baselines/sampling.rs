//! Coordinate-sampling baseline: estimate d_(p) from k uniformly sampled
//! coordinates, d̂ = (D/k) Σ_{i∈S} |x_i − y_i|^p.
//!
//! The "obvious" alternative data-reduction scheme the paper's sketches
//! compete with. Unbiased, same O(k) storage per row, but its variance
//! scales with the *population variance of the coordinate contributions*
//! — catastrophically bad on sparse / heavy-tailed data where a few
//! coordinates carry most of the distance (exactly the TF-vector regime
//! the paper motivates). E8/E11 plot this contrast.

use crate::util::rng::Rng;

/// A coordinate sample of one row: the k sampled values (shared index
/// set per seed, so two rows sampled with the same seed are comparable).
#[derive(Clone, Debug)]
pub struct CoordSample {
    pub d: usize,
    pub values: Vec<f32>,
}

/// Sampler: picks k coordinate indices without replacement from [0, D).
#[derive(Clone, Debug)]
pub struct CoordSampler {
    pub seed: u64,
    pub k: usize,
}

impl CoordSampler {
    pub fn new(seed: u64, k: usize) -> Self {
        CoordSampler { seed, k }
    }

    /// The shared index set for dimension `d` (Floyd's algorithm —
    /// uniform without replacement, O(k) memory).
    pub fn indices(&self, d: usize) -> Vec<usize> {
        let k = self.k.min(d);
        let mut rng = Rng::new(self.seed ^ 0x5A3E_11DE);
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (d - k)..d {
            let t = rng.next_range(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }

    pub fn sample(&self, row: &[f32]) -> CoordSample {
        let values = self.indices(row.len()).iter().map(|&i| row[i]).collect();
        CoordSample { d: row.len(), values }
    }
}

/// Unbiased estimate of d_(p) from two aligned coordinate samples.
pub fn estimate(x: &CoordSample, y: &CoordSample, p: usize) -> f64 {
    assert_eq!(x.d, y.d);
    assert_eq!(x.values.len(), y.values.len());
    let k = x.values.len();
    let half = (p / 2) as i32;
    let mut acc = 0.0f64;
    for (&a, &b) in x.values.iter().zip(&y.values) {
        let diff = (a - b) as f64;
        acc += (diff * diff).powi(half);
    }
    acc * x.d as f64 / k as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::decompose::exact_distance;
    use crate::util::stats::Welford;

    #[test]
    fn indices_are_unique_and_in_range() {
        for seed in 0..20 {
            let s = CoordSampler::new(seed, 17);
            let idx = s.indices(40);
            assert_eq!(idx.len(), 17);
            let set: std::collections::HashSet<_> = idx.iter().collect();
            assert_eq!(set.len(), 17);
            assert!(idx.iter().all(|&i| i < 40));
        }
    }

    #[test]
    fn k_equals_d_is_exact() {
        let x: Vec<f32> = (0..24).map(|i| (i as f32 * 0.3).sin()).collect();
        let y: Vec<f32> = (0..24).map(|i| (i as f32 * 0.7).cos()).collect();
        let s = CoordSampler::new(3, 24);
        let got = estimate(&s.sample(&x), &s.sample(&y), 4);
        let x64: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        let y64: Vec<f64> = y.iter().map(|&v| v as f64).collect();
        let want = exact_distance(&x64, &y64, 4);
        assert!((got - want).abs() < 1e-3 * (1.0 + want));
    }

    #[test]
    fn unbiased_over_seeds() {
        let x: Vec<f32> = (0..64).map(|i| 0.3 + (i as f32 * 0.13).sin().abs()).collect();
        let y: Vec<f32> = (0..64).map(|i| 0.3 + (i as f32 * 0.29).cos().abs()).collect();
        let x64: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        let y64: Vec<f64> = y.iter().map(|&v| v as f64).collect();
        let exact = exact_distance(&x64, &y64, 4);
        let mut w = Welford::new();
        for seed in 0..3000 {
            let s = CoordSampler::new(seed, 16);
            w.push(estimate(&s.sample(&x), &s.sample(&y), 4));
        }
        assert!(w.z_against(exact).abs() < 4.5, "mean={} exact={exact}", w.mean());
    }

    #[test]
    fn heavy_tail_variance_blows_up() {
        // One dominant coordinate: sampling misses it with prob 1−k/D,
        // so the relative variance is huge vs a dense difference vector.
        let d = 256;
        let mut x = vec![0.0f32; d];
        x[7] = 10.0; // single spike carries ~all of the distance
        let y = vec![0.0f32; d];
        let mut w = Welford::new();
        for seed in 0..2000 {
            let s = CoordSampler::new(seed, 16);
            w.push(estimate(&s.sample(&x), &s.sample(&y), 4));
        }
        let exact = 10f64.powi(4);
        let rel_sd = w.sample_variance().sqrt() / exact;
        assert!(rel_sd > 2.0, "expected catastrophic rel sd, got {rel_sd}");
    }
}
