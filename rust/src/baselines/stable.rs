//! Symmetric α-stable random projections — the prior art the paper's
//! introduction contrasts against (Indyk 2000/2006; Li 2008).
//!
//! For 0 < α ≤ 2, projecting rows with i.i.d. α-stable entries gives
//! samples whose scale parameter is the l_α distance; median-type or
//! geometric-mean estimators recover it. The *point of E11* is the other
//! direction: stable distributions do not exist for α > 2, so running
//! this machinery "at p = 4" (the closest one can do is α = 2) estimates
//! the l_2 distance, not l_4 — the estimator is structurally unable to
//! converge to d_(4) no matter how large k grows. That failure is the
//! paper's motivation for the even-p decomposition approach.
//!
//! Sampler: Chambers–Mallows–Stuck (CMS), the standard exact method.

use crate::util::rng::Rng;
use std::f64::consts::PI;

/// Draw one standard symmetric α-stable variate (β = 0) via CMS.
pub fn sample_stable(alpha: f64, rng: &mut Rng) -> f64 {
    assert!(alpha > 0.0 && alpha <= 2.0, "stable requires 0 < α ≤ 2");
    let u = PI * (rng.next_f64_open() - 0.5); // U(−π/2, π/2)
    let w = -rng.next_f64_open().ln(); // Exp(1)
    if (alpha - 1.0).abs() < 1e-12 {
        // Cauchy case (the general formula hits 0/0 at α = 1).
        return u.tan();
    }
    let t = (alpha * u).sin() / u.cos().powf(1.0 / alpha);
    let s = ((1.0 - alpha) * u).cos() / w;
    t * s.powf((1.0 - alpha) / alpha)
}

/// A stable sketch of one row: k projections with i.i.d. S(α,0) entries.
#[derive(Clone, Debug)]
pub struct StableSketch {
    pub alpha: f64,
    pub data: Vec<f64>,
}

/// Stable-projection sketcher (counter-based entries, seeded like
/// [`crate::projection::ProjectionSpec`]).
#[derive(Clone, Debug)]
pub struct StableSketcher {
    pub seed: u64,
    pub k: usize,
    pub alpha: f64,
}

impl StableSketcher {
    pub fn new(seed: u64, k: usize, alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 2.0);
        StableSketcher { seed, k, alpha }
    }

    /// Project one row: out[j] = Σ_i x_i · s_ij, s_ij i.i.d. S(α,0).
    pub fn sketch(&self, row: &[f32]) -> StableSketch {
        let mut data = vec![0.0f64; self.k];
        for (i, &x) in row.iter().enumerate() {
            if x == 0.0 {
                continue;
            }
            // One deterministic RNG stream per (row-index, column) pair.
            let mut rng = Rng::new(
                crate::util::rng::counter_hash(self.seed, i as u64, 0x57AB1E),
            );
            for slot in data.iter_mut() {
                *slot += x as f64 * sample_stable(self.alpha, &mut rng);
            }
        }
        StableSketch { alpha: self.alpha, data }
    }
}

/// Geometric-mean estimator of the l_α distance^α between two sketched
/// rows (Li 2008, SODA): d̂_α = C(α,k) · Π |u_j − v_j|^{α/k}.
///
/// The bias-correction constant uses E|S(α,0)|^{α/k}; we compute it by
/// seeded Monte-Carlo once per (α, k) — exact closed forms involve
/// gamma-function ratios, and MC at 200k draws is accurate to ~0.2%,
/// well inside the estimator's own noise at practical k.
pub fn geometric_mean_estimate(u: &StableSketch, v: &StableSketch) -> f64 {
    assert_eq!(u.data.len(), v.data.len());
    assert_eq!(u.alpha, v.alpha);
    let k = u.data.len();
    let alpha = u.alpha;
    let exp = alpha / k as f64;
    let mut log_prod = 0.0f64;
    for (a, b) in u.data.iter().zip(&v.data) {
        let diff = (a - b).abs().max(1e-300);
        log_prod += exp * diff.ln();
    }
    log_prod.exp() / gm_constant(alpha, k)
}

/// E[Π |S_j|^{α/k}] = (E|S|^{α/k})^k for i.i.d. S_j ~ S(α,0) — the
/// normalizer making the geometric-mean estimator unbiased on the scale.
fn gm_constant(alpha: f64, k: usize) -> f64 {
    use std::collections::HashMap;
    use std::sync::Mutex;
    static CACHE: Mutex<Option<HashMap<(u64, usize), f64>>> = Mutex::new(None);
    let key = (alpha.to_bits(), k);
    if let Some(v) = CACHE.lock().unwrap().get_or_insert_with(HashMap::new).get(&key) {
        return *v;
    }
    let c = gm_constant_uncached(alpha, k);
    CACHE.lock().unwrap().get_or_insert_with(HashMap::new).insert(key, c);
    c
}

/// Deterministic seeded MC for E[Π|S_j|^{α/k}]; exact closed forms
/// involve gamma-function ratios that add no accuracy at this tolerance.
fn gm_constant_uncached(alpha: f64, k: usize) -> f64 {
    let reps = 200_000;
    let exp = alpha / k as f64;
    let mut rng = Rng::new(0x6E0_CAFE ^ alpha.to_bits().rotate_left(17) ^ k as u64);
    let mut mean = 0.0f64;
    for _ in 0..reps {
        let s = sample_stable(alpha, &mut rng).abs().max(1e-300);
        mean += s.powf(exp);
    }
    mean /= reps as f64;
    mean.powi(k as i32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Welford;

    #[test]
    fn cauchy_samples_have_cauchy_quartiles() {
        // For S(1,0) = standard Cauchy, the quartiles are ±1.
        let mut rng = Rng::new(77);
        let mut xs: Vec<f64> = (0..20_000).map(|_| sample_stable(1.0, &mut rng)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q1 = xs[xs.len() / 4];
        let q3 = xs[3 * xs.len() / 4];
        assert!((q1 + 1.0).abs() < 0.05, "q1={q1}");
        assert!((q3 - 1.0).abs() < 0.05, "q3={q3}");
    }

    #[test]
    fn alpha2_samples_are_gaussian_var2() {
        // S(2,0) has variance 2.
        let mut rng = Rng::new(78);
        let mut w = Welford::new();
        for _ in 0..40_000 {
            w.push(sample_stable(2.0, &mut rng));
        }
        assert!(w.mean().abs() < 0.03, "mean={}", w.mean());
        assert!((w.sample_variance() - 2.0).abs() < 0.08, "var={}", w.sample_variance());
    }

    #[test]
    fn gm_estimator_recovers_l1_distance() {
        // α = 1: estimates Σ|x−y| (l_1). MC over seeds.
        let x: Vec<f32> = (0..32).map(|i| (i as f32 * 0.17).sin()).collect();
        let y: Vec<f32> = (0..32).map(|i| (i as f32 * 0.11).cos()).collect();
        let exact: f64 = x.iter().zip(&y).map(|(&a, &b)| ((a - b) as f64).abs()).sum();
        let mut w = Welford::new();
        for seed in 0..400 {
            let sk = StableSketcher::new(seed, 64, 1.0);
            let (u, v) = (sk.sketch(&x), sk.sketch(&y));
            w.push(geometric_mean_estimate(&u, &v));
        }
        let rel = (w.mean() - exact).abs() / exact;
        assert!(rel < 0.05, "mean={} exact={exact} rel={rel}", w.mean());
    }

    #[test]
    fn fails_for_p4_structurally() {
        // The E11 claim: α is capped at 2, so the "best effort" stable
        // estimate converges to the l_2 distance — bounded away from the
        // l_4 distance regardless of k.
        let x: Vec<f32> = (0..48).map(|i| 0.5 + 0.4 * (i as f32 * 0.23).sin()).collect();
        let y: Vec<f32> = (0..48).map(|i| 0.5 + 0.4 * (i as f32 * 0.31).cos()).collect();
        let l2: f64 = x.iter().zip(&y).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum();
        let l4: f64 = x.iter().zip(&y).map(|(&a, &b)| ((a - b) as f64).powi(4)).sum();
        let mut w = Welford::new();
        for seed in 0..300 {
            let sk = StableSketcher::new(seed, 128, 2.0);
            let (u, v) = (sk.sketch(&x), sk.sketch(&y));
            w.push(geometric_mean_estimate(&u, &v));
        }
        // Converges to l_2 …
        assert!((w.mean() - l2).abs() / l2 < 0.1, "mean={} l2={l2}", w.mean());
        // … which is far from l_4 (the distances differ by >3× here).
        assert!((w.mean() - l4).abs() / l4 > 1.0, "mean={} l4={l4}", w.mean());
    }
}
