//! In-repo micro-benchmark harness (criterion is not vendored; DESIGN.md
//! §3): warmup, adaptive iteration count, robust summary statistics, and
//! an aligned table printer shared by every `benches/` target.
//!
//! Methodology mirrors criterion's core loop: run the closure until a
//! target measurement time is accumulated (after a warmup phase), then
//! report mean / p50 / p95 over per-iteration times.

use std::time::{Duration, Instant};

use crate::util::stats::percentile;

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    /// Optional throughput denominator (elements per iteration).
    pub elements: Option<u64>,
}

impl Measurement {
    /// Elements per second, if `elements` was set.
    pub fn throughput(&self) -> Option<f64> {
        self.elements
            .map(|e| e as f64 / self.mean.as_secs_f64())
    }
}

/// Harness configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_iters: u64,
    pub max_iters: u64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            min_iters: 5,
            max_iters: 1_000_000,
        }
    }
}

impl BenchConfig {
    /// Faster settings for CI-ish runs (env `LPSKETCH_BENCH_FAST=1`).
    pub fn from_env() -> Self {
        if std::env::var("LPSKETCH_BENCH_FAST").as_deref() == Ok("1") {
            BenchConfig {
                warmup: Duration::from_millis(20),
                measure: Duration::from_millis(100),
                min_iters: 3,
                max_iters: 10_000,
            }
        } else {
            BenchConfig::default()
        }
    }
}

/// Time `f` under `cfg`; `elements` feeds the throughput column.
pub fn bench_with<F: FnMut()>(
    cfg: &BenchConfig,
    name: &str,
    elements: Option<u64>,
    mut f: F,
) -> Measurement {
    // Warmup until the budget elapses (at least one call).
    let w0 = Instant::now();
    let mut warm_iters = 0u64;
    while warm_iters == 0 || w0.elapsed() < cfg.warmup {
        f();
        warm_iters += 1;
        if warm_iters >= cfg.max_iters {
            break;
        }
    }
    // Measure.
    let mut times = Vec::new();
    let m0 = Instant::now();
    while (times.len() as u64) < cfg.min_iters
        || (m0.elapsed() < cfg.measure && (times.len() as u64) < cfg.max_iters)
    {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64());
    }
    let mut sorted = times.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    Measurement {
        name: name.to_string(),
        iters: times.len() as u64,
        mean: Duration::from_secs_f64(mean),
        p50: Duration::from_secs_f64(percentile(&sorted, 0.5)),
        p95: Duration::from_secs_f64(percentile(&sorted, 0.95)),
        elements,
    }
}

/// Convenience: default config from env.
pub fn bench<F: FnMut()>(name: &str, elements: Option<u64>, f: F) -> Measurement {
    bench_with(&BenchConfig::from_env(), name, elements, f)
}

/// Fixed-width table printer: pass header + rows of equal arity.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "table arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Duration → human string (µs/ms/s picked by magnitude).
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}

/// f64 → short scientific-ish string for table cells.
pub fn fmt_num(x: f64) -> String {
    if x == 0.0 {
        return "0".to_string();
    }
    let a = x.abs();
    if (1e-3..1e6).contains(&a) {
        format!("{x:.4}")
    } else {
        format!("{x:.3e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_orders_percentiles() {
        let cfg = BenchConfig {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(10),
            min_iters: 5,
            max_iters: 10_000,
        };
        let m = bench_with(&cfg, "noop", Some(10), || {
            std::hint::black_box(1 + 1);
        });
        assert!(m.iters >= 5);
        assert!(m.p50 <= m.p95);
        assert!(m.throughput().unwrap() > 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["long-name".into(), "22".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[1].chars().all(|c| c == '-'), true);
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    fn formatting_helpers() {
        assert!(fmt_duration(Duration::from_micros(5)).ends_with("us"));
        assert!(fmt_duration(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_duration(Duration::from_secs(5)).ends_with('s'));
        assert_eq!(fmt_num(0.0), "0");
        assert!(fmt_num(1e9).contains('e'));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_rejects_wrong_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
