//! Configuration system: one struct drives the CLI, the pipeline, the
//! examples, and the experiment harness.
//!
//! Sources, later wins: built-in defaults → config file (`key = value`
//! lines, `#` comments) → command-line overrides (`--key value` /
//! `--key=value`). No external parser crates (none are vendored) — the
//! format is a flat key list, documented per field below.

use std::path::{Path, PathBuf};

use crate::core::quant::PanelQuant;
use crate::data::DataDist;
use crate::projection::{ProjectionDist, Strategy};

/// Full pipeline / estimator configuration.
#[derive(Clone, Debug)]
pub struct Config {
    /// Even p ≥ 4 — the l_p distance order.
    pub p: usize,
    /// Sketch width k ≪ D.
    pub k: usize,
    /// Projection strategy (basic | alternative), paper §2.1/§2.2.
    pub strategy: Strategy,
    /// Projection distribution: normal | uniform | threepoint:<s>.
    pub dist: ProjectionDist,
    /// Root seed for projections + data generation.
    pub seed: u64,
    /// Rows per ingest block (the sketch-artifact batch size).
    pub block_rows: usize,
    /// Number of sketch worker threads.
    pub workers: usize,
    /// Bounded-queue depth per stage (backpressure knob).
    pub queue_depth: usize,
    /// Query batcher: max pairs per batch.
    pub batch_max: usize,
    /// Query batcher: deadline in microseconds before a partial batch is
    /// flushed.
    pub batch_deadline_us: u64,
    /// Query-service worker threads: how many batches can be *served*
    /// concurrently (each from its own store snapshot; draining the
    /// batcher itself is serialized).
    pub query_workers: usize,
    /// Use the margin MLE (Lemma 4) on the query path.
    pub use_mle: bool,
    /// Sketch ingest blocks through the register-tiled GEMM kernel into
    /// columnar store segments (default). `false` keeps the per-row
    /// reference path — the baseline the GEMM path is benchmarked and
    /// equivalence-tested against.
    pub ingest_gemm: bool,
    /// Panel storage encoding applied to columnar segments at the store
    /// boundary: `none` (f32, the bitwise reference), `f16`, `bf16`, or
    /// `i8` (per-(order, side) scale). Quantized decode is value-exact,
    /// so every downstream layer — zones, estimates, persistence —
    /// agrees bitwise on the decoded values; the codec's only error is
    /// the one round-trip at ingest (bounded, see `core/quant.rs`).
    /// Moments and per-row map entries always stay full precision.
    pub panel_quant: PanelQuant,
    /// Segment compaction: merge adjacent columnar segments smaller than
    /// this after each ingest (incrementally — only the run the ingest
    /// appended) and on rebalance. `0` disables the pass. Compaction is
    /// estimate-invariant (panels move by contiguous copy) and
    /// copy-on-write (live snapshots keep serving the pre-merge
    /// blocks), so it defaults on: small `block_rows` deployments get
    /// bounded segment counts for free.
    pub compact_min_rows: usize,
    /// Segment compaction: merged segments grow to at most this many
    /// rows.
    pub compact_target_rows: usize,
    /// Background compactor wake interval (durable mode), milliseconds.
    pub compactor_interval_ms: u64,
    /// Durable I/O: retries per seal pass before declaring the data
    /// directory degraded (backoff doubles from 10ms).
    pub io_retry_max: u32,
    /// Prefer the PJRT engine when artifacts match; fall back to pure
    /// rust otherwise.
    pub use_pjrt: bool,
    /// Artifacts directory (manifest + *.hlo.txt).
    pub artifacts_dir: PathBuf,
    /// Synthetic data distribution for generated workloads.
    pub data_dist: DataDist,
    /// Generated workload shape.
    pub n: usize,
    pub d: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            p: 4,
            k: 128,
            strategy: Strategy::Basic,
            dist: ProjectionDist::Normal,
            seed: 42,
            block_rows: 64,
            workers: std::thread::available_parallelism().map_or(1, |n| n.get()),
            queue_depth: 8,
            batch_max: 4096,
            batch_deadline_us: 200,
            query_workers: 2,
            use_mle: false,
            ingest_gemm: true,
            panel_quant: PanelQuant::None,
            compact_min_rows: 1024,
            compact_target_rows: 8192,
            compactor_interval_ms: 1000,
            io_retry_max: 4,
            use_pjrt: false,
            artifacts_dir: PathBuf::from("artifacts"),
            data_dist: DataDist::ZipfTf { exponent: 1.1, density: 0.1 },
            n: 1024,
            d: 1024,
        }
    }
}

impl Config {
    /// Apply one `key`, `value` pair. Unknown keys are an error so typos
    /// fail loudly.
    pub fn set(&mut self, key: &str, value: &str) -> anyhow::Result<()> {
        match key {
            "p" => {
                self.p = value.parse()?;
                anyhow::ensure!(self.p >= 4 && self.p % 2 == 0, "p must be even and >= 4");
            }
            "k" => self.k = parse_nonzero(key, value)?,
            "strategy" => self.strategy = Strategy::parse(value)?,
            "dist" => self.dist = ProjectionDist::parse(value)?,
            "seed" => self.seed = value.parse()?,
            "block-rows" | "block_rows" => self.block_rows = parse_nonzero(key, value)?,
            "workers" => self.workers = parse_nonzero(key, value)?,
            "queue-depth" | "queue_depth" => self.queue_depth = parse_nonzero(key, value)?,
            "batch-max" | "batch_max" => self.batch_max = parse_nonzero(key, value)?,
            "batch-deadline-us" | "batch_deadline_us" => self.batch_deadline_us = value.parse()?,
            "query-workers" | "query_workers" => self.query_workers = parse_nonzero(key, value)?,
            "mle" | "use-mle" | "use_mle" => self.use_mle = parse_bool(value)?,
            "ingest-gemm" | "ingest_gemm" => self.ingest_gemm = parse_bool(value)?,
            "panel-quant" | "panel_quant" => self.panel_quant = PanelQuant::parse(value)?,
            "compact-min-rows" | "compact_min_rows" => self.compact_min_rows = value.parse()?,
            "compact-target-rows" | "compact_target_rows" => {
                self.compact_target_rows = parse_nonzero(key, value)?
            }
            "compactor-interval-ms" | "compactor_interval_ms" => {
                self.compactor_interval_ms = value.parse()?;
                anyhow::ensure!(self.compactor_interval_ms > 0, "{key} must be > 0");
            }
            "io-retry-max" | "io_retry_max" => self.io_retry_max = value.parse()?,
            "pjrt" | "use-pjrt" | "use_pjrt" => self.use_pjrt = parse_bool(value)?,
            "artifacts-dir" | "artifacts_dir" => self.artifacts_dir = PathBuf::from(value),
            "data-dist" | "data_dist" => self.data_dist = DataDist::parse(value)?,
            "n" => self.n = parse_nonzero(key, value)?,
            "d" => self.d = parse_nonzero(key, value)?,
            _ => anyhow::bail!("unknown config key {key:?}"),
        }
        Ok(())
    }

    /// Parse a `key = value` config file.
    pub fn load_file(&mut self, path: &Path) -> anyhow::Result<()> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading config {path:?}: {e}"))?;
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("{path:?}:{}: expected key = value", lineno + 1))?;
            self.set(k.trim(), v.trim())
                .map_err(|e| anyhow::anyhow!("{path:?}:{}: {e}", lineno + 1))?;
        }
        Ok(())
    }

    /// Apply `--key value` / `--key=value` style CLI arguments; returns
    /// the positional (non-flag) arguments in order.
    pub fn apply_args<I: IntoIterator<Item = String>>(
        &mut self,
        args: I,
    ) -> anyhow::Result<Vec<String>> {
        let mut positional = Vec::new();
        let mut it = args.into_iter();
        while let Some(arg) = it.next() {
            if let Some(flag) = arg.strip_prefix("--") {
                if let Some((k, v)) = flag.split_once('=') {
                    self.set(k, v)?;
                } else if flag == "config" {
                    let path = it
                        .next()
                        .ok_or_else(|| anyhow::anyhow!("--config needs a path"))?;
                    self.load_file(Path::new(&path))?;
                } else if matches!(flag, "mle" | "pjrt") {
                    // Bare boolean flags.
                    self.set(flag, "true")?;
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow::anyhow!("--{flag} needs a value"))?;
                    self.set(flag, &v)?;
                }
            } else {
                positional.push(arg);
            }
        }
        self.validate()?;
        Ok(positional)
    }

    /// Cross-field invariants.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.p >= 4 && self.p % 2 == 0, "p must be even and >= 4");
        anyhow::ensure!(
            self.k <= self.d,
            "k ({}) must not exceed d ({}) — sketches must compress",
            self.k,
            self.d
        );
        // compact_min_rows > compact_target_rows is allowed: "small" is
        // then every segment and the target alone caps merged size —
        // which also keeps `--compact-target-rows X` (X < the default
        // min) working without forcing users to retune both knobs.
        Ok(())
    }

    /// Projection spec derived from this config.
    pub fn projection_spec(&self) -> crate::projection::ProjectionSpec {
        crate::projection::ProjectionSpec::new(self.seed, self.k, self.dist, self.strategy)
    }

    /// One-line human summary (logged by the CLI and examples). Covers
    /// every serving-relevant knob — including `query_workers` and the
    /// compaction thresholds — so no caller needs to hand-append them.
    pub fn describe(&self) -> String {
        format!(
            "p={} k={} strategy={} dist={} n={} d={} workers={} qworkers={} block={} \
             compact={}/{} quant={} mle={} gemm={} pjrt={}",
            self.p,
            self.k,
            self.strategy.as_str(),
            self.dist.describe(),
            self.n,
            self.d,
            self.workers,
            self.query_workers,
            self.block_rows,
            self.compact_min_rows,
            self.compact_target_rows,
            self.panel_quant.name(),
            self.use_mle,
            self.ingest_gemm,
            self.use_pjrt,
        )
    }
}

fn parse_bool(v: &str) -> anyhow::Result<bool> {
    match v {
        "true" | "1" | "yes" | "on" => Ok(true),
        "false" | "0" | "no" | "off" => Ok(false),
        _ => anyhow::bail!("expected bool, got {v:?}"),
    }
}

fn parse_nonzero(key: &str, v: &str) -> anyhow::Result<usize> {
    let n: usize = v.parse()?;
    anyhow::ensure!(n > 0, "{key} must be > 0");
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(list: &[&str]) -> Vec<String> {
        list.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_validate() {
        Config::default().validate().unwrap();
    }

    #[test]
    fn cli_overrides() {
        let mut c = Config::default();
        let pos = c
            .apply_args(args(&["--p", "6", "--k=64", "--strategy", "alt", "run"]))
            .unwrap();
        assert_eq!(c.p, 6);
        assert_eq!(c.k, 64);
        assert_eq!(c.strategy, Strategy::Alternative);
        assert_eq!(pos, vec!["run".to_string()]);
    }

    #[test]
    fn bare_boolean_flags() {
        let mut c = Config::default();
        c.apply_args(args(&["--mle", "--pjrt"])).unwrap();
        assert!(c.use_mle);
        assert!(c.use_pjrt);
    }

    #[test]
    fn ingest_gemm_flag_parses() {
        let mut c = Config::default();
        assert!(c.ingest_gemm, "GEMM ingest is the default");
        c.apply_args(args(&["--ingest-gemm", "false"])).unwrap();
        assert!(!c.ingest_gemm);
        c.set("ingest_gemm", "on").unwrap();
        assert!(c.ingest_gemm);
    }

    #[test]
    fn panel_quant_parses_and_defaults_off() {
        let mut c = Config::default();
        assert_eq!(c.panel_quant, PanelQuant::None, "f32 storage is the default");
        c.apply_args(args(&["--panel-quant", "i8"])).unwrap();
        assert_eq!(c.panel_quant, PanelQuant::I8);
        c.set("panel_quant", "f16").unwrap();
        assert_eq!(c.panel_quant, PanelQuant::F16);
        c.set("panel-quant", "bf16").unwrap();
        assert_eq!(c.panel_quant, PanelQuant::Bf16);
        c.set("panel-quant", "none").unwrap();
        assert_eq!(c.panel_quant, PanelQuant::None);
        assert!(c.set("panel-quant", "q4").is_err(), "unknown encodings fail loudly");
        c.panel_quant = PanelQuant::Bf16;
        assert!(c.describe().contains("quant=bf16"), "{}", c.describe());
    }

    #[test]
    fn compaction_knobs_parse_and_validate() {
        let mut c = Config::default();
        assert_eq!(
            c.compact_min_rows, 1024,
            "copy-on-write compaction defaults on with a sane threshold"
        );
        assert!(c.compact_min_rows <= c.compact_target_rows);
        c.apply_args(args(&["--compact-min-rows", "128", "--compact-target-rows", "4096"]))
            .unwrap();
        assert_eq!(c.compact_min_rows, 128);
        assert_eq!(c.compact_target_rows, 4096);
        // 0 still parses (the opt-out).
        c.set("compact-min-rows", "0").unwrap();
        assert_eq!(c.compact_min_rows, 0);
        // Lowering the target below the default min must keep working
        // (target alone caps merged size) — only target = 0 is invalid.
        let mut low = Config::default();
        low.apply_args(args(&["--compact-target-rows", "512"])).unwrap();
        assert_eq!(low.compact_target_rows, 512);
        assert!(c.set("compact-target-rows", "0").is_err());
    }

    #[test]
    fn durability_knobs_parse() {
        let mut c = Config::default();
        assert_eq!(c.compactor_interval_ms, 1000);
        assert_eq!(c.io_retry_max, 4);
        c.apply_args(args(&["--compactor-interval-ms", "50", "--io-retry-max", "0"])).unwrap();
        assert_eq!(c.compactor_interval_ms, 50);
        assert_eq!(c.io_retry_max, 0, "0 retries (fail fast) is legal");
        c.set("compactor_interval_ms", "250").unwrap();
        assert_eq!(c.compactor_interval_ms, 250);
        assert!(c.set("compactor-interval-ms", "0").is_err());
    }

    #[test]
    fn query_workers_parse_and_default() {
        let mut c = Config::default();
        assert_eq!(c.query_workers, 2);
        c.apply_args(args(&["--query-workers", "8"])).unwrap();
        assert_eq!(c.query_workers, 8);
        assert!(c.set("query-workers", "0").is_err());
    }

    #[test]
    fn describe_covers_serving_knobs() {
        // `serve` used to hand-append query_workers; the one-line
        // summary must carry every serving-relevant knob itself.
        let mut c = Config::default();
        c.query_workers = 5;
        c.compact_min_rows = 7;
        c.compact_target_rows = 9;
        let line = c.describe();
        assert!(line.contains("qworkers=5"), "{line}");
        assert!(line.contains("compact=7/9"), "{line}");
    }

    #[test]
    fn rejects_odd_p() {
        let mut c = Config::default();
        assert!(c.set("p", "5").is_err());
        assert!(c.set("p", "2").is_err());
    }

    #[test]
    fn rejects_unknown_key() {
        let mut c = Config::default();
        assert!(c.set("nope", "1").is_err());
    }

    #[test]
    fn rejects_k_above_d() {
        let mut c = Config::default();
        assert!(c.apply_args(args(&["--d", "64", "--k", "128"])).is_err());
    }

    #[test]
    fn config_file_roundtrip() {
        let dir = std::env::temp_dir().join("lpsketch_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("test.conf");
        std::fs::write(&path, "# comment\np = 6\nk = 32 # trailing\n\ndist = threepoint:16\n")
            .unwrap();
        let mut c = Config::default();
        c.load_file(&path).unwrap();
        assert_eq!(c.p, 6);
        assert_eq!(c.k, 32);
        assert_eq!(c.dist, ProjectionDist::ThreePoint(16.0));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn file_error_carries_line() {
        let dir = std::env::temp_dir().join("lpsketch_cfg_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.conf");
        std::fs::write(&path, "p = 4\nbogus_line\n").unwrap();
        let err = Config::default().load_file(&path).unwrap_err().to_string();
        assert!(err.contains(":2"), "{err}");
        std::fs::remove_file(&path).ok();
    }
}
