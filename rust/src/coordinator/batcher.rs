//! Query batcher: coalesces individual queries into batches — the
//! dynamic-batching pattern of serving systems, applied to the typed
//! query API.
//!
//! The batcher is generic over the queued item: the query service runs
//! it over [`crate::api::ApiJob`]s (any typed request — pair batches,
//! top-k, stats — shares one queue and one per-batch store snapshot);
//! [`PairQuery`] is the original id-pair item shape, kept as the
//! minimal example and unit-test vehicle.
//!
//! Rationale: the estimate op amortizes (one artifact dispatch / one
//! cache-warm pass over the sketch store serves the whole batch), so
//! throughput wants big batches while latency wants small ones. The
//! policy is **work-conserving**: a batch is flushed as soon as
//! * `max_batch` queries have accumulated (size cap), or
//! * the queue has gone idle for `idle_tick` (no point waiting — flush
//!   what we have; this keeps single-client latency at ~tick, not at
//!   the deadline), or
//! * `deadline` has elapsed since the *oldest* queued query (upper
//!   bound under a continuous trickle that never goes idle).
//!
//! ## Concurrent draining
//!
//! The batcher itself is single-consumer (it owns the mpsc receiver),
//! but the query service runs **N serving workers** over one batcher by
//! wrapping it in a `Mutex`: exactly one worker blocks in
//! [`Batcher::drain`] at a time, releases the lock the moment a batch
//! is out, and serves it while the next worker drains. Draining is
//! cheap (channel hops) and serving is the expensive part (estimate
//! kernels over a store snapshot), so serialized draining costs nothing
//! while batch *execution* overlaps fully.

use std::sync::mpsc;
use std::time::{Duration, Instant};

/// One pair query with its reply slot — the original (pre-typed-API)
/// item shape, kept as the minimal batching example and test vehicle.
pub struct PairQuery<T> {
    pub a: u64,
    pub b: u64,
    pub reply: mpsc::SyncSender<T>,
}

/// Outcome of one drain step over items of type `Q`.
pub enum Drained<Q> {
    /// A batch ready to execute.
    Batch(Vec<Q>, FlushReason),
    /// Channel closed and nothing pending — shut down.
    Closed,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlushReason {
    /// Size cap reached.
    Size,
    /// Queue went idle (work-conserving fast path).
    Idle,
    /// Deadline since the oldest query expired under continuous load.
    Deadline,
    /// Channel closed with a partial batch pending.
    Drain,
}

/// Batching policy over an mpsc receiver of any queued item type.
pub struct Batcher<Q> {
    rx: mpsc::Receiver<Q>,
    pub max_batch: usize,
    pub deadline: Duration,
    /// How long an empty queue is polled before flushing a partial
    /// batch. Small (≈20µs): this is the added latency for a lone
    /// client.
    pub idle_tick: Duration,
}

impl<Q> Batcher<Q> {
    pub fn new(rx: mpsc::Receiver<Q>, max_batch: usize, deadline: Duration) -> Self {
        assert!(max_batch > 0);
        Batcher { rx, max_batch, deadline, idle_tick: Duration::from_micros(20) }
    }

    /// Block until a batch is ready (or the channel closes).
    pub fn drain(&self) -> Drained<Q> {
        // Block for the first query.
        let first = match self.rx.recv() {
            Ok(q) => q,
            Err(_) => return Drained::Closed,
        };
        let started = Instant::now();
        let mut batch = vec![first];
        while batch.len() < self.max_batch {
            // Fast path: drain whatever is already queued.
            match self.rx.try_recv() {
                Ok(q) => {
                    batch.push(q);
                    continue;
                }
                Err(mpsc::TryRecvError::Disconnected) => {
                    return Drained::Batch(batch, FlushReason::Drain)
                }
                Err(mpsc::TryRecvError::Empty) => {}
            }
            // Queue momentarily empty: give producers one idle tick
            // (bounded by the remaining deadline) then flush.
            let left = self.deadline.saturating_sub(started.elapsed());
            if left.is_zero() {
                return Drained::Batch(batch, FlushReason::Deadline);
            }
            match self.rx.recv_timeout(self.idle_tick.min(left)) {
                Ok(q) => batch.push(q),
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    let reason = if started.elapsed() >= self.deadline {
                        FlushReason::Deadline
                    } else {
                        FlushReason::Idle
                    };
                    return Drained::Batch(batch, reason);
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    return Drained::Batch(batch, FlushReason::Drain)
                }
            }
        }
        Drained::Batch(batch, FlushReason::Size)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(a: u64, b: u64) -> (PairQuery<f64>, mpsc::Receiver<f64>) {
        let (reply, rx) = mpsc::sync_channel(1);
        (PairQuery { a, b, reply }, rx)
    }

    #[test]
    fn flushes_on_size() {
        let (tx, rx) = mpsc::channel();
        let batcher = Batcher::new(rx, 3, Duration::from_secs(10));
        let mut replies = Vec::new();
        for i in 0..3 {
            let (query, r) = q(i, i + 1);
            tx.send(query).unwrap();
            replies.push(r);
        }
        match batcher.drain() {
            Drained::Batch(batch, FlushReason::Size) => assert_eq!(batch.len(), 3),
            _ => panic!("expected size flush"),
        }
    }

    #[test]
    fn lone_query_flushes_fast_on_idle() {
        let (tx, rx) = mpsc::channel();
        let batcher = Batcher::new(rx, 100, Duration::from_secs(10));
        let (query, _r) = q(1, 2);
        tx.send(query).unwrap();
        let t0 = Instant::now();
        match batcher.drain() {
            Drained::Batch(batch, FlushReason::Idle) => {
                assert_eq!(batch.len(), 1);
                // Work-conserving: flushed in ~idle_tick, far below the
                // 10s deadline.
                assert!(t0.elapsed() < Duration::from_millis(100));
            }
            _ => panic!("expected idle flush"),
        }
    }

    #[test]
    fn burst_is_coalesced_into_one_batch() {
        let (tx, rx) = mpsc::channel();
        let batcher = Batcher::new(rx, 100, Duration::from_secs(10));
        let mut replies = Vec::new();
        for i in 0..10 {
            let (query, r) = q(i, i + 1);
            tx.send(query).unwrap();
            replies.push(r);
        }
        match batcher.drain() {
            Drained::Batch(batch, reason) => {
                assert_eq!(batch.len(), 10);
                assert!(matches!(reason, FlushReason::Idle | FlushReason::Deadline));
            }
            _ => panic!("expected a batch"),
        }
    }

    #[test]
    fn deadline_bounds_continuous_trickle() {
        // A producer sending faster than the idle tick keeps the queue
        // warm; the deadline caps how long the batch can grow.
        let (tx, rx) = mpsc::channel();
        let mut batcher = Batcher::new(rx, 1_000_000, Duration::from_millis(30));
        batcher.idle_tick = Duration::from_millis(5);
        let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        let stop2 = stop.clone();
        let producer = std::thread::spawn(move || {
            let mut i = 0u64;
            while !stop2.load(std::sync::atomic::Ordering::Relaxed) {
                let (query, _r) = q(i, i + 1);
                if tx.send(query).is_err() {
                    break;
                }
                i += 1;
                std::thread::sleep(Duration::from_millis(1));
            }
        });
        let t0 = Instant::now();
        match batcher.drain() {
            Drained::Batch(batch, FlushReason::Deadline) => {
                assert!(batch.len() >= 2);
                assert!(t0.elapsed() >= Duration::from_millis(25));
            }
            Drained::Batch(_, reason) => panic!("expected deadline flush, got {reason:?}"),
            Drained::Closed => panic!("closed"),
        }
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        producer.join().unwrap();
    }

    #[test]
    fn drains_partial_on_close() {
        let (tx, rx) = mpsc::channel();
        let batcher = Batcher::new(rx, 100, Duration::from_secs(10));
        let (query, _r) = q(1, 2);
        tx.send(query).unwrap();
        drop(tx);
        match batcher.drain() {
            Drained::Batch(batch, FlushReason::Drain) => assert_eq!(batch.len(), 1),
            _ => panic!("expected drain flush"),
        }
    }

    #[test]
    fn closed_empty_reports_closed() {
        let (tx, rx) = mpsc::channel::<PairQuery<f64>>();
        drop(tx);
        let batcher = Batcher::new(rx, 10, Duration::from_millis(1));
        assert!(matches!(batcher.drain(), Drained::Closed));
    }
}
