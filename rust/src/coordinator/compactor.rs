//! The background compactor: a single thread that periodically merges
//! small columnar segments *across ingest runs* (the per-ingest
//! lifecycle hook only sees its own run's range) under the store's COW
//! `compact_range` swap, then seals the result through the durability
//! layer so restart replays only the WAL tail.
//!
//! Robustness contract:
//!
//! * **graceful shutdown** — dropping the [`Compactor`] disconnects its
//!   channel; the thread runs one final drain pass (so the freshest
//!   state is sealed) and exits, and `Drop` joins it.
//! * **retry with backoff** — transient I/O errors retry up to
//!   `io_retry_max` times with doubling sleeps before a pass is
//!   declared failed.
//! * **degraded mode** — a pass that exhausts its retries (data
//!   directory unwritable, disk full) sets the `durable_degraded`
//!   gauge and logs loudly, once per transition; reads keep serving
//!   from memory and the next successful pass clears the flag. Never a
//!   panic.

// Serving path: clippy backs the pallas-lint serving-no-panic rule.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Duration;

use super::pipeline::Pipeline;

/// Handle to the background compaction thread. Dropping it shuts the
/// thread down gracefully (drain-on-drop: one final compact+seal pass).
pub struct Compactor {
    tx: Option<mpsc::Sender<()>>,
    join: Option<JoinHandle<()>>,
}

impl Compactor {
    /// Spawn the compactor over `pipeline`, waking every `interval`
    /// (and immediately on [`Compactor::poke`]).
    pub fn spawn(pipeline: Arc<Pipeline>, interval: Duration) -> Compactor {
        let (tx, rx) = mpsc::channel::<()>();
        let join = std::thread::spawn(move || run_loop(&pipeline, interval, &rx));
        Compactor { tx: Some(tx), join: Some(join) }
    }

    /// Request an immediate pass (e.g. right after a large ingest).
    pub fn poke(&self) {
        if let Some(tx) = &self.tx {
            let _ = tx.send(());
        }
    }
}

impl Drop for Compactor {
    fn drop(&mut self) {
        // Disconnect wakes the loop; it runs one final pass and exits.
        drop(self.tx.take());
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

fn run_loop(pipeline: &Arc<Pipeline>, interval: Duration, rx: &mpsc::Receiver<()>) {
    loop {
        let shutdown = match rx.recv_timeout(interval) {
            Ok(()) | Err(mpsc::RecvTimeoutError::Timeout) => false,
            Err(mpsc::RecvTimeoutError::Disconnected) => true,
        };
        run_pass(pipeline);
        if shutdown {
            break;
        }
    }
}

/// One compact+seal pass — public so tests and the CLI can drive a
/// pass synchronously (the CLI's durable `ingest` seals before exit).
pub fn run_pass(pipeline: &Pipeline) {
    let metrics = pipeline.metrics_raw();
    metrics.compactor_passes.fetch_add(1, Ordering::Relaxed);
    // Cross-run merge: `Pipeline::compact` scans the whole store (the
    // ingest hook only compacts within its own run) and swaps merged
    // segments in under the COW write lock.
    let cfg = pipeline.config();
    if cfg.compact_min_rows > 0 {
        let _ = pipeline.compact();
    }
    let Some(durability) = pipeline.durability() else {
        return;
    };
    // Seal with retry-with-backoff; exhaustion flips degraded mode.
    let mut delay = Duration::from_millis(10);
    let mut last_err = None;
    for attempt in 0..=cfg.io_retry_max {
        match durability.seal(pipeline.store()) {
            Ok(report) => {
                metrics.segments_sealed.fetch_add(report.segments_written, Ordering::Relaxed);
                let (records, bytes) = durability.wal_stats();
                metrics.wal_records.store(records, Ordering::Relaxed);
                metrics.wal_bytes.store(bytes, Ordering::Relaxed);
                if durability.set_degraded(false) {
                    metrics.durable_degraded.store(0, Ordering::Relaxed);
                    eprintln!("durability restored: data directory is writable again");
                }
                return;
            }
            Err(e) => {
                last_err = Some(e);
                if attempt < cfg.io_retry_max {
                    metrics.io_retries.fetch_add(1, Ordering::Relaxed);
                    std::thread::sleep(delay);
                    delay = delay.saturating_mul(2);
                }
            }
        }
    }
    // Retries exhausted: degrade loudly (once per transition), keep
    // serving reads. The in-memory store is intact; only persistence
    // of *new* state is paused until the directory heals.
    metrics.durable_degraded.store(1, Ordering::Relaxed);
    if durability.set_degraded(true) {
        let err = last_err.map(|e| format!("{e:#}")).unwrap_or_else(|| "unknown error".to_string());
        eprintln!(
            "DEGRADED: durability seal failed after {} retries ({err}); \
             reads keep serving, new ingest is not being persisted",
            cfg.io_retry_max
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::data::{gen, DataDist};

    fn small_cfg() -> Config {
        let mut cfg = Config::default();
        cfg.n = 48;
        cfg.d = 24;
        cfg.k = 8;
        cfg.p = 4;
        cfg.block_rows = 4;
        cfg.workers = 2;
        cfg.compact_min_rows = 0; // keep ingest's own hook out of the way
        cfg
    }

    #[test]
    fn compactor_merges_across_ingest_runs() {
        let mut cfg = small_cfg();
        cfg.compact_min_rows = 1024;
        cfg.compact_target_rows = 4096;
        let pipeline = Arc::new(Pipeline::new(cfg.clone()).unwrap());
        // Several small ingest runs leave several small segments; the
        // per-ingest hook cannot merge across runs.
        for seed in 0..4 {
            let data = gen::generate(DataDist::Gaussian, 12, cfg.d, 100 + seed);
            pipeline.ingest(&data).unwrap();
        }
        let before = pipeline.store().segment_count();
        assert!(before > 1, "setup should leave multiple segments, got {before}");
        run_pass(&pipeline);
        let after = pipeline.store().segment_count();
        assert!(after < before, "cross-run pass must merge ({before} -> {after})");
        assert_eq!(pipeline.metrics().compactor_passes, 1);
        // Estimates survive compaction bitwise (COW swap invariant).
        let ids = pipeline.store().ids();
        assert_eq!(ids.len(), 48);
    }

    #[test]
    fn drop_joins_the_thread() {
        let pipeline = Arc::new(Pipeline::new(small_cfg()).unwrap());
        let compactor = Compactor::spawn(Arc::clone(&pipeline), Duration::from_secs(3600));
        compactor.poke();
        drop(compactor); // must not hang; runs the final drain pass
        assert!(pipeline.metrics().compactor_passes >= 1);
    }
}
