//! Durable ingest: the data-directory layer behind `serve --data-dir`.
//!
//! Sketches are expensive to (re)compute — each ingested row costs p−1
//! projections through the GEMM path — so acknowledged ingest must
//! survive `kill -9`, torn writes, and full disks. The layer is three
//! cooperating pieces:
//!
//! * a checksummed write-ahead log ([`super::wal`]) that records every
//!   acknowledged batch before the ack,
//! * immutable per-segment files ([`super::segfile`]) that seal the
//!   store's columnar blocks so restart replays only the WAL tail,
//! * a background compactor ([`super::compactor`]) that merges small
//!   segments across ingest runs and drives sealing.
//!
//! ## The data directory
//!
//! ```text
//! <root>/
//!   store.meta            sketch shape + projection (magic LPDM, CRC)
//!   snapshot.lpsk         optional persist v1/v2/v3 snapshot (compat)
//!   wal/wal-<seq>.wal     append-only record logs, replayed in order
//!   seg/seg-<base>-<rows>.lpsk   sealed columnar segments (footer CRC)
//! ```
//!
//! ## The ack protocol (insert-then-log)
//!
//! Ingest inserts into the in-memory store **first**, then appends the
//! record and fsyncs; only a successful sync acknowledges the batch.
//! Sealing snapshots the store *under the durability mutex*, so every
//! record in a deleted WAL is provably covered by the snapshot that was
//! sealed: a concurrent writer either landed before the snapshot (and
//! is sealed with it) or logs after the rotation (into the fresh WAL).
//! A crash can leave *unacknowledged* rows in WAL files or lose rows
//! that were inserted but never synced — never an acknowledged one.
//!
//! ## Recovery
//!
//! [`Durability::open`] rebuilds the store from disk: load the optional
//! snapshot, adopt sealed segment files (newest/widest first, exact
//! duplicates and fully-covered ranges skipped, partial overlap is a
//! hard error), then replay WAL files in sequence order with the same
//! idempotence rules. Torn tails — the unsynced suffix a crash leaves —
//! are tolerated on every WAL file (a torn record was never
//! acknowledged); corruption *under* an intact record's CRC is a hard
//! error, in the persist-v2 discipline: caps and bytes-present are
//! validated before any allocation, and nothing here panics.
//!
//! All I/O goes through the injectable [`DurableFs`] trait so the
//! fault-injection harness (`testkit::faultfs`) can crash the layer at
//! every named point: torn record, short write, fsync failure, rename
//! failure, disk-full.

// Serving path: clippy backs the pallas-lint serving-no-panic rule.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::collections::BTreeSet;
use std::io;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Context;

use crate::config::Config;
use crate::projection::sketcher::{ColumnarBlock, RowSketch};
use crate::projection::{ProjectionDist, Strategy};
use crate::util::sync::MutexExt;

use super::persist::{self, ProjectionInfo};
use super::state::SketchStore;
use super::{segfile, wal};

/// Hard caps on declared shapes (mirrors `persist`): a corrupt header
/// must error, never drive a multi-gigabyte allocation.
pub(crate) const MAX_K: usize = 1 << 24;
pub(crate) const MAX_ORDERS: usize = 64;
pub(crate) const MAX_MOMENT_ORDERS: usize = 256;

// ---------------------------------------------------------------------------
// CRC32 (IEEE 802.3, the zlib polynomial) — no vendored crc crate.
// ---------------------------------------------------------------------------

const CRC32_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut bit = 0;
        while bit < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            bit += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// CRC32 of `bytes` (IEEE: init all-ones, reflected, final xor).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC32_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// ---------------------------------------------------------------------------
// Injectable filesystem
// ---------------------------------------------------------------------------

/// The filesystem surface the durability layer is written against.
/// Production uses [`RealFs`]; the fault-injection harness wraps it and
/// fails named call sites. Method names are deliberately distinct from
/// lock-acquisition vocabulary (`read`/`write`) so lint scopes stay
/// precise.
pub trait DurableFs: Send + Sync {
    fn read_file(&self, path: &Path) -> io::Result<Vec<u8>>;
    /// Create-or-truncate `path` with exactly `data`.
    fn write_file(&self, path: &Path, data: &[u8]) -> io::Result<()>;
    /// Append `data` to `path`, creating it when absent.
    fn append_file(&self, path: &Path, data: &[u8]) -> io::Result<()>;
    /// `fsync` the file's contents + metadata.
    fn sync_file(&self, path: &Path) -> io::Result<()>;
    /// `fsync` a directory (makes renames/creates in it durable).
    fn sync_dir(&self, path: &Path) -> io::Result<()>;
    fn rename(&self, from: &Path, to: &Path) -> io::Result<()>;
    fn remove_file(&self, path: &Path) -> io::Result<()>;
    fn list_dir(&self, dir: &Path) -> io::Result<Vec<PathBuf>>;
    fn create_dir_all(&self, path: &Path) -> io::Result<()>;
}

/// [`DurableFs`] over `std::fs`.
#[derive(Clone, Copy, Debug, Default)]
pub struct RealFs;

impl DurableFs for RealFs {
    fn read_file(&self, path: &Path) -> io::Result<Vec<u8>> {
        std::fs::read(path)
    }

    fn write_file(&self, path: &Path, data: &[u8]) -> io::Result<()> {
        std::fs::write(path, data)
    }

    fn append_file(&self, path: &Path, data: &[u8]) -> io::Result<()> {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        f.write_all(data)
    }

    fn sync_file(&self, path: &Path) -> io::Result<()> {
        std::fs::File::open(path)?.sync_all()
    }

    fn sync_dir(&self, path: &Path) -> io::Result<()> {
        // Directory fsync is how a rename/create becomes crash-durable
        // on POSIX; platforms where opening a directory fails treat the
        // rename itself as the barrier.
        match std::fs::File::open(path) {
            Ok(d) => d.sync_all(),
            Err(_) => Ok(()),
        }
    }

    fn rename(&self, from: &Path, to: &Path) -> io::Result<()> {
        std::fs::rename(from, to)
    }

    fn remove_file(&self, path: &Path) -> io::Result<()> {
        std::fs::remove_file(path)
    }

    fn list_dir(&self, dir: &Path) -> io::Result<Vec<PathBuf>> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(dir)? {
            out.push(entry?.path());
        }
        out.sort();
        Ok(out)
    }

    fn create_dir_all(&self, path: &Path) -> io::Result<()> {
        std::fs::create_dir_all(path)
    }
}

// ---------------------------------------------------------------------------
// Little-endian codec helpers (shared by wal.rs / segfile.rs / meta)
// ---------------------------------------------------------------------------

pub(crate) fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    out.reserve(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

pub(crate) fn put_f64s(out: &mut Vec<u8>, xs: &[f64]) {
    out.reserve(xs.len() * 8);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

pub(crate) fn put_u16s(out: &mut Vec<u8>, xs: &[u16]) {
    out.reserve(xs.len() * 2);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

pub(crate) fn put_i8s(out: &mut Vec<u8>, xs: &[i8]) {
    out.reserve(xs.len());
    for x in xs {
        out.push(*x as u8);
    }
}

/// Bounds-checked cursor over a byte slice: every take validates
/// bytes-present *before* allocating, and a short buffer is an error,
/// never a panic.
pub(crate) struct ByteReader<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> ByteReader<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> Self {
        ByteReader { buf, off: 0 }
    }

    pub(crate) fn remaining(&self) -> usize {
        self.buf.len() - self.off
    }

    pub(crate) fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(
            n <= self.remaining(),
            "truncated record: need {n} bytes at offset {}, have {}",
            self.off,
            self.remaining()
        );
        let s = &self.buf[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    pub(crate) fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u32(&mut self) -> anyhow::Result<u32> {
        let s = self.take(4)?;
        let mut b = [0u8; 4];
        b.copy_from_slice(s);
        Ok(u32::from_le_bytes(b))
    }

    pub(crate) fn u64(&mut self) -> anyhow::Result<u64> {
        let s = self.take(8)?;
        let mut b = [0u8; 8];
        b.copy_from_slice(s);
        Ok(u64::from_le_bytes(b))
    }

    pub(crate) fn f64(&mut self) -> anyhow::Result<f64> {
        let s = self.take(8)?;
        let mut b = [0u8; 8];
        b.copy_from_slice(s);
        Ok(f64::from_le_bytes(b))
    }

    pub(crate) fn f32s(&mut self, n: usize) -> anyhow::Result<Vec<f32>> {
        let bytes = n.checked_mul(4).ok_or_else(|| anyhow::anyhow!("panel length overflow"))?;
        anyhow::ensure!(bytes <= self.remaining(), "truncated f32 panel ({n} values)");
        let s = self.take(bytes)?;
        let mut out = Vec::with_capacity(n);
        for c in s.chunks_exact(4) {
            let mut b = [0u8; 4];
            b.copy_from_slice(c);
            out.push(f32::from_le_bytes(b));
        }
        Ok(out)
    }

    pub(crate) fn u16s(&mut self, n: usize) -> anyhow::Result<Vec<u16>> {
        let bytes = n.checked_mul(2).ok_or_else(|| anyhow::anyhow!("panel length overflow"))?;
        anyhow::ensure!(bytes <= self.remaining(), "truncated u16 panel ({n} values)");
        let s = self.take(bytes)?;
        let mut out = Vec::with_capacity(n);
        for c in s.chunks_exact(2) {
            let mut b = [0u8; 2];
            b.copy_from_slice(c);
            out.push(u16::from_le_bytes(b));
        }
        Ok(out)
    }

    pub(crate) fn i8s(&mut self, n: usize) -> anyhow::Result<Vec<i8>> {
        anyhow::ensure!(n <= self.remaining(), "truncated i8 panel ({n} values)");
        let s = self.take(n)?;
        Ok(s.iter().map(|&b| b as i8).collect())
    }

    pub(crate) fn f64s(&mut self, n: usize) -> anyhow::Result<Vec<f64>> {
        let bytes = n.checked_mul(8).ok_or_else(|| anyhow::anyhow!("panel length overflow"))?;
        anyhow::ensure!(bytes <= self.remaining(), "truncated f64 panel ({n} values)");
        let s = self.take(bytes)?;
        let mut out = Vec::with_capacity(n);
        for c in s.chunks_exact(8) {
            let mut b = [0u8; 8];
            b.copy_from_slice(c);
            out.push(f64::from_le_bytes(b));
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// The sketch-shape meta file (store.meta)
// ---------------------------------------------------------------------------

/// The shape every record in a data directory must match — written once
/// at creation, authoritative at recovery (a `recover` CLI run adopts
/// it into the serving config). Mirrors the persist header plus the
/// projection, so a recovered store can sketch fresh query vectors.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MetaShape {
    /// Distance order p (orders = p−1).
    pub p: u32,
    pub k: u32,
    pub orders: u32,
    pub moment_orders: u32,
    pub two_sided: bool,
    pub seed: u64,
    pub dist: ProjectionDist,
}

impl MetaShape {
    pub fn from_config(cfg: &Config) -> Self {
        MetaShape {
            p: cfg.p as u32,
            k: cfg.k as u32,
            orders: (cfg.p - 1) as u32,
            moment_orders: (2 * (cfg.p - 1)) as u32,
            two_sided: matches!(cfg.strategy, Strategy::Alternative),
            seed: cfg.seed,
            dist: cfg.dist,
        }
    }

    /// Reject implausible shapes before they size any buffer.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.k >= 1 && self.k as usize <= MAX_K, "implausible k {}", self.k);
        anyhow::ensure!(
            self.orders >= 1 && self.orders as usize <= MAX_ORDERS,
            "implausible order count {}",
            self.orders
        );
        anyhow::ensure!(
            self.moment_orders == 2 * self.orders
                && self.moment_orders as usize <= MAX_MOMENT_ORDERS,
            "inconsistent moment count {} for {} orders",
            self.moment_orders,
            self.orders
        );
        anyhow::ensure!(self.p == self.orders + 1, "p {} does not match orders {}", self.p, self.orders);
        Ok(())
    }

    /// f32 values per row and side-count-adjusted (u plus v when
    /// two-sided).
    pub(crate) fn row_f32s(&self) -> usize {
        let side = self.orders as usize * self.k as usize;
        side * if self.two_sided { 2 } else { 1 }
    }

    /// Payload bytes of one row's sketch data (panels + moments).
    pub(crate) fn row_data_bytes(&self) -> usize {
        self.row_f32s() * 4 + self.moment_orders as usize * 8
    }

    /// The projection this directory's sketches were built with.
    pub fn projection_info(&self) -> ProjectionInfo {
        ProjectionInfo { seed: self.seed, dist: self.dist }
    }
}

const META_MAGIC: &[u8; 4] = b"LPDM";
const META_VERSION: u32 = 1;
const DIST_NORMAL: u8 = 0;
const DIST_UNIFORM: u8 = 1;
const DIST_THREE_POINT: u8 = 2;

fn encode_meta(shape: &MetaShape) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(META_MAGIC);
    put_u32(&mut out, META_VERSION);
    put_u32(&mut out, shape.p);
    put_u32(&mut out, shape.k);
    put_u32(&mut out, shape.orders);
    put_u32(&mut out, shape.moment_orders);
    out.push(shape.two_sided as u8);
    put_u64(&mut out, shape.seed);
    let (tag, param) = match shape.dist {
        ProjectionDist::Normal => (DIST_NORMAL, 0.0),
        ProjectionDist::Uniform => (DIST_UNIFORM, 0.0),
        ProjectionDist::ThreePoint(s) => (DIST_THREE_POINT, s),
    };
    out.push(tag);
    out.extend_from_slice(&param.to_le_bytes());
    let crc = crc32(&out);
    put_u32(&mut out, crc);
    out
}

fn decode_meta(data: &[u8]) -> anyhow::Result<MetaShape> {
    anyhow::ensure!(data.len() >= 4 + 4 + 4, "meta file too short");
    anyhow::ensure!(&data[..4] == META_MAGIC, "not a store.meta file (bad magic)");
    let body = &data[..data.len() - 4];
    let mut tail = ByteReader::new(&data[data.len() - 4..]);
    let want = tail.u32()?;
    anyhow::ensure!(crc32(body) == want, "store.meta checksum mismatch (corrupt)");
    let mut r = ByteReader::new(&body[4..]);
    let version = r.u32()?;
    anyhow::ensure!(version == META_VERSION, "unsupported store.meta version {version}");
    let p = r.u32()?;
    let k = r.u32()?;
    let orders = r.u32()?;
    let moment_orders = r.u32()?;
    let two_sided = r.u8()? != 0;
    let seed = r.u64()?;
    let tag = r.u8()?;
    let param = r.f64()?;
    let dist = match tag {
        DIST_NORMAL => ProjectionDist::Normal,
        DIST_UNIFORM => ProjectionDist::Uniform,
        DIST_THREE_POINT => {
            anyhow::ensure!(
                param.is_finite() && param >= 1.0,
                "corrupt three-point parameter {param}"
            );
            ProjectionDist::ThreePoint(param)
        }
        t => anyhow::bail!("unknown projection distribution tag {t}"),
    };
    anyhow::ensure!(r.remaining() == 0, "trailing bytes in store.meta");
    let shape = MetaShape { p, k, orders, moment_orders, two_sided, seed, dist };
    shape.validate()?;
    Ok(shape)
}

// ---------------------------------------------------------------------------
// Data-directory layout
// ---------------------------------------------------------------------------

/// Path layout of one data directory.
#[derive(Clone, Debug)]
pub struct DataDir {
    root: PathBuf,
}

impl DataDir {
    pub fn new(root: impl Into<PathBuf>) -> Self {
        DataDir { root: root.into() }
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    pub fn wal_dir(&self) -> PathBuf {
        self.root.join("wal")
    }

    pub fn seg_dir(&self) -> PathBuf {
        self.root.join("seg")
    }

    pub fn meta_path(&self) -> PathBuf {
        self.root.join("store.meta")
    }

    /// Optional persist-format snapshot adopted at recovery (compat
    /// with `--save-sketches` files; v1/v2/v3 all load).
    pub fn snapshot_path(&self) -> PathBuf {
        self.root.join("snapshot.lpsk")
    }

    pub fn wal_path(&self, seq: u64) -> PathBuf {
        self.wal_dir().join(format!("wal-{seq:016x}.wal"))
    }
}

/// Read the directory's meta file (`None` when it does not exist yet).
pub fn read_meta(fs: &dyn DurableFs, dir: &DataDir) -> anyhow::Result<Option<MetaShape>> {
    match fs.read_file(&dir.meta_path()) {
        Ok(data) => Ok(Some(decode_meta(&data)?)),
        Err(e) if e.kind() == io::ErrorKind::NotFound => Ok(None),
        Err(e) => Err(e).context("reading store.meta"),
    }
}

fn write_meta(fs: &dyn DurableFs, dir: &DataDir, shape: &MetaShape) -> anyhow::Result<()> {
    let tmp = dir.root().join("store.meta.tmp");
    let path = dir.meta_path();
    fs.write_file(&tmp, &encode_meta(shape)).context("writing store.meta.tmp")?;
    fs.sync_file(&tmp).context("syncing store.meta.tmp")?;
    fs.rename(&tmp, &path).context("publishing store.meta")?;
    fs.sync_dir(dir.root()).context("syncing data dir")?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Reports
// ---------------------------------------------------------------------------

/// What [`Durability::open`] found and rebuilt.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RecoveryReport {
    /// True when the directory was newly created (nothing to recover).
    pub fresh: bool,
    /// Rows loaded from `snapshot.lpsk`.
    pub snapshot_rows: u64,
    /// Sealed segment files adopted into the store.
    pub segments_adopted: u64,
    /// Sealed segment files skipped because their range was already
    /// covered (superseded by compaction or the snapshot).
    pub segments_superseded: u64,
    /// WAL files scanned.
    pub wal_files: u64,
    /// Rows applied from WAL records.
    pub wal_rows_applied: u64,
    /// Rows skipped as duplicates (idempotent replay).
    pub wal_rows_skipped: u64,
    /// WAL files that ended in a torn (unacknowledged) tail.
    pub torn_tails: u64,
    /// Total rows in the recovered store.
    pub rows: u64,
}

/// What one [`Durability::seal`] pass did.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SealReport {
    /// Segment files written this pass.
    pub segments_written: u64,
    /// Map rows re-logged into the rotated WAL.
    pub map_rows_logged: u64,
    /// Old WAL files removed.
    pub wal_files_removed: u64,
    /// Superseded segment files removed.
    pub seg_files_removed: u64,
}

/// Accounting for one acknowledged WAL append.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WalAppend {
    pub records: u64,
    pub bytes: u64,
}

/// Result of [`Durability::open`].
pub struct Opened {
    pub store: SketchStore,
    pub durability: Durability,
    pub report: RecoveryReport,
}

// ---------------------------------------------------------------------------
// Coverage tracking (recovery idempotence without store panics)
// ---------------------------------------------------------------------------

/// Which row ids the store already holds, as coalesced half-open ranges
/// plus loose map-row ids. Recovery consults this before every insert
/// so duplicate replay skips and genuine collisions become errors —
/// the store's own collision `assert!`s are never reached.
struct Coverage {
    /// Sorted, disjoint, coalesced `[lo, hi)` ranges.
    ranges: Vec<(u64, u64)>,
    ids: BTreeSet<u64>,
}

impl Coverage {
    fn from_store(store: &SketchStore) -> Self {
        let mut ranges: Vec<(u64, u64)> = store
            .segments_snapshot()
            .iter()
            .map(|(base, block)| (*base, base + block.rows() as u64))
            .collect();
        ranges.sort_unstable();
        let mut cov = Coverage { ranges: Vec::new(), ids: store.map_ids().into_iter().collect() };
        for (lo, hi) in ranges.drain(..) {
            cov.insert_range(lo, hi);
        }
        cov
    }

    /// True when `[lo, hi)` lies entirely inside one coalesced range.
    fn covers(&self, lo: u64, hi: u64) -> bool {
        let i = self.ranges.partition_point(|&(_, rhi)| rhi < hi);
        self.ranges.get(i).is_some_and(|&(rlo, rhi)| rlo <= lo && hi <= rhi)
    }

    /// True when `[lo, hi)` intersects any covered range or map id.
    fn overlaps(&self, lo: u64, hi: u64) -> bool {
        let i = self.ranges.partition_point(|&(_, rhi)| rhi <= lo);
        if self.ranges.get(i).is_some_and(|&(rlo, _)| rlo < hi) {
            return true;
        }
        self.ids.range(lo..hi).next().is_some()
    }

    /// Record `[lo, hi)` as covered, coalescing adjacent ranges.
    fn insert_range(&mut self, lo: u64, hi: u64) {
        let i = self.ranges.partition_point(|&(_, rhi)| rhi < lo);
        let mut lo = lo;
        let mut hi = hi;
        let mut j = i;
        while j < self.ranges.len() && self.ranges[j].0 <= hi {
            lo = lo.min(self.ranges[j].0);
            hi = hi.max(self.ranges[j].1);
            j += 1;
        }
        self.ranges.splice(i..j, [(lo, hi)]);
    }

    fn contains_id(&self, id: u64) -> bool {
        self.ids.contains(&id) || {
            let i = self.ranges.partition_point(|&(_, rhi)| rhi <= id);
            self.ranges.get(i).is_some_and(|&(rlo, _)| rlo <= id)
        }
    }

    fn insert_id(&mut self, id: u64) {
        self.ids.insert(id);
    }
}

// ---------------------------------------------------------------------------
// The runtime object
// ---------------------------------------------------------------------------

struct DurState {
    /// Sequence number of the WAL file new appends land in.
    wal_seq: u64,
    /// Records / bytes appended to the current WAL file.
    wal_records: u64,
    wal_bytes: u64,
    /// `(base, rows)` of every segment already sealed on disk.
    sealed: Vec<(u64, u64)>,
    /// A failed append may have left a torn tail mid-file; appending
    /// after it would turn the tear into mid-log corruption, so the
    /// next append must rotate to a fresh file first.
    poisoned: bool,
    /// False only when nothing was appended since the last seal (lets
    /// the compactor's idle passes skip disk writes entirely).
    dirty: bool,
}

/// The durability runtime: owns the WAL tail, the sealed-segment
/// directory, and the degraded flag. Cheap to share behind `Arc`;
/// every method is `&self`.
pub struct Durability {
    fs: Arc<dyn DurableFs>,
    dir: DataDir,
    shape: MetaShape,
    state: Mutex<DurState>,
    degraded: AtomicBool,
}

impl Durability {
    /// Create-or-recover a data directory: write the meta file on first
    /// use, otherwise validate the shape, replay the directory into a
    /// fresh store, and start a fresh WAL file for new appends (a
    /// possibly-torn tail is never appended to).
    pub fn open(
        fs: Arc<dyn DurableFs>,
        root: &Path,
        shape: MetaShape,
        shards: usize,
    ) -> anyhow::Result<Opened> {
        shape.validate()?;
        let dir = DataDir::new(root);
        fs.create_dir_all(&dir.wal_dir()).context("creating wal dir")?;
        fs.create_dir_all(&dir.seg_dir()).context("creating seg dir")?;
        let existing = read_meta(fs.as_ref(), &dir)?;
        let fresh = existing.is_none();
        match existing {
            Some(disk) => anyhow::ensure!(
                disk == shape,
                "data dir shape mismatch: directory holds {disk:?}, config wants {shape:?} \
                 (run `recover` to adopt the directory's shape)"
            ),
            None => write_meta(fs.as_ref(), &dir, &shape)?,
        }
        let (store, mut report, sealed, next_seq) =
            recover_into(fs.as_ref(), &dir, &shape, shards)?;
        report.fresh = fresh;
        // Fresh WAL for new appends: never continue a file whose tail
        // may be torn. Created eagerly so a later append failure is a
        // clean per-batch error, not a half-created log.
        let path = dir.wal_path(next_seq);
        fs.write_file(&path, &wal::file_header()).context("creating WAL file")?;
        fs.sync_file(&path).context("syncing WAL file")?;
        fs.sync_dir(&dir.wal_dir()).context("syncing wal dir")?;
        let durability = Durability {
            fs,
            dir,
            shape,
            state: Mutex::new(DurState {
                wal_seq: next_seq,
                wal_records: 0,
                wal_bytes: 0,
                sealed,
                poisoned: false,
                // Older WAL files may still hold unsealed rows; the
                // first seal pass must not early-out.
                dirty: !fresh,
            }),
            degraded: AtomicBool::new(false),
        };
        Ok(Opened { store, durability, report })
    }

    pub fn shape(&self) -> &MetaShape {
        &self.shape
    }

    pub fn dir(&self) -> &DataDir {
        &self.dir
    }

    /// True while the data directory is unwritable and ingest/seal is
    /// failing — reads keep serving from memory.
    pub fn degraded(&self) -> bool {
        self.degraded.load(Ordering::Relaxed)
    }

    /// Flip the degraded flag; returns true when the value changed (the
    /// caller logs transitions loudly, once).
    pub(crate) fn set_degraded(&self, on: bool) -> bool {
        self.degraded.swap(on, Ordering::Relaxed) != on
    }

    /// `(records, bytes)` appended to the current WAL file.
    pub fn wal_stats(&self) -> (u64, u64) {
        let st = self.state.lock_recover();
        (st.wal_records, st.wal_bytes)
    }

    /// Log one row batch (per-row ingest path). The rows must already
    /// be inserted in the store — see the module-level ack protocol.
    /// All records land in one buffer, one append, one fsync (group
    /// commit); `Ok` is the acknowledgement.
    pub fn log_rows(&self, rows: &[(u64, RowSketch)]) -> anyhow::Result<WalAppend> {
        if rows.is_empty() {
            return Ok(WalAppend::default());
        }
        let mut buf = Vec::new();
        for (id, rs) in rows {
            wal::encode_row(&self.shape, *id, rs, &mut buf)?;
        }
        self.append_records(&buf, rows.len() as u64)
    }

    /// Log one columnar block (GEMM/PJRT ingest path). The block must
    /// already be inserted in the store.
    pub fn log_block(&self, base: u64, block: &ColumnarBlock) -> anyhow::Result<WalAppend> {
        let mut buf = Vec::new();
        wal::encode_batch(&self.shape, base, block, &mut buf)?;
        self.append_records(&buf, 1)
    }

    fn append_records(&self, buf: &[u8], records: u64) -> anyhow::Result<WalAppend> {
        let mut st = self.state.lock_recover();
        if st.poisoned {
            // Self-heal after a torn append: rotate to a fresh file so
            // the tear stays a tolerated tail, then continue. The torn
            // file keeps its valid prefix for replay.
            let seq = st.wal_seq + 1;
            let path = self.dir.wal_path(seq);
            self.fs
                .write_file(&path, &wal::file_header())
                .and_then(|()| self.fs.sync_file(&path))
                .and_then(|()| self.fs.sync_dir(&self.dir.wal_dir()))
                .context("rotating WAL after a torn append")?;
            st.wal_seq = seq;
            st.wal_records = 0;
            st.wal_bytes = 0;
            st.poisoned = false;
        }
        let path = self.dir.wal_path(st.wal_seq);
        let res = self
            .fs
            .append_file(&path, buf)
            .and_then(|()| self.fs.sync_file(&path));
        match res {
            Ok(()) => {
                st.wal_records += records;
                st.wal_bytes += buf.len() as u64;
                st.dirty = true;
                Ok(WalAppend { records, bytes: buf.len() as u64 })
            }
            Err(e) => {
                st.poisoned = true;
                st.dirty = true;
                Err(e).context("WAL append failed (batch not acknowledged)")
            }
        }
    }

    /// Seal the store's current state: write a segment file for every
    /// in-memory segment not yet on disk, rotate the WAL to a fresh
    /// file seeded with the map rows, then clean up superseded files.
    /// After a successful seal, restart replays only the fresh WAL.
    ///
    /// The snapshot is captured *under the durability mutex*: every
    /// record in the WALs being deleted was logged before this point,
    /// so its insert happened-before the snapshot and the row is sealed
    /// with it (see the module-level ack protocol).
    pub fn seal(&self, store: &SketchStore) -> anyhow::Result<SealReport> {
        let mut st = self.state.lock_recover();
        let snap = store.snapshot();
        let mut report = SealReport::default();
        let mut new_sealed: Vec<(u64, u64)> = Vec::new();
        for seg in snap.segments() {
            new_sealed.push((seg.base, seg.block.rows() as u64));
        }
        if !st.dirty && new_sealed == st.sealed {
            // Nothing appended, nothing compacted: idle pass, no I/O.
            return Ok(report);
        }
        for seg in snap.segments() {
            let key = (seg.base, seg.block.rows() as u64);
            if !st.sealed.contains(&key) {
                segfile::write_segment(
                    self.fs.as_ref(),
                    &self.dir.seg_dir(),
                    seg.base,
                    &seg.block,
                    &seg.zone,
                )?;
                report.segments_written += 1;
            }
        }
        // Rotate: the fresh WAL opens with every map row, so deleting
        // the old files loses nothing.
        let seq = st.wal_seq + 1;
        let mut buf = wal::file_header().to_vec();
        let map_ids = snap.map_ids();
        for &id in &map_ids {
            if let Some(rs) = snap.get(id) {
                wal::encode_row(&self.shape, id, &rs, &mut buf)?;
                report.map_rows_logged += 1;
            }
        }
        let path = self.dir.wal_path(seq);
        self.fs.write_file(&path, &buf).context("writing rotated WAL")?;
        self.fs.sync_file(&path).context("syncing rotated WAL")?;
        self.fs.sync_dir(&self.dir.wal_dir()).context("syncing wal dir")?;
        st.wal_seq = seq;
        st.wal_records = report.map_rows_logged;
        st.wal_bytes = buf.len() as u64;
        st.poisoned = false;
        st.dirty = false;
        st.sealed = new_sealed;
        // Cleanup is best-effort: a failure leaves stale files whose
        // replay is idempotent, retried next pass.
        if let Ok(entries) = self.fs.list_dir(&self.dir.wal_dir()) {
            for p in entries {
                let name = p.file_name().and_then(|n| n.to_str()).unwrap_or("");
                if let Some(old) = wal::parse_wal_name(name) {
                    if old != seq && self.fs.remove_file(&p).is_ok() {
                        report.wal_files_removed += 1;
                    }
                }
            }
        }
        if let Ok(entries) = self.fs.list_dir(&self.dir.seg_dir()) {
            for p in entries {
                let name = p.file_name().and_then(|n| n.to_str()).unwrap_or("");
                let stale = match segfile::parse_name(name) {
                    Some(key) => !st.sealed.contains(&key),
                    None => name.ends_with(".tmp"),
                };
                if stale && self.fs.remove_file(&p).is_ok() {
                    report.seg_files_removed += 1;
                }
            }
        }
        Ok(report)
    }
}

// ---------------------------------------------------------------------------
// Recovery
// ---------------------------------------------------------------------------

/// Rebuild a store from the directory: snapshot → sealed segments →
/// WAL replay. Returns the store, the report, the adopted sealed set,
/// and the next free WAL sequence number.
fn recover_into(
    fs: &dyn DurableFs,
    dir: &DataDir,
    shape: &MetaShape,
    shards: usize,
) -> anyhow::Result<(SketchStore, RecoveryReport, Vec<(u64, u64)>, u64)> {
    let mut report = RecoveryReport::default();
    // A crashed seal can leave *.tmp segment files; they were never
    // published, so they are dead weight.
    if let Ok(entries) = fs.list_dir(&dir.seg_dir()) {
        for p in entries {
            let name = p.file_name().and_then(|n| n.to_str()).unwrap_or("");
            if name.ends_with(".tmp") {
                let _ = fs.remove_file(&p);
            }
        }
    }
    // 1. Optional snapshot seeds the store (persist v1/v2/v3 compat).
    let snap_path = dir.snapshot_path();
    let have_snapshot = fs
        .list_dir(dir.root())
        .map(|e| e.iter().any(|p| p.file_name() == snap_path.file_name()))
        .unwrap_or(false);
    let store = if have_snapshot {
        let (store, header) = persist::load(&snap_path, shards).context("loading snapshot.lpsk")?;
        anyhow::ensure!(
            header.rows == 0
                || (header.k == shape.k
                    && header.orders == shape.orders
                    && header.moment_orders == shape.moment_orders
                    && header.two_sided == shape.two_sided),
            "snapshot.lpsk shape (k={}, orders={}, two_sided={}) does not match store.meta",
            header.k,
            header.orders,
            header.two_sided
        );
        report.snapshot_rows = header.rows;
        store
    } else {
        SketchStore::new(shards)
    };
    let mut cov = Coverage::from_store(&store);
    // 2. Adopt sealed segments, widest-first per base so a compacted
    // file supersedes the smaller files it merged.
    let mut seg_entries: Vec<(u64, u64, PathBuf)> = Vec::new();
    for p in fs.list_dir(&dir.seg_dir()).context("listing seg dir")? {
        let name = p.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if let Some((base, rows)) = segfile::parse_name(name) {
            seg_entries.push((base, rows, p));
        }
    }
    seg_entries.sort_by(|a, b| a.0.cmp(&b.0).then(b.1.cmp(&a.1)));
    let mut sealed: Vec<(u64, u64)> = Vec::new();
    for (base, rows, path) in seg_entries {
        let end = base
            .checked_add(rows)
            .ok_or_else(|| anyhow::anyhow!("segment {path:?} id range overflows"))?;
        if cov.covers(base, end) {
            report.segments_superseded += 1;
            let _ = fs.remove_file(&path);
            continue;
        }
        anyhow::ensure!(
            !cov.overlaps(base, end),
            "sealed segment {path:?} partially overlaps recovered rows (corrupt data directory)"
        );
        let (got_base, block, zone) = segfile::read_segment(fs, &path, shape)
            .with_context(|| format!("reading sealed segment {path:?}"))?;
        anyhow::ensure!(
            got_base == base && block.rows() as u64 == rows,
            "segment file {path:?} name does not match its header"
        );
        match zone {
            // v2 segments carry their zone — adopt it verbatim, no
            // O(rows·orders·k) rescan on the recovery path.
            Some(z) => store.insert_block_prezoned(base, Arc::new(block), Arc::new(z)),
            // v1 segments predate zones — recompute from the panels.
            None => store.insert_block_columnar(base, block),
        }
        cov.insert_range(base, end);
        sealed.push((base, rows));
        report.segments_adopted += 1;
    }
    sealed.sort_unstable();
    // 3. Replay WAL files in sequence order.
    let mut wal_entries: Vec<(u64, PathBuf)> = Vec::new();
    for p in fs.list_dir(&dir.wal_dir()).context("listing wal dir")? {
        let name = p.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if let Some(seq) = wal::parse_wal_name(name) {
            wal_entries.push((seq, p));
        }
    }
    wal_entries.sort_unstable();
    let mut max_seq: Option<u64> = None;
    for (seq, path) in wal_entries {
        max_seq = Some(seq);
        let scan = wal::replay_file(fs, &path, shape)
            .with_context(|| format!("replaying WAL {path:?}"))?;
        report.wal_files += 1;
        if scan.torn_tail {
            report.torn_tails += 1;
        }
        for rec in scan.records {
            match rec {
                wal::WalRecord::Row(id, rs) => {
                    if cov.contains_id(id) {
                        report.wal_rows_skipped += 1;
                    } else {
                        store.insert(id, rs);
                        cov.insert_id(id);
                        report.wal_rows_applied += 1;
                    }
                }
                wal::WalRecord::Batch(base, block) => {
                    let rows = block.rows() as u64;
                    let end = base
                        .checked_add(rows)
                        .ok_or_else(|| anyhow::anyhow!("WAL batch id range overflows"))?;
                    if cov.covers(base, end) {
                        report.wal_rows_skipped += rows;
                    } else {
                        anyhow::ensure!(
                            !cov.overlaps(base, end),
                            "WAL batch [{base}, {end}) partially overlaps recovered rows \
                             (corrupt data directory)"
                        );
                        store.insert_block_columnar(base, block);
                        cov.insert_range(base, end);
                        report.wal_rows_applied += rows;
                    }
                }
            }
        }
    }
    report.rows = store.len() as u64;
    let next_seq = max_seq.map_or(0, |s| s + 1);
    Ok((store, report, sealed, next_seq))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::projection::sketcher::Sketcher;
    use crate::projection::{ProjectionSpec, Strategy};

    fn tmp_root(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join("lpsketch_durable_test")
            .join(format!("{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn shape4() -> MetaShape {
        MetaShape {
            p: 4,
            k: 8,
            orders: 3,
            moment_orders: 6,
            two_sided: false,
            seed: 11,
            dist: ProjectionDist::Normal,
        }
    }

    fn sketcher_for(shape: &MetaShape) -> Sketcher {
        let strategy = if shape.two_sided { Strategy::Alternative } else { Strategy::Basic };
        Sketcher::new(
            ProjectionSpec::new(shape.seed, shape.k as usize, shape.dist, strategy),
            shape.p as usize,
        )
    }

    #[test]
    fn crc32_matches_reference_vectors() {
        // The canonical IEEE check value plus a zero run (independently
        // verified against Python's zlib.crc32).
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(&[0u8; 32]), 0x190A_55AD);
        assert_eq!(crc32(b"lpsketch"), crc32(b"lpsketch"));
        assert_ne!(crc32(b"lpsketch"), crc32(b"lpsketcH"));
    }

    #[test]
    fn meta_roundtrips_and_rejects_corruption() {
        for dist in [
            ProjectionDist::Normal,
            ProjectionDist::Uniform,
            ProjectionDist::ThreePoint(9.0),
        ] {
            let mut shape = shape4();
            shape.dist = dist;
            let bytes = encode_meta(&shape);
            assert_eq!(decode_meta(&bytes).unwrap(), shape);
            // Any single-byte flip must be caught by the CRC (or the
            // magic/field validation).
            for off in 0..bytes.len() {
                let mut b = bytes.clone();
                b[off] ^= 0x40;
                assert!(decode_meta(&b).is_err(), "flip at {off} must error");
            }
        }
        assert!(decode_meta(b"garbage").is_err());
    }

    #[test]
    fn coverage_coalesces_and_classifies() {
        let store = SketchStore::new(2);
        let mut cov = Coverage::from_store(&store);
        cov.insert_range(10, 20);
        cov.insert_range(20, 30); // adjacent → coalesced
        assert!(cov.covers(12, 28));
        assert!(cov.covers(10, 30));
        assert!(!cov.covers(10, 31));
        assert!(cov.overlaps(29, 40));
        assert!(!cov.overlaps(30, 40));
        cov.insert_id(5);
        assert!(cov.contains_id(5));
        assert!(cov.contains_id(15));
        assert!(!cov.contains_id(30));
        assert!(cov.overlaps(0, 6));
        cov.insert_range(40, 50);
        cov.insert_range(30, 40); // bridges the gap
        assert!(cov.covers(10, 50));
        assert_eq!(cov.ranges, vec![(10, 50)]);
    }

    #[test]
    fn open_fresh_log_crash_recover_roundtrip() {
        let root = tmp_root("roundtrip");
        let shape = shape4();
        let sk = sketcher_for(&shape);
        let fs: Arc<dyn DurableFs> = Arc::new(RealFs);
        let opened = Durability::open(Arc::clone(&fs), &root, shape, 2).unwrap();
        assert!(opened.report.fresh);
        let rows: Vec<Vec<f32>> =
            (0..6).map(|i| (0..10).map(|t| ((i * 7 + t) as f32 * 0.3).sin()).collect()).collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        // Map rows + one columnar block, insert-then-log.
        for (i, r) in refs[..2].iter().enumerate() {
            let rs = sk.sketch_row(r);
            opened.store.insert(i as u64, rs.clone());
            opened.durability.log_rows(&[(i as u64, rs)]).unwrap();
        }
        let block = sk.sketch_block(&refs[2..], 1);
        opened.store.insert_block_columnar(100, block.clone());
        opened.durability.log_block(100, &block).unwrap();
        let before = opened.store.ids();
        drop(opened); // crash before any seal: pure WAL replay
        let re = Durability::open(Arc::clone(&fs), &root, shape, 3).unwrap();
        assert!(!re.report.fresh);
        assert_eq!(re.report.wal_rows_applied, 6);
        assert_eq!(re.store.ids(), before);
        // Sketch payloads are bitwise identical through the log.
        for id in 0..2u64 {
            assert_eq!(re.store.get(id).unwrap().uside.data, sk.sketch_row(refs[id as usize]).uside.data);
        }
        assert_eq!(re.store.segments_snapshot().len(), 1);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn seal_truncates_wal_and_survives_restart() {
        let root = tmp_root("seal");
        let shape = shape4();
        let sk = sketcher_for(&shape);
        let fs: Arc<dyn DurableFs> = Arc::new(RealFs);
        let opened = Durability::open(Arc::clone(&fs), &root, shape, 2).unwrap();
        let rows: Vec<Vec<f32>> =
            (0..9).map(|i| (0..12).map(|t| ((i * 5 + t) as f32 * 0.2).cos()).collect()).collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let rs = sk.sketch_row(refs[0]);
        opened.store.insert(7, rs.clone());
        opened.durability.log_rows(&[(7, rs)]).unwrap();
        let block = sk.sketch_block(&refs[1..], 1);
        opened.store.insert_block_columnar(50, block.clone());
        opened.durability.log_block(50, &block).unwrap();
        let report = opened.durability.seal(&opened.store).unwrap();
        assert_eq!(report.segments_written, 1);
        assert_eq!(report.map_rows_logged, 1);
        assert_eq!(report.wal_files_removed, 1);
        // Idle pass after a seal: no I/O at all.
        let idle = opened.durability.seal(&opened.store).unwrap();
        assert_eq!(idle, SealReport::default());
        let ids = opened.store.ids();
        drop(opened);
        let re = Durability::open(Arc::clone(&fs), &root, shape, 2).unwrap();
        assert_eq!(re.report.segments_adopted, 1);
        assert_eq!(re.report.wal_rows_applied, 1); // the re-logged map row
        assert_eq!(re.store.ids(), ids);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn shape_mismatch_is_an_error() {
        let root = tmp_root("mismatch");
        let fs: Arc<dyn DurableFs> = Arc::new(RealFs);
        let shape = shape4();
        drop(Durability::open(Arc::clone(&fs), &root, shape, 2).unwrap());
        let mut other = shape;
        other.k = 16;
        assert!(Durability::open(Arc::clone(&fs), &root, other, 2).is_err());
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn meta_shape_validation_rejects_nonsense() {
        let mut s = shape4();
        s.moment_orders = 7;
        assert!(s.validate().is_err());
        let mut s = shape4();
        s.orders = 0;
        assert!(s.validate().is_err());
        let mut s = shape4();
        s.k = 0;
        assert!(s.validate().is_err());
        assert!(shape4().validate().is_ok());
    }
}
