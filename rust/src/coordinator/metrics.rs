//! Pipeline metrics: lock-free counters + a log-bucketed latency
//! histogram, snapshotable for the CLI / benches.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Log₂-bucketed histogram of microsecond latencies (buckets:
/// [0,1), [1,2), [2,4), … — 40 buckets covers > 15 minutes).
pub struct Histogram {
    buckets: [AtomicU64; 40],
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl Histogram {
    pub const fn new() -> Self {
        #[allow(clippy::declare_interior_mutable_const)]
        const ZERO: AtomicU64 = AtomicU64::new(0);
        Histogram { buckets: [ZERO; 40], count: AtomicU64::new(0), sum_us: AtomicU64::new(0) }
    }

    pub fn record_us(&self, us: u64) {
        self.record_us_many(us, 1);
    }

    pub fn record(&self, dur: std::time::Duration) {
        self.record_us(dur.as_micros() as u64);
    }

    /// Record `n` identical samples in O(1) — the batched query path
    /// logs its amortized per-item latency once per item this way, so
    /// `count` stays consistent with the per-item counters without n
    /// atomic round-trips.
    pub fn record_us_many(&self, us: u64, n: u64) {
        if n == 0 {
            return;
        }
        let bucket = (64 - us.leading_zeros()) as usize; // 0 → 0, 1 → 1, 2..3 → 2, …
        self.buckets[bucket.min(39)].fetch_add(n, Ordering::Relaxed);
        self.count.fetch_add(n, Ordering::Relaxed);
        self.sum_us.fetch_add(us * n, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// Upper bound of the bucket containing quantile `q` (0..1) — a
    /// ≤ 2× overestimate by construction, good enough for dashboards.
    pub fn quantile_us(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let target = ((n as f64) * q).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return if i == 0 { 1 } else { 1u64 << i };
            }
        }
        1u64 << 39
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// All pipeline counters. Cheap to share via `Arc`.
#[derive(Default)]
pub struct Metrics {
    pub rows_ingested: AtomicU64,
    pub blocks_sketched: AtomicU64,
    pub queries_served: AtomicU64,
    pub batches_flushed: AtomicU64,
    pub batch_deadline_flushes: AtomicU64,
    pub pjrt_calls: AtomicU64,
    /// Blocks sketched through the register-tiled GEMM ingest path.
    pub gemm_calls: AtomicU64,
    /// Blocks sketched through the per-row reference path.
    pub fallback_calls: AtomicU64,
    /// Segment-merge operations performed by compaction passes.
    pub compactions: AtomicU64,
    /// Gauge: columnar segments currently resident in the store
    /// (refreshed by the pipeline after ingest / compaction / adoption).
    pub segment_count: AtomicU64,
    /// Gauge: pair queries currently being served by the query-service
    /// workers (incremented per drained batch, decremented when its
    /// replies are sent).
    pub queries_in_flight: AtomicU64,
    /// Gauge: store-epoch bumps between the query service's previous
    /// serving snapshot and its current one — i.e. how many writes
    /// landed while the last batch was being served (or the service
    /// idled). 0 = nothing changed between batches; the first batch
    /// reports 0.
    pub snapshot_age: AtomicU64,
    /// Epoch of the query service's most recent serving snapshot
    /// (internal bookkeeping for `snapshot_age`; not exported).
    /// `u64::MAX` = no batch served yet — epoch 0 is a legitimate
    /// serve point on an empty store, so 0 cannot double as the
    /// sentinel (it would under-report staleness after an empty-store
    /// start).
    pub last_serve_epoch: AtomicU64,
    /// Malformed frames / stalled connections dropped by the wire
    /// server. Behind `Arc` so the server can count without holding the
    /// whole pipeline.
    pub wire_errors: Arc<AtomicU64>,
    /// Gauge: records in the current (unsealed) WAL file.
    pub wal_records: AtomicU64,
    /// Gauge: bytes in the current (unsealed) WAL file.
    pub wal_bytes: AtomicU64,
    /// Segment files sealed to disk by the durability layer.
    pub segments_sealed: AtomicU64,
    /// Compact+seal passes run by the background compactor.
    pub compactor_passes: AtomicU64,
    /// Transient durable-I/O errors that were retried.
    pub io_retries: AtomicU64,
    /// Gauge: 1 while durability is degraded (data dir unwritable;
    /// reads keep serving, persistence paused), else 0.
    pub durable_degraded: AtomicU64,
    /// Segments (re-)indexed by serving-index refreshes. A cold build
    /// counts every segment; an incremental refresh counts only
    /// segments newer than the cached epoch.
    pub knn_segments_reindexed: AtomicU64,
    /// Zoned segments actually scanned by pruned top-k queries
    /// (one count per (query, segment) visit).
    pub topk_segments_visited: AtomicU64,
    /// Zoned segments skipped whole by pruned top-k queries — their
    /// admissible lower bound could not beat the heap threshold.
    pub topk_segments_skipped: AtomicU64,
    pub sketch_latency: Histogram,
    pub query_latency: Histogram,
}

impl Metrics {
    pub fn new() -> Self {
        let m = Self::default();
        m.last_serve_epoch.store(u64::MAX, Ordering::Relaxed);
        m
    }

    pub fn snapshot(&self) -> Snapshot {
        Snapshot {
            // Resolved once per process by runtime dispatch; surfaced
            // here so operators can verify which inner-loop kernel —
            // avx / neon / portable / scalar — is actually serving.
            simd_kernel: crate::projection::simd::active_kernel(),
            rows_ingested: self.rows_ingested.load(Ordering::Relaxed),
            blocks_sketched: self.blocks_sketched.load(Ordering::Relaxed),
            queries_served: self.queries_served.load(Ordering::Relaxed),
            batches_flushed: self.batches_flushed.load(Ordering::Relaxed),
            batch_deadline_flushes: self.batch_deadline_flushes.load(Ordering::Relaxed),
            pjrt_calls: self.pjrt_calls.load(Ordering::Relaxed),
            gemm_calls: self.gemm_calls.load(Ordering::Relaxed),
            fallback_calls: self.fallback_calls.load(Ordering::Relaxed),
            compactions: self.compactions.load(Ordering::Relaxed),
            segment_count: self.segment_count.load(Ordering::Relaxed),
            queries_in_flight: self.queries_in_flight.load(Ordering::Relaxed),
            snapshot_age: self.snapshot_age.load(Ordering::Relaxed),
            wire_errors: self.wire_errors.load(Ordering::Relaxed),
            wal_records: self.wal_records.load(Ordering::Relaxed),
            wal_bytes: self.wal_bytes.load(Ordering::Relaxed),
            segments_sealed: self.segments_sealed.load(Ordering::Relaxed),
            compactor_passes: self.compactor_passes.load(Ordering::Relaxed),
            io_retries: self.io_retries.load(Ordering::Relaxed),
            durable_degraded: self.durable_degraded.load(Ordering::Relaxed),
            knn_segments_reindexed: self.knn_segments_reindexed.load(Ordering::Relaxed),
            topk_segments_visited: self.topk_segments_visited.load(Ordering::Relaxed),
            topk_segments_skipped: self.topk_segments_skipped.load(Ordering::Relaxed),
            sketch_mean_us: self.sketch_latency.mean_us(),
            sketch_p95_us: self.sketch_latency.quantile_us(0.95),
            query_mean_us: self.query_latency.mean_us(),
            query_p95_us: self.query_latency.quantile_us(0.95),
        }
    }
}

/// Point-in-time copy of the counters.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Snapshot {
    /// The SIMD kernel the f32 inner loops dispatched to ("avx",
    /// "neon", "portable", or "scalar" — see `projection/simd.rs`).
    pub simd_kernel: &'static str,
    pub rows_ingested: u64,
    pub blocks_sketched: u64,
    pub queries_served: u64,
    pub batches_flushed: u64,
    pub batch_deadline_flushes: u64,
    pub pjrt_calls: u64,
    pub gemm_calls: u64,
    pub fallback_calls: u64,
    pub compactions: u64,
    pub segment_count: u64,
    pub queries_in_flight: u64,
    pub snapshot_age: u64,
    pub wire_errors: u64,
    pub wal_records: u64,
    pub wal_bytes: u64,
    pub segments_sealed: u64,
    pub compactor_passes: u64,
    pub io_retries: u64,
    pub durable_degraded: u64,
    pub knn_segments_reindexed: u64,
    pub topk_segments_visited: u64,
    pub topk_segments_skipped: u64,
    pub sketch_mean_us: f64,
    pub sketch_p95_us: u64,
    pub query_mean_us: f64,
    pub query_p95_us: u64,
}

impl Snapshot {
    pub fn render(&self) -> String {
        format!(
            "simd={} rows={} blocks={} queries={} batches={} (deadline={}) pjrt={} gemm={} \
             fallback={} \
             compactions={} segments={} in_flight={} snapshot_age={} wire_errors={} \
             wal_records={} wal_bytes={} sealed={} compactor_passes={} io_retries={} \
             degraded={} knn_reindexed={} topk_visited={} topk_skipped={} \
             sketch_mean={:.1}us \
             sketch_p95={}us query_mean={:.1}us query_p95={}us",
            self.simd_kernel,
            self.rows_ingested,
            self.blocks_sketched,
            self.queries_served,
            self.batches_flushed,
            self.batch_deadline_flushes,
            self.pjrt_calls,
            self.gemm_calls,
            self.fallback_calls,
            self.compactions,
            self.segment_count,
            self.queries_in_flight,
            self.snapshot_age,
            self.wire_errors,
            self.wal_records,
            self.wal_bytes,
            self.segments_sealed,
            self.compactor_passes,
            self.io_retries,
            self.durable_degraded,
            self.knn_segments_reindexed,
            self.topk_segments_visited,
            self.topk_segments_skipped,
            self.sketch_mean_us,
            self.sketch_p95_us,
            self.query_mean_us,
            self.query_p95_us,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_are_monotone() {
        let h = Histogram::new();
        for us in [0u64, 1, 3, 7, 100, 1000, 100_000] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 7);
        assert!(h.quantile_us(0.01) <= h.quantile_us(0.5));
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.99));
    }

    #[test]
    fn quantile_bounds_value() {
        let h = Histogram::new();
        for _ in 0..100 {
            h.record_us(100);
        }
        let q = h.quantile_us(0.5);
        assert!((100..=256).contains(&q), "q={q}"); // ≤ 2× overestimate
    }

    #[test]
    fn bulk_record_matches_repeated() {
        let a = Histogram::new();
        let b = Histogram::new();
        for _ in 0..5 {
            a.record_us(7);
        }
        b.record_us_many(7, 5);
        assert_eq!(a.count(), b.count());
        assert_eq!(a.mean_us(), b.mean_us());
        assert_eq!(a.quantile_us(0.5), b.quantile_us(0.5));
        b.record_us_many(100, 0); // no-op
        assert_eq!(b.count(), 5);
    }

    #[test]
    fn mean_is_exact() {
        let h = Histogram::new();
        h.record_us(10);
        h.record_us(30);
        assert_eq!(h.mean_us(), 20.0);
    }

    #[test]
    fn snapshot_roundtrip() {
        let m = Metrics::new();
        m.rows_ingested.fetch_add(5, Ordering::Relaxed);
        m.pjrt_calls.fetch_add(2, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.rows_ingested, 5);
        assert_eq!(s.pjrt_calls, 2);
        assert!(s.render().contains("rows=5"));
    }

    #[test]
    fn snapshot_reports_the_active_simd_kernel() {
        let s = Metrics::new().snapshot();
        assert!(
            ["avx", "neon", "portable", "scalar"].contains(&s.simd_kernel),
            "unexpected kernel {:?}",
            s.simd_kernel
        );
        assert!(s.render().contains(&format!("simd={}", s.simd_kernel)));
    }
}
