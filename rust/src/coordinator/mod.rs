//! Layer-3 coordinator: the streaming orchestrator that is this repo's
//! systems contribution (DESIGN.md §2).
//!
//! * [`pipeline`] — reader → sharded sketch workers → sketch store, with
//!   bounded channels as backpressure; query side (single / batched /
//!   all-pairs).
//! * [`scheduler`] — slices row streams into fixed-size blocks.
//! * [`batcher`] — deadline+size dynamic batching, generic over the
//!   queued item (the query service batches typed API requests).
//! * [`router`] — row-id → shard assignment (a partition, by invariant).
//! * [`state`] — the sharded SketchStore (the O(nk) replacement for the
//!   O(nD) matrix), read through epoch snapshots so scans never pin the
//!   write path.
//! * [`metrics`] — counters + latency histograms.
//! * [`durable`] / [`wal`] / [`segfile`] — crash durability: a
//!   checksummed write-ahead log of acknowledged ingest, immutable
//!   sealed-segment files, and the recovery path that replays a data
//!   directory back into a store. All I/O goes through the injectable
//!   [`durable::DurableFs`] trait so tests can inject faults at named
//!   crash points.
//! * [`compactor`] — background thread merging small segments across
//!   ingest runs and sealing durable state, with drain-on-drop
//!   shutdown, retry-with-backoff, and a degraded mode that keeps
//!   serving reads when the data directory is unwritable.

pub mod batcher;
pub mod compactor;
pub mod durable;
pub mod metrics;
pub mod persist;
pub mod pipeline;
pub mod rebalance;
pub mod router;
pub mod scheduler;
pub mod segfile;
pub mod state;
pub mod wal;

pub use compactor::Compactor;
pub use durable::{DataDir, Durability, DurableFs, MetaShape, Opened, RealFs, RecoveryReport, SealReport};
pub use metrics::{Metrics, Snapshot};
pub use pipeline::{IngestReport, Pipeline};
pub use router::Router;
pub use scheduler::{Block, BlockScheduler};
pub use state::{ArenaSnapshot, CompactionReport, Segment, SegmentPanels, SketchStore, StoreSnapshot};
