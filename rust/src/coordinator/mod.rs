//! Layer-3 coordinator: the streaming orchestrator that is this repo's
//! systems contribution (DESIGN.md §2).
//!
//! * [`pipeline`] — reader → sharded sketch workers → sketch store, with
//!   bounded channels as backpressure; query side (single / batched /
//!   all-pairs).
//! * [`scheduler`] — slices row streams into fixed-size blocks.
//! * [`batcher`] — deadline+size dynamic batching, generic over the
//!   queued item (the query service batches typed API requests).
//! * [`router`] — row-id → shard assignment (a partition, by invariant).
//! * [`state`] — the sharded SketchStore (the O(nk) replacement for the
//!   O(nD) matrix), read through epoch snapshots so scans never pin the
//!   write path.
//! * [`metrics`] — counters + latency histograms.

pub mod batcher;
pub mod metrics;
pub mod persist;
pub mod pipeline;
pub mod rebalance;
pub mod router;
pub mod scheduler;
pub mod state;

pub use metrics::{Metrics, Snapshot};
pub use pipeline::{IngestReport, Pipeline};
pub use router::Router;
pub use scheduler::{Block, BlockScheduler};
pub use state::{ArenaSnapshot, CompactionReport, Segment, SegmentPanels, SketchStore, StoreSnapshot};
