//! Sketch-store persistence: save the O(nk) sketch state to disk and
//! reload it later — the operational consequence of the paper's storage
//! claim (after the linear scan, the sketches *are* the dataset; the
//! O(nD) matrix can be discarded).
//!
//! Format (little-endian, versioned):
//! ```text
//! magic "LPSK" | u32 version | u32 p | u32 k | u32 orders |
//! u32 moment_orders | u8 two_sided | u64 row_count |
//! per row: u64 id | uside f32[orders*k] | (vside f32[orders*k])? |
//!          moments f64[moment_orders]
//! ```
//! The header captures everything needed to validate compatibility with
//! a [`crate::config::Config`] before any row is read.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::core::marginals::Moments;
use crate::projection::sketcher::{RowSketch, SketchSet};

use super::state::SketchStore;

const MAGIC: &[u8; 4] = b"LPSK";
const VERSION: u32 = 1;

/// Header of a sketch file.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SketchFileHeader {
    pub p: u32,
    pub k: u32,
    pub orders: u32,
    pub moment_orders: u32,
    pub two_sided: bool,
    pub rows: u64,
}

fn w_u32(w: &mut impl Write, v: u32) -> std::io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

fn w_u64(w: &mut impl Write, v: u64) -> std::io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

fn r_u32(r: &mut impl Read) -> anyhow::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn r_u64(r: &mut impl Read) -> anyhow::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn w_f32s(w: &mut impl Write, xs: &[f32]) -> std::io::Result<()> {
    for x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn r_f32s(r: &mut impl Read, n: usize) -> anyhow::Result<Vec<f32>> {
    let mut out = Vec::with_capacity(n);
    let mut b = [0u8; 4];
    for _ in 0..n {
        r.read_exact(&mut b)?;
        out.push(f32::from_le_bytes(b));
    }
    Ok(out)
}

/// Save every row of `store` to `path`. `p` is the distance order the
/// sketches were built for (recorded for load-time validation).
pub fn save(store: &SketchStore, p: usize, path: &Path) -> anyhow::Result<SketchFileHeader> {
    let ids = store.ids();
    // Probe shape from the first row (empty stores save an empty file
    // with zeroed shape — loadable, yields an empty store).
    let probe = ids.first().map(|&id| store.get(id).unwrap());
    let (k, orders, nm, two_sided) = match &probe {
        Some(rs) => (
            rs.uside.k as u32,
            rs.uside.orders as u32,
            rs.moments.len() as u32,
            rs.vside_data.is_some(),
        ),
        None => (0, 0, 0, false),
    };
    let header = SketchFileHeader {
        p: p as u32,
        k,
        orders,
        moment_orders: nm,
        two_sided,
        rows: ids.len() as u64,
    };
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    w.write_all(MAGIC)?;
    w_u32(&mut w, VERSION)?;
    w_u32(&mut w, header.p)?;
    w_u32(&mut w, header.k)?;
    w_u32(&mut w, header.orders)?;
    w_u32(&mut w, header.moment_orders)?;
    w.write_all(&[header.two_sided as u8])?;
    w_u64(&mut w, header.rows)?;
    for id in ids {
        let rs = store.get(id).expect("listed id");
        anyhow::ensure!(
            rs.uside.k as u32 == k && rs.uside.orders as u32 == orders,
            "heterogeneous store (row {id})"
        );
        w_u64(&mut w, id)?;
        w_f32s(&mut w, &rs.uside.data)?;
        match (&rs.vside_data, two_sided) {
            (Some(v), true) => w_f32s(&mut w, &v.data)?,
            (None, false) => {}
            _ => anyhow::bail!("mixed one/two-sided rows (row {id})"),
        }
        for o in 1..=rs.moments.len() {
            w.write_all(&rs.moments.get(o).to_le_bytes())?;
        }
    }
    w.flush()?;
    Ok(header)
}

/// Read just the header (cheap compatibility probe).
pub fn read_header(path: &Path) -> anyhow::Result<SketchFileHeader> {
    let mut r = BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "not a sketch file");
    let version = r_u32(&mut r)?;
    anyhow::ensure!(version == VERSION, "unsupported sketch-file version {version}");
    let p = r_u32(&mut r)?;
    let k = r_u32(&mut r)?;
    let orders = r_u32(&mut r)?;
    let moment_orders = r_u32(&mut r)?;
    let mut flag = [0u8; 1];
    r.read_exact(&mut flag)?;
    let rows = r_u64(&mut r)?;
    Ok(SketchFileHeader { p, k, orders, moment_orders, two_sided: flag[0] != 0, rows })
}

/// Load a sketch file into a fresh store with `shards` shards.
pub fn load(path: &Path, shards: usize) -> anyhow::Result<(SketchStore, SketchFileHeader)> {
    let mut r = BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "not a sketch file");
    let version = r_u32(&mut r)?;
    anyhow::ensure!(version == VERSION, "unsupported sketch-file version {version}");
    let p = r_u32(&mut r)?;
    let k = r_u32(&mut r)? as usize;
    let orders = r_u32(&mut r)? as usize;
    let nm = r_u32(&mut r)? as usize;
    let mut flag = [0u8; 1];
    r.read_exact(&mut flag)?;
    let two_sided = flag[0] != 0;
    let rows = r_u64(&mut r)?;
    let store = SketchStore::new(shards);
    for _ in 0..rows {
        let id = r_u64(&mut r)?;
        let udata = r_f32s(&mut r, orders * k)?;
        let vside_data = if two_sided {
            Some(SketchSet { orders, k, data: r_f32s(&mut r, orders * k)? })
        } else {
            None
        };
        let mut moments = Vec::with_capacity(nm);
        let mut b = [0u8; 8];
        for _ in 0..nm {
            r.read_exact(&mut b)?;
            moments.push(f64::from_le_bytes(b));
        }
        store.insert(
            id,
            RowSketch {
                uside: SketchSet { orders, k, data: udata },
                vside_data,
                moments: Moments(moments),
            },
        );
    }
    let header = SketchFileHeader {
        p,
        k: k as u32,
        orders: orders as u32,
        moment_orders: nm as u32,
        two_sided,
        rows,
    };
    Ok((store, header))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::decompose::Decomposition;
    use crate::core::estimator;
    use crate::projection::sketcher::Sketcher;
    use crate::projection::{ProjectionDist, ProjectionSpec, Strategy};

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("lpsketch_persist_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn filled_store(strategy: Strategy, n: u64) -> SketchStore {
        let sk = Sketcher::new(ProjectionSpec::new(5, 8, ProjectionDist::Normal, strategy), 4);
        let store = SketchStore::new(3);
        for id in 0..n {
            let row: Vec<f32> = (0..20).map(|i| ((id + 1) as f32 * 0.1 + i as f32 * 0.01).sin()).collect();
            store.insert(id, sk.sketch_row(&row));
        }
        store
    }

    #[test]
    fn roundtrip_basic_strategy() {
        let store = filled_store(Strategy::Basic, 17);
        let path = tmp("basic.lpsk");
        let saved = save(&store, 4, &path).unwrap();
        assert_eq!(saved.rows, 17);
        assert!(!saved.two_sided);
        let (loaded, header) = load(&path, 5).unwrap();
        assert_eq!(header, saved);
        assert_eq!(loaded.ids(), store.ids());
        // Estimates identical through the roundtrip.
        let dec = Decomposition::new(4).unwrap();
        let before = store.with_pair(1, 9, |a, b| estimator::estimate(&dec, a, b)).unwrap();
        let after = loaded.with_pair(1, 9, |a, b| estimator::estimate(&dec, a, b)).unwrap();
        assert_eq!(before, after);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn roundtrip_alternative_strategy() {
        let store = filled_store(Strategy::Alternative, 9);
        let path = tmp("alt.lpsk");
        let saved = save(&store, 4, &path).unwrap();
        assert!(saved.two_sided);
        let (loaded, _) = load(&path, 2).unwrap();
        for id in 0..9u64 {
            let a = store.get(id).unwrap();
            let b = loaded.get(id).unwrap();
            assert_eq!(a.uside.data, b.uside.data);
            assert_eq!(a.vside().data, b.vside().data);
            assert_eq!(a.moments.0, b.moments.0);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn header_probe_without_full_read() {
        let store = filled_store(Strategy::Basic, 4);
        let path = tmp("probe.lpsk");
        save(&store, 6, &path).unwrap();
        let h = read_header(&path).unwrap();
        assert_eq!(h.p, 6);
        assert_eq!(h.rows, 4);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_garbage_file() {
        let path = tmp("garbage.lpsk");
        std::fs::write(&path, b"not a sketch file at all").unwrap();
        assert!(load(&path, 1).is_err());
        assert!(read_header(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn empty_store_roundtrips() {
        let store = SketchStore::new(2);
        let path = tmp("empty.lpsk");
        let saved = save(&store, 4, &path).unwrap();
        assert_eq!(saved.rows, 0);
        let (loaded, _) = load(&path, 2).unwrap();
        assert!(loaded.is_empty());
        std::fs::remove_file(&path).ok();
    }
}
