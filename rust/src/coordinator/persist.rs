//! Sketch-store persistence: save the O(nk) sketch state to disk and
//! reload it later — the operational consequence of the paper's storage
//! claim (after the linear scan, the sketches *are* the dataset; the
//! O(nD) matrix can be discarded).
//!
//! ## Format v5 (little-endian, current)
//!
//! The store's two internal representations are persisted as they are
//! held: per-row map entries row-wise, columnar segments as contiguous
//! panels (one bulk write per (order, side) per segment), so a
//! save/load cycle preserves the columnar layout — and with it the
//! memcpy `arena_snapshot` / segment-native query fast paths — instead
//! of degrading every row to a map entry. v4 additionally persists each
//! segment's zone summary (its pruning metadata), so a restored store
//! serves pruned top-k immediately, without an O(rows·orders·k)
//! recomputation pass. v5 additionally records each segment's panel
//! encoding ([`PanelQuant`]) so quantized segments persist **as
//! stored** — an i8 segment writes 1 byte/value plus its per-order
//! scales, not a decoded f32 blow-up — and restore bit-identically.
//!
//! | field                | type                  | notes                              |
//! |----------------------|-----------------------|------------------------------------|
//! | magic                | `b"LPSK"`             |                                    |
//! | version              | `u32` = 4             |                                    |
//! | p                    | `u32`                 | distance order (validation)        |
//! | k                    | `u32`                 | sketch width                       |
//! | orders               | `u32`                 | sketch orders (p−1)                |
//! | moment_orders        | `u32`                 | moments per row (2(p−1))           |
//! | two_sided            | `u8`                  | alternative strategy ⇒ 1           |
//! | rows                 | `u64`                 | total rows (map + segments)        |
//! | map_rows             | `u64`                 | per-row map entries                |
//! | segments             | `u64`                 | columnar segment count             |
//! | has_projection       | `u8`                  | v3+: projection recorded ⇒ 1       |
//! |   proj_seed          | `u64`                 | only if has_projection             |
//! |   proj_dist          | `u8`                  | 0 normal, 1 uniform, 2 three-point |
//! |   proj_param         | `f64`                 | three-point s (0 otherwise)        |
//! | *per map row*        |                       | *id ascending*                     |
//! |   id                 | `u64`                 |                                    |
//! |   uside              | `f32[orders·k]`       |                                    |
//! |   vside              | `f32[orders·k]`       | only if two_sided                  |
//! |   moments            | `f64[moment_orders]`  |                                    |
//! | *per segment*        |                       | *base ascending, ranges disjoint*  |
//! |   base               | `u64`                 | first covered id                   |
//! |   seg_rows           | `u64`                 |                                    |
//! |   enc                | `u8`                  | v5: `PanelQuant` tag (0 f32, 1 f16, 2 bf16, 3 i8) |
//! |   u_scales           | `f32[orders]`         | v5, i8 only: per-order u scales    |
//! |   v_scales           | `f32[orders]`         | v5, i8 + two_sided only            |
//! |   enc_crc            | `u32`                 | v5: CRC32 of tag + scale bytes     |
//! |   u panels           | `enc[orders·rows·k]`  | one contiguous panel per order, `enc`-sized values |
//! |   v panels           | `enc[orders·rows·k]`  | only if two_sided                  |
//! |   moments            | `f64[rows·nm]`        | row-major, always f64              |
//! |   zone_len           | `u32`                 | v4: zone words, = `encoded_len`    |
//! |   zone               | `f64[zone_len]`       | v4: `ZoneMeta::to_f64s` layout     |
//! |   zone_crc           | `u32`                 | v4: CRC32 of the zone bytes        |
//!
//! `zone_len` is redundant with the header shape (it must equal
//! [`ZoneMeta::encoded_len`]) and is validated *before* the zone buffer
//! is allocated — an inflated count is a hard error, not an allocation.
//! The per-zone CRC pins the summary: zones gate which segments a
//! pruned top-k even reads, so a silently corrupted zone could drop
//! true neighbors; a corrupted zone file errors instead. The v5
//! encoding trailer is pinned the same way: an unknown tag is rejected
//! *before* any panel byte is sized or read (the tag decides
//! bytes-per-value, so a flipped tag would mis-slice the whole
//! segment), a corrupted scale errors via `enc_crc`, and a non-finite
//! or negative scale is rejected outright. Restored quantized segments
//! keep their stored zone verbatim — admissible because quantized
//! decode is value-exact, so the values the zone bounds are exactly the
//! values every kernel sees.
//!
//! ## Format v4 (read-only compatibility)
//!
//! v5 without the per-segment encoding trailer: panels are always f32.
//!
//! ## Format v3 (read-only compatibility)
//!
//! v4 without the per-segment zone trailer. Loads fine; zones are
//! recomputed from the panels at insertion.
//!
//! The recorded projection (seed + distribution; strategy is already
//! implied by `two_sided`) is what lets a store restored via
//! `--load-sketches` sketch **fresh query vectors** consistently with
//! its stored rows — the paper's out-of-store query model. Files
//! without it (v1/v2, or a v3 writer given no spec) still load, but
//! the restored pipeline rejects fresh-vector queries with a clear
//! error instead of silently mis-sketching.
//!
//! ## Format v2 (read-only compatibility)
//!
//! v3 without the `has_projection` trailer — the header ends at the
//! `segments` count.
//!
//! ## Format v1 (read-only compatibility)
//!
//! `magic | u32 1 | p | k | orders | moment_orders | u8 two_sided |
//! u64 rows | per row: id, uside, (vside)?, moments` — every row loads
//! into the per-row map (v1 had no segment section).
//!
//! Corrupt input fails with an error, never a panic: declared sizes are
//! validated against hard caps and the file's actual length before any
//! buffer is allocated, and segment ranges are checked for overlap
//! before touching the store.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;
use std::sync::Arc;

use crate::core::marginals::Moments;
use crate::core::quant::{PanelQuant, PanelStore};
use crate::core::zone::ZoneMeta;
use crate::projection::sketcher::{ColumnarBlock, RowSketch, SketchSet};
use crate::projection::ProjectionDist;

use super::durable::crc32;
use super::state::SketchStore;

const MAGIC: &[u8; 4] = b"LPSK";
const VERSION: u32 = 5;

/// Hard caps on declared shapes — a corrupt header must error, not
/// drive a multi-gigabyte allocation.
const MAX_K: usize = 1 << 24;
const MAX_ORDERS: usize = 64;
const MAX_MOMENT_ORDERS: usize = 256;

/// The projection parameters a sketch file can record (v3+): together
/// with the strategy (implied by `two_sided`) and `k`, everything
/// needed to re-sketch fresh query vectors bit-identically to the rows
/// already in the file.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ProjectionInfo {
    pub seed: u64,
    pub dist: ProjectionDist,
}

/// Header of a sketch file.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SketchFileHeader {
    pub p: u32,
    pub k: u32,
    pub orders: u32,
    pub moment_orders: u32,
    pub two_sided: bool,
    /// Total rows (map + segment-resident).
    pub rows: u64,
    /// Rows held in the per-row map (= `rows` for v1 files).
    pub map_rows: u64,
    /// Columnar segments (0 for v1 files).
    pub segments: u64,
    /// Projection parameters (None for v1/v2 files, which predate the
    /// field — fresh-vector queries are disabled on such restores).
    pub projection: Option<ProjectionInfo>,
}

/// Distribution tags for the projection trailer.
const DIST_NORMAL: u8 = 0;
const DIST_UNIFORM: u8 = 1;
const DIST_THREE_POINT: u8 = 2;

fn w_u32(w: &mut impl Write, v: u32) -> std::io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

fn w_u64(w: &mut impl Write, v: u64) -> std::io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

fn r_u32(r: &mut impl Read) -> anyhow::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn r_u64(r: &mut impl Read) -> anyhow::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// One bulk write: serialize the whole slice into a byte buffer first so
/// each (order, side) panel hits the writer as a single `write_all`.
fn w_f32s(w: &mut impl Write, xs: &[f32]) -> std::io::Result<()> {
    let mut bytes = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    w.write_all(&bytes)
}

fn w_f64s(w: &mut impl Write, xs: &[f64]) -> std::io::Result<()> {
    let mut bytes = Vec::with_capacity(xs.len() * 8);
    for x in xs {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    w.write_all(&bytes)
}

fn w_u16s(w: &mut impl Write, xs: &[u16]) -> std::io::Result<()> {
    let mut bytes = Vec::with_capacity(xs.len() * 2);
    for x in xs {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    w.write_all(&bytes)
}

fn w_i8s(w: &mut impl Write, xs: &[i8]) -> std::io::Result<()> {
    let bytes: Vec<u8> = xs.iter().map(|&x| x as u8).collect();
    w.write_all(&bytes)
}

/// Write one panel store in its held encoding — the whole point of the
/// v5 segment body: an i8 store hits disk at 1 byte/value.
fn w_store(w: &mut impl Write, s: &PanelStore) -> std::io::Result<()> {
    match s {
        PanelStore::F32(xs) => w_f32s(w, xs),
        PanelStore::F16(xs) | PanelStore::Bf16(xs) => w_u16s(w, xs),
        PanelStore::I8 { data, .. } => w_i8s(w, data),
    }
}

fn r_f32s(r: &mut impl Read, n: usize) -> anyhow::Result<Vec<f32>> {
    let len = n.checked_mul(4).ok_or_else(|| anyhow::anyhow!("panel length overflow"))?;
    let mut bytes = vec![0u8; len];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().expect("4-byte chunk")))
        .collect())
}

fn r_f64s(r: &mut impl Read, n: usize) -> anyhow::Result<Vec<f64>> {
    let len = n.checked_mul(8).ok_or_else(|| anyhow::anyhow!("panel length overflow"))?;
    let mut bytes = vec![0u8; len];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().expect("8-byte chunk")))
        .collect())
}

fn r_u16s(r: &mut impl Read, n: usize) -> anyhow::Result<Vec<u16>> {
    let len = n.checked_mul(2).ok_or_else(|| anyhow::anyhow!("panel length overflow"))?;
    let mut bytes = vec![0u8; len];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(2)
        .map(|c| u16::from_le_bytes(c.try_into().expect("2-byte chunk")))
        .collect())
}

fn r_i8s(r: &mut impl Read, n: usize) -> anyhow::Result<Vec<i8>> {
    let mut bytes = vec![0u8; n];
    r.read_exact(&mut bytes)?;
    Ok(bytes.into_iter().map(|b| b as i8).collect())
}

/// Read one panel store of `n` values in encoding `enc`. `scales` must
/// be `Some` exactly when `enc` is i8 (the caller read and validated
/// them from the segment's encoding trailer).
fn r_store(
    r: &mut impl Read,
    enc: PanelQuant,
    n: usize,
    scales: Option<Vec<f32>>,
) -> anyhow::Result<PanelStore> {
    Ok(match enc {
        PanelQuant::None => PanelStore::F32(r_f32s(r, n)?),
        PanelQuant::F16 => PanelStore::F16(r_u16s(r, n)?),
        PanelQuant::Bf16 => PanelStore::Bf16(r_u16s(r, n)?),
        PanelQuant::I8 => PanelStore::I8 {
            data: r_i8s(r, n)?,
            scales: scales.ok_or_else(|| anyhow::anyhow!("i8 segment without scales"))?,
        },
    })
}

/// Per-row shape of one side, validated for homogeneity at save time.
#[derive(Clone, Copy, PartialEq, Eq)]
struct Shape {
    k: usize,
    orders: usize,
    nm: usize,
    two_sided: bool,
}

/// Save every row of `store` to `path` (format v5: map rows row-wise,
/// columnar segments as contiguous panels in their stored encoding,
/// each with its zone summary). `p` is the distance order
/// the sketches were built for (recorded for load-time validation);
/// `projection` records the projection seed + distribution so the
/// restored store can sketch fresh query vectors consistently (pass
/// `None` only when the parameters are genuinely unknown, e.g. when
/// re-saving a store restored from a pre-v3 file).
///
/// The whole file is written from **one epoch snapshot**: ids, rows,
/// and segments all come from the same consistent cut, ingest is never
/// paused for the write, and a concurrent insert can neither tear the
/// row count nor slip between the header and the body.
pub fn save(
    store: &SketchStore,
    p: usize,
    projection: Option<ProjectionInfo>,
    path: &Path,
) -> anyhow::Result<SketchFileHeader> {
    let snap = store.snapshot();
    let map_ids = snap.map_ids();
    let segments: Vec<_> = snap
        .segments()
        .iter()
        .map(|s| (s.base, Arc::clone(&s.block), Arc::clone(&s.zone)))
        .collect();
    // Probe shape from the first map row or the first segment (empty
    // stores save an empty file with zeroed shape — loadable, yields an
    // empty store).
    let probe_row = map_ids.first().map(|&id| snap.get(id).expect("listed id"));
    let shape = match (&probe_row, segments.first()) {
        (Some(rs), _) => Some(Shape {
            k: rs.uside.k,
            orders: rs.uside.orders,
            nm: rs.moments.len(),
            two_sided: rs.vside_data.is_some(),
        }),
        (None, Some((_, block, _))) => Some(Shape {
            k: block.k(),
            orders: block.orders(),
            nm: block.moment_orders(),
            two_sided: block.is_two_sided(),
        }),
        (None, None) => None,
    };
    let shape = shape.unwrap_or(Shape { k: 0, orders: 0, nm: 0, two_sided: false });
    let seg_rows: usize = segments.iter().map(|(_, b, _)| b.rows()).sum();
    let header = SketchFileHeader {
        p: p as u32,
        k: shape.k as u32,
        orders: shape.orders as u32,
        moment_orders: shape.nm as u32,
        two_sided: shape.two_sided,
        rows: (map_ids.len() + seg_rows) as u64,
        map_rows: map_ids.len() as u64,
        segments: segments.len() as u64,
        projection,
    };
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    w.write_all(MAGIC)?;
    w_u32(&mut w, VERSION)?;
    w_u32(&mut w, header.p)?;
    w_u32(&mut w, header.k)?;
    w_u32(&mut w, header.orders)?;
    w_u32(&mut w, header.moment_orders)?;
    w.write_all(&[header.two_sided as u8])?;
    w_u64(&mut w, header.rows)?;
    w_u64(&mut w, header.map_rows)?;
    w_u64(&mut w, header.segments)?;
    match &header.projection {
        Some(info) => {
            w.write_all(&[1u8])?;
            w_u64(&mut w, info.seed)?;
            let (tag, param) = match info.dist {
                ProjectionDist::Normal => (DIST_NORMAL, 0.0),
                ProjectionDist::Uniform => (DIST_UNIFORM, 0.0),
                ProjectionDist::ThreePoint(s) => (DIST_THREE_POINT, s),
            };
            w.write_all(&[tag])?;
            w.write_all(&param.to_le_bytes())?;
        }
        None => w.write_all(&[0u8])?,
    }
    for id in map_ids {
        let rs = snap.get(id).expect("listed id");
        let row_shape = Shape {
            k: rs.uside.k,
            orders: rs.uside.orders,
            nm: rs.moments.len(),
            two_sided: rs.vside_data.is_some(),
        };
        anyhow::ensure!(row_shape == shape, "heterogeneous store (row {id})");
        w_u64(&mut w, id)?;
        w_f32s(&mut w, &rs.uside.data)?;
        if let Some(v) = &rs.vside_data {
            w_f32s(&mut w, &v.data)?;
        }
        w_f64s(&mut w, &rs.moments.0)?;
    }
    for (base, block, zone) in &segments {
        let block_shape = Shape {
            k: block.k(),
            orders: block.orders(),
            nm: block.moment_orders(),
            two_sided: block.is_two_sided(),
        };
        anyhow::ensure!(block_shape == shape, "heterogeneous store (segment at {base})");
        w_u64(&mut w, *base)?;
        w_u64(&mut w, block.rows() as u64)?;
        // v5 encoding trailer: tag byte (+ per-order i8 scales), pinned
        // by its own CRC — the tag decides bytes-per-value for the rest
        // of the segment, so it must not be trusted un-checksummed.
        let mut ebytes = vec![block.encoding().tag()];
        if let Some(scales) = block.u_store().i8_scales() {
            for x in scales {
                ebytes.extend_from_slice(&x.to_le_bytes());
            }
            if let Some(vs) = block.v_store() {
                for x in vs.i8_scales().expect("cross-side encodings match") {
                    ebytes.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
        w.write_all(&ebytes)?;
        w_u32(&mut w, crc32(&ebytes))?;
        // Panels ride in their stored encoding; moments stay f64.
        w_store(&mut w, block.u_store())?;
        if let Some(vs) = block.v_store() {
            w_store(&mut w, vs)?;
        }
        w_f64s(&mut w, block.moments_all())?;
        // v4 zone trailer: word count, payload, CRC of the payload
        // bytes. The serialized zone is the one the serving path uses —
        // the store's live summary rides verbatim, it is not recomputed.
        let zvals = zone.to_f64s(shape.two_sided);
        let mut zbytes = Vec::with_capacity(zvals.len() * 8);
        for x in &zvals {
            zbytes.extend_from_slice(&x.to_le_bytes());
        }
        w_u32(&mut w, zvals.len() as u32)?;
        w.write_all(&zbytes)?;
        w_u32(&mut w, crc32(&zbytes))?;
    }
    w.flush()?;
    Ok(header)
}

/// Parse the fixed header fields after the version word.
fn read_header_body(r: &mut impl Read, version: u32) -> anyhow::Result<SketchFileHeader> {
    let p = r_u32(r)?;
    let k = r_u32(r)?;
    let orders = r_u32(r)?;
    let moment_orders = r_u32(r)?;
    let mut flag = [0u8; 1];
    r.read_exact(&mut flag)?;
    let rows = r_u64(r)?;
    let (map_rows, segments) = if version >= 2 { (r_u64(r)?, r_u64(r)?) } else { (rows, 0) };
    // v3 appends the projection trailer; older files simply don't have
    // it (backward-compatible field append, gated by the version word).
    let projection = if version >= 3 {
        let mut has = [0u8; 1];
        r.read_exact(&mut has)?;
        match has[0] {
            0 => None,
            1 => {
                let seed = r_u64(r)?;
                let mut tag = [0u8; 1];
                r.read_exact(&mut tag)?;
                let mut param = [0u8; 8];
                r.read_exact(&mut param)?;
                let param = f64::from_le_bytes(param);
                let dist = match tag[0] {
                    DIST_NORMAL => ProjectionDist::Normal,
                    DIST_UNIFORM => ProjectionDist::Uniform,
                    DIST_THREE_POINT => {
                        anyhow::ensure!(
                            param.is_finite() && param >= 1.0,
                            "corrupt three-point parameter {param}"
                        );
                        ProjectionDist::ThreePoint(param)
                    }
                    t => anyhow::bail!("unknown projection distribution tag {t}"),
                };
                Some(ProjectionInfo { seed, dist })
            }
            f => anyhow::bail!("corrupt projection flag {f}"),
        }
    } else {
        None
    };
    let header = SketchFileHeader {
        p,
        k,
        orders,
        moment_orders,
        two_sided: flag[0] != 0,
        rows,
        map_rows,
        segments,
        projection,
    };
    anyhow::ensure!(header.k as usize <= MAX_K, "implausible sketch width {}", header.k);
    anyhow::ensure!(
        header.orders as usize <= MAX_ORDERS,
        "implausible order count {}",
        header.orders
    );
    anyhow::ensure!(
        header.moment_orders as usize <= MAX_MOMENT_ORDERS,
        "implausible moment count {}",
        header.moment_orders
    );
    anyhow::ensure!(header.map_rows <= header.rows, "map rows exceed total rows");
    if header.rows > 0 {
        // Every writer (v1 and v2) produces moments = 2·orders with
        // nonzero k and orders; anything else would index out of bounds
        // at query time (`norm_p` reads moment p = orders + 1), so
        // reject it here with an error. (`p` itself is advisory — the
        // serving config decides the decomposition.)
        anyhow::ensure!(
            header.orders >= 1 && header.k >= 1 && header.moment_orders == 2 * header.orders,
            "inconsistent sketch shape (orders={}, k={}, moments={})",
            header.orders,
            header.k,
            header.moment_orders
        );
    } else {
        anyhow::ensure!(header.segments == 0, "zero-row file declares segments");
    }
    Ok(header)
}

fn read_magic_version(r: &mut impl Read) -> anyhow::Result<u32> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "not a sketch file");
    let version = r_u32(r)?;
    anyhow::ensure!(
        version >= 1 && version <= VERSION,
        "unsupported sketch-file version {version}"
    );
    Ok(version)
}

/// Read just the header (cheap compatibility probe). Handles v1 and v2.
pub fn read_header(path: &Path) -> anyhow::Result<SketchFileHeader> {
    let mut r = BufReader::new(std::fs::File::open(path)?);
    let version = read_magic_version(&mut r)?;
    read_header_body(&mut r, version)
}

/// Read one row-wise map entry (shared by the v1 body and the v2 map
/// section).
fn read_map_row(r: &mut impl Read, h: &SketchFileHeader) -> anyhow::Result<(u64, RowSketch)> {
    let (orders, k, nm) = (h.orders as usize, h.k as usize, h.moment_orders as usize);
    let id = r_u64(r)?;
    let udata = r_f32s(r, orders * k)?;
    let vside_data = if h.two_sided {
        Some(SketchSet { orders, k, data: r_f32s(r, orders * k)? })
    } else {
        None
    };
    let moments = Moments(r_f64s(r, nm)?);
    Ok((id, RowSketch { uside: SketchSet { orders, k, data: udata }, vside_data, moments }))
}

/// Load a sketch file into a fresh store with `shards` shards. v2+
/// files reconstruct their columnar segments verbatim; v4+ files also
/// restore each segment's zone summary as stored (via
/// [`SketchStore::insert_block_prezoned`]), while v2/v3 segments land
/// through [`SketchStore::insert_block_columnar`], which recomputes the
/// zone from the panels. v5 segments restore in their stored panel
/// encoding (pre-v5 segments are always f32); the prezoned path never
/// re-encodes, so quantized segments come back bit-identical. v1 files
/// load every row into the per-row map, as they were saved.
pub fn load(path: &Path, shards: usize) -> anyhow::Result<(SketchStore, SketchFileHeader)> {
    let file = std::fs::File::open(path)?;
    let file_len = file.metadata()?.len();
    let mut r = BufReader::new(file);
    let version = read_magic_version(&mut r)?;
    let header = read_header_body(&mut r, version)?;
    let (orders, k, nm) = (
        header.orders as usize,
        header.k as usize,
        header.moment_orders as usize,
    );
    // Every declared payload must fit in the file: catches truncation
    // and garbage counts before any large allocation.
    let row_bytes = 8
        + (orders * k * 4) as u64 * if header.two_sided { 2 } else { 1 }
        + (nm * 8) as u64;
    anyhow::ensure!(
        header.map_rows.saturating_mul(row_bytes) <= file_len,
        "declared map rows exceed file size (truncated or corrupt)"
    );
    let store = SketchStore::new(shards);
    let mut map_ids: Vec<u64> = Vec::with_capacity(header.map_rows as usize);
    for _ in 0..header.map_rows {
        let (id, rs) = read_map_row(&mut r, &header)?;
        map_ids.push(id);
        store.insert(id, rs);
    }
    map_ids.sort_unstable();
    // A duplicate id would silently collapse via insert-overwrite and
    // leave the store with fewer rows than the header declares.
    anyhow::ensure!(
        map_ids.windows(2).all(|w| w[0] != w[1]),
        "duplicate map row id (corrupt file)"
    );
    let mut seg_rows_total = 0u64;
    let mut prev_end = 0u64;
    let sides = if header.two_sided { 2usize } else { 1 };
    for s in 0..header.segments {
        let base = r_u64(&mut r)?;
        let rows = r_u64(&mut r)?;
        anyhow::ensure!(rows > 0, "segment {s} is empty");
        // v5 encoding trailer. The tag is validated *first* — it sets
        // bytes-per-value for the whole segment, so an unknown tag must
        // be rejected before any panel buffer is sized.
        let (enc, mut u_scales, mut v_scales) = if version >= 5 {
            let mut ebytes = vec![0u8; 1];
            r.read_exact(&mut ebytes)?;
            let enc = PanelQuant::from_tag(ebytes[0]).ok_or_else(|| {
                anyhow::anyhow!("segment {s} has unknown panel-encoding tag {}", ebytes[0])
            })?;
            let (us, vs) = if enc == PanelQuant::I8 {
                let mut sbytes = vec![0u8; orders * 4 * sides];
                r.read_exact(&mut sbytes)?;
                ebytes.extend_from_slice(&sbytes);
                let all: Vec<f32> = sbytes
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().expect("4-byte chunk")))
                    .collect();
                anyhow::ensure!(
                    all.iter().all(|x| x.is_finite() && *x >= 0.0),
                    "segment {s} has a non-finite or negative i8 scale"
                );
                let (u, v) = all.split_at(orders);
                (Some(u.to_vec()), header.two_sided.then(|| v.to_vec()))
            } else {
                (None, None)
            };
            let want_crc = r_u32(&mut r)?;
            anyhow::ensure!(
                crc32(&ebytes) == want_crc,
                "segment {s} panel-encoding checksum mismatch (corrupt)"
            );
            (enc, us, vs)
        } else {
            // Pre-v5 files always hold f32 panels.
            (PanelQuant::None, None, None)
        };
        // Bytes one segment row occupies in the panels section, under
        // this segment's encoding — exact accounting before allocation.
        let seg_row_bytes =
            (orders * k * enc.bytes_per_value()) as u64 * sides as u64 + (nm * 8) as u64;
        anyhow::ensure!(
            rows.checked_mul(seg_row_bytes).is_some_and(|b| b <= file_len),
            "segment {s} declares more rows than the file holds (truncated or corrupt)"
        );
        let end = base
            .checked_add(rows)
            .ok_or_else(|| anyhow::anyhow!("segment {s} id range overflows"))?;
        anyhow::ensure!(
            s == 0 || base >= prev_end,
            "segment {s} overlaps its predecessor (corrupt segment directory)"
        );
        // A map row inside the segment's range would trip the store's
        // collision panic; reject the file with an error instead.
        let lo = map_ids.partition_point(|&id| id < base);
        anyhow::ensure!(
            !map_ids.get(lo).is_some_and(|&id| id < end),
            "segment {s} range [{base}, {end}) collides with a map row"
        );
        prev_end = end;
        let rows = rows as usize;
        // The per-order u panels are stored consecutively, so the whole
        // u (and v) buffer reads as one contiguous chunk — exactly the
        // block's internal layout, in the segment's stored encoding.
        let u = r_store(&mut r, enc, orders * rows * k, u_scales.take())?;
        let v = if header.two_sided {
            Some(r_store(&mut r, enc, orders * rows * k, v_scales.take())?)
        } else {
            None
        };
        let moments = r_f64s(&mut r, rows * nm)?;
        let block = ColumnarBlock::from_stores(orders, k, nm, rows, u, v, moments);
        if version >= 4 {
            // Zone trailer: the declared word count must match the
            // shape exactly — checked before the payload buffer exists,
            // so an inflated count is an error, never an allocation.
            let zone_len = r_u32(&mut r)? as usize;
            let want_len = ZoneMeta::encoded_len(nm, orders, header.two_sided);
            anyhow::ensure!(
                zone_len == want_len,
                "segment {s} declares a zone of {zone_len} words; shape requires {want_len}"
            );
            let mut zbytes = vec![0u8; zone_len * 8];
            r.read_exact(&mut zbytes)?;
            let want_crc = r_u32(&mut r)?;
            anyhow::ensure!(
                crc32(&zbytes) == want_crc,
                "segment {s} zone checksum mismatch (corrupt)"
            );
            let zvals: Vec<f64> = zbytes
                .chunks_exact(8)
                .map(|c| f64::from_le_bytes(c.try_into().expect("8-byte chunk")))
                .collect();
            let zone = ZoneMeta::from_f64s(rows, nm, orders, header.two_sided, &zvals)?;
            store.insert_block_prezoned(base, Arc::new(block), Arc::new(zone));
        } else {
            // Pre-v4 files carry no zones — recompute from the panels.
            store.insert_block_columnar(base, block);
        }
        seg_rows_total += rows as u64;
    }
    anyhow::ensure!(
        header.map_rows + seg_rows_total == header.rows,
        "row count mismatch: header declares {} rows, body holds {}",
        header.rows,
        header.map_rows + seg_rows_total
    );
    Ok((store, header))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::decompose::Decomposition;
    use crate::core::estimator;
    use crate::projection::sketcher::Sketcher;
    use crate::projection::{ProjectionDist, ProjectionSpec, Strategy};

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("lpsketch_persist_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn filled_store(strategy: Strategy, n: u64) -> SketchStore {
        let sk = Sketcher::new(ProjectionSpec::new(5, 8, ProjectionDist::Normal, strategy), 4);
        let store = SketchStore::new(3);
        for id in 0..n {
            let row: Vec<f32> = (0..20).map(|i| ((id + 1) as f32 * 0.1 + i as f32 * 0.01).sin()).collect();
            store.insert(id, sk.sketch_row(&row));
        }
        store
    }

    /// The projection the `filled_store` sketcher uses — what a real
    /// caller records so the restore can sketch fresh vectors.
    fn proj() -> ProjectionInfo {
        ProjectionInfo { seed: 5, dist: ProjectionDist::Normal }
    }

    #[test]
    fn roundtrip_basic_strategy() {
        let store = filled_store(Strategy::Basic, 17);
        let path = tmp("basic.lpsk");
        let saved = save(&store, 4, Some(proj()), &path).unwrap();
        assert_eq!(saved.rows, 17);
        assert_eq!(saved.map_rows, 17);
        assert_eq!(saved.segments, 0);
        assert!(!saved.two_sided);
        assert_eq!(saved.projection, Some(proj()));
        let (loaded, header) = load(&path, 5).unwrap();
        assert_eq!(header, saved);
        assert_eq!(loaded.ids(), store.ids());
        // Estimates identical through the roundtrip.
        let dec = Decomposition::new(4).unwrap();
        let before = store.with_pair(1, 9, |a, b| estimator::estimate(&dec, a, b)).unwrap();
        let after = loaded.with_pair(1, 9, |a, b| estimator::estimate(&dec, a, b)).unwrap();
        assert_eq!(before, after);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn roundtrip_alternative_strategy() {
        let store = filled_store(Strategy::Alternative, 9);
        let path = tmp("alt.lpsk");
        let saved = save(&store, 4, Some(proj()), &path).unwrap();
        assert!(saved.two_sided);
        let (loaded, _) = load(&path, 2).unwrap();
        for id in 0..9u64 {
            let a = store.get(id).unwrap();
            let b = loaded.get(id).unwrap();
            assert_eq!(a.uside.data, b.uside.data);
            assert_eq!(a.vside().data, b.vside().data);
            assert_eq!(a.moments.0, b.moments.0);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn roundtrip_preserves_columnar_segments() {
        // The PR-3 regression pin: before this, save de-columnarized
        // every row and load rebuilt the map, silently losing the
        // segment layout (and with it the memcpy snapshot path).
        for strategy in [Strategy::Basic, Strategy::Alternative] {
            let sk = Sketcher::new(
                ProjectionSpec::new(5, 8, ProjectionDist::Normal, strategy),
                4,
            );
            let store = SketchStore::new(3);
            store.insert(2, sk.sketch_row(&[0.4, -0.1, 0.9]));
            let rows: Vec<Vec<f32>> = (0..7)
                .map(|i| (0..20).map(|t| ((i * 13 + t) as f32 * 0.17).sin()).collect())
                .collect();
            let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
            store.insert_block_columnar(10, sk.sketch_block(&refs[..4], 1)); // 10..14
            store.insert_block_columnar(14, sk.sketch_block(&refs[4..], 1)); // 14..17
            let path = tmp(&format!("segments_{strategy:?}.lpsk"));
            let saved = save(&store, 4, Some(proj()), &path).unwrap();
            assert_eq!(saved.rows, 8);
            assert_eq!(saved.map_rows, 1);
            assert_eq!(saved.segments, 2);
            let (loaded, header) = load(&path, 4).unwrap();
            assert_eq!(header, saved);
            // Columnar layout survives verbatim: same segment directory,
            // bitwise-equal blocks, same byte accounting.
            assert_eq!(loaded.segments_snapshot(), store.segments_snapshot());
            assert_eq!(loaded.bytes(), store.bytes());
            assert_eq!(loaded.map_ids(), vec![2]);
            assert_eq!(loaded.ids(), store.ids());
            std::fs::remove_file(&path).ok();
        }
    }

    #[test]
    fn header_probe_without_full_read() {
        let store = filled_store(Strategy::Basic, 4);
        let path = tmp("probe.lpsk");
        save(&store, 6, Some(proj()), &path).unwrap();
        let h = read_header(&path).unwrap();
        assert_eq!(h.p, 6);
        assert_eq!(h.rows, 4);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_garbage_file() {
        let path = tmp("garbage.lpsk");
        std::fs::write(&path, b"not a sketch file at all").unwrap();
        assert!(load(&path, 1).is_err());
        assert!(read_header(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn empty_store_roundtrips() {
        let store = SketchStore::new(2);
        let path = tmp("empty.lpsk");
        let saved = save(&store, 4, None, &path).unwrap();
        assert_eq!(saved.rows, 0);
        let (loaded, _) = load(&path, 2).unwrap();
        assert!(loaded.is_empty());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn projection_trailer_roundtrips_every_distribution() {
        let store = filled_store(Strategy::Basic, 3);
        for (name, info) in [
            ("none", None),
            ("normal", Some(ProjectionInfo { seed: 42, dist: ProjectionDist::Normal })),
            ("uniform", Some(ProjectionInfo { seed: 7, dist: ProjectionDist::Uniform })),
            (
                "threepoint",
                Some(ProjectionInfo { seed: u64::MAX, dist: ProjectionDist::ThreePoint(16.0) }),
            ),
        ] {
            let path = tmp(&format!("proj_{name}.lpsk"));
            let saved = save(&store, 4, info, &path).unwrap();
            assert_eq!(saved.projection, info);
            assert_eq!(read_header(&path).unwrap().projection, info);
            let (loaded, header) = load(&path, 2).unwrap();
            assert_eq!(header.projection, info);
            assert_eq!(loaded.ids(), store.ids());
            std::fs::remove_file(&path).ok();
        }
    }

    #[test]
    fn legacy_v2_files_load_with_unknown_projection() {
        // Hand-rolled old-v2 writer (header ends at the segment count;
        // no projection trailer): such files must keep loading, with
        // `projection: None` telling the restore that fresh-vector
        // queries are off the table.
        let store = filled_store(Strategy::Basic, 5);
        let ids = store.ids();
        let probe = store.get(ids[0]).unwrap();
        let (k, orders, nm) = (probe.uside.k, probe.uside.orders, probe.moments.len());
        let mut out: Vec<u8> = Vec::new();
        out.extend_from_slice(b"LPSK");
        for v in [2u32, 4, k as u32, orders as u32, nm as u32] {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out.push(0u8); // one-sided
        for v in [ids.len() as u64, ids.len() as u64, 0u64] {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for id in ids {
            let rs = store.get(id).unwrap();
            out.extend_from_slice(&id.to_le_bytes());
            for x in &rs.uside.data {
                out.extend_from_slice(&x.to_le_bytes());
            }
            for o in 1..=nm {
                out.extend_from_slice(&rs.moments.get(o).to_le_bytes());
            }
        }
        let path = tmp("legacy_v2.lpsk");
        std::fs::write(&path, out).unwrap();
        let header = read_header(&path).unwrap();
        assert_eq!(header.projection, None);
        assert_eq!(header.rows, 5);
        let (loaded, _) = load(&path, 3).unwrap();
        assert_eq!(loaded.ids(), store.ids());
        for id in loaded.ids() {
            assert_eq!(loaded.get(id).unwrap().uside.data, store.get(id).unwrap().uside.data);
        }
        std::fs::remove_file(&path).ok();
    }

    /// Build a store whose rows all live in columnar segments (the
    /// zone-bearing representation).
    fn segmented_store(strategy: Strategy) -> SketchStore {
        let sk = Sketcher::new(ProjectionSpec::new(5, 8, ProjectionDist::Normal, strategy), 4);
        let store = SketchStore::new(3);
        let rows: Vec<Vec<f32>> = (0..9)
            .map(|i| (0..20).map(|t| ((i * 7 + t) as f32 * 0.23).sin()).collect())
            .collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        store.insert_block_columnar(10, sk.sketch_block(&refs[..5], 1)); // 10..15
        store.insert_block_columnar(40, sk.sketch_block(&refs[5..], 1)); // 40..44
        store
    }

    #[test]
    fn roundtrip_preserves_segment_zones() {
        for strategy in [Strategy::Basic, Strategy::Alternative] {
            let store = segmented_store(strategy);
            let path = tmp(&format!("zones_{strategy:?}.lpsk"));
            save(&store, 4, Some(proj()), &path).unwrap();
            let (loaded, _) = load(&path, 2).unwrap();
            let before = store.segments_snapshot_zoned();
            let after = loaded.segments_snapshot_zoned();
            assert_eq!(before.len(), after.len());
            for ((b_base, _, b_zone), (a_base, _, a_zone)) in before.iter().zip(&after) {
                assert_eq!(b_base, a_base);
                assert_eq!(**b_zone, **a_zone, "zone must survive the roundtrip bitwise");
            }
            std::fs::remove_file(&path).ok();
        }
    }

    #[test]
    fn v4_zone_trailer_is_adopted_verbatim_not_recomputed() {
        // The proof that v4 loads *trust* the stored zone: deflate one
        // word of the last segment's zone (a smaller minimum only
        // loosens the lower bound, so the crafted zone stays
        // admissible), fix the CRC, and the load must surface the
        // deflated value — not a recomputation from the panels.
        let store = segmented_store(Strategy::Basic);
        let path = tmp("zone_adopt.lpsk");
        let header = save(&store, 4, Some(proj()), &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let zlen = ZoneMeta::encoded_len(
            header.moment_orders as usize,
            header.orders as usize,
            header.two_sided,
        );
        // The last segment's zone trailer ends the file:
        // [zone_len u32][payload f64·zlen][crc u32].
        let payload_at = bytes.len() - 4 - 8 * zlen;
        let original = store.segments_snapshot_zoned().pop().unwrap().2;
        let deflated = original.min_moment[0] - 1.0;
        bytes[payload_at..payload_at + 8].copy_from_slice(&deflated.to_le_bytes());
        let crc = crc32(&bytes[payload_at..bytes.len() - 4]);
        let crc_at = bytes.len() - 4;
        bytes[crc_at..].copy_from_slice(&crc.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let (loaded, _) = load(&path, 2).unwrap();
        let (_, _, lz) = loaded.segments_snapshot_zoned().pop().unwrap();
        assert_eq!(lz.min_moment[0], deflated, "stored zone must load verbatim");
        assert_ne!(*lz, *original);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn legacy_v3_files_load_with_zones_recomputed() {
        // Hand-rolled v3 writer (the current format minus the zone
        // trailer): segments must keep loading, with zones recomputed
        // from the panels at insertion.
        let sk = Sketcher::new(
            ProjectionSpec::new(5, 8, ProjectionDist::Normal, Strategy::Basic),
            4,
        );
        let rows: Vec<Vec<f32>> = (0..7)
            .map(|i| (0..20).map(|t| ((i * 11 + t) as f32 * 0.19).sin()).collect())
            .collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let block = sk.sketch_block(&refs, 1);
        let mut out: Vec<u8> = Vec::new();
        out.extend_from_slice(b"LPSK");
        for v in [3u32, 4, block.k() as u32, block.orders() as u32, block.moment_orders() as u32]
        {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out.push(0u8); // one-sided
        for v in [block.rows() as u64, 0u64, 1u64] {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out.push(0u8); // no projection recorded
        out.extend_from_slice(&5u64.to_le_bytes()); // base
        out.extend_from_slice(&(block.rows() as u64).to_le_bytes());
        for m in 1..=block.orders() {
            for x in block.u_order(m) {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        for x in block.moments_all() {
            out.extend_from_slice(&x.to_le_bytes());
        }
        let path = tmp("legacy_v3.lpsk");
        std::fs::write(&path, out).unwrap();
        let (loaded, header) = load(&path, 3).unwrap();
        assert_eq!(header.segments, 1);
        assert_eq!(header.projection, None);
        let segs = loaded.segments_snapshot_zoned();
        assert_eq!(segs.len(), 1);
        assert_eq!(segs[0].0, 5);
        assert_eq!(
            *segs[0].2,
            ZoneMeta::from_block(&segs[0].1),
            "v3 segments recompute their zone at load"
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupt_zone_trailer_errors_never_panics() {
        let store = segmented_store(Strategy::Alternative);
        let path = tmp("zone_corrupt.lpsk");
        let header = save(&store, 4, Some(proj()), &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let zlen = ZoneMeta::encoded_len(
            header.moment_orders as usize,
            header.orders as usize,
            header.two_sided,
        );
        let trailer_at = bytes.len() - 8 - 8 * zlen;
        let attack = tmp("zone_attacked.lpsk");
        // Every byte of the last zone trailer is load-bearing: flips in
        // the count trip the length check, flips in the payload or the
        // CRC word trip the checksum comparison.
        for off in trailer_at..bytes.len() {
            let mut b = bytes.clone();
            b[off] ^= 0xFF;
            std::fs::write(&attack, &b).unwrap();
            assert!(load(&attack, 1).is_err(), "flip at {off} must error");
        }
        // Truncation anywhere inside the trailer errors too.
        for len in trailer_at..bytes.len() {
            std::fs::write(&attack, &bytes[..len]).unwrap();
            assert!(load(&attack, 1).is_err(), "truncation to {len} must error");
        }
        // An inflated word count must be rejected by the shape check —
        // before a multi-gigabyte zone buffer could be allocated.
        let mut b = bytes.clone();
        b[trailer_at..trailer_at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        std::fs::write(&attack, &b).unwrap();
        let err = load(&attack, 1).unwrap_err().to_string();
        assert!(err.contains("zone"), "unexpected error: {err}");
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&attack).ok();
    }

    /// Like `segmented_store`, with the store's panel quantization set
    /// before ingest so both segments land encoded.
    fn quantized_segmented_store(strategy: Strategy, q: PanelQuant) -> SketchStore {
        let sk = Sketcher::new(ProjectionSpec::new(5, 8, ProjectionDist::Normal, strategy), 4);
        let store = SketchStore::new(3);
        store.set_panel_quant(q);
        let rows: Vec<Vec<f32>> = (0..9)
            .map(|i| (0..20).map(|t| ((i * 7 + t) as f32 * 0.23).sin()).collect())
            .collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        store.insert_block_columnar(10, sk.sketch_block(&refs[..5], 1)); // 10..15
        store.insert_block_columnar(40, sk.sketch_block(&refs[5..], 1)); // 40..44
        store
    }

    #[test]
    fn roundtrip_preserves_quantized_segments() {
        // Quantized segments persist *as stored* — same encoding, same
        // bytes, same zones, bitwise-equal estimates — and the file is
        // strictly smaller than its f32 twin.
        let dec = Decomposition::new(4).unwrap();
        for strategy in [Strategy::Basic, Strategy::Alternative] {
            let f32_path = tmp(&format!("quant_f32_{strategy:?}.lpsk"));
            save(&segmented_store(strategy), 4, Some(proj()), &f32_path).unwrap();
            let f32_len = std::fs::metadata(&f32_path).unwrap().len();
            for q in [PanelQuant::F16, PanelQuant::Bf16, PanelQuant::I8] {
                let store = quantized_segmented_store(strategy, q);
                let path = tmp(&format!("quant_{}_{strategy:?}.lpsk", q.name()));
                let saved = save(&store, 4, Some(proj()), &path).unwrap();
                assert_eq!(saved.segments, 2);
                assert!(
                    std::fs::metadata(&path).unwrap().len() < f32_len,
                    "{q:?} file must be smaller than the f32 twin"
                );
                let (loaded, header) = load(&path, 2).unwrap();
                assert_eq!(header, saved);
                assert_eq!(loaded.segments_snapshot(), store.segments_snapshot());
                assert_eq!(loaded.bytes(), store.bytes());
                for ((_, _, bz), (_, _, az)) in store
                    .segments_snapshot_zoned()
                    .iter()
                    .zip(&loaded.segments_snapshot_zoned())
                {
                    assert_eq!(**bz, **az, "zones survive the roundtrip bitwise");
                }
                for (_, block) in loaded.segments_snapshot() {
                    assert_eq!(block.encoding(), q);
                }
                assert_eq!(
                    store.estimate_pair_plain(&dec, 11, 41),
                    loaded.estimate_pair_plain(&dec, 11, 41),
                    "quantized estimates identical through the roundtrip"
                );
                std::fs::remove_file(&path).ok();
            }
            std::fs::remove_file(&f32_path).ok();
        }
    }

    #[test]
    fn mixed_encoding_stores_roundtrip_per_segment() {
        // The encoding tag is per segment: a store whose quantization
        // setting changed mid-life holds mixed segments, and each must
        // come back in its own encoding.
        let sk = Sketcher::new(
            ProjectionSpec::new(5, 8, ProjectionDist::Normal, Strategy::Basic),
            4,
        );
        let store = SketchStore::new(2);
        let rows: Vec<Vec<f32>> = (0..8)
            .map(|i| (0..20).map(|t| ((i * 5 + t) as f32 * 0.31).sin()).collect())
            .collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        store.insert_block_columnar(0, sk.sketch_block(&refs[..4], 1)); // f32
        store.set_panel_quant(PanelQuant::I8);
        store.insert_block_columnar(4, sk.sketch_block(&refs[4..], 1)); // i8
        let path = tmp("mixed_enc.lpsk");
        save(&store, 4, Some(proj()), &path).unwrap();
        let (loaded, _) = load(&path, 2).unwrap();
        let segs = loaded.segments_snapshot();
        assert_eq!(segs.len(), 2);
        assert_eq!(segs[0].1.encoding(), PanelQuant::None);
        assert_eq!(segs[1].1.encoding(), PanelQuant::I8);
        assert_eq!(segs, store.segments_snapshot());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupt_panel_encoding_trailer_errors_never_panics() {
        let store = quantized_segmented_store(Strategy::Basic, PanelQuant::I8);
        let path = tmp("enc_corrupt.lpsk");
        let header = save(&store, 4, Some(proj()), &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // Header layout (with projection): magic(4) version(4) p(4)
        // k(4) orders(4) nm(4) flag(1) rows(8) map_rows(8) segments(8)
        // has_proj(1) seed(8) dist(1) param(8) = 67 bytes; first segment
        // follows with base(8) rows(8), so its encoding trailer —
        // tag(1) + scales(orders·4, one-sided i8) + crc(4) — starts at
        // byte 83.
        let trailer_at = 67 + 16;
        let trailer_len = 1 + header.orders as usize * 4 + 4;
        let attack = tmp("enc_attacked.lpsk");
        // Every byte of the trailer is load-bearing: a flipped tag is
        // unknown (or fails the CRC), flipped scales and flipped CRC
        // words fail the checksum comparison.
        for off in trailer_at..trailer_at + trailer_len {
            let mut b = bytes.clone();
            b[off] ^= 0xFF;
            std::fs::write(&attack, &b).unwrap();
            assert!(load(&attack, 1).is_err(), "flip at {off} must error");
        }
        // Truncation anywhere inside the trailer, and inside the panels
        // that follow it, errors too.
        for len in trailer_at..trailer_at + trailer_len + 5 {
            std::fs::write(&attack, &bytes[..len]).unwrap();
            assert!(load(&attack, 1).is_err(), "truncation to {len} must error");
        }
        // An unknown tag is rejected by name, before any panel buffer
        // is sized from it.
        let mut b = bytes.clone();
        b[trailer_at] = 200;
        std::fs::write(&attack, &b).unwrap();
        let err = load(&attack, 1).unwrap_err().to_string();
        assert!(err.contains("unknown panel-encoding tag"), "unexpected error: {err}");
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&attack).ok();
    }

    #[test]
    fn legacy_v4_files_load_as_f32_with_zones_adopted() {
        // Hand-rolled v4 writer (the current format minus the encoding
        // trailer): panels are implicitly f32, and the zone trailer is
        // still adopted verbatim.
        let sk = Sketcher::new(
            ProjectionSpec::new(5, 8, ProjectionDist::Normal, Strategy::Basic),
            4,
        );
        let rows: Vec<Vec<f32>> = (0..6)
            .map(|i| (0..20).map(|t| ((i * 3 + t) as f32 * 0.29).sin()).collect())
            .collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let block = sk.sketch_block(&refs, 1);
        let zone = ZoneMeta::from_block(&block);
        let mut out: Vec<u8> = Vec::new();
        out.extend_from_slice(b"LPSK");
        for v in [4u32, 4, block.k() as u32, block.orders() as u32, block.moment_orders() as u32]
        {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out.push(0u8); // one-sided
        for v in [block.rows() as u64, 0u64, 1u64] {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out.push(0u8); // no projection recorded
        out.extend_from_slice(&7u64.to_le_bytes()); // base
        out.extend_from_slice(&(block.rows() as u64).to_le_bytes());
        for m in 1..=block.orders() {
            for x in block.u_order(m) {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        for x in block.moments_all() {
            out.extend_from_slice(&x.to_le_bytes());
        }
        let zvals = zone.to_f64s(false);
        let mut zbytes = Vec::with_capacity(zvals.len() * 8);
        for x in &zvals {
            zbytes.extend_from_slice(&x.to_le_bytes());
        }
        out.extend_from_slice(&(zvals.len() as u32).to_le_bytes());
        out.extend_from_slice(&zbytes);
        out.extend_from_slice(&crc32(&zbytes).to_le_bytes());
        let path = tmp("legacy_v4.lpsk");
        std::fs::write(&path, out).unwrap();
        let (loaded, header) = load(&path, 2).unwrap();
        assert_eq!(header.segments, 1);
        let segs = loaded.segments_snapshot_zoned();
        assert_eq!(segs.len(), 1);
        assert_eq!(segs[0].0, 7);
        assert_eq!(segs[0].1.encoding(), PanelQuant::None);
        assert_eq!(*segs[0].2, zone, "v4 zones still adopt verbatim");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupt_projection_trailer_errors() {
        let store = filled_store(Strategy::Basic, 2);
        let path = tmp("proj_attack.lpsk");
        save(&store, 4, Some(proj()), &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // Header layout: magic(4) version(4) p(4) k(4) orders(4) nm(4)
        // flag(1) rows(8) map_rows(8) segments(8) → has_projection at 49.
        let attack = tmp("proj_attacked.lpsk");
        for (off, val, what) in [
            (49usize, 7u8, "bad projection flag"),
            (58, 9, "bad distribution tag"),
        ] {
            let mut b = bytes.clone();
            b[off] = val;
            std::fs::write(&attack, &b).unwrap();
            assert!(load(&attack, 1).is_err(), "{what} must error");
            assert!(read_header(&attack).is_err(), "{what} header probe must error");
        }
        // A three-point tag with a garbage parameter must error too.
        let mut b = bytes.clone();
        b[58] = DIST_THREE_POINT;
        b[59..67].copy_from_slice(&f64::NAN.to_le_bytes());
        std::fs::write(&attack, &b).unwrap();
        assert!(load(&attack, 1).is_err(), "NaN three-point parameter must error");
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&attack).ok();
    }
}
