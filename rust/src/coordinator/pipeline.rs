//! The streaming pipeline: reader → sharded sketch workers → sketch
//! store, with bounded channels as backpressure, then a query side
//! (single pairs, batched pairs, all-pairs export).
//!
//! This is the paper's operating regime made concrete: the data matrix
//! streams through once (the "linear scan"), only O(nk) sketch state is
//! retained, and pairwise distances are answered on the fly from the
//! sketches — never stored O(n²), never recomputed O(D).
//!
//! Every batch reader (pair batches, top-k, all-pairs, the query
//! service) runs on a [`StoreSnapshot`]: an O(segments) capture of the
//! store's `Arc`-held state, so scans never pin the store locks and
//! ingest proceeds concurrently — the serving side of the epoch design
//! in [`super::state`].
//!
//! ## The unified query surface
//!
//! Every query enters as a typed [`Request`] and leaves as a typed
//! [`Response`] (see [`crate::api`]). [`Pipeline::answer`] is the
//! direct, single-snapshot dispatch; the query service
//! ([`Pipeline::spawn_query_service`]) is the batched concurrent layer:
//! `query_workers` threads drain one [`super::batcher::Batcher`] of
//! [`crate::api::ApiJob`]s in turn, each drained batch served by
//! [`Pipeline::serve_api_batch`] from one per-batch epoch snapshot
//! (re-captured only when ingest advanced the store), with
//! `snapshot_age` / `queries_in_flight` gauges observing it. Top-k
//! requests are served from an epoch-cached serving index refreshed
//! *incrementally* ([`crate::knn::KnnIndex::from_snapshot_incremental`]:
//! only segments newer than the cached epoch are re-indexed) — by
//! stored id (straight from the stored panels, zero materialization) or
//! by fresh vector (sketched with the pipeline's projection; rejected
//! with a clear error when the store was restored from a file that does
//! not record the projection parameters). All routes produce
//! bitwise-identical estimates.
//!
//! Compute backends per block:
//! * **PJRT** (`use_pjrt`): blocks padded to the artifact's batch B,
//!   executed on the AOT-compiled fused sketch kernel (L1/L2 of the
//!   stack). Used when an artifact matches (p, k) and D. Outputs land
//!   columnar (the artifact stacks are already order-major, so each
//!   (order, side) panel is one contiguous slice) unless `ingest_gemm`
//!   is off, which keeps the pinned per-row unpack reference.
//! * **pure rust GEMM** (`ingest_gemm`, default): the register-tiled
//!   block kernel (`Sketcher::sketch_block`), landing columnar segments
//!   in the store — no per-row AoS allocation, no store→arena repack.
//! * **pure rust per-row**: the [`Sketcher`] reference mirror, any
//!   shape; kept as the baseline the GEMM path is pinned against.

// Serving path: clippy backs the pallas-lint serving-no-panic rule.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::api::{ApiHandle, ApiJob, ApiStats, Request, Response, TopKTarget};
use crate::config::Config;
use crate::core::arena::SketchArena;
use crate::core::decompose::Decomposition;
use crate::core::estimator;
use crate::core::marginals::Moments;
use crate::core::mle::{self, Solve};
use crate::util::sync::MutexExt;
use crate::data::RowMatrix;
use crate::knn::KnnIndex;
use crate::projection::sketcher::{ColumnarBlock, RowSketch, SketchSet, Sketcher};
use crate::projection::Strategy;
use crate::runtime::{ArtifactMeta, Engine, EngineHandle, OpKind, OwnedInput};

use super::batcher::FlushReason;
use super::durable::Durability;
use super::metrics::{Metrics, Snapshot};
use super::router::Router;
use super::scheduler::{Block, BlockScheduler};
use super::state::{SketchStore, StoreSnapshot};

/// Outcome of one `ingest` call.
#[derive(Clone, Debug)]
pub struct IngestReport {
    pub rows: usize,
    pub blocks: usize,
    pub elapsed: Duration,
    /// Sketch bytes added (the O(nk) side of the storage claim).
    pub sketch_bytes: usize,
    /// Raw data bytes scanned (the O(nD) side).
    pub data_bytes: usize,
    /// Rows sketched via PJRT vs the rust fallback.
    pub pjrt_rows: usize,
}

/// The coordinator. Owns the sketch store; cheap to share behind `Arc`.
pub struct Pipeline {
    cfg: Config,
    dec: Decomposition,
    sketcher: Sketcher,
    store: SketchStore,
    metrics: Metrics,
    router: Router,
    next_id: AtomicU64,
    /// Serving-side KNN index, refreshed incrementally from a store
    /// snapshot whenever a top-k request observes a newer epoch than
    /// the cached build (unchanged segments carry over by `Arc`).
    knn_cache: Mutex<Option<(u64, Arc<ServingIndex>)>>,
    /// Row width of the first ingested block (0 = nothing ingested,
    /// e.g. a store restored from a sketch file, which does not record
    /// d). Fresh-vector queries validate against it when known — a
    /// client sending a wrong-width vector must get an error, not
    /// plausible-but-wrong estimates.
    ingest_d: AtomicU64,
    /// False only when the store was restored from a sketch file that
    /// does not record its projection parameters — fresh-vector queries
    /// (top-k by vector, vector distance) are then rejected with an
    /// error instead of sketching with the wrong projection and
    /// silently mis-scoring.
    projection_known: bool,
    /// PJRT state, present when `cfg.use_pjrt` and the engine started.
    pjrt: Option<PjrtPath>,
    /// Durability runtime (WAL + sealed segments), attached in durable
    /// mode. Ingest then inserts-then-logs every batch: a batch is
    /// acknowledged (ingest returns `Ok`) only after its WAL record is
    /// fsynced, so a crash can lose at most unacknowledged work.
    durability: Option<Arc<Durability>>,
    _engine: Option<Engine>,
}

/// One epoch's serving index: the snapshot-rebuilt [`KnnIndex`] plus
/// the store id of every index row.
struct ServingIndex {
    index: KnnIndex,
    ids: Vec<u64>,
}

struct PjrtPath {
    handle: EngineHandle,
    meta: ArtifactMeta,
}

/// Raw sketch-artifact outputs: (u stack, moment stack, v stack?).
type PjrtRaw = (Vec<f32>, Vec<f32>, Option<Vec<f32>>);

impl Pipeline {
    /// Build a pipeline. With `use_pjrt`, starts the engine and warms
    /// the matching sketch artifact; fails fast if none matches (p, k).
    pub fn new(cfg: Config) -> anyhow::Result<Self> {
        cfg.validate()?;
        let dec = Decomposition::new(cfg.p)?;
        let sketcher = Sketcher::new(cfg.projection_spec(), cfg.p);
        let (pjrt, engine) = if cfg.use_pjrt {
            let engine = Engine::start(&cfg.artifacts_dir)?;
            let op = match cfg.strategy {
                Strategy::Basic => OpKind::Sketch,
                Strategy::Alternative => OpKind::SketchAlt,
            };
            let meta = engine
                .handle()
                .manifest()
                .find_sketch(op, cfg.p, cfg.k)
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "no {} artifact for p={} k={} (rebuild with `make artifacts`)",
                        op.as_str(),
                        cfg.p,
                        cfg.k
                    )
                })?
                .clone();
            engine.handle().warm(&meta.name)?;
            (Some(PjrtPath { handle: engine.handle(), meta }), Some(engine))
        } else {
            (None, None)
        };
        let workers = cfg.workers;
        let store = SketchStore::new(workers);
        // Block ingest quantizes at the store boundary from here on;
        // per-row map entries and the WAL stay f32 regardless.
        store.set_panel_quant(cfg.panel_quant);
        Ok(Pipeline {
            dec,
            sketcher,
            store,
            metrics: Metrics::new(),
            router: Router::new_mod(workers),
            next_id: AtomicU64::new(0),
            knn_cache: Mutex::new(None),
            ingest_d: AtomicU64::new(0),
            projection_known: true,
            pjrt,
            durability: None,
            _engine: engine,
            cfg,
        })
    }

    /// Build a pipeline serving an existing store — the persistence
    /// restore path (`persist::load` → queries, no re-ingest; the O(nD)
    /// matrix is gone). Fresh ids continue past the store's maximum, and
    /// the store's sketch shape must match the config. Refreshes the
    /// `segment_count` gauge so a restore that silently lost its
    /// columnar segments is observable.
    pub fn with_store(cfg: Config, store: SketchStore) -> anyhow::Result<Self> {
        let mut pipeline = Self::new(cfg)?;
        let ids = store.ids();
        if let Some(&first) = ids.first() {
            let rs = store
                .get(first)
                .ok_or_else(|| anyhow::anyhow!("store lists id {first} but cannot serve it"))?;
            anyhow::ensure!(
                rs.uside.k == pipeline.cfg.k && rs.uside.orders == pipeline.cfg.p - 1,
                "store shape (k={}, orders={}) does not match config (k={}, p={})",
                rs.uside.k,
                rs.uside.orders,
                pipeline.cfg.k,
                pipeline.cfg.p,
            );
            // Sidedness must match too: adopting two-sided rows under a
            // basic-strategy config (or vice versa) would sketch queries
            // with the wrong projection pairing and silently mis-score.
            let two_sided = rs.vside_data.is_some();
            anyhow::ensure!(
                two_sided == matches!(pipeline.cfg.strategy, Strategy::Alternative),
                "store sidedness (two_sided={two_sided}) does not match config strategy {}",
                pipeline.cfg.strategy.as_str(),
            );
            pipeline.next_id = AtomicU64::new(ids.last().copied().unwrap_or(first) + 1);
        }
        // The adopted store keeps its existing segments as they are;
        // the config's encoding applies to blocks ingested from now on.
        store.set_panel_quant(pipeline.cfg.panel_quant);
        pipeline.store = store;
        pipeline
            .metrics
            .segment_count
            .store(pipeline.store.segment_count() as u64, Ordering::Relaxed);
        Ok(pipeline)
    }

    /// [`Pipeline::with_store`] for stores restored from a sketch file:
    /// `projection_known = false` marks a file that predates the
    /// recorded-projection header, disabling fresh-vector queries
    /// (which would otherwise sketch with an unrelated projection and
    /// return silently wrong estimates). Stored-id queries — pairs,
    /// top-k by id, all-pairs — are unaffected.
    pub fn with_store_restored(
        cfg: Config,
        store: SketchStore,
        projection_known: bool,
    ) -> anyhow::Result<Self> {
        let mut pipeline = Self::with_store(cfg, store)?;
        pipeline.projection_known = projection_known;
        Ok(pipeline)
    }

    /// Whether this pipeline can sketch fresh query vectors
    /// consistently with its stored sketches.
    pub fn projection_known(&self) -> bool {
        self.projection_known
    }

    fn ensure_projection_known(&self, what: &str) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.projection_known,
            "{what} requires the store's projection parameters, but this store was restored \
             from a sketch file that does not record them (restore with --assume-projection \
             plus the original --seed/--dist if you know them, or re-ingest and save with \
             the current version)"
        );
        Ok(())
    }

    /// Reject fresh query vectors whose width cannot match the stored
    /// sketches: empty always, and any width other than the ingested
    /// one when this pipeline ingested data itself (restored stores
    /// don't record d, so only the emptiness check applies there).
    fn ensure_query_dim(&self, len: usize) -> anyhow::Result<()> {
        anyhow::ensure!(len > 0, "empty query vector");
        let d = self.ingest_d.load(Ordering::Relaxed);
        anyhow::ensure!(
            d == 0 || len as u64 == d,
            "query vector has {len} entries but the store was ingested at d={d} — \
             a mismatched width would be sketched as if zero-padded/truncated and \
             score silently wrong"
        );
        Ok(())
    }

    pub fn config(&self) -> &Config {
        &self.cfg
    }

    pub fn store(&self) -> &SketchStore {
        &self.store
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    pub fn metrics(&self) -> Snapshot {
        self.metrics.snapshot()
    }

    /// Live counters (not a point-in-time copy) — the compactor and the
    /// wire server update durability/wire gauges through this.
    pub fn metrics_raw(&self) -> &Metrics {
        &self.metrics
    }

    /// The wire server's malformed-frame / stall counter, shareable
    /// without holding the whole pipeline.
    pub fn wire_errors_handle(&self) -> Arc<AtomicU64> {
        Arc::clone(&self.metrics.wire_errors)
    }

    /// Attach the durability runtime. From here on every ingested batch
    /// is inserted into the store and then logged to the WAL before
    /// ingest acknowledges it.
    pub fn attach_durability(&mut self, durability: Arc<Durability>) {
        let (records, bytes) = durability.wal_stats();
        self.metrics.wal_records.store(records, Ordering::Relaxed);
        self.metrics.wal_bytes.store(bytes, Ordering::Relaxed);
        self.durability = Some(durability);
    }

    pub fn durability(&self) -> Option<&Arc<Durability>> {
        self.durability.as_ref()
    }

    pub fn rows(&self) -> usize {
        self.store.len()
    }

    /// Insert per-row sketches, then (in durable mode) append them to
    /// the WAL — `Ok` means fsynced, i.e. acknowledged.
    fn insert_rows_logged(&self, rows: Vec<(u64, RowSketch)>) -> anyhow::Result<()> {
        // One batched insert — a single epoch bump and snapshot-cache
        // purge for the whole batch, not one per row, so concurrent
        // readers keep their cached snapshot across an ingest wave and
        // never observe a torn batch.
        match &self.durability {
            Some(d) => {
                self.store.insert_rows(rows.clone());
                d.log_rows(&rows)?;
                let (records, bytes) = d.wal_stats();
                self.metrics.wal_records.store(records, Ordering::Relaxed);
                self.metrics.wal_bytes.store(bytes, Ordering::Relaxed);
            }
            None => self.store.insert_rows(rows),
        }
        Ok(())
    }

    /// Insert one columnar block, then (in durable mode) append it to
    /// the WAL as a single batch record.
    fn insert_block_logged(&self, base: u64, cb: ColumnarBlock) -> anyhow::Result<()> {
        match &self.durability {
            Some(d) => {
                let cb = Arc::new(cb);
                self.store.insert_block_shared(base, Arc::clone(&cb));
                d.log_block(base, &cb)?;
                let (records, bytes) = d.wal_stats();
                self.metrics.wal_records.store(records, Ordering::Relaxed);
                self.metrics.wal_bytes.store(bytes, Ordering::Relaxed);
            }
            None => self.store.insert_block_columnar(base, cb),
        }
        Ok(())
    }

    /// Whether blocks of width `d` can take the PJRT path.
    fn pjrt_usable(&self, d: usize) -> bool {
        self.pjrt.as_ref().is_some_and(|p| p.meta.d == d)
    }

    /// Stream `data` through the pipeline: one reader, `workers` sketch
    /// workers, bounded queues of depth `queue_depth` (backpressure).
    /// Returns ids `base..base+n` in row order.
    pub fn ingest(&self, data: &RowMatrix) -> anyhow::Result<IngestReport> {
        let n = data.n();
        // First ingest pins the row width fresh-vector queries are
        // validated against (later ingests with the same pipeline use
        // the same matrix shape by construction of the CLI/callers).
        let _ = self.ingest_d.compare_exchange(
            0,
            data.d() as u64,
            Ordering::Relaxed,
            Ordering::Relaxed,
        );
        let base = self.next_id.fetch_add(n as u64, Ordering::Relaxed);
        let t0 = Instant::now();
        let bytes_before = self.store.bytes();
        let use_pjrt = self.pjrt_usable(data.d());
        let use_gemm = self.cfg.ingest_gemm;
        let pjrt_rows = AtomicU64::new(0);
        let errors: std::sync::Mutex<Vec<anyhow::Error>> = std::sync::Mutex::new(Vec::new());

        std::thread::scope(|scope| {
            let (tx, rx) = mpsc::sync_channel::<Block>(self.cfg.queue_depth);
            let rx = Arc::new(std::sync::Mutex::new(rx));
            for _ in 0..self.cfg.workers {
                let rx = rx.clone();
                let pjrt_rows = &pjrt_rows;
                let errors = &errors;
                scope.spawn(move || loop {
                    let block = {
                        let guard = rx.lock_recover();
                        // pallas-lint: allow(lock-order) -- shared-Receiver idiom: this mutex exists to serialize recv; senders never take it
                        guard.recv()
                    };
                    let Ok(block) = block else { break };
                    let t = Instant::now();
                    let stored = if use_pjrt && use_gemm {
                        // PJRT columnar path: the artifact stacks are
                        // already order-major, so the block lands in
                        // the store as contiguous panels — no per-row
                        // AoS sketches, same as the GEMM path.
                        self.sketch_block_pjrt_columnar(&block).and_then(|cb| {
                            pjrt_rows.fetch_add(block.rows as u64, Ordering::Relaxed);
                            self.metrics.pjrt_calls.fetch_add(1, Ordering::Relaxed);
                            self.insert_block_logged(base + block.first_row, cb)
                        })
                    } else if use_pjrt {
                        // Pinned reference: per-row unpack of the same
                        // artifact outputs (`ingest-gemm false`).
                        self.sketch_block_pjrt(&block).and_then(|sketches| {
                            pjrt_rows.fetch_add(block.rows as u64, Ordering::Relaxed);
                            self.metrics.pjrt_calls.fetch_add(1, Ordering::Relaxed);
                            let rows = sketches
                                .into_iter()
                                .enumerate()
                                .map(|(i, rs)| (base + block.row_id(i), rs))
                                .collect();
                            self.insert_rows_logged(rows)
                        })
                    } else if use_gemm {
                        // GEMM hot path: power-expand once, project with
                        // the register-tiled kernel, land the columnar
                        // block in the store verbatim (no per-row AoS).
                        // Intra-block workers stay at 1 — ingest
                        // parallelism lives at the block level, in this
                        // worker pool.
                        self.metrics.gemm_calls.fetch_add(1, Ordering::Relaxed);
                        self.insert_block_logged(
                            base + block.first_row,
                            self.sketch_block_gemm(&block),
                        )
                    } else {
                        self.metrics.fallback_calls.fetch_add(1, Ordering::Relaxed);
                        let rows = self
                            .sketch_block_rust(&block)
                            .into_iter()
                            .enumerate()
                            .map(|(i, rs)| (base + block.row_id(i), rs))
                            .collect();
                        self.insert_rows_logged(rows)
                    };
                    match stored {
                        Ok(()) => {
                            self.metrics.rows_ingested.fetch_add(block.rows as u64, Ordering::Relaxed);
                            self.metrics.blocks_sketched.fetch_add(1, Ordering::Relaxed);
                            self.metrics.sketch_latency.record(t.elapsed());
                        }
                        Err(e) => errors.lock_recover().push(e),
                    }
                });
            }
            // Reader: the bounded send blocks when workers lag — that is
            // the backpressure (queue never exceeds queue_depth).
            for block in BlockScheduler::new(data.data(), n, data.d(), self.cfg.block_rows) {
                if tx.send(block).is_err() {
                    break;
                }
            }
            drop(tx);
        });

        let errs = errors.into_inner().unwrap_or_else(std::sync::PoisonError::into_inner);
        if let Some(e) = errs.into_iter().next() {
            return Err(e);
        }
        // Lifecycle hook: small `block_rows` lands one segment per
        // block; merge small adjacent segments so the segment count
        // stays bounded (estimate-invariant — panels move by contiguous
        // copy). Incremental: only the run of segments this ingest
        // appended (`base .. base + n`) is considered, so the hook's
        // cost scales with the ingest, not the store, and compaction
        // being copy-on-write means readers are never paused for it.
        // `compact-min-rows = 0` disables it.
        if self.cfg.compact_min_rows > 0 {
            let report = self.store.compact_range(
                self.cfg.compact_min_rows,
                self.cfg.compact_target_rows,
                base,
                base + n as u64,
            );
            self.metrics.compactions.fetch_add(report.merges as u64, Ordering::Relaxed);
        }
        self.metrics
            .segment_count
            .store(self.store.segment_count() as u64, Ordering::Relaxed);
        Ok(IngestReport {
            rows: n,
            blocks: n.div_ceil(self.cfg.block_rows),
            elapsed: t0.elapsed(),
            sketch_bytes: self.store.bytes() - bytes_before,
            data_bytes: data.bytes(),
            pjrt_rows: pjrt_rows.load(Ordering::Relaxed) as usize,
        })
    }

    /// Run one segment-compaction pass over the store with the
    /// configured `compact-min-rows` / `compact-target-rows` knobs,
    /// recording `compactions` and the `segment_count` gauge.
    pub fn compact(&self) -> super::state::CompactionReport {
        let report = self
            .store
            .compact_segments(self.cfg.compact_min_rows, self.cfg.compact_target_rows);
        self.metrics.compactions.fetch_add(report.merges as u64, Ordering::Relaxed);
        self.metrics
            .segment_count
            .store(self.store.segment_count() as u64, Ordering::Relaxed);
        report
    }

    /// Pure-rust per-row sketch of one block (the reference baseline).
    fn sketch_block_rust(&self, block: &Block) -> Vec<RowSketch> {
        let rows: Vec<&[f32]> = (0..block.rows).map(|i| block.row(i)).collect();
        self.sketcher.sketch_rows(&rows)
    }

    /// Register-tiled GEMM sketch of one block, columnar output.
    fn sketch_block_gemm(&self, block: &Block) -> crate::projection::sketcher::ColumnarBlock {
        let rows: Vec<&[f32]> = (0..block.rows).map(|i| block.row(i)).collect();
        self.sketcher.sketch_block(&rows, 1)
    }

    /// Run the sketch artifact(s) on one block and return the raw
    /// stacked outputs: `u` (orders × B × K, order-major), `m`
    /// (moments × B), and the v-side stack under the alternative
    /// strategy (second artifact pass with the order-reversed matrix
    /// stack: order m paired with matrix id p−m).
    fn pjrt_raw(&self, block: &Block) -> anyhow::Result<PjrtRaw> {
        let pjrt = self
            .pjrt
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("pjrt path invoked without a loaded artifact"))?;
        let meta = &pjrt.meta;
        anyhow::ensure!(block.rows <= meta.b, "block exceeds artifact batch");
        anyhow::ensure!(block.d == meta.d, "block width mismatch");
        let x = block.padded(meta.b);
        let spec = &self.sketcher.spec;
        let orders = self.dec.orders();
        let (u, m) = match self.cfg.strategy {
            Strategy::Basic => {
                let r = spec.materialize(1, 0, meta.d).data;
                let outs = pjrt.handle.run(
                    &meta.name,
                    vec![
                        OwnedInput::new(x, &[meta.b, meta.d]),
                        OwnedInput::new(r, &[meta.d, meta.k]),
                    ],
                )?;
                let mut it = outs.into_iter();
                match (it.next(), it.next()) {
                    (Some(u), Some(m)) => (u, m),
                    _ => anyhow::bail!("sketch artifact returns (u, m)"),
                }
            }
            Strategy::Alternative => {
                // u-side: order m uses matrix id m.
                let mut r_stack = Vec::with_capacity(orders * meta.d * meta.k);
                for ord in 1..=orders {
                    r_stack.extend_from_slice(&spec.materialize(ord, 0, meta.d).data);
                }
                let outs = pjrt.handle.run(
                    &meta.name,
                    vec![
                        OwnedInput::new(x.clone(), &[meta.b, meta.d]),
                        OwnedInput::new(r_stack, &[orders, meta.d, meta.k]),
                    ],
                )?;
                let mut it = outs.into_iter();
                match (it.next(), it.next()) {
                    (Some(u), Some(m)) => (u, m),
                    _ => anyhow::bail!("sketch artifact returns (u, m)"),
                }
            }
        };
        let v = if matches!(self.cfg.strategy, Strategy::Alternative) {
            let p = self.dec.p();
            let x = block.padded(meta.b);
            let mut r_stack = Vec::with_capacity(orders * meta.d * meta.k);
            for ord in 1..=orders {
                r_stack.extend_from_slice(&spec.materialize(p - ord, 0, meta.d).data);
            }
            let outs = pjrt.handle.run(
                &meta.name,
                vec![
                    OwnedInput::new(x, &[meta.b, meta.d]),
                    OwnedInput::new(r_stack, &[orders, meta.d, meta.k]),
                ],
            )?;
            let vout = outs
                .into_iter()
                .next()
                .ok_or_else(|| anyhow::anyhow!("v-side artifact returns (u, ..)"))?;
            Some(vout)
        } else {
            None
        };
        Ok((u, m, v))
    }

    /// PJRT sketch of one block, per-row AoS output — the pinned
    /// reference unpack (`ingest-gemm false`), mirroring the pure-rust
    /// per-row baseline. The deployed path is
    /// [`Pipeline::sketch_block_pjrt_columnar`].
    fn sketch_block_pjrt(&self, block: &Block) -> anyhow::Result<Vec<RowSketch>> {
        let (u, m, v) = self.pjrt_raw(block)?;
        let meta = &self
            .pjrt
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("pjrt path invoked without a loaded artifact"))?
            .meta;
        let orders = self.dec.orders();
        let mut sketches = self.unpack_sketches(block, meta, &u, &m);
        if let Some(v) = v {
            for (i, rs) in sketches.iter_mut().enumerate() {
                let mut vset = SketchSet::zeros(orders, meta.k);
                for ord in 1..=orders {
                    let src = &v
                        [((ord - 1) * meta.b + i) * meta.k..((ord - 1) * meta.b + i + 1) * meta.k];
                    vset.u_mut(ord).copy_from_slice(src);
                }
                rs.vside_data = Some(vset);
            }
        }
        Ok(sketches)
    }

    /// PJRT sketch of one block, columnar output: the artifact stacks
    /// are already order-major with the padded batch rows leading each
    /// order panel, so assembly is one contiguous slice per
    /// (order, side) plus a moment-column gather — no per-row AoS
    /// sketches, exactly like the GEMM ingest path.
    fn sketch_block_pjrt_columnar(&self, block: &Block) -> anyhow::Result<ColumnarBlock> {
        let (u, m, v) = self.pjrt_raw(block)?;
        let meta = &self
            .pjrt
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("pjrt path invoked without a loaded artifact"))?
            .meta;
        Ok(assemble_columnar(
            self.dec.orders(),
            meta.k,
            self.dec.moment_orders(),
            block.rows,
            meta.b,
            &u,
            &m,
            v.as_deref(),
        ))
    }

    /// Slice artifact outputs (u: orders×B×K, m: moments×B) into
    /// per-row [`RowSketch`]es for the block's logical rows.
    fn unpack_sketches(
        &self,
        block: &Block,
        meta: &ArtifactMeta,
        u: &[f32],
        m: &[f32],
    ) -> Vec<RowSketch> {
        let orders = self.dec.orders();
        let nm = self.dec.moment_orders();
        (0..block.rows)
            .map(|i| {
                let mut uset = SketchSet::zeros(orders, meta.k);
                for ord in 1..=orders {
                    let src = &u[((ord - 1) * meta.b + i) * meta.k..((ord - 1) * meta.b + i + 1) * meta.k];
                    uset.u_mut(ord).copy_from_slice(src);
                }
                let moments =
                    Moments((1..=nm).map(|o| m[(o - 1) * meta.b + i] as f64).collect());
                RowSketch { uside: uset, vside_data: None, moments }
            })
            .collect()
    }

    /// Estimate the distance between two stored rows (the query path).
    ///
    /// The plain estimator scores straight from wherever the rows live
    /// (map rows by reference, columnar segments from their panels — no
    /// materialization); the MLE consumes full per-row state and goes
    /// through `with_pair`.
    pub fn estimate_pair(&self, a: u64, b: u64) -> Option<f64> {
        let t = Instant::now();
        let out = if self.cfg.use_mle {
            self.store.with_pair(a, b, |ra, rb| {
                mle::estimate_mle(&self.dec, ra, rb, Solve::OneStepNewton)
            })
        } else {
            self.store.estimate_pair_plain(&self.dec, a, b)
        };
        if out.is_some() {
            self.metrics.queries_served.fetch_add(1, Ordering::Relaxed);
            self.metrics.query_latency.record(t.elapsed());
        }
        out
    }

    /// Batch of pair estimates (None for unknown ids).
    ///
    /// Large plain-estimator batches run on one epoch snapshot: when
    /// the store is fully columnar the pairs are scored *in place* on
    /// the snapshot's segment panels (no copy at all); otherwise one
    /// arena copy of the snapshot, then contiguous scoring — cheaper
    /// than per-pair resolution once the batch is big enough to
    /// amortize the O(n·k) copy. Either way no store lock is held while
    /// scoring, so ingest proceeds concurrently. Small batches and the
    /// MLE mode stay on the per-pair path. All routes are
    /// bitwise-identical.
    pub fn estimate_pairs(&self, pairs: &[(u64, u64)]) -> Vec<Option<f64>> {
        // One capture serves both the size gate and the scan, so the
        // two always agree on one epoch (and a write-heavy store pays
        // one O(segments) capture, not two).
        let snap = (!self.cfg.use_mle && pairs.len() >= 32).then(|| self.store.snapshot());
        if let Some(snap) = snap {
            let t = Instant::now();
            if let Some(out) = self.pairs_big_batch_on(&snap, pairs) {
                let served = out.iter().filter(|o| o.is_some()).count() as u64;
                self.metrics.queries_served.fetch_add(served, Ordering::Relaxed);
                // query_latency holds per-pair samples; log the batch's
                // amortized per-pair cost once per served pair (bulk,
                // O(1)) so count stays consistent with queries_served
                // and the percentiles remain comparable with the
                // single-pair path.
                if served > 0 {
                    let per_pair_us = (t.elapsed().as_micros() as u64).div_ceil(served).max(1);
                    self.metrics.query_latency.record_us_many(per_pair_us, served);
                }
                return out;
            }
        }
        pairs.iter().map(|&(a, b)| self.estimate_pair(a, b)).collect()
    }

    /// The blocked batch fast path, shared by [`Pipeline::estimate_pairs`]
    /// and the typed-API service: when the batch is big enough to
    /// amortize (≥ 1/4 of the view), score the pairs straight from the
    /// snapshot's columnar panels — or one arena copy when map rows
    /// exist. `None` when the batch is too small (or MLE is on) and the
    /// per-pair route should serve instead. Bitwise-identical to the
    /// per-pair path (pinned by `batched_pairs_match_single_queries`).
    /// Records no metrics — callers own their accounting.
    fn pairs_big_batch_on(
        &self,
        snap: &StoreSnapshot,
        pairs: &[(u64, u64)],
    ) -> Option<Vec<Option<f64>>> {
        if self.cfg.use_mle || pairs.len() < 32 || pairs.len() * 4 < snap.len() {
            return None;
        }
        Some(match snap.columnar_panels(self.cfg.p) {
            Some(v) => pairs
                .iter()
                .map(|&(a, b)| match (v.pos_of(a), v.pos_of(b)) {
                    (Some(i), Some(j)) => {
                        Some(estimator::estimate_arena(&self.dec, &v, i, &v, j))
                    }
                    _ => None,
                })
                .collect(),
            None => {
                let arena = snap.arena(self.cfg.p, self.cfg.k);
                pairs
                    .iter()
                    .map(|&(a, b)| match (arena.pos.get(&a), arena.pos.get(&b)) {
                        (Some(&i), Some(&j)) => Some(estimator::estimate_arena(
                            &self.dec, &arena.arena, i, &arena.arena, j,
                        )),
                        _ => None,
                    })
                    .collect()
            }
        })
    }

    /// Store-served batch KNN for fresh query vectors: sketch
    /// `queries` with the pipeline's projection, then stream one epoch
    /// snapshot of the store through the fused arena top-k kernel.
    /// Returns per query the `top` nearest stored rows as
    /// `(id, estimated distance)`, ascending. A fully-columnar snapshot
    /// is scanned segment-natively (no copy); otherwise one arena copy
    /// serves the scan. No store lock is held during the kernel —
    /// ingest runs concurrently and the scan serves the epoch it
    /// captured. Plain estimator only, like all blocked paths (the MLE
    /// consumes per-row state). Errors when the projection parameters
    /// are unknown (store restored from a pre-v3 sketch file): a fresh
    /// vector cannot be sketched consistently then.
    pub fn top_k(&self, queries: &[&[f32]], top: usize) -> anyhow::Result<Vec<Vec<(u64, f64)>>> {
        if queries.is_empty() {
            return Ok(Vec::new());
        }
        self.ensure_projection_known("top-k by fresh vector")?;
        for q in queries {
            self.ensure_query_dim(q.len())?;
        }
        let qsk = self.sketcher.sketch_rows(queries);
        let snap = self.store.snapshot();
        let out = self.top_k_sketched(&snap, &qsk, top);
        self.metrics.queries_served.fetch_add(queries.len() as u64, Ordering::Relaxed);
        Ok(out)
    }

    /// Store-served batch KNN for *stored* rows: each query is a row id
    /// whose stored sketch ranks the rest of the store — no raw data,
    /// no re-sketching, so this works even when the projection
    /// parameters are unknown. Unknown ids answer `None`. Same kernel,
    /// same snapshot discipline, bitwise-identical scores to
    /// [`Pipeline::top_k`] on the vector that produced the stored
    /// sketch.
    pub fn top_k_ids(&self, ids: &[u64], top: usize) -> Vec<Option<Vec<(u64, f64)>>> {
        if ids.is_empty() {
            return Vec::new();
        }
        let snap = self.store.snapshot();
        let rows: Vec<Option<RowSketch>> = ids.iter().map(|&id| snap.get(id)).collect();
        let present: Vec<bool> = rows.iter().map(|r| r.is_some()).collect();
        let known: Vec<RowSketch> = rows.into_iter().flatten().collect();
        if known.is_empty() {
            return vec![None; ids.len()];
        }
        let lists = self.top_k_sketched(&snap, &known, top);
        self.metrics.queries_served.fetch_add(known.len() as u64, Ordering::Relaxed);
        // `lists` carries exactly one entry per true flag in `present`;
        // `flatten` (rather than an assertion) keeps the serving path
        // panic-free even if that invariant were ever broken.
        let mut it = lists.into_iter();
        present.into_iter().map(|p| p.then(|| it.next()).flatten()).collect()
    }

    /// Shared top-k scan: already-sketched queries against one snapshot.
    /// A fully-columnar snapshot runs the *zone-pruned* scan on its
    /// segment panels — segments whose admissible lower bound cannot
    /// beat the heap threshold are skipped whole (counted by the
    /// `topk_segments_visited` / `topk_segments_skipped` metrics),
    /// bitwise-identical to the full scan by the bound's admissibility.
    fn top_k_sketched(
        &self,
        snap: &StoreSnapshot,
        qsk: &[RowSketch],
        top: usize,
    ) -> Vec<Vec<(u64, f64)>> {
        let qarena = SketchArena::from_rows(self.cfg.p, self.cfg.k, qsk);
        let workers = self.cfg.workers.max(1);
        match snap.columnar_panels(self.cfg.p) {
            Some(v) => {
                let (lists, stats) = estimator::top_k_scan_zoned(
                    &self.dec,
                    &qarena,
                    &v,
                    &v.extents(),
                    top,
                    workers,
                );
                self.record_prune(&stats);
                lists
                    .into_iter()
                    .map(|lst| lst.into_iter().map(|(i, d)| (v.id_at(i), d)).collect())
                    .collect()
            }
            None => {
                let arena = snap.arena(self.cfg.p, self.cfg.k);
                estimator::top_k_scan_arena(&self.dec, &qarena, &arena.arena, top, workers)
                    .into_iter()
                    .map(|lst| lst.into_iter().map(|(i, d)| (arena.ids[i], d)).collect())
                    .collect()
            }
        }
    }

    /// Fold one zoned scan's pruning counters into the metrics.
    fn record_prune(&self, stats: &estimator::PruneStats) {
        self.metrics
            .topk_segments_visited
            .fetch_add(stats.segments_visited, Ordering::Relaxed);
        self.metrics
            .topk_segments_skipped
            .fetch_add(stats.segments_skipped, Ordering::Relaxed);
    }

    /// Distances from a fresh (never-ingested) vector to the given
    /// stored ids — the paper's out-of-store query model: the vector is
    /// sketched once with the pipeline's projection, then scored
    /// against each stored row's sketch (`None` per unknown id; the
    /// margin MLE applies when configured). Errors when the projection
    /// parameters are unknown.
    pub fn vector_distances(
        &self,
        vector: &[f32],
        ids: &[u64],
    ) -> anyhow::Result<Vec<Option<f64>>> {
        let snap = self.store.snapshot();
        self.serve_vector_distance_on(&snap, vector, ids)
    }

    /// All pairwise estimates over the stored ids, ascending (condensed
    /// upper-triangle order, matching
    /// [`crate::baselines::exact::condensed_index`]).
    ///
    /// Backend preference for the plain estimator: the PJRT estimate
    /// artifact (blocked MXU GEMMs) when available, else the cache-tiled
    /// pure-rust arena kernel sharded over `cfg.workers`. The margin-MLE
    /// mode uses the per-row path (the arena stores only what the plain
    /// combine needs).
    pub fn all_pairs_condensed(&self) -> Vec<f64> {
        // One epoch snapshot serves the whole scan — ids, rows, and
        // panels all come from the same consistent cut, and the store
        // is never pinned while the kernel runs.
        let snap = self.store.snapshot();
        if !self.cfg.use_mle {
            if let Some(pjrt) = &self.pjrt {
                if let Some(meta) =
                    pjrt.handle.manifest().find_estimate(self.cfg.p, self.cfg.k).cloned()
                {
                    let ids = snap.ids();
                    let n = ids.len();
                    if n < 2 {
                        return Vec::new();
                    }
                    let rows: Vec<RowSketch> =
                        ids.iter().filter_map(|&id| snap.get(id)).collect();
                    let mut out = vec![0.0f64; n * (n - 1) / 2];
                    if let Ok(()) = self.all_pairs_pjrt(&rows, &meta, &mut out) {
                        self.metrics
                            .queries_served
                            .fetch_add((n * (n - 1) / 2) as u64, Ordering::Relaxed);
                        return out;
                    }
                }
            }
            // Fully-columnar snapshot: run the condensed kernel straight
            // on the segment panels (zero-copy). Otherwise one arena
            // copy: segments land by contiguous copy, map rows by one
            // transpose each — no intermediate Vec<RowSketch>. Both
            // order rows by ascending id, so the outputs are
            // bitwise-identical.
            let workers = self.cfg.workers.max(1);
            let out = match snap.columnar_panels(self.cfg.p) {
                Some(v) => estimator::estimate_condensed_arena(&self.dec, &v, workers),
                None => {
                    let arena = snap.arena(self.cfg.p, self.cfg.k);
                    estimator::estimate_condensed_arena(&self.dec, &arena.arena, workers)
                }
            };
            let n = snap.len();
            self.metrics
                .queries_served
                .fetch_add((n.saturating_sub(1) * n / 2) as u64, Ordering::Relaxed);
            return out;
        }
        let ids = snap.ids();
        if ids.len() < 2 {
            return Vec::new();
        }
        // MLE consumes per-order norms/moments the arena does not hold;
        // materialize per-row sketches once from the snapshot.
        let rows: Vec<RowSketch> = ids.iter().filter_map(|&id| snap.get(id)).collect();
        self.per_row_condensed(&rows)
    }

    /// Reference per-row all-pairs path (one `estimate`/`estimate_mle`
    /// call per pair, row-sharded across workers). Kept as the oracle
    /// and baseline the arena kernel is benchmarked against (E7,
    /// `benches/hotpath.rs`); also serves the MLE mode.
    pub fn all_pairs_condensed_per_row(&self) -> Vec<f64> {
        let snap = self.store.snapshot();
        let ids = snap.ids();
        if ids.len() < 2 {
            return Vec::new();
        }
        let rows: Vec<RowSketch> = ids.iter().filter_map(|&id| snap.get(id)).collect();
        self.per_row_condensed(&rows)
    }

    fn per_row_condensed(&self, rows: &[RowSketch]) -> Vec<f64> {
        let n = rows.len();
        let mut out = vec![0.0f64; n * (n - 1) / 2];
        let workers = self.cfg.workers.max(1);
        let chunks: Vec<(usize, &mut [f64])> = {
            // Split the condensed buffer by row ranges.
            let mut parts = Vec::new();
            let mut rest: &mut [f64] = &mut out;
            for i in 0..n - 1 {
                let len = n - 1 - i;
                let (head, tail) = rest.split_at_mut(len);
                parts.push((i, head));
                rest = tail;
            }
            parts
        };
        std::thread::scope(|scope| {
            for assigned in estimator::round_robin(chunks, workers) {
                let dec = &self.dec;
                let use_mle = self.cfg.use_mle;
                scope.spawn(move || {
                    for (i, chunk) in assigned {
                        for (off, slot) in chunk.iter_mut().enumerate() {
                            let j = i + 1 + off;
                            *slot = if use_mle {
                                mle::estimate_mle(dec, &rows[i], &rows[j], Solve::OneStepNewton)
                            } else {
                                estimator::estimate(dec, &rows[i], &rows[j])
                            };
                        }
                    }
                });
            }
        });
        self.metrics
            .queries_served
            .fetch_add((n * (n - 1) / 2) as u64, Ordering::Relaxed);
        out
    }

    /// Blocked all-pairs via the PJRT estimate artifact: one MXU GEMM
    /// per block pair instead of O(b²) scalar dots (§Perf iteration 4).
    fn all_pairs_pjrt(
        &self,
        rows: &[RowSketch],
        meta: &ArtifactMeta,
        out: &mut [f64],
    ) -> anyhow::Result<()> {
        let n = rows.len();
        let (b, k, p) = (meta.b, meta.k, self.dec.p());
        let orders = self.dec.orders();
        anyhow::ensure!(meta.b2 == b, "estimate artifact must be square-blocked");
        let pjrt = self
            .pjrt
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("pjrt path invoked without a loaded artifact"))?;
        pjrt.handle.warm(&meta.name)?;
        // Pack per-block stacks once: U from uside, V from vside, plus
        // marginal p-norms.
        let blocks = n.div_ceil(b);
        let pack = |bi: usize, vside: bool| -> (Vec<f32>, Vec<f32>) {
            let mut stack = vec![0.0f32; orders * b * k];
            let mut norms = vec![0.0f32; b];
            for (slot, row) in rows[bi * b..((bi + 1) * b).min(n)].iter().enumerate() {
                let set = if vside { row.vside() } else { &row.uside };
                for m in 1..=orders {
                    stack[((m - 1) * b + slot) * k..((m - 1) * b + slot + 1) * k]
                        .copy_from_slice(set.u(m));
                }
                norms[slot] = row.moments.get(p) as f32;
            }
            (stack, norms)
        };
        let packed_u: Vec<_> = (0..blocks).map(|bi| pack(bi, false)).collect();
        let packed_v: Vec<_> = (0..blocks).map(|bi| pack(bi, true)).collect();
        for bi in 0..blocks {
            for bj in bi..blocks {
                let (u, mx) = &packed_u[bi];
                let (v, my) = &packed_v[bj];
                let outs = pjrt.handle.run(
                    &meta.name,
                    vec![
                        OwnedInput::new(u.clone(), &[orders, b, k]),
                        OwnedInput::new(v.clone(), &[orders, b, k]),
                        OwnedInput::new(mx.clone(), &[b]),
                        OwnedInput::new(my.clone(), &[b]),
                    ],
                )?;
                self.metrics.pjrt_calls.fetch_add(1, Ordering::Relaxed);
                let est = &outs[0];
                for si in 0..b {
                    let i = bi * b + si;
                    if i >= n {
                        break;
                    }
                    let j0 = if bi == bj { si + 1 } else { 0 };
                    for sj in j0..b {
                        let j = bj * b + sj;
                        if j >= n {
                            break;
                        }
                        out[crate::baselines::exact::condensed_index(n, i, j)] =
                            est[si * b + sj] as f64;
                    }
                }
            }
        }
        Ok(())
    }

    /// Spawn the batched query service: `query_workers` threads take
    /// turns draining one [`crate::coordinator::batcher::Batcher`] of
    /// typed [`ApiJob`]s (one drainer at a time behind a mutex; the
    /// lock is released before a batch is *served*, so batches execute
    /// concurrently across workers). Each batch is answered from an
    /// epoch snapshot that refreshes automatically when ingest advances
    /// the store — a quiescent store reuses the cached snapshot in
    /// O(1), a busy one pays one O(segments) capture per batch. The
    /// `snapshot_age` gauge records how many writes behind the serving
    /// snapshot was; `queries_in_flight` counts requests currently
    /// being answered. The returned handle is cloneable; the service
    /// stops when every handle is dropped. The same handle backs the
    /// TCP server ([`crate::api::Server`]), so remote and in-process
    /// clients share one queue and one snapshot discipline.
    pub fn spawn_query_service(self: &Arc<Self>) -> ApiHandle {
        crate::api::service::spawn(Arc::clone(self))
    }

    /// Answer one typed request directly, from one fresh store
    /// snapshot — the unbatched entry point of the unified API (used by
    /// tests and benches as the "direct" arm; the service and the wire
    /// server route through [`Pipeline::serve_api_batch`] instead).
    pub fn answer(&self, request: Request) -> Response {
        let snap = self.store.snapshot();
        self.serve_request_on(&snap, request)
    }

    /// Answer one drained batch of typed requests from a per-batch
    /// snapshot. The `queries_in_flight` gauge counts the batch's
    /// requests and is decremented per request *before* its reply is
    /// sent, so a client that has received every answer observes the
    /// gauge already drained.
    pub(crate) fn serve_api_batch(&self, batch: Vec<ApiJob>, reason: FlushReason) {
        self.metrics.batches_flushed.fetch_add(1, Ordering::Relaxed);
        if reason == FlushReason::Deadline {
            self.metrics.batch_deadline_flushes.fetch_add(1, Ordering::Relaxed);
        }
        self.metrics.queries_in_flight.fetch_add(batch.len() as u64, Ordering::Relaxed);
        let snap = self.store.snapshot();
        // Staleness gauge: epoch distance from the previous serving
        // snapshot to this one — the writes that landed while the last
        // batch was in flight (a just-captured snapshot is always
        // current w.r.t. the store, so comparing against the *live*
        // epoch would read ~0 forever).
        let prev = self.metrics.last_serve_epoch.swap(snap.epoch(), Ordering::Relaxed);
        let age = if prev == u64::MAX { 0 } else { snap.epoch().saturating_sub(prev) };
        self.metrics.snapshot_age.store(age, Ordering::Relaxed);
        for job in batch {
            let resp = self.serve_request_on(&snap, job.request);
            self.metrics.queries_in_flight.fetch_sub(1, Ordering::Relaxed);
            let _ = job.reply.send(resp);
        }
    }

    /// The single dispatch point of the unified API: every request
    /// kind, answered from the given snapshot. Serving-side failures
    /// become [`Response::Error`] — the connection/channel stays
    /// healthy.
    fn serve_request_on(&self, snap: &Arc<StoreSnapshot>, request: Request) -> Response {
        match request {
            Request::Ping => {
                Response::Pong { version: crate::api::wire::WIRE_VERSION as u32 }
            }
            Request::Stats => Response::Stats(self.api_stats_on(snap)),
            Request::PairBatch(pairs) => {
                Response::PairBatch(self.serve_pairs_on(snap, &pairs))
            }
            Request::TopK { target, top } => {
                match self.serve_top_k_on(snap, target, top as usize) {
                    Ok(list) => Response::TopK(list),
                    Err(e) => Response::Error(e.to_string()),
                }
            }
            Request::VectorDistance { vector, ids } => {
                match self.serve_vector_distance_on(snap, &vector, &ids) {
                    Ok(ests) => Response::VectorDistance(ests),
                    Err(e) => Response::Error(e.to_string()),
                }
            }
        }
    }

    /// Pair estimates from one snapshot (plain or MLE per config),
    /// `None` per unknown id — with the per-pair serving metrics the
    /// pre-API query service recorded. Large plain batches (a remote
    /// client can legally send millions of pairs in one frame) take
    /// the same blocked columnar fast path as
    /// [`Pipeline::estimate_pairs`]; small batches and MLE resolve
    /// per pair. All routes are bitwise-identical.
    fn serve_pairs_on(&self, snap: &StoreSnapshot, pairs: &[(u64, u64)]) -> Vec<Option<f64>> {
        let t = Instant::now();
        let out: Vec<Option<f64>> = self.pairs_big_batch_on(snap, pairs).unwrap_or_else(|| {
            pairs
                .iter()
                .map(|&(a, b)| {
                    if self.cfg.use_mle {
                        snap.with_pair(a, b, |ra, rb| {
                            mle::estimate_mle(&self.dec, ra, rb, Solve::OneStepNewton)
                        })
                    } else {
                        snap.estimate_pair_plain(&self.dec, a, b)
                    }
                })
                .collect()
        });
        let served = out.iter().filter(|o| o.is_some()).count() as u64;
        if served > 0 {
            self.metrics.queries_served.fetch_add(served, Ordering::Relaxed);
            // Amortized per-pair latency, recorded once per served pair
            // (bulk, O(1)) so percentiles stay comparable with the
            // single-pair path.
            let per_pair_us = (t.elapsed().as_micros() as u64).div_ceil(served).max(1);
            self.metrics.query_latency.record_us_many(per_pair_us, served);
        }
        out
    }

    /// Serve one top-k request from the epoch-cached serving index
    /// ([`KnnIndex::from_snapshot`] — assembled entirely from the
    /// snapshot's O(nk) sketch state, never from raw data).
    fn serve_top_k_on(
        &self,
        snap: &Arc<StoreSnapshot>,
        target: TopKTarget,
        top: usize,
    ) -> anyhow::Result<Vec<(u64, f64)>> {
        // Reject doomed fresh-vector requests before paying the O(nk)
        // index rebuild (and before taking the cache lock at all).
        if let TopKTarget::Vector(v) = &target {
            self.ensure_projection_known("top-k by fresh vector")?;
            self.ensure_query_dim(v.len())?;
        }
        let serving = self.serving_index(snap)?;
        let lists = match target {
            TopKTarget::StoredId(id) => {
                let pos = serving
                    .ids
                    .binary_search(&id)
                    .map_err(|_| anyhow::anyhow!("unknown id {id}"))?;
                // By-position: the stored row's panels ARE the query —
                // no sketch materialization, no query-arena copy.
                let (list, stats) = serving.index.query_pos_stats(pos, top);
                self.record_prune(&stats);
                vec![list]
            }
            TopKTarget::Vector(v) => serving.index.query_batch(&[v.as_slice()], top),
        };
        self.metrics.queries_served.fetch_add(1, Ordering::Relaxed);
        Ok(lists
            .into_iter()
            .next()
            .unwrap_or_default()
            .into_iter()
            .map(|nb| (serving.ids[nb.index], nb.distance))
            .collect())
    }

    fn serve_vector_distance_on(
        &self,
        snap: &StoreSnapshot,
        vector: &[f32],
        ids: &[u64],
    ) -> anyhow::Result<Vec<Option<f64>>> {
        self.ensure_projection_known("fresh-vector distance")?;
        self.ensure_query_dim(vector.len())?;
        let t = Instant::now();
        let qs = self.sketcher.sketch_row(vector);
        let out: Vec<Option<f64>> = ids
            .iter()
            .map(|&id| {
                snap.get(id).map(|rs| {
                    if self.cfg.use_mle {
                        mle::estimate_mle(&self.dec, &qs, &rs, Solve::OneStepNewton)
                    } else {
                        estimator::estimate(&self.dec, &qs, &rs)
                    }
                })
            })
            .collect();
        let served = out.iter().filter(|o| o.is_some()).count() as u64;
        if served > 0 {
            self.metrics.queries_served.fetch_add(served, Ordering::Relaxed);
            let per_us = (t.elapsed().as_micros() as u64).div_ceil(served).max(1);
            self.metrics.query_latency.record_us_many(per_us, served);
        }
        Ok(out)
    }

    /// Metrics counters + store shape from one snapshot (the `Stats`
    /// reply body).
    fn api_stats_on(&self, snap: &StoreSnapshot) -> ApiStats {
        let m = self.metrics.snapshot();
        ApiStats {
            rows: snap.len() as u64,
            map_rows: snap.map_ids().len() as u64,
            segments: snap.segment_count() as u64,
            epoch: snap.epoch(),
            rows_ingested: m.rows_ingested,
            queries_served: m.queries_served,
            batches_flushed: m.batches_flushed,
            compactions: m.compactions,
            queries_in_flight: m.queries_in_flight,
            snapshot_age: m.snapshot_age,
            p: self.cfg.p as u32,
            k: self.cfg.k as u32,
            two_sided: matches!(self.cfg.strategy, Strategy::Alternative),
            projection_known: self.projection_known,
        }
    }

    /// The serving index for `snap`'s epoch: reused while the store is
    /// quiescent, refreshed *incrementally* the first time a top-k
    /// request observes a newer epoch — segment shards whose panels are
    /// still the cached index's `Arc` allocations carry over untouched,
    /// and only segments newer than the cached epoch (fresh ingests,
    /// compaction outputs) are re-indexed (the `knn_segments_reindexed`
    /// metric counts exactly those). The cache lock is held across a
    /// refresh, so racing top-k requests build each epoch's index
    /// exactly once.
    fn serving_index(&self, snap: &Arc<StoreSnapshot>) -> anyhow::Result<Arc<ServingIndex>> {
        let mut cache = self.knn_cache.lock_recover();
        if let Some((epoch, serving)) = cache.as_ref() {
            if *epoch == snap.epoch() {
                return Ok(Arc::clone(serving));
            }
        }
        let prev = cache.as_ref().map(|(_, s)| Arc::clone(s));
        let (index, ids, reindexed) = KnnIndex::from_snapshot_incremental(
            snap,
            self.cfg.projection_spec(),
            self.cfg.p,
            prev.as_deref().map(|s| &s.index),
        )?;
        self.metrics
            .knn_segments_reindexed
            .fetch_add(reindexed as u64, Ordering::Relaxed);
        let built = Arc::new(ServingIndex { index, ids });
        *cache = Some((snap.epoch(), Arc::clone(&built)));
        Ok(built)
    }

    /// Current store snapshot — the serving-side entry point for
    /// callers that want to run several reads against one consistent
    /// cut (e.g. KNN index rebuilds via
    /// [`crate::knn::KnnIndex::from_snapshot`]).
    pub fn store_snapshot(&self) -> Arc<StoreSnapshot> {
        self.store.snapshot()
    }
}

/// Assemble a [`ColumnarBlock`] from raw PJRT artifact outputs:
/// `u`/`v` stacks are order-major `orders × b × k` with the block's
/// `rows` logical rows leading each order panel (padding trails), so
/// each (order, side) panel is one contiguous slice; moments arrive
/// column-major (`nm × b`) and are gathered row-major. Kept as a free
/// function so the assembly is unit-testable without a PJRT engine.
#[allow(clippy::too_many_arguments)]
fn assemble_columnar(
    orders: usize,
    k: usize,
    nm: usize,
    rows: usize,
    b: usize,
    u: &[f32],
    m: &[f32],
    v: Option<&[f32]>,
) -> ColumnarBlock {
    let take = |stack: &[f32]| -> Vec<f32> {
        let mut out = Vec::with_capacity(orders * rows * k);
        for ord in 0..orders {
            let off = ord * b * k;
            out.extend_from_slice(&stack[off..off + rows * k]);
        }
        out
    };
    let u_panels = take(u);
    let v_panels = v.map(take);
    let mut moments = vec![0.0f64; rows * nm];
    for r in 0..rows {
        for o in 1..=nm {
            moments[r * nm + o - 1] = m[(o - 1) * b + r] as f64;
        }
    }
    ColumnarBlock::from_parts(orders, k, nm, rows, u_panels, v_panels, moments)
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::core::decompose::exact_distance;
    use crate::data::{gen, DataDist};

    fn cfg(n: usize, d: usize) -> Config {
        let mut c = Config::default();
        c.n = n;
        c.d = d;
        c.k = 32.min(d);
        c.block_rows = 16;
        c.workers = 3;
        c.queue_depth = 2;
        c
    }

    #[test]
    fn ingest_sketches_every_row_exactly_once() {
        // d large enough that sketches compress: sketch bytes/row =
        // (p−1)·k·4 + moments, data bytes/row = d·4.
        let c = cfg(100, 256);
        let data = gen::generate(DataDist::Uniform01, c.n, c.d, 1);
        let p = Pipeline::new(c).unwrap();
        let report = p.ingest(&data).unwrap();
        assert_eq!(report.rows, 100);
        assert_eq!(p.rows(), 100);
        assert_eq!(p.store().ids(), (0..100).collect::<Vec<u64>>());
        assert_eq!(p.metrics().rows_ingested, 100);
        assert!(report.sketch_bytes < report.data_bytes);
    }

    #[test]
    fn second_ingest_appends_ids() {
        let c = cfg(10, 32);
        let data = gen::generate(DataDist::Uniform01, 10, 32, 2);
        let p = Pipeline::new(c).unwrap();
        p.ingest(&data).unwrap();
        p.ingest(&data).unwrap();
        assert_eq!(p.rows(), 20);
        assert_eq!(p.store().ids(), (0..20).collect::<Vec<u64>>());
    }

    #[test]
    fn estimates_track_exact_distances() {
        // Gaussian (centered) data: the marginal norms do not dwarf the
        // distance, so the k=64 estimator has moderate relative error.
        // (On similar non-negative rows the plain estimator's relative
        // error is intrinsically large — that is what Lemma 4 is for.)
        let mut c = cfg(40, 128);
        c.k = 64;
        let data = gen::generate(DataDist::Gaussian, c.n, c.d, 3);
        let p = Pipeline::new(c).unwrap();
        p.ingest(&data).unwrap();
        // Averaged relative error over pairs should be moderate at k=64.
        let mut rel = 0.0;
        let mut count = 0;
        for i in 0..10u64 {
            for j in (i + 1)..10u64 {
                let est = p.estimate_pair(i, j).unwrap();
                let exact = exact_distance(
                    &data.row_f64(i as usize),
                    &data.row_f64(j as usize),
                    4,
                );
                rel += (est - exact).abs() / exact;
                count += 1;
            }
        }
        rel /= count as f64;
        assert!(rel < 0.5, "mean rel err {rel}");
    }

    #[test]
    fn unknown_id_is_none() {
        let c = cfg(5, 32);
        let data = gen::generate(DataDist::Uniform01, 5, 32, 4);
        let p = Pipeline::new(c).unwrap();
        p.ingest(&data).unwrap();
        assert!(p.estimate_pair(0, 99).is_none());
    }

    #[test]
    fn all_pairs_matches_pointwise() {
        let c = cfg(12, 64);
        let data = gen::generate(DataDist::LogNormal { sigma: 1.0 }, 12, 64, 5);
        let p = Pipeline::new(c).unwrap();
        p.ingest(&data).unwrap();
        let all = p.all_pairs_condensed();
        for i in 0..12u64 {
            for j in (i + 1)..12u64 {
                let idx = crate::baselines::exact::condensed_index(12, i as usize, j as usize);
                let single = p.estimate_pair(i, j).unwrap();
                assert!((all[idx] - single).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn all_pairs_arena_matches_per_row_reference() {
        let c = cfg(30, 64);
        let data = gen::generate(DataDist::Gaussian, 30, 64, 15);
        let p = Pipeline::new(c).unwrap();
        p.ingest(&data).unwrap();
        let arena = p.all_pairs_condensed();
        let per_row = p.all_pairs_condensed_per_row();
        assert_eq!(arena.len(), per_row.len());
        for (a, b) in arena.iter().zip(&per_row) {
            assert!((a - b).abs() <= 1e-12 * b.abs().max(1.0), "{a} vs {b}");
        }
    }

    #[test]
    fn all_pairs_on_tiny_stores_is_empty_not_a_panic() {
        let c = cfg(5, 32);
        let p = Pipeline::new(c.clone()).unwrap();
        // Nothing ingested: n = 0.
        assert!(p.all_pairs_condensed().is_empty());
        assert!(p.all_pairs_condensed_per_row().is_empty());
        // One row: no pairs.
        let data = gen::generate(DataDist::Uniform01, 1, 32, 8);
        p.ingest(&data).unwrap();
        assert!(p.all_pairs_condensed().is_empty());
    }

    #[test]
    fn batched_pairs_match_single_queries() {
        let c = cfg(40, 64);
        let data = gen::generate(DataDist::Uniform01, 40, 64, 9);
        let p = Pipeline::new(c).unwrap();
        p.ingest(&data).unwrap();
        // Big batch (arena path), including unknown ids.
        let mut pairs: Vec<(u64, u64)> = (0..40u64)
            .flat_map(|i| (0..4u64).map(move |j| (i, (i * 3 + j + 1) % 40)))
            .collect();
        pairs.push((0, 999)); // unknown
        pairs.push((999, 1)); // unknown
        let batched = p.estimate_pairs(&pairs);
        for (&(a, b), got) in pairs.iter().zip(&batched) {
            assert_eq!(*got, p.estimate_pair(a, b), "pair ({a},{b})");
        }
        // Small batch (per-pair path) agrees too.
        let small = p.estimate_pairs(&pairs[..3]);
        assert_eq!(small, batched[..3].to_vec());
    }

    #[test]
    fn query_service_round_trips() {
        let c = cfg(20, 32);
        let data = gen::generate(DataDist::Uniform01, 20, 32, 6);
        let p = Arc::new(Pipeline::new(c).unwrap());
        p.ingest(&data).unwrap();
        let h = p.spawn_query_service();
        let mut threads = Vec::new();
        for t in 0..4u64 {
            let h = h.clone();
            threads.push(std::thread::spawn(move || {
                for i in 0..5u64 {
                    let got = h.query(t, (t + i + 1) % 20).unwrap();
                    assert!(got.is_some());
                }
            }));
        }
        for t in threads {
            t.join().unwrap();
        }
        let snap = p.metrics();
        assert!(snap.batches_flushed >= 1);
        assert_eq!(snap.queries_served, 20);
    }

    #[test]
    fn gemm_ingest_matches_per_row_ingest() {
        // End-to-end old-path vs new-path equivalence: same ids, same
        // sketches within f32 accumulation tolerance, same moments to
        // f64 precision, same estimates — only the counters differ.
        for strategy in [Strategy::Basic, Strategy::Alternative] {
            let mut c = cfg(60, 128);
            c.k = 32;
            c.strategy = strategy;
            let data = gen::generate(DataDist::Gaussian, c.n, c.d, 33);
            let gemm = Pipeline::new(c.clone()).unwrap();
            gemm.ingest(&data).unwrap();
            let mut c2 = c.clone();
            c2.ingest_gemm = false;
            let per_row = Pipeline::new(c2).unwrap();
            per_row.ingest(&data).unwrap();
            assert_eq!(gemm.store().ids(), per_row.store().ids());
            for id in gemm.store().ids() {
                let a = gemm.store().get(id).unwrap();
                let b = per_row.store().get(id).unwrap();
                for (x, y) in a.uside.data.iter().zip(&b.uside.data) {
                    assert!((x - y).abs() <= 1e-3 * (1.0 + y.abs()), "id {id}: {x} vs {y}");
                }
                for (x, y) in a.vside().data.iter().zip(&b.vside().data) {
                    assert!((x - y).abs() <= 1e-3 * (1.0 + y.abs()), "id {id} vside");
                }
                for o in 1..=a.moments.len() {
                    let (x, y) = (a.moments.get(o), b.moments.get(o));
                    assert!((x - y).abs() <= 1e-9 * (1.0 + y.abs()), "id {id} moment {o}");
                }
            }
            let ga = gemm.all_pairs_condensed();
            let pa = per_row.all_pairs_condensed();
            assert_eq!(ga.len(), pa.len());
            for (x, y) in ga.iter().zip(&pa) {
                assert!((x - y).abs() <= 1e-3 * (1.0 + y.abs()), "{x} vs {y}");
            }
            assert!(gemm.metrics().gemm_calls > 0);
            assert_eq!(gemm.metrics().fallback_calls, 0);
            assert!(per_row.metrics().fallback_calls > 0);
            assert_eq!(per_row.metrics().gemm_calls, 0);
        }
    }

    #[test]
    fn gemm_ingest_serves_every_query_path() {
        // Columnar segments must serve single-pair, batched, and MLE
        // queries (materialized per-row views) identically to the
        // snapshot-driven paths.
        let mut c = cfg(40, 64);
        c.k = 32;
        let data = gen::generate(DataDist::Uniform01, c.n, c.d, 41);
        let p = Pipeline::new(c.clone()).unwrap();
        p.ingest(&data).unwrap();
        assert!(p.estimate_pair(0, 39).unwrap().is_finite());
        let pairs: Vec<(u64, u64)> = (0..40u64).map(|i| (i, (i + 7) % 40)).collect();
        let batched = p.estimate_pairs(&pairs);
        for (&(a, b), got) in pairs.iter().zip(&batched) {
            assert_eq!(*got, p.estimate_pair(a, b), "pair ({a},{b})");
        }
        // MLE mode on GEMM-ingested sketches.
        let mut cm = c.clone();
        cm.use_mle = true;
        let pm = Pipeline::new(cm).unwrap();
        pm.ingest(&data).unwrap();
        assert!(pm.estimate_pair(1, 2).unwrap().is_finite());
    }

    #[test]
    fn ingest_compaction_hook_bounds_segments_and_keeps_estimates() {
        let mut c = cfg(64, 64);
        c.k = 16;
        c.block_rows = 8; // 8 tiny segments without compaction
        c.compact_min_rows = 0; // baseline: hook disabled
        let data = gen::generate(DataDist::Gaussian, c.n, c.d, 51);
        let plain = Pipeline::new(c.clone()).unwrap();
        plain.ingest(&data).unwrap();
        assert_eq!(plain.metrics().segment_count, 8);
        assert_eq!(plain.metrics().compactions, 0);
        let mut cc = c.clone();
        cc.compact_min_rows = 64;
        let compacted = Pipeline::new(cc).unwrap();
        compacted.ingest(&data).unwrap();
        // Adjacent 8-row segments merge into one 64-row segment.
        assert_eq!(compacted.metrics().segment_count, 1);
        assert!(compacted.metrics().compactions >= 1);
        assert_eq!(compacted.store().segments_snapshot()[0].1.rows(), 64);
        // Compaction is estimate-invariant: both stores hold the same
        // sketches, so every estimate matches bitwise.
        assert_eq!(plain.all_pairs_condensed(), compacted.all_pairs_condensed());
        for (a, b) in [(0u64, 63u64), (5, 40), (62, 63)] {
            assert_eq!(plain.estimate_pair(a, b), compacted.estimate_pair(a, b));
        }
    }

    #[test]
    fn with_store_restores_queries_ids_and_segment_metric() {
        let c = cfg(30, 64);
        let data = gen::generate(DataDist::Uniform01, c.n, c.d, 61);
        let p1 = Pipeline::new(c.clone()).unwrap();
        p1.ingest(&data).unwrap();
        let want = p1.all_pairs_condensed();
        // Hand the store to a fresh pipeline (the persistence-restore
        // shape; rebalance produces an identical copy).
        let (copy, _) = crate::coordinator::rebalance::rebalance(p1.store(), 5);
        let p2 = Pipeline::with_store(c.clone(), copy).unwrap();
        assert!(p2.metrics().segment_count > 0, "columnar layout lost in adoption");
        assert_eq!(p2.all_pairs_condensed(), want);
        // Fresh ingest continues past the adopted ids.
        p2.ingest(&data).unwrap();
        assert_eq!(p2.store().ids(), (0..60).collect::<Vec<u64>>());
        // Shape mismatch is an error, not silent corruption.
        let (copy2, _) = crate::coordinator::rebalance::rebalance(p1.store(), 2);
        let mut bad = c.clone();
        bad.k = 16;
        assert!(Pipeline::with_store(bad, copy2).is_err());
        // So is sidedness mismatch (one-sided rows under an
        // alternative-strategy config would mis-pair query sketches).
        let (copy3, _) = crate::coordinator::rebalance::rebalance(p1.store(), 2);
        let mut alt = c.clone();
        alt.strategy = Strategy::Alternative;
        assert!(Pipeline::with_store(alt, copy3).is_err());
    }

    #[test]
    fn top_k_is_consistent_across_batching_and_workers() {
        let mut c = cfg(50, 64);
        c.k = 32;
        let data = gen::generate(DataDist::Gaussian, c.n, c.d, 71);
        let p = Pipeline::new(c.clone()).unwrap();
        p.ingest(&data).unwrap();
        let queries: Vec<&[f32]> = (0..4).map(|i| data.row(i * 11)).collect();
        let batch = p.top_k(&queries, 5).unwrap();
        assert_eq!(batch.len(), 4);
        for (qi, lst) in batch.iter().enumerate() {
            assert_eq!(lst.len(), 5);
            // Ascending distances, valid store ids.
            for w in lst.windows(2) {
                assert!(w[0].1 <= w[1].1);
            }
            assert!(lst.iter().all(|&(id, _)| p.store().contains(id)));
            // Batch equals the single-query call.
            assert_eq!(&batch[qi], &p.top_k(&queries[qi..qi + 1], 5).unwrap()[0]);
        }
        // Worker count never changes results (same data, same seed ⇒
        // bitwise-identical store on both pipelines).
        let mut cw = c.clone();
        cw.workers = 1;
        let pw = Pipeline::new(cw).unwrap();
        pw.ingest(&data).unwrap();
        assert_eq!(pw.top_k(&queries, 5).unwrap(), batch);
        // Empty query batch and empty store are fine.
        assert!(p.top_k(&[], 5).unwrap().is_empty());
        let empty = Pipeline::new(c.clone()).unwrap();
        let lists = empty.top_k(&queries[..1], 5).unwrap();
        assert_eq!(lists.len(), 1);
        assert!(lists[0].is_empty());
    }

    #[test]
    fn top_k_ids_matches_top_k_on_the_ingested_vector() {
        // A stored id's top-k (served from its stored sketch) must rank
        // bitwise-identically to top-k on the raw vector that produced
        // that sketch — the two entry points share the kernel and the
        // query sketch.
        let mut c = cfg(40, 64);
        c.k = 32;
        let data = gen::generate(DataDist::Gaussian, c.n, c.d, 81);
        let p = Pipeline::new(c).unwrap();
        p.ingest(&data).unwrap();
        let ids = [0u64, 7, 39];
        let by_id = p.top_k_ids(&ids, 6);
        let queries: Vec<&[f32]> = ids.iter().map(|&id| data.row(id as usize)).collect();
        let by_vec = p.top_k(&queries, 6).unwrap();
        for (i, lst) in by_id.iter().enumerate() {
            assert_eq!(lst.as_ref().unwrap(), &by_vec[i], "id {}", ids[i]);
        }
        // Unknown ids answer None without disturbing known ones.
        let mixed = p.top_k_ids(&[7, 9999], 6);
        assert_eq!(mixed[0].as_ref().unwrap(), &by_vec[1]);
        assert!(mixed[1].is_none());
        assert!(p.top_k_ids(&[], 6).is_empty());
        assert_eq!(p.top_k_ids(&[12345], 6), vec![None]);
    }

    #[test]
    fn typed_api_answers_match_direct_calls() {
        use crate::api::{Request, Response, TopKTarget};
        let mut c = cfg(32, 64);
        c.k = 32;
        let data = gen::generate(DataDist::Uniform01, c.n, c.d, 91);
        let p = Pipeline::new(c).unwrap();
        p.ingest(&data).unwrap();
        let pairs: Vec<(u64, u64)> = (0..32u64).map(|i| (i, (i + 3) % 32)).collect();
        match p.answer(Request::PairBatch(pairs.clone())) {
            Response::PairBatch(got) => assert_eq!(got, p.estimate_pairs(&pairs)),
            other => panic!("unexpected {other:?}"),
        }
        match p.answer(Request::TopK { target: TopKTarget::StoredId(5), top: 4 }) {
            Response::TopK(got) => {
                assert_eq!(got, p.top_k_ids(&[5], 4)[0].clone().unwrap())
            }
            other => panic!("unexpected {other:?}"),
        }
        let q = data.row(9);
        match p.answer(Request::TopK { target: TopKTarget::Vector(q.to_vec()), top: 4 }) {
            Response::TopK(got) => assert_eq!(got, p.top_k(&[q], 4).unwrap()[0]),
            other => panic!("unexpected {other:?}"),
        }
        let ids: Vec<u64> = (0..32).chain([999]).collect();
        match p.answer(Request::VectorDistance { vector: q.to_vec(), ids: ids.clone() }) {
            Response::VectorDistance(got) => {
                assert_eq!(got, p.vector_distances(q, &ids).unwrap())
            }
            other => panic!("unexpected {other:?}"),
        }
        match p.answer(Request::Stats) {
            Response::Stats(s) => {
                assert_eq!(s.rows, 32);
                assert!(s.projection_known);
                assert_eq!(s.p, 4);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert!(matches!(p.answer(Request::Ping), Response::Pong { .. }));
        // Unknown id on top-k is a typed error, not a panic.
        match p.answer(Request::TopK { target: TopKTarget::StoredId(777), top: 2 }) {
            Response::Error(e) => assert!(e.contains("unknown id"), "{e}"),
            other => panic!("unexpected {other:?}"),
        }
        // A fresh vector of the wrong width is rejected, not sketched
        // as if zero-padded and silently mis-scored.
        match p.answer(Request::VectorDistance { vector: vec![1.0; 7], ids: vec![0] }) {
            Response::Error(e) => assert!(e.contains("ingested at d="), "{e}"),
            other => panic!("unexpected {other:?}"),
        }
        assert!(p.vector_distances(&[1.0; 7], &[0]).is_err());
        assert!(p.top_k(&[&[1.0; 7][..]], 3).is_err());
        match p.answer(Request::TopK { target: TopKTarget::Vector(vec![]), top: 2 }) {
            Response::Error(e) => assert!(e.contains("empty query vector"), "{e}"),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn unknown_projection_rejects_fresh_vector_queries_only() {
        use crate::api::{Request, Response, TopKTarget};
        let c = cfg(20, 64);
        let data = gen::generate(DataDist::Uniform01, c.n, c.d, 95);
        let origin = Pipeline::new(c.clone()).unwrap();
        origin.ingest(&data).unwrap();
        let (copy, _) = crate::coordinator::rebalance::rebalance(origin.store(), 3);
        let restored = Pipeline::with_store_restored(c, copy, false).unwrap();
        assert!(!restored.projection_known());
        // Stored-id queries still work, bitwise.
        assert_eq!(restored.estimate_pair(0, 5), origin.estimate_pair(0, 5));
        assert_eq!(restored.top_k_ids(&[3], 4), origin.top_k_ids(&[3], 4));
        // Fresh-vector queries fail loudly.
        let q = data.row(2);
        let err = restored.top_k(&[q], 4).unwrap_err().to_string();
        assert!(err.contains("projection parameters"), "{err}");
        assert!(restored.vector_distances(q, &[0, 1]).is_err());
        match restored.answer(Request::TopK { target: TopKTarget::Vector(q.to_vec()), top: 3 }) {
            Response::Error(e) => assert!(e.contains("projection parameters"), "{e}"),
            other => panic!("unexpected {other:?}"),
        }
        // Stats advertises the limitation.
        match restored.answer(Request::Stats) {
            Response::Stats(s) => assert!(!s.projection_known),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn ingest_compaction_is_incremental_per_run() {
        // The post-ingest hook only compacts the run of segments the
        // current ingest appended: two ingests leave two (internally
        // merged) segments; a full-store pass may still merge across
        // runs.
        let mut c = cfg(32, 64);
        c.k = 16;
        c.block_rows = 8;
        c.compact_min_rows = 1024; // everything is "small"
        let data = gen::generate(DataDist::Gaussian, c.n, c.d, 77);
        let p = Pipeline::new(c.clone()).unwrap();
        p.ingest(&data).unwrap();
        assert_eq!(p.metrics().segment_count, 1, "run of 4 blocks merges to 1");
        p.ingest(&data).unwrap();
        assert_eq!(
            p.metrics().segment_count,
            2,
            "second run compacts itself but never reaches back across runs"
        );
        let before = p.all_pairs_condensed();
        // Full-store compaction (the explicit knob) merges across runs
        // and changes no estimate.
        let report = p.compact();
        assert_eq!(report.merges, 1);
        assert_eq!(p.metrics().segment_count, 1);
        assert_eq!(p.all_pairs_condensed(), before);
    }

    #[test]
    fn query_service_answers_while_ingest_runs() {
        // The serving claim end-to-end: pair batches keep being
        // answered while a writer streams new rows in. Every answer
        // must come from a consistent snapshot (ids 0..20 are fully
        // ingested before the service starts, so they are present in
        // every epoch the service can capture).
        let c = cfg(20, 32);
        let data = gen::generate(DataDist::Uniform01, 20, 32, 29);
        let p = Arc::new(Pipeline::new(c).unwrap());
        p.ingest(&data).unwrap();
        let h = p.spawn_query_service();
        std::thread::scope(|s| {
            let writer = {
                let p = Arc::clone(&p);
                s.spawn(move || {
                    for _ in 0..3 {
                        p.ingest(&data).unwrap();
                    }
                })
            };
            for t in 0..3u64 {
                let h = h.clone();
                s.spawn(move || {
                    for i in 0..40u64 {
                        let got = h.query((t * 7 + i) % 20, (t * 3 + i * 5 + 1) % 20).unwrap();
                        assert!(got.is_some(), "pre-ingested ids must always resolve");
                    }
                });
            }
            writer.join().unwrap();
        });
        assert_eq!(p.rows(), 80);
        let snap = p.metrics();
        assert_eq!(snap.queries_in_flight, 0, "gauge must return to zero");
        assert!(snap.queries_served >= 3 * 40);
    }

    #[test]
    fn assemble_columnar_matches_per_row_unpack() {
        // The PJRT columnar assembly vs the pinned per-row unpack
        // layout, on synthetic artifact outputs (no engine needed):
        // u[ord][row][j] = ord·1000 + row·10 + j, padded to b rows;
        // moments column-major m[o][row] = o + row/100.
        let (orders, k, nm, rows, b) = (3usize, 4usize, 6usize, 5usize, 8usize);
        let mut u = vec![0.0f32; orders * b * k];
        for ord in 0..orders {
            for r in 0..b {
                for j in 0..k {
                    u[(ord * b + r) * k + j] = (ord * 1000 + r * 10 + j) as f32;
                }
            }
        }
        let mut m = vec![0.0f32; nm * b];
        for o in 0..nm {
            for r in 0..b {
                m[o * b + r] = o as f32 + r as f32 / 100.0;
            }
        }
        let v: Vec<f32> = u.iter().map(|x| -x).collect();
        let block = assemble_columnar(orders, k, nm, rows, b, &u, &m, Some(&v));
        assert_eq!(block.rows(), rows);
        assert!(block.is_two_sided());
        for r in 0..rows {
            for ord in 1..=orders {
                // Exactly the slice the per-row unpack would copy.
                let want = &u[((ord - 1) * b + r) * k..((ord - 1) * b + r + 1) * k];
                assert_eq!(block.u_row(ord, r), want, "u ord {ord} row {r}");
                let wantv = &v[((ord - 1) * b + r) * k..((ord - 1) * b + r + 1) * k];
                assert_eq!(block.v_row(ord, r), wantv, "v ord {ord} row {r}");
            }
            for o in 1..=nm {
                assert_eq!(block.moment(r, o), m[(o - 1) * b + r] as f64, "moment {o} row {r}");
            }
        }
        // One-sided assembly mirrors the u side only.
        let one = assemble_columnar(orders, k, nm, rows, b, &u, &m, None);
        assert!(!one.is_two_sided());
        assert_eq!(one.u_row(2, 3), block.u_row(2, 3));
    }

    #[test]
    fn serving_index_refresh_is_incremental_and_metered() {
        use crate::api::{Request, Response, TopKTarget};
        let mut c = cfg(32, 64);
        c.k = 16;
        c.block_rows = 16;
        c.compact_min_rows = 0; // keep segments exactly as ingested
        let data = gen::generate(DataDist::Gaussian, c.n, c.d, 97);
        let p = Pipeline::new(c).unwrap();
        p.ingest(&data).unwrap();
        let segs0 = p.store().segment_count() as u64;
        assert!(segs0 >= 2);
        match p.answer(Request::TopK { target: TopKTarget::StoredId(0), top: 4 }) {
            Response::TopK(lst) => assert_eq!(lst.len(), 4),
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(p.metrics().knn_segments_reindexed, segs0, "cold build indexes every segment");
        // Quiescent store: the cached index serves, nothing re-indexed.
        let _ = p.answer(Request::TopK { target: TopKTarget::StoredId(1), top: 4 });
        assert_eq!(p.metrics().knn_segments_reindexed, segs0);
        // Appending ingest: the refresh re-indexes ONLY the new
        // segments — the running total lands on the new segment count,
        // not segs0 + segs1.
        p.ingest(&data).unwrap();
        let segs1 = p.store().segment_count() as u64;
        assert!(segs1 > segs0);
        let got = match p.answer(Request::TopK { target: TopKTarget::StoredId(5), top: 4 }) {
            Response::TopK(lst) => lst,
            other => panic!("unexpected {other:?}"),
        };
        assert_eq!(
            p.metrics().knn_segments_reindexed,
            segs1,
            "refresh must re-index only segments newer than the cached epoch"
        );
        // The incrementally refreshed index answers bitwise-identically
        // to a cold rebuild of the same snapshot.
        let snap = p.store_snapshot();
        let (cold, ids) = crate::knn::KnnIndex::from_snapshot(
            &snap,
            p.config().projection_spec(),
            p.config().p,
        )
        .unwrap();
        let pos = ids.binary_search(&5).unwrap();
        let via_cold: Vec<(u64, f64)> =
            cold.query_pos(pos, 4).into_iter().map(|nb| (ids[nb.index], nb.distance)).collect();
        assert_eq!(got, via_cold);
        // The zoned serve path kept its pruning books: every request
        // visited each segment at most once.
        let m = p.metrics();
        assert!(m.topk_segments_visited + m.topk_segments_skipped > 0);
    }

    #[test]
    fn mle_config_changes_estimates() {
        let mut c = cfg(10, 64);
        let data = gen::generate(DataDist::Uniform01, 10, 64, 7);
        let plain = Pipeline::new(c.clone()).unwrap();
        plain.ingest(&data).unwrap();
        c.use_mle = true;
        let mle = Pipeline::new(c).unwrap();
        mle.ingest(&data).unwrap();
        let a = plain.estimate_pair(0, 1).unwrap();
        let b = mle.estimate_pair(0, 1).unwrap();
        assert_ne!(a, b, "MLE should adjust the plain estimate");
        assert!(a.is_finite() && b.is_finite());
    }
}
