//! Shard rebalancing: grow or shrink the worker/shard count of a live
//! [`SketchStore`] without losing rows.
//!
//! The store's shard assignment is `id % shards`; changing the shard
//! count therefore moves ~(1 − 1/max(old,new)) of the rows. Rebalancing
//! is an offline-ish operation (the pipeline quiesces queries around
//! it), but it must be *total* and *cheap in memory* — rows move shard
//! by shard rather than through one big clone.
//!
//! This is the operational knob behind E10's worker sweep: a deployment
//! that scales workers up or down re-shards the existing sketches
//! instead of re-ingesting the data (the whole point is that the raw
//! O(nD) matrix is gone after the scan).

use std::sync::Arc;

use crate::projection::sketcher::RowSketch;

use super::state::{CompactionReport, SketchStore};

/// Report of one rebalance operation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RebalanceReport {
    pub rows: usize,
    pub moved: usize,
    pub old_shards: usize,
    pub new_shards: usize,
}

/// Build a store with `new_shards` shards containing exactly the rows of
/// `store`. Returns the new store and a movement report.
///
/// Runs on one epoch snapshot of the source store — a consistent cut,
/// taken without pausing ingest. Columnar segments are
/// shard-independent (sharding only partitions the hashmap rows), so
/// they carry over by `Arc` handle: the new store *shares* the source's
/// panels instead of copying them (copy-on-write — a later compaction
/// in either store publishes fresh blocks without disturbing the
/// other). `moved` counts map rows only: segment rows never had a
/// shard assignment to move from.
pub fn rebalance(store: &SketchStore, new_shards: usize) -> (SketchStore, RebalanceReport) {
    let snap = store.snapshot();
    let new = SketchStore::new(new_shards);
    let mut moved = 0usize;
    let mut rows = 0usize;
    for seg in snap.segments() {
        rows += seg.block.rows();
        new.insert_block_shared(seg.base, Arc::clone(&seg.block));
    }
    for id in snap.map_ids() {
        let sketch: RowSketch = snap.get(id).expect("id listed but missing");
        rows += 1;
        if store.shard_of(id) != new.shard_of(id) {
            moved += 1;
        }
        new.insert(id, sketch);
    }
    let report = RebalanceReport {
        rows,
        moved,
        old_shards: store.shard_count(),
        new_shards: new.shard_count(),
    };
    (new, report)
}

/// [`rebalance`] followed by a segment-compaction pass on the new store
/// — the natural moment to merge small segments, since rebalancing
/// already rebuilds the whole store and quiesces queries around it.
/// `min_rows == 0` makes the compaction a no-op (see
/// [`SketchStore::compact_segments`]).
pub fn rebalance_compacted(
    store: &SketchStore,
    new_shards: usize,
    min_rows: usize,
    target_rows: usize,
) -> (SketchStore, RebalanceReport, CompactionReport) {
    let (new, report) = rebalance(store, new_shards);
    let compaction = new.compact_segments(min_rows, target_rows);
    (new, report, compaction)
}

/// Expected fraction of rows that change shards when going old → new
/// (for dense sequential ids): 1 − 1/lcm-ish; exact closed form is
/// data-dependent, so we expose the measured fraction instead.
pub fn moved_fraction(report: &RebalanceReport) -> f64 {
    if report.rows == 0 {
        return 0.0;
    }
    report.moved as f64 / report.rows as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::projection::sketcher::Sketcher;
    use crate::projection::{ProjectionDist, ProjectionSpec, Strategy};

    fn store_with(n: u64, shards: usize) -> SketchStore {
        let sk = Sketcher::new(
            ProjectionSpec::new(1, 8, ProjectionDist::Normal, Strategy::Basic),
            4,
        );
        let store = SketchStore::new(shards);
        for id in 0..n {
            store.insert(id, sk.sketch_row(&[id as f32, 1.0, -0.5]));
        }
        store
    }

    #[test]
    fn rebalance_preserves_every_row() {
        let store = store_with(100, 3);
        let (new, report) = rebalance(&store, 7);
        assert_eq!(report.rows, 100);
        assert_eq!(new.len(), 100);
        assert_eq!(new.ids(), store.ids());
        // Content identical.
        for id in [0u64, 13, 99] {
            assert_eq!(
                new.get(id).unwrap().uside.data,
                store.get(id).unwrap().uside.data
            );
        }
    }

    #[test]
    fn same_shard_count_moves_nothing() {
        let store = store_with(50, 4);
        let (_, report) = rebalance(&store, 4);
        assert_eq!(report.moved, 0);
        assert_eq!(moved_fraction(&report), 0.0);
    }

    #[test]
    fn growing_moves_bounded_fraction() {
        let store = store_with(1000, 4);
        let (_, report) = rebalance(&store, 8);
        // Mod-sharding 4→8 moves exactly the ids with id%8 >= 4: half.
        assert_eq!(report.moved, 500);
    }

    #[test]
    fn shrink_to_one_shard() {
        let store = store_with(20, 8);
        let (new, report) = rebalance(&store, 1);
        assert_eq!(new.shard_count(), 1);
        assert_eq!(new.len(), 20);
        assert!(report.moved > 0);
    }

    #[test]
    fn rebalance_preserves_columnar_segments() {
        // Segment-backed rows survive re-sharding verbatim (still
        // columnar, not degraded to map entries) and count as unmoved.
        let sk = Sketcher::new(
            ProjectionSpec::new(1, 8, ProjectionDist::Normal, Strategy::Basic),
            4,
        );
        let store = store_with(10, 3); // map ids 0..10
        let rows: Vec<Vec<f32>> = (0..5)
            .map(|i| (0..16).map(|t| ((i * 7 + t) as f32 * 0.21).sin()).collect())
            .collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        store.insert_block_columnar(100, sk.sketch_block(&refs, 1)); // ids 100..105
        let (new, report) = rebalance(&store, 7);
        assert_eq!(report.rows, 15);
        assert_eq!(new.len(), 15);
        assert_eq!(new.ids(), store.ids());
        assert_eq!(new.segments_snapshot().len(), 1);
        assert!(new.map_ids().iter().all(|&id| id < 10));
        assert_eq!(
            new.get(103).unwrap().uside.data,
            store.get(103).unwrap().uside.data
        );
    }

    #[test]
    fn rebalance_shares_segment_panels_instead_of_copying() {
        let sk = Sketcher::new(
            ProjectionSpec::new(1, 8, ProjectionDist::Normal, Strategy::Basic),
            4,
        );
        let store = SketchStore::new(2);
        let rows: Vec<Vec<f32>> = (0..4)
            .map(|i| (0..16).map(|t| ((i * 5 + t) as f32 * 0.23).sin()).collect())
            .collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        store.insert_block_columnar(100, sk.sketch_block(&refs, 1));
        let (new, _) = rebalance(&store, 5);
        let (a, b) = (store.segments_snapshot(), new.segments_snapshot());
        assert!(Arc::ptr_eq(&a[0].1, &b[0].1), "rebalance must share panels by Arc");
    }

    #[test]
    fn rebalance_compacted_merges_segments_and_keeps_rows() {
        let sk = Sketcher::new(
            ProjectionSpec::new(1, 8, ProjectionDist::Normal, Strategy::Basic),
            4,
        );
        let store = SketchStore::new(2);
        for b in 0..4u64 {
            let rows: Vec<Vec<f32>> = (0..3)
                .map(|i| (0..16).map(|t| ((b * 3 + i + t) as f32 * 0.19).sin()).collect())
                .collect();
            let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
            store.insert_block_columnar(100 + b * 3, sk.sketch_block(&refs, 1));
        }
        assert_eq!(store.segment_count(), 4);
        let (new, report, compaction) = rebalance_compacted(&store, 5, 64, 1024);
        assert_eq!(report.rows, 12);
        assert_eq!(compaction.merges, 1);
        assert_eq!(new.segment_count(), 1);
        assert_eq!(new.ids(), store.ids());
        assert_eq!(
            new.get(105).unwrap().uside.data,
            store.get(105).unwrap().uside.data
        );
        // min_rows = 0: rebalance alone, no merging.
        let (plain, _, compaction) = rebalance_compacted(&store, 3, 0, 1024);
        assert_eq!(compaction.merges, 0);
        assert_eq!(plain.segment_count(), 4);
    }

    #[test]
    fn queries_work_after_rebalance() {
        use crate::core::decompose::Decomposition;
        use crate::core::estimator;
        let store = store_with(30, 2);
        let dec = Decomposition::new(4).unwrap();
        let before = store
            .with_pair(3, 17, |a, b| estimator::estimate(&dec, a, b))
            .unwrap();
        let (new, _) = rebalance(&store, 5);
        let after = new
            .with_pair(3, 17, |a, b| estimator::estimate(&dec, a, b))
            .unwrap();
        assert_eq!(before, after);
    }
}
