//! Shard router: assigns row ids to worker shards and pair queries to
//! their owning shards.
//!
//! Routing must be a *partition* (DESIGN.md §7): every id maps to
//! exactly one shard, stable across the pipeline's lifetime, and in
//! agreement with [`SketchStore::shard_of`](super::state::SketchStore).
//! Two policies:
//! * `Mod` — id % shards: perfect balance for dense id ranges (the
//!   default; ingest assigns ids sequentially).
//! * `Range` — contiguous blocks: preserves block locality when queries
//!   scan id ranges (the all-pairs export path).

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    Mod,
    /// Range routing needs the total id-space size.
    Range { total: u64 },
}

/// Router over `shards` workers.
#[derive(Clone, Copy, Debug)]
pub struct Router {
    pub shards: usize,
    pub policy: Policy,
}

impl Router {
    pub fn new_mod(shards: usize) -> Self {
        Router { shards: shards.max(1), policy: Policy::Mod }
    }

    pub fn new_range(shards: usize, total: u64) -> Self {
        Router { shards: shards.max(1), policy: Policy::Range { total } }
    }

    /// The shard owning row `id`.
    #[inline]
    pub fn route(&self, id: u64) -> usize {
        match self.policy {
            Policy::Mod => (id % self.shards as u64) as usize,
            Policy::Range { total } => {
                let per = total.div_ceil(self.shards as u64).max(1);
                ((id / per) as usize).min(self.shards - 1)
            }
        }
    }

    /// Shard of a *pair* query: the shard of the smaller id (a stable,
    /// balance-preserving convention — each unordered pair has exactly
    /// one home).
    #[inline]
    pub fn route_pair(&self, a: u64, b: u64) -> usize {
        self.route(a.min(b))
    }

    /// Per-shard load for ids `0..n` (test/bench helper).
    pub fn load(&self, n: u64) -> Vec<u64> {
        let mut counts = vec![0u64; self.shards];
        for id in 0..n {
            counts[self.route(id)] += 1;
        }
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mod_routing_is_partition_and_balanced() {
        let r = Router::new_mod(4);
        let load = r.load(1000);
        assert_eq!(load.iter().sum::<u64>(), 1000);
        assert!(load.iter().all(|&c| (249..=251).contains(&c)), "{load:?}");
    }

    #[test]
    fn range_routing_is_partition_and_contiguous() {
        let r = Router::new_range(3, 10);
        let shards: Vec<usize> = (0..10).map(|i| r.route(i)).collect();
        assert_eq!(shards, vec![0, 0, 0, 0, 1, 1, 1, 1, 2, 2]);
        // Monotone ⇒ contiguous ranges.
        assert!(shards.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn range_routing_never_overflows_shards() {
        let r = Router::new_range(4, 3); // more shards than ids
        for id in 0..3 {
            assert!(r.route(id) < 4);
        }
        // Ids beyond `total` still route somewhere valid.
        assert!(r.route(1_000_000) < 4);
    }

    #[test]
    fn pair_routing_is_symmetric() {
        let r = Router::new_mod(5);
        for a in 0..20u64 {
            for b in 0..20u64 {
                assert_eq!(r.route_pair(a, b), r.route_pair(b, a));
            }
        }
    }

    #[test]
    fn matches_store_sharding() {
        use crate::coordinator::state::SketchStore;
        let store = SketchStore::new(6);
        let r = Router::new_mod(6);
        for id in 0..100 {
            assert_eq!(r.route(id), store.shard_of(id));
        }
    }
}
