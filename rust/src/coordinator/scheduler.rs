//! Block scheduler: slices a row stream into fixed-size blocks (the
//! sketch-artifact batch unit), assigning stable row ids.
//!
//! Blocks are the unit of work the pipeline moves through its bounded
//! channels; their size trades PJRT dispatch overhead against latency
//! and padding waste (the last block of a stream is padded to the
//! artifact's B on the PJRT path — the scheduler records the logical
//! `rows` so padded tails are never stored).

/// A scheduled block of rows, row-major.
#[derive(Clone, Debug)]
pub struct Block {
    /// Sequential block id (0-based).
    pub id: u64,
    /// Row id of the first row.
    pub first_row: u64,
    /// Logical row count (≤ capacity; the tail block may be short).
    pub rows: usize,
    /// Feature width.
    pub d: usize,
    /// rows × d values.
    pub data: Vec<f32>,
}

impl Block {
    pub fn row(&self, i: usize) -> &[f32] {
        assert!(i < self.rows);
        &self.data[i * self.d..(i + 1) * self.d]
    }

    pub fn row_id(&self, i: usize) -> u64 {
        self.first_row + i as u64
    }

    /// Copy of the data zero-padded to `b` rows (the PJRT path's fixed
    /// batch shape). Zero rows sketch to zero and are dropped by the
    /// worker, so padding is semantically invisible.
    pub fn padded(&self, b: usize) -> Vec<f32> {
        assert!(self.rows <= b, "block larger than artifact batch");
        let mut out = vec![0.0f32; b * self.d];
        out[..self.rows * self.d].copy_from_slice(&self.data);
        out
    }
}

/// Iterator slicing `(n, d)` row-major data into [`Block`]s.
pub struct BlockScheduler<'a> {
    data: &'a [f32],
    n: usize,
    d: usize,
    block_rows: usize,
    next: usize,
    next_id: u64,
}

impl<'a> BlockScheduler<'a> {
    pub fn new(data: &'a [f32], n: usize, d: usize, block_rows: usize) -> Self {
        assert_eq!(data.len(), n * d, "data shape mismatch");
        assert!(block_rows > 0);
        BlockScheduler { data, n, d, block_rows, next: 0, next_id: 0 }
    }

    pub fn block_count(&self) -> usize {
        self.n.div_ceil(self.block_rows)
    }
}

impl<'a> Iterator for BlockScheduler<'a> {
    type Item = Block;

    fn next(&mut self) -> Option<Block> {
        if self.next >= self.n {
            return None;
        }
        let rows = self.block_rows.min(self.n - self.next);
        let start = self.next * self.d;
        let block = Block {
            id: self.next_id,
            first_row: self.next as u64,
            rows,
            d: self.d,
            data: self.data[start..start + rows * self.d].to_vec(),
        };
        self.next += rows;
        self.next_id += 1;
        Some(block)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_every_row_exactly_once() {
        let n = 23;
        let d = 3;
        let data: Vec<f32> = (0..n * d).map(|i| i as f32).collect();
        let blocks: Vec<Block> = BlockScheduler::new(&data, n, d, 5).collect();
        assert_eq!(blocks.len(), 5); // ceil(23/5)
        let mut seen = vec![false; n];
        for b in &blocks {
            for i in 0..b.rows {
                let rid = b.row_id(i) as usize;
                assert!(!seen[rid], "row {rid} scheduled twice");
                seen[rid] = true;
                // Row content round-trips.
                assert_eq!(b.row(i)[0], (rid * d) as f32);
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn tail_block_is_short() {
        let data = vec![0.0f32; 7 * 2];
        let blocks: Vec<Block> = BlockScheduler::new(&data, 7, 2, 4).collect();
        assert_eq!(blocks[0].rows, 4);
        assert_eq!(blocks[1].rows, 3);
        assert_eq!(blocks[1].first_row, 4);
    }

    #[test]
    fn padding_zero_fills() {
        let data = vec![1.0f32; 3 * 2];
        let blocks: Vec<Block> = BlockScheduler::new(&data, 3, 2, 4).collect();
        let padded = blocks[0].padded(4);
        assert_eq!(padded.len(), 8);
        assert_eq!(&padded[..6], &[1.0; 6]);
        assert_eq!(&padded[6..], &[0.0; 2]);
    }

    #[test]
    fn block_count_matches_iteration() {
        for (n, br) in [(1usize, 1usize), (10, 3), (64, 64), (65, 64)] {
            let data = vec![0.0f32; n];
            let s = BlockScheduler::new(&data, n, 1, br);
            let count = s.block_count();
            assert_eq!(count, BlockScheduler::new(&data, n, 1, br).count());
        }
    }
}
