//! Immutable per-segment files: one sealed columnar block per
//! `.lpsk` file, crash-safe via write-to-temp + fsync + atomic rename,
//! with a trailing CRC32 footer over the whole body. Once a segment is
//! sealed here, restart adopts it directly and replays only the WAL
//! tail — a multi-GB store does not re-decode its settled history.
//!
//! ## File format v2 (little-endian, current)
//!
//! | field     | type                 | notes                          |
//! |-----------|----------------------|--------------------------------|
//! | magic     | `b"LPSG"`            |                                |
//! | version   | `u32` = 2            |                                |
//! | base      | `u64`                | first covered row id           |
//! | rows      | `u64`                |                                |
//! | orders    | `u32`                | must match `store.meta`        |
//! | k         | `u32`                |                                |
//! | nm        | `u32`                | moment orders                  |
//! | two_sided | `u8`                 |                                |
//! | u panels  | `f32[orders·rows·k]` | per-order, contiguous          |
//! | v panels  | `f32[orders·rows·k]` | two-sided only                 |
//! | moments   | `f64[rows·nm]`       | row-major                      |
//! | zone_len  | `u32`                | v2: = `ZoneMeta::encoded_len`  |
//! | zone      | `f64[zone_len]`      | v2: `ZoneMeta::to_f64s` layout |
//! | crc       | `u32`                | CRC32 of everything above      |
//!
//! v2 seals the segment's zone summary with its panels, so recovery
//! adopts pruning metadata verbatim instead of rescanning every panel;
//! the zone rides under the same whole-file footer CRC as the data it
//! summarizes. v1 files (no zone section) still load — the recovered
//! segment recomputes its zone at insertion.
//!
//! The write protocol makes publication atomic: contents are fully
//! fsynced *before* the rename, so a published name never points at
//! torn data — a crash can only lose the directory entry (the WAL
//! still covers those rows), never publish garbage. A present file
//! failing its footer CRC is therefore a hard error, not a tear.

// Serving path: clippy backs the pallas-lint serving-no-panic rule.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::path::{Path, PathBuf};

use anyhow::Context;

use crate::core::zone::ZoneMeta;
use crate::projection::sketcher::ColumnarBlock;

use super::durable::{crc32, put_f32s, put_f64s, put_u32, put_u64, ByteReader, DurableFs, MetaShape};

pub(crate) const SEG_MAGIC: &[u8; 4] = b"LPSG";
pub(crate) const SEG_VERSION: u32 = 2;

/// Fixed bytes before the panels: magic + version + base + rows +
/// orders + k + nm + two_sided.
const SEG_HEADER_BYTES: usize = 4 + 4 + 8 + 8 + 4 + 4 + 4 + 1;

/// `seg-<base:016x>-<rows:016x>.lpsk` for the segment at `base`.
pub(crate) fn seg_file_name(base: u64, rows: u64) -> String {
    format!("seg-{base:016x}-{rows:016x}.lpsk")
}

/// Parse a segment file name back to `(base, rows)`.
pub(crate) fn parse_name(name: &str) -> Option<(u64, u64)> {
    let hex = name.strip_prefix("seg-")?.strip_suffix(".lpsk")?;
    let (b, r) = hex.split_once('-')?;
    if b.len() != 16 || r.len() != 16 {
        return None;
    }
    Some((u64::from_str_radix(b, 16).ok()?, u64::from_str_radix(r, 16).ok()?))
}

fn encode_segment(base: u64, block: &ColumnarBlock, zone: &ZoneMeta) -> Vec<u8> {
    // pallas-lint: allow(len-before-alloc) -- sized from the in-memory block being encoded, not a decoded count
    let mut out = Vec::with_capacity(SEG_HEADER_BYTES + block.bytes() + 4);
    out.extend_from_slice(SEG_MAGIC);
    put_u32(&mut out, SEG_VERSION);
    put_u64(&mut out, base);
    put_u64(&mut out, block.rows() as u64);
    put_u32(&mut out, block.orders() as u32);
    put_u32(&mut out, block.k() as u32);
    put_u32(&mut out, block.moment_orders() as u32);
    out.push(block.is_two_sided() as u8);
    for m in 1..=block.orders() {
        put_f32s(&mut out, block.u_order(m));
    }
    if block.is_two_sided() {
        for m in 1..=block.orders() {
            if let Some(panel) = block.v_order(m) {
                put_f32s(&mut out, panel);
            }
        }
    }
    put_f64s(&mut out, block.moments_all());
    // v2 zone section, under the same footer CRC as the panels.
    let zvals = zone.to_f64s(block.is_two_sided());
    put_u32(&mut out, zvals.len() as u32);
    put_f64s(&mut out, &zvals);
    let crc = crc32(&out);
    put_u32(&mut out, crc);
    out
}

/// Seal one columnar block (and its zone summary) as an immutable
/// segment file in `seg_dir`: write to a `.tmp` sibling, fsync the
/// contents, atomically rename to the final name, fsync the directory.
/// Returns the published path.
pub(crate) fn write_segment(
    fs: &dyn DurableFs,
    seg_dir: &Path,
    base: u64,
    block: &ColumnarBlock,
    zone: &ZoneMeta,
) -> anyhow::Result<PathBuf> {
    anyhow::ensure!(block.rows() > 0, "refusing to seal an empty segment");
    let name = seg_file_name(base, block.rows() as u64);
    let path = seg_dir.join(&name);
    let tmp = seg_dir.join(format!("{name}.tmp"));
    let data = encode_segment(base, block, zone);
    fs.write_file(&tmp, &data).with_context(|| format!("writing {tmp:?}"))?;
    fs.sync_file(&tmp).with_context(|| format!("syncing {tmp:?}"))?;
    fs.rename(&tmp, &path).with_context(|| format!("publishing {path:?}"))?;
    fs.sync_dir(seg_dir).context("syncing seg dir")?;
    Ok(path)
}

/// Read and validate one sealed segment: footer CRC over the whole
/// body, shape pinned to `store.meta`, exact byte accounting before
/// any panel allocation. Errors, never panics — a published file that
/// fails here is corruption, not a tolerated tear (see module docs).
///
/// v2 files return their sealed zone summary; v1 files (sealed before
/// zones existed) return `None` and the caller recomputes.
pub(crate) fn read_segment(
    fs: &dyn DurableFs,
    path: &Path,
    shape: &MetaShape,
) -> anyhow::Result<(u64, ColumnarBlock, Option<ZoneMeta>)> {
    let data = fs.read_file(path).context("reading segment file")?;
    anyhow::ensure!(data.len() >= SEG_HEADER_BYTES + 4, "segment file too short");
    let body = &data[..data.len() - 4];
    let mut tail = ByteReader::new(&data[data.len() - 4..]);
    let want = tail.u32()?;
    anyhow::ensure!(crc32(body) == want, "segment footer checksum mismatch (corrupt)");
    let mut r = ByteReader::new(body);
    let magic = r.take(4)?;
    anyhow::ensure!(magic == SEG_MAGIC, "not a segment file (bad magic)");
    let version = r.u32()?;
    anyhow::ensure!(
        version >= 1 && version <= SEG_VERSION,
        "unsupported segment version {version}"
    );
    let base = r.u64()?;
    let rows = r.u64()?;
    let orders = r.u32()?;
    let k = r.u32()?;
    let nm = r.u32()?;
    let two_sided = r.u8()? != 0;
    anyhow::ensure!(
        orders == shape.orders && k == shape.k && nm == shape.moment_orders
            && two_sided == shape.two_sided,
        "segment shape (orders={orders}, k={k}, nm={nm}, two_sided={two_sided}) \
         does not match store.meta"
    );
    anyhow::ensure!(rows > 0 && rows <= super::wal::MAX_BATCH_ROWS, "implausible segment of {rows} rows");
    anyhow::ensure!(base.checked_add(rows).is_some(), "segment id range overflows");
    let rows = rows as usize;
    // Exact byte accounting before any allocation — v2 bodies carry
    // the fixed-size zone section after the row data.
    let zone_words =
        ZoneMeta::encoded_len(nm as usize, orders as usize, two_sided);
    let expect = rows
        .checked_mul(shape.row_data_bytes())
        .and_then(|b| b.checked_add(if version >= 2 { 4 + 8 * zone_words } else { 0 }))
        .ok_or_else(|| anyhow::anyhow!("segment byte size overflows"))?;
    anyhow::ensure!(
        r.remaining() == expect,
        "segment body length does not match its declared shape"
    );
    let (orders, k, nm) = (orders as usize, k as usize, nm as usize);
    let u = r.f32s(orders * rows * k)?;
    let v = if two_sided { Some(r.f32s(orders * rows * k)?) } else { None };
    let moments = r.f64s(rows * nm)?;
    let zone = if version >= 2 {
        let zone_len = r.u32()? as usize;
        anyhow::ensure!(
            zone_len == zone_words,
            "segment declares a zone of {zone_len} words; shape requires {zone_words}"
        );
        let zvals = r.f64s(zone_len)?;
        Some(ZoneMeta::from_f64s(rows, nm, orders, two_sided, &zvals)?)
    } else {
        None
    };
    Ok((base, ColumnarBlock::from_parts(orders, k, nm, rows, u, v, moments), zone))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::durable::RealFs;
    use crate::projection::sketcher::Sketcher;
    use crate::projection::{ProjectionDist, ProjectionSpec, Strategy};

    fn shape(two_sided: bool) -> MetaShape {
        MetaShape {
            p: 4,
            k: 8,
            orders: 3,
            moment_orders: 6,
            two_sided,
            seed: 21,
            dist: ProjectionDist::Normal,
        }
    }

    fn tmp_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join("lpsketch_segfile_test")
            .join(format!("{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn block_for(s: &MetaShape, rows: usize) -> ColumnarBlock {
        let strategy = if s.two_sided { Strategy::Alternative } else { Strategy::Basic };
        let sk = Sketcher::new(
            ProjectionSpec::new(s.seed, s.k as usize, s.dist, strategy),
            s.p as usize,
        );
        let data: Vec<Vec<f32>> = (0..rows)
            .map(|i| (0..11).map(|t| ((i * 17 + t) as f32 * 0.23).sin()).collect())
            .collect();
        let refs: Vec<&[f32]> = data.iter().map(|r| r.as_slice()).collect();
        sk.sketch_block(&refs, 1)
    }

    #[test]
    fn names_roundtrip() {
        assert_eq!(parse_name(&seg_file_name(0, 1)), Some((0, 1)));
        assert_eq!(parse_name(&seg_file_name(u64::MAX, 77)), Some((u64::MAX, 77)));
        assert_eq!(parse_name("seg-00-01.lpsk"), None);
        assert_eq!(parse_name("wal-0000000000000000.wal"), None);
        assert_eq!(parse_name("seg-0000000000000100-0000000000000004.lpsk.tmp"), None);
    }

    #[test]
    fn seal_and_read_back_bitwise() {
        for two_sided in [false, true] {
            let s = shape(two_sided);
            let dir = tmp_dir(&format!("roundtrip_{two_sided}"));
            let block = block_for(&s, 5);
            let zone = ZoneMeta::from_block(&block);
            let path = write_segment(&RealFs, &dir, 400, &block, &zone).unwrap();
            assert!(path.file_name().and_then(|n| n.to_str()).map(parse_name).flatten().is_some());
            let (base, got, got_zone) = read_segment(&RealFs, &path, &s).unwrap();
            assert_eq!(base, 400);
            assert_eq!(got.rows(), block.rows());
            for m in 1..=block.orders() {
                assert_eq!(got.u_order(m), block.u_order(m));
                assert_eq!(got.v_order(m), block.v_order(m));
            }
            assert_eq!(got.moments_all(), block.moments_all());
            assert_eq!(got_zone, Some(zone), "zone must survive the seal bitwise");
            // No temp residue after a clean publish.
            let leftovers: Vec<_> = std::fs::read_dir(&dir)
                .unwrap()
                .filter_map(|e| e.ok())
                .filter(|e| e.path().extension().is_some_and(|x| x == "tmp"))
                .collect();
            assert!(leftovers.is_empty());
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    #[test]
    fn every_byte_flip_is_caught() {
        let s = shape(false);
        let dir = tmp_dir("flips");
        let block = block_for(&s, 2);
        let path = write_segment(&RealFs, &dir, 10, &block, &ZoneMeta::from_block(&block)).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // Step through the file (stride keeps the test fast; header and
        // footer are covered exhaustively by the small stride).
        for off in (0..bytes.len()).step_by(3) {
            let mut b = bytes.clone();
            b[off] ^= 0x10;
            std::fs::write(&path, &b).unwrap();
            assert!(
                read_segment(&RealFs, &path, &s).is_err(),
                "flip at offset {off} must be detected"
            );
        }
        // Truncation at any point is an error too (a published segment
        // is never legitimately short).
        for cut in [0, 1, SEG_HEADER_BYTES, bytes.len() - 5, bytes.len() - 1] {
            std::fs::write(&path, &bytes[..cut]).unwrap();
            assert!(read_segment(&RealFs, &path, &s).is_err(), "cut at {cut} must error");
        }
        std::fs::write(&path, &bytes).unwrap();
        assert!(read_segment(&RealFs, &path, &s).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shape_mismatch_is_rejected() {
        let s = shape(false);
        let dir = tmp_dir("shape");
        let block = block_for(&s, 3);
        let path = write_segment(&RealFs, &dir, 0, &block, &ZoneMeta::from_block(&block)).unwrap();
        let mut other = s;
        other.k = 16;
        assert!(read_segment(&RealFs, &path, &other).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn v1_segments_load_with_no_zone() {
        // Hand-rolled v1 file (pre-zone format): header, panels,
        // moments, footer CRC — no zone section. Must keep loading,
        // reporting `None` so the caller recomputes the zone.
        let s = shape(false);
        let dir = tmp_dir("v1_compat");
        let block = block_for(&s, 4);
        let mut out: Vec<u8> = Vec::new();
        out.extend_from_slice(SEG_MAGIC);
        put_u32(&mut out, 1); // v1
        put_u64(&mut out, 30);
        put_u64(&mut out, block.rows() as u64);
        put_u32(&mut out, block.orders() as u32);
        put_u32(&mut out, block.k() as u32);
        put_u32(&mut out, block.moment_orders() as u32);
        out.push(0u8);
        for m in 1..=block.orders() {
            put_f32s(&mut out, block.u_order(m));
        }
        put_f64s(&mut out, block.moments_all());
        let crc = crc32(&out);
        put_u32(&mut out, crc);
        let path = dir.join(seg_file_name(30, block.rows() as u64));
        std::fs::write(&path, &out).unwrap();
        let (base, got, zone) = read_segment(&RealFs, &path, &s).unwrap();
        assert_eq!(base, 30);
        assert_eq!(got.moments_all(), block.moments_all());
        assert_eq!(zone, None, "v1 files predate zones");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn inflated_zone_count_is_rejected_before_allocation() {
        // A CRC-valid file whose zone_len disagrees with the shape must
        // fail the length pin (the byte-accounting and length checks
        // both run before the zone buffer is allocated).
        let s = shape(false);
        let dir = tmp_dir("zone_len");
        let block = block_for(&s, 2);
        let path = write_segment(&RealFs, &dir, 0, &block, &ZoneMeta::from_block(&block)).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let zone_len_at = SEG_HEADER_BYTES + block.rows() * s.row_data_bytes();
        bytes[zone_len_at..zone_len_at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        let body_len = bytes.len() - 4;
        let crc = crc32(&bytes[..body_len]);
        bytes[body_len..].copy_from_slice(&crc.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = read_segment(&RealFs, &path, &s).unwrap_err().to_string();
        assert!(err.contains("zone"), "unexpected error: {err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
