//! Immutable per-segment files: one sealed columnar block per
//! `.lpsk` file, crash-safe via write-to-temp + fsync + atomic rename,
//! with a trailing CRC32 footer over the whole body. Once a segment is
//! sealed here, restart adopts it directly and replays only the WAL
//! tail — a multi-GB store does not re-decode its settled history.
//!
//! ## File format v3 (little-endian, current)
//!
//! | field     | type                 | notes                          |
//! |-----------|----------------------|--------------------------------|
//! | magic     | `b"LPSG"`            |                                |
//! | version   | `u32` = 3            |                                |
//! | base      | `u64`                | first covered row id           |
//! | rows      | `u64`                |                                |
//! | orders    | `u32`                | must match `store.meta`        |
//! | k         | `u32`                |                                |
//! | nm        | `u32`                | moment orders                  |
//! | two_sided | `u8`                 |                                |
//! | enc       | `u8`                 | v3: `PanelQuant` tag (0 f32, 1 f16, 2 bf16, 3 i8) |
//! | u_scales  | `f32[orders]`        | v3, i8 only: per-order scales  |
//! | v_scales  | `f32[orders]`        | v3, i8 + two_sided only        |
//! | u panels  | `enc[orders·rows·k]` | per-order, contiguous, `enc`-sized values |
//! | v panels  | `enc[orders·rows·k]` | two-sided only                 |
//! | moments   | `f64[rows·nm]`       | row-major, always f64          |
//! | zone_len  | `u32`                | v2: = `ZoneMeta::encoded_len`  |
//! | zone      | `f64[zone_len]`      | v2: `ZoneMeta::to_f64s` layout |
//! | crc       | `u32`                | CRC32 of everything above      |
//!
//! v2 seals the segment's zone summary with its panels, so recovery
//! adopts pruning metadata verbatim instead of rescanning every panel;
//! the zone rides under the same whole-file footer CRC as the data it
//! summarizes. v1 files (no zone section) still load — the recovered
//! segment recomputes its zone at insertion.
//!
//! v3 seals quantized panels **as stored**: the encoding tag rides in
//! the header (under the footer CRC), the panel section shrinks to
//! `enc.bytes_per_value()` per value, and recovery adopts the segment
//! in its sealed encoding — no decode, no re-quantization (re-encoding
//! would change values and invalidate the sealed zone). The tag is
//! validated *before* any panel byte is sized: an unknown tag is a
//! hard error, never an allocation. v1/v2 files are always f32.
//!
//! The write protocol makes publication atomic: contents are fully
//! fsynced *before* the rename, so a published name never points at
//! torn data — a crash can only lose the directory entry (the WAL
//! still covers those rows), never publish garbage. A present file
//! failing its footer CRC is therefore a hard error, not a tear.

// Serving path: clippy backs the pallas-lint serving-no-panic rule.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::path::{Path, PathBuf};

use anyhow::Context;

use crate::core::quant::{PanelQuant, PanelStore};
use crate::core::zone::ZoneMeta;
use crate::projection::sketcher::ColumnarBlock;

use super::durable::{
    crc32, put_f32s, put_f64s, put_i8s, put_u16s, put_u32, put_u64, ByteReader, DurableFs,
    MetaShape,
};

pub(crate) const SEG_MAGIC: &[u8; 4] = b"LPSG";
pub(crate) const SEG_VERSION: u32 = 3;

/// Fixed bytes before the panels in v1/v2: magic + version + base +
/// rows + orders + k + nm + two_sided. v3 appends the encoding tag
/// byte (and, for i8, the per-order scales) after this prefix.
const SEG_HEADER_BYTES: usize = 4 + 4 + 8 + 8 + 4 + 4 + 4 + 1;

/// `seg-<base:016x>-<rows:016x>.lpsk` for the segment at `base`.
pub(crate) fn seg_file_name(base: u64, rows: u64) -> String {
    format!("seg-{base:016x}-{rows:016x}.lpsk")
}

/// Parse a segment file name back to `(base, rows)`.
pub(crate) fn parse_name(name: &str) -> Option<(u64, u64)> {
    let hex = name.strip_prefix("seg-")?.strip_suffix(".lpsk")?;
    let (b, r) = hex.split_once('-')?;
    if b.len() != 16 || r.len() != 16 {
        return None;
    }
    Some((u64::from_str_radix(b, 16).ok()?, u64::from_str_radix(r, 16).ok()?))
}

/// Append one panel store in its held encoding.
fn put_store(out: &mut Vec<u8>, s: &PanelStore) {
    match s {
        PanelStore::F32(xs) => put_f32s(out, xs),
        PanelStore::F16(xs) | PanelStore::Bf16(xs) => put_u16s(out, xs),
        PanelStore::I8 { data, .. } => put_i8s(out, data),
    }
}

fn encode_segment(base: u64, block: &ColumnarBlock, zone: &ZoneMeta) -> Vec<u8> {
    let mut out = Vec::with_capacity(SEG_HEADER_BYTES + block.bytes() + 4);
    out.extend_from_slice(SEG_MAGIC);
    put_u32(&mut out, SEG_VERSION);
    put_u64(&mut out, base);
    put_u64(&mut out, block.rows() as u64);
    put_u32(&mut out, block.orders() as u32);
    put_u32(&mut out, block.k() as u32);
    put_u32(&mut out, block.moment_orders() as u32);
    out.push(block.is_two_sided() as u8);
    // v3: encoding tag, then per-order i8 scales (u side, then v side),
    // then the panels in their stored encoding — all under the footer
    // CRC, so a flipped tag can never silently mis-slice the panels.
    out.push(block.encoding().tag());
    if let Some(scales) = block.u_store().i8_scales() {
        put_f32s(&mut out, scales);
        if let Some(scales) = block.v_store().and_then(|v| v.i8_scales()) {
            put_f32s(&mut out, scales);
        }
    }
    put_store(&mut out, block.u_store());
    if let Some(vs) = block.v_store() {
        put_store(&mut out, vs);
    }
    put_f64s(&mut out, block.moments_all());
    // v2 zone section, under the same footer CRC as the panels.
    let zvals = zone.to_f64s(block.is_two_sided());
    put_u32(&mut out, zvals.len() as u32);
    put_f64s(&mut out, &zvals);
    let crc = crc32(&out);
    put_u32(&mut out, crc);
    out
}

/// Seal one columnar block (and its zone summary) as an immutable
/// segment file in `seg_dir`: write to a `.tmp` sibling, fsync the
/// contents, atomically rename to the final name, fsync the directory.
/// Returns the published path.
pub(crate) fn write_segment(
    fs: &dyn DurableFs,
    seg_dir: &Path,
    base: u64,
    block: &ColumnarBlock,
    zone: &ZoneMeta,
) -> anyhow::Result<PathBuf> {
    anyhow::ensure!(block.rows() > 0, "refusing to seal an empty segment");
    let name = seg_file_name(base, block.rows() as u64);
    let path = seg_dir.join(&name);
    let tmp = seg_dir.join(format!("{name}.tmp"));
    let data = encode_segment(base, block, zone);
    fs.write_file(&tmp, &data).with_context(|| format!("writing {tmp:?}"))?;
    fs.sync_file(&tmp).with_context(|| format!("syncing {tmp:?}"))?;
    fs.rename(&tmp, &path).with_context(|| format!("publishing {path:?}"))?;
    fs.sync_dir(seg_dir).context("syncing seg dir")?;
    Ok(path)
}

/// Read and validate one sealed segment: footer CRC over the whole
/// body, shape pinned to `store.meta`, exact byte accounting before
/// any panel allocation. Errors, never panics — a published file that
/// fails here is corruption, not a tolerated tear (see module docs).
///
/// v2+ files return their sealed zone summary; v1 files (sealed before
/// zones existed) return `None` and the caller recomputes. v3 files
/// return the block in its sealed panel encoding (v1/v2 are f32).
pub(crate) fn read_segment(
    fs: &dyn DurableFs,
    path: &Path,
    shape: &MetaShape,
) -> anyhow::Result<(u64, ColumnarBlock, Option<ZoneMeta>)> {
    let data = fs.read_file(path).context("reading segment file")?;
    anyhow::ensure!(data.len() >= SEG_HEADER_BYTES + 4, "segment file too short");
    let body = &data[..data.len() - 4];
    let mut tail = ByteReader::new(&data[data.len() - 4..]);
    let want = tail.u32()?;
    anyhow::ensure!(crc32(body) == want, "segment footer checksum mismatch (corrupt)");
    let mut r = ByteReader::new(body);
    let magic = r.take(4)?;
    anyhow::ensure!(magic == SEG_MAGIC, "not a segment file (bad magic)");
    let version = r.u32()?;
    anyhow::ensure!(
        version >= 1 && version <= SEG_VERSION,
        "unsupported segment version {version}"
    );
    let base = r.u64()?;
    let rows = r.u64()?;
    let orders = r.u32()?;
    let k = r.u32()?;
    let nm = r.u32()?;
    let two_sided = r.u8()? != 0;
    anyhow::ensure!(
        orders == shape.orders && k == shape.k && nm == shape.moment_orders
            && two_sided == shape.two_sided,
        "segment shape (orders={orders}, k={k}, nm={nm}, two_sided={two_sided}) \
         does not match store.meta"
    );
    anyhow::ensure!(rows > 0 && rows <= super::wal::MAX_BATCH_ROWS, "implausible segment of {rows} rows");
    anyhow::ensure!(base.checked_add(rows).is_some(), "segment id range overflows");
    let rows = rows as usize;
    let sides = if two_sided { 2usize } else { 1 };
    // v3: the encoding tag decides bytes-per-value for the rest of the
    // body, so it is validated before any panel byte is sized; the i8
    // scales follow it (u side, then v side). v1/v2 are always f32.
    let (enc, mut u_scales, mut v_scales) = if version >= 3 {
        let tag = r.u8()?;
        let enc = PanelQuant::from_tag(tag)
            .ok_or_else(|| anyhow::anyhow!("unknown panel-encoding tag {tag}"))?;
        let (us, vs) = if enc == PanelQuant::I8 {
            let u = r.f32s(orders as usize)?;
            let v = if two_sided { Some(r.f32s(orders as usize)?) } else { None };
            anyhow::ensure!(
                u.iter().chain(v.iter().flatten()).all(|x| x.is_finite() && *x >= 0.0),
                "non-finite or negative i8 scale"
            );
            (Some(u), v)
        } else {
            (None, None)
        };
        (enc, us, vs)
    } else {
        (PanelQuant::None, None, None)
    };
    // Exact byte accounting before any allocation — v2+ bodies carry
    // the fixed-size zone section after the row data, and v3 panels
    // occupy `enc.bytes_per_value()` per value.
    let zone_words =
        ZoneMeta::encoded_len(nm as usize, orders as usize, two_sided);
    let row_data_bytes = (orders as usize * k as usize * enc.bytes_per_value()) * sides
        + nm as usize * 8;
    let expect = rows
        .checked_mul(row_data_bytes)
        .and_then(|b| b.checked_add(if version >= 2 { 4 + 8 * zone_words } else { 0 }))
        .ok_or_else(|| anyhow::anyhow!("segment byte size overflows"))?;
    anyhow::ensure!(
        r.remaining() == expect,
        "segment body length does not match its declared shape"
    );
    let (orders, k, nm) = (orders as usize, k as usize, nm as usize);
    let vals = orders * rows * k;
    let u = read_store(&mut r, enc, vals, u_scales.take())?;
    let v = if two_sided { Some(read_store(&mut r, enc, vals, v_scales.take())?) } else { None };
    let moments = r.f64s(rows * nm)?;
    let zone = if version >= 2 {
        let zone_len = r.u32()? as usize;
        anyhow::ensure!(
            zone_len == zone_words,
            "segment declares a zone of {zone_len} words; shape requires {zone_words}"
        );
        let zvals = r.f64s(zone_len)?;
        Some(ZoneMeta::from_f64s(rows, nm, orders, two_sided, &zvals)?)
    } else {
        None
    };
    Ok((base, ColumnarBlock::from_stores(orders, k, nm, rows, u, v, moments), zone))
}

/// Read one panel store of `n` values in encoding `enc`. `scales` is
/// `Some` exactly when `enc` is i8 (read from the v3 header).
fn read_store(
    r: &mut ByteReader<'_>,
    enc: PanelQuant,
    n: usize,
    scales: Option<Vec<f32>>,
) -> anyhow::Result<PanelStore> {
    Ok(match enc {
        PanelQuant::None => PanelStore::F32(r.f32s(n)?),
        PanelQuant::F16 => PanelStore::F16(r.u16s(n)?),
        PanelQuant::Bf16 => PanelStore::Bf16(r.u16s(n)?),
        PanelQuant::I8 => PanelStore::I8 {
            data: r.i8s(n)?,
            scales: scales.ok_or_else(|| anyhow::anyhow!("i8 segment without scales"))?,
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::durable::RealFs;
    use crate::projection::sketcher::Sketcher;
    use crate::projection::{ProjectionDist, ProjectionSpec, Strategy};

    fn shape(two_sided: bool) -> MetaShape {
        MetaShape {
            p: 4,
            k: 8,
            orders: 3,
            moment_orders: 6,
            two_sided,
            seed: 21,
            dist: ProjectionDist::Normal,
        }
    }

    fn tmp_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join("lpsketch_segfile_test")
            .join(format!("{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn block_for(s: &MetaShape, rows: usize) -> ColumnarBlock {
        let strategy = if s.two_sided { Strategy::Alternative } else { Strategy::Basic };
        let sk = Sketcher::new(
            ProjectionSpec::new(s.seed, s.k as usize, s.dist, strategy),
            s.p as usize,
        );
        let data: Vec<Vec<f32>> = (0..rows)
            .map(|i| (0..11).map(|t| ((i * 17 + t) as f32 * 0.23).sin()).collect())
            .collect();
        let refs: Vec<&[f32]> = data.iter().map(|r| r.as_slice()).collect();
        sk.sketch_block(&refs, 1)
    }

    #[test]
    fn names_roundtrip() {
        assert_eq!(parse_name(&seg_file_name(0, 1)), Some((0, 1)));
        assert_eq!(parse_name(&seg_file_name(u64::MAX, 77)), Some((u64::MAX, 77)));
        assert_eq!(parse_name("seg-00-01.lpsk"), None);
        assert_eq!(parse_name("wal-0000000000000000.wal"), None);
        assert_eq!(parse_name("seg-0000000000000100-0000000000000004.lpsk.tmp"), None);
    }

    #[test]
    fn seal_and_read_back_bitwise() {
        for two_sided in [false, true] {
            let s = shape(two_sided);
            let dir = tmp_dir(&format!("roundtrip_{two_sided}"));
            let block = block_for(&s, 5);
            let zone = ZoneMeta::from_block(&block);
            let path = write_segment(&RealFs, &dir, 400, &block, &zone).unwrap();
            assert!(path.file_name().and_then(|n| n.to_str()).map(parse_name).flatten().is_some());
            let (base, got, got_zone) = read_segment(&RealFs, &path, &s).unwrap();
            assert_eq!(base, 400);
            assert_eq!(got.rows(), block.rows());
            for m in 1..=block.orders() {
                assert_eq!(got.u_order(m), block.u_order(m));
                assert_eq!(got.v_order(m), block.v_order(m));
            }
            assert_eq!(got.moments_all(), block.moments_all());
            assert_eq!(got_zone, Some(zone), "zone must survive the seal bitwise");
            // No temp residue after a clean publish.
            let leftovers: Vec<_> = std::fs::read_dir(&dir)
                .unwrap()
                .filter_map(|e| e.ok())
                .filter(|e| e.path().extension().is_some_and(|x| x == "tmp"))
                .collect();
            assert!(leftovers.is_empty());
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    #[test]
    fn every_byte_flip_is_caught() {
        let s = shape(false);
        let dir = tmp_dir("flips");
        let block = block_for(&s, 2);
        let path = write_segment(&RealFs, &dir, 10, &block, &ZoneMeta::from_block(&block)).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // Step through the file (stride keeps the test fast; header and
        // footer are covered exhaustively by the small stride).
        for off in (0..bytes.len()).step_by(3) {
            let mut b = bytes.clone();
            b[off] ^= 0x10;
            std::fs::write(&path, &b).unwrap();
            assert!(
                read_segment(&RealFs, &path, &s).is_err(),
                "flip at offset {off} must be detected"
            );
        }
        // Truncation at any point is an error too (a published segment
        // is never legitimately short).
        for cut in [0, 1, SEG_HEADER_BYTES, bytes.len() - 5, bytes.len() - 1] {
            std::fs::write(&path, &bytes[..cut]).unwrap();
            assert!(read_segment(&RealFs, &path, &s).is_err(), "cut at {cut} must error");
        }
        std::fs::write(&path, &bytes).unwrap();
        assert!(read_segment(&RealFs, &path, &s).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn quantized_seal_and_read_back_bitwise() {
        // Quantized segments seal in their stored encoding: the file
        // shrinks with bytes-per-value, and the read-back block — data,
        // scales, views, zone — is bitwise identical.
        for two_sided in [false, true] {
            let s = shape(two_sided);
            let dir = tmp_dir(&format!("quant_roundtrip_{two_sided}"));
            let f32_block = block_for(&s, 5);
            let f32_len = {
                let zone = ZoneMeta::from_block(&f32_block);
                let path = write_segment(&RealFs, &dir, 100, &f32_block, &zone).unwrap();
                std::fs::metadata(&path).unwrap().len()
            };
            for q in [PanelQuant::F16, PanelQuant::Bf16, PanelQuant::I8] {
                let block = f32_block.encoded_as(q);
                let zone = ZoneMeta::from_block(&block);
                let path = write_segment(&RealFs, &dir, 200, &block, &zone).unwrap();
                assert!(
                    std::fs::metadata(&path).unwrap().len() < f32_len,
                    "{q:?} segment must be smaller than the f32 seal"
                );
                let (base, got, got_zone) = read_segment(&RealFs, &path, &s).unwrap();
                assert_eq!(base, 200);
                assert_eq!(got.encoding(), q);
                assert_eq!(got, block, "sealed block must read back bitwise");
                assert_eq!(got_zone, Some(zone), "zone must survive the seal bitwise");
                std::fs::remove_file(&path).ok();
            }
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    #[test]
    fn quantized_segment_every_byte_flip_is_caught() {
        // The i8 layout has the most header structure (tag + scales);
        // every flipped byte — tag, scale, panel, moment, zone, CRC —
        // must be detected, and truncations must error.
        let s = shape(true);
        let dir = tmp_dir("quant_flips");
        let block = block_for(&s, 2).encoded_as(PanelQuant::I8);
        let zone = ZoneMeta::from_block(&block);
        let path = write_segment(&RealFs, &dir, 10, &block, &zone).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        for off in (0..bytes.len()).step_by(3) {
            let mut b = bytes.clone();
            b[off] ^= 0x10;
            std::fs::write(&path, &b).unwrap();
            assert!(
                read_segment(&RealFs, &path, &s).is_err(),
                "flip at offset {off} must be detected"
            );
        }
        for cut in [0, SEG_HEADER_BYTES, SEG_HEADER_BYTES + 1, bytes.len() - 5, bytes.len() - 1] {
            std::fs::write(&path, &bytes[..cut]).unwrap();
            assert!(read_segment(&RealFs, &path, &s).is_err(), "cut at {cut} must error");
        }
        std::fs::write(&path, &bytes).unwrap();
        assert!(read_segment(&RealFs, &path, &s).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unknown_encoding_tag_is_rejected_before_allocation() {
        // A CRC-valid file with an out-of-range tag must fail the tag
        // check by name — before the tag could drive any panel sizing.
        let s = shape(false);
        let dir = tmp_dir("bad_tag");
        let block = block_for(&s, 2);
        let path = write_segment(&RealFs, &dir, 0, &block, &ZoneMeta::from_block(&block)).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[SEG_HEADER_BYTES] = 200; // the v3 enc byte follows the v1/v2 prefix
        let body_len = bytes.len() - 4;
        let crc = crc32(&bytes[..body_len]);
        bytes[body_len..].copy_from_slice(&crc.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = read_segment(&RealFs, &path, &s).unwrap_err().to_string();
        assert!(err.contains("unknown panel-encoding tag"), "unexpected error: {err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shape_mismatch_is_rejected() {
        let s = shape(false);
        let dir = tmp_dir("shape");
        let block = block_for(&s, 3);
        let path = write_segment(&RealFs, &dir, 0, &block, &ZoneMeta::from_block(&block)).unwrap();
        let mut other = s;
        other.k = 16;
        assert!(read_segment(&RealFs, &path, &other).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn v1_segments_load_with_no_zone() {
        // Hand-rolled v1 file (pre-zone format): header, panels,
        // moments, footer CRC — no zone section. Must keep loading,
        // reporting `None` so the caller recomputes the zone.
        let s = shape(false);
        let dir = tmp_dir("v1_compat");
        let block = block_for(&s, 4);
        let mut out: Vec<u8> = Vec::new();
        out.extend_from_slice(SEG_MAGIC);
        put_u32(&mut out, 1); // v1
        put_u64(&mut out, 30);
        put_u64(&mut out, block.rows() as u64);
        put_u32(&mut out, block.orders() as u32);
        put_u32(&mut out, block.k() as u32);
        put_u32(&mut out, block.moment_orders() as u32);
        out.push(0u8);
        for m in 1..=block.orders() {
            put_f32s(&mut out, block.u_order(m));
        }
        put_f64s(&mut out, block.moments_all());
        let crc = crc32(&out);
        put_u32(&mut out, crc);
        let path = dir.join(seg_file_name(30, block.rows() as u64));
        std::fs::write(&path, &out).unwrap();
        let (base, got, zone) = read_segment(&RealFs, &path, &s).unwrap();
        assert_eq!(base, 30);
        assert_eq!(got.moments_all(), block.moments_all());
        assert_eq!(zone, None, "v1 files predate zones");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn v2_segments_load_as_f32_with_their_zone() {
        // Hand-rolled v2 file (pre-encoding format): no enc byte, f32
        // panels, sealed zone. Must keep loading, zone adopted.
        let s = shape(false);
        let dir = tmp_dir("v2_compat");
        let block = block_for(&s, 4);
        let zone = ZoneMeta::from_block(&block);
        let mut out: Vec<u8> = Vec::new();
        out.extend_from_slice(SEG_MAGIC);
        put_u32(&mut out, 2); // v2
        put_u64(&mut out, 50);
        put_u64(&mut out, block.rows() as u64);
        put_u32(&mut out, block.orders() as u32);
        put_u32(&mut out, block.k() as u32);
        put_u32(&mut out, block.moment_orders() as u32);
        out.push(0u8);
        for m in 1..=block.orders() {
            put_f32s(&mut out, block.u_order(m));
        }
        put_f64s(&mut out, block.moments_all());
        let zvals = zone.to_f64s(false);
        put_u32(&mut out, zvals.len() as u32);
        put_f64s(&mut out, &zvals);
        let crc = crc32(&out);
        put_u32(&mut out, crc);
        let path = dir.join(seg_file_name(50, block.rows() as u64));
        std::fs::write(&path, &out).unwrap();
        let (base, got, got_zone) = read_segment(&RealFs, &path, &s).unwrap();
        assert_eq!(base, 50);
        assert_eq!(got.encoding(), PanelQuant::None);
        assert_eq!(got.moments_all(), block.moments_all());
        assert_eq!(got_zone, Some(zone), "v2 zones still adopt verbatim");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn inflated_zone_count_is_rejected_before_allocation() {
        // A CRC-valid file whose zone_len disagrees with the shape must
        // fail the length pin (the byte-accounting and length checks
        // both run before the zone buffer is allocated).
        let s = shape(false);
        let dir = tmp_dir("zone_len");
        let block = block_for(&s, 2);
        let path = write_segment(&RealFs, &dir, 0, &block, &ZoneMeta::from_block(&block)).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // v3: the f32 enc byte sits between the fixed prefix and the
        // panels.
        let zone_len_at = SEG_HEADER_BYTES + 1 + block.rows() * s.row_data_bytes();
        bytes[zone_len_at..zone_len_at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        let body_len = bytes.len() - 4;
        let crc = crc32(&bytes[..body_len]);
        bytes[body_len..].copy_from_slice(&crc.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = read_segment(&RealFs, &path, &s).unwrap_err().to_string();
        assert!(err.contains("zone"), "unexpected error: {err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
