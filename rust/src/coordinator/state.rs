//! SketchStore: the coordinator's state — every ingested row's sketches
//! + marginal moments, sharded for concurrent writes.
//!
//! This is the O(nk) object that replaces the O(nD) matrix (and the
//! O(n²) distance cache) in the paper's storage claim. Shards are
//! written by the pipeline workers in parallel and read lock-free-ish
//! (RwLock read path) by the query side.

use std::collections::HashMap;
use std::sync::RwLock;

use crate::core::arena::SketchArena;
use crate::projection::sketcher::RowSketch;

/// Sharded row-id → sketch map.
pub struct SketchStore {
    shards: Vec<RwLock<HashMap<u64, RowSketch>>>,
}

/// Result of [`SketchStore::arena_snapshot`]: the columnar arena plus
/// both directions of the id ↔ arena-row mapping.
pub struct ArenaSnapshot {
    /// Row ids ascending; arena row `i` holds `ids[i]`.
    pub ids: Vec<u64>,
    /// id → arena row (the inverse of `ids`, built once here so batch
    /// callers don't rebuild it).
    pub pos: HashMap<u64, usize>,
    pub arena: SketchArena,
}

impl SketchStore {
    pub fn new(shards: usize) -> Self {
        let shards = shards.max(1);
        SketchStore {
            shards: (0..shards).map(|_| RwLock::new(HashMap::new())).collect(),
        }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Which shard owns a row id (must agree with the router).
    #[inline]
    pub fn shard_of(&self, id: u64) -> usize {
        (id % self.shards.len() as u64) as usize
    }

    pub fn insert(&self, id: u64, sketch: RowSketch) {
        self.shards[self.shard_of(id)].write().unwrap().insert(id, sketch);
    }

    pub fn get(&self, id: u64) -> Option<RowSketch> {
        self.shards[self.shard_of(id)].read().unwrap().get(&id).cloned()
    }

    /// Visit a pair without cloning (the query hot path).
    pub fn with_pair<T>(
        &self,
        a: u64,
        b: u64,
        f: impl FnOnce(&RowSketch, &RowSketch) -> T,
    ) -> Option<T> {
        let (sa, sb) = (self.shard_of(a), self.shard_of(b));
        if sa == sb {
            let guard = self.shards[sa].read().unwrap();
            let ra = guard.get(&a)?;
            let rb = guard.get(&b)?;
            Some(f(ra, rb))
        } else {
            // Lock in shard order to avoid deadlock with concurrent pairs.
            let (first, second) = if sa < sb { (sa, sb) } else { (sb, sa) };
            let g1 = self.shards[first].read().unwrap();
            let g2 = self.shards[second].read().unwrap();
            let (ga, gb) = if sa < sb { (&g1, &g2) } else { (&g2, &g1) };
            let ra = ga.get(&a)?;
            let rb = gb.get(&b)?;
            Some(f(ra, rb))
        }
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn contains(&self, id: u64) -> bool {
        self.shards[self.shard_of(id)].read().unwrap().contains_key(&id)
    }

    /// Total sketch payload bytes (the paper's O(nk) storage number).
    pub fn bytes(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.read().unwrap().values().map(|r| r.sketch_bytes()).sum::<usize>())
            .sum()
    }

    /// Columnar snapshot of the whole store: every row's sketches
    /// transposed into a [`SketchArena`] (ids ascending, arena row i =
    /// `ids[i]`, inverse map in `pos`). This is the view the pipeline's
    /// blocked estimate / all-pairs export paths consume — one read
    /// lock per shard, rows copied straight into the arena buffers (no
    /// per-row clones, no per-pair locking on the hot path). `p`/`k`
    /// come from the pipeline config (an empty store carries no shape
    /// of its own).
    pub fn arena_snapshot(&self, p: usize, k: usize) -> ArenaSnapshot {
        let ids = self.ids();
        let pos: HashMap<u64, usize> = ids.iter().enumerate().map(|(i, &id)| (id, i)).collect();
        // Hold every shard's read lock together for a consistent copy
        // (writers take exactly one shard lock, so no ordering cycle);
        // sidedness is probed under the same guards. Rows inserted
        // after the `ids()` pass are skipped; the store has no removal
        // API, so every listed id is still present.
        let guards: Vec<_> = self.shards.iter().map(|s| s.read().unwrap()).collect();
        let two_sided = ids.first().is_some_and(|&id| {
            guards[self.shard_of(id)]
                .get(&id)
                .is_some_and(|r| r.vside_data.is_some())
        });
        let arena = SketchArena::from_indexed(
            p,
            k,
            ids.len(),
            two_sided,
            guards.iter().flat_map(|g| {
                g.iter().filter_map(|(id, rs)| pos.get(id).map(|&i| (i, rs)))
            }),
        );
        ArenaSnapshot { ids, pos, arena }
    }

    /// All row ids, ascending (test/debug helper; takes all read locks).
    pub fn ids(&self) -> Vec<u64> {
        let mut ids: Vec<u64> = self
            .shards
            .iter()
            .flat_map(|s| s.read().unwrap().keys().copied().collect::<Vec<_>>())
            .collect();
        ids.sort_unstable();
        ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::projection::sketcher::Sketcher;
    use crate::projection::{ProjectionDist, ProjectionSpec, Strategy};

    fn sketch_of(val: f32) -> RowSketch {
        let sk = Sketcher::new(
            ProjectionSpec::new(1, 4, ProjectionDist::Normal, Strategy::Basic),
            4,
        );
        sk.sketch_row(&[val, val * 2.0, val * 3.0])
    }

    #[test]
    fn insert_get_roundtrip() {
        let store = SketchStore::new(4);
        store.insert(10, sketch_of(1.0));
        assert!(store.contains(10));
        assert!(!store.contains(11));
        let got = store.get(10).unwrap();
        assert_eq!(got.moments.get(1), sketch_of(1.0).moments.get(1));
    }

    #[test]
    fn with_pair_same_and_cross_shard() {
        let store = SketchStore::new(2);
        store.insert(0, sketch_of(1.0)); // shard 0
        store.insert(2, sketch_of(2.0)); // shard 0
        store.insert(1, sketch_of(3.0)); // shard 1
        // Same shard.
        let m = store.with_pair(0, 2, |a, b| (a.moments.get(1), b.moments.get(1))).unwrap();
        assert!(m.0 < m.1);
        // Cross shard, both orders.
        assert!(store.with_pair(0, 1, |_, _| ()).is_some());
        assert!(store.with_pair(1, 0, |_, _| ()).is_some());
        // Missing row.
        assert!(store.with_pair(0, 99, |_, _| ()).is_none());
    }

    #[test]
    fn concurrent_writers_land_once() {
        let store = std::sync::Arc::new(SketchStore::new(4));
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let store = store.clone();
                s.spawn(move || {
                    for i in 0..50u64 {
                        store.insert(t * 50 + i, sketch_of(i as f32));
                    }
                });
            }
        });
        assert_eq!(store.len(), 200);
        assert_eq!(store.ids().len(), 200);
        assert_eq!(store.ids()[0], 0);
        assert_eq!(*store.ids().last().unwrap(), 199);
    }

    #[test]
    fn arena_snapshot_mirrors_rows() {
        let store = SketchStore::new(3);
        for i in 0..7u64 {
            store.insert(i * 2, sketch_of(i as f32 + 1.0)); // non-dense ids
        }
        let snap = store.arena_snapshot(4, 4);
        assert_eq!(snap.ids, (0..7).map(|i| i * 2).collect::<Vec<u64>>());
        assert_eq!(snap.arena.n(), 7);
        for (pos, &id) in snap.ids.iter().enumerate() {
            assert_eq!(snap.pos[&id], pos);
            let rs = store.get(id).unwrap();
            for m in 1..4 {
                assert_eq!(snap.arena.u_row(m, pos), rs.uside.u(m), "id {id} m {m}");
            }
            assert_eq!(snap.arena.norm_p(pos), rs.moments.get(4));
        }
        // Empty store: well-shaped empty arena.
        let empty = SketchStore::new(2);
        let snap = empty.arena_snapshot(4, 4);
        assert!(snap.ids.is_empty());
        assert!(snap.pos.is_empty());
        assert_eq!(snap.arena.n(), 0);
    }

    #[test]
    fn bytes_accounts_all_rows() {
        let store = SketchStore::new(3);
        let one = sketch_of(1.0).sketch_bytes();
        for i in 0..7 {
            store.insert(i, sketch_of(i as f32));
        }
        assert_eq!(store.bytes(), 7 * one);
    }
}
