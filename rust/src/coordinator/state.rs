//! SketchStore: the coordinator's state — every ingested row's sketches
//! + marginal moments, sharded for concurrent writes.
//!
//! This is the O(nk) object that replaces the O(nD) matrix (and the
//! O(n²) distance cache) in the paper's storage claim. Two internal
//! representations coexist:
//!
//! * **sharded per-row map** — `id → RowSketch` hashmap shards, written
//!   by the per-row / PJRT ingest paths and by explicit `insert`s
//!   (rebalance, persistence load). The classic random-access view.
//! * **columnar segments** — whole [`ColumnarBlock`]s from the GEMM
//!   ingest path, covering a contiguous id range each
//!   ([`SketchStore::insert_block_columnar`]). Already arena-shaped, so
//!   [`SketchStore::arena_snapshot`] lands a segment with one memcpy
//!   per (order, side) instead of transposing n per-row sketches, and
//!   ingest never allocates AoS rows at all.
//!
//! Per-row reads (`get`, `with_pair`) serve map rows by reference and
//! materialize segment rows on demand; the plain pair estimator
//! ([`SketchStore::estimate_pair_plain`]) scores segment rows straight
//! from their panels with no materialization at all. Ids must be unique
//! across both representations (the pipeline's monotone id counter
//! guarantees it) — collisions fail loudly at block insertion and again
//! in the snapshot's duplicate-id backstop.

use std::collections::HashMap;
use std::sync::RwLock;

use crate::core::arena::{ArenaBuilder, SketchArena};
use crate::core::decompose::Decomposition;
use crate::core::estimator::{dot, SketchPanels};
use crate::projection::sketcher::{ColumnarBlock, RowSketch};

/// Sharded row-id → sketch map + columnar block segments.
pub struct SketchStore {
    shards: Vec<RwLock<HashMap<u64, RowSketch>>>,
    /// Columnar ingest segments, sorted by base id; each covers ids
    /// `base .. base + block.rows()` (ranges never overlap).
    segments: RwLock<Vec<Segment>>,
}

struct Segment {
    base: u64,
    block: ColumnarBlock,
}

impl Segment {
    #[inline]
    fn end(&self) -> u64 {
        self.base + self.block.rows() as u64
    }

    #[inline]
    fn contains(&self, id: u64) -> bool {
        id >= self.base && id < self.end()
    }
}

/// Where one side of a pair query lives: a map row (borrowed) or a
/// (block, row) coordinate inside a columnar segment.
enum Side<'x> {
    Map(&'x RowSketch),
    Seg(&'x ColumnarBlock, usize),
}

/// Locate `id` in the sorted segment list.
fn seg_side<'x>(segs: &'x [Segment], id: u64) -> Option<Side<'x>> {
    let pos = segs.partition_point(|s| s.base <= id);
    (pos > 0 && segs[pos - 1].contains(id))
        .then(|| Side::Seg(&segs[pos - 1].block, (id - segs[pos - 1].base) as usize))
}

/// Score two resolved sides with *exactly* the `estimator::estimate`
/// accumulation sequence — marginal norms first, then the
/// c_m·⟨u_m, v_{p−m}⟩/k terms in ascending m — so the answer is bitwise
/// identical to the per-row path whichever representation holds a row.
fn score_sides(dec: &Decomposition, x: &Side<'_>, y: &Side<'_>) -> f64 {
    let p = dec.p();
    let kf = match x {
        Side::Map(rs) => rs.uside.k,
        Side::Seg(block, _) => block.k(),
    } as f64;
    let x_norm = match x {
        Side::Map(rs) => rs.moments.get(p),
        Side::Seg(block, r) => block.moment(*r, p),
    };
    let y_norm = match y {
        Side::Map(rs) => rs.moments.get(p),
        Side::Seg(block, r) => block.moment(*r, p),
    };
    let mut est = x_norm + y_norm;
    for m in 1..p {
        let u = match x {
            Side::Map(rs) => rs.uside.u(m),
            Side::Seg(block, r) => block.u_row(m, *r),
        };
        let v = match y {
            Side::Map(rs) => rs.vside().u(p - m),
            Side::Seg(block, r) => block.v_row(p - m, *r),
        };
        est += dec.coeff(m) * dot(u, v) / kf;
    }
    est
}

/// Outcome of one [`SketchStore::compact_segments`] pass.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CompactionReport {
    /// Merge operations performed (each collapses ≥ 2 segments into 1).
    pub merges: usize,
    /// Rows copied into merged blocks.
    pub rows_merged: usize,
    pub segments_before: usize,
    pub segments_after: usize,
}

/// Zero-copy [`SketchPanels`] view over a store's columnar segments:
/// row `i` of the view is the `i`-th segment-resident row in ascending
/// id order, served straight from its segment's panels. Built (and
/// only valid) under the store's segment read lock — see
/// [`SketchStore::with_columnar_view`]. Row → segment resolution is a
/// binary search over segment offsets, amortized to nothing next to the
/// k-wide dot each access feeds.
pub struct SegmentPanels<'x> {
    p: usize,
    k: usize,
    n: usize,
    /// Per segment: (first view row, base id, block), offsets ascending.
    parts: Vec<(usize, u64, &'x ColumnarBlock)>,
}

impl SegmentPanels<'_> {
    /// The segment holding view row `i`, plus the row's offset in it.
    #[inline]
    fn locate(&self, i: usize) -> (&ColumnarBlock, usize) {
        debug_assert!(i < self.n);
        let pos = self.parts.partition_point(|&(off, _, _)| off <= i);
        let (off, _, block) = self.parts[pos - 1];
        (block, i - off)
    }

    /// Store id of view row `i`.
    pub fn id_at(&self, i: usize) -> u64 {
        let pos = self.parts.partition_point(|&(off, _, _)| off <= i);
        let (off, base, _) = self.parts[pos - 1];
        base + (i - off) as u64
    }

    /// View row holding store id `id`, if a segment covers it.
    pub fn pos_of(&self, id: u64) -> Option<usize> {
        let pos = self.parts.partition_point(|&(_, base, _)| base <= id);
        let &(off, base, block) = self.parts.get(pos.checked_sub(1)?)?;
        (id < base + block.rows() as u64).then(|| off + (id - base) as usize)
    }
}

impl SketchPanels for SegmentPanels<'_> {
    fn n(&self) -> usize {
        self.n
    }

    fn k(&self) -> usize {
        self.k
    }

    fn p(&self) -> usize {
        self.p
    }

    fn u_row(&self, m: usize, i: usize) -> &[f32] {
        let (block, r) = self.locate(i);
        block.u_row(m, r)
    }

    fn v_row(&self, m: usize, i: usize) -> &[f32] {
        let (block, r) = self.locate(i);
        block.v_row(m, r)
    }

    fn norm_p(&self, i: usize) -> f64 {
        let (block, r) = self.locate(i);
        block.moment(r, self.p)
    }
}

/// Result of [`SketchStore::arena_snapshot`]: the columnar arena plus
/// both directions of the id ↔ arena-row mapping.
pub struct ArenaSnapshot {
    /// Row ids ascending; arena row `i` holds `ids[i]`.
    pub ids: Vec<u64>,
    /// id → arena row (the inverse of `ids`, built once here so batch
    /// callers don't rebuild it).
    pub pos: HashMap<u64, usize>,
    pub arena: SketchArena,
}

impl SketchStore {
    pub fn new(shards: usize) -> Self {
        let shards = shards.max(1);
        SketchStore {
            shards: (0..shards).map(|_| RwLock::new(HashMap::new())).collect(),
            segments: RwLock::new(Vec::new()),
        }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Which shard owns a row id (must agree with the router).
    #[inline]
    pub fn shard_of(&self, id: u64) -> usize {
        (id % self.shards.len() as u64) as usize
    }

    pub fn insert(&self, id: u64, sketch: RowSketch) {
        // Debug-only mirror of insert_block_columnar's collision check
        // (release ingest stays one shard lock per row; the snapshot's
        // duplicate-id backstop still catches release-mode collisions).
        debug_assert!(
            !self.segment_covers(id),
            "map insert at id {id} collides with a columnar segment"
        );
        self.shards[self.shard_of(id)].write().unwrap().insert(id, sketch);
    }

    /// Whether some columnar segment covers `id`.
    fn segment_covers(&self, id: u64) -> bool {
        seg_side(&self.segments.read().unwrap(), id).is_some()
    }

    /// Land a whole columnar ingest block covering ids
    /// `base .. base + block.rows()` — no per-row allocation, no
    /// transpose; the block is stored as-is and serves arena snapshots
    /// by contiguous copy. Panics if the id range overlaps an existing
    /// segment or a map row already present at insertion time (a silent
    /// duplicate would corrupt `arena_snapshot`'s contiguous landing);
    /// concurrent `insert`s into the range after this check remain the
    /// caller's responsibility, as with double `insert`s, and are caught
    /// by the snapshot's duplicate-id backstop.
    pub fn insert_block_columnar(&self, base: u64, block: ColumnarBlock) {
        if block.rows() == 0 {
            return;
        }
        let end = base + block.rows() as u64;
        // Map-collision check before taking the segment lock (the
        // shard→segment order every path uses); one lock acquisition
        // per shard, not per id.
        let shard_count = self.shards.len() as u64;
        for (s, shard) in self.shards.iter().enumerate() {
            let guard = shard.read().unwrap();
            for id in (base..end).filter(|id| id % shard_count == s as u64) {
                assert!(
                    !guard.contains_key(&id),
                    "columnar segment [{base}, {end}) collides with existing map row {id}"
                );
            }
        }
        let mut segs = self.segments.write().unwrap();
        let pos = segs.partition_point(|s| s.base < base);
        let disjoint = (pos == 0 || segs[pos - 1].end() <= base)
            && (pos == segs.len() || end <= segs[pos].base);
        assert!(disjoint, "columnar segment [{base}, {end}) overlaps an existing segment");
        segs.insert(pos, Segment { base, block });
    }

    /// Materialize a row from the columnar segments, if one covers `id`.
    fn get_segment(&self, id: u64) -> Option<RowSketch> {
        let segs = self.segments.read().unwrap();
        match seg_side(&segs, id) {
            Some(Side::Seg(block, r)) => Some(block.to_row_sketch(r)),
            _ => None,
        }
    }

    pub fn get(&self, id: u64) -> Option<RowSketch> {
        self.shards[self.shard_of(id)]
            .read()
            .unwrap()
            .get(&id)
            .cloned()
            .or_else(|| self.get_segment(id))
    }

    /// Visit a pair without cloning when both rows live in the hashmap
    /// shards (the query hot path); rows held in columnar segments are
    /// materialized on demand.
    pub fn with_pair<T>(
        &self,
        a: u64,
        b: u64,
        f: impl FnOnce(&RowSketch, &RowSketch) -> T,
    ) -> Option<T> {
        let (sa, sb) = (self.shard_of(a), self.shard_of(b));
        let mut f = Some(f);
        if sa == sb {
            let guard = self.shards[sa].read().unwrap();
            if let (Some(ra), Some(rb)) = (guard.get(&a), guard.get(&b)) {
                return Some(f.take().expect("unused")(ra, rb));
            }
        } else {
            // Lock in shard order to avoid deadlock with concurrent pairs.
            let (first, second) = if sa < sb { (sa, sb) } else { (sb, sa) };
            let g1 = self.shards[first].read().unwrap();
            let g2 = self.shards[second].read().unwrap();
            let (ga, gb) = if sa < sb { (&g1, &g2) } else { (&g2, &g1) };
            if let (Some(ra), Some(rb)) = (ga.get(&a), gb.get(&b)) {
                return Some(f.take().expect("unused")(ra, rb));
            }
        }
        // Slow path: at least one row lives in a columnar segment (or
        // is absent entirely) — materialize owned copies.
        let ra = self.get(a)?;
        let rb = self.get(b)?;
        Some(f.take().expect("unused")(&ra, &rb))
    }

    /// Plain §2.1/§2.2 estimate of a pair served without materializing
    /// rows: map rows are scored by reference, segment rows straight
    /// from their columnar panels — the single-pair query hot path
    /// stays allocation-free whichever representation holds the rows.
    /// Bitwise identical to `estimator::estimate` on the corresponding
    /// [`RowSketch`]es (same accumulation sequence, same `dot`).
    pub fn estimate_pair_plain(&self, dec: &Decomposition, a: u64, b: u64) -> Option<f64> {
        // Lock shards in index order (single lock when they collide).
        let (sa, sb) = (self.shard_of(a), self.shard_of(b));
        let (first, second) = if sa <= sb { (sa, sb) } else { (sb, sa) };
        let g1 = self.shards[first].read().unwrap();
        let g2 = (second != first).then(|| self.shards[second].read().unwrap());
        let map_a: &HashMap<u64, RowSketch> =
            if sa == first { &g1 } else { g2.as_ref().expect("two shards") };
        let map_b: &HashMap<u64, RowSketch> =
            if sb == first { &g1 } else { g2.as_ref().expect("two shards") };
        // Map-resident pairs never touch the store-wide segment lock —
        // point queries on a per-row-ingested store contend only on
        // their two shards, exactly like the old with_pair hot path.
        if let (Some(ra), Some(rb)) = (map_a.get(&a), map_b.get(&b)) {
            return Some(score_sides(dec, &Side::Map(ra), &Side::Map(rb)));
        }
        // Shard→segment lock order, as everywhere else.
        let segs = self.segments.read().unwrap();
        let x = match map_a.get(&a) {
            Some(rs) => Side::Map(rs),
            None => seg_side(&segs, a)?,
        };
        let y = match map_b.get(&b) {
            Some(rs) => Side::Map(rs),
            None => seg_side(&segs, b)?,
        };
        Some(score_sides(dec, &x, &y))
    }

    pub fn len(&self) -> usize {
        let mapped: usize = self.shards.iter().map(|s| s.read().unwrap().len()).sum();
        let segmented: usize =
            self.segments.read().unwrap().iter().map(|s| s.block.rows()).sum();
        mapped + segmented
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn contains(&self, id: u64) -> bool {
        self.shards[self.shard_of(id)].read().unwrap().contains_key(&id)
            || self.segment_covers(id)
    }

    /// Total sketch payload bytes (the paper's O(nk) storage number).
    pub fn bytes(&self) -> usize {
        let mapped: usize = self
            .shards
            .iter()
            .map(|s| s.read().unwrap().values().map(|r| r.sketch_bytes()).sum::<usize>())
            .sum();
        let segmented: usize =
            self.segments.read().unwrap().iter().map(|s| s.block.bytes()).sum();
        mapped + segmented
    }

    /// Columnar snapshot of the whole store: every row's sketches in a
    /// [`SketchArena`] (ids ascending, arena row i = `ids[i]`, inverse
    /// map in `pos`). This is the view the pipeline's blocked estimate /
    /// all-pairs export paths consume. Map rows are copied straight into
    /// the arena buffers (no per-row clones); columnar segments are
    /// already arena-shaped, so each lands as one contiguous copy per
    /// (order, side) — the ingest→arena repack is gone. `p`/`k` come
    /// from the pipeline config (an empty store carries no shape of its
    /// own).
    pub fn arena_snapshot(&self, p: usize, k: usize) -> ArenaSnapshot {
        // Hold every shard's read lock + the segment lock together for
        // a consistent copy (writers take exactly one shard lock or the
        // segment lock, so no ordering cycle); sidedness is probed
        // under the same guards.
        let guards: Vec<_> = self.shards.iter().map(|s| s.read().unwrap()).collect();
        let segs = self.segments.read().unwrap();
        let mut ids: Vec<u64> = guards
            .iter()
            .flat_map(|g| g.keys().copied().collect::<Vec<_>>())
            .collect();
        for s in segs.iter() {
            ids.extend(s.base..s.end());
        }
        ids.sort_unstable();
        // Backstop against map/segment id collisions (insertion-time
        // checks can be raced past): a duplicate here would land a
        // segment at shifted positions and silently corrupt the arena.
        if let Some(w) = ids.windows(2).find(|w| w[0] == w[1]) {
            panic!("store id {} present in both map and columnar segments", w[0]);
        }
        let pos: HashMap<u64, usize> = ids.iter().enumerate().map(|(i, &id)| (id, i)).collect();
        let two_sided = ids.first().is_some_and(|&id| {
            guards[self.shard_of(id)]
                .get(&id)
                .map(|r| r.vside_data.is_some())
                .or_else(|| {
                    segs.iter().find(|s| s.contains(id)).map(|s| s.block.is_two_sided())
                })
                .unwrap_or(false)
        });
        let mut b = ArenaBuilder::new(p, k, ids.len(), two_sided);
        for g in guards.iter() {
            for (id, rs) in g.iter() {
                b.set_row(pos[id], rs);
            }
        }
        for s in segs.iter() {
            // Segment ids are contiguous and unique, so their positions
            // in the sorted id list are consecutive: one block landing.
            b.set_block(pos[&s.base], &s.block);
        }
        let arena = b.finish();
        ArenaSnapshot { ids, pos, arena }
    }

    /// Number of columnar segments currently held (the
    /// `segment_count` metric; small `block_rows` without compaction
    /// makes this grow linearly with ingest).
    pub fn segment_count(&self) -> usize {
        self.segments.read().unwrap().len()
    }

    /// Merge runs of small *adjacent* segments (contiguous id ranges)
    /// into larger arena-layout blocks via [`ColumnarBlock::concat`] —
    /// one contiguous copy per (order, side) per input segment, so the
    /// merged panels are bitwise-identical to the originals and every
    /// estimate is unchanged.
    ///
    /// Policy: a segment is *small* when it has fewer than `min_rows`
    /// rows; an adjacent segment joins the current merge group while the
    /// group or the candidate is small and the merged size stays at or
    /// under `target_rows`. `min_rows == 0` disables compaction (nothing
    /// is small). Non-adjacent segments (id gaps) never merge — the
    /// segment invariant is that covered ranges are exactly the ingested
    /// blocks' ranges, with gaps preserved.
    pub fn compact_segments(&self, min_rows: usize, target_rows: usize) -> CompactionReport {
        let mut segs = self.segments.write().unwrap();
        let before = segs.len();
        let old = std::mem::take(&mut *segs);
        let mut merges = 0usize;
        let mut rows_merged = 0usize;
        let mut group: Vec<Segment> = Vec::new();
        let mut flush = |group: &mut Vec<Segment>, out: &mut Vec<Segment>| {
            if group.len() >= 2 {
                let blocks: Vec<&ColumnarBlock> = group.iter().map(|s| &s.block).collect();
                let merged = ColumnarBlock::concat(&blocks);
                merges += 1;
                rows_merged += merged.rows();
                out.push(Segment { base: group[0].base, block: merged });
            } else {
                out.append(group);
            }
            group.clear();
        };
        for seg in old {
            let group_rows: usize = group.iter().map(|s| s.block.rows()).sum();
            let adjacent = group.last().is_some_and(|g| g.end() == seg.base);
            let joinable = adjacent
                && (seg.block.rows() < min_rows || group_rows < min_rows)
                && group_rows + seg.block.rows() <= target_rows;
            if !joinable {
                flush(&mut group, &mut *segs);
            }
            group.push(seg);
        }
        flush(&mut group, &mut *segs);
        CompactionReport {
            merges,
            rows_merged,
            segments_before: before,
            segments_after: segs.len(),
        }
    }

    /// Run `f` on a zero-copy [`SegmentPanels`] view of the store when
    /// it is *fully columnar* (every row segment-resident, at least one
    /// row) — the segment-native batch-query fast path: blocked kernels
    /// score the panels in place, skipping the `arena_snapshot` copy
    /// entirely. Stores with map rows (or empty stores) get `None` and
    /// must take the snapshot path.
    ///
    /// Locking: shard + segment read locks are held for the *whole* of
    /// `f` — for a long kernel (an all-pairs scan) that is much longer
    /// than a snapshot's copy phase, and writers (ingest, compaction)
    /// block until it finishes. That matches how the pipeline already
    /// treats bulk scans (offline-ish, like rebalance); callers needing
    /// ingest concurrency during long scans should prefer
    /// [`SketchStore::arena_snapshot`], which pays the copy to release
    /// the locks early.
    pub fn with_columnar_view<R>(
        &self,
        p: usize,
        f: impl FnOnce(Option<&SegmentPanels<'_>>) -> R,
    ) -> R {
        let guards: Vec<_> = self.shards.iter().map(|s| s.read().unwrap()).collect();
        let segs = self.segments.read().unwrap();
        if segs.is_empty() || guards.iter().any(|g| !g.is_empty()) {
            return f(None);
        }
        let mut parts = Vec::with_capacity(segs.len());
        let mut off = 0usize;
        for s in segs.iter() {
            parts.push((off, s.base, &s.block));
            off += s.block.rows();
        }
        let view = SegmentPanels { p, k: segs[0].block.k(), n: off, parts };
        f(Some(&view))
    }

    /// `(base, block)` clones of every columnar segment, base ascending.
    /// Rebalance carries segments over verbatim — they are
    /// shard-independent, so re-sharding must not degrade them to
    /// per-row map entries.
    pub fn segments_snapshot(&self) -> Vec<(u64, ColumnarBlock)> {
        self.segments
            .read()
            .unwrap()
            .iter()
            .map(|s| (s.base, s.block.clone()))
            .collect()
    }

    /// Ids held in the hashmap shards only (segment-backed ids
    /// excluded), ascending.
    pub fn map_ids(&self) -> Vec<u64> {
        let mut ids: Vec<u64> = self
            .shards
            .iter()
            .flat_map(|s| s.read().unwrap().keys().copied().collect::<Vec<_>>())
            .collect();
        ids.sort_unstable();
        ids
    }

    /// All row ids, ascending (takes all read locks).
    pub fn ids(&self) -> Vec<u64> {
        let mut ids: Vec<u64> = self
            .shards
            .iter()
            .flat_map(|s| s.read().unwrap().keys().copied().collect::<Vec<_>>())
            .collect();
        for s in self.segments.read().unwrap().iter() {
            ids.extend(s.base..s.end());
        }
        ids.sort_unstable();
        ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::projection::sketcher::Sketcher;
    use crate::projection::{ProjectionDist, ProjectionSpec, Strategy};

    fn sketch_of(val: f32) -> RowSketch {
        let sk = Sketcher::new(
            ProjectionSpec::new(1, 4, ProjectionDist::Normal, Strategy::Basic),
            4,
        );
        sk.sketch_row(&[val, val * 2.0, val * 3.0])
    }

    #[test]
    fn insert_get_roundtrip() {
        let store = SketchStore::new(4);
        store.insert(10, sketch_of(1.0));
        assert!(store.contains(10));
        assert!(!store.contains(11));
        let got = store.get(10).unwrap();
        assert_eq!(got.moments.get(1), sketch_of(1.0).moments.get(1));
    }

    #[test]
    fn with_pair_same_and_cross_shard() {
        let store = SketchStore::new(2);
        store.insert(0, sketch_of(1.0)); // shard 0
        store.insert(2, sketch_of(2.0)); // shard 0
        store.insert(1, sketch_of(3.0)); // shard 1
        // Same shard.
        let m = store.with_pair(0, 2, |a, b| (a.moments.get(1), b.moments.get(1))).unwrap();
        assert!(m.0 < m.1);
        // Cross shard, both orders.
        assert!(store.with_pair(0, 1, |_, _| ()).is_some());
        assert!(store.with_pair(1, 0, |_, _| ()).is_some());
        // Missing row.
        assert!(store.with_pair(0, 99, |_, _| ()).is_none());
    }

    #[test]
    fn concurrent_writers_land_once() {
        let store = std::sync::Arc::new(SketchStore::new(4));
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let store = store.clone();
                s.spawn(move || {
                    for i in 0..50u64 {
                        store.insert(t * 50 + i, sketch_of(i as f32));
                    }
                });
            }
        });
        assert_eq!(store.len(), 200);
        assert_eq!(store.ids().len(), 200);
        assert_eq!(store.ids()[0], 0);
        assert_eq!(*store.ids().last().unwrap(), 199);
    }

    #[test]
    fn arena_snapshot_mirrors_rows() {
        let store = SketchStore::new(3);
        for i in 0..7u64 {
            store.insert(i * 2, sketch_of(i as f32 + 1.0)); // non-dense ids
        }
        let snap = store.arena_snapshot(4, 4);
        assert_eq!(snap.ids, (0..7).map(|i| i * 2).collect::<Vec<u64>>());
        assert_eq!(snap.arena.n(), 7);
        for (pos, &id) in snap.ids.iter().enumerate() {
            assert_eq!(snap.pos[&id], pos);
            let rs = store.get(id).unwrap();
            for m in 1..4 {
                assert_eq!(snap.arena.u_row(m, pos), rs.uside.u(m), "id {id} m {m}");
            }
            assert_eq!(snap.arena.norm_p(pos), rs.moments.get(4));
        }
        // Empty store: well-shaped empty arena.
        let empty = SketchStore::new(2);
        let snap = empty.arena_snapshot(4, 4);
        assert!(snap.ids.is_empty());
        assert!(snap.pos.is_empty());
        assert_eq!(snap.arena.n(), 0);
    }

    fn block_of(n: usize) -> crate::projection::sketcher::ColumnarBlock {
        let sk = Sketcher::new(
            ProjectionSpec::new(1, 4, ProjectionDist::Normal, Strategy::Basic),
            4,
        );
        let rows: Vec<Vec<f32>> = (0..n)
            .map(|i| (0..12).map(|t| ((i * 7 + t) as f32 * 0.31).sin()).collect())
            .collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        sk.sketch_block(&refs, 1)
    }

    #[test]
    fn columnar_segments_roundtrip() {
        let store = SketchStore::new(3);
        let block = block_of(6);
        store.insert_block_columnar(10, block.clone());
        store.insert(3, sketch_of(1.0));
        assert_eq!(store.len(), 7);
        assert!(store.contains(3) && store.contains(10) && store.contains(15));
        assert!(!store.contains(9) && !store.contains(16));
        assert_eq!(store.ids(), vec![3, 10, 11, 12, 13, 14, 15]);
        // Per-row reads materialize segment rows.
        let rs = store.get(12).unwrap();
        assert_eq!(rs.uside.u(1), block.u_row(1, 2));
        assert_eq!(rs.moments.0.as_slice(), block.moments_row(2));
        // Pair visits across map and segment rows.
        assert!(store.with_pair(3, 12, |a, b| (a.moments.get(4), b.moments.get(4))).is_some());
        assert!(store.with_pair(12, 14, |_, _| ()).is_some());
        assert!(store.with_pair(12, 99, |_, _| ()).is_none());
        // Storage accounting covers both representations.
        assert_eq!(store.bytes(), sketch_of(1.0).sketch_bytes() + block.bytes());
    }

    #[test]
    fn segment_snapshot_lands_blocks_contiguously() {
        let store = SketchStore::new(2);
        store.insert(0, sketch_of(1.0));
        store.insert_block_columnar(5, block_of(4)); // ids 5..9
        store.insert(20, sketch_of(2.0));
        store.insert_block_columnar(9, block_of(2)); // ids 9..11, adjacent
        let snap = store.arena_snapshot(4, 4);
        assert_eq!(snap.ids, vec![0, 5, 6, 7, 8, 9, 10, 20]);
        assert_eq!(snap.arena.n(), 8);
        for (pos, &id) in snap.ids.iter().enumerate() {
            assert_eq!(snap.pos[&id], pos);
            let rs = store.get(id).unwrap();
            for m in 1..4 {
                assert_eq!(snap.arena.u_row(m, pos), rs.uside.u(m), "id {id} m {m}");
            }
            assert_eq!(snap.arena.norm_p(pos), rs.moments.get(4));
        }
    }

    #[test]
    fn estimate_pair_plain_matches_materialized_estimate() {
        use crate::core::decompose::Decomposition;
        use crate::core::estimator;
        let dec = Decomposition::new(4).unwrap();
        let store = SketchStore::new(3);
        store.insert(1, sketch_of(1.5));
        store.insert(2, sketch_of(-0.75));
        store.insert_block_columnar(10, block_of(4)); // ids 10..14
        // map×map, map×segment, segment×segment — all bitwise equal to
        // the per-row estimator on materialized rows.
        for (a, b) in [(1u64, 2u64), (1, 12), (12, 1), (10, 13)] {
            let want = {
                let (ra, rb) = (store.get(a).unwrap(), store.get(b).unwrap());
                estimator::estimate(&dec, &ra, &rb)
            };
            let got = store.estimate_pair_plain(&dec, a, b).unwrap();
            assert_eq!(got, want, "pair ({a},{b})");
        }
        assert!(store.estimate_pair_plain(&dec, 1, 99).is_none());
        assert!(store.estimate_pair_plain(&dec, 99, 1).is_none());
    }

    #[test]
    #[should_panic(expected = "overlaps an existing segment")]
    fn overlapping_segments_rejected() {
        let store = SketchStore::new(1);
        store.insert_block_columnar(10, block_of(4));
        store.insert_block_columnar(12, block_of(4));
    }

    #[test]
    #[should_panic(expected = "collides with existing map row")]
    fn segment_colliding_with_map_row_rejected() {
        let store = SketchStore::new(2);
        store.insert(12, sketch_of(1.0));
        store.insert_block_columnar(10, block_of(6));
    }

    #[test]
    fn empty_block_is_a_noop() {
        let store = SketchStore::new(1);
        store.insert_block_columnar(10, block_of(0));
        assert!(store.is_empty());
        assert!(store.ids().is_empty());
    }

    #[test]
    fn bytes_accounts_all_rows() {
        let store = SketchStore::new(3);
        let one = sketch_of(1.0).sketch_bytes();
        for i in 0..7 {
            store.insert(i, sketch_of(i as f32));
        }
        assert_eq!(store.bytes(), 7 * one);
    }

    #[test]
    fn compaction_merges_adjacent_small_segments() {
        let store = SketchStore::new(2);
        store.insert_block_columnar(10, block_of(4)); // 10..14
        store.insert_block_columnar(14, block_of(2)); // 14..16, adjacent
        store.insert_block_columnar(16, block_of(3)); // 16..19, adjacent
        store.insert_block_columnar(40, block_of(2)); // gapped: never merges
        assert_eq!(store.segment_count(), 4);
        let ids = store.ids();
        let bytes = store.bytes();
        let report = store.compact_segments(8, 100);
        assert_eq!(report.segments_before, 4);
        assert_eq!(report.segments_after, 2);
        assert_eq!(report.merges, 1);
        assert_eq!(report.rows_merged, 9);
        assert_eq!(store.segment_count(), 2);
        // Content unchanged: same ids, same bytes, same row payloads.
        assert_eq!(store.ids(), ids);
        assert_eq!(store.bytes(), bytes);
        let snap = store.segments_snapshot();
        assert_eq!(snap[0].0, 10);
        assert_eq!(snap[0].1.rows(), 9);
        assert_eq!(snap[1].0, 40);
    }

    #[test]
    fn compaction_respects_target_rows_and_zero_min() {
        let store = SketchStore::new(1);
        for i in 0..6u64 {
            store.insert_block_columnar(i * 3, block_of(3)); // 0..18, adjacent
        }
        // min 0 disables the pass entirely.
        let report = store.compact_segments(0, 100);
        assert_eq!(report.merges, 0);
        assert_eq!(store.segment_count(), 6);
        // Target caps merged size: 3-row segments pack to ≤ 7 rows
        // (two per group), leaving 3 merged pairs.
        let report = store.compact_segments(100, 7);
        assert_eq!(report.merges, 3);
        assert_eq!(store.segment_count(), 3);
        assert_eq!(
            store.segments_snapshot().iter().map(|(b, blk)| (*b, blk.rows())).collect::<Vec<_>>(),
            vec![(0, 6), (6, 6), (12, 6)]
        );
        // Idempotent once nothing is small enough to join.
        let report = store.compact_segments(4, 7);
        assert_eq!(report.merges, 0);
    }

    #[test]
    fn compaction_is_estimate_invariant_bitwise() {
        use crate::core::decompose::Decomposition;
        let dec = Decomposition::new(4).unwrap();
        let store = SketchStore::new(3);
        store.insert(2, sketch_of(0.5));
        store.insert_block_columnar(10, block_of(5)); // 10..15
        store.insert_block_columnar(15, block_of(4)); // 15..19
        let pairs = [(2u64, 11u64), (10, 18), (14, 15), (11, 11)];
        let before: Vec<f64> =
            pairs.iter().map(|&(a, b)| store.estimate_pair_plain(&dec, a, b).unwrap()).collect();
        let report = store.compact_segments(64, 1024);
        assert_eq!(report.merges, 1);
        let after: Vec<f64> =
            pairs.iter().map(|&(a, b)| store.estimate_pair_plain(&dec, a, b).unwrap()).collect();
        assert_eq!(before, after);
    }

    #[test]
    fn columnar_view_engages_only_when_fully_columnar() {
        let store = SketchStore::new(2);
        // Empty store: no view.
        assert!(store.with_columnar_view(4, |v| v.is_none()));
        store.insert_block_columnar(10, block_of(4));
        assert!(store.with_columnar_view(4, |v| v.is_some()));
        // One map row degrades to the snapshot path.
        store.insert(0, sketch_of(1.0));
        assert!(store.with_columnar_view(4, |v| v.is_none()));
    }

    #[test]
    fn columnar_view_mirrors_arena_snapshot() {
        let store = SketchStore::new(2);
        store.insert_block_columnar(10, block_of(4)); // 10..14
        store.insert_block_columnar(20, block_of(3)); // 20..23 (gap)
        let snap = store.arena_snapshot(4, 4);
        store.with_columnar_view(4, |view| {
            let v = view.expect("fully columnar");
            assert_eq!(v.n(), 7);
            assert_eq!(v.k(), 4);
            assert_eq!(v.p(), 4);
            for i in 0..7 {
                assert_eq!(v.id_at(i), snap.ids[i]);
                assert_eq!(v.pos_of(snap.ids[i]), Some(i));
                for m in 1..4 {
                    assert_eq!(v.u_row(m, i), snap.arena.u_row(m, i), "m={m} i={i}");
                    assert_eq!(v.v_row(m, i), snap.arena.v_row(m, i), "m={m} i={i}");
                }
                assert_eq!(v.norm_p(i), snap.arena.norm_p(i));
            }
            // Ids outside any segment resolve to None.
            for missing in [0u64, 9, 14, 19, 23, 99] {
                assert_eq!(v.pos_of(missing), None, "id {missing}");
            }
        });
    }
}
