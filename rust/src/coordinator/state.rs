//! SketchStore: the coordinator's state — every ingested row's sketches
//! + marginal moments, served to readers through cheap immutable
//! **epoch snapshots** so scans never pin the write path.
//!
//! This is the O(nk) object that replaces the O(nD) matrix (and the
//! O(n²) distance cache) in the paper's storage claim. Two internal
//! representations coexist:
//!
//! * **sharded per-row map** — `id → RowSketch` hashmap shards, written
//!   by the per-row ingest path and by explicit `insert`s (rebalance,
//!   persistence load). Each shard holds an `Arc<HashMap<..>>` of
//!   `Arc<RowSketch>` payloads: writers mutate through
//!   `Arc::make_mut`, so a shard whose map is pinned by a live snapshot
//!   is cloned **at pointer level** (the row payloads are shared, never
//!   deep-copied) on the first write after the snapshot — classic
//!   copy-on-write epoch publishing.
//! * **columnar segments** — whole [`ColumnarBlock`]s from the GEMM /
//!   PJRT ingest paths, covering a contiguous id range each
//!   ([`SketchStore::insert_block_columnar`]), held behind `Arc` so a
//!   snapshot captures a segment by handle, never by panel copy.
//!
//! ## Snapshots
//!
//! [`SketchStore::snapshot`] returns an [`Arc<StoreSnapshot>`]: the
//! per-shard map `Arc`s plus the segment directory (`Vec` of
//! `(base, Arc<ColumnarBlock>)`). Capture cost is **O(shards +
//! segments)** — the shard/segment read locks are held only for the
//! pointer clones, and a monotone store **epoch** (bumped inside every
//! writer's critical section) lets repeated captures of a quiescent
//! store return the cached `Arc` in O(1) without touching any shard
//! lock. Writers are therefore never blocked longer than one capture;
//! every bulk reader (batch queries, all-pairs, top-k, persistence
//! `save`, rebalance) runs entirely on its snapshot.
//!
//! **What a snapshot pins:** the shard maps and segment blocks that
//! were live at capture. Later inserts/compactions publish new `Arc`s
//! in the store; the snapshot keeps serving its frozen view (ids,
//! bytes, estimates are all answered from the same consistent cut) and
//! frees the shared state when dropped. **Staleness:** a snapshot's
//! [`StoreSnapshot::epoch`] against [`SketchStore::epoch`] measures how
//! many writes it is behind (the query service's `snapshot_age` gauge).
//!
//! **Copy-on-write compaction:** [`SketchStore::compact_range`] plans
//! merge groups from a snapshot, builds the merged blocks entirely
//! off-lock, then swaps them into the directory under one brief write
//! lock. Live snapshots keep serving the pre-merge blocks (their `Arc`s
//! stay alive); new snapshots see the merged blocks. Both views score
//! bitwise-identically — panels move only by contiguous copy.
//!
//! Per-row reads (`get`, `with_pair`) serve map rows by reference and
//! materialize segment rows on demand; the plain pair estimator
//! ([`StoreSnapshot::estimate_pair_plain`]) scores segment rows
//! straight from their panels with no materialization at all. Ids must
//! be unique across both representations (the pipeline's monotone id
//! counter guarantees it) — collisions fail loudly at block insertion
//! and again in the arena build's duplicate-id backstop.

// Serving path: clippy backs the pallas-lint serving-no-panic rule.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::core::arena::{ArenaBuilder, SketchArena};
use crate::core::decompose::Decomposition;
use crate::core::estimator::{SketchPanels, ZoneExtent};
use crate::core::quant::{dot_views, PanelQuant, RowView};
use crate::core::zone::ZoneMeta;
use crate::projection::sketcher::{ColumnarBlock, RowSketch};
use crate::util::sync::{MutexExt, RwLockExt};

type ShardMap = HashMap<u64, Arc<RowSketch>>;

/// One columnar segment: ids `base .. base + block.rows()`, panels
/// shared by handle between the store and every snapshot that captured
/// them, plus the zone summary the pruned top-k scan bounds distances
/// with (computed at insertion, merged exactly at compaction).
#[derive(Clone)]
pub struct Segment {
    pub base: u64,
    pub block: Arc<ColumnarBlock>,
    pub zone: Arc<ZoneMeta>,
}

impl Segment {
    #[inline]
    fn end(&self) -> u64 {
        self.base + self.block.rows() as u64
    }

    #[inline]
    fn contains(&self, id: u64) -> bool {
        id >= self.base && id < self.end()
    }
}

/// Sharded row-id → sketch map + columnar block segments, epoch-ed for
/// lock-free snapshot reads.
pub struct SketchStore {
    shards: Vec<RwLock<Arc<ShardMap>>>,
    /// Columnar ingest segments, sorted by base id; ranges never
    /// overlap.
    segments: RwLock<Vec<Segment>>,
    /// Monotone write epoch; bumped inside each writer's critical
    /// section, so any capture that holds all read locks observes a
    /// stable value consistent with the content it clones.
    epoch: AtomicU64,
    /// Last published snapshot; reused (O(1), no shard locks) while the
    /// epoch has not advanced.
    cached: RwLock<Option<Arc<StoreSnapshot>>>,
    /// Serializes compaction passes, so a planned merge run can never
    /// be mutated by a rival compactor between plan and swap.
    compaction: Mutex<()>,
    /// Panel encoding applied to blocks landed via
    /// [`SketchStore::insert_block_shared`] (the `panel-quant` config
    /// knob, stored as a [`PanelQuant`] tag). Quantization happens
    /// exactly once, at this store boundary; prezoned insertions
    /// (recovery, rebalance) adopt their blocks verbatim.
    panel_quant: std::sync::atomic::AtomicU8,
}

/// Where one side of a pair query lives: a map row (borrowed) or a
/// (block, row) coordinate inside a columnar segment.
enum Side<'x> {
    Map(&'x RowSketch),
    Seg(&'x ColumnarBlock, usize),
}

/// Locate `id` in the sorted segment list, as a (block, row)
/// coordinate. Returning the coordinate directly (rather than a
/// [`Side`]) lets callers that only ever see segment hits destructure
/// infallibly.
fn seg_side<'x>(segs: &'x [Segment], id: u64) -> Option<(&'x ColumnarBlock, usize)> {
    let pos = segs.partition_point(|s| s.base <= id);
    (pos > 0 && segs[pos - 1].contains(id))
        .then(|| (segs[pos - 1].block.as_ref(), (id - segs[pos - 1].base) as usize))
}

/// Score two resolved sides with *exactly* the `estimator::estimate`
/// accumulation sequence — marginal norms first, then the
/// c_m·⟨u_m, v_{p−m}⟩/k terms in ascending m — so the answer is bitwise
/// identical to the per-row path whichever representation holds a row.
fn score_sides(dec: &Decomposition, x: &Side<'_>, y: &Side<'_>) -> f64 {
    let p = dec.p();
    let kf = match x {
        Side::Map(rs) => rs.uside.k,
        Side::Seg(block, _) => block.k(),
    } as f64;
    let x_norm = match x {
        Side::Map(rs) => rs.moments.get(p),
        Side::Seg(block, r) => block.moment(*r, p),
    };
    let y_norm = match y {
        Side::Map(rs) => rs.moments.get(p),
        Side::Seg(block, r) => block.moment(*r, p),
    };
    let mut est = x_norm + y_norm;
    for m in 1..p {
        let u = match x {
            Side::Map(rs) => RowView::F32(rs.uside.u(m)),
            Side::Seg(block, r) => block.u_view(m, *r),
        };
        let v = match y {
            Side::Map(rs) => RowView::F32(rs.vside().u(p - m)),
            Side::Seg(block, r) => block.v_view(p - m, *r),
        };
        est += dec.coeff(m) * dot_views(u, v) / kf;
    }
    est
}

/// Outcome of one [`SketchStore::compact_segments`] pass.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CompactionReport {
    /// Merge operations performed (each collapses ≥ 2 segments into 1).
    pub merges: usize,
    /// Rows copied into merged blocks.
    pub rows_merged: usize,
    pub segments_before: usize,
    pub segments_after: usize,
}

/// Immutable point-in-time view of a [`SketchStore`]: the per-shard map
/// `Arc`s plus the segment directory, captured in O(shards + segments)
/// with no panel copies. Every read method answers from this frozen
/// cut, with no locks and no coordination — the store may ingest and
/// compact freely underneath.
pub struct StoreSnapshot {
    epoch: u64,
    map: Vec<Arc<ShardMap>>,
    segments: Vec<Segment>,
}

impl StoreSnapshot {
    /// Store epoch at capture time (compare with
    /// [`SketchStore::epoch`] for staleness).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn shard_count(&self) -> usize {
        self.map.len()
    }

    #[inline]
    fn shard_of(&self, id: u64) -> usize {
        (id % self.map.len() as u64) as usize
    }

    /// The captured segment directory, base ascending. The `Arc`s are
    /// the very allocations the store held at capture (pointer-shared,
    /// never copied).
    pub fn segments(&self) -> &[Segment] {
        &self.segments
    }

    pub fn segment_count(&self) -> usize {
        self.segments.len()
    }

    /// Rows in this view (map + segment-resident).
    pub fn len(&self) -> usize {
        let mapped: usize = self.map.iter().map(|m| m.len()).sum();
        let segmented: usize = self.segments.iter().map(|s| s.block.rows()).sum();
        mapped + segmented
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn contains(&self, id: u64) -> bool {
        self.map[self.shard_of(id)].contains_key(&id) || seg_side(&self.segments, id).is_some()
    }

    /// Total sketch payload bytes (the paper's O(nk) storage number) —
    /// one consistent cut, immune to concurrent inserts.
    pub fn bytes(&self) -> usize {
        let mapped: usize = self
            .map
            .iter()
            .map(|m| m.values().map(|r| r.sketch_bytes()).sum::<usize>())
            .sum();
        let segmented: usize = self.segments.iter().map(|s| s.block.bytes()).sum();
        mapped + segmented
    }

    /// Ids held in the hashmap shards only (segment-backed excluded),
    /// ascending.
    pub fn map_ids(&self) -> Vec<u64> {
        let mut ids: Vec<u64> =
            self.map.iter().flat_map(|m| m.keys().copied()).collect();
        ids.sort_unstable();
        ids
    }

    /// All row ids, ascending.
    pub fn ids(&self) -> Vec<u64> {
        let mut ids: Vec<u64> =
            self.map.iter().flat_map(|m| m.keys().copied()).collect();
        for s in &self.segments {
            ids.extend(s.base..s.end());
        }
        ids.sort_unstable();
        ids
    }

    /// Materialize a row (map rows cloned, segment rows assembled).
    pub fn get(&self, id: u64) -> Option<RowSketch> {
        if let Some(rs) = self.map[self.shard_of(id)].get(&id) {
            return Some(rs.as_ref().clone());
        }
        seg_side(&self.segments, id).map(|(block, r)| block.to_row_sketch(r))
    }

    /// Visit a pair without cloning when both rows live in the map
    /// shards; segment rows are materialized on demand. Lock-free —
    /// resolution happens on the frozen view.
    pub fn with_pair<T>(
        &self,
        a: u64,
        b: u64,
        f: impl FnOnce(&RowSketch, &RowSketch) -> T,
    ) -> Option<T> {
        let ma = self.map[self.shard_of(a)].get(&a);
        let mb = self.map[self.shard_of(b)].get(&b);
        let oa;
        let ob;
        let ra: &RowSketch = match ma {
            Some(rs) => rs.as_ref(),
            None => {
                let (block, r) = seg_side(&self.segments, a)?;
                oa = block.to_row_sketch(r);
                &oa
            }
        };
        let rb: &RowSketch = match mb {
            Some(rs) => rs.as_ref(),
            None => {
                let (block, r) = seg_side(&self.segments, b)?;
                ob = block.to_row_sketch(r);
                &ob
            }
        };
        Some(f(ra, rb))
    }

    /// Plain §2.1/§2.2 estimate of a pair served without materializing
    /// rows: map rows are scored by reference, segment rows straight
    /// from their columnar panels — allocation-free and lock-free.
    /// Bitwise identical to `estimator::estimate` on the corresponding
    /// [`RowSketch`]es (same accumulation sequence, same `dot`).
    pub fn estimate_pair_plain(&self, dec: &Decomposition, a: u64, b: u64) -> Option<f64> {
        let x = match self.map[self.shard_of(a)].get(&a) {
            Some(rs) => Side::Map(rs.as_ref()),
            None => {
                let (block, r) = seg_side(&self.segments, a)?;
                Side::Seg(block, r)
            }
        };
        let y = match self.map[self.shard_of(b)].get(&b) {
            Some(rs) => Side::Map(rs.as_ref()),
            None => {
                let (block, r) = seg_side(&self.segments, b)?;
                Side::Seg(block, r)
            }
        };
        Some(score_sides(dec, &x, &y))
    }

    /// Columnar arena copy of the whole view: every row's sketches in a
    /// [`SketchArena`] (ids ascending, arena row i = `ids[i]`, inverse
    /// map in `pos`). Map rows are copied straight into the arena
    /// buffers (no per-row clones); columnar segments are already
    /// arena-shaped, so each lands as one contiguous copy per
    /// (order, side). The copy runs entirely off-lock — the store is
    /// never pinned. `p`/`k` come from the caller's config (an empty
    /// view carries no shape of its own).
    pub fn arena(&self, p: usize, k: usize) -> ArenaSnapshot {
        let ids = self.ids();
        // Backstop against map/segment id collisions (insertion-time
        // checks can be raced past): a duplicate here would land a
        // segment at shifted positions and silently corrupt the arena.
        if let Some(w) = ids.windows(2).find(|w| w[0] == w[1]) {
            // pallas-lint: allow(serving-no-panic) -- corruption backstop: serving from a mis-shifted arena would silently return wrong distances
            panic!("store id {} present in both map and columnar segments", w[0]);
        }
        let pos: HashMap<u64, usize> = ids.iter().enumerate().map(|(i, &id)| (id, i)).collect();
        let two_sided = ids.first().is_some_and(|&id| {
            self.map[self.shard_of(id)]
                .get(&id)
                .map(|r| r.vside_data.is_some())
                .or_else(|| {
                    self.segments
                        .iter()
                        .find(|s| s.contains(id))
                        .map(|s| s.block.is_two_sided())
                })
                .unwrap_or(false)
        });
        let mut b = ArenaBuilder::new(p, k, ids.len(), two_sided);
        for m in &self.map {
            for (id, rs) in m.iter() {
                b.set_row(pos[id], rs);
            }
        }
        for s in &self.segments {
            // Segment ids are contiguous and unique, so their positions
            // in the sorted id list are consecutive: one block landing.
            b.set_block(pos[&s.base], &s.block);
        }
        let arena = b.finish();
        ArenaSnapshot { ids, pos, arena }
    }

    /// Zero-copy [`SegmentPanels`] over this view when it is *fully
    /// columnar* (every row segment-resident, at least one row) — the
    /// segment-native batch-query fast path. The panels own `Arc`
    /// handles (no borrowed lifetimes), so the view outlives any store
    /// mutation and a kernel may run on it for as long as it likes
    /// without blocking a single writer. Views with map rows (or empty
    /// views) get `None` and must take the [`StoreSnapshot::arena`]
    /// path.
    pub fn columnar_panels(&self, p: usize) -> Option<SegmentPanels> {
        if self.segments.is_empty() || self.map.iter().any(|m| !m.is_empty()) {
            return None;
        }
        let mut parts = Vec::with_capacity(self.segments.len());
        let mut off = 0usize;
        for s in &self.segments {
            parts.push((off, s.base, s.block.clone(), s.zone.clone()));
            off += s.block.rows();
        }
        Some(SegmentPanels { p, k: self.segments[0].block.k(), n: off, parts })
    }

    /// Arc handle of the map-shard row holding `id`, if any — the row
    /// payload is shared, never copied (the serving index's map shards
    /// are built from these).
    pub fn map_row(&self, id: u64) -> Option<Arc<RowSketch>> {
        self.map[self.shard_of(id)].get(&id).map(Arc::clone)
    }
}

/// Owned [`SketchPanels`] view over a snapshot's columnar segments: row
/// `i` of the view is the `i`-th segment-resident row in ascending id
/// order, served straight from its segment's panels. Holds `Arc`
/// handles — no borrowed lifetimes, no locks; build one with
/// [`StoreSnapshot::columnar_panels`]. Row → segment resolution is a
/// binary search over segment offsets, amortized to nothing next to the
/// k-wide dot each access feeds.
pub struct SegmentPanels {
    p: usize,
    k: usize,
    n: usize,
    /// Per segment: (first view row, base id, block, zone), offsets
    /// ascending.
    parts: Vec<(usize, u64, Arc<ColumnarBlock>, Arc<ZoneMeta>)>,
}

impl SegmentPanels {
    /// The segment holding view row `i`, plus the row's offset in it.
    #[inline]
    fn locate(&self, i: usize) -> (&ColumnarBlock, usize) {
        debug_assert!(i < self.n);
        let pos = self.parts.partition_point(|&(off, ..)| off <= i);
        let (off, _, block, _) = &self.parts[pos - 1];
        (block.as_ref(), i - off)
    }

    /// Store id of view row `i`.
    pub fn id_at(&self, i: usize) -> u64 {
        let pos = self.parts.partition_point(|&(off, ..)| off <= i);
        let (off, base, ..) = &self.parts[pos - 1];
        base + (i - off) as u64
    }

    /// View row holding store id `id`, if a segment covers it.
    pub fn pos_of(&self, id: u64) -> Option<usize> {
        let pos = self.parts.partition_point(|&(_, base, ..)| base <= id);
        let (off, base, block, _) = self.parts.get(pos.checked_sub(1)?)?;
        (id < base + block.rows() as u64).then(|| off + (id - base) as usize)
    }

    /// Zone extents for `estimator::top_k_scan_zoned`: one per segment,
    /// tiling `[0, n)` in view-row order.
    pub fn extents(&self) -> Vec<ZoneExtent<'_>> {
        self.parts
            .iter()
            .map(|(off, _, block, zone)| ZoneExtent {
                off: *off,
                rows: block.rows(),
                zone: Some(zone.as_ref()),
            })
            .collect()
    }
}

impl SketchPanels for SegmentPanels {
    fn n(&self) -> usize {
        self.n
    }

    fn k(&self) -> usize {
        self.k
    }

    fn p(&self) -> usize {
        self.p
    }

    fn u_row(&self, m: usize, i: usize) -> RowView<'_> {
        let (block, r) = self.locate(i);
        block.u_view(m, r)
    }

    fn v_row(&self, m: usize, i: usize) -> RowView<'_> {
        let (block, r) = self.locate(i);
        block.v_view(m, r)
    }

    fn norm_p(&self, i: usize) -> f64 {
        let (block, r) = self.locate(i);
        block.moment(r, self.p)
    }
}

/// Result of [`SketchStore::arena_snapshot`]: the columnar arena plus
/// both directions of the id ↔ arena-row mapping.
pub struct ArenaSnapshot {
    /// Row ids ascending; arena row `i` holds `ids[i]`.
    pub ids: Vec<u64>,
    /// id → arena row (the inverse of `ids`, built once here so batch
    /// callers don't rebuild it).
    pub pos: HashMap<u64, usize>,
    pub arena: SketchArena,
}

impl SketchStore {
    pub fn new(shards: usize) -> Self {
        let shards = shards.max(1);
        SketchStore {
            shards: (0..shards).map(|_| RwLock::new(Arc::new(HashMap::new()))).collect(),
            segments: RwLock::new(Vec::new()),
            epoch: AtomicU64::new(0),
            cached: RwLock::new(None),
            compaction: Mutex::new(()),
            panel_quant: std::sync::atomic::AtomicU8::new(PanelQuant::None.tag()),
        }
    }

    /// Panel encoding newly ingested blocks are stored under.
    pub fn panel_quant(&self) -> PanelQuant {
        PanelQuant::from_tag(self.panel_quant.load(Ordering::Relaxed))
            .unwrap_or(PanelQuant::None)
    }

    /// Set the panel encoding for future block ingest (existing
    /// segments are never rewritten; mixed-encoding directories are
    /// fine — compaction merges homogeneous runs bytewise and decodes
    /// mixed ones).
    pub fn set_panel_quant(&self, q: PanelQuant) {
        self.panel_quant.store(q.tag(), Ordering::Relaxed);
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Which shard owns a row id (must agree with the router).
    #[inline]
    pub fn shard_of(&self, id: u64) -> usize {
        (id % self.shards.len() as u64) as usize
    }

    /// Current write epoch. `epoch() - snapshot.epoch()` is how many
    /// writes a snapshot is behind (the `snapshot_age` metric).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    pub fn insert(&self, id: u64, sketch: RowSketch) {
        // Debug-only mirror of insert_block_columnar's collision check
        // (release ingest stays one shard lock per row; the arena
        // build's duplicate-id backstop still catches release-mode
        // collisions).
        debug_assert!(
            !self.segment_covers(id),
            "map insert at id {id} collides with a columnar segment"
        );
        let mut guard = self.shards[self.shard_of(id)].write_recover();
        // Drop the cached snapshot first (non-blocking; skipped if a
        // capture is mid-flight): it is stale the moment this insert
        // lands, and releasing its pin on the shard maps lets the
        // make_mut below mutate in place instead of cloning a map that
        // no reader is actually holding. Snapshots held by live readers
        // still pin their maps — that clone is the real COW cost.
        if let Ok(mut cache) = self.cached.try_write() {
            *cache = None;
        }
        // COW publish: if a live snapshot pinned this shard's map,
        // make_mut clones it at pointer level (payloads stay shared)
        // and later inserts mutate the fresh copy in place.
        Arc::make_mut(&mut guard).insert(id, Arc::new(sketch));
        self.epoch.fetch_add(1, Ordering::Release);
    }

    /// Insert a batch of per-row sketches with **one** epoch bump and
    /// one shard-lock acquisition per touched shard — the per-row
    /// ingest path used to bump the epoch once per row, invalidating
    /// the snapshot cache `rows` times per WAL batch and forcing every
    /// interleaved point read to re-capture. All touched shard locks
    /// are held together across the bump (ascending index, the same
    /// order [`SketchStore::snapshot`] acquires them), so readers never
    /// observe a torn batch: a capture sees either none of it or all of
    /// it, with an epoch to match.
    pub fn insert_rows(&self, batch: Vec<(u64, RowSketch)>) {
        if batch.is_empty() {
            return;
        }
        let mut by_shard: Vec<Vec<(u64, Arc<RowSketch>)>> =
            (0..self.shards.len()).map(|_| Vec::new()).collect();
        for (id, rs) in batch {
            debug_assert!(
                !self.segment_covers(id),
                "map insert at id {id} collides with a columnar segment"
            );
            by_shard[self.shard_of(id)].push((id, Arc::new(rs)));
        }
        // Same non-blocking cache purge as `insert` (cache → shards
        // lock order).
        if let Ok(mut cache) = self.cached.try_write() {
            *cache = None;
        }
        let mut guards: Vec<_> = self
            .shards
            .iter()
            .zip(&by_shard)
            .map(|(shard, rows)| (!rows.is_empty()).then(|| shard.write_recover()))
            .collect();
        for (guard, rows) in guards.iter_mut().zip(by_shard) {
            if let Some(guard) = guard {
                let map = Arc::make_mut(guard);
                for (id, rs) in rows {
                    map.insert(id, rs);
                }
            }
        }
        self.epoch.fetch_add(1, Ordering::Release);
    }

    /// Whether some columnar segment covers `id`.
    fn segment_covers(&self, id: u64) -> bool {
        seg_side(&self.segments.read_recover(), id).is_some()
    }

    /// Land a whole columnar ingest block covering ids
    /// `base .. base + block.rows()` — no per-row allocation, no
    /// transpose. See [`SketchStore::insert_block_shared`].
    pub fn insert_block_columnar(&self, base: u64, block: ColumnarBlock) {
        self.insert_block_shared(base, Arc::new(block));
    }

    /// Land an `Arc`-held columnar block — the zero-copy variant used
    /// by rebalance and snapshot replays, which share panels with the
    /// source store instead of copying them. Under a non-`None`
    /// [`SketchStore::panel_quant`] setting, f32 blocks are encoded
    /// here (once, off-lock) before publication; already-encoded blocks
    /// pass through verbatim, so replays and rebalances never re-lose
    /// precision. The zone summary is computed from the *stored*
    /// (possibly encoded) panels — decode is value-exact, so the zone
    /// bounds exactly what the serving kernels will see.
    pub fn insert_block_shared(&self, base: u64, block: Arc<ColumnarBlock>) {
        if block.rows() == 0 {
            return;
        }
        let q = self.panel_quant();
        let block = if q != PanelQuant::None && block.encoding() == PanelQuant::None {
            Arc::new(block.encoded_as(q))
        } else {
            block
        };
        let zone = Arc::new(ZoneMeta::from_block(&block));
        self.insert_block_prezoned(base, block, zone);
    }

    /// Land a columnar block with a zone computed elsewhere (persist v4
    /// load, recovered segment files) — trusted summaries skip the
    /// `from_block` pass. Panics if the id range overlaps an existing
    /// segment or a map row already present at insertion time (a silent
    /// duplicate would corrupt the arena build's contiguous landing);
    /// concurrent `insert`s into the range after this check remain the
    /// caller's responsibility, as with double `insert`s, and are
    /// caught by the arena duplicate-id backstop.
    pub fn insert_block_prezoned(&self, base: u64, block: Arc<ColumnarBlock>, zone: Arc<ZoneMeta>) {
        if block.rows() == 0 {
            return;
        }
        assert_eq!(zone.rows, block.rows(), "zone summarizes a different row count");
        let end = base + block.rows() as u64;
        // Map-collision check before taking the segment lock (the
        // shard→segment order every path uses); one lock acquisition
        // per shard, not per id.
        let shard_count = self.shards.len() as u64;
        for (s, shard) in self.shards.iter().enumerate() {
            let guard = shard.read_recover();
            for id in (base..end).filter(|id| id % shard_count == s as u64) {
                assert!(
                    !guard.contains_key(&id),
                    "columnar segment [{base}, {end}) collides with existing map row {id}"
                );
            }
        }
        let mut segs = self.segments.write_recover();
        let pos = segs.partition_point(|s| s.base < base);
        let disjoint = (pos == 0 || segs[pos - 1].end() <= base)
            && (pos == segs.len() || end <= segs[pos].base);
        assert!(disjoint, "columnar segment [{base}, {end}) overlaps an existing segment");
        segs.insert(pos, Segment { base, block, zone });
        self.epoch.fetch_add(1, Ordering::Release);
    }

    /// Capture an immutable snapshot: O(shards + segments) pointer
    /// clones under briefly-held read locks — no panel copies, no map
    /// copies. A quiescent store (epoch unchanged since the last
    /// capture) returns the cached `Arc` in O(1) without touching any
    /// shard lock, which is what makes point reads on an idle store
    /// effectively lock-free.
    pub fn snapshot(&self) -> Arc<StoreSnapshot> {
        let now = self.epoch.load(Ordering::Acquire);
        if let Some(s) = self.cached.read_recover().as_ref() {
            if s.epoch == now {
                return Arc::clone(s);
            }
        }
        // Double-checked: one capturer at a time holds the cache write
        // lock; rivals that queued behind it find the fresh snapshot on
        // re-check instead of each re-capturing the same epoch (the
        // thundering-herd case under concurrent point reads).
        let mut cache = self.cached.write_recover();
        let now = self.epoch.load(Ordering::Acquire);
        if let Some(s) = cache.as_ref() {
            if s.epoch == now {
                return Arc::clone(s);
            }
        }
        let snap = {
            // Hold every shard's read lock + the segment lock together
            // for a consistent cut (writers bump the epoch inside their
            // critical sections, so the epoch read here matches the
            // content exactly). Lock order cache → shards → segments;
            // writers take shard/segment locks without the cache lock
            // (insert's cache purge is a non-blocking try_write), so no
            // cycle exists.
            let guards: Vec<_> = self.shards.iter().map(|s| s.read_recover()).collect();
            let segs = self.segments.read_recover();
            Arc::new(StoreSnapshot {
                epoch: self.epoch.load(Ordering::Acquire),
                map: guards.iter().map(|g| Arc::clone(g)).collect(),
                segments: segs.clone(),
            })
        };
        *cache = Some(Arc::clone(&snap));
        snap
    }

    pub fn get(&self, id: u64) -> Option<RowSketch> {
        self.snapshot().get(id)
    }

    /// Visit a pair without cloning when both rows live in the hashmap
    /// shards (the query hot path); rows held in columnar segments are
    /// materialized on demand. Served from a snapshot — consistent and
    /// lock-free on a quiescent store.
    pub fn with_pair<T>(
        &self,
        a: u64,
        b: u64,
        f: impl FnOnce(&RowSketch, &RowSketch) -> T,
    ) -> Option<T> {
        self.snapshot().with_pair(a, b, f)
    }

    /// Plain pair estimate from the current snapshot — see
    /// [`StoreSnapshot::estimate_pair_plain`].
    pub fn estimate_pair_plain(&self, dec: &Decomposition, a: u64, b: u64) -> Option<f64> {
        self.snapshot().estimate_pair_plain(dec, a, b)
    }

    pub fn len(&self) -> usize {
        self.snapshot().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn contains(&self, id: u64) -> bool {
        self.snapshot().contains(id)
    }

    /// Total sketch payload bytes (the paper's O(nk) storage number).
    /// One consistent snapshot — a concurrent insert can no longer be
    /// double-counted or missed mid-iteration.
    pub fn bytes(&self) -> usize {
        self.snapshot().bytes()
    }

    /// Columnar snapshot of the whole store, copied off-lock from an
    /// epoch snapshot — see [`StoreSnapshot::arena`].
    pub fn arena_snapshot(&self, p: usize, k: usize) -> ArenaSnapshot {
        self.snapshot().arena(p, k)
    }

    /// Number of columnar segments currently held (the
    /// `segment_count` metric; small `block_rows` without compaction
    /// makes this grow linearly with ingest).
    pub fn segment_count(&self) -> usize {
        self.segments.read_recover().len()
    }

    /// Merge runs of small *adjacent* segments across the whole id
    /// space — [`SketchStore::compact_range`] with an unbounded range.
    pub fn compact_segments(&self, min_rows: usize, target_rows: usize) -> CompactionReport {
        self.compact_range(min_rows, target_rows, 0, u64::MAX)
    }

    /// Copy-on-write compaction over segments fully inside
    /// `[lo, hi)`: merge runs of small *adjacent* segments (contiguous
    /// id ranges) into larger arena-layout blocks via
    /// [`ColumnarBlock::concat`] — one contiguous copy per
    /// (order, side) per input segment, so the merged panels are
    /// bitwise-identical to the originals and every estimate is
    /// unchanged.
    ///
    /// The pass plans its merge groups from a snapshot of the
    /// directory, builds every merged block **off-lock** (readers and
    /// writers proceed freely), then swaps the groups in under one
    /// brief write lock. Old snapshots keep serving the pre-merge
    /// blocks. Concurrent compactions are serialized by an internal
    /// mutex; concurrent ingest can only append disjoint segments,
    /// which never invalidates a planned run.
    ///
    /// Policy: a segment is *small* when it has fewer than `min_rows`
    /// rows; an adjacent segment joins the current merge group while
    /// the group or the candidate is small and the merged size stays at
    /// or under `target_rows`. `min_rows == 0` disables compaction
    /// (nothing is small). Non-adjacent segments (id gaps) never merge
    /// — the segment invariant is that covered ranges are exactly the
    /// ingested blocks' ranges, with gaps preserved. Segments
    /// straddling the range boundary act as barriers and are left
    /// untouched, which is what makes the post-ingest hook incremental:
    /// it passes the ingest's own id range and never re-touches older
    /// segments.
    pub fn compact_range(
        &self,
        min_rows: usize,
        target_rows: usize,
        lo: u64,
        hi: u64,
    ) -> CompactionReport {
        let _serial = self.compaction.lock_recover();
        // Plan from a directory snapshot (Arc handles, no panel copies).
        let plan: Vec<Segment> = self.segments.read_recover().clone();
        let before = plan.len();
        let mut groups: Vec<Vec<Segment>> = Vec::new();
        let mut group: Vec<Segment> = Vec::new();
        let flush = |group: &mut Vec<Segment>, groups: &mut Vec<Vec<Segment>>| {
            if group.len() >= 2 {
                groups.push(std::mem::take(group));
            } else {
                group.clear();
            }
        };
        for seg in plan {
            if seg.base < lo || seg.end() > hi {
                // Out-of-range segment: a barrier, never a member.
                flush(&mut group, &mut groups);
                continue;
            }
            let group_rows: usize = group.iter().map(|s| s.block.rows()).sum();
            let adjacent = group.last().is_some_and(|g| g.end() == seg.base);
            let joinable = adjacent
                && (seg.block.rows() < min_rows || group_rows < min_rows)
                && group_rows + seg.block.rows() <= target_rows;
            if !joinable {
                flush(&mut group, &mut groups);
            }
            group.push(seg);
        }
        flush(&mut group, &mut groups);
        // Build merged blocks entirely off-lock.
        let mut merges = 0usize;
        let mut rows_merged = 0usize;
        let merged: Vec<(Vec<u64>, Segment)> = groups
            .iter()
            .map(|g| {
                let blocks: Vec<&ColumnarBlock> =
                    g.iter().map(|s| s.block.as_ref()).collect();
                let block = ColumnarBlock::concat(&blocks);
                // Elementwise zone merge — bitwise-identical to
                // ZoneMeta::from_block over the concatenated panels,
                // without rescanning a single row.
                let zones: Vec<&ZoneMeta> = g.iter().map(|s| s.zone.as_ref()).collect();
                let zone = Arc::new(ZoneMeta::merge(&zones));
                merges += 1;
                rows_merged += block.rows();
                let bases = g.iter().map(|s| s.base).collect();
                (bases, Segment { base: g[0].base, block: Arc::new(block), zone })
            })
            .collect();
        // Swap each run atomically. Planned runs are still intact:
        // compaction is serialized, and ingest can only add segments
        // outside a run's contiguous id range.
        let after = {
            let mut segs = self.segments.write_recover();
            for (bases, seg) in merged {
                let pos = segs.partition_point(|s| s.base < seg.base);
                for (i, &base) in bases.iter().enumerate() {
                    assert!(
                        segs.get(pos + i).is_some_and(|s| s.base == base),
                        "compaction plan invalidated at segment base {base}"
                    );
                }
                segs.splice(pos..pos + bases.len(), std::iter::once(seg));
            }
            if merges > 0 {
                self.epoch.fetch_add(1, Ordering::Release);
            }
            segs.len()
        };
        CompactionReport { merges, rows_merged, segments_before: before, segments_after: after }
    }

    /// Run `f` on an owned [`SegmentPanels`] view captured from a
    /// snapshot when the store is *fully columnar* — see
    /// [`StoreSnapshot::columnar_panels`]. No lock is held while `f`
    /// runs: a long kernel (an all-pairs scan) no longer blocks ingest
    /// or compaction, it just serves the epoch it captured.
    pub fn with_columnar_view<R>(
        &self,
        p: usize,
        f: impl FnOnce(Option<&SegmentPanels>) -> R,
    ) -> R {
        let snap = self.snapshot();
        f(snap.columnar_panels(p).as_ref())
    }

    /// The pre-snapshot behavior, kept as the measurable baseline for
    /// `benches/hotpath.rs`' concurrent-serving arm: shard + segment
    /// read locks are pinned for the *whole* of `f`, so writers queue
    /// behind the scan. Not used by any serving path.
    pub fn with_columnar_view_locked<R>(
        &self,
        p: usize,
        f: impl FnOnce(Option<&SegmentPanels>) -> R,
    ) -> R {
        let guards: Vec<_> = self.shards.iter().map(|s| s.read_recover()).collect();
        let segs = self.segments.read_recover();
        if segs.is_empty() || guards.iter().any(|g| !g.is_empty()) {
            return f(None);
        }
        let mut parts = Vec::with_capacity(segs.len());
        let mut off = 0usize;
        for s in segs.iter() {
            parts.push((off, s.base, s.block.clone(), s.zone.clone()));
            off += s.block.rows();
        }
        let view = SegmentPanels { p, k: segs[0].block.k(), n: off, parts };
        f(Some(&view))
    }

    /// `(base, block)` handles of every columnar segment, base
    /// ascending — `Arc` clones, no panel copies. Rebalance carries
    /// these over verbatim: segments are shard-independent, so
    /// re-sharding shares panels instead of copying them.
    pub fn segments_snapshot(&self) -> Vec<(u64, Arc<ColumnarBlock>)> {
        self.snapshot().segments().iter().map(|s| (s.base, Arc::clone(&s.block))).collect()
    }

    /// Like [`SketchStore::segments_snapshot`], with each segment's zone
    /// summary — persistence rides zones alongside the panels so a
    /// restored store prunes immediately, without recomputation.
    pub fn segments_snapshot_zoned(&self) -> Vec<(u64, Arc<ColumnarBlock>, Arc<ZoneMeta>)> {
        self.snapshot()
            .segments()
            .iter()
            .map(|s| (s.base, Arc::clone(&s.block), Arc::clone(&s.zone)))
            .collect()
    }

    /// Ids held in the hashmap shards only (segment-backed ids
    /// excluded), ascending. One consistent snapshot.
    pub fn map_ids(&self) -> Vec<u64> {
        self.snapshot().map_ids()
    }

    /// All row ids, ascending. One consistent snapshot.
    pub fn ids(&self) -> Vec<u64> {
        self.snapshot().ids()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::projection::sketcher::Sketcher;
    use crate::projection::{ProjectionDist, ProjectionSpec, Strategy};

    fn sketch_of(val: f32) -> RowSketch {
        let sk = Sketcher::new(
            ProjectionSpec::new(1, 4, ProjectionDist::Normal, Strategy::Basic),
            4,
        );
        sk.sketch_row(&[val, val * 2.0, val * 3.0])
    }

    #[test]
    fn insert_get_roundtrip() {
        let store = SketchStore::new(4);
        store.insert(10, sketch_of(1.0));
        assert!(store.contains(10));
        assert!(!store.contains(11));
        let got = store.get(10).unwrap();
        assert_eq!(got.moments.get(1), sketch_of(1.0).moments.get(1));
    }

    #[test]
    fn with_pair_same_and_cross_shard() {
        let store = SketchStore::new(2);
        store.insert(0, sketch_of(1.0)); // shard 0
        store.insert(2, sketch_of(2.0)); // shard 0
        store.insert(1, sketch_of(3.0)); // shard 1
        // Same shard.
        let m = store.with_pair(0, 2, |a, b| (a.moments.get(1), b.moments.get(1))).unwrap();
        assert!(m.0 < m.1);
        // Cross shard, both orders.
        assert!(store.with_pair(0, 1, |_, _| ()).is_some());
        assert!(store.with_pair(1, 0, |_, _| ()).is_some());
        // Missing row.
        assert!(store.with_pair(0, 99, |_, _| ()).is_none());
    }

    #[test]
    fn concurrent_writers_land_once() {
        let store = std::sync::Arc::new(SketchStore::new(4));
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let store = store.clone();
                s.spawn(move || {
                    for i in 0..50u64 {
                        store.insert(t * 50 + i, sketch_of(i as f32));
                    }
                });
            }
        });
        assert_eq!(store.len(), 200);
        assert_eq!(store.ids().len(), 200);
        assert_eq!(store.ids()[0], 0);
        assert_eq!(*store.ids().last().unwrap(), 199);
    }

    #[test]
    fn arena_snapshot_mirrors_rows() {
        let store = SketchStore::new(3);
        for i in 0..7u64 {
            store.insert(i * 2, sketch_of(i as f32 + 1.0)); // non-dense ids
        }
        let snap = store.arena_snapshot(4, 4);
        assert_eq!(snap.ids, (0..7).map(|i| i * 2).collect::<Vec<u64>>());
        assert_eq!(snap.arena.n(), 7);
        for (pos, &id) in snap.ids.iter().enumerate() {
            assert_eq!(snap.pos[&id], pos);
            let rs = store.get(id).unwrap();
            for m in 1..4 {
                assert_eq!(snap.arena.u_row(m, pos), rs.uside.u(m), "id {id} m {m}");
            }
            assert_eq!(snap.arena.norm_p(pos), rs.moments.get(4));
        }
        // Empty store: well-shaped empty arena.
        let empty = SketchStore::new(2);
        let snap = empty.arena_snapshot(4, 4);
        assert!(snap.ids.is_empty());
        assert!(snap.pos.is_empty());
        assert_eq!(snap.arena.n(), 0);
    }

    fn block_of(n: usize) -> crate::projection::sketcher::ColumnarBlock {
        let sk = Sketcher::new(
            ProjectionSpec::new(1, 4, ProjectionDist::Normal, Strategy::Basic),
            4,
        );
        let rows: Vec<Vec<f32>> = (0..n)
            .map(|i| (0..12).map(|t| ((i * 7 + t) as f32 * 0.31).sin()).collect())
            .collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        sk.sketch_block(&refs, 1)
    }

    #[test]
    fn columnar_segments_roundtrip() {
        let store = SketchStore::new(3);
        let block = block_of(6);
        store.insert_block_columnar(10, block.clone());
        store.insert(3, sketch_of(1.0));
        assert_eq!(store.len(), 7);
        assert!(store.contains(3) && store.contains(10) && store.contains(15));
        assert!(!store.contains(9) && !store.contains(16));
        assert_eq!(store.ids(), vec![3, 10, 11, 12, 13, 14, 15]);
        // Per-row reads materialize segment rows.
        let rs = store.get(12).unwrap();
        assert_eq!(rs.uside.u(1), block.u_row(1, 2));
        assert_eq!(rs.moments.0.as_slice(), block.moments_row(2));
        // Pair visits across map and segment rows.
        assert!(store.with_pair(3, 12, |a, b| (a.moments.get(4), b.moments.get(4))).is_some());
        assert!(store.with_pair(12, 14, |_, _| ()).is_some());
        assert!(store.with_pair(12, 99, |_, _| ()).is_none());
        // Storage accounting covers both representations.
        assert_eq!(store.bytes(), sketch_of(1.0).sketch_bytes() + block.bytes());
    }

    #[test]
    fn segment_snapshot_lands_blocks_contiguously() {
        let store = SketchStore::new(2);
        store.insert(0, sketch_of(1.0));
        store.insert_block_columnar(5, block_of(4)); // ids 5..9
        store.insert(20, sketch_of(2.0));
        store.insert_block_columnar(9, block_of(2)); // ids 9..11, adjacent
        let snap = store.arena_snapshot(4, 4);
        assert_eq!(snap.ids, vec![0, 5, 6, 7, 8, 9, 10, 20]);
        assert_eq!(snap.arena.n(), 8);
        for (pos, &id) in snap.ids.iter().enumerate() {
            assert_eq!(snap.pos[&id], pos);
            let rs = store.get(id).unwrap();
            for m in 1..4 {
                assert_eq!(snap.arena.u_row(m, pos), rs.uside.u(m), "id {id} m {m}");
            }
            assert_eq!(snap.arena.norm_p(pos), rs.moments.get(4));
        }
    }

    #[test]
    fn estimate_pair_plain_matches_materialized_estimate() {
        use crate::core::decompose::Decomposition;
        use crate::core::estimator;
        let dec = Decomposition::new(4).unwrap();
        let store = SketchStore::new(3);
        store.insert(1, sketch_of(1.5));
        store.insert(2, sketch_of(-0.75));
        store.insert_block_columnar(10, block_of(4)); // ids 10..14
        // map×map, map×segment, segment×segment — all bitwise equal to
        // the per-row estimator on materialized rows.
        for (a, b) in [(1u64, 2u64), (1, 12), (12, 1), (10, 13)] {
            let want = {
                let (ra, rb) = (store.get(a).unwrap(), store.get(b).unwrap());
                estimator::estimate(&dec, &ra, &rb)
            };
            let got = store.estimate_pair_plain(&dec, a, b).unwrap();
            assert_eq!(got, want, "pair ({a},{b})");
        }
        assert!(store.estimate_pair_plain(&dec, 1, 99).is_none());
        assert!(store.estimate_pair_plain(&dec, 99, 1).is_none());
    }

    #[test]
    #[should_panic(expected = "overlaps an existing segment")]
    fn overlapping_segments_rejected() {
        let store = SketchStore::new(1);
        store.insert_block_columnar(10, block_of(4));
        store.insert_block_columnar(12, block_of(4));
    }

    #[test]
    #[should_panic(expected = "collides with existing map row")]
    fn segment_colliding_with_map_row_rejected() {
        let store = SketchStore::new(2);
        store.insert(12, sketch_of(1.0));
        store.insert_block_columnar(10, block_of(6));
    }

    #[test]
    fn empty_block_is_a_noop() {
        let store = SketchStore::new(1);
        store.insert_block_columnar(10, block_of(0));
        assert!(store.is_empty());
        assert!(store.ids().is_empty());
    }

    #[test]
    fn bytes_accounts_all_rows() {
        let store = SketchStore::new(3);
        let one = sketch_of(1.0).sketch_bytes();
        for i in 0..7 {
            store.insert(i, sketch_of(i as f32));
        }
        assert_eq!(store.bytes(), 7 * one);
    }

    #[test]
    fn compaction_merges_adjacent_small_segments() {
        let store = SketchStore::new(2);
        store.insert_block_columnar(10, block_of(4)); // 10..14
        store.insert_block_columnar(14, block_of(2)); // 14..16, adjacent
        store.insert_block_columnar(16, block_of(3)); // 16..19, adjacent
        store.insert_block_columnar(40, block_of(2)); // gapped: never merges
        assert_eq!(store.segment_count(), 4);
        let ids = store.ids();
        let bytes = store.bytes();
        let report = store.compact_segments(8, 100);
        assert_eq!(report.segments_before, 4);
        assert_eq!(report.segments_after, 2);
        assert_eq!(report.merges, 1);
        assert_eq!(report.rows_merged, 9);
        assert_eq!(store.segment_count(), 2);
        // Content unchanged: same ids, same bytes, same row payloads.
        assert_eq!(store.ids(), ids);
        assert_eq!(store.bytes(), bytes);
        let snap = store.segments_snapshot();
        assert_eq!(snap[0].0, 10);
        assert_eq!(snap[0].1.rows(), 9);
        assert_eq!(snap[1].0, 40);
    }

    #[test]
    fn compaction_respects_target_rows_and_zero_min() {
        let store = SketchStore::new(1);
        for i in 0..6u64 {
            store.insert_block_columnar(i * 3, block_of(3)); // 0..18, adjacent
        }
        // min 0 disables the pass entirely.
        let report = store.compact_segments(0, 100);
        assert_eq!(report.merges, 0);
        assert_eq!(store.segment_count(), 6);
        // Target caps merged size: 3-row segments pack to ≤ 7 rows
        // (two per group), leaving 3 merged pairs.
        let report = store.compact_segments(100, 7);
        assert_eq!(report.merges, 3);
        assert_eq!(store.segment_count(), 3);
        assert_eq!(
            store.segments_snapshot().iter().map(|(b, blk)| (*b, blk.rows())).collect::<Vec<_>>(),
            vec![(0, 6), (6, 6), (12, 6)]
        );
        // Idempotent once nothing is small enough to join.
        let report = store.compact_segments(4, 7);
        assert_eq!(report.merges, 0);
    }

    #[test]
    fn compact_range_only_touches_the_given_id_window() {
        let store = SketchStore::new(1);
        for i in 0..6u64 {
            store.insert_block_columnar(i * 3, block_of(3)); // 0..18, adjacent
        }
        // Only segments fully inside [6, 15) merge: bases 6, 9, 12.
        let report = store.compact_range(100, 1024, 6, 15);
        assert_eq!(report.merges, 1);
        assert_eq!(report.rows_merged, 9);
        assert_eq!(store.segment_count(), 4);
        assert_eq!(
            store.segments_snapshot().iter().map(|(b, blk)| (*b, blk.rows())).collect::<Vec<_>>(),
            vec![(0, 3), (3, 3), (6, 9), (15, 3)]
        );
        // A window covering nothing fully is a no-op.
        let report = store.compact_range(100, 1024, 1, 5);
        assert_eq!(report.merges, 0);
    }

    #[test]
    fn compaction_is_estimate_invariant_bitwise() {
        use crate::core::decompose::Decomposition;
        let dec = Decomposition::new(4).unwrap();
        let store = SketchStore::new(3);
        store.insert(2, sketch_of(0.5));
        store.insert_block_columnar(10, block_of(5)); // 10..15
        store.insert_block_columnar(15, block_of(4)); // 15..19
        let pairs = [(2u64, 11u64), (10, 18), (14, 15), (11, 11)];
        let before: Vec<f64> =
            pairs.iter().map(|&(a, b)| store.estimate_pair_plain(&dec, a, b).unwrap()).collect();
        let report = store.compact_segments(64, 1024);
        assert_eq!(report.merges, 1);
        let after: Vec<f64> =
            pairs.iter().map(|&(a, b)| store.estimate_pair_plain(&dec, a, b).unwrap()).collect();
        assert_eq!(before, after);
    }

    #[test]
    fn columnar_view_engages_only_when_fully_columnar() {
        let store = SketchStore::new(2);
        // Empty store: no view.
        assert!(store.with_columnar_view(4, |v| v.is_none()));
        store.insert_block_columnar(10, block_of(4));
        assert!(store.with_columnar_view(4, |v| v.is_some()));
        // One map row degrades to the snapshot path.
        store.insert(0, sketch_of(1.0));
        assert!(store.with_columnar_view(4, |v| v.is_none()));
        // The legacy locked baseline agrees on engagement.
        assert!(store.with_columnar_view_locked(4, |v| v.is_none()));
    }

    #[test]
    fn columnar_view_mirrors_arena_snapshot() {
        let store = SketchStore::new(2);
        store.insert_block_columnar(10, block_of(4)); // 10..14
        store.insert_block_columnar(20, block_of(3)); // 20..23 (gap)
        let snap = store.arena_snapshot(4, 4);
        store.with_columnar_view(4, |view| {
            let v = view.expect("fully columnar");
            assert_eq!(v.n(), 7);
            assert_eq!(v.k(), 4);
            assert_eq!(v.p(), 4);
            for i in 0..7 {
                assert_eq!(v.id_at(i), snap.ids[i]);
                assert_eq!(v.pos_of(snap.ids[i]), Some(i));
                for m in 1..4 {
                    assert_eq!(
                        v.u_row(m, i).as_f32(),
                        Some(snap.arena.u_row(m, i)),
                        "m={m} i={i}"
                    );
                    assert_eq!(
                        v.v_row(m, i).as_f32(),
                        Some(snap.arena.v_row(m, i)),
                        "m={m} i={i}"
                    );
                }
                assert_eq!(v.norm_p(i), snap.arena.norm_p(i));
            }
            // Ids outside any segment resolve to None.
            for missing in [0u64, 9, 14, 19, 23, 99] {
                assert_eq!(v.pos_of(missing), None, "id {missing}");
            }
        });
    }

    // ---- epoch snapshots ------------------------------------------------

    #[test]
    fn snapshot_shares_segment_panels_by_pointer() {
        // The O(segments) acceptance: a snapshot's segment panels are
        // the very Arc allocations the store holds — capture copies
        // handles, never panels.
        let store = SketchStore::new(2);
        store.insert_block_columnar(10, block_of(4));
        store.insert_block_columnar(30, block_of(3));
        let snap = store.snapshot();
        let direct = store.segments_snapshot();
        assert_eq!(snap.segments().len(), 2);
        for (s, (base, block)) in snap.segments().iter().zip(&direct) {
            assert_eq!(s.base, *base);
            assert!(Arc::ptr_eq(&s.block, block), "segment at {base} was copied, not shared");
        }
        // The owned panels view shares the same allocations too —
        // zones included.
        let panels = snap.columnar_panels(4).expect("fully columnar");
        assert_eq!(panels.n(), 7);
        for (i, (_, base, block, zone)) in panels.parts.iter().enumerate() {
            assert_eq!(*base, snap.segments()[i].base);
            assert!(Arc::ptr_eq(block, &snap.segments()[i].block));
            assert!(Arc::ptr_eq(zone, &snap.segments()[i].zone));
        }
    }

    // ---- zone maps ------------------------------------------------------

    #[test]
    fn inserted_segments_carry_their_block_zone() {
        use crate::core::zone::ZoneMeta;
        let store = SketchStore::new(2);
        let block = block_of(5);
        store.insert_block_columnar(10, block.clone());
        let segs = store.segments_snapshot_zoned();
        assert_eq!(segs.len(), 1);
        assert_eq!(*segs[0].2, ZoneMeta::from_block(&block));
        // Prezoned insertion adopts the supplied summary verbatim.
        let store2 = SketchStore::new(2);
        let mut custom = ZoneMeta::from_block(&block);
        custom.min_moment[0] -= 1.0; // deflated: still admissible
        store2.insert_block_prezoned(10, Arc::new(block), Arc::new(custom.clone()));
        let segs2 = store2.segments_snapshot_zoned();
        assert_eq!(*segs2[0].2, custom);
    }

    #[test]
    #[should_panic(expected = "zone summarizes a different row count")]
    fn prezoned_insert_rejects_row_count_mismatch() {
        use crate::core::zone::ZoneMeta;
        let store = SketchStore::new(1);
        let mut zone = ZoneMeta::from_block(&block_of(4));
        zone.rows = 3;
        store.insert_block_prezoned(10, Arc::new(block_of(4)), Arc::new(zone));
    }

    #[test]
    fn compaction_merges_zones_bitwise_equal_to_recomputation() {
        use crate::core::zone::ZoneMeta;
        let store = SketchStore::new(1);
        store.insert_block_columnar(10, block_of(4)); // 10..14
        store.insert_block_columnar(14, block_of(2)); // 14..16
        store.insert_block_columnar(16, block_of(3)); // 16..19
        let report = store.compact_segments(16, 1024);
        assert_eq!(report.merges, 1);
        let segs = store.segments_snapshot_zoned();
        assert_eq!(segs.len(), 1);
        assert_eq!(*segs[0].2, ZoneMeta::from_block(&segs[0].1));
    }

    #[test]
    fn panels_extents_tile_the_view_with_segment_zones() {
        let store = SketchStore::new(2);
        store.insert_block_columnar(10, block_of(4));
        store.insert_block_columnar(30, block_of(3));
        let snap = store.snapshot();
        let panels = snap.columnar_panels(4).expect("fully columnar");
        let extents = panels.extents();
        assert_eq!(extents.len(), 2);
        assert_eq!((extents[0].off, extents[0].rows), (0, 4));
        assert_eq!((extents[1].off, extents[1].rows), (4, 3));
        for (ext, seg) in extents.iter().zip(snap.segments()) {
            assert_eq!(ext.zone.expect("segment extents are zoned"), seg.zone.as_ref());
        }
    }

    #[test]
    fn quiescent_snapshots_hit_the_cache_and_writes_invalidate_it() {
        let store = SketchStore::new(2);
        store.insert_block_columnar(10, block_of(4));
        let a = store.snapshot();
        let b = store.snapshot();
        assert!(Arc::ptr_eq(&a, &b), "quiescent capture must reuse the cached snapshot");
        assert_eq!(a.epoch(), store.epoch());
        store.insert(0, sketch_of(1.0));
        let c = store.snapshot();
        assert!(!Arc::ptr_eq(&a, &c), "a write must invalidate the cached snapshot");
        assert!(c.epoch() > a.epoch());
        // The old snapshot still serves its frozen view.
        assert_eq!(a.len(), 4);
        assert!(!a.contains(0));
        assert_eq!(c.len(), 5);
        assert!(c.contains(0));
    }

    #[test]
    fn old_snapshots_survive_cow_compaction_and_score_identically() {
        use crate::core::decompose::Decomposition;
        let dec = Decomposition::new(4).unwrap();
        let store = SketchStore::new(2);
        store.insert_block_columnar(10, block_of(5)); // 10..15
        store.insert_block_columnar(15, block_of(4)); // 15..19
        let before = store.snapshot();
        let report = store.compact_segments(64, 1024);
        assert_eq!(report.merges, 1);
        let after = store.snapshot();
        // Directory swapped: old snapshot pins the pre-merge blocks.
        assert_eq!(before.segment_count(), 2);
        assert_eq!(after.segment_count(), 1);
        assert!(!Arc::ptr_eq(&before.segments()[0].block, &after.segments()[0].block));
        // Both cuts score every pair bitwise-identically.
        for (a, b) in [(10u64, 18u64), (11, 15), (14, 14)] {
            assert_eq!(
                before.estimate_pair_plain(&dec, a, b),
                after.estimate_pair_plain(&dec, a, b),
                "pair ({a},{b})"
            );
        }
        assert_eq!(before.ids(), after.ids());
        assert_eq!(before.bytes(), after.bytes());
    }

    #[test]
    fn snapshot_map_rows_are_cow_isolated_from_later_inserts() {
        let store = SketchStore::new(2);
        store.insert(0, sketch_of(1.0));
        store.insert(1, sketch_of(2.0));
        let snap = store.snapshot();
        store.insert(2, sketch_of(3.0));
        store.insert(3, sketch_of(4.0));
        assert_eq!(snap.ids(), vec![0, 1]);
        assert_eq!(store.ids(), vec![0, 1, 2, 3]);
        // Payloads are shared, not copied: the snapshot's row is the
        // same Arc the store still holds.
        let in_snap = snap.map[0].get(&0).unwrap();
        let in_store = store.snapshot().map[0].get(&0).unwrap().clone();
        assert!(Arc::ptr_eq(in_snap, &in_store));
    }

    #[test]
    fn insert_block_shared_shares_panels() {
        let store = SketchStore::new(2);
        let block = Arc::new(block_of(4));
        store.insert_block_shared(10, Arc::clone(&block));
        let held = store.segments_snapshot();
        assert!(Arc::ptr_eq(&held[0].1, &block));
    }

    #[test]
    fn insert_rows_bumps_epoch_once_per_batch() {
        let store = SketchStore::new(4);
        let e0 = store.epoch();
        store.insert_rows((0..10u64).map(|i| (i, sketch_of(i as f32 + 1.0))).collect());
        assert_eq!(store.epoch(), e0 + 1, "one batch, one epoch bump");
        assert_eq!(store.len(), 10);
        for i in 0..10u64 {
            assert!(store.contains(i));
        }
        // Snapshot cache stays hot between batches (the point of
        // batching: point reads interleaved with block ingest reuse the
        // cached capture instead of re-walking every shard per row).
        let a = store.snapshot();
        let b = store.snapshot();
        assert!(Arc::ptr_eq(&a, &b), "quiescent captures must share the cached snapshot");
        store.insert_rows(vec![(100, sketch_of(0.5)), (101, sketch_of(0.25))]);
        let c = store.snapshot();
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(c.epoch(), e0 + 2);
        assert!(Arc::ptr_eq(&c, &store.snapshot()), "cache hot again after the batch");
        // Empty batches are complete no-ops.
        store.insert_rows(Vec::new());
        assert_eq!(store.epoch(), e0 + 2);
        // Batched rows read back identically to per-row inserts.
        let per_row = SketchStore::new(4);
        for i in 0..10u64 {
            per_row.insert(i, sketch_of(i as f32 + 1.0));
        }
        assert_eq!(per_row.epoch(), 10, "per-row path: one bump per row");
        for i in 0..10u64 {
            let (x, y) = (store.get(i).unwrap(), per_row.get(i).unwrap());
            assert_eq!(x.uside.data, y.uside.data);
            assert_eq!(x.moments.0, y.moments.0);
        }
    }

    #[test]
    fn panel_quant_setting_encodes_at_the_store_boundary() {
        use crate::core::decompose::Decomposition;
        use crate::core::estimator;
        use crate::core::quant::PanelQuant;
        use crate::core::zone::ZoneMeta;
        let block = block_of(5);
        let f32_bytes = block.bytes();

        let store = SketchStore::new(2);
        store.set_panel_quant(PanelQuant::I8);
        assert_eq!(store.panel_quant(), PanelQuant::I8);
        store.insert_block_columnar(10, block.clone());
        let segs = store.segments_snapshot_zoned();
        assert_eq!(segs[0].1.encoding(), PanelQuant::I8);
        assert!(segs[0].1.bytes() < f32_bytes, "quantized segment must shrink");
        // The zone summarizes the *stored* (encoded) panels, so it
        // bounds exactly what serving decodes.
        assert_eq!(*segs[0].2, ZoneMeta::from_block(&segs[0].1));
        // Row materialization decodes the stored values exactly…
        let rs = store.get(12).unwrap();
        for m in 1..4 {
            for j in 0..4 {
                assert_eq!(rs.uside.u(m)[j], segs[0].1.u_view(m, 2).get(j));
            }
        }
        // …so panel-served pair estimates are bitwise equal to the
        // per-row reference estimator on materialized rows.
        let dec = Decomposition::new(4).unwrap();
        let want = {
            let (ra, rb) = (store.get(11).unwrap(), store.get(13).unwrap());
            estimator::estimate(&dec, &ra, &rb)
        };
        assert_eq!(store.estimate_pair_plain(&dec, 11, 13).unwrap(), want);

        // Prezoned insertion (recovery, rebalance) adopts blocks
        // verbatim — never re-encodes, whatever the setting says.
        let store2 = SketchStore::new(2);
        store2.set_panel_quant(PanelQuant::F16);
        let zone = Arc::new(ZoneMeta::from_block(&block));
        store2.insert_block_prezoned(10, Arc::new(block.clone()), zone);
        assert_eq!(store2.segments_snapshot()[0].1.encoding(), PanelQuant::None);

        // Already-encoded blocks pass through insert_block_shared
        // untouched (no double quantization, panels still shared).
        let store3 = SketchStore::new(2);
        store3.set_panel_quant(PanelQuant::F16);
        let pre = Arc::new(block.encoded_as(PanelQuant::I8));
        store3.insert_block_shared(10, Arc::clone(&pre));
        assert!(Arc::ptr_eq(&store3.segments_snapshot()[0].1, &pre));
    }
}
