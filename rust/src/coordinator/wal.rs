//! The write-ahead log: length-prefixed, CRC32-checksummed records of
//! acknowledged ingest, in the persist-v2 corruption discipline (caps
//! and bytes-present validated before any allocation; errors, never
//! panics).
//!
//! ## File format (little-endian)
//!
//! | field   | type          | notes                                |
//! |---------|---------------|--------------------------------------|
//! | magic   | `b"LPWL"`     |                                      |
//! | version | `u32` = 1     |                                      |
//! | records | …             | until EOF                            |
//!
//! Each record:
//!
//! | field   | type          | notes                                |
//! |---------|---------------|--------------------------------------|
//! | len     | `u32`         | payload bytes, `1..=MAX_RECORD_LEN`  |
//! | crc     | `u32`         | CRC32 of the payload                 |
//! | payload | `u8[len]`     | kind byte + body                     |
//!
//! Payload kinds (shape comes from `store.meta`, never the record):
//!
//! * kind 1 — map row: `id u64`, u panel `f32[orders·k]`, v panel
//!   (two-sided only), moments `f64[moment_orders]`.
//! * kind 2 — columnar batch: `base u64`, `rows u64`, then the
//!   segment-panel layout of [`super::persist`]: per-order u panels,
//!   per-order v panels (two-sided only), row-major moments.
//!
//! ## Tail discipline
//!
//! A crash can leave the final record torn: short header, short
//! payload, a zero-extended suffix (metadata landed, data blocks did
//! not), or a present-but-checksum-failing final record. All of these
//! stop the scan at the last valid record — a torn record was never
//! fsynced, so it was never acknowledged. Anything wrong *before* the
//! final record (checksum mismatch, zero length mid-file, implausible
//! length, CRC-valid garbage) is mid-log corruption: a hard error,
//! because silently skipping it could drop acknowledged data.

// Serving path: clippy backs the pallas-lint serving-no-panic rule.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::path::Path;

use anyhow::Context;

use crate::core::marginals::Moments;
use crate::projection::sketcher::{ColumnarBlock, RowSketch, SketchSet};

use super::durable::{crc32, put_f32s, put_f64s, put_u32, put_u64, ByteReader, DurableFs, MetaShape};

pub(crate) const WAL_MAGIC: &[u8; 4] = b"LPWL";
pub(crate) const WAL_VERSION: u32 = 1;

/// Cap on one record's payload — a corrupt length field must error,
/// not drive a gigabyte allocation.
pub(crate) const MAX_RECORD_LEN: u32 = 1 << 30;
/// Cap on a batch record's declared row count.
pub(crate) const MAX_BATCH_ROWS: u64 = 1 << 24;

const KIND_ROW: u8 = 1;
const KIND_BATCH: u8 = 2;

/// The 8-byte file header every WAL file starts with.
pub(crate) fn file_header() -> [u8; 8] {
    let mut h = [0u8; 8];
    h[..4].copy_from_slice(WAL_MAGIC);
    h[4..].copy_from_slice(&WAL_VERSION.to_le_bytes());
    h
}

/// `wal-<seq:016x>.wal` → seq.
pub(crate) fn parse_wal_name(name: &str) -> Option<u64> {
    let hex = name.strip_prefix("wal-")?.strip_suffix(".wal")?;
    if hex.len() != 16 {
        return None;
    }
    u64::from_str_radix(hex, 16).ok()
}

/// One decoded record.
pub(crate) enum WalRecord {
    Row(u64, RowSketch),
    Batch(u64, ColumnarBlock),
}

/// Result of scanning one WAL file.
pub(crate) struct WalScan {
    pub records: Vec<WalRecord>,
    /// The file ended in a torn (tolerated, unacknowledged) tail.
    pub torn_tail: bool,
}

fn frame(out: &mut Vec<u8>, payload: &[u8]) -> anyhow::Result<()> {
    anyhow::ensure!(
        !payload.is_empty() && payload.len() <= MAX_RECORD_LEN as usize,
        "WAL record payload of {} bytes exceeds the cap",
        payload.len()
    );
    put_u32(out, payload.len() as u32);
    put_u32(out, crc32(payload));
    out.extend_from_slice(payload);
    Ok(())
}

/// Append one map-row record to `out`. The row's shape must match the
/// directory's meta shape (the payload does not repeat it).
pub(crate) fn encode_row(
    shape: &MetaShape,
    id: u64,
    rs: &RowSketch,
    out: &mut Vec<u8>,
) -> anyhow::Result<()> {
    let (orders, k, nm) = (shape.orders as usize, shape.k as usize, shape.moment_orders as usize);
    anyhow::ensure!(
        rs.uside.orders == orders
            && rs.uside.k == k
            && rs.moments.len() == nm
            && rs.vside_data.is_some() == shape.two_sided,
        "row {id} does not match the data dir shape"
    );
    let mut payload = Vec::with_capacity(1 + 8 + shape.row_data_bytes());
    payload.push(KIND_ROW);
    put_u64(&mut payload, id);
    put_f32s(&mut payload, &rs.uside.data);
    if let Some(v) = &rs.vside_data {
        put_f32s(&mut payload, &v.data);
    }
    put_f64s(&mut payload, &rs.moments.0);
    frame(out, &payload)
}

/// Append one columnar-batch record to `out` (panels in the persist
/// segment layout).
pub(crate) fn encode_batch(
    shape: &MetaShape,
    base: u64,
    block: &ColumnarBlock,
    out: &mut Vec<u8>,
) -> anyhow::Result<()> {
    let (orders, k, nm) = (shape.orders as usize, shape.k as usize, shape.moment_orders as usize);
    anyhow::ensure!(
        block.orders() == orders
            && block.k() == k
            && block.moment_orders() == nm
            && block.is_two_sided() == shape.two_sided,
        "block at base {base} does not match the data dir shape"
    );
    // WAL batch records are always plain f32: the log sits *before* the
    // store boundary where `panel-quant` applies, so replayed batches
    // re-quantize under whatever setting the recovering store has.
    anyhow::ensure!(
        block.encoding() == crate::core::quant::PanelQuant::None,
        "WAL batch at base {base} must be f32-encoded, got {}",
        block.encoding().name()
    );
    let rows = block.rows();
    anyhow::ensure!(rows > 0 && (rows as u64) <= MAX_BATCH_ROWS, "implausible batch of {rows} rows");
    anyhow::ensure!(base.checked_add(rows as u64).is_some(), "batch id range overflows");
    let mut payload = Vec::with_capacity(1 + 16 + rows * shape.row_data_bytes());
    payload.push(KIND_BATCH);
    put_u64(&mut payload, base);
    put_u64(&mut payload, rows as u64);
    for m in 1..=orders {
        put_f32s(&mut payload, block.u_order(m));
    }
    if block.is_two_sided() {
        for m in 1..=orders {
            if let Some(panel) = block.v_order(m) {
                put_f32s(&mut payload, panel);
            }
        }
    }
    put_f64s(&mut payload, block.moments_all());
    frame(out, &payload)
}

fn decode_record(payload: &[u8], shape: &MetaShape) -> anyhow::Result<WalRecord> {
    let mut r = ByteReader::new(payload);
    let kind = r.u8()?;
    let (orders, k, nm) = (shape.orders as usize, shape.k as usize, shape.moment_orders as usize);
    let side = orders * k;
    match kind {
        KIND_ROW => {
            let id = r.u64()?;
            anyhow::ensure!(
                r.remaining() == shape.row_data_bytes(),
                "row record length does not match the store shape"
            );
            let udata = r.f32s(side)?;
            let vside_data = if shape.two_sided {
                Some(SketchSet { orders, k, data: r.f32s(side)? })
            } else {
                None
            };
            let moments = Moments(r.f64s(nm)?);
            Ok(WalRecord::Row(
                id,
                RowSketch { uside: SketchSet { orders, k, data: udata }, vside_data, moments },
            ))
        }
        KIND_BATCH => {
            let base = r.u64()?;
            let rows = r.u64()?;
            anyhow::ensure!(rows > 0 && rows <= MAX_BATCH_ROWS, "implausible batch of {rows} rows");
            anyhow::ensure!(base.checked_add(rows).is_some(), "batch id range overflows");
            let rows = rows as usize;
            let expect = rows
                .checked_mul(shape.row_data_bytes())
                .ok_or_else(|| anyhow::anyhow!("batch byte size overflows"))?;
            anyhow::ensure!(
                r.remaining() == expect,
                "batch record length does not match the store shape"
            );
            let u = r.f32s(side * rows)?;
            let v = if shape.two_sided { Some(r.f32s(side * rows)?) } else { None };
            let moments = r.f64s(rows * nm)?;
            Ok(WalRecord::Batch(base, ColumnarBlock::from_parts(orders, k, nm, rows, u, v, moments)))
        }
        t => anyhow::bail!("unknown WAL record kind {t}"),
    }
}

/// Scan one WAL file: every intact record in order, stopping at a torn
/// tail; mid-log corruption is a hard error (see the module docs for
/// the full decision procedure).
pub(crate) fn replay_file(
    fs: &dyn DurableFs,
    path: &Path,
    shape: &MetaShape,
) -> anyhow::Result<WalScan> {
    let data = fs.read_file(path).context("reading WAL file")?;
    if data.len() < 8 {
        // A crash during file creation can tear the 8-byte header
        // itself; nothing in this file was ever acknowledged.
        return Ok(WalScan { records: Vec::new(), torn_tail: true });
    }
    anyhow::ensure!(&data[..4] == WAL_MAGIC, "not a WAL file (bad magic)");
    let mut hdr = ByteReader::new(&data[4..8]);
    let version = hdr.u32()?;
    anyhow::ensure!(version == WAL_VERSION, "unsupported WAL version {version}");
    let mut off = 8usize;
    let mut records = Vec::new();
    let mut torn_tail = false;
    loop {
        let rem = data.len() - off;
        if rem == 0 {
            break; // clean end
        }
        if rem < 8 {
            torn_tail = true; // short record header
            break;
        }
        let mut h = ByteReader::new(&data[off..off + 8]);
        let len = h.u32()?;
        let want_crc = h.u32()?;
        if len == 0 {
            // Zero length + all-zero suffix is filesystem
            // zero-extension after a crash (size metadata landed, data
            // blocks did not): a torn, unacknowledged tail. A zero
            // length with nonzero bytes after it cannot come from a
            // tear — hard error.
            anyhow::ensure!(
                want_crc == 0 && data[off..].iter().all(|&b| b == 0),
                "corrupt WAL record at offset {off}: zero length mid-log"
            );
            torn_tail = true;
            break;
        }
        anyhow::ensure!(
            len <= MAX_RECORD_LEN,
            "implausible WAL record length {len} at offset {off}"
        );
        let len = len as usize;
        if rem - 8 < len {
            torn_tail = true; // short payload
            break;
        }
        let payload = &data[off + 8..off + 8 + len];
        if crc32(payload) != want_crc {
            // A checksum failure on the *final* record is a torn tail
            // (partially-persisted last append); anywhere else it is
            // corruption under the CRC of settled data.
            anyhow::ensure!(
                off + 8 + len == data.len(),
                "WAL checksum mismatch at offset {off} (mid-log corruption)"
            );
            torn_tail = true;
            break;
        }
        let rec = decode_record(payload, shape)
            .with_context(|| format!("decoding WAL record at offset {off}"))?;
        records.push(rec);
        off += 8 + len;
    }
    Ok(WalScan { records, torn_tail })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::durable::RealFs;
    use crate::projection::sketcher::Sketcher;
    use crate::projection::{ProjectionDist, ProjectionSpec, Strategy};
    use std::path::PathBuf;

    fn shape() -> MetaShape {
        MetaShape {
            p: 4,
            k: 6,
            orders: 3,
            moment_orders: 6,
            two_sided: false,
            seed: 3,
            dist: ProjectionDist::Normal,
        }
    }

    fn shape_alt() -> MetaShape {
        MetaShape {
            p: 6,
            k: 4,
            orders: 5,
            moment_orders: 10,
            two_sided: true,
            seed: 9,
            dist: ProjectionDist::Uniform,
        }
    }

    fn sketcher(s: &MetaShape) -> Sketcher {
        let strategy = if s.two_sided { Strategy::Alternative } else { Strategy::Basic };
        Sketcher::new(ProjectionSpec::new(s.seed, s.k as usize, s.dist, strategy), s.p as usize)
    }

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("lpsketch_wal_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{name}_{}", std::process::id()))
    }

    fn write_wal(name: &str, body: &[u8]) -> PathBuf {
        let path = tmp(name);
        let mut data = file_header().to_vec();
        data.extend_from_slice(body);
        std::fs::write(&path, data).unwrap();
        path
    }

    #[test]
    fn wal_names_roundtrip() {
        assert_eq!(parse_wal_name("wal-0000000000000000.wal"), Some(0));
        assert_eq!(parse_wal_name("wal-00000000000000ff.wal"), Some(255));
        assert_eq!(parse_wal_name("wal-ff.wal"), None);
        assert_eq!(parse_wal_name("seg-0000000000000000.wal"), None);
        assert_eq!(parse_wal_name("wal-000000000000000g.wal"), None);
    }

    #[test]
    fn records_roundtrip_both_kinds_and_sides() {
        for s in [shape(), shape_alt()] {
            let sk = sketcher(&s);
            let rows: Vec<Vec<f32>> = (0..5)
                .map(|i| (0..9).map(|t| ((i * 3 + t) as f32 * 0.4).sin()).collect())
                .collect();
            let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
            let rs = sk.sketch_row(refs[0]);
            let block = sk.sketch_block(&refs[1..], 1);
            let mut body = Vec::new();
            encode_row(&s, 42, &rs, &mut body).unwrap();
            encode_batch(&s, 1000, &block, &mut body).unwrap();
            let path = write_wal(&format!("roundtrip_{}", s.two_sided), &body);
            let scan = replay_file(&RealFs, &path, &s).unwrap();
            assert!(!scan.torn_tail);
            assert_eq!(scan.records.len(), 2);
            match &scan.records[0] {
                WalRecord::Row(id, got) => {
                    assert_eq!(*id, 42);
                    assert_eq!(got.uside.data, rs.uside.data);
                    assert_eq!(got.moments.0, rs.moments.0);
                    assert_eq!(
                        got.vside_data.as_ref().map(|v| &v.data),
                        rs.vside_data.as_ref().map(|v| &v.data)
                    );
                }
                _ => panic!("expected a row record"),
            }
            match &scan.records[1] {
                WalRecord::Batch(base, got) => {
                    assert_eq!(*base, 1000);
                    assert_eq!(got.rows(), block.rows());
                    for m in 1..=block.orders() {
                        assert_eq!(got.u_order(m), block.u_order(m));
                    }
                    assert_eq!(got.moments_all(), block.moments_all());
                }
                _ => panic!("expected a batch record"),
            }
            std::fs::remove_file(&path).ok();
        }
    }

    #[test]
    fn torn_tails_are_tolerated() {
        let s = shape();
        let sk = sketcher(&s);
        let rs = sk.sketch_row(&[0.5, -0.2, 0.8, 0.1]);
        let mut body = Vec::new();
        encode_row(&s, 1, &rs, &mut body).unwrap();
        let full = body.clone();
        encode_row(&s, 2, &rs, &mut body).unwrap();
        // Every truncation point inside the second record leaves record
        // one intact and tolerates the tail (a cut at exactly the first
        // record's end is simply a clean, shorter file).
        for cut in full.len() + 1..body.len() {
            let path = write_wal("torn", &body[..cut]);
            let scan = replay_file(&RealFs, &path, &s).unwrap();
            assert!(scan.torn_tail, "cut at {cut} must be a torn tail");
            assert_eq!(scan.records.len(), 1, "cut at {cut} keeps the first record");
            std::fs::remove_file(&path).ok();
        }
        // Zero-extension tear: full first record, then a run of zeros.
        let mut zeroed = full.clone();
        zeroed.extend_from_slice(&[0u8; 23]);
        let path = write_wal("zeroext", &zeroed);
        let scan = replay_file(&RealFs, &path, &s).unwrap();
        assert!(scan.torn_tail);
        assert_eq!(scan.records.len(), 1);
        std::fs::remove_file(&path).ok();
        // Torn file header (crash during creation).
        let path = tmp("torn_header");
        std::fs::write(&path, &file_header()[..3]).unwrap();
        let scan = replay_file(&RealFs, &path, &s).unwrap();
        assert!(scan.torn_tail);
        assert!(scan.records.is_empty());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn mid_log_corruption_is_a_hard_error() {
        let s = shape();
        let sk = sketcher(&s);
        let rs = sk.sketch_row(&[0.5, -0.2, 0.8, 0.1]);
        let mut body = Vec::new();
        encode_row(&s, 1, &rs, &mut body).unwrap();
        encode_row(&s, 2, &rs, &mut body).unwrap();
        // Flip a payload byte of the *first* record: settled data.
        let mut b = body.clone();
        b[10] ^= 0x01;
        let path = write_wal("midflip", &b);
        assert!(replay_file(&RealFs, &path, &s).is_err());
        std::fs::remove_file(&path).ok();
        // Zero length mid-log with nonzero data after it.
        let mut b = body.clone();
        b[..4].copy_from_slice(&0u32.to_le_bytes());
        let path = write_wal("zerolen", &b);
        assert!(replay_file(&RealFs, &path, &s).is_err());
        std::fs::remove_file(&path).ok();
        // Implausible length field.
        let mut b = body.clone();
        b[..4].copy_from_slice(&(MAX_RECORD_LEN + 1).to_le_bytes());
        let path = write_wal("hugelen", &b);
        assert!(replay_file(&RealFs, &path, &s).is_err());
        std::fs::remove_file(&path).ok();
        // Bad magic is never a tear.
        let path = tmp("badmagic");
        let mut data = file_header().to_vec();
        data[0] ^= 0xFF;
        data.extend_from_slice(&body);
        std::fs::write(&path, data).unwrap();
        assert!(replay_file(&RealFs, &path, &s).is_err());
        std::fs::remove_file(&path).ok();
        // A checksum failure on the final record is a tolerated tail
        // (indistinguishable from a partially-persisted append) — the
        // prefix survives.
        let mut b = body.clone();
        let last = b.len() - 1;
        b[last] ^= 0x01;
        let path = write_wal("tailflip", &b);
        let scan = replay_file(&RealFs, &path, &s).unwrap();
        assert!(scan.torn_tail);
        assert_eq!(scan.records.len(), 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn shape_mismatch_in_record_is_an_error() {
        let s = shape();
        let sk = sketcher(&s);
        let rs = sk.sketch_row(&[0.1, 0.2, 0.3]);
        let mut body = Vec::new();
        encode_row(&s, 5, &rs, &mut body).unwrap();
        // Replaying under a different shape must fail cleanly (exact
        // length check), not misparse.
        let path = write_wal("shapeshift", &body);
        assert!(replay_file(&RealFs, &path, &shape_alt()).is_err());
        std::fs::remove_file(&path).ok();
        // Encoding a row under the wrong shape is rejected up front.
        let mut out = Vec::new();
        assert!(encode_row(&shape_alt(), 5, &rs, &mut out).is_err());
    }
}
