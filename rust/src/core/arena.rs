//! Columnar sketch arena — the structure-of-arrays mirror of a batch of
//! [`RowSketch`]es, laid out for blocked (cache-tiled) estimation.
//!
//! Per-row sketches are ideal for streaming ingest (each worker owns its
//! rows) but poor for the serving hot path: scoring a query against n
//! rows chases n separate heap allocations and reloads the marginal
//! moments per pair. The arena transposes that state into three dense
//! buffers:
//!
//! ```text
//! u      : orders × (n × k) f32   — order-major; block m holds every
//!                                   row's u_m sketch contiguously
//! v      : same layout (alternative strategy only; absent ⇒ u is both
//!                                   sides, exactly like RowSketch::vside)
//! norm_p : n f64                  — the marginal Σ x^p of each row
//! ```
//!
//! With this layout the blocked kernels in [`crate::core::estimator`]
//! (`estimate_block_arena`, `top_k_scan_arena`,
//! `estimate_condensed_arena`) stream one order at a time through
//! L1-sized row tiles, GEMM-style: a tile of query u_m rows is reused
//! against a tile of target v_{p−m} rows before either leaves cache.
//!
//! The arena stores exactly what the *plain* estimator (§2.1/§2.2
//! combine rule) needs. The margin MLE (Lemma 4) additionally consumes
//! per-order norms and higher moments and stays on the per-row path.

use crate::projection::sketcher::{ColumnarBlock, RowSketch};

/// Columnar store of `n` rows' power sketches + marginal p-norms.
#[derive(Clone, Debug)]
pub struct SketchArena {
    p: usize,
    orders: usize,
    k: usize,
    n: usize,
    /// Order-major u-side sketches: `u[((m-1)·n + i)·k ..][..k]` = u_m of row i.
    u: Vec<f32>,
    /// Order-major v-side sketches (alternative strategy); `None` ⇒ the
    /// sides coincide (basic strategy), mirroring `RowSketch::vside()`.
    v: Option<Vec<f32>>,
    /// Marginal p-norms Σ x^p per row, f64.
    norm_p: Vec<f64>,
}

impl SketchArena {
    /// Build an arena from per-row sketches. `k` must be passed
    /// explicitly so an empty row set still yields a well-shaped arena
    /// (orders and k are not inferable from zero rows).
    ///
    /// Panics if any row disagrees on `k`, `orders`, or sidedness.
    pub fn from_rows(p: usize, k: usize, rows: &[RowSketch]) -> Self {
        let two_sided = rows.first().is_some_and(|r| r.vside_data.is_some());
        Self::from_indexed(p, k, rows.len(), two_sided, rows.iter().enumerate())
    }

    /// Build an arena of `n` rows from `(position, row)` pairs in any
    /// order — the store snapshot streams rows shard by shard, straight
    /// into the arena buffers, with no intermediate per-row clones.
    /// Every position in `[0, n)` must be supplied exactly once.
    pub fn from_indexed<'a, I>(p: usize, k: usize, n: usize, two_sided: bool, rows: I) -> Self
    where
        I: IntoIterator<Item = (usize, &'a RowSketch)>,
    {
        let mut b = ArenaBuilder::new(p, k, n, two_sided);
        for (i, rs) in rows {
            b.set_row(i, rs);
        }
        b.finish()
    }

    /// Arena with zero rows (valid input to every kernel).
    pub fn empty(p: usize, k: usize) -> Self {
        Self::from_rows(p, k, &[])
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn p(&self) -> usize {
        self.p
    }

    pub fn orders(&self) -> usize {
        self.orders
    }

    /// Whether separate v-side sketches are stored (alternative strategy).
    pub fn is_two_sided(&self) -> bool {
        self.v.is_some()
    }

    /// u_m sketch of row `i` (the left/query side of a pair).
    #[inline]
    pub fn u_row(&self, m: usize, i: usize) -> &[f32] {
        debug_assert!(m >= 1 && m <= self.orders && i < self.n);
        let off = ((m - 1) * self.n + i) * self.k;
        &self.u[off..off + self.k]
    }

    /// v_m sketch of row `i` (the right/target side of a pair); falls
    /// back to the u side under the basic strategy.
    #[inline]
    pub fn v_row(&self, m: usize, i: usize) -> &[f32] {
        match &self.v {
            Some(v) => {
                debug_assert!(m >= 1 && m <= self.orders && i < self.n);
                let off = ((m - 1) * self.n + i) * self.k;
                &v[off..off + self.k]
            }
            None => self.u_row(m, i),
        }
    }

    /// The contiguous `n × k` block of every row's u_m sketch.
    pub fn u_order(&self, m: usize) -> &[f32] {
        let off = (m - 1) * self.n * self.k;
        &self.u[off..off + self.n * self.k]
    }

    /// Marginal p-norm Σ x^p of row `i`.
    #[inline]
    pub fn norm_p(&self, i: usize) -> f64 {
        self.norm_p[i]
    }

    /// All marginal p-norms, row order.
    pub fn norms(&self) -> &[f64] {
        &self.norm_p
    }

    /// Payload bytes (storage accounting alongside `RowSketch::sketch_bytes`).
    pub fn bytes(&self) -> usize {
        let floats = self.u.len() + self.v.as_ref().map_or(0, |v| v.len());
        floats * std::mem::size_of::<f32>() + self.norm_p.len() * std::mem::size_of::<f64>()
    }
}

/// Incremental [`SketchArena`] constructor: rows land either one at a
/// time from per-row [`RowSketch`]es ([`ArenaBuilder::set_row`]) or as
/// whole columnar ingest blocks ([`ArenaBuilder::set_block`] — one
/// contiguous copy per order per side, since [`ColumnarBlock`] already
/// uses the arena's order-major layout). Every position in `[0, n)`
/// must be supplied exactly once before [`ArenaBuilder::finish`].
pub struct ArenaBuilder {
    p: usize,
    orders: usize,
    k: usize,
    n: usize,
    u: Vec<f32>,
    v: Option<Vec<f32>>,
    norm_p: Vec<f64>,
    filled: usize,
}

impl ArenaBuilder {
    pub fn new(p: usize, k: usize, n: usize, two_sided: bool) -> Self {
        let orders = p - 1;
        ArenaBuilder {
            p,
            orders,
            k,
            n,
            u: vec![0.0f32; orders * n * k],
            v: two_sided.then(|| vec![0.0f32; orders * n * k]),
            norm_p: vec![0.0f64; n],
            filled: 0,
        }
    }

    /// Land one per-row sketch at arena position `i`.
    pub fn set_row(&mut self, i: usize, rs: &RowSketch) {
        let (n, k, orders) = (self.n, self.k, self.orders);
        assert!(i < n, "arena position {i} out of range (n={n})");
        assert_eq!(rs.uside.k, k, "row {i}: sketch width mismatch");
        assert_eq!(rs.uside.orders, orders, "row {i}: order count mismatch");
        assert_eq!(
            rs.vside_data.is_some(),
            self.v.is_some(),
            "row {i}: mixed one/two-sided rows"
        );
        for m in 1..=orders {
            let off = ((m - 1) * n + i) * k;
            self.u[off..off + k].copy_from_slice(rs.uside.u(m));
            if let Some(vbuf) = self.v.as_mut() {
                vbuf[off..off + k]
                    .copy_from_slice(rs.vside_data.as_ref().expect("two-sided").u(m));
            }
        }
        self.norm_p[i] = rs.moments.get(self.p);
        self.filled += 1;
    }

    /// Land a whole columnar block at arena positions
    /// `[i0, i0 + block.rows())` — the ingest fast path: the block's
    /// order panels are already arena-shaped, so each (order, side) is
    /// a single bulk copy and only the marginal p-norms are gathered
    /// per row. Quantized blocks decode panel-wise into the arena's f32
    /// buffers — decode is value-exact, so arena-served estimates equal
    /// view-served ones bitwise.
    pub fn set_block(&mut self, i0: usize, block: &ColumnarBlock) {
        let rows = block.rows();
        let (n, k, orders) = (self.n, self.k, self.orders);
        assert!(i0 + rows <= n, "block [{i0}, {}) out of range (n={n})", i0 + rows);
        assert_eq!(block.k(), k, "block sketch width mismatch");
        assert_eq!(block.orders(), orders, "block order count mismatch");
        assert_eq!(
            block.is_two_sided(),
            self.v.is_some(),
            "mixed one/two-sided blocks"
        );
        assert!(block.moment_orders() >= self.p, "block moments too short for p");
        for m in 1..=orders {
            let off = ((m - 1) * n + i0) * k;
            block.decode_u_order_into(m, &mut self.u[off..off + rows * k]);
            if let Some(vbuf) = self.v.as_mut() {
                block.decode_v_order_into(m, &mut vbuf[off..off + rows * k]);
            }
        }
        for r in 0..rows {
            self.norm_p[i0 + r] = block.moment(r, self.p);
        }
        self.filled += rows;
    }

    pub fn finish(self) -> SketchArena {
        assert_eq!(self.filled, self.n, "arena expects every position filled exactly once");
        SketchArena {
            p: self.p,
            orders: self.orders,
            k: self.k,
            n: self.n,
            u: self.u,
            v: self.v,
            norm_p: self.norm_p,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::projection::sketcher::Sketcher;
    use crate::projection::{ProjectionDist, ProjectionSpec, Strategy};

    fn sketch_rows(strategy: Strategy, p: usize, k: usize, n: usize) -> Vec<RowSketch> {
        let sk = Sketcher::new(ProjectionSpec::new(7, k, ProjectionDist::Normal, strategy), p);
        let rows: Vec<Vec<f32>> = (0..n)
            .map(|i| (0..24).map(|t| ((i * 31 + t) as f32 * 0.11).sin()).collect())
            .collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        sk.sketch_rows(&refs)
    }

    #[test]
    fn arena_rows_match_per_row_sketches() {
        for strategy in [Strategy::Basic, Strategy::Alternative] {
            let (p, k, n) = (4, 8, 5);
            let rows = sketch_rows(strategy, p, k, n);
            let arena = SketchArena::from_rows(p, k, &rows);
            assert_eq!(arena.n(), n);
            assert_eq!(arena.is_two_sided(), matches!(strategy, Strategy::Alternative));
            for (i, rs) in rows.iter().enumerate() {
                for m in 1..p {
                    assert_eq!(arena.u_row(m, i), rs.uside.u(m), "u m={m} i={i}");
                    assert_eq!(arena.v_row(m, i), rs.vside().u(m), "v m={m} i={i}");
                }
                assert_eq!(arena.norm_p(i), rs.moments.get(p));
            }
        }
    }

    #[test]
    fn order_blocks_are_contiguous() {
        let rows = sketch_rows(Strategy::Basic, 4, 4, 3);
        let arena = SketchArena::from_rows(4, 4, &rows);
        let block = arena.u_order(2);
        assert_eq!(block.len(), 3 * 4);
        assert_eq!(&block[4..8], arena.u_row(2, 1));
    }

    #[test]
    fn empty_arena_is_well_shaped() {
        let a = SketchArena::empty(6, 16);
        assert_eq!(a.n(), 0);
        assert_eq!(a.k(), 16);
        assert_eq!(a.orders(), 5);
        assert!(a.norms().is_empty());
        assert_eq!(a.bytes(), 0);
    }

    #[test]
    #[should_panic(expected = "sketch width mismatch")]
    fn rejects_inconsistent_k() {
        let rows = sketch_rows(Strategy::Basic, 4, 8, 2);
        SketchArena::from_rows(4, 16, &rows);
    }

    fn block_of(strategy: Strategy, p: usize, k: usize, n: usize) -> ColumnarBlock {
        let sk = Sketcher::new(ProjectionSpec::new(7, k, ProjectionDist::Normal, strategy), p);
        let rows: Vec<Vec<f32>> = (0..n)
            .map(|i| (0..24).map(|t| ((i * 31 + t) as f32 * 0.11).sin()).collect())
            .collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        sk.sketch_block(&refs, 2)
    }

    #[test]
    fn builder_block_lands_verbatim() {
        for strategy in [Strategy::Basic, Strategy::Alternative] {
            let (p, k, n) = (4, 8, 5);
            let block = block_of(strategy, p, k, n);
            let mut b = ArenaBuilder::new(p, k, n, block.is_two_sided());
            b.set_block(0, &block);
            let arena = b.finish();
            for r in 0..n {
                for m in 1..p {
                    assert_eq!(arena.u_row(m, r), block.u_row(m, r), "u m={m} r={r}");
                    assert_eq!(arena.v_row(m, r), block.v_row(m, r), "v m={m} r={r}");
                }
                assert_eq!(arena.norm_p(r), block.moment(r, p));
            }
        }
    }

    #[test]
    fn builder_mixes_blocks_and_rows() {
        // A columnar block landed at an offset, per-row sketches around
        // it — the store-snapshot shape (segments + hashmap rows).
        let (p, k) = (4, 8);
        let block = block_of(Strategy::Basic, p, k, 3);
        let rows = sketch_rows(Strategy::Basic, p, k, 2);
        let mut b = ArenaBuilder::new(p, k, 5, false);
        b.set_row(0, &rows[0]);
        b.set_block(1, &block);
        b.set_row(4, &rows[1]);
        let arena = b.finish();
        assert_eq!(arena.u_row(2, 0), rows[0].uside.u(2));
        for r in 0..3 {
            assert_eq!(arena.u_row(2, 1 + r), block.u_row(2, r));
        }
        assert_eq!(arena.u_row(2, 4), rows[1].uside.u(2));
        assert_eq!(arena.norm_p(2), block.moment(1, p));
    }

    #[test]
    fn concat_block_lands_like_sequential_blocks() {
        // Compaction invariant at the arena layer: landing
        // `ColumnarBlock::concat(&[a, b])` as one block is
        // bitwise-identical to landing `a` and `b` sequentially — the
        // store may merge segments at any time without changing any
        // arena-served estimate.
        for strategy in [Strategy::Basic, Strategy::Alternative] {
            let (p, k) = (4, 8);
            let a = block_of(strategy, p, k, 3);
            let b = block_of(strategy, p, k, 2);
            let merged = ColumnarBlock::concat(&[&a, &b]);
            assert_eq!(merged.rows(), 5);
            let mut seq = ArenaBuilder::new(p, k, 5, a.is_two_sided());
            seq.set_block(0, &a);
            seq.set_block(3, &b);
            let seq = seq.finish();
            let mut one = ArenaBuilder::new(p, k, 5, merged.is_two_sided());
            one.set_block(0, &merged);
            let one = one.finish();
            for r in 0..5 {
                for m in 1..p {
                    assert_eq!(one.u_row(m, r), seq.u_row(m, r), "u m={m} r={r}");
                    assert_eq!(one.v_row(m, r), seq.v_row(m, r), "v m={m} r={r}");
                }
                assert_eq!(one.norm_p(r), seq.norm_p(r), "norm r={r}");
            }
        }
    }

    #[test]
    fn quantized_block_lands_decoded_values() {
        // Landing an encoded block fills the arena with exactly the
        // values the block's views decode to (value-exact decode).
        use crate::core::quant::PanelQuant;
        for q in [PanelQuant::F16, PanelQuant::Bf16, PanelQuant::I8] {
            let (p, k, n) = (4, 8, 5);
            let block = block_of(Strategy::Alternative, p, k, n).encoded_as(q);
            let mut b = ArenaBuilder::new(p, k, n, true);
            b.set_block(0, &block);
            let arena = b.finish();
            for r in 0..n {
                for m in 1..p {
                    for j in 0..k {
                        assert_eq!(arena.u_row(m, r)[j], block.u_view(m, r).get(j));
                        assert_eq!(arena.v_row(m, r)[j], block.v_view(m, r).get(j));
                    }
                }
                assert_eq!(arena.norm_p(r), block.moment(r, p));
            }
        }
    }

    #[test]
    #[should_panic(expected = "filled exactly once")]
    fn builder_rejects_partial_fill() {
        let block = block_of(Strategy::Basic, 4, 8, 3);
        let mut b = ArenaBuilder::new(4, 8, 5, false);
        b.set_block(0, &block);
        let _ = b.finish();
    }

    #[test]
    #[should_panic(expected = "mixed one/two-sided")]
    fn builder_rejects_mixed_sidedness() {
        let block = block_of(Strategy::Alternative, 4, 8, 3);
        let mut b = ArenaBuilder::new(4, 8, 3, false);
        b.set_block(0, &block);
    }
}
