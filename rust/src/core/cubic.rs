//! Real-root cubic solver (Cardano + trigonometric branch) and the
//! one-step Newton iteration the paper recommends for the margin MLE
//! ("one-step Newton-Rhapson in statistics", §2.3).

/// All real roots of z³ + a z² + b z + c = 0, ascending, deduplicated to
/// numerical precision.
pub fn real_roots(a: f64, b: f64, c: f64) -> Vec<f64> {
    // Depressed cubic t³ + p t + q, z = t - a/3.
    let shift = a / 3.0;
    let p = b - a * a / 3.0;
    let q = 2.0 * a * a * a / 27.0 - a * b / 3.0 + c;
    let disc = (q / 2.0).powi(2) + (p / 3.0).powi(3);

    let mut roots = if disc > 1e-300 {
        // One real root (Cardano).
        let sq = disc.sqrt();
        let u = cbrt(-q / 2.0 + sq);
        let v = cbrt(-q / 2.0 - sq);
        vec![u + v - shift]
    } else if p.abs() < 1e-300 && q.abs() < 1e-300 {
        vec![-shift]
    } else {
        // Three real roots (trigonometric / Viète).
        let r = (-p / 3.0).max(0.0).sqrt();
        let arg = (3.0 * q / (2.0 * p * r.max(1e-300))).clamp(-1.0, 1.0);
        let phi = arg.acos();
        (0..3)
            .map(|i| 2.0 * r * ((phi - 2.0 * std::f64::consts::PI * i as f64) / 3.0).cos() - shift)
            .collect()
    };

    // Polish with a couple of Newton steps (Cardano loses digits when the
    // roots are badly scaled) and sort/dedup.
    for z in roots.iter_mut() {
        for _ in 0..3 {
            *z = newton_step(*z, a, b, c);
        }
    }
    roots.sort_by(|x, y| x.partial_cmp(y).unwrap());
    roots.dedup_by(|x, y| (*x - *y).abs() < 1e-8 * (x.abs() + y.abs() + 1.0));
    roots
}

/// One Newton–Raphson step on f(z) = z³ + a z² + b z + c.
#[inline]
pub fn newton_step(z: f64, a: f64, b: f64, c: f64) -> f64 {
    let f = ((z + a) * z + b) * z + c;
    let fp = (3.0 * z + 2.0 * a) * z + b;
    if fp.abs() < 1e-300 {
        z
    } else {
        z - f / fp
    }
}

#[inline]
fn cbrt(x: f64) -> f64 {
    x.signum() * x.abs().cbrt()
}

/// Residual |f(z)| of a candidate root (testing hook).
pub fn residual(z: f64, a: f64, b: f64, c: f64) -> f64 {
    (((z + a) * z + b) * z + c).abs()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    #[test]
    fn three_known_roots() {
        // (z-1)(z-2)(z-3) = z³ -6z² +11z -6
        let r = real_roots(-6.0, 11.0, -6.0);
        assert_eq!(r.len(), 3);
        for (got, want) in r.iter().zip([1.0, 2.0, 3.0]) {
            assert!((got - want).abs() < 1e-9, "{got} vs {want}");
        }
    }

    #[test]
    fn single_real_root() {
        // z³ + z + 1 has one real root ≈ -0.682327803828
        let r = real_roots(0.0, 1.0, 1.0);
        assert_eq!(r.len(), 1);
        assert!((r[0] + 0.6823278038280193).abs() < 1e-9);
    }

    #[test]
    fn triple_root() {
        // (z-2)³ = z³ -6z² +12z -8
        let r = real_roots(-6.0, 12.0, -8.0);
        assert!(!r.is_empty());
        for z in r {
            assert!((z - 2.0).abs() < 1e-5, "z={z}");
        }
    }

    #[test]
    fn roots_have_small_residual_property() {
        testkit::check(300, |g| {
            // Build a cubic from random roots, possibly with two complex.
            let scale = 10f64.powi(g.usize_in(0, 5) as i32 - 2);
            let (a, b, c) = (
                g.f64_in(-5.0, 5.0) * scale,
                g.f64_in(-5.0, 5.0) * scale,
                g.f64_in(-5.0, 5.0) * scale,
            );
            let roots = real_roots(a, b, c);
            crate::prop_assert!(!roots.is_empty(), "cubic must have a real root");
            for z in roots {
                let tol = 1e-7 * (1.0 + z.abs().powi(3) + a.abs() * z.abs() * z.abs());
                crate::prop_assert!(
                    residual(z, a, b, c) < tol,
                    "residual {} at z={z} (a={a} b={b} c={c})",
                    residual(z, a, b, c)
                );
            }
        });
    }

    #[test]
    fn newton_converges_to_root() {
        let (a, b, c) = (-6.0, 11.0, -6.0);
        let mut z = 2.9;
        for _ in 0..20 {
            z = newton_step(z, a, b, c);
        }
        assert!((z - 3.0).abs() < 1e-12);
    }
}
