//! Binomial decomposition of the even-p l_p distance (paper §1.1).
//!
//! For even p,
//! ```text
//! |x - y|^p = (x - y)^p = Σ_{m=0}^{p} (-1)^(p-m) C(p,m) x^m y^(p-m)
//! ```
//! splitting d_(p) into **2 marginal norms** (m = 0, p; coefficient +1)
//! and **p-1 mixed inner products** Σ_i x_i^m y_i^(p-m) with coefficient
//! `c_m = (-1)^m C(p,m)` (p even ⇒ (-1)^(p-m) = (-1)^m).
//!
//! p = 4 ⇒ c = [-4, +6, -4]; p = 6 ⇒ c = [-6, +15, -20, +15, -6] — the
//! exact expansions displayed in §2 and §3 of the paper.

/// A validated even-p decomposition: coefficient table + bookkeeping.
#[derive(Clone, Debug, PartialEq)]
pub struct Decomposition {
    p: usize,
    /// c_m for m = 1..p-1 (index m-1).
    coeffs: Vec<f64>,
}

impl Decomposition {
    /// Build the decomposition for even `p >= 4`.
    pub fn new(p: usize) -> anyhow::Result<Self> {
        anyhow::ensure!(p >= 4 && p % 2 == 0, "p must be even and >= 4, got {p}");
        let coeffs = (1..p)
            .map(|m| {
                let sign = if m % 2 == 0 { 1.0 } else { -1.0 };
                sign * binomial(p, m) as f64
            })
            .collect();
        Ok(Decomposition { p, coeffs })
    }

    pub fn p(&self) -> usize {
        self.p
    }

    /// Number of mixed inner products / power-sketch orders (= p-1).
    pub fn orders(&self) -> usize {
        self.p - 1
    }

    /// Highest marginal moment the estimators and variance formulas
    /// consume: 2(p-1) (Σx^6 for Lemma 1, Σx^10 for Lemma 5).
    pub fn moment_orders(&self) -> usize {
        2 * (self.p - 1)
    }

    /// Coefficient c_m of Σ x^m y^(p-m), m in 1..=p-1.
    pub fn coeff(&self, m: usize) -> f64 {
        self.coeffs[m - 1]
    }

    pub fn coeffs(&self) -> &[f64] {
        &self.coeffs
    }

    /// Evaluate d_(p) from exact building blocks: marginal p-norms and the
    /// exact mixed inner products (index m-1 holds Σ x^m y^(p-m)).
    pub fn combine(&self, x_norm_p: f64, y_norm_p: f64, inner: &[f64]) -> f64 {
        assert_eq!(inner.len(), self.orders());
        let mut d = x_norm_p + y_norm_p;
        for (m, &ip) in (1..self.p).zip(inner) {
            d += self.coeff(m) * ip;
        }
        d
    }
}

/// C(n, k) as u128 (safe for the p ≤ 32 range we could ever sketch).
pub fn binomial(n: usize, k: usize) -> u128 {
    let k = k.min(n - k);
    let mut num: u128 = 1;
    let mut den: u128 = 1;
    for i in 0..k {
        num *= (n - i) as u128;
        den *= (i + 1) as u128;
    }
    num / den
}

/// Exact mixed inner products Σ_i x_i^m y_i^(p-m) for m = 1..p-1.
pub fn exact_inner_products(x: &[f64], y: &[f64], p: usize) -> Vec<f64> {
    assert_eq!(x.len(), y.len());
    (1..p)
        .map(|m| {
            x.iter()
                .zip(y)
                .map(|(&a, &b)| a.powi(m as i32) * b.powi((p - m) as i32))
                .sum()
        })
        .collect()
}

/// Exact l_p^p distance (the quantity all estimators target).
pub fn exact_distance(x: &[f64], y: &[f64], p: usize) -> f64 {
    assert_eq!(x.len(), y.len());
    x.iter()
        .zip(y)
        .map(|(&a, &b)| (a - b).abs().powi(p as i32))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    #[test]
    fn paper_coefficients() {
        let d4 = Decomposition::new(4).unwrap();
        assert_eq!(d4.coeffs(), &[-4.0, 6.0, -4.0]);
        let d6 = Decomposition::new(6).unwrap();
        assert_eq!(d6.coeffs(), &[-6.0, 15.0, -20.0, 15.0, -6.0]);
        let d8 = Decomposition::new(8).unwrap();
        assert_eq!(d8.coeffs(), &[-8.0, 28.0, -56.0, 70.0, -56.0, 28.0, -8.0]);
    }

    #[test]
    fn rejects_odd_and_small_p() {
        assert!(Decomposition::new(3).is_err());
        assert!(Decomposition::new(5).is_err());
        assert!(Decomposition::new(2).is_err());
        assert!(Decomposition::new(0).is_err());
    }

    #[test]
    fn binomial_values() {
        assert_eq!(binomial(4, 2), 6);
        assert_eq!(binomial(6, 3), 20);
        assert_eq!(binomial(10, 0), 1);
        assert_eq!(binomial(10, 10), 1);
        assert_eq!(binomial(20, 10), 184_756);
    }

    #[test]
    fn decomposition_identity_property() {
        // Σ|x-y|^p == combine(marginals, exact inner products) for random
        // signed data and p in {4, 6, 8}.
        testkit::check(100, |g| {
            let p = [4, 6, 8][g.usize_in(0, 3)];
            let n = g.usize_in(1, 40);
            let x: Vec<f64> = (0..n).map(|_| g.f64_in(-2.0, 2.0)).collect();
            let y: Vec<f64> = (0..n).map(|_| g.f64_in(-2.0, 2.0)).collect();
            let dec = Decomposition::new(p).unwrap();
            let xn: f64 = x.iter().map(|v| v.powi(p as i32)).sum();
            let yn: f64 = y.iter().map(|v| v.powi(p as i32)).sum();
            let inner = exact_inner_products(&x, &y, p);
            let lhs = exact_distance(&x, &y, p);
            let rhs = dec.combine(xn, yn, &inner);
            let scale = lhs.abs().max(1.0);
            crate::prop_assert!(
                (lhs - rhs).abs() / scale < 1e-9,
                "p={p} lhs={lhs} rhs={rhs}"
            );
        });
    }

    #[test]
    fn zero_distance_at_equal_vectors() {
        let x: [f64; 4] = [0.3, 1.7, 0.9, 2.2];
        for p in [4, 6] {
            let dec = Decomposition::new(p).unwrap();
            let xn: f64 = x.iter().map(|v| v.powi(p as i32)).sum();
            let inner = exact_inner_products(&x, &x, p);
            let d = dec.combine(xn, xn, &inner);
            assert!(d.abs() < 1e-9 * xn.abs(), "p={p} d={d}");
        }
    }
}
