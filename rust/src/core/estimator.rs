//! The paper's unbiased l_p^p distance estimators (§2.1, §2.2, §3, §4).
//!
//! Both projection strategies share one combine rule — the strategy only
//! changes how the sketches were *produced* (shared vs independent R):
//!
//! ```text
//! d̂ = Σx^p + Σy^p + (1/k) Σ_{m=1}^{p-1} c_m ⟨u_m, v_{p-m}⟩
//! ```
//!
//! ## Per-row vs arena (blocked) kernels
//!
//! [`estimate`] / [`estimate_block`] score one pair at a time from
//! [`RowSketch`]es — fine for a single lookup, wasteful for batched
//! serving (every pair re-walks scattered heap allocations). The
//! `*_arena` kernels consume a [`SketchArena`] (structure-of-arrays, see
//! `core::arena`) and tile the work cache-consciously:
//!
//! * queries are processed in [`ARENA_TILE`]-row tiles, each tile owned
//!   by one worker thread (`std::thread::scope`, round-robin);
//! * within a tile, targets stream in [`ARENA_TILE`]-row tiles and the
//!   combine runs order-major (GEMM-style): for each order m the tile of
//!   query u_m rows is re-used against the resident tile of target
//!   v_{p−m} rows — one (TILE×k + TILE×k) working set per order, sized
//!   for L1/L2;
//! * accumulation is f64 throughout, in *exactly* the same operation
//!   order as [`estimate`], so arena and per-row results agree bitwise
//!   (tiling only reorders which pairs are computed when, never the
//!   arithmetic within a pair).
//!
//! Four arena entry points: [`estimate_block_arena`] (dense B×n
//! matrix), [`top_k_scan_arena`] (fused top-k: streams tiles through a
//! bounded per-query heap without materializing B×n),
//! [`top_k_scan_zoned`] (the same fused top-k but zone-pruned: segments
//! are visited in ascending lower-bound order and skipped once they
//! cannot beat the heap threshold — bitwise-identical results), and
//! [`estimate_condensed_arena`] (upper-triangle all-pairs, scipy
//! `squareform` order). All take a `workers` thread count; results are
//! deterministic in it.

// Serving path: clippy backs the pallas-lint serving-no-panic rule.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicU64, Ordering as AtomicOrdering};

use super::arena::SketchArena;
use super::decompose::Decomposition;
use super::quant::{dot_views, RowView};
use super::zone::ZoneMeta;
use crate::projection::sketcher::{RowSketch, SketchSet};

/// f64 dot product of two f32 sketch vectors, SIMD-dispatched
/// (`projection::simd`, bitwise-identical on every kernel).
///
/// The reduction-order contract — four independent f64 accumulators
/// over chunks of 4, a scalar tail, final
/// `(acc0 + acc2) + (acc1 + acc3) + tail` — is pinned in
/// [`crate::projection::simd::dot_f32_scalar`]; the four accumulators
/// both break the sequential dependency chain (≈2.3× on the estimate
/// hot path — EXPERIMENTS.md §Perf iteration 3) and map one-to-one
/// onto the 4 f64 lanes of the vector kernels. f64 accumulation is
/// load-bearing: sketch entries are O(√D) and the combine multiplies
/// by binomial coefficients, so f32 accumulation loses digits exactly
/// where the distance is a small difference of large terms.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    crate::projection::simd::dot_f32(a, b)
}

/// Plain estimator from two sketch sets + exact marginal p-norms.
pub fn combine(
    dec: &Decomposition,
    u: &SketchSet,
    v: &SketchSet,
    x_norm_p: f64,
    y_norm_p: f64,
) -> f64 {
    let p = dec.p();
    debug_assert_eq!(u.orders, p - 1);
    debug_assert_eq!(v.orders, p - 1);
    let k = u.k as f64;
    let mut d = x_norm_p + y_norm_p;
    for m in 1..p {
        d += dec.coeff(m) * dot(u.u(m), v.u(p - m)) / k;
    }
    d
}

/// Plain estimator straight from two [`RowSketch`]es (marginal p-norm is
/// moment `p`). `x` is the left element of the pair (u-side sketches),
/// `y` the right (v-side) — the distinction only matters under the
/// alternative strategy.
pub fn estimate(dec: &Decomposition, x: &RowSketch, y: &RowSketch) -> f64 {
    combine(
        dec,
        &x.uside,
        y.vside(),
        x.moments.get(dec.p()),
        y.moments.get(dec.p()),
    )
}

/// Per-order sketch inner products ⟨u_m, v_{p-m}⟩/k — the raw unbiased
/// estimates of Σ x^m y^(p-m) (inputs to the margin MLE).
pub fn raw_inner_estimates(dec: &Decomposition, u: &SketchSet, v: &SketchSet) -> Vec<f64> {
    let p = dec.p();
    let k = u.k as f64;
    (1..p).map(|m| dot(u.u(m), v.u(p - m)) / k).collect()
}

/// Dense pairwise estimate matrix (row-major B×B2) — the pure-rust mirror
/// of the `estimate` PJRT artifact, for arbitrary shapes.
pub fn estimate_block(dec: &Decomposition, xs: &[RowSketch], ys: &[RowSketch]) -> Vec<f64> {
    let mut out = Vec::with_capacity(xs.len() * ys.len());
    for x in xs {
        for y in ys {
            out.push(estimate(dec, x, y));
        }
    }
    out
}

/// Rows per tile in the blocked arena kernels. 64 rows × k floats × 4 B
/// is 16 KiB at k=64 — a query tile plus a target tile of one order fit
/// comfortably in L1/L2 together.
pub const ARENA_TILE: usize = 64;

/// Read-only view of columnar sketch panels — the shape every blocked
/// kernel consumes. Implemented by [`SketchArena`] (the owned
/// transposed copy) and by the store's zero-copy segment view
/// (`coordinator::state::SegmentPanels`), so batch queries over a
/// fully-columnar store score segment rows straight from their panels
/// without paying the `arena_snapshot` copy first.
///
/// Rows come back as [`RowView`]s so quantized segment panels
/// (`core::quant`) are scored by decoding lanes in registers — no f32
/// copy is ever materialized. An f32-backed implementation must return
/// the same values / f64 norms the equivalent arena would; since
/// quantized decode is value-exact (the decoded f32 *is* the stored
/// value), every kernel is bitwise-consistent across implementations
/// holding the same values, whatever their storage width.
pub trait SketchPanels: Sync {
    /// Number of rows.
    fn n(&self) -> usize;
    /// Sketch width.
    fn k(&self) -> usize;
    /// Distance order the sketches were built for.
    fn p(&self) -> usize;
    /// u_m sketch of row `i` (the left/query side of a pair).
    fn u_row(&self, m: usize, i: usize) -> RowView<'_>;
    /// v_m sketch of row `i` (the right/target side of a pair).
    fn v_row(&self, m: usize, i: usize) -> RowView<'_>;
    /// Marginal p-norm Σ x^p of row `i`.
    fn norm_p(&self, i: usize) -> f64;
}

impl SketchPanels for SketchArena {
    fn n(&self) -> usize {
        SketchArena::n(self)
    }

    fn k(&self) -> usize {
        SketchArena::k(self)
    }

    fn p(&self) -> usize {
        SketchArena::p(self)
    }

    fn u_row(&self, m: usize, i: usize) -> RowView<'_> {
        RowView::F32(SketchArena::u_row(self, m, i))
    }

    fn v_row(&self, m: usize, i: usize) -> RowView<'_> {
        RowView::F32(SketchArena::v_row(self, m, i))
    }

    fn norm_p(&self, i: usize) -> f64 {
        SketchArena::norm_p(self, i)
    }
}

/// Single-pair estimate from panel rows: row `i` of `q` (u side) against
/// row `j` of `t` (v side). Bitwise-identical to [`estimate`] on the
/// corresponding [`RowSketch`]es.
pub fn estimate_arena<Q: SketchPanels + ?Sized, T: SketchPanels + ?Sized>(
    dec: &Decomposition,
    q: &Q,
    i: usize,
    t: &T,
    j: usize,
) -> f64 {
    let p = dec.p();
    let kf = q.k() as f64;
    let mut d = q.norm_p(i) + t.norm_p(j);
    for m in 1..p {
        d += dec.coeff(m) * dot_views(q.u_row(m, i), t.v_row(p - m, j)) / kf;
    }
    d
}

/// Shape/compat checks shared by the arena kernels (skipped when either
/// side is empty — an empty arena carries no usable k).
fn check_arena_compat<Q: SketchPanels + ?Sized, T: SketchPanels + ?Sized>(
    dec: &Decomposition,
    q: &Q,
    t: &T,
) {
    assert_eq!(q.p(), dec.p(), "query arena p mismatch");
    assert_eq!(t.p(), dec.p(), "target arena p mismatch");
    assert_eq!(q.k(), t.k(), "arena sketch widths differ");
}

/// Score one (query-tile × target-tile) block into `out` with row stride
/// `stride`: `out[r·stride + j2]` = d̂(q row i0+r, t row j0+j2).
///
/// The accumulation sequence per slot — marginal norms first, then the
/// c_m·⟨u_m, v_{p−m}⟩/k terms in ascending m — matches [`estimate`]
/// exactly, so every downstream arena kernel is bitwise-consistent with
/// the per-row path.
#[allow(clippy::too_many_arguments)]
fn score_tile<Q: SketchPanels + ?Sized, T: SketchPanels + ?Sized>(
    dec: &Decomposition,
    q: &Q,
    t: &T,
    i0: usize,
    rows: usize,
    j0: usize,
    width: usize,
    out: &mut [f64],
    stride: usize,
) {
    let p = dec.p();
    let kf = q.k() as f64;
    for r in 0..rows {
        let base = q.norm_p(i0 + r);
        let row = &mut out[r * stride..r * stride + width];
        for (j2, slot) in row.iter_mut().enumerate() {
            *slot = base + t.norm_p(j0 + j2);
        }
    }
    for m in 1..p {
        let c = dec.coeff(m);
        let pm = p - m;
        for r in 0..rows {
            let urow = q.u_row(m, i0 + r);
            let row = &mut out[r * stride..r * stride + width];
            for (j2, slot) in row.iter_mut().enumerate() {
                *slot += c * dot_views(urow, t.v_row(pm, j0 + j2)) / kf;
            }
        }
    }
}

/// Round-robin assignment of work items to at most `ways` buckets.
/// Empty buckets are dropped so callers never spawn idle threads.
pub(crate) fn round_robin<T>(items: Vec<T>, ways: usize) -> Vec<Vec<T>> {
    let ways = ways.max(1);
    let mut parts: Vec<Vec<T>> = (0..ways).map(|_| Vec::new()).collect();
    for (i, item) in items.into_iter().enumerate() {
        parts[i % ways].push(item);
    }
    parts.retain(|p| !p.is_empty());
    parts
}

/// Run one closure per bundle: inline on the caller thread when there is
/// a single bundle (a point query must not pay a thread spawn), scoped
/// threads otherwise.
fn run_bundles<T, F>(mut bundles: Vec<Vec<T>>, work: F)
where
    T: Send,
    F: Fn(Vec<T>) + Sync,
{
    if bundles.len() == 1 {
        if let Some(only) = bundles.pop() {
            work(only);
        }
        return;
    }
    std::thread::scope(|scope| {
        let work = &work;
        for bundle in bundles {
            scope.spawn(move || work(bundle));
        }
    });
}

/// Blocked dense estimate matrix (row-major `q.n() × t.n()`) from two
/// panel sources — the cache-tiled, multi-threaded mirror of
/// [`estimate_block`]. Results are bitwise-identical to the per-row path
/// and independent of `workers`.
pub fn estimate_block_arena<Q: SketchPanels + ?Sized, T: SketchPanels + ?Sized>(
    dec: &Decomposition,
    q: &Q,
    t: &T,
    workers: usize,
) -> Vec<f64> {
    let (bn, tn) = (q.n(), t.n());
    let mut out = vec![0.0f64; bn * tn];
    if bn == 0 || tn == 0 {
        return out;
    }
    check_arena_compat(dec, q, t);
    let tiles: Vec<(usize, &mut [f64])> = out.chunks_mut(ARENA_TILE * tn).enumerate().collect();
    run_bundles(round_robin(tiles, workers), |bundle| {
        for (ti, chunk) in bundle {
            let i0 = ti * ARENA_TILE;
            let rows = chunk.len() / tn;
            let mut j0 = 0;
            while j0 < tn {
                let width = ARENA_TILE.min(tn - j0);
                score_tile(dec, q, t, i0, rows, j0, width, &mut chunk[j0..], tn);
                j0 += width;
            }
        }
    });
    out
}

/// Max-heap entry ordered by (distance, index); the root is the worst
/// retained candidate.
struct HeapEntry {
    d: f64,
    idx: usize,
}

impl PartialEq for HeapEntry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for HeapEntry {}

impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        self.d.total_cmp(&other.d).then_with(|| self.idx.cmp(&other.idx))
    }
}

/// Push into a bounded max-heap, dropping NaN scores outright.
fn push_bounded(heap: &mut BinaryHeap<HeapEntry>, cap: usize, idx: usize, d: f64) {
    if d.is_nan() {
        return;
    }
    let entry = HeapEntry { d, idx };
    if heap.len() < cap {
        heap.push(entry);
    } else if let Some(worst) = heap.peek() {
        if entry.cmp(worst) == Ordering::Less {
            heap.pop();
            heap.push(entry);
        }
    }
}

/// Fused top-k scan: for every query row, the `top` nearest target rows
/// by estimated distance, ascending (ties broken by target index).
///
/// Target tiles stream through a bounded per-query heap, so memory is
/// O(B·(top + TILE)) instead of the O(B·n) a materialize-then-select
/// pass would need. NaN scores are filtered (never returned, never
/// panic). Deterministic in `workers`.
pub fn top_k_scan_arena<Q: SketchPanels + ?Sized, T: SketchPanels + ?Sized>(
    dec: &Decomposition,
    q: &Q,
    t: &T,
    top: usize,
    workers: usize,
) -> Vec<Vec<(usize, f64)>> {
    let (bn, tn) = (q.n(), t.n());
    let mut out: Vec<Vec<(usize, f64)>> = (0..bn).map(|_| Vec::new()).collect();
    if bn == 0 || tn == 0 || top == 0 {
        return out;
    }
    check_arena_compat(dec, q, t);
    let tiles: Vec<(usize, &mut [Vec<(usize, f64)>])> =
        out.chunks_mut(ARENA_TILE).enumerate().collect();
    run_bundles(round_robin(tiles, workers), |bundle| {
        let mut buf = vec![0.0f64; ARENA_TILE * ARENA_TILE];
        for (ti, slots) in bundle {
            let i0 = ti * ARENA_TILE;
            let rows = slots.len();
            let mut heaps: Vec<BinaryHeap<HeapEntry>> =
                (0..rows).map(|_| BinaryHeap::with_capacity(top + 1)).collect();
            let mut j0 = 0;
            while j0 < tn {
                let width = ARENA_TILE.min(tn - j0);
                score_tile(dec, q, t, i0, rows, j0, width, &mut buf, width);
                for (r, heap) in heaps.iter_mut().enumerate() {
                    for j2 in 0..width {
                        push_bounded(heap, top, j0 + j2, buf[r * width + j2]);
                    }
                }
                j0 += width;
            }
            for (slot, heap) in slots.iter_mut().zip(heaps) {
                *slot = heap
                    .into_sorted_vec()
                    .into_iter()
                    .map(|e| (e.idx, e.d))
                    .collect();
            }
        }
    });
    out
}

/// Relative deflation applied to every zone lower bound so fp rounding
/// in the bound computation can never make it *over*-estimate a row's
/// distance. The true rounding error is bounded by ~c·(k+p)·ε relative
/// to the bound's term magnitudes (ε = 2⁻⁵²; ≈2e-11 even at k = 10⁵);
/// 1e-9 leaves two orders of magnitude of headroom. Deflation only ever
/// costs a missed skip — pruned results stay bitwise-identical to the
/// full scan regardless of the margin's size.
pub const ZONE_BOUND_MARGIN: f64 = 1e-9;

/// Admissible lower bound on d̂(q-row, y) over every row `y` of a
/// segment summarized by `zone`:
///
/// ```text
/// d̂(q, y) = Σq^p + Σy^p + (1/k)·Σ_m c_m ⟨u_m(q), v_{p−m}(y)⟩
///          ≥ Σq^p + min_moment[p] − (1/k)·Σ_m |c_m|·‖u_m(q)‖₂·max_v2[p−m]
/// ```
///
/// by Cauchy–Schwarz per order, deflated by [`ZONE_BOUND_MARGIN`].
/// `q_u2[m-1]` must be ‖u_m(q)‖₂; `k` the sketch width. Returns
/// `NEG_INFINITY` (prune nothing) for non-finite inputs or shapes too
/// small for order `p` — the bound is an optimization, never a gate.
pub fn zone_lower_bound(
    dec: &Decomposition,
    q_norm_p: f64,
    q_u2: &[f64],
    zone: &ZoneMeta,
    k: f64,
) -> f64 {
    let p = dec.p();
    if zone.min_moment.len() < p || zone.max_v2.len() < p - 1 || q_u2.len() < p - 1 {
        return f64::NEG_INFINITY;
    }
    let mut b = q_norm_p + zone.min_moment[p - 1];
    let mut scale = q_norm_p.abs() + zone.min_moment[p - 1].abs();
    for m in 1..p {
        let term = dec.coeff(m).abs() * q_u2[m - 1] * zone.max_v2[p - m - 1] / k;
        b -= term;
        scale += term;
    }
    let bound = b - ZONE_BOUND_MARGIN * scale;
    if bound.is_finite() {
        bound
    } else {
        f64::NEG_INFINITY
    }
}

/// One contiguous run of target rows with an optional zone summary.
/// `zone: None` (map rows, or segments without zones) is never skipped.
#[derive(Clone, Copy, Debug)]
pub struct ZoneExtent<'z> {
    /// First target row of the run.
    pub off: usize,
    /// Rows in the run.
    pub rows: usize,
    /// Zone summary, if the run is a zoned segment.
    pub zone: Option<&'z ZoneMeta>,
}

/// Pruning effectiveness counters for one [`top_k_scan_zoned`] call,
/// summed over all queries.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PruneStats {
    /// (query, extent) visits that scanned rows.
    pub segments_visited: u64,
    /// (query, extent) visits skipped via the zone bound.
    pub segments_skipped: u64,
    /// Rows inside skipped extents (work avoided vs the full scan).
    pub rows_skipped: u64,
}

/// Zone-pruned fused top-k scan — **bitwise-identical** results to
/// [`top_k_scan_arena`], plus [`PruneStats`].
///
/// `extents` must tile `[0, t.n())` contiguously (the store's segment
/// layout). Per query, extents are visited in ascending lower-bound
/// order; once the heap holds `top` candidates and the next extent's
/// bound is **strictly** above the heap root's distance, that extent
/// and every later one are skipped. Identity argument: the scan order
/// never changes any per-pair score (same [`score_tile`] arithmetic),
/// the heap retains the `top` smallest (d, idx) pairs under the same
/// total order regardless of insertion order, and a skipped row has
/// d̂ ≥ bound > worst.d, so it could not have displaced the root even
/// via the index tie-break (which only applies at equal distance).
/// Strictness matters: at `bound == worst.d` an equal-distance,
/// lower-index row could still displace the root, so we scan.
pub fn top_k_scan_zoned<Q: SketchPanels + ?Sized, T: SketchPanels + ?Sized>(
    dec: &Decomposition,
    q: &Q,
    t: &T,
    extents: &[ZoneExtent<'_>],
    top: usize,
    workers: usize,
) -> (Vec<Vec<(usize, f64)>>, PruneStats) {
    let (bn, tn) = (q.n(), t.n());
    let mut out: Vec<Vec<(usize, f64)>> = (0..bn).map(|_| Vec::new()).collect();
    if bn == 0 || tn == 0 || top == 0 {
        return (out, PruneStats::default());
    }
    check_arena_compat(dec, q, t);
    let mut cover = 0;
    for ext in extents {
        assert_eq!(ext.off, cover, "zone extents must tile the target contiguously");
        cover += ext.rows;
    }
    assert_eq!(cover, tn, "zone extents must cover every target row");
    let p = dec.p();
    let kf = q.k() as f64;
    let visited = AtomicU64::new(0);
    let skipped = AtomicU64::new(0);
    let rows_skipped = AtomicU64::new(0);
    let slots: Vec<(usize, &mut Vec<(usize, f64)>)> = out.iter_mut().enumerate().collect();
    run_bundles(round_robin(slots, workers), |bundle| {
        let mut buf = vec![0.0f64; ARENA_TILE];
        let mut order: Vec<(f64, usize)> = Vec::with_capacity(extents.len());
        for (qi, slot) in bundle {
            let q_norm_p = q.norm_p(qi);
            let q_u2: Vec<f64> = (1..p)
                .map(|m| {
                    let u = q.u_row(m, qi);
                    dot_views(u, u).sqrt()
                })
                .collect();
            order.clear();
            for (e, ext) in extents.iter().enumerate() {
                let b = match ext.zone {
                    Some(z) => zone_lower_bound(dec, q_norm_p, &q_u2, z, kf),
                    None => f64::NEG_INFINITY,
                };
                order.push((b, e));
            }
            order.sort_unstable_by(|a, b| {
                a.0.total_cmp(&b.0)
                    .then_with(|| extents[a.1].off.cmp(&extents[b.1].off))
            });
            let mut heap: BinaryHeap<HeapEntry> = BinaryHeap::with_capacity(top + 1);
            for (pos, &(bound, e)) in order.iter().enumerate() {
                if heap.len() == top {
                    if let Some(worst) = heap.peek() {
                        if bound.total_cmp(&worst.d) == Ordering::Greater {
                            // Bounds ascend from here and the root only
                            // improves — everything remaining is prunable.
                            for &(_, e2) in &order[pos..] {
                                skipped.fetch_add(1, AtomicOrdering::Relaxed);
                                rows_skipped
                                    .fetch_add(extents[e2].rows as u64, AtomicOrdering::Relaxed);
                            }
                            break;
                        }
                    }
                }
                visited.fetch_add(1, AtomicOrdering::Relaxed);
                let ext = &extents[e];
                let end = ext.off + ext.rows;
                let mut j0 = ext.off;
                while j0 < end {
                    let width = ARENA_TILE.min(end - j0);
                    score_tile(dec, q, t, qi, 1, j0, width, &mut buf, width);
                    for j2 in 0..width {
                        push_bounded(&mut heap, top, j0 + j2, buf[j2]);
                    }
                    j0 += width;
                }
            }
            *slot = heap
                .into_sorted_vec()
                .into_iter()
                .map(|e| (e.idx, e.d))
                .collect();
        }
    });
    let stats = PruneStats {
        segments_visited: visited.into_inner(),
        segments_skipped: skipped.into_inner(),
        rows_skipped: rows_skipped.into_inner(),
    };
    (out, stats)
}

/// Blocked all-pairs over one panel source, condensed upper-triangle
/// order (matching [`crate::baselines::exact::condensed_index`]). Row
/// tiles own contiguous condensed regions, so workers write disjoint
/// slices.
pub fn estimate_condensed_arena<A: SketchPanels + ?Sized>(
    dec: &Decomposition,
    a: &A,
    workers: usize,
) -> Vec<f64> {
    let n = a.n();
    if n < 2 {
        return Vec::new();
    }
    check_arena_compat(dec, a, a);
    let mut out = vec![0.0f64; n * (n - 1) / 2];
    let mut regions: Vec<(usize, &mut [f64])> = Vec::new();
    {
        // Rows [i0, i1) own condensed [base(i0), base(i1)) — contiguous.
        let mut rest: &mut [f64] = &mut out;
        let mut i0 = 0;
        while i0 < n - 1 {
            let i1 = (i0 + ARENA_TILE).min(n - 1);
            let len = crate::baselines::exact::condensed_base(n, i1)
                - crate::baselines::exact::condensed_base(n, i0);
            let (head, tail) = rest.split_at_mut(len);
            regions.push((i0, head));
            rest = tail;
            i0 = i1;
        }
    }
    run_bundles(round_robin(regions, workers), |bundle| {
        let mut buf = vec![0.0f64; ARENA_TILE * ARENA_TILE];
        for (i0, region) in bundle {
            let i1 = (i0 + ARENA_TILE).min(n - 1);
            let rows = i1 - i0;
            let base0 = crate::baselines::exact::condensed_base(n, i0);
            let mut j0 = i0 + 1;
            while j0 < n {
                let width = ARENA_TILE.min(n - j0);
                score_tile(dec, a, a, i0, rows, j0, width, &mut buf, width);
                for r in 0..rows {
                    let i = i0 + r;
                    let row_off = crate::baselines::exact::condensed_base(n, i) - base0;
                    for j2 in 0..width {
                        let j = j0 + j2;
                        if j > i {
                            region[row_off + j - i - 1] = buf[r * width + j2];
                        }
                    }
                }
                j0 += width;
            }
        }
    });
    out
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::core::decompose::exact_distance;
    use crate::core::variance;
    use crate::projection::sketcher::Sketcher;
    use crate::projection::{ProjectionDist, ProjectionSpec, Strategy};
    use crate::util::rng::Rng;
    use crate::util::stats::Welford;

    fn random_rows(rng: &mut Rng, d: usize, lo: f64) -> (Vec<f32>, Vec<f32>) {
        let x: Vec<f32> = (0..d).map(|_| (lo + rng.next_f64() * (1.0 - lo)) as f32).collect();
        let y: Vec<f32> = (0..d).map(|_| (lo + rng.next_f64() * (1.0 - lo)) as f32).collect();
        (x, y)
    }

    /// Monte-Carlo over projection seeds: mean → exact distance (unbiased)
    /// and empirical variance → the Lemma formula.
    fn mc_check(p: usize, strategy: Strategy, dist: ProjectionDist, var_of: impl Fn(&variance::CrossTable, usize) -> f64) {
        let mut rng = Rng::new(2024);
        let d = 64;
        let k = 32;
        let (x, y) = random_rows(&mut rng, d, 0.0);
        let x64: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        let y64: Vec<f64> = y.iter().map(|&v| v as f64).collect();
        let exact = exact_distance(&x64, &y64, p);
        let t = variance::table_for(&x64, &y64, p);
        let theory_var = var_of(&t, k);

        let dec = Decomposition::new(p).unwrap();
        let mut w = Welford::new();
        let reps = 4000;
        for rep in 0..reps {
            let spec = ProjectionSpec::new(rep as u64, k, dist, strategy);
            let sk = Sketcher::new(spec, p);
            let out = sk.sketch_rows(&[&x, &y]);
            w.push(estimate(&dec, &out[0], &out[1]));
        }
        // Unbiasedness: z-test of the MC mean against the exact distance.
        let z = w.z_against(exact);
        assert!(z.abs() < 4.5, "p={p} {strategy:?}: biased, z={z} mean={} exact={exact}", w.mean());
        // Variance within MC tolerance (sd of var-estimate ~ sqrt(2/n)·var).
        let rel = (w.sample_variance() - theory_var).abs() / theory_var;
        assert!(
            rel < 0.15,
            "p={p} {strategy:?}: var mismatch: emp={} theory={theory_var} rel={rel}",
            w.sample_variance()
        );
    }

    #[test]
    fn lemma1_mc_basic_p4() {
        mc_check(4, Strategy::Basic, ProjectionDist::Normal, variance::lemma1_var);
    }

    #[test]
    fn lemma2_mc_alternative_p4() {
        mc_check(4, Strategy::Alternative, ProjectionDist::Normal, variance::lemma2_var);
    }

    #[test]
    fn lemma5_mc_basic_p6() {
        mc_check(6, Strategy::Basic, ProjectionDist::Normal, variance::lemma5_var);
    }

    #[test]
    fn lemma6_mc_three_point_s10() {
        mc_check(4, Strategy::Basic, ProjectionDist::ThreePoint(10.0), |t, k| {
            variance::lemma6_var(t, 10.0, k)
        });
    }

    #[test]
    fn lemma6_mc_uniform() {
        mc_check(4, Strategy::Basic, ProjectionDist::Uniform, |t, k| {
            variance::lemma6_var(t, 9.0 / 5.0, k)
        });
    }

    #[test]
    fn general_p8_mc_unbiased_and_variance() {
        // The paper works out p=4 and p=6; the decomposition and the
        // general variance machinery extend to any even p — verify at
        // p=8 (moments up to x^14, so small D keeps f64 healthy).
        let mut rng = Rng::new(88);
        let d = 16;
        let k = 24;
        let (x, y) = random_rows(&mut rng, d, 0.0);
        let x64: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        let y64: Vec<f64> = y.iter().map(|&v| v as f64).collect();
        let exact = exact_distance(&x64, &y64, 8);
        let t = variance::table_for(&x64, &y64, 8);
        let theory = variance::var_basic_general(8, 3.0, &t, k);
        let dec = Decomposition::new(8).unwrap();
        let mut w = Welford::new();
        for rep in 0..4000 {
            let spec = ProjectionSpec::new(rep, k, ProjectionDist::Normal, Strategy::Basic);
            let sk = Sketcher::new(spec, 8);
            let out = sk.sketch_rows(&[&x, &y]);
            w.push(estimate(&dec, &out[0], &out[1]));
        }
        assert!(w.z_against(exact).abs() < 4.5, "p=8 biased: z={}", w.z_against(exact));
        let rel = (w.sample_variance() - theory).abs() / theory;
        assert!(rel < 0.2, "p=8 var mismatch: emp={} theory={theory}", w.sample_variance());
    }

    #[test]
    fn alt_variance_mc_p6_matches_general() {
        mc_check(6, Strategy::Alternative, ProjectionDist::Normal, |t, k| {
            variance::var_alt_general(6, 3.0, t, k)
        });
    }

    #[test]
    fn estimate_block_matches_pairwise() {
        let mut rng = Rng::new(5);
        let (x, y) = random_rows(&mut rng, 32, -1.0);
        let (z, _) = random_rows(&mut rng, 32, -1.0);
        let dec = Decomposition::new(4).unwrap();
        let sk = Sketcher::new(
            ProjectionSpec::new(1, 16, ProjectionDist::Normal, Strategy::Basic),
            4,
        );
        let rows = sk.sketch_rows(&[&x, &y, &z]);
        let block = estimate_block(&dec, &rows[..2], &rows[1..]);
        assert_eq!(block.len(), 4);
        assert_eq!(block[0], estimate(&dec, &rows[0], &rows[1]));
        assert_eq!(block[3], estimate(&dec, &rows[1], &rows[2]));
    }

    #[test]
    fn identical_rows_estimate_near_zero_distance() {
        // d(x,x)=0; the estimator is unbiased so the MC mean must → 0.
        let mut rng = Rng::new(8);
        let (x, _) = random_rows(&mut rng, 64, 0.0);
        let dec = Decomposition::new(4).unwrap();
        let mut w = Welford::new();
        for rep in 0..2000 {
            let sk = Sketcher::new(
                ProjectionSpec::new(rep, 32, ProjectionDist::Normal, Strategy::Basic),
                4,
            );
            let out = sk.sketch_rows(&[&x, &x]);
            w.push(estimate(&dec, &out[0], &out[1]));
        }
        assert!(w.z_against(0.0).abs() < 4.5, "mean={} sem={}", w.mean(), w.sem());
    }

    // ---- arena kernels -------------------------------------------------

    use crate::core::arena::SketchArena;

    fn sketch_batch(strategy: Strategy, p: usize, k: usize, n: usize, seed: u64) -> Vec<RowSketch> {
        let sk = Sketcher::new(ProjectionSpec::new(seed, k, ProjectionDist::Normal, strategy), p);
        let rows: Vec<Vec<f32>> = (0..n)
            .map(|i| (0..20).map(|t| ((i * 37 + t) as f32 * 0.13).sin()).collect())
            .collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        sk.sketch_rows(&refs)
    }

    fn assert_close(a: f64, b: f64, ctx: &str) {
        assert!(
            (a - b).abs() <= 1e-12 * b.abs().max(1.0),
            "{ctx}: {a} vs {b}"
        );
    }

    #[test]
    fn arena_block_matches_per_row_across_strategies_and_p() {
        // Cross the tile boundary (n > ARENA_TILE) and leave a ragged
        // tail (n not a multiple of the tile).
        let n = ARENA_TILE + 7;
        let bq = 9;
        for (strategy, p) in [
            (Strategy::Basic, 4),
            (Strategy::Alternative, 4),
            (Strategy::Basic, 6),
            (Strategy::Alternative, 6),
        ] {
            let rows = sketch_batch(strategy, p, 8, n, 3);
            let dec = Decomposition::new(p).unwrap();
            let tarena = SketchArena::from_rows(p, 8, &rows);
            let qarena = SketchArena::from_rows(p, 8, &rows[..bq]);
            let want = estimate_block(&dec, &rows[..bq], &rows);
            let got = estimate_block_arena(&dec, &qarena, &tarena, 3);
            assert_eq!(got.len(), want.len());
            for (idx, (g, w)) in got.iter().zip(&want).enumerate() {
                assert_close(*g, *w, &format!("{strategy:?} p={p} idx={idx}"));
            }
            // Single-pair arena accessor agrees too.
            assert_close(
                estimate_arena(&dec, &qarena, 2, &tarena, n - 1),
                estimate(&dec, &rows[2], &rows[n - 1]),
                "estimate_arena",
            );
        }
    }

    #[test]
    fn arena_topk_matches_sorted_per_row_scores() {
        let n = 2 * ARENA_TILE + 13;
        let rows = sketch_batch(Strategy::Basic, 4, 8, n, 5);
        let dec = Decomposition::new(4).unwrap();
        let tarena = SketchArena::from_rows(4, 8, &rows);
        let qarena = SketchArena::from_rows(4, 8, &rows[..4]);
        let top = 10;
        let got = top_k_scan_arena(&dec, &qarena, &tarena, top, 2);
        assert_eq!(got.len(), 4);
        for (qi, lst) in got.iter().enumerate() {
            let mut scored: Vec<(usize, f64)> = rows
                .iter()
                .enumerate()
                .map(|(j, r)| (j, estimate(&dec, &rows[qi], r)))
                .collect();
            scored.sort_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
            scored.truncate(top);
            assert_eq!(lst.len(), top);
            for (g, w) in lst.iter().zip(&scored) {
                assert_eq!(g.0, w.0, "query {qi}");
                assert_close(g.1, w.1, &format!("query {qi} target {}", g.0));
            }
        }
    }

    #[test]
    fn arena_condensed_matches_pairwise() {
        let n = ARENA_TILE + 21;
        for strategy in [Strategy::Basic, Strategy::Alternative] {
            let rows = sketch_batch(strategy, 4, 8, n, 9);
            let dec = Decomposition::new(4).unwrap();
            let arena = SketchArena::from_rows(4, 8, &rows);
            let got = estimate_condensed_arena(&dec, &arena, 3);
            assert_eq!(got.len(), n * (n - 1) / 2);
            for i in 0..n {
                for j in (i + 1)..n {
                    let idx = crate::baselines::exact::condensed_index(n, i, j);
                    assert_close(
                        got[idx],
                        estimate(&dec, &rows[i], &rows[j]),
                        &format!("{strategy:?} pair ({i},{j})"),
                    );
                }
            }
        }
    }

    #[test]
    fn arena_kernels_are_worker_count_invariant() {
        let n = ARENA_TILE * 2 + 3;
        let rows = sketch_batch(Strategy::Basic, 4, 8, n, 11);
        let dec = Decomposition::new(4).unwrap();
        let arena = SketchArena::from_rows(4, 8, &rows);
        let q = SketchArena::from_rows(4, 8, &rows[..6]);
        assert_eq!(
            estimate_block_arena(&dec, &q, &arena, 1),
            estimate_block_arena(&dec, &q, &arena, 5)
        );
        assert_eq!(
            top_k_scan_arena(&dec, &q, &arena, 7, 1),
            top_k_scan_arena(&dec, &q, &arena, 7, 5)
        );
        assert_eq!(
            estimate_condensed_arena(&dec, &arena, 1),
            estimate_condensed_arena(&dec, &arena, 5)
        );
    }

    #[test]
    fn arena_kernels_are_bitwise_invariant_under_simd_dispatch() {
        use crate::projection::simd;
        let _g = simd::lock_dispatch();
        let n = ARENA_TILE + 9;
        for (strategy, p) in [
            (Strategy::Basic, 4),
            (Strategy::Alternative, 4),
            (Strategy::Basic, 6),
            (Strategy::Alternative, 6),
        ] {
            // k = 10 straddles the 4-wide accumulator chunks (2 chunks
            // + a 2-lane tail) — the widths where a broken tail or
            // reduction order would show.
            for k in [8usize, 10] {
                let rows = sketch_batch(strategy, p, k, n, 17);
                let dec = Decomposition::new(p).unwrap();
                let tarena = SketchArena::from_rows(p, k, &rows);
                let qarena = SketchArena::from_rows(p, k, &rows[..5]);
                simd::force_scalar(false);
                let fast_block = estimate_block_arena(&dec, &qarena, &tarena, 2);
                let fast_topk = top_k_scan_arena(&dec, &qarena, &tarena, 6, 2);
                let fast_cond = estimate_condensed_arena(&dec, &tarena, 2);
                simd::force_scalar(true);
                let slow_block = estimate_block_arena(&dec, &qarena, &tarena, 2);
                let slow_topk = top_k_scan_arena(&dec, &qarena, &tarena, 6, 2);
                let slow_cond = estimate_condensed_arena(&dec, &tarena, 2);
                for (i, (f, s)) in fast_block.iter().zip(&slow_block).enumerate() {
                    assert_eq!(f.to_bits(), s.to_bits(), "{strategy:?} p={p} k={k} block {i}");
                }
                for (i, (f, s)) in fast_cond.iter().zip(&slow_cond).enumerate() {
                    assert_eq!(f.to_bits(), s.to_bits(), "{strategy:?} p={p} k={k} cond {i}");
                }
                for (qi, (fl, sl)) in fast_topk.iter().zip(&slow_topk).enumerate() {
                    assert_eq!(fl.len(), sl.len());
                    for ((fi, fd), (si, sd)) in fl.iter().zip(sl) {
                        assert_eq!(fi, si, "{strategy:?} p={p} k={k} query {qi}");
                        assert_eq!(fd.to_bits(), sd.to_bits(), "{strategy:?} p={p} k={k} query {qi}");
                    }
                }
            }
        }
    }

    #[test]
    fn arena_edge_shapes_are_nan_free() {
        let dec = Decomposition::new(4).unwrap();
        let rows1 = sketch_batch(Strategy::Basic, 4, 8, 1, 13);
        let one = SketchArena::from_rows(4, 8, &rows1);
        let empty = SketchArena::empty(4, 8);

        // n = 0 on either side: empty outputs, no panic, no NaN.
        assert!(estimate_block_arena(&dec, &empty, &one, 2).is_empty());
        assert!(estimate_block_arena(&dec, &one, &empty, 2).iter().all(|v| !v.is_nan()));
        assert_eq!(estimate_block_arena(&dec, &one, &empty, 2).len(), 0);
        assert!(top_k_scan_arena(&dec, &empty, &one, 5, 2).is_empty());
        let lists = top_k_scan_arena(&dec, &one, &empty, 5, 2);
        assert_eq!(lists.len(), 1);
        assert!(lists[0].is_empty());
        assert!(estimate_condensed_arena(&dec, &empty, 2).is_empty());
        // n = 1: a 1×1 block, an empty condensed triangle.
        let block = estimate_block_arena(&dec, &one, &one, 2);
        assert_eq!(block.len(), 1);
        assert!(!block[0].is_nan());
        assert!(estimate_condensed_arena(&dec, &one, 2).is_empty());
        // top = 0: empty lists, not a panic.
        let lists = top_k_scan_arena(&dec, &one, &one, 0, 2);
        assert!(lists[0].is_empty());
    }

    // ---- zoned top-k ---------------------------------------------------

    use crate::projection::sketcher::ColumnarBlock;

    /// Segment-shaped population: one block per scale, rows are scaled
    /// sin patterns. Returns the blocks plus the same rows flattened (so
    /// an arena built from them is bitwise-identical to the panels).
    fn zoned_population(
        strategy: Strategy,
        p: usize,
        k: usize,
        scales: &[f32],
        rows_per: usize,
        seed: u64,
    ) -> (Vec<ColumnarBlock>, Vec<RowSketch>) {
        let sk = Sketcher::new(ProjectionSpec::new(seed, k, ProjectionDist::Normal, strategy), p);
        let mut blocks = Vec::new();
        let mut rows = Vec::new();
        for (b, &scale) in scales.iter().enumerate() {
            let data: Vec<Vec<f32>> = (0..rows_per)
                .map(|i| {
                    (0..20)
                        .map(|t| scale * ((b * 91 + i * 37 + t) as f32 * 0.13).sin())
                        .collect()
                })
                .collect();
            let refs: Vec<&[f32]> = data.iter().map(|r| r.as_slice()).collect();
            let block = sk.sketch_block(&refs, 1);
            for r in 0..block.rows() {
                rows.push(block.to_row_sketch(r));
            }
            blocks.push(block);
        }
        (blocks, rows)
    }

    fn extents_of<'z>(blocks: &[ColumnarBlock], zones: &'z [ZoneMeta]) -> Vec<ZoneExtent<'z>> {
        let mut out = Vec::new();
        let mut off = 0;
        for (block, zone) in blocks.iter().zip(zones) {
            out.push(ZoneExtent { off, rows: block.rows(), zone: Some(zone) });
            off += block.rows();
        }
        out
    }

    #[test]
    fn zoned_topk_is_bitwise_identical_to_full_scan() {
        for (strategy, p) in [
            (Strategy::Basic, 4),
            (Strategy::Alternative, 4),
            (Strategy::Basic, 6),
            (Strategy::Alternative, 6),
        ] {
            // Uniform scales: bounds rarely prune, exercising the
            // visit-everything path with ragged tile edges (17-row
            // segments ≠ multiple of ARENA_TILE).
            let (blocks, rows) =
                zoned_population(strategy, p, 8, &[1.0, 1.0, 1.0, 1.0], 17, 21);
            let zones: Vec<ZoneMeta> = blocks.iter().map(ZoneMeta::from_block).collect();
            let dec = Decomposition::new(p).unwrap();
            let tarena = SketchArena::from_rows(p, 8, &rows);
            let qarena = SketchArena::from_rows(p, 8, &rows[..5]);
            let want = top_k_scan_arena(&dec, &qarena, &tarena, 7, 2);
            let (got, _) =
                top_k_scan_zoned(&dec, &qarena, &tarena, &extents_of(&blocks, &zones), 7, 2);
            assert_eq!(got, want, "{strategy:?} p={p}");
            // One zoneless extent over everything == plain full scan.
            let whole = [ZoneExtent { off: 0, rows: rows.len(), zone: None }];
            let (got, stats) = top_k_scan_zoned(&dec, &qarena, &tarena, &whole, 7, 2);
            assert_eq!(got, want, "{strategy:?} p={p} zoneless");
            assert_eq!(stats.segments_skipped, 0);
        }
    }

    #[test]
    fn zoned_topk_skips_segments_on_skewed_population_and_stays_exact() {
        // Magnitude bands: p-norms of the far bands are ≥8⁴× the near
        // band's, so their lower bounds dwarf the heap threshold.
        let (blocks, rows) =
            zoned_population(Strategy::Basic, 4, 8, &[1.0, 8.0, 64.0, 512.0], 19, 33);
        let zones: Vec<ZoneMeta> = blocks.iter().map(ZoneMeta::from_block).collect();
        let dec = Decomposition::new(4).unwrap();
        let tarena = SketchArena::from_rows(4, 8, &rows);
        let qarena = SketchArena::from_rows(4, 8, &rows[..4]);
        let extents = extents_of(&blocks, &zones);
        let want = top_k_scan_arena(&dec, &qarena, &tarena, 5, 1);
        let (got, stats) = top_k_scan_zoned(&dec, &qarena, &tarena, &extents, 5, 1);
        assert_eq!(got, want);
        assert!(
            stats.segments_skipped > 0,
            "skewed population must actually prune: {stats:?}"
        );
        assert!(stats.rows_skipped > 0);
        // Deterministic in workers — results AND counters.
        let (got5, stats5) = top_k_scan_zoned(&dec, &qarena, &tarena, &extents, 5, 5);
        assert_eq!(got5, want);
        assert_eq!(stats5, stats);
    }

    #[test]
    fn zoned_topk_handles_ties_single_rows_and_edge_shapes() {
        // All rows identical: every distance ties, ordering falls to the
        // index tie-break, and the deflated bound can never prune (it
        // sits strictly below the shared distance).
        let (blocks, rows) = zoned_population(Strategy::Basic, 4, 8, &[1.0, 1.0], 1, 41);
        let dup_blocks = [blocks[0].clone(), blocks[0].clone(), blocks[1].clone()];
        let dup_rows =
            [rows[0].clone(), rows[0].clone(), rows[1].clone()];
        let zones: Vec<ZoneMeta> = dup_blocks.iter().map(ZoneMeta::from_block).collect();
        let dec = Decomposition::new(4).unwrap();
        let tarena = SketchArena::from_rows(4, 8, &dup_rows);
        let qarena = SketchArena::from_rows(4, 8, &dup_rows[..1]);
        let extents = extents_of(&dup_blocks, &zones);
        for top in [1, 2, 3, 5] {
            // top ≥ n included: heap never fills, nothing is skippable.
            let want = top_k_scan_arena(&dec, &qarena, &tarena, top, 1);
            let (got, _) = top_k_scan_zoned(&dec, &qarena, &tarena, &extents, top, 1);
            assert_eq!(got, want, "top={top}");
        }
        // top = 0 and empty query side: empty outputs, zero stats.
        let (lists, stats) = top_k_scan_zoned(&dec, &qarena, &tarena, &extents, 0, 1);
        assert!(lists[0].is_empty());
        assert_eq!(stats, PruneStats::default());
        let empty = SketchArena::empty(4, 8);
        let (lists, stats) = top_k_scan_zoned(&dec, &empty, &tarena, &extents, 3, 1);
        assert!(lists.is_empty());
        assert_eq!(stats, PruneStats::default());
    }

    #[test]
    #[should_panic(expected = "zone extents must cover every target row")]
    fn zoned_topk_rejects_partial_extent_coverage() {
        let (_, rows) = zoned_population(Strategy::Basic, 4, 8, &[1.0], 3, 43);
        let dec = Decomposition::new(4).unwrap();
        let arena = SketchArena::from_rows(4, 8, &rows);
        let short = [ZoneExtent { off: 0, rows: 2, zone: None }];
        let _ = top_k_scan_zoned(&dec, &arena, &arena, &short, 1, 1);
    }
}
