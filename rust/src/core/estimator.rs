//! The paper's unbiased l_p^p distance estimators (§2.1, §2.2, §3, §4).
//!
//! Both projection strategies share one combine rule — the strategy only
//! changes how the sketches were *produced* (shared vs independent R):
//!
//! ```text
//! d̂ = Σx^p + Σy^p + (1/k) Σ_{m=1}^{p-1} c_m ⟨u_m, v_{p-m}⟩
//! ```

use super::decompose::Decomposition;
use crate::projection::sketcher::{RowSketch, SketchSet};

/// f64 dot product of two f32 sketch vectors.
///
/// Four independent accumulators break the sequential-FMA dependency
/// chain so the compiler can vectorize the f32→f64 convert + FMA loop
/// (≈2.3× on the estimate hot path — EXPERIMENTS.md §Perf iteration 3).
/// f64 accumulation is load-bearing: sketch entries are O(√D) and the
/// combine multiplies by binomial coefficients, so f32 accumulation
/// loses digits exactly where the distance is a small difference of
/// large terms.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += (a[i] as f64) * (b[i] as f64);
        acc[1] += (a[i + 1] as f64) * (b[i + 1] as f64);
        acc[2] += (a[i + 2] as f64) * (b[i + 2] as f64);
        acc[3] += (a[i + 3] as f64) * (b[i + 3] as f64);
    }
    let mut tail = 0.0f64;
    for i in chunks * 4..a.len() {
        tail += (a[i] as f64) * (b[i] as f64);
    }
    (acc[0] + acc[2]) + (acc[1] + acc[3]) + tail
}

/// Plain estimator from two sketch sets + exact marginal p-norms.
pub fn combine(
    dec: &Decomposition,
    u: &SketchSet,
    v: &SketchSet,
    x_norm_p: f64,
    y_norm_p: f64,
) -> f64 {
    let p = dec.p();
    debug_assert_eq!(u.orders, p - 1);
    debug_assert_eq!(v.orders, p - 1);
    let k = u.k as f64;
    let mut d = x_norm_p + y_norm_p;
    for m in 1..p {
        d += dec.coeff(m) * dot(u.u(m), v.u(p - m)) / k;
    }
    d
}

/// Plain estimator straight from two [`RowSketch`]es (marginal p-norm is
/// moment `p`). `x` is the left element of the pair (u-side sketches),
/// `y` the right (v-side) — the distinction only matters under the
/// alternative strategy.
pub fn estimate(dec: &Decomposition, x: &RowSketch, y: &RowSketch) -> f64 {
    combine(
        dec,
        &x.uside,
        y.vside(),
        x.moments.get(dec.p()),
        y.moments.get(dec.p()),
    )
}

/// Per-order sketch inner products ⟨u_m, v_{p-m}⟩/k — the raw unbiased
/// estimates of Σ x^m y^(p-m) (inputs to the margin MLE).
pub fn raw_inner_estimates(dec: &Decomposition, u: &SketchSet, v: &SketchSet) -> Vec<f64> {
    let p = dec.p();
    let k = u.k as f64;
    (1..p).map(|m| dot(u.u(m), v.u(p - m)) / k).collect()
}

/// Dense pairwise estimate matrix (row-major B×B2) — the pure-rust mirror
/// of the `estimate` PJRT artifact, for arbitrary shapes.
pub fn estimate_block(dec: &Decomposition, xs: &[RowSketch], ys: &[RowSketch]) -> Vec<f64> {
    let mut out = Vec::with_capacity(xs.len() * ys.len());
    for x in xs {
        for y in ys {
            out.push(estimate(dec, x, y));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::decompose::exact_distance;
    use crate::core::variance;
    use crate::projection::sketcher::Sketcher;
    use crate::projection::{ProjectionDist, ProjectionSpec, Strategy};
    use crate::util::rng::Rng;
    use crate::util::stats::Welford;

    fn random_rows(rng: &mut Rng, d: usize, lo: f64) -> (Vec<f32>, Vec<f32>) {
        let x: Vec<f32> = (0..d).map(|_| (lo + rng.next_f64() * (1.0 - lo)) as f32).collect();
        let y: Vec<f32> = (0..d).map(|_| (lo + rng.next_f64() * (1.0 - lo)) as f32).collect();
        (x, y)
    }

    /// Monte-Carlo over projection seeds: mean → exact distance (unbiased)
    /// and empirical variance → the Lemma formula.
    fn mc_check(p: usize, strategy: Strategy, dist: ProjectionDist, var_of: impl Fn(&variance::CrossTable, usize) -> f64) {
        let mut rng = Rng::new(2024);
        let d = 64;
        let k = 32;
        let (x, y) = random_rows(&mut rng, d, 0.0);
        let x64: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        let y64: Vec<f64> = y.iter().map(|&v| v as f64).collect();
        let exact = exact_distance(&x64, &y64, p);
        let t = variance::table_for(&x64, &y64, p);
        let theory_var = var_of(&t, k);

        let dec = Decomposition::new(p).unwrap();
        let mut w = Welford::new();
        let reps = 4000;
        for rep in 0..reps {
            let spec = ProjectionSpec::new(rep as u64, k, dist, strategy);
            let sk = Sketcher::new(spec, p);
            let out = sk.sketch_rows(&[&x, &y]);
            w.push(estimate(&dec, &out[0], &out[1]));
        }
        // Unbiasedness: z-test of the MC mean against the exact distance.
        let z = w.z_against(exact);
        assert!(z.abs() < 4.5, "p={p} {strategy:?}: biased, z={z} mean={} exact={exact}", w.mean());
        // Variance within MC tolerance (sd of var-estimate ~ sqrt(2/n)·var).
        let rel = (w.sample_variance() - theory_var).abs() / theory_var;
        assert!(
            rel < 0.15,
            "p={p} {strategy:?}: var mismatch: emp={} theory={theory_var} rel={rel}",
            w.sample_variance()
        );
    }

    #[test]
    fn lemma1_mc_basic_p4() {
        mc_check(4, Strategy::Basic, ProjectionDist::Normal, variance::lemma1_var);
    }

    #[test]
    fn lemma2_mc_alternative_p4() {
        mc_check(4, Strategy::Alternative, ProjectionDist::Normal, variance::lemma2_var);
    }

    #[test]
    fn lemma5_mc_basic_p6() {
        mc_check(6, Strategy::Basic, ProjectionDist::Normal, variance::lemma5_var);
    }

    #[test]
    fn lemma6_mc_three_point_s10() {
        mc_check(4, Strategy::Basic, ProjectionDist::ThreePoint(10.0), |t, k| {
            variance::lemma6_var(t, 10.0, k)
        });
    }

    #[test]
    fn lemma6_mc_uniform() {
        mc_check(4, Strategy::Basic, ProjectionDist::Uniform, |t, k| {
            variance::lemma6_var(t, 9.0 / 5.0, k)
        });
    }

    #[test]
    fn general_p8_mc_unbiased_and_variance() {
        // The paper works out p=4 and p=6; the decomposition and the
        // general variance machinery extend to any even p — verify at
        // p=8 (moments up to x^14, so small D keeps f64 healthy).
        let mut rng = Rng::new(88);
        let d = 16;
        let k = 24;
        let (x, y) = random_rows(&mut rng, d, 0.0);
        let x64: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        let y64: Vec<f64> = y.iter().map(|&v| v as f64).collect();
        let exact = exact_distance(&x64, &y64, 8);
        let t = variance::table_for(&x64, &y64, 8);
        let theory = variance::var_basic_general(8, 3.0, &t, k);
        let dec = Decomposition::new(8).unwrap();
        let mut w = Welford::new();
        for rep in 0..4000 {
            let spec = ProjectionSpec::new(rep, k, ProjectionDist::Normal, Strategy::Basic);
            let sk = Sketcher::new(spec, 8);
            let out = sk.sketch_rows(&[&x, &y]);
            w.push(estimate(&dec, &out[0], &out[1]));
        }
        assert!(w.z_against(exact).abs() < 4.5, "p=8 biased: z={}", w.z_against(exact));
        let rel = (w.sample_variance() - theory).abs() / theory;
        assert!(rel < 0.2, "p=8 var mismatch: emp={} theory={theory}", w.sample_variance());
    }

    #[test]
    fn alt_variance_mc_p6_matches_general() {
        mc_check(6, Strategy::Alternative, ProjectionDist::Normal, |t, k| {
            variance::var_alt_general(6, 3.0, t, k)
        });
    }

    #[test]
    fn estimate_block_matches_pairwise() {
        let mut rng = Rng::new(5);
        let (x, y) = random_rows(&mut rng, 32, -1.0);
        let (z, _) = random_rows(&mut rng, 32, -1.0);
        let dec = Decomposition::new(4).unwrap();
        let sk = Sketcher::new(
            ProjectionSpec::new(1, 16, ProjectionDist::Normal, Strategy::Basic),
            4,
        );
        let rows = sk.sketch_rows(&[&x, &y, &z]);
        let block = estimate_block(&dec, &rows[..2], &rows[1..]);
        assert_eq!(block.len(), 4);
        assert_eq!(block[0], estimate(&dec, &rows[0], &rows[1]));
        assert_eq!(block[3], estimate(&dec, &rows[1], &rows[2]));
    }

    #[test]
    fn identical_rows_estimate_near_zero_distance() {
        // d(x,x)=0; the estimator is unbiased so the MC mean must → 0.
        let mut rng = Rng::new(8);
        let (x, _) = random_rows(&mut rng, 64, 0.0);
        let dec = Decomposition::new(4).unwrap();
        let mut w = Welford::new();
        for rep in 0..2000 {
            let sk = Sketcher::new(
                ProjectionSpec::new(rep, 32, ProjectionDist::Normal, Strategy::Basic),
                4,
            );
            let out = sk.sketch_rows(&[&x, &x]);
            w.push(estimate(&dec, &out[0], &out[1]));
        }
        assert!(w.z_against(0.0).abs() < 4.5, "mean={} sem={}", w.mean(), w.sem());
    }
}
