//! Marginal-moment linear scan (the "assume a linear scan is feasible"
//! half of the paper's method) and the cross moments Σ x^a y^b the
//! variance formulas consume.

/// Marginal moments of one row: `m[i] = Σ_j x_j^(i+1)` for i+1 = 1..=n.
#[derive(Clone, Debug, PartialEq)]
pub struct Moments(pub Vec<f64>);

impl Moments {
    /// One pass over `x`, walking the Hadamard power ladder — mirrors the
    /// L1 kernel so rust fallback and PJRT artifacts agree bit-for-bit in
    /// structure (f32 vs f64 rounding aside).
    pub fn scan(x: &[f64], n: usize) -> Self {
        let mut m = vec![0.0; n];
        for &v in x {
            let mut p = 1.0;
            for slot in m.iter_mut() {
                p *= v;
                *slot += p;
            }
        }
        Moments(m)
    }

    pub fn scan_f32(x: &[f32], n: usize) -> Self {
        let mut m = vec![0.0f64; n];
        for &v in x {
            let v = v as f64;
            let mut p = 1.0;
            for slot in m.iter_mut() {
                p *= v;
                *slot += p;
            }
        }
        Moments(m)
    }

    /// Σ x^order (order >= 1).
    pub fn get(&self, order: usize) -> f64 {
        self.0[order - 1]
    }

    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    /// Moments are additive over D-chunks (streaming invariant).
    pub fn merge(&mut self, other: &Moments) {
        assert_eq!(self.0.len(), other.0.len());
        for (a, b) in self.0.iter_mut().zip(&other.0) {
            *a += b;
        }
    }
}

/// Cross moment Σ_i x_i^a y_i^b (a or b may be 0).
pub fn cross_moment(x: &[f64], y: &[f64], a: usize, b: usize) -> f64 {
    assert_eq!(x.len(), y.len());
    x.iter()
        .zip(y)
        .map(|(&xv, &yv)| xv.powi(a as i32) * yv.powi(b as i32))
        .sum()
}

/// All cross moments Σ x^a y^b for 0 <= a, b <= max_order in one pass,
/// indexed `[a][b]`. `[0][0]` = D. Used by the variance formulas, which
/// for p=6 touch ~30 distinct (a, b) pairs.
pub fn cross_moment_table(x: &[f64], y: &[f64], max_order: usize) -> Vec<Vec<f64>> {
    assert_eq!(x.len(), y.len());
    let n = max_order + 1;
    let mut t = vec![vec![0.0; n]; n];
    let mut xp = vec![0.0; n];
    let mut yp = vec![0.0; n];
    for (&xv, &yv) in x.iter().zip(y) {
        xp[0] = 1.0;
        yp[0] = 1.0;
        for i in 1..n {
            xp[i] = xp[i - 1] * xv;
            yp[i] = yp[i - 1] * yv;
        }
        for a in 0..n {
            let row = &mut t[a];
            let xa = xp[a];
            for b in 0..n {
                row[b] += xa * yp[b];
            }
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    #[test]
    fn scan_matches_naive() {
        let x = [1.0, -2.0, 0.5];
        let m = Moments::scan(&x, 4);
        for order in 1..=4 {
            let naive: f64 = x.iter().map(|v| v.powi(order as i32)).sum();
            assert!((m.get(order) - naive).abs() < 1e-12, "order {order}");
        }
    }

    #[test]
    fn f32_scan_close_to_f64() {
        let x64 = [0.25, 0.5, 0.75, 1.25];
        let x32: Vec<f32> = x64.iter().map(|&v| v as f32).collect();
        let a = Moments::scan(&x64, 6);
        let b = Moments::scan_f32(&x32, 6);
        for o in 1..=6 {
            assert!((a.get(o) - b.get(o)).abs() < 1e-6);
        }
    }

    #[test]
    fn merge_is_chunked_scan() {
        testkit::check(50, |g| {
            let x = g.vec_f64(2..64, -1.5..1.5);
            let split = g.usize_in(1, x.len());
            let whole = Moments::scan(&x, 10);
            let mut left = Moments::scan(&x[..split], 10);
            left.merge(&Moments::scan(&x[split..], 10));
            for o in 1..=10 {
                let scale = whole.get(o).abs().max(1.0);
                crate::prop_assert!(
                    (whole.get(o) - left.get(o)).abs() / scale < 1e-12,
                    "order {o}"
                );
            }
        });
    }

    #[test]
    fn cross_table_matches_pointwise() {
        testkit::check(30, |g| {
            let n = g.usize_in(1, 30);
            let x: Vec<f64> = (0..n).map(|_| g.f64_in(-1.0, 1.0)).collect();
            let y: Vec<f64> = (0..n).map(|_| g.f64_in(-1.0, 1.0)).collect();
            let t = cross_moment_table(&x, &y, 5);
            for a in 0..=5 {
                for b in 0..=5 {
                    let direct = cross_moment(&x, &y, a, b);
                    crate::prop_assert!(
                        (t[a][b] - direct).abs() < 1e-9 * direct.abs().max(1.0),
                        "a={a} b={b}"
                    );
                }
            }
        });
    }
}
