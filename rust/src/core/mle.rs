//! Margin-aware MLE estimator (paper §2.3, Lemma 4).
//!
//! Each mixed inner product a = Σ x^m y^(p-m) is re-estimated using the
//! exactly-known marginal norms mx = Σ x^(2m), my = Σ y^(2(p-m)) — the
//! [Li–Hastie–Church 2006] margin trick applied per order. â solves the
//! cubic
//!
//! ```text
//! a³ − (a²/k)·uᵀv + a·[ (mx‖v‖² + my‖u‖²)/k − mx·my ] − (mx·my/k)·uᵀv = 0
//! ```
//!
//! (u = u_m, v = v_{p-m}). The paper gives this for the alternative
//! strategy where the three orders are independent; in practice it is
//! applied under the basic strategy too (§2.3 last paragraph), which the
//! E4/E9 experiments quantify. Solved either in closed form (Cardano,
//! picking the root nearest the plain estimate — the MLE branch) or by
//! the one-step Newton iteration the paper recommends.

use super::cubic;
use super::decompose::Decomposition;
use super::estimator::dot;
use crate::projection::sketcher::RowSketch;

/// How to solve the per-order cubic.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Solve {
    /// Closed-form roots; pick the admissible one nearest the plain
    /// estimate.
    ClosedForm,
    /// One Newton–Raphson step from the plain estimate ("one-step
    /// Newton-Rhapson", §2.3).
    OneStepNewton,
}

/// MLE of one mixed inner product.
///
/// * `uv`   — uᵀv (NOT divided by k)
/// * `nu2`  — ‖u‖², `nv2` — ‖v‖²
/// * `mx`   — Σ x^(2m), `my` — Σ y^(2(p-m))
pub fn inner_mle(uv: f64, nu2: f64, nv2: f64, mx: f64, my: f64, k: usize, solve: Solve) -> f64 {
    let kf = k as f64;
    // Cubic z³ + A z² + B z + C = 0.
    let a = -uv / kf;
    let b = (mx * nv2 + my * nu2) / kf - mx * my;
    let c = -mx * my * uv / kf;
    let plain = uv / kf;
    match solve {
        Solve::OneStepNewton => cubic::newton_step(plain, a, b, c),
        Solve::ClosedForm => {
            let bound = (mx * my).sqrt(); // |Σ x^m y^(p-m)| ≤ √(mx·my)
            let roots = cubic::real_roots(a, b, c);
            roots
                .into_iter()
                .filter(|z| z.abs() <= bound * (1.0 + 1e-9))
                .min_by(|x, y| {
                    (x - plain).abs().partial_cmp(&(y - plain).abs()).unwrap()
                })
                // All roots outside the Cauchy–Schwarz ball (tiny-k noise):
                // fall back to the clamped plain estimate.
                .unwrap_or_else(|| plain.clamp(-bound, bound))
        }
    }
}

/// Margin-MLE distance estimate d̂_(p),mle from two row sketches.
pub fn estimate_mle(dec: &Decomposition, x: &RowSketch, y: &RowSketch, solve: Solve) -> f64 {
    let p = dec.p();
    let k = x.uside.k;
    let mut d = x.moments.get(p) + y.moments.get(p);
    for m in 1..p {
        let u = x.uside.u(m);
        let v = y.vside().u(p - m);
        let a_hat = inner_mle(
            dot(u, v),
            x.uside.norm2(m),
            y.vside().norm2(p - m),
            x.moments.get(2 * m),
            y.moments.get(2 * (p - m)),
            k,
            solve,
        );
        d += dec.coeff(m) * a_hat;
    }
    d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::decompose::exact_distance;
    use crate::core::estimator::estimate;
    use crate::core::variance;
    use crate::projection::sketcher::Sketcher;
    use crate::projection::{ProjectionDist, ProjectionSpec, Strategy};
    use crate::util::rng::Rng;
    use crate::util::stats::Welford;

    fn rows(seed: u64, d: usize) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..d).map(|_| rng.next_f64() as f32).collect();
        let y: Vec<f32> = (0..d).map(|_| rng.next_f64() as f32).collect();
        (x, y)
    }

    #[test]
    fn mle_root_is_exact_at_infinite_k_limit() {
        // If the sketches were noiseless (u = v = the true quantities in a
        // k=1 "perfect" setup), the cubic is satisfied by the true a.
        // Synthetic check: build uv, norms from a consistent model.
        let (mx, my, a_true) = (2.0, 3.0, 1.2);
        let k = 1000;
        // E[uᵀv] = k·a, E‖u‖² = k·mx, E‖v‖² = k·my.
        let est = inner_mle(
            k as f64 * a_true,
            k as f64 * mx,
            k as f64 * my,
            mx,
            my,
            k,
            Solve::ClosedForm,
        );
        assert!((est - a_true).abs() < 1e-9, "est={est}");
    }

    #[test]
    fn mle_unbiased_and_beats_plain_variance() {
        // MC over seeds (alternative strategy, as analyzed by Lemma 4):
        // mean → exact, variance strictly below the plain estimator's and
        // close to the Lemma 4 asymptote.
        let (x, y) = rows(31, 64);
        let x64: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        let y64: Vec<f64> = y.iter().map(|&v| v as f64).collect();
        let exact = exact_distance(&x64, &y64, 4);
        let t = variance::table_for(&x64, &y64, 4);
        let k = 64;
        let dec = Decomposition::new(4).unwrap();

        let (mut w_plain, mut w_mle, mut w_newton) =
            (Welford::new(), Welford::new(), Welford::new());
        for rep in 0..3000 {
            let spec = ProjectionSpec::new(rep, k, ProjectionDist::Normal, Strategy::Alternative);
            let sk = Sketcher::new(spec, 4);
            let out = sk.sketch_rows(&[&x, &y]);
            w_plain.push(estimate(&dec, &out[0], &out[1]));
            w_mle.push(estimate_mle(&dec, &out[0], &out[1], Solve::ClosedForm));
            w_newton.push(estimate_mle(&dec, &out[0], &out[1], Solve::OneStepNewton));
        }
        // Asymptotically unbiased: allow a small bias at finite k but the
        // mean must sit within a few percent of the exact distance.
        assert!(
            (w_mle.mean() - exact).abs() / exact < 0.05,
            "mle mean={} exact={exact}",
            w_mle.mean()
        );
        let plain_var = variance::lemma2_var(&t, k);
        let mle_var = variance::lemma4_mle_var(&t, k);
        assert!(
            w_mle.sample_variance() < w_plain.sample_variance(),
            "MLE should reduce variance: {} vs {}",
            w_mle.sample_variance(),
            w_plain.sample_variance()
        );
        // Within 30% of the asymptotic Lemma 4 prediction (O(1/k²) terms
        // and MC noise both contribute).
        let rel = (w_mle.sample_variance() - mle_var).abs() / mle_var;
        assert!(
            rel < 0.3,
            "mle var {} vs lemma4 {mle_var} (plain theory {plain_var})",
            w_mle.sample_variance()
        );
        // One-step Newton is asymptotically equivalent; at k=64 it still
        // carries an O(1/k) gap vs the full solve (E9 quantifies). It must
        // land strictly between plain and ~1.6× the full-MLE variance.
        let rel_n = (w_newton.sample_variance() - w_mle.sample_variance()).abs()
            / w_mle.sample_variance();
        assert!(rel_n < 0.8, "newton var off by {rel_n}");
        assert!(
            w_newton.sample_variance() < w_plain.sample_variance(),
            "one-step newton should still beat the plain estimator"
        );
    }

    #[test]
    fn mle_respects_cauchy_schwarz_bound() {
        crate::testkit::check(200, |g| {
            let mx = g.f64_in(0.1, 5.0);
            let my = g.f64_in(0.1, 5.0);
            let k = g.usize_in(2, 64);
            let uv = g.f64_in(-3.0, 3.0) * k as f64;
            let nu2 = g.f64_in(0.1, 5.0) * k as f64;
            let nv2 = g.f64_in(0.1, 5.0) * k as f64;
            let est = inner_mle(uv, nu2, nv2, mx, my, k, Solve::ClosedForm);
            let bound = (mx * my).sqrt() * (1.0 + 1e-6);
            crate::prop_assert!(est.abs() <= bound, "est={est} bound={bound}");
        });
    }

    #[test]
    fn one_step_newton_close_to_closed_form_at_large_k() {
        let (x, y) = rows(77, 128);
        let dec = Decomposition::new(4).unwrap();
        let spec = ProjectionSpec::new(5, 256, ProjectionDist::Normal, Strategy::Alternative);
        let sk = Sketcher::new(spec, 4);
        let out = sk.sketch_rows(&[&x, &y]);
        let a = estimate_mle(&dec, &out[0], &out[1], Solve::ClosedForm);
        let b = estimate_mle(&dec, &out[0], &out[1], Solve::OneStepNewton);
        assert!((a - b).abs() / a.abs().max(1.0) < 0.10, "{a} vs {b}");
    }

    #[test]
    fn works_for_p6_extension() {
        let (x, y) = rows(13, 64);
        let dec = Decomposition::new(6).unwrap();
        let spec = ProjectionSpec::new(5, 128, ProjectionDist::Normal, Strategy::Alternative);
        let sk = Sketcher::new(spec, 6);
        let out = sk.sketch_rows(&[&x, &y]);
        let est = estimate_mle(&dec, &out[0], &out[1], Solve::ClosedForm);
        assert!(est.is_finite());
    }
}
