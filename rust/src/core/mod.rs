//! The paper's estimation theory: decomposition, estimators, margin MLE,
//! variance formulas (Lemmas 1–6), and supporting numerics.

pub mod arena;
pub mod cubic;
pub mod quant;
pub mod decompose;
pub mod estimator;
pub mod marginals;
pub mod mle;
pub mod tail;
pub mod variance;
pub mod zone;

pub use decompose::Decomposition;
