//! Quantized sketch-panel codec: f16 / bf16 / i8-with-per-order-scale
//! storage for the columnar u/v panels.
//!
//! Sketches are already lossy estimates whose accuracy is set by the
//! width k, so panel precision beyond ~3 decimal digits buys nothing —
//! while the top-k scan at millions of rows is memory-bandwidth bound.
//! Quantized panels move 2–4× fewer bytes per row and decode **lane-wise
//! in registers** inside the dot kernels (see [`dot_views`] and
//! `projection::simd`); no f32 copy of a panel is ever materialized on
//! the scan path. Moments and marginal norms stay f64 end to end — they
//! enter the estimator exactly.
//!
//! ## Encodings and error bounds
//!
//! | encoding | storage      | per-value error       | bytes/value |
//! |----------|--------------|-----------------------|-------------|
//! | `none`   | f32          | 0 (reference)         | 4           |
//! | `f16`    | IEEE binary16| rel ≤ 2⁻¹¹ (normal)   | 2           |
//! | `bf16`   | bfloat16     | rel ≤ 2⁻⁸             | 2           |
//! | `i8`     | i8 + f32 scale per (order, side) | abs ≤ scale/2 | 1 (+ε) |
//!
//! Encoding is round-to-nearest-even everywhere; f16/bf16 saturate to
//! their largest finite value instead of overflowing to infinity, so a
//! huge sketch entry degrades an estimate instead of poisoning it.
//! Decoding is **exact** (f16/bf16 are subsets of f32; i8 decodes as
//! the single correctly-rounded product `q as f32 * scale`), which
//! makes every decoded value *the* value: kernels, zone summaries and
//! round-tripped files all agree bitwise on what a quantized panel
//! means. [`dot_error_bound`] turns the table above into an analytic
//! bound on a quantized-vs-f32 inner product — the widened-tolerance
//! property suites pin quantization error against it.

// Decoded views feed the serving-path kernels.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use crate::core::estimator::dot;

/// Panel storage encoding — the `panel-quant` config knob and the tag
/// persisted in `.lpsk` v5 / segment-file v3 headers.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum PanelQuant {
    /// Full f32 panels (the bitwise reference).
    #[default]
    None,
    /// IEEE binary16.
    F16,
    /// bfloat16 (f32 with the low 16 mantissa bits dropped).
    Bf16,
    /// i8 with one f32 scale per (order, side) panel.
    I8,
}

impl PanelQuant {
    /// Wire tag (persisted; stable across versions).
    pub fn tag(self) -> u8 {
        match self {
            PanelQuant::None => 0,
            PanelQuant::F16 => 1,
            PanelQuant::Bf16 => 2,
            PanelQuant::I8 => 3,
        }
    }

    /// Inverse of [`PanelQuant::tag`]; `None` for unknown tags (callers
    /// must reject the record *before* sizing any buffer from it).
    pub fn from_tag(tag: u8) -> Option<Self> {
        match tag {
            0 => Some(PanelQuant::None),
            1 => Some(PanelQuant::F16),
            2 => Some(PanelQuant::Bf16),
            3 => Some(PanelQuant::I8),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            PanelQuant::None => "none",
            PanelQuant::F16 => "f16",
            PanelQuant::Bf16 => "bf16",
            PanelQuant::I8 => "i8",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "none" | "f32" | "off" => Ok(PanelQuant::None),
            "f16" | "half" => Ok(PanelQuant::F16),
            "bf16" => Ok(PanelQuant::Bf16),
            "i8" | "int8" => Ok(PanelQuant::I8),
            _ => anyhow::bail!("unknown panel-quant {s:?} (want none|f16|bf16|i8)"),
        }
    }

    /// Storage bytes per panel value (i8 scales are accounted
    /// separately — one f32 per order per side).
    pub fn bytes_per_value(self) -> usize {
        match self {
            PanelQuant::None => 4,
            PanelQuant::F16 | PanelQuant::Bf16 => 2,
            PanelQuant::I8 => 1,
        }
    }

    /// Relative error bound of one encoded value (f16/bf16 in their
    /// normal range; 0 for f32, `None` for i8 whose error is absolute —
    /// see [`dot_error_bound`]).
    pub fn rel_err(self) -> Option<f64> {
        match self {
            PanelQuant::None => Some(0.0),
            PanelQuant::F16 => Some(1.0 / 2048.0),  // 2^-11
            PanelQuant::Bf16 => Some(1.0 / 256.0),  // 2^-8
            PanelQuant::I8 => None,
        }
    }
}

// ---------------------------------------------------------------------------
// Scalar conversion primitives (round-to-nearest-even, saturating)
// ---------------------------------------------------------------------------

/// f32 → IEEE binary16 bits, round-to-nearest-even; finite overflow
/// saturates to ±65504 (largest finite half) instead of ±inf.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let b = x.to_bits();
    let sign = ((b >> 16) & 0x8000) as u16;
    let abs = b & 0x7fff_ffff;
    if abs >= 0x7f80_0000 {
        // Inf / NaN keep their class (NaN payload folded into one bit).
        return sign | 0x7c00 | if abs > 0x7f80_0000 { 0x0200 } else { 0 };
    }
    let exp = (abs >> 23) as i32 - 127;
    if exp >= 16 {
        return sign | 0x7bff; // saturate: 65504.0
    }
    if exp >= -14 {
        // Normal half: RTNE on the 13 dropped mantissa bits.
        let man = abs & 0x007f_ffff;
        let base = (((exp + 15) as u32) << 10) | (man >> 13);
        let rem = man & 0x1fff;
        let round = (rem > 0x1000 || (rem == 0x1000 && base & 1 == 1)) as u32;
        let out = base + round;
        // A carry at the top exponent would round past 65504 into inf.
        return sign | if out >= 0x7c00 { 0x7bff } else { out as u16 };
    }
    if exp >= -25 {
        // Subnormal half: implicit bit joins the mantissa, then a
        // rounding shift places it at 2^-24 granularity.
        let man = (abs & 0x007f_ffff) | 0x0080_0000;
        let shift = (-exp - 1) as u32; // 14..=24
        let base = man >> shift;
        let rem = man & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let round = (rem > halfway || (rem == halfway && base & 1 == 1)) as u32;
        // A full carry promotes to the smallest normal — correct RTNE.
        return sign | (base + round) as u16;
    }
    sign // underflow to ±0
}

/// IEEE binary16 bits → f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x3ff) as u32;
    let bits = if exp == 0x1f {
        sign | 0x7f80_0000 | (man << 13)
    } else if exp == 0 {
        if man == 0 {
            sign
        } else {
            // Subnormal half = man·2⁻²⁴: normalize into an f32.
            let l = 31 - man.leading_zeros(); // top set bit, 0..=9
            sign | ((l + 103) << 23) | ((man << (23 - l)) & 0x007f_ffff)
        }
    } else {
        sign | ((exp + 112) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

/// f32 → bfloat16 bits, round-to-nearest-even; finite overflow
/// saturates to the largest finite bf16.
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    let b = x.to_bits();
    if x.is_nan() {
        return ((b >> 16) as u16) | 0x0040; // quiet, sign preserved
    }
    let base = b >> 16;
    let rem = b & 0xffff;
    let round = (rem > 0x8000 || (rem == 0x8000 && base & 1 == 1)) as u32;
    let out = base + round;
    if out & 0x7fff == 0x7f80 {
        // Finite input rounded into inf: saturate.
        return (out as u16 & 0x8000) | 0x7f7f;
    }
    out as u16
}

/// bfloat16 bits → f32 (exact).
#[inline]
pub fn bf16_bits_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// i8 quantizer for one panel: symmetric, scale = max|x| / 127 (0.0 for
/// an all-zero panel). Non-finite entries quantize to 0 — a NaN lane
/// must not poison the whole panel's scale.
pub fn i8_scale_for(values: &[f32]) -> f32 {
    let max = values.iter().filter(|v| v.is_finite()).fold(0.0f32, |m, &v| m.max(v.abs()));
    if max == 0.0 {
        0.0
    } else {
        max / 127.0
    }
}

/// Quantize one value at `scale` (round-to-nearest, clamped to ±127).
#[inline]
pub fn i8_encode(x: f32, scale: f32) -> i8 {
    if scale == 0.0 || !x.is_finite() {
        return 0;
    }
    (x / scale).round().clamp(-127.0, 127.0) as i8
}

/// Decode one i8 lane — the single correctly-rounded f32 product every
/// consumer (kernels, zones, round-trips) agrees on.
#[inline]
pub fn i8_decode(q: i8, scale: f32) -> f32 {
    q as f32 * scale
}

// ---------------------------------------------------------------------------
// Panel storage + row views
// ---------------------------------------------------------------------------

/// Backing storage of one side's order-major sketch panels. All
/// variants hold `orders · rows · k` values in the arena layout; `I8`
/// additionally carries one scale per order.
#[derive(Clone, Debug, PartialEq)]
pub enum PanelStore {
    F32(Vec<f32>),
    F16(Vec<u16>),
    Bf16(Vec<u16>),
    I8 {
        data: Vec<i8>,
        /// `scales[m-1]` is order m's quantization scale.
        scales: Vec<f32>,
    },
}

impl PanelStore {
    pub fn encoding(&self) -> PanelQuant {
        match self {
            PanelStore::F32(_) => PanelQuant::None,
            PanelStore::F16(_) => PanelQuant::F16,
            PanelStore::Bf16(_) => PanelQuant::Bf16,
            PanelStore::I8 { .. } => PanelQuant::I8,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            PanelStore::F32(v) => v.len(),
            PanelStore::F16(v) | PanelStore::Bf16(v) => v.len(),
            PanelStore::I8 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Storage bytes (values + i8 scales).
    pub fn bytes(&self) -> usize {
        match self {
            PanelStore::F32(v) => v.len() * 4,
            PanelStore::F16(v) | PanelStore::Bf16(v) => v.len() * 2,
            PanelStore::I8 { data, scales } => data.len() + scales.len() * 4,
        }
    }

    /// Encode an f32 panel buffer (`orders` consecutive panels of
    /// `panel_len` values each) into `q` storage.
    pub fn encode(values: Vec<f32>, q: PanelQuant, orders: usize, panel_len: usize) -> PanelStore {
        debug_assert_eq!(values.len(), orders * panel_len);
        match q {
            PanelQuant::None => PanelStore::F32(values),
            PanelQuant::F16 => {
                PanelStore::F16(values.iter().map(|&x| f32_to_f16_bits(x)).collect())
            }
            PanelQuant::Bf16 => {
                PanelStore::Bf16(values.iter().map(|&x| f32_to_bf16_bits(x)).collect())
            }
            PanelQuant::I8 => {
                let scales: Vec<f32> = (0..orders)
                    .map(|m| i8_scale_for(&values[m * panel_len..(m + 1) * panel_len]))
                    .collect();
                let data = values
                    .iter()
                    .enumerate()
                    .map(|(i, &x)| i8_encode(x, scales[if panel_len == 0 { 0 } else { i / panel_len }]))
                    .collect();
                PanelStore::I8 { data, scales }
            }
        }
    }

    /// Row view of `len` values at element offset `off`; `order_idx` is
    /// the 0-based order (selects the i8 scale).
    #[inline]
    pub fn view(&self, order_idx: usize, off: usize, len: usize) -> RowView<'_> {
        match self {
            PanelStore::F32(v) => RowView::F32(&v[off..off + len]),
            PanelStore::F16(v) => RowView::F16(&v[off..off + len]),
            PanelStore::Bf16(v) => RowView::Bf16(&v[off..off + len]),
            PanelStore::I8 { data, scales } => {
                RowView::I8 { q: &data[off..off + len], scale: scales[order_idx] }
            }
        }
    }

    /// Decode `len` values at element offset `off` into `out`
    /// (`order_idx` selects the i8 scale). F32 storage is a straight
    /// copy.
    pub fn decode_into(&self, order_idx: usize, off: usize, out: &mut [f32]) {
        self.view(order_idx, off, out.len()).decode_into(out);
    }

    /// Per-order i8 scales (`None` for every other encoding).
    pub fn i8_scales(&self) -> Option<&[f32]> {
        match self {
            PanelStore::I8 { scales, .. } => Some(scales),
            _ => None,
        }
    }

    /// Byte-concatenate same-encoding stores covering consecutive row
    /// ranges — the compaction fast path. Each part is `(store, rows)`,
    /// order-major with `k` values per row. Returns `None` unless every
    /// part shares the first's encoding — and, for i8, its exact
    /// per-order scales (re-encoding at a merged scale would *change*
    /// decoded values and invalidate zone summaries). Callers hitting
    /// `None` decode to f32 and concat there; decode is value-exact, so
    /// either route yields the same decoded values.
    pub fn concat_rows(
        parts: &[(&PanelStore, usize)],
        orders: usize,
        k: usize,
    ) -> Option<PanelStore> {
        let (first, _) = *parts.first()?;
        let enc = first.encoding();
        if parts.iter().any(|(s, _)| s.encoding() != enc) {
            return None;
        }
        let total: usize = parts.iter().map(|&(_, r)| r).sum();
        fn gather<T: Copy + Default>(
            parts: &[(&PanelStore, usize)],
            orders: usize,
            k: usize,
            total: usize,
            slice_of: impl Fn(&PanelStore) -> Option<&[T]>,
        ) -> Option<Vec<T>> {
            let mut out = vec![T::default(); orders * total * k];
            for m in 0..orders {
                let mut r0 = 0usize;
                for &(part, rows) in parts {
                    let src = slice_of(part)?;
                    out.get_mut((m * total + r0) * k..(m * total + r0 + rows) * k)?
                        .copy_from_slice(src.get(m * rows * k..(m * rows + rows) * k)?);
                    r0 += rows;
                }
            }
            Some(out)
        }
        match first {
            PanelStore::F32(_) => Some(PanelStore::F32(gather(parts, orders, k, total, |s| {
                match s {
                    PanelStore::F32(v) => Some(v.as_slice()),
                    _ => None,
                }
            })?)),
            PanelStore::F16(_) => Some(PanelStore::F16(gather(parts, orders, k, total, |s| {
                match s {
                    PanelStore::F16(v) => Some(v.as_slice()),
                    _ => None,
                }
            })?)),
            PanelStore::Bf16(_) => Some(PanelStore::Bf16(gather(parts, orders, k, total, |s| {
                match s {
                    PanelStore::Bf16(v) => Some(v.as_slice()),
                    _ => None,
                }
            })?)),
            PanelStore::I8 { scales, .. } => {
                if parts.iter().any(|(s, _)| s.i8_scales() != Some(scales.as_slice())) {
                    return None;
                }
                Some(PanelStore::I8 {
                    data: gather(parts, orders, k, total, |s| match s {
                        PanelStore::I8 { data, .. } => Some(data.as_slice()),
                        _ => None,
                    })?,
                    scales: scales.clone(),
                })
            }
        }
    }
}

/// Borrowed view of one sketch row in its storage encoding. Kernels
/// consume views directly ([`dot_views`]), decoding lane-wise in
/// registers — a quantized panel is never expanded to f32 in memory on
/// the scan path.
#[derive(Clone, Copy, Debug)]
pub enum RowView<'a> {
    F32(&'a [f32]),
    F16(&'a [u16]),
    Bf16(&'a [u16]),
    I8 { q: &'a [i8], scale: f32 },
}

impl<'a> RowView<'a> {
    #[inline]
    pub fn len(&self) -> usize {
        match self {
            RowView::F32(v) => v.len(),
            RowView::F16(v) | RowView::Bf16(v) => v.len(),
            RowView::I8 { q, .. } => q.len(),
        }
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Decode one lane to f32 (exact — see module docs).
    #[inline]
    pub fn get(&self, i: usize) -> f32 {
        match self {
            RowView::F32(v) => v[i],
            RowView::F16(v) => f16_bits_to_f32(v[i]),
            RowView::Bf16(v) => bf16_bits_to_f32(v[i]),
            RowView::I8 { q, scale } => i8_decode(q[i], *scale),
        }
    }

    /// The f32 slice behind an unquantized view.
    pub fn as_f32(&self) -> Option<&'a [f32]> {
        match self {
            RowView::F32(v) => Some(v),
            _ => None,
        }
    }

    pub fn decode_into(&self, out: &mut [f32]) {
        match self {
            RowView::F32(v) => out.copy_from_slice(v),
            _ => {
                for (i, slot) in out.iter_mut().enumerate() {
                    *slot = self.get(i);
                }
            }
        }
    }

    pub fn to_f32_vec(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.len()];
        self.decode_into(&mut out);
        out
    }
}

/// f64 dot product of two row views — the quantized counterpart of
/// [`crate::core::estimator::dot`], with **the identical accumulation
/// contract**: four independent f64 accumulators over chunks of 4,
/// scalar tail, final reduction `(acc0 + acc2) + (acc1 + acc3) + tail`.
/// Lanes are decoded to f32 in registers, widened to f64, multiplied
/// and added in exactly that order, so:
///
/// * two `F32` views reproduce `dot` bitwise (it *is* `dot`, routed
///   through the same SIMD dispatch), and
/// * a quantized view differs from its f32 original only by the
///   encoding error of the stored lanes — bounded analytically by
///   [`dot_error_bound`] — never by accumulation-order drift.
#[inline]
pub fn dot_views(a: RowView<'_>, b: RowView<'_>) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    match (a, b) {
        (RowView::F32(x), RowView::F32(y)) => dot(x, y),
        (RowView::F16(x), RowView::F16(y)) => crate::projection::simd::dot_f16_f16(x, y),
        (RowView::F32(x), RowView::F16(y)) => crate::projection::simd::dot_f32_f16(x, y),
        // IEEE multiplication commutes bitwise, and the accumulation
        // contract is symmetric in the operands — swapping sides is
        // exact.
        (RowView::F16(x), RowView::F32(y)) => crate::projection::simd::dot_f32_f16(y, x),
        _ => dot_views_generic(a, b),
    }
}

/// Portable any-encoding dot: per-lane decode via [`RowView::get`],
/// same 4-accumulator contract. The reference the SIMD f16 paths must
/// match bitwise (their decodes are exact, so equal inputs ⇒ equal
/// roundings).
pub fn dot_views_generic(a: RowView<'_>, b: RowView<'_>) -> f64 {
    let n = a.len();
    debug_assert_eq!(n, b.len());
    let mut acc = [0.0f64; 4];
    let chunks = n / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += (a.get(i) as f64) * (b.get(i) as f64);
        acc[1] += (a.get(i + 1) as f64) * (b.get(i + 1) as f64);
        acc[2] += (a.get(i + 2) as f64) * (b.get(i + 2) as f64);
        acc[3] += (a.get(i + 3) as f64) * (b.get(i + 3) as f64);
    }
    let mut tail = 0.0f64;
    for i in chunks * 4..n {
        tail += (a.get(i) as f64) * (b.get(i) as f64);
    }
    (acc[0] + acc[2]) + (acc[1] + acc[3]) + tail
}

/// Analytic bound on `|⟨ũ, ṽ⟩ − ⟨u, v⟩|` where ũ/ṽ are `u`/`v` encoded
/// at `(qu, su)` / `(qv, sv)` (`s*` = the i8 scale, ignored otherwise).
///
/// With per-value errors `|δu_i| ≤ eu·|u_i| + au` and
/// `|δv_i| ≤ ev·|v_i| + av` (relative for f16/bf16, absolute for i8):
///
/// ```text
/// |Σ δ| ≤ Σ (|u_i||δv_i| + |v_i||δu_i| + |δu_i||δv_i|)
/// ```
///
/// expanded term-by-term below. A small headroom factor absorbs the
/// f64 rounding of the bound computation itself; the property suites
/// assert observed error ≤ this bound.
pub fn dot_error_bound(
    u: &[f32],
    v: &[f32],
    qu: PanelQuant,
    su: f32,
    qv: PanelQuant,
    sv: f32,
) -> f64 {
    let (eu, au) = per_value_err(qu, su);
    let (ev, av) = per_value_err(qv, sv);
    let mut bound = 0.0f64;
    for (&x, &y) in u.iter().zip(v) {
        let (ax, ay) = (x.abs() as f64, y.abs() as f64);
        let du = eu * ax + au;
        let dv = ev * ay + av;
        bound += ax * dv + ay * du + du * dv;
    }
    // Headroom: the bound itself rounds in f64, and i8 decode rounds
    // once per lane (≤ 2⁻²⁴ relative) on top of the quantization step.
    bound * 1.001 + 1e-12
}

/// (relative, absolute) per-value error of one encoding. f16 values
/// below the normal range (|x| < 2⁻¹⁴) incur an absolute subnormal
/// quantum instead of the relative bound.
fn per_value_err(q: PanelQuant, scale: f32) -> (f64, f64) {
    match q {
        PanelQuant::None => (0.0, 0.0),
        PanelQuant::F16 => (1.0 / 2048.0, 2.0f64.powi(-25)),
        PanelQuant::Bf16 => (1.0 / 256.0, 0.0),
        PanelQuant::I8 => (0.0, scale as f64 * 0.5),
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample(rng: &mut Rng, n: usize, scale: f64) -> Vec<f32> {
        (0..n).map(|_| ((rng.next_f64() - 0.5) * 2.0 * scale) as f32).collect()
    }

    #[test]
    fn f16_roundtrip_is_exact_for_representables() {
        // Every finite f16 must survive f32→f16→f32 bitwise.
        for h in 0..=u16::MAX {
            let exp = (h >> 10) & 0x1f;
            if exp == 0x1f {
                continue; // inf/nan
            }
            let x = f16_bits_to_f32(h);
            let back = f32_to_f16_bits(x);
            assert_eq!(back, h, "half bits {h:#06x} -> {x} -> {back:#06x}");
        }
    }

    #[test]
    fn f16_error_is_within_half_ulp() {
        let mut rng = Rng::new(7);
        for _ in 0..2000 {
            let x = ((rng.next_f64() - 0.5) * 100.0) as f32;
            let y = f16_bits_to_f32(f32_to_f16_bits(x));
            let err = (y - x).abs() as f64;
            assert!(
                err <= (x.abs() as f64) / 2048.0 + 2.0f64.powi(-25),
                "x={x} y={y} err={err}"
            );
        }
    }

    #[test]
    fn f16_saturates_instead_of_overflowing() {
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1e9)), 65504.0);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(-1e9)), -65504.0);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(65505.0)), 65504.0);
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::INFINITY)).is_infinite());
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
    }

    #[test]
    fn f16_rtne_ties() {
        // 2049 sits exactly between representable halves 2048 and 2050:
        // round-to-even picks 2048.
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(2049.0)), 2048.0);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(2051.0)), 2052.0);
    }

    #[test]
    fn f16_subnormals_roundtrip() {
        let tiny = 2.0f32.powi(-24); // smallest positive half subnormal
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(tiny)), tiny);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(tiny * 0.4)), 0.0);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(2.0f32.powi(-15))), 2.0f32.powi(-15));
    }

    #[test]
    fn bf16_roundtrip_and_saturation() {
        let mut rng = Rng::new(11);
        for _ in 0..2000 {
            let x = ((rng.next_f64() - 0.5) * 1e6) as f32;
            let y = bf16_bits_to_f32(f32_to_bf16_bits(x));
            assert!(((y - x).abs() as f64) <= (x.abs() as f64) / 256.0, "x={x} y={y}");
        }
        assert!(bf16_bits_to_f32(f32_to_bf16_bits(f32::MAX)).is_finite());
        assert!(bf16_bits_to_f32(f32_to_bf16_bits(f32::NAN)).is_nan());
    }

    #[test]
    fn i8_error_within_half_scale() {
        let mut rng = Rng::new(13);
        let vals = sample(&mut rng, 512, 8.0);
        let scale = i8_scale_for(&vals);
        for &x in &vals {
            let y = i8_decode(i8_encode(x, scale), scale);
            assert!(
                ((y - x).abs() as f64) <= scale as f64 * 0.5 + 1e-7,
                "x={x} y={y} scale={scale}"
            );
        }
        // Degenerate panels stay representable.
        assert_eq!(i8_scale_for(&[0.0; 8]), 0.0);
        assert_eq!(i8_encode(1.0, 0.0), 0);
        assert_eq!(i8_encode(f32::NAN, 1.0), 0);
    }

    #[test]
    fn panel_store_encodes_per_order_scales() {
        // Two orders with very different magnitudes: per-order scales
        // must keep the small order's resolution.
        let panel_len = 64;
        let mut rng = Rng::new(17);
        let mut vals = sample(&mut rng, panel_len, 0.01);
        vals.extend(sample(&mut rng, panel_len, 100.0));
        let store = PanelStore::encode(vals.clone(), PanelQuant::I8, 2, panel_len);
        let scales = store.i8_scales().unwrap();
        assert!(scales[0] < scales[1] / 100.0, "scales {scales:?}");
        for m in 0..2 {
            let mut out = vec![0.0f32; panel_len];
            store.decode_into(m, m * panel_len, &mut out);
            for (i, (&got, &want)) in out.iter().zip(&vals[m * panel_len..]).enumerate() {
                assert!(
                    ((got - want).abs() as f64) <= scales[m] as f64 * 0.5 + 1e-7,
                    "order {m} lane {i}: {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn dot_views_f32_is_bitwise_dot() {
        let mut rng = Rng::new(19);
        for n in [0usize, 1, 3, 4, 7, 8, 64, 129] {
            let a = sample(&mut rng, n, 2.0);
            let b = sample(&mut rng, n, 2.0);
            let via_views = dot_views(RowView::F32(&a), RowView::F32(&b));
            assert_eq!(via_views.to_bits(), dot(&a, &b).to_bits(), "n={n}");
            // The generic per-lane path implements the same contract.
            let generic = dot_views_generic(RowView::F32(&a), RowView::F32(&b));
            assert_eq!(generic.to_bits(), dot(&a, &b).to_bits(), "generic n={n}");
        }
    }

    #[test]
    fn quantized_dot_error_is_within_analytic_bound() {
        let mut rng = Rng::new(23);
        for q in [PanelQuant::F16, PanelQuant::Bf16, PanelQuant::I8] {
            for n in [5usize, 32, 64, 257] {
                let a = sample(&mut rng, n, 3.0);
                let b = sample(&mut rng, n, 3.0);
                let sa = PanelStore::encode(a.clone(), q, 1, n);
                let sb = PanelStore::encode(b.clone(), q, 1, n);
                let (ssa, ssb) = (
                    sa.i8_scales().map_or(0.0, |s| s[0]),
                    sb.i8_scales().map_or(0.0, |s| s[0]),
                );
                let exact = dot(&a, &b);
                let approx = dot_views(sa.view(0, 0, n), sb.view(0, 0, n));
                let bound = dot_error_bound(&a, &b, q, ssa, q, ssb);
                assert!(
                    (approx - exact).abs() <= bound,
                    "{}: n={n} err={} bound={bound}",
                    q.name(),
                    (approx - exact).abs()
                );
                // Mixed f32 × quantized (the serving top-k shape).
                let mixed = dot_views(RowView::F32(&a), sb.view(0, 0, n));
                let mbound = dot_error_bound(&a, &b, PanelQuant::None, 0.0, q, ssb);
                assert!((mixed - exact).abs() <= mbound, "{} mixed n={n}", q.name());
            }
        }
    }

    #[test]
    fn decoded_views_are_deterministic() {
        // decode_into, get and to_f32_vec must agree bitwise — the
        // decoded value is *the* value everywhere.
        let mut rng = Rng::new(29);
        let vals = sample(&mut rng, 96, 5.0);
        for q in [PanelQuant::None, PanelQuant::F16, PanelQuant::Bf16, PanelQuant::I8] {
            let store = PanelStore::encode(vals.clone(), q, 3, 32);
            for m in 0..3 {
                let view = store.view(m, m * 32, 32);
                let vec = view.to_f32_vec();
                for i in 0..32 {
                    assert_eq!(vec[i].to_bits(), view.get(i).to_bits());
                }
            }
        }
    }

    #[test]
    fn tags_roundtrip_and_unknown_rejected() {
        for q in [PanelQuant::None, PanelQuant::F16, PanelQuant::Bf16, PanelQuant::I8] {
            assert_eq!(PanelQuant::from_tag(q.tag()), Some(q));
            assert_eq!(PanelQuant::parse(q.name()).unwrap(), q);
        }
        for t in 4..=u8::MAX {
            assert_eq!(PanelQuant::from_tag(t), None);
        }
        assert!(PanelQuant::parse("q4").is_err());
    }
}
