//! Confidence intervals for the sketch estimators — the paper's
//! "extension" direction (its companion works, [15][18], develop tail
//! bounds for the p ≤ 2 estimators; here we provide the practical
//! equivalent for even p ≥ 4).
//!
//! Two routes:
//! * **Plug-in Gaussian CI** — the estimator is a mean of k i.i.d.
//!   per-column terms, so it is asymptotically normal with the Lemma
//!   1/2/6 variance; plugging sketch-measurable proxies for the unknown
//!   cross-moments gives a usable interval. We use the conservative
//!   Cauchy–Schwarz closure: every |Σxᵃyᵇ| in the variance formula is
//!   bounded by √(Σx^2a · Σy^2b), all computable from the stored
//!   marginal moments alone.
//! * **Empirical (per-column) CI** — the k per-column combine terms are
//!   themselves i.i.d. samples of the estimator; their sample variance
//!   gives a self-normalized interval with no formula at all.
//!
//! E-coverage tests verify both intervals hit nominal coverage.

use super::decompose::Decomposition;
use crate::core::marginals::Moments;
use crate::projection::sketcher::RowSketch;

/// Two-sided normal quantile for common confidence levels.
pub fn z_quantile(confidence: f64) -> f64 {
    // Acklam-style rational approximation of Φ⁻¹((1+c)/2); accurate to
    // ~1e-4 over the levels we use — far inside CI-width noise.
    let p = (1.0 + confidence) / 2.0;
    assert!((0.5..1.0).contains(&p), "confidence in (0,1)");
    inverse_normal_cdf(p)
}

fn inverse_normal_cdf(p: f64) -> f64 {
    // Peter Acklam's approximation.
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    let p_low = 0.02425;
    if p < p_low {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - p_low {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        -inverse_normal_cdf(1.0 - p)
    }
}

/// A confidence interval around an estimate.
#[derive(Clone, Copy, Debug)]
pub struct Interval {
    pub estimate: f64,
    pub lo: f64,
    pub hi: f64,
}

impl Interval {
    pub fn contains(&self, v: f64) -> bool {
        self.lo <= v && v <= self.hi
    }

    pub fn width(&self) -> f64 {
        self.hi - self.lo
    }
}

/// Conservative variance upper bound from marginal moments alone
/// (Cauchy–Schwarz closure of the Lemma 2 formula; strategy-independent
/// upper bound on non-negative data by Lemma 3, and an upper bound of
/// Lemma 1's |cross terms| in general).
pub fn variance_upper_bound(p: usize, mx: &Moments, my: &Moments, s: f64, k: usize) -> f64 {
    let dec = Decomposition::new(p).expect("valid p");
    let mut var = 0.0;
    for m in 1..p {
        let c = dec.coeff(m);
        // Var of one inner-product estimator ≤ (Σx^2m Σy^2(p−m)
        //   + (Σxᵐy^{p−m})² + |s−3|·Σx^2m y^2(p−m)) / k,
        // each unknown bounded via Cauchy–Schwarz by marginal moments.
        let xa = mx.get(2 * m);
        let yb = my.get(2 * (p - m));
        let cross2 = xa * yb; // ≥ (Σ xᵐ y^{p−m})²  and ≥ Σx^2m y^2(p−m)
        var += c * c * (xa * yb + cross2 + (s - 3.0).abs() * cross2);
    }
    // Cross-order covariances (basic strategy): bound each |cov| by the
    // product of the component sds (Cauchy–Schwarz again).
    let mut sds: Vec<f64> = Vec::with_capacity(p - 1);
    for m in 1..p {
        let xa = mx.get(2 * m);
        let yb = my.get(2 * (p - m));
        sds.push((2.0 + (s - 3.0).abs()) * xa * yb);
    }
    for i in 0..sds.len() {
        for j in 0..sds.len() {
            if i != j {
                let ci = dec.coeff(i + 1).abs();
                let cj = dec.coeff(j + 1).abs();
                var += ci * cj * (sds[i] * sds[j]).sqrt();
            }
        }
    }
    var / k as f64
}

/// Plug-in CI from the stored sketches' marginal moments.
pub fn plugin_interval(
    dec: &Decomposition,
    x: &RowSketch,
    y: &RowSketch,
    s: f64,
    confidence: f64,
) -> Interval {
    let estimate = crate::core::estimator::estimate(dec, x, y);
    let var = variance_upper_bound(dec.p(), &x.moments, &y.moments, s, x.uside.k);
    let half = z_quantile(confidence) * var.sqrt();
    Interval { estimate, lo: estimate - half, hi: estimate + half }
}

/// Empirical CI from the k per-column combine terms.
///
/// Column j's term `Σ_m c_m u_{m,j} v_{p−m,j}` is one i.i.d. draw of the
/// (centered) inner-product part; their sample sd / √k self-normalizes
/// the interval.
pub fn empirical_interval(
    dec: &Decomposition,
    x: &RowSketch,
    y: &RowSketch,
    confidence: f64,
) -> Interval {
    let p = dec.p();
    let k = x.uside.k;
    let margins = x.moments.get(p) + y.moments.get(p);
    let mut w = crate::util::stats::Welford::new();
    let v = y.vside();
    for j in 0..k {
        let mut term = 0.0;
        for m in 1..p {
            term += dec.coeff(m) * (x.uside.u(m)[j] as f64) * (v.u(p - m)[j] as f64);
        }
        w.push(term);
    }
    let estimate = margins + w.mean();
    let half = z_quantile(confidence) * w.sem();
    Interval { estimate, lo: estimate - half, hi: estimate + half }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::decompose::exact_distance;
    use crate::projection::sketcher::Sketcher;
    use crate::projection::{ProjectionDist, ProjectionSpec, Strategy};
    use crate::util::rng::Rng;

    #[test]
    fn z_quantiles_match_tables() {
        assert!((z_quantile(0.95) - 1.9600).abs() < 1e-3);
        assert!((z_quantile(0.90) - 1.6449).abs() < 1e-3);
        assert!((z_quantile(0.99) - 2.5758).abs() < 1e-3);
    }

    fn pair(d: usize, seed: u64) -> (Vec<f32>, Vec<f32>, f64) {
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..d).map(|_| rng.next_f64() as f32).collect();
        let y: Vec<f32> = (0..d).map(|_| rng.next_f64() as f32).collect();
        let x64: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        let y64: Vec<f64> = y.iter().map(|&v| v as f64).collect();
        let exact = exact_distance(&x64, &y64, 4);
        (x, y, exact)
    }

    #[test]
    fn empirical_interval_centers_on_estimate() {
        let (x, y, _) = pair(64, 1);
        let dec = Decomposition::new(4).unwrap();
        let sk = Sketcher::new(
            ProjectionSpec::new(3, 64, ProjectionDist::Normal, Strategy::Basic),
            4,
        );
        let rows = sk.sketch_rows(&[&x, &y]);
        let iv = empirical_interval(&dec, &rows[0], &rows[1], 0.95);
        let plain = crate::core::estimator::estimate(&dec, &rows[0], &rows[1]);
        assert!((iv.estimate - plain).abs() < 1e-9 * (1.0 + plain.abs()));
        assert!(iv.lo < iv.estimate && iv.estimate < iv.hi);
    }

    #[test]
    fn empirical_coverage_near_nominal() {
        let (x, y, exact) = pair(64, 2);
        let dec = Decomposition::new(4).unwrap();
        let mut hits = 0;
        let reps = 600;
        for seed in 0..reps {
            let sk = Sketcher::new(
                ProjectionSpec::new(seed, 96, ProjectionDist::Normal, Strategy::Basic),
                4,
            );
            let rows = sk.sketch_rows(&[&x, &y]);
            if empirical_interval(&dec, &rows[0], &rows[1], 0.95).contains(exact) {
                hits += 1;
            }
        }
        let coverage = hits as f64 / reps as f64;
        // Nominal 95% ± finite-k slack (per-column terms are heavy-tailed).
        assert!((0.88..=1.0).contains(&coverage), "coverage {coverage}");
    }

    #[test]
    fn plugin_interval_is_conservative() {
        // The Cauchy–Schwarz closure over-covers by design.
        let (x, y, exact) = pair(64, 3);
        let dec = Decomposition::new(4).unwrap();
        let mut hits = 0;
        let reps = 300;
        for seed in 0..reps {
            let sk = Sketcher::new(
                ProjectionSpec::new(seed, 64, ProjectionDist::Normal, Strategy::Basic),
                4,
            );
            let rows = sk.sketch_rows(&[&x, &y]);
            if plugin_interval(&dec, &rows[0], &rows[1], 3.0, 0.95).contains(exact) {
                hits += 1;
            }
        }
        let coverage = hits as f64 / reps as f64;
        assert!(coverage >= 0.95, "conservative interval under-covers: {coverage}");
    }

    #[test]
    fn plugin_width_shrinks_with_k() {
        let (x, y, _) = pair(64, 4);
        let dec = Decomposition::new(4).unwrap();
        let width = |k: usize| {
            let sk = Sketcher::new(
                ProjectionSpec::new(9, k, ProjectionDist::Normal, Strategy::Basic),
                4,
            );
            let rows = sk.sketch_rows(&[&x, &y]);
            plugin_interval(&dec, &rows[0], &rows[1], 3.0, 0.95).width()
        };
        let w16 = width(16);
        let w256 = width(256);
        assert!(w256 < w16 / 2.0, "width should shrink ~1/sqrt(k): {w16} vs {w256}");
    }

    #[test]
    fn variance_bound_dominates_lemma1() {
        use crate::core::variance;
        let (x, y, _) = pair(48, 5);
        let x64: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        let y64: Vec<f64> = y.iter().map(|&v| v as f64).collect();
        let t = variance::table_for(&x64, &y64, 4);
        let mx = Moments::scan(&x64, 6);
        let my = Moments::scan(&y64, 6);
        let bound = variance_upper_bound(4, &mx, &my, 3.0, 32);
        let lemma1 = variance::lemma1_var(&t, 32);
        assert!(bound >= lemma1, "bound {bound} < lemma1 {lemma1}");
    }
}
