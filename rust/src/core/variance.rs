//! The paper's variance theory (Lemmas 1, 2, 4, 5, 6 and the Δ₄ / Δ₆
//! strategy gaps), in two independent implementations that are tested
//! against each other:
//!
//! 1. **Hard-coded transcriptions** of the formulas exactly as printed in
//!    the paper (`lemma1_var`, `lemma2_var`, `delta4`, `lemma4_mle_var`,
//!    `lemma5_var`, `delta6`, `lemma6_var`).
//! 2. A **general derivation** for any even p and any projection kurtosis
//!    `s = E r⁴` (`var_basic_general`, `var_alt_general`), built from the
//!    Isserlis/Wick identity for four projected factors sharing a column r:
//!
//!    ```text
//!    E[(w₁ᵀr)(w₂ᵀr)(w₃ᵀr)(w₄ᵀr)] = ⟨w₁,w₂⟩⟨w₃,w₄⟩ + ⟨w₁,w₃⟩⟨w₂,w₄⟩
//!                                 + ⟨w₁,w₄⟩⟨w₂,w₃⟩ + (s−3)Σᵢ w₁w₂w₃w₄
//!    ```
//!
//!    with w = x^∘a or y^∘b, so every term reduces to a cross moment
//!    Σ xᵃyᵇ. Setting p=4, s=3 reproduces Lemma 1 term-by-term; s free
//!    reproduces Lemma 6; dropping cross-order terms reproduces Lemma 2.
//!
//! All functions return Var(d̂) for sketch size k, i.e. they include the
//! 1/k factor.

use super::decompose::Decomposition;
use super::marginals::cross_moment_table;

/// Cross-moment table `t[a][b] = Σᵢ xᵢᵃ yᵢᵇ` (a, b ≤ 2(p-1)).
pub type CrossTable = Vec<Vec<f64>>;

/// Build the cross-moment table sized for even p.
pub fn table_for(x: &[f64], y: &[f64], p: usize) -> CrossTable {
    cross_moment_table(x, y, 2 * (p - 1))
}

/// General Var(d̂) for the *basic* strategy (one shared R), any even p,
/// projection kurtosis `s` (normal: s = 3; three-point SubG(s): s).
pub fn var_basic_general(p: usize, s: f64, t: &CrossTable, k: usize) -> f64 {
    let dec = Decomposition::new(p).expect("valid p");
    let mut v = 0.0;
    for m in 1..p {
        for mp in 1..p {
            let c = dec.coeff(m) * dec.coeff(mp);
            // E[u_m v_{p-m} u_m' v_{p-m'}] minus the product of means:
            // ⟨x^m, x^m'⟩⟨y^{p-m}, y^{p-m'}⟩  +  ⟨x^m, y^{p-m'}⟩⟨x^m', y^{p-m}⟩
            // + (s-3) Σ x^{m+m'} y^{2p-m-m'}
            v += c
                * (t[m + mp][0] * t[0][2 * p - m - mp]
                    + t[m][p - mp] * t[mp][p - m]
                    + (s - 3.0) * t[m + mp][2 * p - m - mp]);
        }
    }
    v / k as f64
}

/// General Var(d̂) for the *alternative* strategy (independent R per
/// order): cross-order covariances vanish.
pub fn var_alt_general(p: usize, s: f64, t: &CrossTable, k: usize) -> f64 {
    let dec = Decomposition::new(p).expect("valid p");
    let mut v = 0.0;
    for m in 1..p {
        let c = dec.coeff(m).powi(2);
        v += c
            * (t[2 * m][0] * t[0][2 * (p - m)]
                + t[m][p - m] * t[m][p - m]
                + (s - 3.0) * t[2 * m][2 * (p - m)]);
    }
    v / k as f64
}

/// Strategy gap Δ_p = Var(basic) − Var(alternative) (Lemma 3 / §3): the
/// sum of cross-order covariance terms. Negative on non-negative data for
/// p = 4 (proved) and p = 6 (conjectured; E5 checks it empirically).
pub fn delta_general(p: usize, s: f64, t: &CrossTable, k: usize) -> f64 {
    var_basic_general(p, s, t, k) - var_alt_general(p, s, t, k)
}

// --------------------------------------------------------------------
// Paper transcriptions, p = 4
// --------------------------------------------------------------------

/// Lemma 1: Var(d̂_(4)) for the basic strategy with normal projections.
pub fn lemma1_var(t: &CrossTable, k: usize) -> f64 {
    let kf = k as f64;
    let main = 36.0 / kf * (t[4][0] * t[0][4] + t[2][2] * t[2][2])
        + 16.0 / kf * (t[6][0] * t[0][2] + t[3][1] * t[3][1])
        + 16.0 / kf * (t[2][0] * t[0][6] + t[1][3] * t[1][3]);
    main + delta4(t, k)
}

/// The Δ₄ cross-term of Lemma 1 / Eq. (1).
pub fn delta4(t: &CrossTable, k: usize) -> f64 {
    let kf = k as f64;
    -48.0 / kf * (t[5][0] * t[0][3] + t[2][1] * t[3][2])
        - 48.0 / kf * (t[3][0] * t[0][5] + t[1][2] * t[2][3])
        + 32.0 / kf * (t[4][0] * t[0][4] + t[1][1] * t[3][3])
}

/// Lemma 2: Var(d̂_(4),a) for the alternative strategy.
pub fn lemma2_var(t: &CrossTable, k: usize) -> f64 {
    let kf = k as f64;
    36.0 / kf * (t[4][0] * t[0][4] + t[2][2] * t[2][2])
        + 16.0 / kf * (t[6][0] * t[0][2] + t[3][1] * t[3][1])
        + 16.0 / kf * (t[2][0] * t[0][6] + t[1][3] * t[1][3])
}

/// Lemma 4: asymptotic Var(d̂_(4),a,mle) — the margin-aware MLE under the
/// alternative strategy (O(1/k²) terms dropped).
pub fn lemma4_mle_var(t: &CrossTable, k: usize) -> f64 {
    let kf = k as f64;
    let term = |prod: f64, a: f64, c: f64| c / kf * (prod - a * a).powi(2) / (prod + a * a);
    term(t[4][0] * t[0][4], t[2][2], 36.0)
        + term(t[6][0] * t[0][2], t[3][1], 16.0)
        + term(t[2][0] * t[0][6], t[1][3], 16.0)
}

/// Extension of Lemma 4 to any even p (the paper skips the p=6 analysis;
/// each order's MLE is independent under the alternative strategy, so the
/// same per-order shrinkage applies).
pub fn mle_var_general(p: usize, t: &CrossTable, k: usize) -> f64 {
    let dec = Decomposition::new(p).expect("valid p");
    let kf = k as f64;
    (1..p)
        .map(|m| {
            let c = dec.coeff(m).powi(2);
            let prod = t[2 * m][0] * t[0][2 * (p - m)];
            let a = t[m][p - m];
            c / kf * (prod - a * a).powi(2) / (prod + a * a)
        })
        .sum()
}

// --------------------------------------------------------------------
// Paper transcriptions, p = 6
// --------------------------------------------------------------------

/// Lemma 5: Var(d̂_(6)) for the basic strategy with normal projections.
pub fn lemma5_var(t: &CrossTable, k: usize) -> f64 {
    let kf = k as f64;
    let main = 400.0 / kf * (t[6][0] * t[0][6] + t[3][3] * t[3][3])
        + 225.0 / kf * (t[4][0] * t[0][8] + t[2][4] * t[2][4])
        + 225.0 / kf * (t[8][0] * t[0][4] + t[4][2] * t[4][2])
        + 36.0 / kf * (t[2][0] * t[0][10] + t[1][5] * t[1][5])
        + 36.0 / kf * (t[10][0] * t[0][2] + t[5][1] * t[5][1]);
    main + delta6(t, k)
}

/// The Δ₆ cross-term of Lemma 5.
pub fn delta6(t: &CrossTable, k: usize) -> f64 {
    let kf = k as f64;
    (-600.0 * (t[5][0] * t[0][7] + t[3][4] * t[2][3])
        - 600.0 * (t[7][0] * t[0][5] + t[3][2] * t[4][3])
        + 240.0 * (t[4][0] * t[0][8] + t[3][5] * t[1][3])
        + 240.0 * (t[8][0] * t[0][4] + t[3][1] * t[5][3])
        + 450.0 * (t[6][0] * t[0][6] + t[2][2] * t[4][4])
        - 180.0 * (t[3][0] * t[0][9] + t[2][5] * t[1][4])
        - 180.0 * (t[7][0] * t[0][5] + t[2][1] * t[5][4])
        - 180.0 * (t[5][0] * t[0][7] + t[4][5] * t[1][2])
        - 180.0 * (t[9][0] * t[0][3] + t[4][1] * t[5][2])
        + 72.0 * (t[6][0] * t[0][6] + t[1][1] * t[5][5]))
        / kf
}

// --------------------------------------------------------------------
// Paper transcription, sub-Gaussian (Lemma 6)
// --------------------------------------------------------------------

/// Lemma 6: Var(d̂_(4),s) — basic strategy, projections with E r⁴ = s.
pub fn lemma6_var(t: &CrossTable, s: f64, k: usize) -> f64 {
    let kf = k as f64;
    let e = s - 3.0;
    36.0 / kf * (t[4][0] * t[0][4] + t[2][2] * t[2][2] + e * t[4][4])
        + 16.0 / kf * (t[6][0] * t[0][2] + t[3][1] * t[3][1] + e * t[6][2])
        + 16.0 / kf * (t[2][0] * t[0][6] + t[1][3] * t[1][3] + e * t[2][6])
        - 48.0 / kf * (t[5][0] * t[0][3] + t[2][1] * t[3][2] + e * t[5][3])
        - 48.0 / kf * (t[3][0] * t[0][5] + t[1][2] * t[2][3] + e * t[3][5])
        + 32.0 / kf * (t[4][0] * t[0][4] + t[1][1] * t[3][3] + e * t[4][4])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    fn random_pair(g: &mut crate::testkit::Gen, lo: f64) -> (Vec<f64>, Vec<f64>) {
        let n = g.usize_in(2, 40);
        let x: Vec<f64> = (0..n).map(|_| g.f64_in(lo, 1.0)).collect();
        let y: Vec<f64> = (0..n).map(|_| g.f64_in(lo, 1.0)).collect();
        (x, y)
    }

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1e-12)
    }

    #[test]
    fn lemma1_matches_general_derivation() {
        testkit::check(100, |g| {
            let (x, y) = random_pair(g, -1.0);
            let t = table_for(&x, &y, 4);
            let paper = lemma1_var(&t, 16);
            let general = var_basic_general(4, 3.0, &t, 16);
            crate::prop_assert!(close(paper, general), "paper={paper} general={general}");
        });
    }

    #[test]
    fn lemma2_matches_general_derivation() {
        testkit::check(100, |g| {
            let (x, y) = random_pair(g, -1.0);
            let t = table_for(&x, &y, 4);
            crate::prop_assert!(
                close(lemma2_var(&t, 8), var_alt_general(4, 3.0, &t, 8)),
                "lemma2 mismatch"
            );
        });
    }

    #[test]
    fn lemma5_matches_general_derivation() {
        testkit::check(100, |g| {
            let (x, y) = random_pair(g, -1.0);
            let t = table_for(&x, &y, 6);
            let paper = lemma5_var(&t, 32);
            let general = var_basic_general(6, 3.0, &t, 32);
            crate::prop_assert!(close(paper, general), "paper={paper} general={general}");
        });
    }

    #[test]
    fn lemma6_matches_general_derivation() {
        testkit::check(100, |g| {
            let (x, y) = random_pair(g, -1.0);
            let s = g.f64_in(1.0, 20.0);
            let t = table_for(&x, &y, 4);
            let paper = lemma6_var(&t, s, 4);
            let general = var_basic_general(4, s, &t, 4);
            crate::prop_assert!(close(paper, general), "s={s} paper={paper} general={general}");
        });
    }

    #[test]
    fn lemma6_at_s3_is_lemma1() {
        testkit::check(50, |g| {
            let (x, y) = random_pair(g, -1.0);
            let t = table_for(&x, &y, 4);
            crate::prop_assert!(close(lemma6_var(&t, 3.0, 7), lemma1_var(&t, 7)), "s=3");
        });
    }

    #[test]
    fn delta4_is_lemma1_minus_lemma2() {
        testkit::check(50, |g| {
            let (x, y) = random_pair(g, -1.0);
            let t = table_for(&x, &y, 4);
            let d = lemma1_var(&t, 5) - lemma2_var(&t, 5);
            crate::prop_assert!(close(d, delta4(&t, 5)), "delta4 identity");
        });
    }

    #[test]
    fn lemma3_delta4_nonpositive_on_nonneg_data() {
        // The paper's Lemma 3 (proved via AM-GM): Δ4 <= 0 when x, y >= 0.
        testkit::check(300, |g| {
            let (x, y) = random_pair(g, 0.0);
            let t = table_for(&x, &y, 4);
            let d = delta4(&t, 1);
            crate::prop_assert!(d <= 1e-9 * t[4][0].max(1.0), "delta4={d} > 0");
        });
    }

    #[test]
    fn delta4_can_be_positive_on_signed_data() {
        // Paper §2.2: all x negative, all y positive => Δ4 >= 0.
        let x = vec![-0.5, -1.0, -0.25, -0.8];
        let y = vec![0.7, 0.3, 0.9, 0.2];
        let t = table_for(&x, &y, 4);
        assert!(delta4(&t, 1) >= 0.0, "expected Δ4 >= 0, got {}", delta4(&t, 1));
    }

    #[test]
    fn delta6_conjecture_nonpositive_on_nonneg_data() {
        // §3: "we believe Δ6 <= 0 [for non-negative data]" — checked here.
        testkit::check(300, |g| {
            let (x, y) = random_pair(g, 0.0);
            let t = table_for(&x, &y, 6);
            let d = delta6(&t, 1);
            crate::prop_assert!(d <= 1e-9 * t[6][0].max(1.0), "delta6={d} > 0");
        });
    }

    #[test]
    fn mle_never_worse_than_plain_alternative() {
        // (prod - a²)²/(prod + a²) <= prod + a² for every order term.
        testkit::check(100, |g| {
            let (x, y) = random_pair(g, -1.0);
            let t = table_for(&x, &y, 4);
            crate::prop_assert!(
                lemma4_mle_var(&t, 9) <= lemma2_var(&t, 9) * (1.0 + 1e-12),
                "MLE var exceeds plain var"
            );
        });
    }

    #[test]
    fn mle_general_matches_lemma4_at_p4() {
        testkit::check(50, |g| {
            let (x, y) = random_pair(g, -1.0);
            let t = table_for(&x, &y, 4);
            crate::prop_assert!(
                close(mle_var_general(4, &t, 3), lemma4_mle_var(&t, 3)),
                "general MLE vs Lemma 4"
            );
        });
    }

    #[test]
    fn variance_scales_as_one_over_k() {
        let x = vec![0.1, 0.4, 0.8];
        let y = vec![0.9, 0.2, 0.5];
        let t = table_for(&x, &y, 4);
        assert!(close(lemma1_var(&t, 1) / 10.0, lemma1_var(&t, 10)));
    }
}
